"""Color & multi-channel demo: superpixel-compressed FCM.

Segments an RGB phantom and a three-channel (T1/T2/PD-like) stack —
workloads the scalar histogram path cannot touch — through the serving
engine's ``method="superpixel"`` route (SLIC compression on ingest,
weighted vector FCM over ~K superpixel rows) and the uncompressed
``method="pixel"`` reference, then reports per-tissue DSC and the
N -> K compression ratio. Outputs land in the gitignored
``examples/out/``.

  PYTHONPATH=src python examples/segment_color.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.configs.fcm_brainweb import make_config
from repro.data import phantom
from repro.serving.fcm_engine import FCMServeEngine

SIZE = 128


def write_ppm(path, img):
    img = np.asarray(img, np.uint8)
    with open(path, "wb") as f:
        f.write(b"P6\n%d %d\n255\n" % (img.shape[1], img.shape[0]))
        f.write(img.tobytes())


def main():
    out_dir = os.path.join(os.path.dirname(__file__), "out")
    os.makedirs(out_dir, exist_ok=True)
    job = make_config()
    eng = FCMServeEngine(job.fcm, superpixel_cfg=job.superpixel)

    workloads = [
        ("rgb", phantom.CLASS_MEANS_RGB,
         *phantom.phantom_slice_rgb(SIZE, SIZE, noise=6.0, seed=7)),
        ("t1t2pd", phantom.CLASS_MEANS_MULTI,
         *phantom.phantom_slice_channels(SIZE, SIZE, noise=6.0, seed=7)),
    ]
    for name, class_means, img, gt in workloads:
        n = img.shape[0] * img.shape[1]
        r_sp = eng.segment([img], method="superpixel")[0]
        r_px = eng.segment([img], method="pixel")[0]
        k = int(np.asarray(eng.superpixel_cfg.n_segments))
        print(f"{name}: {img.shape} -> ~{k} superpixels "
              f"({n / k:.0f}x compression)")
        for tag, res in [("superpixel", r_sp), ("pixel", r_px)]:
            pred = phantom.match_labels_to_means(res.labels, res.centers,
                                                 class_means)
            dscs = phantom.dice_per_class(pred, gt)
            print(f"  {tag:10s} ({res.n_iters:3d} iters) DSC:",
                  {c: round(d, 3) for c, d in zip(phantom.CLASS_NAMES,
                                                  dscs)})
            if name == "rgb":
                colors = phantom.CLASS_MEANS_RGB.astype(np.uint8)
                write_ppm(os.path.join(out_dir, f"color_{tag}.ppm"),
                          colors[pred])
        if name == "rgb":
            write_ppm(os.path.join(out_dir, "color_input.ppm"), img)

    s = eng.stats()
    print("route mix:", s["method_requests"],
          f"| compress {s['compress_seconds'] * 1e3:.0f} ms, "
          f"superpixel fit {s['superpixel_seconds'] * 1e3:.0f} ms, "
          f"pixel fit {s['pixel_seconds'] * 1e3:.0f} ms")
    print(f"wrote {out_dir}/color_input.ppm and color_*.ppm")


if __name__ == "__main__":
    main()
