"""End-to-end training driver: a ~100M-param llama-style model for a few
hundred steps on the synthetic pipeline, with checkpoint/resume and the
straggler watchdog — the full substrate on one CPU device.

  PYTHONPATH=src python examples/train_lm.py --steps 200
  PYTHONPATH=src python examples/train_lm.py --steps 300   # resumes @200
"""
import argparse
import dataclasses
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp

from repro import configs
from repro.data import pipeline
from repro.training import checkpoint as ckpt
from repro.training import elastic
from repro.training import optimizer as opt
from repro.training import train_loop as tl


def make_100m_config():
    """~100M params: llama-family, narrow (113M with tied embeddings)."""
    base = configs.get_config("llama3.2-1b")
    return dataclasses.replace(
        base, name="llama-100m", n_layers=12, d_model=768, n_heads=12,
        n_kv_heads=4, head_dim=64, d_ff=3072, vocab_size=8192,
        dtype=jnp.float32, remat=False)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt-dir", default=os.path.join(
        os.path.dirname(__file__), "out", "ckpt_100m"))
    ap.add_argument("--ckpt-every", type=int, default=50)
    args = ap.parse_args()

    cfg = make_100m_config()
    shape = configs.ShapeConfig("train", "train", args.seq, args.batch)
    tcfg = tl.TrainConfig(optimizer=opt.OptimizerConfig(
        lr=1e-3, warmup_steps=20, total_steps=max(args.steps, 100)))

    n_params = sum(x.size for x in jax.tree_util.tree_leaves(
        jax.eval_shape(lambda k: tl.init_state(k, cfg, tcfg),
                       jax.ShapeDtypeStruct((2,), jnp.uint32))["params"]))
    print(f"model: {cfg.name}, {n_params / 1e6:.1f}M params")

    start = ckpt.latest_step(args.ckpt_dir) if os.path.isdir(
        args.ckpt_dir) else None
    state = tl.init_state(jax.random.PRNGKey(0), cfg, tcfg)
    if start is not None:
        state, manifest = ckpt.load_checkpoint(args.ckpt_dir, state)
        print(f"resumed from step {manifest['step']}")

    step_fn = jax.jit(tl.make_train_step(cfg, tcfg), donate_argnums=(0,))
    saver = ckpt.AsyncCheckpointer(args.ckpt_dir, keep=3)
    timer = elastic.StepTimer(threshold=3.0)

    first = int(state["step"])
    for i, batch in enumerate(pipeline.batches(cfg, shape, first)):
        step = first + i
        if step >= args.steps:
            break
        timer.start()
        state, metrics = step_fn(
            state, {k: jnp.asarray(v) for k, v in batch.items()})
        rebalance = timer.stop()
        if step % 20 == 0 or step == args.steps - 1:
            print(f"step {step:5d} loss={float(metrics['loss']):.4f} "
                  f"ppl={float(metrics['perplexity']):.1f} "
                  f"gnorm={float(metrics['grad_norm']):.2f} "
                  f"lr={float(metrics['lr']):.2e}"
                  + (" [straggler-flagged]" if rebalance else ""))
        if step > 0 and step % args.ckpt_every == 0:
            saver.save(state, step)
    saver.save(state, int(state["step"]))
    saver.wait()
    print(f"done at step {int(state['step'])}; checkpoints in "
          f"{args.ckpt_dir}")


if __name__ == "__main__":
    main()
