"""Batched serving demo: prefill + step-synchronous greedy decode with
the KV cache (the serve_step the decode dry-run shapes lower). Verifies
the decoded continuation against teacher-forced argmax.

  PYTHONPATH=src python examples/serve_lm.py
"""
import dataclasses
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np
import jax
import jax.numpy as jnp

from repro import configs
from repro.models import lm
from repro.serving import ServeEngine


def main():
    cfg = dataclasses.replace(
        configs.get_config("llama3.2-1b").reduced(),
        name="serve-demo", n_layers=4, d_model=128, n_heads=4,
        n_kv_heads=2, head_dim=32, d_ff=256, vocab_size=1024)
    params = lm.init_params(jax.random.PRNGKey(7), cfg)

    batch, prompt_len, n_new = 4, 12, 20
    rng = np.random.default_rng(0)
    prompts = rng.integers(0, cfg.vocab_size,
                           (batch, prompt_len)).astype(np.int32)

    engine = ServeEngine(cfg, params, max_len=prompt_len + n_new,
                         batch_size=batch)
    out = engine.generate(prompts, n_new=n_new, temperature=0.0)
    print("prompts:", prompts[:, :8], "...")
    print("generated:", out[:, prompt_len:])

    # verify against teacher forcing: feed the generated stream through
    # the train forward; argmax at each position must reproduce it.
    logits, _ = jax.jit(lambda p, t: lm.forward(p, t, cfg))(
        params, jnp.asarray(out[:, :-1]))
    greedy = np.asarray(jnp.argmax(logits, -1))[:, prompt_len - 1:]
    agree = (greedy == out[:, prompt_len:]).mean()
    print(f"teacher-forced agreement: {agree:.3f}")
    assert agree == 1.0, "decode path diverged from train forward"
    print("serving OK")


if __name__ == "__main__":
    main()
