"""Quickstart: the paper's pipeline end-to-end on one axial slice.

Segments a synthetic brain phantom into WM/GM/CSF/background through the
unified solver core — the SAME ``solve(pixel_problem(x))`` entry point
drives the paper-faithful staged pipeline (``backend="staged"``) and the
fused device-resident fixed point (the default) — reports DSC against
ground truth for both (paper Fig. 7), and writes PGM images you can open
with any viewer.

  PYTHONPATH=src python examples/quickstart.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.core import fcm as F
from repro.core import solver as SV
from repro.data import phantom


def write_pgm(path, img):
    img = np.asarray(img, np.uint8)
    with open(path, "wb") as f:
        f.write(b"P5\n%d %d\n255\n" % (img.shape[1], img.shape[0]))
        f.write(img.tobytes())


def main():
    out_dir = os.path.join(os.path.dirname(__file__), "out")
    os.makedirs(out_dir, exist_ok=True)

    img, gt = phantom.phantom_slice(217, 181, slice_pos=0.5, seed=96)
    x = img.ravel().astype(np.float32)
    print(f"phantom slice: {img.shape}, {x.size / 1024:.0f} KB")

    # The paper "manually selects" the four clusters; we use the
    # deterministic linspace init for both paths (pure random membership
    # init can collapse clusters on some seeds).
    import jax.numpy as jnp
    u0 = F.update_membership(jnp.asarray(x),
                             F.linspace_centers(jnp.asarray(x), 4), 2.0)
    cfg = F.FCMConfig()
    problem = SV.pixel_problem(x, cfg)
    base = SV.solve(problem, cfg, backend="staged", u0=u0)
    fused = SV.solve(problem, cfg)
    print(f"baseline (paper-faithful): {base.n_iters} iters, "
          f"centers={np.sort(np.asarray(base.centers)).round(1)}")
    print(f"fused (device-resident):   {fused.n_iters} iters, "
          f"centers={np.sort(np.asarray(fused.centers)).round(1)}")

    for tag, res in [("baseline", base), ("fused", fused)]:
        pred = phantom.match_labels_to_classes(
            np.asarray(res.labels), np.asarray(res.centers))
        dscs = phantom.dice_per_class(pred.reshape(img.shape), gt)
        print(f"  {tag} DSC:", {c: round(d, 4) for c, d in
                                zip(phantom.CLASS_NAMES, dscs)})
        seg = (pred.reshape(img.shape) * 85).astype(np.uint8)
        write_pgm(os.path.join(out_dir, f"segmented_{tag}.pgm"), seg)
    write_pgm(os.path.join(out_dir, "input.pgm"), img)
    print(f"wrote {out_dir}/input.pgm and segmented_*.pgm")


if __name__ == "__main__":
    main()
