"""Multi-slice (3-D volume) segmentation with the distributed FCM and
elastic restart: fits the whole volume's pixels as one distributed
dataset (histogram path: one 256-float psum total), checkpoints centers,
then simulates a node-failure restart resuming from the centers alone —
the FCM state is c floats, so recovery is trivial at any scale.

  PYTHONPATH=src python examples/segment_volume.py
"""
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np
import jax

from repro.core import fcm as F
from repro.core import solver as SV
from repro.data import phantom


def main():
    out_dir = os.path.join(os.path.dirname(__file__), "out")
    os.makedirs(out_dir, exist_ok=True)

    # a 24-slice volume
    slices, gts = [], []
    for z in range(24):
        img, gt = phantom.phantom_slice(128, 128,
                                        slice_pos=0.3 + 0.4 * z / 24,
                                        seed=z)
        slices.append(img)
        gts.append(gt)
    vol = np.stack(slices)
    x = vol.ravel().astype(np.float32)
    print(f"volume: {vol.shape} = {x.size / 1024:.0f} KB")

    cfg = F.FCMConfig(max_iters=300)
    hres = SV.solve(SV.histogram_problem(x, cfg), cfg)
    res = F.FCMResult(centers=hres.centers,
                      labels=F.labels_from_centers(x, hres.centers),
                      n_iters=hres.n_iters, final_delta=hres.final_delta)
    print(f"histogram FCM converged in {res.n_iters} iters; "
          f"centers={np.sort(np.asarray(res.centers)).round(1)}")

    # checkpoint = the centers (plus config); restart needs nothing else
    ckpt = {"centers": np.asarray(res.centers).tolist(), "c": 4, "m": 2.0}
    ckpt_path = os.path.join(out_dir, "fcm_centers.json")
    with open(ckpt_path, "w") as f:
        json.dump(ckpt, f)

    # --- simulated failure & restart ---
    restored = json.load(open(ckpt_path))
    v0 = np.asarray(restored["centers"], np.float32)
    res2 = SV.solve(SV.pixel_problem(x, v0=v0), eps=cfg.eps, max_iters=50)
    print(f"restart from centers: {res2.n_iters} extra iters "
          f"(already converged)" if res2.n_iters <= 2 else "")

    dsc = phantom.dice_per_class(
        phantom.match_labels_to_classes(
            np.asarray(res.labels), np.asarray(res.centers)).reshape(
            vol.shape),
        np.stack(gts))
    print("volume DSC:", {c: round(d, 4) for c, d in
                          zip(phantom.CLASS_NAMES, dsc)})
    assert min(dsc) > 0.85
    print("volume segmentation OK")


if __name__ == "__main__":
    main()
