"""Noisy-MRI demo: plain FCM vs spatially-regularized FCM_S.

Corrupts a phantom slice with heavy Gaussian + salt-and-pepper noise,
segments it with the histogram fast path (plain FCM, spatial-blind) and
with the spatial solver route (8-neighbor FCM_S, both
through the serving engine's ``method="spatial"`` route and directly),
then reports per-tissue DSC. Outputs land in the gitignored
``examples/out/``.

  PYTHONPATH=src python examples/segment_noisy.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.configs.fcm_brainweb import make_config
from repro.data import phantom
from repro.serving.fcm_engine import FCMServeEngine


def write_pgm(path, img):
    img = np.asarray(img, np.uint8)
    with open(path, "wb") as f:
        f.write(b"P5\n%d %d\n255\n" % (img.shape[1], img.shape[0]))
        f.write(img.tobytes())


def main():
    out_dir = os.path.join(os.path.dirname(__file__), "out")
    os.makedirs(out_dir, exist_ok=True)
    job = make_config()

    sigma, impulse = job.noise_levels[-1]
    img, gt = phantom.noisy_phantom_slice(217, 181, noise=sigma,
                                          impulse=impulse, seed=7)
    print(f"noisy slice: {img.shape}, gaussian sigma={sigma}, "
          f"impulse={impulse:.0%}")

    eng = FCMServeEngine(job.fcm, spatial_cfg=job.spatial)
    plain = eng.segment([img])[0]                       # histogram fast path
    spatial = eng.segment([img], method="spatial")[0]   # FCM_S route

    for tag, res in [("plain-histogram", plain), ("spatial-fcm_s", spatial)]:
        pred = phantom.match_labels_to_classes(res.labels, res.centers)
        dscs = phantom.dice_per_class(pred, gt)
        print(f"  {tag:16s} ({res.n_iters} iters) DSC:",
              {c: round(d, 3) for c, d in zip(phantom.CLASS_NAMES, dscs)})
        write_pgm(os.path.join(out_dir, f"noisy_{tag}.pgm"),
                  (pred * 85).astype(np.uint8))
    write_pgm(os.path.join(out_dir, "noisy_input.pgm"), img)
    s = eng.stats()
    print(f"engine: {s['requests']} requests, {s['spatial_requests']} "
          f"spatial, cache entries {s['cache_entries']}")
    print(f"wrote {out_dir}/noisy_input.pgm and noisy_*.pgm")


if __name__ == "__main__":
    main()
