"""Serve FCM segmentation over a synthetic multi-slice phantom volume.

Simulates the production traffic pattern the engine is built for: a
stream of heterogeneous-size 8-bit slices (a volumetric study plus some
repeat submissions) hits :class:`repro.serving.FCMServeEngine`, which
histograms each request on ingest, buckets the queue into fixed batch
shapes, fits every batch in one vmapped device call, and answers repeats
from the histogram-keyed LRU cache.

  PYTHONPATH=src python examples/serve_segmentation.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np  # noqa: E402

from repro.configs.fcm_brainweb import make_config  # noqa: E402
from repro.data import phantom  # noqa: E402
from repro.serving import FCMServeEngine  # noqa: E402


def main():
    job = make_config()
    engine = FCMServeEngine(job.fcm, batch_sizes=job.serving_batch_sizes,
                            spatial_cfg=job.spatial)

    # A 40-slice study with varying anatomy + a couple of odd-size scouts.
    slices, gts = [], []
    for z in range(40):
        img, gt = phantom.phantom_slice(
            128, 128, slice_pos=0.25 + 0.5 * z / 40,
            noise=3.0 + (z % 4), seed=z)
        slices.append(img)
        gts.append(gt)
    scouts = [phantom.phantom_slice(96, 160, slice_pos=0.5, seed=100)[0],
              phantom.phantom_slice(64, 64, slice_pos=0.45, seed=101)[0]]

    results = engine.segment(slices + scouts)
    print(f"served {len(results)} requests in "
          f"{engine.stats()['batches']} batched fits")

    # Quality check against ground truth on the study slices.
    dscs = []
    for r, gt in zip(results[:40], gts):
        pred = phantom.match_labels_to_classes(r.labels, r.centers)
        dscs.append(min(phantom.dice_per_class(pred, gt)))
    print(f"worst per-slice min-DSC over the study: {min(dscs):.4f}")
    assert min(dscs) > 0.80

    # Re-submission of the whole study: served from cache, no fits.
    before = engine.stats()["batches"]
    again = engine.segment(slices)
    assert all(r.cache_hit for r in again)
    assert engine.stats()["batches"] == before
    print("re-submitted study: 100% cache hits, 0 new fits")

    # Spatial traffic batches across requests too (route registry): 8
    # same-shape noisy slices -> ONE per-lane-masked stencil solve.
    noisy = [phantom.noisy_phantom_slice(64, 64, noise=10.0, impulse=0.04,
                                         seed=z)[0] for z in range(8)]
    sres = engine.segment(noisy, method="spatial")
    s = engine.stats()
    assert s["spatial_batches"] == 1 and s["spatial_batched_images"] == 8
    print(f"spatial study: {len(sres)} FCM_S requests served in "
          f"{s['spatial_batches']} batched stencil solve")

    print(f"stats: requests={s['requests']} cache_hit_rate="
          f"{s['cache_hit_rate']:.2f} batched_images={s['batched_images']} "
          f"padded_lanes={s['padded_lanes']} "
          f"fit_throughput={s['images_per_sec']:.1f} img/s")
    print("serve_segmentation OK")


if __name__ == "__main__":
    main()
