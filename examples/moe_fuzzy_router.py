"""The paper bridge: FCM fuzzy membership as an MoE router.

Experts act as cluster centers over token embeddings; the gate is the
FCM membership (Eq. 4, m=2) truncated to top-k. This demo trains the
same tiny MoE LM with the standard softmax router and with the fcm
router and compares losses + expert load balance (fuzzy memberships are
naturally normalized, so the router needs no load-balance loss to avoid
collapse).

  PYTHONPATH=src python examples/moe_fuzzy_router.py
"""
import dataclasses
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np
import jax
import jax.numpy as jnp

from repro import configs
from repro.data import pipeline
from repro.training import optimizer as opt
from repro.training import train_loop as tl


def run(router: str, steps: int = 60):
    base = configs.get_config("granite-moe-3b-a800m").reduced()
    cfg = dataclasses.replace(
        base, name=f"moe-{router}",
        moe=dataclasses.replace(base.moe, router=router))
    tcfg = tl.TrainConfig(optimizer=opt.OptimizerConfig(
        lr=2e-3, warmup_steps=10, total_steps=steps))
    state = tl.init_state(jax.random.PRNGKey(0), cfg, tcfg)
    step_fn = jax.jit(tl.make_train_step(cfg, tcfg), donate_argnums=(0,))
    shape = configs.ShapeConfig("t", "train", 64, 8)
    losses = []
    for i, batch in enumerate(pipeline.batches(cfg, shape, 0)):
        if i >= steps:
            break
        state, m = step_fn(state,
                           {k: jnp.asarray(v) for k, v in batch.items()})
        losses.append(float(m["loss"]))
    # expert load distribution on a held-out batch
    from repro.models import moe as M
    batch = pipeline.make_batch(cfg, shape, 999)
    from repro.models import lm
    x, _ = lm.forward(state["params"], jnp.asarray(batch["tokens"]), cfg,
                      return_features=True)
    blk = jax.tree_util.tree_map(lambda a: a[0],
                                 state["params"]["groups"])["b0"]
    idx, gates, _ = M._route(x.reshape(-1, cfg.d_model),
                             blk["ffn"]["router"], cfg)
    counts = np.bincount(np.asarray(idx).ravel(),
                         minlength=cfg.moe.n_experts)
    balance = counts.min() / max(counts.max(), 1)
    return losses, balance


def main():
    for router in ("softmax", "fcm"):
        losses, balance = run(router)
        print(f"router={router:8s} loss {losses[0]:.3f} -> {losses[-1]:.3f}"
              f"  expert load min/max={balance:.2f}")
    print("fuzzy-membership routing trains comparably; see DESIGN.md §5")


if __name__ == "__main__":
    main()
