# The paper's primary contribution: Fuzzy C-Means, paper-faithful and
# beyond-paper variants. See DESIGN.md §2 and §6.
from . import batched, distributed, fcm, histogram, sequential, spatial  # noqa: F401,E501
from .fcm import (FCMConfig, FCMResult, defuzzify, fit_baseline,  # noqa: F401
                  fit_fused, labels_from_centers, objective,
                  update_centers, update_membership)
from .histogram import fit_histogram  # noqa: F401
from .distributed import fit_sharded  # noqa: F401
from .batched import (BatchedFCMResult, fit_batched,  # noqa: F401
                      fit_batched_pixels, fit_batched_sharded)
from .spatial import SpatialFCMConfig, fit_spatial  # noqa: F401
