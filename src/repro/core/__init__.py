# The paper's primary contribution: Fuzzy C-Means. One solver core
# (core/solver.py) runs every variant — pixels, histogram, superpixel
# rows, FCM_S stencils, single or batched — and the legacy fit_* entry
# points survive as deprecated thin adapters. See DESIGN.md §2 and §6.
from . import (batched, distributed, fcm, histogram, sequential,  # noqa: F401
               solver, spatial, vector_fcm)
from .solver import (FCMProblem, StencilSpec, BatchedFCMResult,  # noqa: F401
                     batch_problems, histogram_problem, pixel_problem,
                     solve, solve_batched, solve_staged, spatial_problem,
                     vector_problem, weighted_center_step)
from .fcm import (FCMConfig, FCMResult, defuzzify, fit_baseline,  # noqa: F401
                  fit_fused, labels_from_centers, objective,
                  update_centers, update_membership)
from .histogram import fit_histogram  # noqa: F401
from .distributed import fit_sharded  # noqa: F401
from .batched import (fit_batched,  # noqa: F401
                      fit_batched_pixels, fit_batched_sharded)
from .spatial import SpatialFCMConfig, fit_spatial  # noqa: F401
from .vector_fcm import fit_vector_fcm, fit_vector_batched  # noqa: F401
