"""Fuzzy C-Means core in JAX.

Layout convention: memberships are **cluster-major**, ``u[j, i]`` = degree
of pixel ``i`` in cluster ``j``, shape ``(c, N)``. Cluster-major keeps the
pixel axis minor-most so TPU tiles are (8, 128)-lane aligned; the paper's
1-D coalesced layout maps to the same idea on CUDA.

Features ``x`` may be ``(N,)`` (grayscale, the paper's case) or ``(N, F)``.
Centers are ``(c,)`` or ``(c, F)`` correspondingly.

This module owns the elementary FCM math (Eqs. 1, 3, 4, inits,
defuzzification) that every variant shares. The fit entry points
:func:`fit_baseline` (paper-faithful staged pipeline, host convergence
test) and :func:`fit_fused` (device-resident fused fixed point) are
**deprecated thin adapters** over the unified solver core — build an
:class:`repro.core.solver.FCMProblem` and call
:func:`repro.core.solver.solve` instead (``backend="staged"`` for the
paper-faithful pipeline).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

_D2_FLOOR = 1e-12  # distance clamp before the negative-power; exact zeros
                   # are handled separately with a one-hot membership.


@dataclasses.dataclass(frozen=True)
class FCMConfig:
    """Hyper-parameters; defaults follow the paper (c=4, m=2, eps=0.005)."""
    n_clusters: int = 4
    m: float = 2.0
    eps: float = 5e-3
    max_iters: int = 300
    seed: int = 0
    # 'membership' reproduces the paper's ||u_new - u_old||_inf < eps test;
    # 'centers' is the device-resident equivalent used by the fused path.
    convergence: str = "membership"


# ---------------------------------------------------------------------------
# Elementary updates (Eqs. 3 and 4 of the paper)
# ---------------------------------------------------------------------------

def _as_2d(x: jax.Array) -> jax.Array:
    return x[:, None] if x.ndim == 1 else x


def pairwise_d2(x: jax.Array, v: jax.Array) -> jax.Array:
    """Squared Euclidean distances, shape (c, N)."""
    x2 = _as_2d(x)            # (N, F)
    v2 = _as_2d(v)            # (c, F)
    d2 = jnp.sum((v2[:, None, :] - x2[None, :, :]) ** 2, axis=-1)
    return d2


def membership_from_d2(d2: jax.Array, m: float) -> jax.Array:
    """Eq. 4: u_ji = d_ji^(-2/(m-1)) / sum_k d_ki^(-2/(m-1)); (c, N)."""
    p = jnp.clip(d2, _D2_FLOOR, None) ** (-1.0 / (m - 1.0))
    u = p / jnp.sum(p, axis=0, keepdims=True)
    # Exact-zero distances (pixel sits on a center — common for uint8 data):
    # membership mass goes entirely to the zero-distance cluster(s).
    zero = (d2 <= 0.0)
    any_zero = jnp.any(zero, axis=0, keepdims=True)
    u_zero = zero.astype(u.dtype) / jnp.maximum(
        jnp.sum(zero, axis=0, keepdims=True), 1).astype(u.dtype)
    return jnp.where(any_zero, u_zero, u)


def update_membership(x: jax.Array, v: jax.Array, m: float) -> jax.Array:
    """Eq. 4 from pixels + centers; (c, N)."""
    return membership_from_d2(pairwise_d2(x, v), m)


def center_terms(x: jax.Array, u: jax.Array, m: float):
    """Per-pixel numerator/denominator terms of Eq. 3 (the paper's first
    CUDA kernel): no summation yet. Returns (num_terms (c, N, F),
    den_terms (c, N))."""
    um = u ** m
    num_terms = um[:, :, None] * _as_2d(x)[None, :, :]
    return num_terms, um


def update_centers(x: jax.Array, u: jax.Array, m: float) -> jax.Array:
    """Eq. 3: v_j = sum_i u_ji^m x_i / sum_i u_ji^m. Shape matches x's
    feature layout: (c,) for (N,) input, (c, F) for (N, F)."""
    num_terms, den_terms = center_terms(x, u, m)
    v = jnp.sum(num_terms, axis=1) / jnp.maximum(
        jnp.sum(den_terms, axis=1)[:, None], _D2_FLOOR)
    return v[:, 0] if x.ndim == 1 else v


def objective(x: jax.Array, u: jax.Array, v: jax.Array, m: float) -> jax.Array:
    """Eq. 1: J = sum_ij u_ji^m d_ji^2."""
    return jnp.sum((u ** m) * pairwise_d2(x, v))


def defuzzify(u: jax.Array) -> jax.Array:
    """Maximal-membership hard assignment; (N,) int32 labels."""
    return jnp.argmax(u, axis=0).astype(jnp.int32)


def labels_from_centers(x: jax.Array, v: jax.Array) -> jax.Array:
    """argmin distance == argmax membership for any m > 1."""
    return jnp.argmin(pairwise_d2(x, v), axis=0).astype(jnp.int32)


# ---------------------------------------------------------------------------
# Initialization
# ---------------------------------------------------------------------------

def random_membership(key: jax.Array, c: int, n: int,
                      dtype=jnp.float32) -> jax.Array:
    """Paper Step 2: random memberships, rows normalized to sum to 1."""
    u = jax.random.uniform(key, (c, n), dtype=dtype, minval=1e-3, maxval=1.0)
    return u / jnp.sum(u, axis=0, keepdims=True)


def linspace_centers(x: jax.Array, c: int) -> jax.Array:
    """Deterministic center init: c points evenly spaced in [min, max].
    Needs only a min/max reduction, so it distributes with one tiny psum."""
    x2 = _as_2d(x)
    lo = jnp.min(x2, axis=0)
    hi = jnp.max(x2, axis=0)
    frac = (jnp.arange(c, dtype=x2.dtype) + 0.5) / c
    v = lo[None, :] + frac[:, None] * (hi - lo)[None, :]
    return v[:, 0] if x.ndim == 1 else v


# ---------------------------------------------------------------------------
# Fit paths
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class FCMResult:
    centers: jax.Array          # (c,) or (c, F)
    labels: jax.Array           # (N,) int32
    n_iters: int
    final_delta: float
    membership: Optional[jax.Array] = None   # (c, N) if kept
    #: False when the solve exhausted max_iters without meeting its
    #: center-movement (or staged-membership) tolerance.
    converged: bool = True
    #: False when the returned centers contain NaN/Inf.
    healthy: bool = True


# --- paper-faithful staged pipeline -----------------------------------------

@partial(jax.jit, static_argnames=("m",))
def _stage_terms(x, u, m):
    # CUDA kernel #1: heavy per-pixel math, results materialized.
    return center_terms(x, u, m)


@jax.jit
def _stage_reduce_num(num_terms):
    # CUDA kernel #2: tree-reduce numerator (per cluster).
    return jnp.sum(num_terms, axis=1)


@jax.jit
def _stage_reduce_den(den_terms):
    # CUDA kernel #3: tree-reduce denominator (per cluster).
    return jnp.sum(den_terms, axis=1)


@jax.jit
def _stage_combine(num, den):
    # CUDA kernel #4 (single thread in the paper): final division on device.
    return num / jnp.maximum(den[:, None], _D2_FLOOR)


@partial(jax.jit, static_argnames=("m",))
def _stage_membership(x, v, m):
    # The one-kernel membership phase (paper §4.3).
    return update_membership(x, v, m)


def fit_baseline(x: jax.Array, cfg: FCMConfig = FCMConfig(),
                 use_pallas: bool = False,
                 u0: Optional[jax.Array] = None) -> FCMResult:
    """DEPRECATED alias for the paper-faithful staged pipeline — use
    ``solver.solve(solver.pixel_problem(x, cfg), backend="staged")``.

    Staged 'kernels', membership in HBM between stages, host-side
    convergence test each iteration (the paper copies the membership
    array back to the host to test it). With ``use_pallas=True`` the
    per-stage math runs through the Pallas kernels in
    :mod:`repro.kernels` (interpret mode on CPU)."""
    from . import solver as SV
    SV.warn_deprecated("fit_baseline",
                       "solver.solve(pixel_problem(x), backend='staged')")
    return SV.solve_staged(SV.pixel_problem(x, cfg), eps=cfg.eps,
                           max_iters=cfg.max_iters, seed=cfg.seed, u0=u0,
                           keep_membership=True, use_pallas=use_pallas)


# --- fused, device-resident path ---------------------------------------------

@partial(jax.jit, static_argnames=("m",))
def fused_center_step(x: jax.Array, v: jax.Array, m: float) -> jax.Array:
    """One v -> v' fixed-point step with Eq. 4 substituted into Eq. 3;
    memberships exist only as registers/VMEM inside the step. (The
    unit-weight scalar case of
    :func:`repro.core.solver.weighted_center_step`.)"""
    u = update_membership(x, v, m)
    return update_centers(x, u, m)


def _while_centers(step, v0, eps, max_iters):
    """Backward-compat alias: THE convergence loop now lives in
    :func:`repro.core.solver.while_centers`."""
    from . import solver as SV
    return SV.while_centers(step, v0, eps, max_iters)


def fit_fused(x: jax.Array, cfg: FCMConfig = FCMConfig(),
              v0: Optional[jax.Array] = None,
              keep_membership: bool = False) -> FCMResult:
    """DEPRECATED alias for the fused device-resident fit — use
    ``solver.solve(solver.pixel_problem(x, cfg))``.

    Device-resident while_loop over the fused center iteration,
    deterministic linspace init, center-movement convergence."""
    from . import solver as SV
    SV.warn_deprecated("fit_fused", "solver.solve(pixel_problem(x, cfg))")
    return SV.solve(SV.pixel_problem(x, cfg, v0=v0), cfg,
                    backend="reference", keep_membership=keep_membership)
