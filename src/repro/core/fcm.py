"""Fuzzy C-Means core in JAX.

Layout convention: memberships are **cluster-major**, ``u[j, i]`` = degree
of pixel ``i`` in cluster ``j``, shape ``(c, N)``. Cluster-major keeps the
pixel axis minor-most so TPU tiles are (8, 128)-lane aligned; the paper's
1-D coalesced layout maps to the same idea on CUDA.

Features ``x`` may be ``(N,)`` (grayscale, the paper's case) or ``(N, F)``.
Centers are ``(c,)`` or ``(c, F)`` correspondingly.

Two fit paths are provided:

* :func:`fit_baseline` — the paper-faithful pipeline: random membership
  init, then per iteration the same five stages the paper launches as
  CUDA kernels (per-pixel num/den terms -> reduce num -> reduce den ->
  combine -> membership update), with the membership array materialized
  between stages and the convergence test on the host, exactly like the
  paper's host loop.
* :func:`fit_fused` — the beyond-paper path: the fixed point only needs
  centers, so the whole iteration runs device-resident inside
  ``lax.while_loop`` with no membership materialization. Memberships are
  computed once at the end for defuzzification.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

_D2_FLOOR = 1e-12  # distance clamp before the negative-power; exact zeros
                   # are handled separately with a one-hot membership.


@dataclasses.dataclass(frozen=True)
class FCMConfig:
    """Hyper-parameters; defaults follow the paper (c=4, m=2, eps=0.005)."""
    n_clusters: int = 4
    m: float = 2.0
    eps: float = 5e-3
    max_iters: int = 300
    seed: int = 0
    # 'membership' reproduces the paper's ||u_new - u_old||_inf < eps test;
    # 'centers' is the device-resident equivalent used by the fused path.
    convergence: str = "membership"


# ---------------------------------------------------------------------------
# Elementary updates (Eqs. 3 and 4 of the paper)
# ---------------------------------------------------------------------------

def _as_2d(x: jax.Array) -> jax.Array:
    return x[:, None] if x.ndim == 1 else x


def pairwise_d2(x: jax.Array, v: jax.Array) -> jax.Array:
    """Squared Euclidean distances, shape (c, N)."""
    x2 = _as_2d(x)            # (N, F)
    v2 = _as_2d(v)            # (c, F)
    d2 = jnp.sum((v2[:, None, :] - x2[None, :, :]) ** 2, axis=-1)
    return d2


def membership_from_d2(d2: jax.Array, m: float) -> jax.Array:
    """Eq. 4: u_ji = d_ji^(-2/(m-1)) / sum_k d_ki^(-2/(m-1)); (c, N)."""
    p = jnp.clip(d2, _D2_FLOOR, None) ** (-1.0 / (m - 1.0))
    u = p / jnp.sum(p, axis=0, keepdims=True)
    # Exact-zero distances (pixel sits on a center — common for uint8 data):
    # membership mass goes entirely to the zero-distance cluster(s).
    zero = (d2 <= 0.0)
    any_zero = jnp.any(zero, axis=0, keepdims=True)
    u_zero = zero.astype(u.dtype) / jnp.maximum(
        jnp.sum(zero, axis=0, keepdims=True), 1).astype(u.dtype)
    return jnp.where(any_zero, u_zero, u)


def update_membership(x: jax.Array, v: jax.Array, m: float) -> jax.Array:
    """Eq. 4 from pixels + centers; (c, N)."""
    return membership_from_d2(pairwise_d2(x, v), m)


def center_terms(x: jax.Array, u: jax.Array, m: float):
    """Per-pixel numerator/denominator terms of Eq. 3 (the paper's first
    CUDA kernel): no summation yet. Returns (num_terms (c, N, F),
    den_terms (c, N))."""
    um = u ** m
    num_terms = um[:, :, None] * _as_2d(x)[None, :, :]
    return num_terms, um


def update_centers(x: jax.Array, u: jax.Array, m: float) -> jax.Array:
    """Eq. 3: v_j = sum_i u_ji^m x_i / sum_i u_ji^m. Shape matches x's
    feature layout: (c,) for (N,) input, (c, F) for (N, F)."""
    num_terms, den_terms = center_terms(x, u, m)
    v = jnp.sum(num_terms, axis=1) / jnp.maximum(
        jnp.sum(den_terms, axis=1)[:, None], _D2_FLOOR)
    return v[:, 0] if x.ndim == 1 else v


def objective(x: jax.Array, u: jax.Array, v: jax.Array, m: float) -> jax.Array:
    """Eq. 1: J = sum_ij u_ji^m d_ji^2."""
    return jnp.sum((u ** m) * pairwise_d2(x, v))


def defuzzify(u: jax.Array) -> jax.Array:
    """Maximal-membership hard assignment; (N,) int32 labels."""
    return jnp.argmax(u, axis=0).astype(jnp.int32)


def labels_from_centers(x: jax.Array, v: jax.Array) -> jax.Array:
    """argmin distance == argmax membership for any m > 1."""
    return jnp.argmin(pairwise_d2(x, v), axis=0).astype(jnp.int32)


# ---------------------------------------------------------------------------
# Initialization
# ---------------------------------------------------------------------------

def random_membership(key: jax.Array, c: int, n: int,
                      dtype=jnp.float32) -> jax.Array:
    """Paper Step 2: random memberships, rows normalized to sum to 1."""
    u = jax.random.uniform(key, (c, n), dtype=dtype, minval=1e-3, maxval=1.0)
    return u / jnp.sum(u, axis=0, keepdims=True)


def linspace_centers(x: jax.Array, c: int) -> jax.Array:
    """Deterministic center init: c points evenly spaced in [min, max].
    Needs only a min/max reduction, so it distributes with one tiny psum."""
    x2 = _as_2d(x)
    lo = jnp.min(x2, axis=0)
    hi = jnp.max(x2, axis=0)
    frac = (jnp.arange(c, dtype=x2.dtype) + 0.5) / c
    v = lo[None, :] + frac[:, None] * (hi - lo)[None, :]
    return v[:, 0] if x.ndim == 1 else v


# ---------------------------------------------------------------------------
# Fit paths
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class FCMResult:
    centers: jax.Array          # (c,) or (c, F)
    labels: jax.Array           # (N,) int32
    n_iters: int
    final_delta: float
    membership: Optional[jax.Array] = None   # (c, N) if kept


# --- paper-faithful staged pipeline -----------------------------------------

@partial(jax.jit, static_argnames=("m",))
def _stage_terms(x, u, m):
    # CUDA kernel #1: heavy per-pixel math, results materialized.
    return center_terms(x, u, m)


@jax.jit
def _stage_reduce_num(num_terms):
    # CUDA kernel #2: tree-reduce numerator (per cluster).
    return jnp.sum(num_terms, axis=1)


@jax.jit
def _stage_reduce_den(den_terms):
    # CUDA kernel #3: tree-reduce denominator (per cluster).
    return jnp.sum(den_terms, axis=1)


@jax.jit
def _stage_combine(num, den):
    # CUDA kernel #4 (single thread in the paper): final division on device.
    return num / jnp.maximum(den[:, None], _D2_FLOOR)


@partial(jax.jit, static_argnames=("m",))
def _stage_membership(x, v, m):
    # The one-kernel membership phase (paper §4.3).
    return update_membership(x, v, m)


def fit_baseline(x: jax.Array, cfg: FCMConfig = FCMConfig(),
                 use_pallas: bool = False,
                 u0: Optional[jax.Array] = None) -> FCMResult:
    """Paper-faithful FCM: staged 'kernels', membership in HBM between
    stages, host-side convergence test each iteration (the paper copies
    the membership array back to the host to test it).

    With ``use_pallas=True`` the per-stage math runs through the Pallas
    kernels in :mod:`repro.kernels` (interpret mode on CPU)."""
    x = jnp.asarray(x, jnp.float32)
    n = x.shape[0]
    c = cfg.n_clusters
    key = jax.random.PRNGKey(cfg.seed)
    u = random_membership(key, c, n) if u0 is None else jnp.asarray(
        u0, jnp.float32)
    if use_pallas:
        from repro.kernels import ops as kops

    n_iters = 0
    delta = jnp.inf
    v = None
    for it in range(cfg.max_iters):
        if use_pallas and x.ndim == 1:
            num, den = kops.center_partials(x, u, cfg.m)
            v = _stage_combine(num, den)
            v = v[:, 0]
            u_new = kops.membership(x, v, cfg.m)
        else:
            num_terms, den_terms = _stage_terms(x, u, cfg.m)
            num = _stage_reduce_num(num_terms)
            den = _stage_reduce_den(den_terms)
            v = _stage_combine(num, den)
            v = v[:, 0] if x.ndim == 1 else v
            u_new = _stage_membership(x, v, cfg.m)
        # Host round-trip, as in the paper's block diagram.
        delta = float(jnp.max(jnp.abs(u_new - u)))
        u = u_new
        n_iters = it + 1
        if delta < cfg.eps:
            break
    if v is None:
        # max_iters=0: centers from the initial membership, so the result
        # is still well-defined.
        v = update_centers(x, u, cfg.m)
    return FCMResult(centers=v, labels=defuzzify(u), n_iters=n_iters,
                     final_delta=delta, membership=u)


# --- fused, device-resident path ---------------------------------------------

@partial(jax.jit, static_argnames=("m",))
def fused_center_step(x: jax.Array, v: jax.Array, m: float) -> jax.Array:
    """One v -> v' fixed-point step with Eq. 4 substituted into Eq. 3;
    memberships exist only as registers/VMEM inside the step."""
    u = update_membership(x, v, m)
    return update_centers(x, u, m)


def _while_centers(step, v0, eps, max_iters):
    """Generic device-resident center fixed point: iterate ``v -> step(v)``
    until ``max|v' - v| < eps`` or ``max_iters``. Shared by the fused and
    spatial (FCM_S) fit paths so the convergence test cannot drift.
    Returns (v, delta, it)."""
    def cond(state):
        _, delta, it = state
        return jnp.logical_and(delta >= eps, it < max_iters)

    def body(state):
        v, _, it = state
        v_new = step(v)
        delta = jnp.max(jnp.abs(v_new - v))
        return v_new, delta, it + 1

    state = (jnp.asarray(v0, jnp.float32),
             jnp.asarray(jnp.inf, jnp.float32),
             jnp.asarray(0, jnp.int32))
    return jax.lax.while_loop(cond, body, state)


@partial(jax.jit, static_argnames=("c", "m", "max_iters"))
def _fused_loop(x, v0, c, m, eps, max_iters):
    return _while_centers(lambda v: fused_center_step(x, v, m), v0, eps,
                          max_iters)


def fit_fused(x: jax.Array, cfg: FCMConfig = FCMConfig(),
              v0: Optional[jax.Array] = None,
              keep_membership: bool = False) -> FCMResult:
    """Optimized FCM: device-resident while_loop over the fused center
    iteration, deterministic linspace init, center-movement convergence.
    Validated equivalent to :func:`fit_baseline` in tests."""
    x = jnp.asarray(x, jnp.float32)
    if v0 is None:
        v0 = linspace_centers(x, cfg.n_clusters)
    # eps on centers: the membership test at eps_u corresponds to a center
    # test at roughly eps_u * data-range / c (Lipschitz); use eps directly
    # in intensity units scaled by the data range.
    rng = float(jnp.max(x) - jnp.min(x)) or 1.0
    eps_v = cfg.eps * rng * 0.1
    v, delta, it = _fused_loop(x, v0, cfg.n_clusters, cfg.m, eps_v,
                               cfg.max_iters)
    u = update_membership(x, v, cfg.m) if keep_membership else None
    labels = labels_from_centers(x, v)
    return FCMResult(centers=v, labels=labels, n_iters=int(it),
                     final_delta=float(delta), membership=u)
