"""Weighted FCM over vector features (the multi-channel compression core).

:mod:`repro.core.histogram` proves the compression algebra for 1-D
intensities: every pixel sum in Eqs. 3/4 factors through (value, count)
pairs, so 256 weighted rows replace N pixels. Once features are vectors
(RGB, multi-modal T1/T2/PD stacks) there is no 256-bin histogram — but
the *algebra* survives unchanged: any surjection pixels -> K groups with
per-group mean features and pixel counts yields a weighted FCM over
``(K, D)`` rows whose center fixed point approximates the pixel-space
one to the within-group variance. The superpixel subsystem
(:mod:`repro.superpixel`) supplies exactly that surjection; this module
is the weighted vector fixed point behind it.

Entry points mirror the scalar stack:

* :func:`weighted_vector_center_step` — one fused v -> v' step over
  ``(K, D)`` feature rows with per-row weights (generalizes
  ``histogram.weighted_center_step`` to D > 1).
* :func:`fit_vector_fcm` — the single-problem fit, driven by the same
  :func:`repro.core.fcm._while_centers` convergence loop as
  ``fit_fused`` / ``fit_spatial`` so the tolerance semantics cannot
  drift. With D = 1 rows and histogram counts as weights it reproduces
  :func:`repro.core.histogram.fit_histogram` (validated in tests).
* :func:`fit_vector_batched` — ``(B, K, D)`` payload batches through the
  per-lane-masked ``while_loop`` of :mod:`repro.core.batched`; the
  serving engine's ``method="superpixel"`` buckets land here.
"""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from . import fcm as F
from .batched import BatchedFCMResult, _masked_while

_D2_FLOOR = 1e-12
_BIG = 3.4e38


def weighted_vector_center_step(feats: jax.Array, w: jax.Array,
                                v: jax.Array, m: float) -> jax.Array:
    """One fused v -> v' step over weighted feature rows.

    ``feats`` (K, D), ``w`` (K,) nonnegative row weights (zero rows are
    inert), ``v`` (c, D) -> (c, D). Eq. 4 membership on the rows, then
    the weighted Eq. 3 center update; memberships never leave the step.
    """
    u = F.update_membership(feats, v, m)            # (c, K)
    um = (u ** m) * w[None, :]
    num = um @ feats                                # (c, D)
    den = jnp.maximum(jnp.sum(um, axis=1), _D2_FLOOR)
    return num / den[:, None]


def weighted_support(feats: jax.Array, w: jax.Array):
    """Per-dimension (lo, hi) over rows with nonzero weight: empty
    superpixels and batch padding must stretch neither the linspace
    init nor the tolerance scaling. (D,), (D,)."""
    active = (w > 0)[:, None]
    lo = jnp.min(jnp.where(active, feats, _BIG), axis=0)
    hi = jnp.max(jnp.where(active, feats, -_BIG), axis=0)
    return lo, hi


def _linspace_from_support(lo: jax.Array, hi: jax.Array,
                           c: int) -> jax.Array:
    """lo/hi (..., D) -> per-dimension linspace centers (..., c, D)."""
    frac = (jnp.arange(c, dtype=lo.dtype) + 0.5) / c
    shape = (1,) * (lo.ndim - 1) + (c, 1)
    return lo[..., None, :] + frac.reshape(shape) * (hi - lo)[..., None, :]


def weighted_linspace_centers(feats: jax.Array, w: jax.Array,
                              c: int) -> jax.Array:
    """Per-dimension linspace init over the weighted support; (c, D)."""
    lo, hi = weighted_support(feats, w)
    return _linspace_from_support(lo, hi, c)


@partial(jax.jit, static_argnames=("c", "m", "max_iters"))
def _vector_loop(feats, w, v0, c, m, eps, max_iters):
    step = lambda v: weighted_vector_center_step(feats, w, v, m)
    return F._while_centers(step, v0, eps, max_iters)


def fit_vector_fcm(feats, weights=None, cfg: F.FCMConfig = F.FCMConfig(),
                   v0: Optional[jax.Array] = None,
                   keep_membership: bool = False) -> F.FCMResult:
    """Weighted FCM over (K, D) feature rows; per-row ``weights`` default
    to 1 (plain vector FCM over the rows). ``labels`` are per-row
    nearest-center assignments (K,) — the caller broadcasts them back
    through whatever map produced the rows."""
    feats = F._as_2d(jnp.asarray(feats, jnp.float32))
    k = feats.shape[0]
    w = (jnp.ones((k,), jnp.float32) if weights is None
         else jnp.asarray(weights, jnp.float32))
    lo, hi = weighted_support(feats, w)
    if v0 is None:
        v0 = _linspace_from_support(lo, hi, cfg.n_clusters)
    # Same center-movement tolerance scaling as fit_fused, on the widest
    # feature dimension.
    rng = float(jnp.max(hi - lo)) or 1.0
    eps_v = cfg.eps * rng * 0.1
    v, delta, it = _vector_loop(feats, w, jnp.asarray(v0, jnp.float32),
                                cfg.n_clusters, cfg.m, eps_v, cfg.max_iters)
    u = F.update_membership(feats, v, cfg.m) if keep_membership else None
    labels = F.labels_from_centers(feats, v)
    return F.FCMResult(centers=v, labels=labels, n_iters=int(it),
                       final_delta=float(delta), membership=u)


# ---------------------------------------------------------------------------
# Batched variant: fixed-K payload buckets for the serving engine
# ---------------------------------------------------------------------------

@partial(jax.jit, static_argnames=("c", "m", "max_iters"))
def _batched_vector_loop(feats, ws, c, m, eps, max_iters):
    """feats (B, K, D), ws (B, K) -> (centers (B, c, D), delta (B,),
    iters (B,), total_it). Reuses the per-lane-masked while_loop of
    core.batched by flattening centers to (B, c*D) around the step."""
    b, _, d = feats.shape
    lo, hi = jax.vmap(weighted_support)(feats, ws)           # (B, D) each
    v0 = _linspace_from_support(lo, hi, c)                   # (B, c, D)
    rng = jnp.max(hi - lo, axis=1)
    eps_v = eps * jnp.where(rng > 0, rng, 1.0) * 0.1

    vstep = jax.vmap(weighted_vector_center_step, in_axes=(0, 0, 0, None))

    def flat_step(vflat):
        return vstep(feats, ws, vflat.reshape(b, c, d), m).reshape(b, c * d)

    v, delta, iters, it = _masked_while(flat_step, v0.reshape(b, c * d),
                                        eps_v, max_iters)
    return v.reshape(b, c, d), delta, iters, it


def fit_vector_batched(feats, weights,
                       cfg: F.FCMConfig = F.FCMConfig()) -> BatchedFCMResult:
    """Batched weighted vector FCM over a fixed-K bucket.

    ``feats`` (B, K, D), ``weights`` (B, K); lanes are independent
    problems converging under the same per-lane masking as
    :func:`repro.core.batched.fit_batched`, so a lane's trajectory
    matches what :func:`fit_vector_fcm` would produce alone."""
    feats = jnp.asarray(feats, jnp.float32)
    weights = jnp.asarray(weights, jnp.float32)
    v, delta, iters, it = _batched_vector_loop(
        feats, weights, cfg.n_clusters, cfg.m, cfg.eps, cfg.max_iters)
    return BatchedFCMResult(centers=v, n_iters=np.asarray(iters),
                            final_delta=np.asarray(delta),
                            total_iters=int(it))
