"""Weighted FCM over vector features (the multi-channel compression face
of the unified solver).

:mod:`repro.core.histogram` proves the compression algebra for 1-D
intensities: every pixel sum in Eqs. 3/4 factors through (value, count)
pairs. Once features are vectors (RGB, multi-modal T1/T2/PD stacks)
there is no 256-bin histogram — but the *algebra* survives unchanged:
any surjection pixels -> K groups with per-group mean features and pixel
counts yields a weighted FCM over ``(K, D)`` rows. The superpixel
subsystem (:mod:`repro.superpixel`) supplies exactly that surjection.

Since the solver unification this module is a naming shim: the weighted
``(K, D)`` fixed point IS :func:`repro.core.solver.weighted_center_step`
under :func:`repro.core.solver.solve`, and the entry points here are
deprecated thin adapters kept for one release:

* :func:`fit_vector_fcm`      -> ``solve(vector_problem(feats, w, cfg))``
* :func:`fit_vector_batched`  -> ``solve_batched(batch_problems(...))``
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from . import fcm as F
from . import solver as SV
from .solver import BatchedFCMResult  # noqa: F401  (compat re-export)


def weighted_vector_center_step(feats: jax.Array, w: jax.Array,
                                v: jax.Array, m: float) -> jax.Array:
    """One fused v -> v' step over weighted feature rows; alias of the
    canonical :func:`repro.core.solver.weighted_center_step`."""
    return SV.weighted_center_step(feats, w, v, m)


def weighted_support(feats: jax.Array, w: jax.Array):
    """Per-dimension (lo, hi) over rows with nonzero weight; see
    :func:`repro.core.solver.weighted_support`."""
    return SV.weighted_support(feats, w)


def weighted_linspace_centers(feats: jax.Array, w: jax.Array,
                              c: int) -> jax.Array:
    """Per-dimension linspace init over the weighted support; (c, D)."""
    lo, hi = SV.weighted_support(feats, w)
    return SV.linspace_from_support(lo, hi, c)


def fit_vector_fcm(feats, weights=None, cfg: F.FCMConfig = F.FCMConfig(),
                   v0: Optional[jax.Array] = None,
                   keep_membership: bool = False) -> F.FCMResult:
    """DEPRECATED alias — use
    ``solver.solve(solver.vector_problem(feats, weights, cfg))``.

    Weighted FCM over (K, D) feature rows; per-row ``weights`` default
    to 1. ``labels`` are per-row nearest-center assignments (K,) — the
    caller broadcasts them back through whatever map produced the rows."""
    SV.warn_deprecated("fit_vector_fcm",
                       "solver.solve(vector_problem(feats, weights, cfg))")
    feats = F._as_2d(jnp.asarray(feats, jnp.float32))
    problem = SV.vector_problem(feats, weights, cfg, v0=v0)
    return SV.solve(problem, cfg, backend="reference",
                    keep_membership=keep_membership)


def fit_vector_batched(feats, weights,
                       cfg: F.FCMConfig = F.FCMConfig()) -> BatchedFCMResult:
    """DEPRECATED alias — use ``solver.solve_batched`` on a
    ``solver.batch_problems(feats, weights, cfg=cfg)`` stack.

    Batched weighted vector FCM over a fixed-K bucket: ``feats``
    (B, K, D), ``weights`` (B, K); lanes are independent problems under
    the solver's per-lane convergence masking, so a lane's trajectory
    matches what a solo fit of it would produce."""
    SV.warn_deprecated("fit_vector_batched",
                       "solver.solve_batched(batch_problems(feats, weights))")
    problem = SV.batch_problems(jnp.asarray(feats, jnp.float32),
                                jnp.asarray(weights, jnp.float32), cfg=cfg)
    return SV.solve_batched(problem, cfg)
