"""Spatially-regularized Fuzzy C-Means (FCM_S, Ahmed-style).

The histogram fast path (:mod:`repro.core.histogram`) discards pixel
positions, so it cannot penalize spatially-isolated misclassifications —
exactly the failure mode of plain FCM on noisy MRI (salt-and-pepper
impulses land in whichever cluster their corrupted intensity is nearest
to). FCM_S (Ahmed et al. 2002; cf. the 3DPIFCM/IFCM line,
arXiv:2002.01985) adds a neighborhood penalty to the objective:

    J = sum_ji u_ji^m [ d2_ji + (alpha/|N_i|) sum_{r in N_i} d2_jr ]

which changes the two update equations to

    u_ji  ∝ (d2_ji + alpha * mean_{r in N_i} d2_jr)^(-1/(m-1))      (Eq. 4')
    v_j   = sum_i u_ji^m (x_i + alpha * xbar_i)
            / ((1 + alpha) sum_i u_ji^m)                            (Eq. 3')

with ``xbar_i`` the mean intensity of pixel i's neighborhood. Border
pixels use their true (smaller) neighborhoods — |N_i| is per-pixel.

Neighborhoods: 4- or 8-connected for 2-D slices, 6-connected for 3-D
volumes. With ``alpha = 0`` every formula degenerates bitwise to plain
FCM, so :func:`fit_spatial` reproduces :func:`repro.core.fcm.fit_fused`
exactly (validated in tests).

Two step implementations drive the same fused ``while_loop``:

* the pure-``jnp`` reference in this module (shifted-array stencil), and
* the Pallas stencil kernel in :mod:`repro.kernels.fcm_spatial`
  (``use_pallas=True``), which fuses the stencil average, the membership
  update, and the center reduction into one VMEM pass.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from . import fcm as F

_D2_FLOOR = 1e-12

OFFSETS_2D_4 = ((-1, 0), (1, 0), (0, -1), (0, 1))
OFFSETS_2D_8 = OFFSETS_2D_4 + ((-1, -1), (-1, 1), (1, -1), (1, 1))
OFFSETS_3D_6 = ((-1, 0, 0), (1, 0, 0), (0, -1, 0), (0, 1, 0),
                (0, 0, -1), (0, 0, 1))


@dataclasses.dataclass(frozen=True)
class SpatialFCMConfig(F.FCMConfig):
    """FCM_S hyper-parameters on top of the plain-FCM set.

    ``alpha`` weighs the neighborhood term (0 = plain FCM; the FCM_S
    literature uses 0.5–2 for moderate-to-heavy noise). ``neighbors``
    is the 2-D stencil arity (4 or 8); 3-D volumes always use the
    6-connected stencil.
    """
    alpha: float = 1.0
    neighbors: int = 4


def neighbor_offsets(ndim: int, neighbors: int) -> Tuple[Tuple[int, ...], ...]:
    """The symmetric stencil offset set for an image rank + arity."""
    if ndim == 2:
        if neighbors == 4:
            return OFFSETS_2D_4
        if neighbors == 8:
            return OFFSETS_2D_8
        raise ValueError(f"2-D neighborhoods are 4 or 8, got {neighbors}")
    if ndim == 3:
        if neighbors != 6:
            raise ValueError(f"3-D neighborhoods are 6-connected, "
                             f"got {neighbors}")
        return OFFSETS_3D_6
    raise ValueError(f"expected a 2-D image or 3-D volume, rank {ndim}")


def _shift(a: jax.Array, off: Tuple[int, ...]) -> jax.Array:
    """Zero-filled shift: out[i] = a[i - off] (per axis)."""
    pads, slices = [], []
    for ax, o in enumerate(off):
        n = a.shape[ax]
        if o >= 0:
            pads.append((o, 0))
            slices.append(slice(0, n))
        else:
            pads.append((0, -o))
            slices.append(slice(-o, None))
    return jnp.pad(a, pads)[tuple(slices)]


def neighbor_fields(img: jax.Array, v: jax.Array, neighbors: int):
    """The three stencil fields of FCM_S, computed by shifted arrays.

    Returns ``(d2, nb_d2_mean, xbar)``: the plain squared distances
    ``(c, *img.shape)``, the per-pixel neighborhood mean of the
    per-cluster squared distances (same shape), and the neighborhood
    mean intensity ``img.shape``. Borders average over the in-image
    neighbors only.
    """
    img = jnp.asarray(img, jnp.float32)
    v = jnp.asarray(v, jnp.float32)
    offsets = neighbor_offsets(img.ndim, neighbors)
    vb = v.reshape((-1,) + (1,) * img.ndim)
    w = jnp.ones(img.shape, jnp.float32)
    nb_d2 = jnp.zeros((v.shape[0],) + img.shape, jnp.float32)
    cnt = jnp.zeros(img.shape, jnp.float32)
    sx = jnp.zeros(img.shape, jnp.float32)
    for off in offsets:
        xs = _shift(img, off)
        ws = _shift(w, off)
        nb_d2 = nb_d2 + ws[None] * (vb - xs[None]) ** 2
        cnt = cnt + ws
        sx = sx + ws * xs
    cnt = jnp.maximum(cnt, 1.0)
    d2 = (vb - img[None]) ** 2
    return d2, nb_d2 / cnt[None], sx / cnt


def spatial_membership(img: jax.Array, v: jax.Array, m: float = 2.0,
                       alpha: float = 1.0, neighbors: int = 4) -> jax.Array:
    """Eq. 4' memberships from the spatially-effective distances;
    shape ``(c,) + img.shape``."""
    d2, nb, _ = neighbor_fields(img, v, neighbors)
    return F.membership_from_d2(d2 + alpha * nb, m)


def spatial_center_step(img: jax.Array, v: jax.Array, m: float = 2.0,
                        alpha: float = 1.0, neighbors: int = 4) -> jax.Array:
    """One fused v -> v' FCM_S iteration (pure-jnp stencil reference).

    Eq. 3' is plain Eq. 3 on the effective pixels
    ``(x + alpha * xbar) / (1 + alpha)``, so the update reuses
    :func:`repro.core.fcm.update_centers` — with ``alpha = 0`` the
    effective pixels equal ``x`` bitwise and the step degenerates to
    :func:`repro.core.fcm.fused_center_step`.
    """
    d2, nb, xbar = neighbor_fields(img, v, neighbors)
    c = v.shape[0]
    u = F.membership_from_d2((d2 + alpha * nb).reshape(c, -1), m)
    x_eff = ((jnp.asarray(img, jnp.float32) + alpha * xbar)
             / (1.0 + alpha)).reshape(-1)
    return F.update_centers(x_eff, u, m)


# ---------------------------------------------------------------------------
# Fused while_loop drivers (share core.fcm's convergence loop)
# ---------------------------------------------------------------------------

@partial(jax.jit, static_argnames=("m", "alpha", "neighbors", "max_iters"))
def _spatial_loop_ref(img, v0, m, alpha, neighbors, eps, max_iters):
    step = lambda v: spatial_center_step(img, v, m, alpha, neighbors)
    return F._while_centers(step, v0, eps, max_iters)


@partial(jax.jit, static_argnames=("m", "alpha", "neighbors", "max_iters",
                                   "block_rows", "interpret"))
def _spatial_loop_pallas(xpad, wpad, v0, m, alpha, neighbors, eps,
                         max_iters, block_rows, interpret):
    from repro.kernels import ops as kops

    def step(v):
        num, den = kops.spatial_partials(xpad, wpad, v, m, alpha, neighbors,
                                         block_rows, interpret)
        return num / jnp.maximum((1.0 + alpha) * den, _D2_FLOOR)

    return F._while_centers(step, v0, eps, max_iters)


def fit_spatial(img, cfg: SpatialFCMConfig = SpatialFCMConfig(),
                use_pallas: bool = False,
                v0: Optional[jax.Array] = None,
                keep_membership: bool = False,
                block_rows: int = 64,
                interpret: Optional[bool] = None) -> F.FCMResult:
    """Spatially-regularized FCM over a 2-D image or 3-D volume.

    Unlike the flat-pixel fit paths, ``labels`` (and ``membership``
    when kept) retain the input's spatial shape. ``use_pallas=True``
    drives the loop with the fused stencil kernel of
    :mod:`repro.kernels.fcm_spatial`; the padding to tile shapes
    happens once, outside the loop.
    """
    img = jnp.asarray(img, jnp.float32)
    if img.ndim not in (2, 3):
        raise ValueError(f"fit_spatial needs (H, W) or (D, H, W) input, "
                         f"got shape {img.shape}")
    neighbors = cfg.neighbors if img.ndim == 2 else 6
    neighbor_offsets(img.ndim, neighbors)   # validate arity early
    x = img.ravel()
    if v0 is None:
        v0 = F.linspace_centers(x, cfg.n_clusters)
    # Same center-movement tolerance scaling as fit_fused.
    rng = float(jnp.max(x) - jnp.min(x)) or 1.0
    eps_v = cfg.eps * rng * 0.1
    if use_pallas:
        from repro.kernels import ops as kops
        xpad, wpad = kops.tile_grid(img, block_rows)
        v, delta, it = _spatial_loop_pallas(
            xpad, wpad, v0, cfg.m, cfg.alpha, neighbors, eps_v,
            cfg.max_iters, block_rows, interpret)
    else:
        v, delta, it = _spatial_loop_ref(
            img, v0, cfg.m, cfg.alpha, neighbors, eps_v, cfg.max_iters)
    u = spatial_membership(img, v, cfg.m, cfg.alpha, neighbors)
    labels = F.defuzzify(u.reshape(cfg.n_clusters, -1)).reshape(img.shape)
    return F.FCMResult(centers=v, labels=labels, n_iters=int(it),
                       final_delta=float(delta),
                       membership=u if keep_membership else None)
