"""Spatially-regularized Fuzzy C-Means (FCM_S, Ahmed-style).

The histogram fast path (:mod:`repro.core.histogram`) discards pixel
positions, so it cannot penalize spatially-isolated misclassifications —
exactly the failure mode of plain FCM on noisy MRI (salt-and-pepper
impulses land in whichever cluster their corrupted intensity is nearest
to). FCM_S (Ahmed et al. 2002; cf. the 3DPIFCM/IFCM line,
arXiv:2002.01985) adds a neighborhood penalty to the objective:

    J = sum_ji u_ji^m [ d2_ji + (alpha/|N_i|) sum_{r in N_i} d2_jr ]

which changes the two update equations to

    u_ji  ∝ (d2_ji + alpha * mean_{r in N_i} d2_jr)^(-1/(m-1))      (Eq. 4')
    v_j   = sum_i u_ji^m (x_i + alpha * xbar_i)
            / ((1 + alpha) sum_i u_ji^m)                            (Eq. 3')

with ``xbar_i`` the mean intensity of pixel i's neighborhood. Border
pixels use their true (smaller) neighborhoods — |N_i| is per-pixel.

Neighborhoods: 4- or 8-connected for 2-D slices, 6-connected for 3-D
volumes. With ``alpha = 0`` every formula degenerates bitwise to plain
FCM, so :func:`fit_spatial` reproduces :func:`repro.core.fcm.fit_fused`
exactly (validated in tests).

Two step implementations are registered in the
:mod:`repro.kernels.ops` dispatch registry under kind ``"stencil"`` and
drive the same solver convergence loop:

* ``"reference"`` — the pure-``jnp`` shifted-array stencil in this
  module (:func:`spatial_center_step`), and
* ``"pallas"`` — the stencil kernel in :mod:`repro.kernels.fcm_spatial`,
  which fuses the stencil average, the membership update, and the
  center reduction into one VMEM pass.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from . import fcm as F

_D2_FLOOR = 1e-12

OFFSETS_2D_4 = ((-1, 0), (1, 0), (0, -1), (0, 1))
OFFSETS_2D_8 = OFFSETS_2D_4 + ((-1, -1), (-1, 1), (1, -1), (1, 1))
OFFSETS_3D_6 = ((-1, 0, 0), (1, 0, 0), (0, -1, 0), (0, 1, 0),
                (0, 0, -1), (0, 0, 1))


@dataclasses.dataclass(frozen=True)
class SpatialFCMConfig(F.FCMConfig):
    """FCM_S hyper-parameters on top of the plain-FCM set.

    ``alpha`` weighs the neighborhood term (0 = plain FCM; the FCM_S
    literature uses 0.5–2 for moderate-to-heavy noise). ``neighbors``
    is the 2-D stencil arity (4 or 8); 3-D volumes always use the
    6-connected stencil.
    """
    alpha: float = 1.0
    neighbors: int = 4


def neighbor_offsets(ndim: int, neighbors: int) -> Tuple[Tuple[int, ...], ...]:
    """The symmetric stencil offset set for an image rank + arity."""
    if ndim == 2:
        if neighbors == 4:
            return OFFSETS_2D_4
        if neighbors == 8:
            return OFFSETS_2D_8
        raise ValueError(f"2-D neighborhoods are 4 or 8, got {neighbors}")
    if ndim == 3:
        if neighbors != 6:
            raise ValueError(f"3-D neighborhoods are 6-connected, "
                             f"got {neighbors}")
        return OFFSETS_3D_6
    raise ValueError(f"expected a 2-D image or 3-D volume, rank {ndim}")


def _shift(a: jax.Array, off: Tuple[int, ...]) -> jax.Array:
    """Zero-filled shift: out[i] = a[i - off] (per axis)."""
    pads, slices = [], []
    for ax, o in enumerate(off):
        n = a.shape[ax]
        if o >= 0:
            pads.append((o, 0))
            slices.append(slice(0, n))
        else:
            pads.append((0, -o))
            slices.append(slice(-o, None))
    return jnp.pad(a, pads)[tuple(slices)]


def neighbor_fields(img: jax.Array, v: jax.Array, neighbors: int):
    """The three stencil fields of FCM_S, computed by shifted arrays.

    Returns ``(d2, nb_d2_mean, xbar)``: the plain squared distances
    ``(c, *img.shape)``, the per-pixel neighborhood mean of the
    per-cluster squared distances (same shape), and the neighborhood
    mean intensity ``img.shape``. Borders average over the in-image
    neighbors only.
    """
    img = jnp.asarray(img, jnp.float32)
    v = jnp.asarray(v, jnp.float32)
    offsets = neighbor_offsets(img.ndim, neighbors)
    vb = v.reshape((-1,) + (1,) * img.ndim)
    w = jnp.ones(img.shape, jnp.float32)
    nb_d2 = jnp.zeros((v.shape[0],) + img.shape, jnp.float32)
    cnt = jnp.zeros(img.shape, jnp.float32)
    sx = jnp.zeros(img.shape, jnp.float32)
    for off in offsets:
        xs = _shift(img, off)
        ws = _shift(w, off)
        nb_d2 = nb_d2 + ws[None] * (vb - xs[None]) ** 2
        cnt = cnt + ws
        sx = sx + ws * xs
    cnt = jnp.maximum(cnt, 1.0)
    d2 = (vb - img[None]) ** 2
    return d2, nb_d2 / cnt[None], sx / cnt


def spatial_membership(img: jax.Array, v: jax.Array, m: float = 2.0,
                       alpha: float = 1.0, neighbors: int = 4) -> jax.Array:
    """Eq. 4' memberships from the spatially-effective distances;
    shape ``(c,) + img.shape``."""
    d2, nb, _ = neighbor_fields(img, v, neighbors)
    return F.membership_from_d2(d2 + alpha * nb, m)


def spatial_center_step(img: jax.Array, v: jax.Array, m: float = 2.0,
                        alpha: float = 1.0, neighbors: int = 4) -> jax.Array:
    """One fused v -> v' FCM_S iteration (pure-jnp stencil reference).

    Eq. 3' is plain Eq. 3 on the effective pixels
    ``(x + alpha * xbar) / (1 + alpha)``, so the update reuses
    :func:`repro.core.fcm.update_centers` — with ``alpha = 0`` the
    effective pixels equal ``x`` bitwise and the step degenerates to
    :func:`repro.core.fcm.fused_center_step`.
    """
    d2, nb, xbar = neighbor_fields(img, v, neighbors)
    c = v.shape[0]
    u = F.membership_from_d2((d2 + alpha * nb).reshape(c, -1), m)
    x_eff = ((jnp.asarray(img, jnp.float32) + alpha * xbar)
             / (1.0 + alpha)).reshape(-1)
    return F.update_centers(x_eff, u, m)


# ---------------------------------------------------------------------------
# Fit entry point (deprecated adapter over the unified solver)
# ---------------------------------------------------------------------------

def fit_spatial(img, cfg: SpatialFCMConfig = SpatialFCMConfig(),
                use_pallas: bool = False,
                v0: Optional[jax.Array] = None,
                keep_membership: bool = False,
                block_rows: int = 64,
                interpret: Optional[bool] = None) -> F.FCMResult:
    """DEPRECATED alias — use
    ``solver.solve(solver.spatial_problem(img, cfg))``
    (``backend="pallas"`` for the fused stencil kernel).

    Spatially-regularized FCM over a 2-D image or 3-D volume. Unlike the
    flat-pixel fit paths, ``labels`` (and ``membership`` when kept)
    retain the input's spatial shape.
    """
    from . import solver as SV
    SV.warn_deprecated("fit_spatial",
                       "solver.solve(spatial_problem(img, cfg))")
    img = jnp.asarray(img, jnp.float32)
    if img.ndim not in (2, 3):
        raise ValueError(f"fit_spatial needs (H, W) or (D, H, W) input, "
                         f"got shape {img.shape}")
    problem = SV.spatial_problem(img, cfg, v0=v0)
    return SV.solve(problem, cfg,
                    backend="pallas" if use_pallas else "reference",
                    keep_membership=keep_membership,
                    block_rows=block_rows, interpret=interpret)
