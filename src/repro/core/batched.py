"""Batched multi-image FCM (beyond-paper optimization #4).

The paper parallelizes one image's FCM across CUDA threads; this module
parallelizes *across images*. Histogram compression (see
:mod:`repro.core.histogram`) is what makes the batch regular: any 8-bit
image, whatever its pixel count, reduces to a fixed ``(n_bins,)`` weight
vector, so B independent fits become one vmapped weighted fixed point —
a single device launch per iteration instead of B.

Since the solver unification the per-lane-masked convergence loop lives
in :func:`repro.core.solver.masked_while_centers` (lanes freeze at their
own convergence point, so a lane's trajectory is identical to a solo
fit — validated in tests), and the entry points here are deprecated
thin adapters over :func:`repro.core.solver.solve_batched`:

* :func:`fit_batched` — histograms (or images, histogrammed on ingest)
  -> per-image centers / iteration counts / deltas.
* :func:`fit_batched_pixels` — same masking machinery over raw ``(B, N)``
  same-shape pixel batches (float data that does not quantize to bins).
* :func:`build_sharded_batched_fit` — shard_map variant splitting the
  batch axis over the mesh; lanes are independent so the per-iteration
  collective traffic is exactly zero (cf. ``core/distributed.py``, which
  psums partial sums because it splits *pixels*, not images).
"""
from __future__ import annotations

from functools import lru_cache
from typing import List, Optional, Sequence, Union

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from . import fcm as F
from . import histogram as H
from . import solver as SV
from .distributed import mesh_axes, shard_map
from .solver import BatchedFCMResult  # noqa: F401  (canonical home moved)

#: Backward-compat alias: the per-lane-masked while_loop now lives in
#: the solver core.
_masked_while = SV.masked_while_centers


def hist_rows(hists: jax.Array) -> jax.Array:
    """(B, n_bins) histograms -> the (B, n_bins) scalar bin-value rows
    they weigh (the batched histogram problem's features)."""
    b, n_bins = hists.shape
    vals = jnp.arange(n_bins, dtype=jnp.float32)
    return jnp.broadcast_to(vals[None, :], (b, n_bins))


# ---------------------------------------------------------------------------
# Public entry points
# ---------------------------------------------------------------------------

def histograms_of(imgs: Sequence[np.ndarray], n_bins: int = 256) -> jax.Array:
    """Stack per-image intensity histograms into (B, n_bins)."""
    return jnp.stack([H.intensity_histogram(
        jnp.asarray(np.asarray(im).ravel(), jnp.float32), n_bins)
        for im in imgs])


def fit_batched(imgs_or_hists: Union[jax.Array, np.ndarray, Sequence],
                cfg: F.FCMConfig = F.FCMConfig(),
                n_bins: int = 256,
                compute_labels: bool = True) -> BatchedFCMResult:
    """DEPRECATED alias — use ``solver.solve_batched`` on a
    ``batch_problems(hist_rows(hists), hists, cfg=cfg)`` stack.

    Batched histogram-compressed FCM. ``imgs_or_hists`` is either a
    ``(B, n_bins)`` array of histograms, or a sequence of images (any
    shapes/sizes — each is flattened and histogrammed on ingest, and
    per-image labels are returned).
    """
    SV.warn_deprecated("fit_batched",
                       "solver.solve_batched(batch_problems(...))")
    imgs: Optional[List[np.ndarray]] = None
    if isinstance(imgs_or_hists, (jnp.ndarray, np.ndarray)) and \
            np.ndim(imgs_or_hists) == 2 and \
            np.shape(imgs_or_hists)[1] == n_bins:
        hists = jnp.asarray(imgs_or_hists, jnp.float32)
    else:
        imgs = [np.asarray(im) for im in imgs_or_hists]
        hists = histograms_of(imgs, n_bins)

    res = SV.solve_batched(
        SV.batch_problems(hist_rows(hists), hists, cfg=cfg), cfg)

    if imgs is not None and compute_labels:
        vals = jnp.arange(n_bins, dtype=jnp.float32)
        # 256-entry LUT per image: label every bin once, then gather.
        luts = np.asarray(jax.vmap(
            lambda vv: F.labels_from_centers(vals, vv))(res.centers))
        res.labels = [luts[i][np.clip(im.astype(np.int64), 0, n_bins - 1)]
                      for i, im in enumerate(imgs)]
    return res


def fit_batched_pixels(xs, cfg: F.FCMConfig = F.FCMConfig(),
                       compute_labels: bool = True) -> BatchedFCMResult:
    """DEPRECATED alias — use ``solver.solve_batched`` on a
    ``batch_problems(xs, cfg=cfg)`` stack.

    Batched FCM over a same-shape pixel batch ``(B, N)`` (or (B, H, W),
    flattened). For float-valued data that does not quantize to bins;
    for 8-bit images prefer the histogram compression."""
    SV.warn_deprecated("fit_batched_pixels",
                       "solver.solve_batched(batch_problems(xs))")
    xs = jnp.asarray(xs, jnp.float32)
    xs = xs.reshape(xs.shape[0], -1)
    res = SV.solve_batched(SV.batch_problems(xs, cfg=cfg), cfg)
    if compute_labels:
        res.labels = list(np.asarray(
            jax.vmap(F.labels_from_centers)(xs, res.centers)))
    return res


# ---------------------------------------------------------------------------
# Sharded variant: split the batch axis over the mesh
# ---------------------------------------------------------------------------

@lru_cache(maxsize=None)
def build_sharded_batched_fit(mesh: Mesh,
                              cfg: F.FCMConfig = F.FCMConfig(),
                              max_iters: Optional[int] = None):
    """Returns jit(fn)(hists (B, n_bins)) -> (centers, delta, iters).
    Cached on (mesh, cfg, max_iters) so repeated eager calls reuse one
    jitted closure instead of re-tracing per call.

    The batch axis is sharded over every mesh axis; each device runs the
    solver's masked batched loop on its local lanes with **zero**
    per-iteration collectives (images are independent). B must divide by
    mesh.size. Complements ``core/distributed.py``, which shards pixels
    of ONE image and psums partial sums every iteration.
    """
    axes = mesh_axes(mesh)
    bspec = P(axes)                  # batch dim sharded over every axis
    c, m = cfg.n_clusters, cfg.m
    mi = cfg.max_iters if max_iters is None else max_iters

    def local_fit(hists, active):
        # Padding lanes (active=False) start frozen in the masked loop:
        # they keep v0, report 0 iterations and 0.0 residual, and — the
        # point — cannot extend the shared trip count past the real
        # lanes' own convergence, so a ragged batch's per-lane counts
        # match an unpadded solve_batched exactly.
        v, delta, iters, _ = SV._flat_batched_loop_masked(
            hist_rows(hists)[..., None], hists, active, c, m, cfg.eps, mi)
        return v[..., 0], delta, iters

    fn = shard_map(local_fit, mesh=mesh,
                   in_specs=(P(axes, None), P(axes)),
                   out_specs=(P(axes, None), bspec, bspec))
    return jax.jit(fn)


def fit_batched_sharded(hists, mesh: Mesh,
                        cfg: F.FCMConfig = F.FCMConfig()) -> BatchedFCMResult:
    """Eager entry point: pads the batch to the mesh size, shards, fits."""
    hists = jnp.asarray(hists, jnp.float32)
    b = hists.shape[0]
    n_pad = (-b) % mesh.size
    active = jnp.ones((b,), bool)
    if n_pad:
        # Pad lanes carry a uniform histogram payload but are masked
        # inactive, so they never iterate and are dropped on return.
        pad = jnp.ones((n_pad, hists.shape[1]), jnp.float32)
        hists = jnp.concatenate([hists, pad])
        active = jnp.concatenate([active, jnp.zeros((n_pad,), bool)])
    sharding = NamedSharding(mesh, P(mesh_axes(mesh), None))
    hists = jax.device_put(hists, sharding)
    active = jax.device_put(active, NamedSharding(mesh, P(mesh_axes(mesh))))
    v, delta, iters = build_sharded_batched_fit(mesh, cfg)(hists, active)
    return BatchedFCMResult(centers=v[:b], n_iters=np.asarray(iters)[:b],
                            final_delta=np.asarray(delta)[:b],
                            total_iters=int(np.max(np.asarray(iters)[:b]))
                            if b else 0)
