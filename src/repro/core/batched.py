"""Batched multi-image FCM (beyond-paper optimization #4).

The paper parallelizes one image's FCM across CUDA threads; this module
parallelizes *across images*. Histogram compression (see
:mod:`repro.core.histogram`) is what makes the batch regular: any 8-bit
image, whatever its pixel count, reduces to a fixed ``(n_bins,)`` weight
vector, so B independent fits become one ``(B, n_bins)`` vmapped weighted
fixed point — a single device launch per iteration instead of B.

Convergence is per-image: each batch lane carries a done flag inside one
``lax.while_loop``; converged lanes freeze (their centers stop moving and
their iteration counters stop), and the loop exits when every lane is done
or ``max_iters`` is reached. This makes a lane's trajectory identical to
what :func:`repro.core.histogram.fit_histogram` would have produced for
that image alone — validated in tests.

Three entry points:

* :func:`fit_batched` — histograms (or images, histogrammed on ingest)
  -> per-image centers / iteration counts / deltas. The serving path.
* :func:`fit_batched_pixels` — same masking machinery over raw ``(B, N)``
  same-shape pixel batches (float data that does not quantize to bins).
* :func:`build_sharded_batched_fit` — shard_map variant splitting the
  batch axis over the mesh; lanes are independent so the per-iteration
  collective traffic is exactly zero (cf. ``core/distributed.py``, which
  psums partial sums because it splits *pixels*, not images).
"""
from __future__ import annotations

import dataclasses
from functools import lru_cache, partial
from typing import List, Optional, Sequence, Union

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from . import fcm as F
from . import histogram as H
from .distributed import mesh_axes, shard_map

_BIG = 3.4e38


@dataclasses.dataclass
class BatchedFCMResult:
    """Per-image results of a batched fit."""
    centers: jax.Array            # (B, c)
    n_iters: np.ndarray           # (B,) int32, per-image iteration counts
    final_delta: np.ndarray       # (B,) float32, per-image last center move
    total_iters: int              # global while_loop trip count
    labels: Optional[List[np.ndarray]] = None   # per image, if images given


# ---------------------------------------------------------------------------
# Batched init: per-image linspace centers + eps from histogram support
# ---------------------------------------------------------------------------

def _hist_support(hists: jax.Array, vals: jax.Array):
    """Per-image (lo, hi) of the nonzero histogram support; (B,), (B,)."""
    nz = hists > 0
    lo = jnp.min(jnp.where(nz, vals[None, :], _BIG), axis=1)
    hi = jnp.max(jnp.where(nz, vals[None, :], -_BIG), axis=1)
    return lo, hi


def _linspace_init(lo: jax.Array, hi: jax.Array, c: int, eps: float):
    """Per-image linspace centers (B, c) + center-movement tolerance (B,)
    from per-image data ranges, matching fit_histogram's init/eps scaling."""
    frac = (jnp.arange(c, dtype=jnp.float32) + 0.5) / c
    v0 = lo[:, None] + frac[None, :] * (hi - lo)[:, None]
    rng = hi - lo
    eps_v = eps * jnp.where(rng > 0, rng, 1.0) * 0.1
    return v0, eps_v


def _batched_init(hists: jax.Array, vals: jax.Array, c: int, eps: float):
    """v0/eps_v per lane from the nonzero histogram support."""
    lo, hi = _hist_support(hists, vals)
    return _linspace_init(lo, hi, c, eps)


# ---------------------------------------------------------------------------
# The masked batched fixed point
# ---------------------------------------------------------------------------

def _masked_while(step, v0, eps_v, max_iters):
    """Run ``v_new = step(v)`` (batched, (B, c) -> (B, c)) to per-lane
    convergence inside ONE while_loop. Converged lanes freeze; the loop
    exits when all lanes are done or at max_iters. Returns
    (v, delta (B,), iters (B,), total_it)."""
    b = v0.shape[0]

    def cond(state):
        _, _, _, done, it = state
        return jnp.logical_and(jnp.logical_not(jnp.all(done)), it < max_iters)

    def body(state):
        v, delta, iters, done, it = state
        v_new = step(v)
        # Frozen lanes keep their converged centers verbatim.
        v_new = jnp.where(done[:, None], v, v_new)
        d = jnp.max(jnp.abs(v_new - v), axis=1)
        delta = jnp.where(done, delta, d)
        iters = iters + jnp.where(done, 0, 1).astype(jnp.int32)
        done = jnp.logical_or(done, d < eps_v)
        return v_new, delta, iters, done, it + 1

    state = (v0,
             jnp.full((b,), jnp.inf, jnp.float32),
             jnp.zeros((b,), jnp.int32),
             jnp.zeros((b,), bool),
             jnp.asarray(0, jnp.int32))
    v, delta, iters, done, it = jax.lax.while_loop(cond, body, state)
    return v, delta, iters, it


@partial(jax.jit, static_argnames=("c", "m", "max_iters"))
def _batched_hist_loop(hists, c, m, eps, max_iters):
    """hists (B, n_bins) -> (centers (B, c), delta (B,), iters (B,), it)."""
    n_bins = hists.shape[1]
    vals = jnp.arange(n_bins, dtype=jnp.float32)
    v0, eps_v = _batched_init(hists, vals, c, eps)
    step = jax.vmap(lambda w, v: H.weighted_center_step(vals, w, v, m),
                    in_axes=(0, 0))
    return _masked_while(lambda v: step(hists, v), v0, eps_v, max_iters)


@partial(jax.jit, static_argnames=("c", "m", "max_iters"))
def _batched_pixel_loop(xs, c, m, eps, max_iters):
    """xs (B, N) same-shape pixel batch -> same outputs as the hist loop."""
    v0, eps_v = _linspace_init(jnp.min(xs, axis=1), jnp.max(xs, axis=1),
                               c, eps)
    step = jax.vmap(lambda x, v: F.fused_center_step(x, v, m),
                    in_axes=(0, 0))
    return _masked_while(lambda v: step(xs, v), v0, eps_v, max_iters)


# ---------------------------------------------------------------------------
# Public entry points
# ---------------------------------------------------------------------------

def histograms_of(imgs: Sequence[np.ndarray], n_bins: int = 256) -> jax.Array:
    """Stack per-image intensity histograms into (B, n_bins)."""
    return jnp.stack([H.intensity_histogram(
        jnp.asarray(np.asarray(im).ravel(), jnp.float32), n_bins)
        for im in imgs])


def fit_batched(imgs_or_hists: Union[jax.Array, np.ndarray, Sequence],
                cfg: F.FCMConfig = F.FCMConfig(),
                n_bins: int = 256,
                compute_labels: bool = True) -> BatchedFCMResult:
    """Batched histogram-compressed FCM.

    ``imgs_or_hists`` is either a ``(B, n_bins)`` array of histograms, or
    a sequence of images (any shapes/sizes — each is flattened and
    histogrammed on ingest, and per-image labels are returned).
    """
    imgs: Optional[List[np.ndarray]] = None
    if isinstance(imgs_or_hists, (jnp.ndarray, np.ndarray)) and \
            np.ndim(imgs_or_hists) == 2 and \
            np.shape(imgs_or_hists)[1] == n_bins:
        hists = jnp.asarray(imgs_or_hists, jnp.float32)
    else:
        imgs = [np.asarray(im) for im in imgs_or_hists]
        hists = histograms_of(imgs, n_bins)

    v, delta, iters, it = _batched_hist_loop(
        hists, cfg.n_clusters, cfg.m, cfg.eps, cfg.max_iters)

    labels = None
    if imgs is not None and compute_labels:
        vals = jnp.arange(n_bins, dtype=jnp.float32)
        # 256-entry LUT per image: label every bin once, then gather.
        luts = np.asarray(jax.vmap(
            lambda vv: F.labels_from_centers(vals, vv))(v))
        labels = [luts[i][np.clip(im.astype(np.int64), 0, n_bins - 1)]
                  for i, im in enumerate(imgs)]
    return BatchedFCMResult(centers=v, n_iters=np.asarray(iters),
                            final_delta=np.asarray(delta),
                            total_iters=int(it), labels=labels)


def fit_batched_pixels(xs, cfg: F.FCMConfig = F.FCMConfig(),
                       compute_labels: bool = True) -> BatchedFCMResult:
    """Batched FCM over a same-shape pixel batch ``(B, N)`` (or (B, H, W),
    flattened). For float-valued data that does not quantize to bins; for
    8-bit images prefer :func:`fit_batched`."""
    xs = jnp.asarray(xs, jnp.float32)
    xs = xs.reshape(xs.shape[0], -1)
    v, delta, iters, it = _batched_pixel_loop(
        xs, cfg.n_clusters, cfg.m, cfg.eps, cfg.max_iters)
    labels = None
    if compute_labels:
        labels = list(np.asarray(jax.vmap(F.labels_from_centers)(xs, v)))
    return BatchedFCMResult(centers=v, n_iters=np.asarray(iters),
                            final_delta=np.asarray(delta),
                            total_iters=int(it), labels=labels)


# ---------------------------------------------------------------------------
# Sharded variant: split the batch axis over the mesh
# ---------------------------------------------------------------------------

@lru_cache(maxsize=None)
def build_sharded_batched_fit(mesh: Mesh,
                              cfg: F.FCMConfig = F.FCMConfig(),
                              max_iters: Optional[int] = None):
    """Returns jit(fn)(hists (B, n_bins)) -> (centers, delta, iters).
    Cached on (mesh, cfg, max_iters) so repeated eager calls reuse one
    jitted closure instead of re-tracing per call.

    The batch axis is sharded over every mesh axis; each device runs the
    masked batched loop on its local lanes with **zero** per-iteration
    collectives (images are independent). B must divide by mesh.size.
    Complements ``core/distributed.py``, which shards pixels of ONE image
    and psums partial sums every iteration.
    """
    axes = mesh_axes(mesh)
    bspec = P(axes)                  # batch dim sharded over every axis
    c, m = cfg.n_clusters, cfg.m
    mi = cfg.max_iters if max_iters is None else max_iters

    def local_fit(hists):
        n_bins = hists.shape[1]
        vals = jnp.arange(n_bins, dtype=jnp.float32)
        v0, eps_v = _batched_init(hists, vals, c, cfg.eps)
        step = jax.vmap(lambda w, v: H.weighted_center_step(vals, w, v, m),
                        in_axes=(0, 0))
        v, delta, iters, _ = _masked_while(
            lambda v: step(hists, v), v0, eps_v, mi)
        return v, delta, iters

    fn = shard_map(local_fit, mesh=mesh,
                   in_specs=(P(axes, None),),
                   out_specs=(P(axes, None), bspec, bspec))
    return jax.jit(fn)


def fit_batched_sharded(hists, mesh: Mesh,
                        cfg: F.FCMConfig = F.FCMConfig()) -> BatchedFCMResult:
    """Eager entry point: pads the batch to the mesh size, shards, fits."""
    hists = jnp.asarray(hists, jnp.float32)
    b = hists.shape[0]
    n_pad = (-b) % mesh.size
    if n_pad:
        # Pad lanes with a uniform histogram; they converge and are dropped.
        pad = jnp.ones((n_pad, hists.shape[1]), jnp.float32)
        hists = jnp.concatenate([hists, pad])
    sharding = NamedSharding(mesh, P(mesh_axes(mesh), None))
    hists = jax.device_put(hists, sharding)
    v, delta, iters = build_sharded_batched_fit(mesh, cfg)(hists)
    return BatchedFCMResult(centers=v[:b], n_iters=np.asarray(iters)[:b],
                            final_delta=np.asarray(delta)[:b],
                            total_iters=int(np.max(np.asarray(iters)[:b]))
                            if b else 0)
