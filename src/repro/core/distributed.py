"""Multi-pod FCM via shard_map (beyond-paper optimization #3).

The paper's two-level reduction (CUDA shared-memory block sums -> device
global partials -> single-thread combine) generalizes to the pod scale:

  VMEM tile accumulation (Pallas / XLA fusion)      <- paper's level 1
  per-device partial sums                            <- paper's level 2
  psum over the ICI/DCN mesh (2c floats/iteration)   <- paper's "stay on
                                                        device" combine,
                                                        across devices

Pixels are sharded over **every** mesh axis (clustering has no model
dimension), so the same code runs on an 8-device CPU test mesh, a 256-chip
pod, or a multi-pod (pod, data, model) mesh. Per-iteration collective
traffic is O(c) floats independent of N — the algorithm is communication-
trivial and scales to thousands of nodes; fault tolerance only needs the
c-float center vector (see repro/training/checkpoint.py notes).
"""
from __future__ import annotations

from functools import partial
from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from . import fcm as F
from . import histogram as H
from . import solver as SV

try:                                  # jax >= 0.6 exposes shard_map at top level
    _shard_map = jax.shard_map
except AttributeError:                # pragma: no cover
    from jax.experimental.shard_map import shard_map as _shard_map  # type: ignore


def shard_map(f, *, mesh, in_specs, out_specs):
    """Version-tolerant shard_map: older jax has no replication rule for
    ``while`` and needs ``check_rep=False``; newer jax renamed/removed
    the flag. Our bodies run while_loops, so disable the check wherever
    the installed jax still spells it ``check_rep``."""
    try:
        return _shard_map(f, mesh=mesh, in_specs=in_specs,
                          out_specs=out_specs, check_rep=False)
    except TypeError:
        return _shard_map(f, mesh=mesh, in_specs=in_specs,
                          out_specs=out_specs)


def mesh_axes(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(mesh.axis_names)


def pad_to_devices(x, n_devices: int):
    """Pad (N,)->(N', ) with N' % n_devices == 0; returns (x_pad, w_pad).

    This masks padded *pixels* of one image: zero weights drop them from
    every weighted partial sum, so they cannot shift centers or the
    convergence test. Padded batch *lanes* (whole fake images added to
    round a ragged batch up to the mesh size) are masked differently —
    via the ``active`` mask of ``solver.masked_while_centers``, which
    freezes them at iteration 0 so they can't perturb per-lane or total
    iteration counts (see ``batched.fit_batched_sharded``)."""
    n = x.shape[0]
    n_pad = (-n) % n_devices
    xp = jnp.concatenate([jnp.asarray(x, jnp.float32),
                          jnp.zeros((n_pad,), jnp.float32)])
    w = jnp.concatenate([jnp.ones((n,), jnp.float32),
                         jnp.zeros((n_pad,), jnp.float32)])
    return xp, w


def masked_center_step(x, w, v, m):
    """Fused v->v' step with a validity mask (local partial sums only)."""
    u = F.update_membership(x, v, m)          # (c, n_local)
    um = (u ** m) * w[None, :]
    num = um @ x                              # (c,)
    den = jnp.sum(um, axis=1)                 # (c,)
    return num, den


def build_sharded_fit(mesh: Mesh, cfg: F.FCMConfig = F.FCMConfig()):
    """Returns jit(fn)(x_padded, weights) -> (centers, n_iters, delta).

    The returned function is AOT-lowerable with ShapeDtypeStructs (used by
    the dry-run). Pixels and weights must be pre-padded to a multiple of
    the mesh size; shard over all mesh axes on dim 0.
    """
    axes = mesh_axes(mesh)
    xspec = P(axes)           # dim0 sharded over every axis
    rspec = P()               # replicated

    c, m, max_iters = cfg.n_clusters, cfg.m, cfg.max_iters

    def local_fit(x, w):
        # --- init: global min/max via one tiny collective ---
        big = jnp.asarray(3.4e38, jnp.float32)
        lo = jax.lax.pmin(jnp.min(jnp.where(w > 0, x, big)), axes)
        hi = jax.lax.pmax(jnp.max(jnp.where(w > 0, x, -big)), axes)
        frac = (jnp.arange(c, dtype=jnp.float32) + 0.5) / c
        v0 = lo + frac * (hi - lo)
        eps_v = cfg.eps * jnp.maximum(hi - lo, 1.0) * 0.1

        def step(v):
            num, den = masked_center_step(x, w, v, m)
            num = jax.lax.psum(num, axes)          # 2c floats on the wire
            den = jax.lax.psum(den, axes)
            return num / jnp.maximum(den, 1e-12)

        # The convergence test is the solver core's — only the step
        # (with its psums) is distributed-specific.
        v, delta, it = SV.while_centers(step, v0, eps_v, max_iters)
        labels = F.labels_from_centers(x, v)
        return v, labels, delta, it

    fn = shard_map(local_fit, mesh=mesh,
                   in_specs=(xspec, xspec),
                   out_specs=(rspec, xspec, rspec, rspec))
    return jax.jit(fn)


def build_sharded_histogram_fit(mesh: Mesh,
                                cfg: F.FCMConfig = F.FCMConfig(),
                                n_bins: int = 256):
    """Histogram-compressed distributed fit: ONE psum of 256 floats total,
    then the per-iteration loop is fully local/replicated."""
    axes = mesh_axes(mesh)
    xspec = P(axes)
    rspec = P()
    c, m = cfg.n_clusters, cfg.m

    def local_fit(x, w):
        idx = jnp.clip(x.astype(jnp.int32), 0, n_bins - 1)
        hist = jnp.zeros((n_bins,), jnp.float32).at[idx].add(w)
        hist = jax.lax.psum(hist, axes)            # the only O(bins) psum
        vals = jnp.arange(n_bins, dtype=jnp.float32)
        nz = hist > 0
        lo = jnp.min(jnp.where(nz, vals, jnp.asarray(3.4e38)))
        hi = jnp.max(jnp.where(nz, vals, jnp.asarray(-3.4e38)))
        frac = (jnp.arange(c, dtype=jnp.float32) + 0.5) / c
        v0 = lo + frac * (hi - lo)
        eps_v = cfg.eps * jnp.maximum(hi - lo, 1.0) * 0.1

        # Post-psum the loop is fully local/replicated: plain weighted
        # FCM over 256 rows, driven by the solver core's loop.
        v, delta, it = SV.while_centers(
            lambda v: H.weighted_center_step(vals, hist, v, m),
            v0, eps_v, cfg.max_iters)
        labels = F.labels_from_centers(x, v)
        return v, labels, delta, it

    fn = shard_map(local_fit, mesh=mesh,
                   in_specs=(xspec, xspec),
                   out_specs=(rspec, xspec, rspec, rspec))
    return jax.jit(fn)


def fit_sharded(x, mesh: Mesh, cfg: F.FCMConfig = F.FCMConfig(),
                histogram: bool = False) -> F.FCMResult:
    """Eager entry point: pads, shards, fits, unpads."""
    n = x.shape[0]
    xp, w = pad_to_devices(x, mesh.size)
    sharding = NamedSharding(mesh, P(mesh_axes(mesh)))
    xp = jax.device_put(xp, sharding)
    w = jax.device_put(w, sharding)
    fit = (build_sharded_histogram_fit if histogram
           else build_sharded_fit)(mesh, cfg)
    v, labels, delta, it = fit(xp, w)
    return F.FCMResult(centers=v, labels=labels[:n], n_iters=int(it),
                       final_delta=float(delta))
