"""Histogram-compressed FCM (beyond-paper optimization #2).

8-bit grayscale images have at most 256 distinct intensities, so FCM over
pixels is algebraically identical to *weighted* FCM over (value, count)
pairs: every sum over pixels factors through the histogram. One O(N)
counting pass replaces the per-iteration O(N·c) traffic with O(256·c)
arithmetic — the data-reduction idea of br-FCM [Eschrich et al. 2003],
which the paper cites as related work [11] but does not implement.

Distributed: each shard histograms locally, one psum(256) merges, and the
(tiny) weighted FCM then runs replicated on every device with **zero**
further communication per iteration.
"""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from . import fcm as F


@partial(jax.jit, static_argnames=("n_bins",))
def _histogram_impl(x: jax.Array, n_bins: int) -> jax.Array:
    idx = jnp.clip(x.astype(jnp.int32), 0, n_bins - 1)
    return jnp.zeros((n_bins,), jnp.float32).at[idx].add(1.0)


def intensity_histogram(x: jax.Array, n_bins: int = 256,
                        clip: bool = False) -> jax.Array:
    """Counts per integer intensity; x is float-valued but integral.

    The binning *clamps* to [0, n_bins): without validation, a
    normalized float image in [0, 1] silently piles every pixel into
    bins 0/1 and the downstream fit segments garbage. Unless
    ``clip=True`` (the documented I-really-mean-it escape hatch that
    restores the old clamp-silently behavior), concrete inputs are
    validated eagerly and out-of-range or normalized-looking data
    raises ``ValueError``. Traced inputs (inside jit/vmap) skip the
    check — values are unknowable there.
    """
    if not clip and not isinstance(x, jax.core.Tracer):
        lo = float(jnp.min(x))
        hi = float(jnp.max(x))
        if lo < 0.0 or hi > n_bins - 1:
            raise ValueError(
                f"intensity_histogram: values in [{lo:g}, {hi:g}] fall "
                f"outside the bin range [0, {n_bins - 1}]; rescale the "
                f"image or pass clip=True to clamp deliberately")
        if (n_bins > 2 and 0.0 < hi <= 1.0
                and jnp.issubdtype(jnp.asarray(x).dtype, jnp.floating)
                and bool(jnp.any(x != jnp.round(x)))):
            # integral float data in {0, 1} (e.g. a binary mask cast to
            # float) is legitimate 8-bit-range input; only *fractional*
            # values betray a normalized image
            raise ValueError(
                f"intensity_histogram: float values span [{lo:g}, {hi:g}] "
                f"— this looks like a [0, 1]-normalized image, which "
                f"would collapse into bins 0/1 of {n_bins}; multiply by "
                f"{n_bins - 1} first or pass clip=True to bin as-is")
    return _histogram_impl(x, n_bins)


def weighted_membership(vals: jax.Array, v: jax.Array, m: float) -> jax.Array:
    return F.update_membership(vals, v, m)


def weighted_center_step(vals: jax.Array, w: jax.Array, v: jax.Array,
                         m: float) -> jax.Array:
    """Fused v -> v' step over (value, weight) pairs — the scalar face of
    the canonical :func:`repro.core.solver.weighted_center_step`."""
    from . import solver as SV
    out = SV.weighted_center_step(vals, w, F._as_2d(v), m)
    return out[:, 0] if jnp.ndim(v) == 1 else out


def fit_histogram(x: jax.Array, cfg: F.FCMConfig = F.FCMConfig(),
                  n_bins: int = 256,
                  hist: Optional[jax.Array] = None) -> F.FCMResult:
    """DEPRECATED alias — use
    ``solver.solve(solver.histogram_problem(x, cfg))``.

    FCM via histogram compression. ``hist`` may be supplied directly
    (e.g. a psum-merged global histogram in the distributed path);
    labels still come back per-pixel."""
    from . import solver as SV
    SV.warn_deprecated("fit_histogram",
                       "solver.solve(histogram_problem(x, cfg))")
    x = jnp.asarray(x, jnp.float32)
    problem = SV.histogram_problem(x, cfg, hist=hist, n_bins=n_bins)
    res = SV.solve(problem, cfg, backend="reference")
    return F.FCMResult(centers=res.centers,
                       labels=F.labels_from_centers(x, res.centers),
                       n_iters=res.n_iters, final_delta=res.final_delta)
