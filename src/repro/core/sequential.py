"""Sequential FCM baselines (the paper's comparison floor).

The paper benchmarks against a sequential C implementation derived from a
Java reference. Two fidelity levels are provided:

* :func:`fcm_sequential_python` — literal per-pixel loops, matching the
  C code's structure statement-for-statement. Only usable for tiny N;
  exists so tests can pin the numerics of the other implementations to
  the paper's reference semantics.
* :func:`fcm_sequential_numpy` — the same algorithm vectorized with
  single-threaded numpy. This is the honest "sequential CPU" comparator
  on this container (a Python interpreter loop would understate the
  paper's C baseline by ~100x; numpy is the closest stand-in for
  compiled single-core C).

Both sit behind the unified solver as
``repro.core.solver.solve(pixel_problem(x), backend="sequential")`` —
the paper's CPU-vs-device comparison (benchmarks/table3_speedup.py)
runs every side from that one entry point.
"""
from __future__ import annotations

import numpy as np


def _init_membership(rng: np.random.Generator, c: int, n: int) -> np.ndarray:
    u = rng.uniform(1e-3, 1.0, size=(c, n))
    return u / u.sum(axis=0, keepdims=True)


def fcm_sequential_python(x, c=4, m=2.0, eps=5e-3, max_iters=300, seed=0):
    """Literal port: nested loops over pixels and clusters."""
    x = np.asarray(x, np.float64).ravel()
    n = x.shape[0]
    rng = np.random.default_rng(seed)
    u = _init_membership(rng, c, n)
    v = np.zeros(c)
    exp = -2.0 / (m - 1.0)
    for it in range(max_iters):
        # Eq. 3 — cluster centers (the paper's 4-kernel phase, as loops).
        for j in range(c):
            num = 0.0
            den = 0.0
            for i in range(n):
                w = u[j, i] ** m
                num += w * x[i]
                den += w
            v[j] = num / max(den, 1e-12)
        # Eq. 4 — memberships.
        u_new = np.empty_like(u)
        for i in range(n):
            d = np.abs(x[i] - v)
            if np.any(d == 0.0):
                z = (d == 0.0)
                u_new[:, i] = z / z.sum()
                continue
            p = d ** exp
            u_new[:, i] = p / p.sum()
        delta = np.abs(u_new - u).max()
        u = u_new
        if delta < eps:
            break
    labels = u.argmax(axis=0).astype(np.int32)
    return v, labels, it + 1


def fcm_sequential_numpy(x, c=4, m=2.0, eps=5e-3, max_iters=300, seed=0,
                         u0=None):
    """Single-core numpy FCM, same algorithm and init as the Python port."""
    x = np.asarray(x, np.float64).ravel()
    n = x.shape[0]
    rng = np.random.default_rng(seed)
    u = _init_membership(rng, c, n) if u0 is None else np.asarray(u0, np.float64)
    for it in range(max_iters):
        um = u ** m                                    # (c, n)
        v = (um @ x) / np.maximum(um.sum(axis=1), 1e-12)
        d2 = (v[:, None] - x[None, :]) ** 2            # (c, n)
        p = np.clip(d2, 1e-12, None) ** (-1.0 / (m - 1.0))
        u_new = p / p.sum(axis=0, keepdims=True)
        zero = d2 <= 0.0
        any_zero = zero.any(axis=0)
        if any_zero.any():
            zz = zero[:, any_zero]
            u_new[:, any_zero] = zz / zz.sum(axis=0, keepdims=True)
        delta = np.abs(u_new - u).max()
        u = u_new
        if delta < eps:
            break
    labels = u.argmax(axis=0).astype(np.int32)
    return v, labels, it + 1
