"""The unified weighted-feature FCM solver core.

Every FCM variant in this repo is the same algorithm wearing a different
feature map: the fixed point iterates ``v -> step(v)`` where ``step``
substitutes the Eq. 4 membership into the Eq. 3 weighted center update
over some set of (feature row, weight) pairs —

=============  ==========================  =====================
variant        feature rows                row weights
=============  ==========================  =====================
pixels         ``(N,)`` / ``(N, D)``       1
histogram      256 bin values              bin counts
superpixels    ``(K, D)`` mean features    pixel counts
FCM_S          the pixel grid + stencil    1 (stencil-effective)
=============  ==========================  =====================

This module owns that fixed point **once**: :class:`FCMProblem` names the
feature map, :func:`solve` runs it, and :func:`solve_batched` runs a
stacked batch of independent problems with per-lane convergence masking.
The two ``lax.while_loop`` drivers (:func:`while_centers`,
:func:`masked_while_centers`) here are the ONLY convergence loops in the
repo — the legacy ``fit_*`` entry points in :mod:`repro.core.fcm`,
``histogram``, ``spatial``, ``vector_fcm`` and ``batched`` are deprecated
thin adapters over this module, and the distributed/SLIC fixed points
drive their steps through the same loops.

Step implementations (pure-jnp reference vs the Pallas kernels) are
selected through the dispatch registry in :mod:`repro.kernels.ops` by
problem shape and platform; ``backend=`` forces a choice:

* ``"auto"``       — registry pick (Pallas on TPU where eligible,
  pure-jnp reference elsewhere),
* ``"reference"``  — pure-jnp step,
* ``"pallas"``     — Pallas kernels (interpret mode off-TPU; tests only),
* ``"staged"``     — the paper-faithful host loop: staged kernels,
  membership materialized between stages, host-side ``|u' - u|_inf``
  convergence test (what :func:`repro.core.fcm.fit_baseline` wraps),
* ``"sequential"`` — the single-core numpy comparator from
  :mod:`repro.core.sequential` (the paper's CPU baseline), so the
  paper's CPU-vs-device comparison runs from this one entry point,
* ``"resident"``   — the VMEM-resident whole-solve kernel: for flat
  problems that fit on-chip (<= 256 rows, c <= 8, D <= 8 — histogram
  and superpixel payloads) the COMPLETE convergence loop runs inside
  one ``pallas_call``, zero HBM round-trips and zero per-iteration
  dispatch. ``auto`` picks it on TPU when the problem fits; off-TPU it
  falls back to the reference step (pass ``interpret=True`` to force
  the kernel for parity testing).
"""
from __future__ import annotations

import dataclasses
import warnings
from functools import partial
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import fcm as F

_D2_FLOOR = 1e-12
_BIG = 3.4e38

BACKENDS = ("auto", "reference", "pallas", "staged", "sequential",
            "resident")


def _record_telemetry(kind: str, impl: str, n_iters: int,
                      final_delta: Optional[float] = None,
                      lane_iters=None) -> None:
    """Convergence telemetry into the process-wide obs registry: every
    solve records its iterations-to-converge (per lane for batched
    solves) and final residual, so iteration-count regressions are
    visible independently of wall time. Counters/histograms:

      solver.solves{kind,impl}        — solve() / solve_batched() calls
      solver.lanes{kind,impl}         — problems solved (B per batch)
      solver.iters{kind}              — iteration-count histogram
      solver.last_final_delta{kind}   — last center-movement residual
    """
    from repro import obs
    reg = obs.default_registry()
    reg.counter("solver.solves", kind=kind, impl=impl).inc()
    h = reg.histogram("solver.iters", edges=obs.ITER_EDGES, kind=kind)
    if lane_iters is not None:
        reg.counter("solver.lanes", kind=kind, impl=impl).inc(
            len(lane_iters))
        for it in lane_iters:
            h.record(int(it))
    else:
        reg.counter("solver.lanes", kind=kind, impl=impl).inc(1)
        h.record(int(n_iters))
    if final_delta is not None and not np.isnan(final_delta):
        reg.gauge("solver.last_final_delta", kind=kind).set(
            float(final_delta))


def warn_deprecated(old: str, new: str) -> None:
    """One-release deprecation shim for the legacy ``fit_*`` aliases."""
    warnings.warn(
        f"{old} is deprecated; build an FCMProblem and call {new} "
        f"(see README 'Migrating from the fit_* zoo')",
        DeprecationWarning, stacklevel=3)


# ---------------------------------------------------------------------------
# Problem specification
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class StencilSpec:
    """FCM_S neighborhood regularization (Ahmed-style).

    ``alpha`` weighs the neighborhood penalty (0 degenerates to plain
    FCM); ``neighbors`` is the stencil arity — 4 or 8 for 2-D images,
    6 for 3-D volumes.
    """
    alpha: float = 1.0
    neighbors: int = 4


@dataclasses.dataclass(frozen=True)
class FCMProblem:
    """One weighted-feature FCM problem (or a stacked batch of them).

    ``features`` is ``(K,)`` / ``(K, D)`` weighted rows for flat
    problems, or the raw pixel grid ``(H, W)`` / ``(D, H, W)`` when
    ``stencil`` is set (FCM_S needs positions, so it cannot reduce to
    rows). With ``batch=True`` a leading lane axis is added everywhere
    and lanes are independent problems. ``weights`` are per-row
    multiplicities (``None`` = 1; stencil problems take no weights).
    ``init`` overrides the default weighted-support linspace ``v0``.
    """
    features: Any
    weights: Any = None
    c: int = 4
    m: float = 2.0
    stencil: Optional[StencilSpec] = None
    init: Any = None
    batch: bool = False

    def __post_init__(self):
        feats = jnp.asarray(self.features, jnp.float32)
        object.__setattr__(self, "features", feats)
        if self.weights is not None:
            object.__setattr__(self, "weights",
                               jnp.asarray(self.weights, jnp.float32))
        if self.init is not None:
            object.__setattr__(self, "init",
                               jnp.asarray(self.init, jnp.float32))
        lead = 1 if self.batch else 0
        if self.stencil is not None:
            if self.weights is not None:
                raise ValueError("stencil problems take no row weights "
                                 "(every grid pixel weighs 1)")
            if feats.ndim - lead not in (2, 3):
                raise ValueError(
                    f"stencil problems need a (H, W) or (D, H, W) pixel "
                    f"grid{' per lane' if self.batch else ''}, got shape "
                    f"{feats.shape}")
            ndim = feats.ndim - lead
            ok = (4, 8) if ndim == 2 else (6,)
            if self.stencil.neighbors not in ok:
                raise ValueError(
                    f"{ndim}-D neighborhoods are "
                    f"{' or '.join(map(str, ok))}-connected, got "
                    f"{self.stencil.neighbors}")
        else:
            if feats.ndim - lead not in (1, 2):
                raise ValueError(
                    f"flat problems need (K,) or (K, D) feature rows"
                    f"{' per lane' if self.batch else ''}, got shape "
                    f"{feats.shape}")

    # -- shape helpers -----------------------------------------------------

    @property
    def scalar(self) -> bool:
        """True when centers should come back featureless, shape (c,)."""
        lead = 1 if self.batch else 0
        if self.stencil is not None:
            return True
        return self.features.ndim - lead == 1

    @property
    def n_feat(self) -> int:
        if self.scalar:
            return 1
        return self.features.shape[-1]

    @property
    def n_rows(self) -> Optional[int]:
        """Problem size the registry's VMEM-residency bounds are
        checked against: the row count of a flat problem, or the
        per-lane PIXEL count of a stencil problem (what the resident
        stencil solve must hold in VMEM)."""
        lead = 1 if self.batch else 0
        if self.stencil is not None:
            return int(np.prod(self.features.shape[lead:]))
        return int(self.features.shape[lead])

    def rows(self) -> Tuple[jax.Array, jax.Array]:
        """Canonical ``(K, D)`` rows + ``(K,)`` weights (flat problems;
        with ``batch=True`` a leading lane axis on both)."""
        if self.stencil is not None:
            raise ValueError("stencil problems have no flat rows")
        feats = self.features
        lead = 1 if self.batch else 0
        if feats.ndim - lead == 1:
            feats = feats[..., None]
        w = self.weights
        if w is None:
            w = jnp.ones(feats.shape[:-1], jnp.float32)
        return feats, w


# -- problem factories (what the deprecated fit_* adapters build) -----------

def _cfg_c_m(cfg, c, m):
    if cfg is not None:
        c = cfg.n_clusters if c is None else c
        m = cfg.m if m is None else m
    return (4 if c is None else int(c)), (2.0 if m is None else float(m))


def pixel_problem(x, cfg: Optional[F.FCMConfig] = None, *,
                  c: Optional[int] = None, m: Optional[float] = None,
                  v0=None) -> FCMProblem:
    """Uncompressed pixels (the paper's problem): ``x`` is ``(N,)``
    grayscale or ``(N, D)`` feature rows, every row weighing 1."""
    c, m = _cfg_c_m(cfg, c, m)
    return FCMProblem(features=x, c=c, m=m, init=v0)


def histogram_problem(x=None, cfg: Optional[F.FCMConfig] = None, *,
                      hist=None, n_bins: int = 256,
                      c: Optional[int] = None, m: Optional[float] = None,
                      v0=None) -> FCMProblem:
    """Histogram-compressed scalar FCM: ``n_bins`` (value, count) rows.
    Pass pixels ``x`` (histogrammed on ingest) or a prebuilt ``hist``."""
    from . import histogram as H
    c, m = _cfg_c_m(cfg, c, m)
    if hist is None:
        if x is None:
            raise ValueError("histogram_problem needs pixels x or a hist")
        hist = H.intensity_histogram(jnp.asarray(x, jnp.float32), n_bins)
    vals = jnp.arange(n_bins, dtype=jnp.float32)
    return FCMProblem(features=vals, weights=hist, c=c, m=m, init=v0)


def vector_problem(feats, weights=None, cfg: Optional[F.FCMConfig] = None, *,
                   c: Optional[int] = None, m: Optional[float] = None,
                   v0=None) -> FCMProblem:
    """Weighted vector rows (the superpixel-compression payload)."""
    c, m = _cfg_c_m(cfg, c, m)
    return FCMProblem(features=feats, weights=weights, c=c, m=m, init=v0)


def spatial_problem(img, cfg=None, *, alpha: Optional[float] = None,
                    neighbors: Optional[int] = None,
                    c: Optional[int] = None, m: Optional[float] = None,
                    v0=None) -> FCMProblem:
    """FCM_S over a 2-D image or 3-D volume. ``cfg`` may be a
    :class:`repro.core.spatial.SpatialFCMConfig` (supplies
    alpha/neighbors too); 3-D volumes always use the 6-stencil."""
    c, m = _cfg_c_m(cfg, c, m)
    if alpha is None:
        alpha = getattr(cfg, "alpha", 1.0)
    if neighbors is None:
        neighbors = getattr(cfg, "neighbors", 4)
    img = jnp.asarray(img, jnp.float32)
    if img.ndim == 3:
        neighbors = 6
    return FCMProblem(features=img, c=c, m=m,
                      stencil=StencilSpec(alpha=float(alpha),
                                          neighbors=int(neighbors)),
                      init=v0)


def batch_problems(features, weights=None, *, stencil=None,
                   cfg: Optional[F.FCMConfig] = None,
                   c: Optional[int] = None,
                   m: Optional[float] = None) -> FCMProblem:
    """Stack same-shape independent problems along a leading lane axis:
    flat ``(B, K[, D])`` rows (+ ``(B, K)`` weights) or stencil
    ``(B, H, W)`` / ``(B, D, H, W)`` grids."""
    c, m = _cfg_c_m(cfg, c, m)
    return FCMProblem(features=features, weights=weights, c=c, m=m,
                      stencil=stencil, batch=True)


# ---------------------------------------------------------------------------
# The canonical center update and convergence loops
# ---------------------------------------------------------------------------

def weighted_center_step(feats: jax.Array, w: jax.Array, v: jax.Array,
                         m: float) -> jax.Array:
    """THE core update: one fused ``v -> v'`` step of weighted FCM.

    Eq. 4 membership on the rows substituted into the weighted Eq. 3
    center update; memberships never leave the step. ``feats`` ``(K,)``
    or ``(K, D)``, ``w`` ``(K,)`` (zero rows are inert), ``v`` ``(c, D)``
    -> ``(c, D)``. With unit weights and scalar rows this is bitwise
    :func:`repro.core.fcm.fused_center_step`.
    """
    feats2 = F._as_2d(feats)
    u = F.update_membership(feats2, v, m)                 # (c, K)
    um = (u ** m) * w[None, :]
    # broadcast-multiply-sum rather than `um @ feats2`: the reduction
    # order matches fcm.update_centers bitwise, which is what keeps the
    # unit-weight case (and FCM_S at alpha=0, which goes through
    # update_centers) iteration-for-iteration identical to this step —
    # the parity the adapter tests pin. XLA fuses the product into the
    # reduction, and with c ~ 4 the matmul would not be MXU-bound anyway.
    num = jnp.sum(um[:, :, None] * feats2[None, :, :], axis=1)
    den = jnp.maximum(jnp.sum(um, axis=1)[:, None], _D2_FLOOR)
    return num / den


def while_centers(step, v0, tol, max_iters):
    """Device-resident center fixed point: iterate ``v -> step(v)`` until
    ``max|v' - v| < tol`` or ``max_iters``. Returns ``(v, delta, it)``.

    This (plus :func:`masked_while_centers`) is the only FCM convergence
    loop in the repo; every variant's trajectory is defined by it.
    """
    def cond(state):
        _, delta, it = state
        return jnp.logical_and(delta >= tol, it < max_iters)

    def body(state):
        v, _, it = state
        v_new = step(v)
        delta = jnp.max(jnp.abs(v_new - v))
        return v_new, delta, it + 1

    state = (jnp.asarray(v0, jnp.float32),
             jnp.asarray(jnp.inf, jnp.float32),
             jnp.asarray(0, jnp.int32))
    return jax.lax.while_loop(cond, body, state)


def masked_while_centers(step, v0, tol, max_iters, active=None):
    """Per-lane-masked batched fixed point: run ``v' = step(v)``
    (``(B, cd) -> (B, cd)``) until every lane's ``max|v' - v| < tol[b]``
    or ``max_iters``, inside ONE while_loop. Converged lanes freeze
    (centers verbatim, iteration counters stop), so each lane's
    trajectory is identical to a solo :func:`while_centers` run.

    ``active`` is an optional ``(B,)`` bool mask naming the *real*
    lanes: inactive lanes (batch padding up to a bucket or mesh size)
    start frozen — they keep ``v0`` verbatim, report 0 iterations and a
    0.0 residual, and can neither stretch the loop's trip count nor
    perturb any convergence statistic. ``None`` means every lane is
    real (the pre-existing behavior, bitwise).

    Returns ``(v, delta (B,), iters (B,), total_it)``."""
    b = v0.shape[0]

    def cond(state):
        _, _, _, done, it = state
        return jnp.logical_and(jnp.logical_not(jnp.all(done)), it < max_iters)

    def body(state):
        v, delta, iters, done, it = state
        v_new = step(v)
        v_new = jnp.where(done[:, None], v, v_new)
        d = jnp.max(jnp.abs(v_new - v), axis=1)
        delta = jnp.where(done, delta, d)
        iters = iters + jnp.where(done, 0, 1).astype(jnp.int32)
        done = jnp.logical_or(done, d < tol)
        return v_new, delta, iters, done, it + 1

    if active is None:
        done0 = jnp.zeros((b,), bool)
        delta0 = jnp.full((b,), jnp.inf, jnp.float32)
    else:
        done0 = jnp.logical_not(jnp.asarray(active, bool))
        delta0 = jnp.where(done0, 0.0, jnp.inf).astype(jnp.float32)
    state = (v0,
             delta0,
             jnp.zeros((b,), jnp.int32),
             done0,
             jnp.asarray(0, jnp.int32))
    v, delta, iters, done, it = jax.lax.while_loop(cond, body, state)
    return v, delta, iters, it


# ---------------------------------------------------------------------------
# Init + tolerance from the weighted feature support
# ---------------------------------------------------------------------------

def weighted_support(feats2: jax.Array, w: jax.Array):
    """Per-dimension (lo, hi) over rows with nonzero weight — empty
    superpixels, zero histogram bins and batch padding must stretch
    neither the init nor the tolerance. ``(K, D)``, ``(K,)`` -> (D,) x2."""
    active = (w > 0)[:, None]
    lo = jnp.min(jnp.where(active, feats2, _BIG), axis=0)
    hi = jnp.max(jnp.where(active, feats2, -_BIG), axis=0)
    return lo, hi


def linspace_from_support(lo: jax.Array, hi: jax.Array, c: int) -> jax.Array:
    """lo/hi (..., D) -> per-dimension linspace centers (..., c, D)."""
    frac = (jnp.arange(c, dtype=lo.dtype) + 0.5) / c
    shape = (1,) * (lo.ndim - 1) + (c, 1)
    return lo[..., None, :] + frac.reshape(shape) * (hi - lo)[..., None, :]


def _tol_from_range(rng, eps):
    """Center-movement tolerance: the membership test at eps corresponds
    to a center test at ~eps * data-range (Lipschitz); scaled by 0.1."""
    return eps * jnp.where(rng > 0, rng, 1.0) * 0.1


@partial(jax.jit, static_argnames=("b",))
def _lane_tol_stencil(features, eps, b):
    flat = features.reshape(b, -1)
    rng = jnp.max(flat, axis=1) - jnp.min(flat, axis=1)
    return _tol_from_range(rng, eps)


@jax.jit
def _lane_tol_flat(feats, w, eps):
    lo, hi = jax.vmap(weighted_support)(feats, w)
    return _tol_from_range(jnp.max(hi - lo, axis=1), eps)


def lane_tolerances(problem: FCMProblem, eps: float) -> np.ndarray:
    """Host-side replica of the per-lane center-movement tolerances the
    batched loop drivers derive internally (same f32 arithmetic), so a
    post-solve pass can decide per lane whether ``final_delta`` actually
    met the stop test — the ``converged`` signal on
    :class:`BatchedFCMResult`. Jitted per shape: this runs on every
    ``solve_batched`` call, so eager dispatch here would tax the B=64
    hot path the throughput gate times."""
    if problem.stencil is not None:
        b = problem.features.shape[0]
        return np.asarray(_lane_tol_stencil(problem.features, eps, b))
    feats, w = problem.rows()
    return np.asarray(_lane_tol_flat(feats, w, eps))


def _single_init(problem: FCMProblem, eps: float, tol: Optional[float]):
    """Concrete (v0 (c, D), tol) for one problem (eager, like fit_*)."""
    if problem.stencil is not None:
        flat = problem.features.reshape(-1, 1)
        w = jnp.ones((flat.shape[0],), jnp.float32)
    else:
        flat, w = problem.rows()
    lo, hi = weighted_support(flat, w)
    if problem.init is not None:
        v0 = F._as_2d(problem.init)
    else:
        v0 = linspace_from_support(lo, hi, problem.c)
    if tol is None:
        # Same formula (and f32 arithmetic) as the batched per-lane
        # tolerances, so a lane's trajectory matches its solo solve.
        tol = float(_tol_from_range(jnp.max(hi - lo), eps))
    return v0, tol


# ---------------------------------------------------------------------------
# Jitted loop drivers (one per step kind x impl; stable jit signatures)
# ---------------------------------------------------------------------------

@partial(jax.jit, static_argnames=("c", "m", "max_iters"))
def _flat_loop(feats2, w, v0, c, m, tol, max_iters):
    from repro.kernels import ops as kops
    step = kops.build_step("flat", "reference", feats=feats2, weights=w, m=m)
    return while_centers(step, v0, tol, max_iters)


@partial(jax.jit, static_argnames=("c", "m", "max_iters", "block_rows",
                                   "interpret"))
def _flat_loop_pallas(x2d, w2d, v0, c, m, tol, max_iters, block_rows,
                      interpret):
    from repro.kernels import ops as kops
    step = kops.build_step("flat", "pallas", x2d=x2d, w2d=w2d, m=m,
                           block_rows=block_rows, interpret=interpret)
    return while_centers(step, v0, tol, max_iters)


@partial(jax.jit, static_argnames=("c", "m", "max_iters", "interpret"))
def _flat_loop_resident(x4, w3, v0, c, m, tol, max_iters, interpret):
    """Single-problem face of the VMEM-resident whole-solve kernel
    (one lane); returns the same (v, delta, it) triple as the other
    loop drivers."""
    from repro.kernels import ops as kops
    solve_fn = kops.build_step("flat", "resident", x4=x4, w3=w3, m=m,
                               max_iters=max_iters, interpret=interpret)
    v, delta, it = solve_fn(v0[None], jnp.asarray(tol, jnp.float32)[None])
    return v[0], delta[0], it[0]


@partial(jax.jit, static_argnames=("m", "alpha", "neighbors", "max_iters"))
def _stencil_loop(img, v0, m, alpha, neighbors, tol, max_iters):
    from repro.kernels import ops as kops
    step = kops.build_step("stencil", "reference", img=img, m=m,
                           alpha=alpha, neighbors=neighbors)
    return while_centers(step, v0, tol, max_iters)


@partial(jax.jit, static_argnames=("c", "m", "max_iters", "interpret"))
def _flat_loop_resident_streamed(x4, w3, v0, c, m, tol, max_iters,
                                 interpret):
    """Single-problem face of the HBM-streamed whole-solve kernel
    (inputs pre-tiled with ``rows_multiple=STREAM_CHUNK_ROWS``)."""
    from repro.kernels import ops as kops
    solve_fn = kops.build_step("flat", "resident_streamed", x4=x4, w3=w3,
                               m=m, max_iters=max_iters,
                               interpret=interpret)
    v, delta, it = solve_fn(v0[None], jnp.asarray(tol, jnp.float32)[None])
    return v[0], delta[0], it[0]


@partial(jax.jit, static_argnames=("m", "alpha", "neighbors", "max_iters",
                                   "block_rows", "interpret"))
def _stencil_loop_pallas(xpad, wpad, v0, m, alpha, neighbors, tol,
                         max_iters, block_rows, interpret):
    from repro.kernels import ops as kops
    step = kops.build_step("stencil", "pallas", xpad=xpad, wpad=wpad, m=m,
                           alpha=alpha, neighbors=neighbors,
                           block_rows=block_rows, interpret=interpret)
    return while_centers(step, v0, tol, max_iters)


@partial(jax.jit, static_argnames=("m", "alpha", "neighbors", "max_iters",
                                   "interpret"))
def _stencil_loop_resident(xpad, vpad, v0, m, alpha, neighbors, tol,
                           max_iters, interpret):
    """Single-problem face of the VMEM-resident FCM_S whole-solve
    (one lane; inputs from ``tile_grid_batched``). Returns the same
    ``(v (c, 1), delta, it)`` triple as the other stencil drivers."""
    from repro.kernels import ops as kops
    solve_fn = kops.build_step("stencil", "resident", xpad=xpad, vpad=vpad,
                               m=m, alpha=alpha, neighbors=neighbors,
                               max_iters=max_iters, interpret=interpret)
    v, delta, it = solve_fn(v0[None, :, 0],
                            jnp.asarray(tol, jnp.float32)[None])
    return v[0][:, None], delta[0], it[0]


def flat_batched_solve(feats, w, c, m, eps, max_iters,
                       impl: str = "reference", interpret: bool = False,
                       active=None):
    """Traceable batched flat solve: feats (B, K, D), w (B, K) ->
    (v (B, c, D), delta (B,), iters (B,), total). The core both jitted
    loop drivers wrap, exported un-jitted so larger device programs
    (the serving engine's fused route programs) can inline it and keep a
    whole request batch at ONE dispatch. ``impl`` picks the registry
    implementation: ``"reference"`` is the per-lane-masked vmapped
    ``while_loop``; ``"resident"`` / ``"resident_streamed"`` run every
    lane's complete convergence loop inside one whole-solve kernel
    (VMEM-held vs HBM-streamed rows; each lane stops at its own
    convergence point, so trajectories match solo solves either
    way). ``active`` is the optional (B,) real-lane mask of
    :func:`masked_while_centers` — padding lanes freeze at iteration 0
    (reference impl only; the whole-solve kernels have no lane mask)."""
    from repro.kernels import ops as kops
    from repro.kernels import fcm_resident as KR
    b, _, d = feats.shape
    lo, hi = jax.vmap(weighted_support)(feats, w)           # (B, D) each
    v0 = linspace_from_support(lo, hi, c)                   # (B, c, D)
    tol = _tol_from_range(jnp.max(hi - lo, axis=1), eps)

    if impl in ("resident", "resident_streamed"):
        if active is not None:
            raise ValueError("active lane masks are supported by the "
                             "reference impl only (the whole-solve "
                             "kernels run every lane)")
        rows_multiple = (KR.STREAM_CHUNK_ROWS
                         if impl == "resident_streamed" else 1)
        x4, w3 = kops.tile_rows_batched(feats, w,
                                        rows_multiple=rows_multiple)
        solve_fn = kops.build_step("flat", impl, x4=x4, w3=w3, m=m,
                                   max_iters=max_iters, interpret=interpret)
        v, delta, iters = solve_fn(v0, tol)
        return v, delta, iters, jnp.max(iters)

    vstep = jax.vmap(weighted_center_step, in_axes=(0, 0, 0, None))

    def flat_step(vflat):
        return vstep(feats, w, vflat.reshape(b, c, d), m).reshape(b, c * d)

    v, delta, iters, it = masked_while_centers(
        flat_step, v0.reshape(b, c * d), tol, max_iters, active=active)
    return v.reshape(b, c, d), delta, iters, it


@partial(jax.jit, static_argnames=("c", "m", "max_iters"))
def _flat_batched_loop(feats, w, c, m, eps, max_iters):
    """feats (B, K, D), w (B, K) -> (v (B, c, D), delta, iters, total)."""
    return flat_batched_solve(feats, w, c, m, eps, max_iters)


@partial(jax.jit, static_argnames=("c", "m", "max_iters"))
def _flat_batched_loop_masked(feats, w, active, c, m, eps, max_iters):
    """Ragged-batch twin of :func:`_flat_batched_loop`: ``active`` (B,)
    bool freezes padding lanes at iteration 0 so they can't perturb the
    shared-loop trip count (the real lanes' iters/delta/total match an
    unpadded solve exactly)."""
    return flat_batched_solve(feats, w, c, m, eps, max_iters,
                              active=active)


@partial(jax.jit, static_argnames=("c", "m", "max_iters", "interpret"))
def _flat_batched_loop_resident(feats, w, c, m, eps, max_iters, interpret):
    """Whole-solve-kernel twin of :func:`_flat_batched_loop`: one
    ``pallas_call`` runs every lane to its own convergence."""
    return flat_batched_solve(feats, w, c, m, eps, max_iters,
                              impl="resident", interpret=interpret)


@partial(jax.jit, static_argnames=("c", "m", "max_iters", "interpret"))
def _flat_batched_loop_resident_streamed(feats, w, c, m, eps, max_iters,
                                         interpret):
    """HBM-streamed twin of :func:`_flat_batched_loop_resident` for
    lanes whose rows exceed the VMEM-held bound."""
    return flat_batched_solve(feats, w, c, m, eps, max_iters,
                              impl="resident_streamed",
                              interpret=interpret)


def stencil_batched_solve(imgs, c, m, alpha, neighbors, eps, max_iters,
                          impl: str = "reference",
                          interpret: bool = False):
    """Traceable batched FCM_S solve: imgs (B, *grid) -> (v (B, c),
    delta, iters, total) — the stencil twin of
    :func:`flat_batched_solve`, exported un-jitted so the serving
    engine's fused spatial route program can inline it. ``impl``:
    ``"reference"`` vmaps the shifted-array stencil step through the
    per-lane-masked ``while_loop``; ``"resident"`` runs every lane's
    complete fixed point inside one whole-solve stencil kernel."""
    from . import spatial as SP
    b = imgs.shape[0]
    flat = imgs.reshape(b, -1)
    lo = jnp.min(flat, axis=1)
    hi = jnp.max(flat, axis=1)
    frac = (jnp.arange(c, dtype=jnp.float32) + 0.5) / c
    v0 = lo[:, None] + frac[None, :] * (hi - lo)[:, None]
    tol = _tol_from_range(hi - lo, eps)

    if impl == "resident":
        from repro.kernels import ops as kops
        xpad, vpad = kops.tile_grid_batched(imgs)
        solve_fn = kops.build_step("stencil", "resident", xpad=xpad,
                                   vpad=vpad, m=m, alpha=alpha,
                                   neighbors=neighbors,
                                   max_iters=max_iters, interpret=interpret)
        v, delta, iters = solve_fn(v0, tol)
        return v, delta, iters, jnp.max(iters)

    vstep = jax.vmap(SP.spatial_center_step, in_axes=(0, 0, None, None, None))

    def step(v):
        return vstep(imgs, v, m, alpha, neighbors)

    return masked_while_centers(step, v0, tol, max_iters)


@partial(jax.jit, static_argnames=("c", "m", "alpha", "neighbors",
                                   "max_iters"))
def _stencil_batched_loop(imgs, c, m, alpha, neighbors, eps, max_iters):
    """imgs (B, *grid) -> (v (B, c), delta, iters, total). The batched
    FCM_S path: same per-lane masking as the flat batch, stencil step
    vmapped over lanes — what makes spatial serving traffic batchable."""
    return stencil_batched_solve(imgs, c, m, alpha, neighbors, eps,
                                 max_iters)


@partial(jax.jit, static_argnames=("c", "m", "alpha", "neighbors",
                                   "max_iters", "interpret"))
def _stencil_batched_loop_resident(imgs, c, m, alpha, neighbors, eps,
                                   max_iters, interpret):
    """Whole-solve-kernel twin of :func:`_stencil_batched_loop`: one
    ``pallas_call`` runs every lane's FCM_S fixed point."""
    return stencil_batched_solve(imgs, c, m, alpha, neighbors, eps,
                                 max_iters, impl="resident",
                                 interpret=interpret)


# ---------------------------------------------------------------------------
# solve / solve_batched
# ---------------------------------------------------------------------------

def _resolve(cfg, eps, max_iters, seed=0):
    if eps is None:
        eps = cfg.eps if cfg is not None else F.FCMConfig.eps
    if max_iters is None:
        max_iters = cfg.max_iters if cfg is not None else F.FCMConfig.max_iters
    if seed is None:
        seed = cfg.seed if cfg is not None else F.FCMConfig.seed
    return float(eps), int(max_iters), int(seed)


def _select_impl(problem: FCMProblem, backend: str, batch: bool = False,
                 force_platform: Optional[str] = None) -> str:
    """Registry dispatch: which step implementation runs this problem.
    ``force_platform`` overrides the platform check (``interpret=True``
    forces the resident kernel off-TPU for parity testing).
    ``backend="resident"`` routes by problem size: the VMEM-held
    whole-solve when the rows fit its bounds, the HBM-streamed variant
    for larger flat problems, the resident stencil solve for stencil
    problems."""
    from repro.kernels import ops as kops
    prefer = {"auto": None, "reference": "reference",
              "pallas": "pallas", "resident": "resident"}[backend]
    kind = "stencil" if problem.stencil is not None else "flat"
    if backend == "resident" and kind == "flat":
        small = kops._STEP_REGISTRY[("flat", "resident")]
        if not small.fits(problem.n_feat, problem.n_rows, problem.c):
            prefer = "resident_streamed"
    impl = kops.select_step(kind, prefer=prefer, platform=force_platform,
                            n_feat=problem.n_feat, batched=batch,
                            n_rows=problem.n_rows, c=problem.c)
    return impl.name


def solve(problem: FCMProblem, cfg: Optional[F.FCMConfig] = None, *,
          eps: Optional[float] = None, max_iters: Optional[int] = None,
          tol: Optional[float] = None, backend: str = "auto",
          keep_membership: bool = False, u0=None,
          seed: Optional[int] = None,
          block_rows: int = 64, interpret: Optional[bool] = None
          ) -> F.FCMResult:
    """Solve one :class:`FCMProblem` to convergence.

    ``eps``/``max_iters``/``seed`` (or a legacy
    :class:`~repro.core.fcm.FCMConfig` supplying them) control the stop
    test and the random-init backends: the center-movement tolerance is
    ``eps * feature-range * 0.1`` unless an absolute ``tol`` is given
    (``tol=-1`` forces exactly ``max_iters`` iterations — what the
    benchmarks use for like-for-like timing); ``seed`` only matters for
    the membership-initialized ``staged``/``sequential`` backends.
    ``labels`` come back per-row for flat problems and grid-shaped for
    stencil problems.
    """
    if problem.batch:
        raise ValueError("solve() takes a single problem; use "
                         "solve_batched() for batch=True problems")
    if backend not in BACKENDS:
        raise ValueError(f"unknown backend {backend!r}; one of {BACKENDS}")
    eps, max_iters, seed = _resolve(cfg, eps, max_iters, seed)

    if backend == "sequential":
        res = _solve_sequential(problem, eps, max_iters, seed, u0)
        _record_telemetry("flat", "sequential", res.n_iters,
                          res.final_delta)
        return res
    if backend == "staged":
        res = solve_staged(problem, eps=eps, max_iters=max_iters,
                           seed=seed, u0=u0,
                           keep_membership=keep_membership)
        _record_telemetry("flat", "staged", res.n_iters, res.final_delta)
        return res

    # interpret=True forces Pallas-family impls off-platform (tests);
    # without it backend="resident" degrades to the reference step
    # off-TPU, per the registry's declared fallback.
    force = "tpu" if (backend == "resident" and interpret) else None
    impl = _select_impl(problem, backend, force_platform=force)
    v0, tol = _single_init(problem, eps, tol)
    c, m = problem.c, problem.m

    if problem.stencil is not None:
        img = problem.features
        alpha, neighbors = problem.stencil.alpha, problem.stencil.neighbors
        if impl == "pallas":
            from repro.kernels import ops as kops
            xpad, wpad = kops.tile_grid(img, block_rows)
            if interpret is None:
                interpret = kops._interpret_default()
            v, delta, it = _stencil_loop_pallas(
                xpad, wpad, v0, m, alpha, neighbors, tol, max_iters,
                block_rows, interpret)
        elif impl == "resident":
            from repro.kernels import ops as kops
            xpad, vpad = kops.tile_grid_batched(img[None])
            if interpret is None:
                interpret = kops._interpret_default()
            v, delta, it = _stencil_loop_resident(
                xpad, vpad, v0, m, alpha, neighbors, tol, max_iters,
                interpret)
        else:
            v, delta, it = _stencil_loop(img, v0, m, alpha, neighbors,
                                         tol, max_iters)
        from . import spatial as SP
        u = SP.spatial_membership(img, v[:, 0], m, alpha, neighbors)
        labels = F.defuzzify(u.reshape(c, -1)).reshape(img.shape)
        _record_telemetry("stencil", impl, int(it), float(delta))
        centers = v[:, 0]
        return F.FCMResult(centers=centers, labels=labels, n_iters=int(it),
                           final_delta=float(delta),
                           membership=u if keep_membership else None,
                           converged=bool(float(delta) < tol),
                           healthy=bool(np.isfinite(
                               np.asarray(centers)).all()))

    feats2, w = problem.rows()
    if impl == "resident":
        from repro.kernels import ops as kops
        x4, w3 = kops.tile_rows_batched(feats2[None], w[None])
        if interpret is None:
            interpret = kops._interpret_default()
        v, delta, it = _flat_loop_resident(x4, w3, v0, c, m, tol,
                                           max_iters, interpret)
    elif impl == "resident_streamed":
        from repro.kernels import ops as kops
        from repro.kernels import fcm_resident as KR
        x4, w3 = kops.tile_rows_batched(
            feats2[None], w[None], rows_multiple=KR.STREAM_CHUNK_ROWS)
        if interpret is None:
            interpret = kops._interpret_default()
        v, delta, it = _flat_loop_resident_streamed(
            x4, w3, v0, c, m, tol, max_iters, interpret)
    elif impl == "pallas":
        from repro.kernels import ops as kops
        x2d, w2d = kops.tile_rows(feats2[:, 0], w, block_rows)
        if interpret is None:
            interpret = kops._interpret_default()
        v, delta, it = _flat_loop_pallas(x2d, w2d, v0, c, m, tol,
                                         max_iters, block_rows, interpret)
    else:
        v, delta, it = _flat_loop(feats2, w, v0, c, m, tol, max_iters)
    from repro.kernels import ops as kops
    labels = kops.defuzzify_labels(feats2, v, interpret=interpret)
    u = F.update_membership(feats2, v, m) if keep_membership else None
    centers = v[:, 0] if problem.scalar else v
    _record_telemetry("flat", impl, int(it), float(delta))
    return F.FCMResult(centers=centers, labels=labels, n_iters=int(it),
                       final_delta=float(delta), membership=u,
                       converged=bool(float(delta) < tol),
                       healthy=bool(np.isfinite(np.asarray(centers)).all()))


@dataclasses.dataclass
class BatchedFCMResult:
    """Per-lane results of a batched solve (+ per-lane health flags)."""
    centers: jax.Array            # (B, c) scalar or (B, c, D)
    n_iters: np.ndarray           # (B,) int32, per-lane iteration counts
    final_delta: np.ndarray       # (B,) float32, per-lane last center move
    total_iters: int              # global while_loop trip count
    labels: Optional[list] = None  # per lane, if the adapter computes them
    #: (B,) bool — lane met its center-movement tolerance (False =
    #: max_iters exhausted). None only on legacy constructors.
    converged: Optional[np.ndarray] = None
    #: (B,) bool — lane's centers are all finite (post-salvage).
    healthy: Optional[np.ndarray] = None
    #: (B,) bool — lane was re-solved on the reference backend after the
    #: primary impl left it poisoned/unconverged.
    salvaged: Optional[np.ndarray] = None


def solve_batched(problem: FCMProblem, cfg: Optional[F.FCMConfig] = None, *,
                  eps: Optional[float] = None,
                  max_iters: Optional[int] = None,
                  backend: str = "auto",
                  interpret: Optional[bool] = None,
                  salvage: bool = True) -> BatchedFCMResult:
    """Solve a stacked batch of independent problems (``batch=True``):
    one device loop — the per-lane-masked reference ``while_loop``, or
    the VMEM-resident whole-solve kernel (``backend="resident"``, or
    ``auto`` on TPU when the problem fits) — with each lane freezing at
    its own convergence point, so a lane's trajectory is identical to
    what :func:`solve` would produce for it alone.

    Post-solve, every lane gets health flags (``converged`` — met its
    tolerance; ``healthy`` — finite centers), and with ``salvage=True``
    (the default) bad lanes are re-solved *individually-masked* on the
    reference loop and scattered back — one poisoned or kernel-diverged
    lane degrades to the reference backend instead of failing the whole
    batch, and healthy lanes' centers ride through bitwise untouched."""
    if not problem.batch:
        raise ValueError("solve_batched() needs a batch=True problem "
                         "(see batch_problems())")
    if backend not in ("auto", "reference", "resident"):
        raise ValueError(f"batched solves run the reference (vmapped) or "
                         f"resident steps only; got backend={backend!r}")
    eps, max_iters, _ = _resolve(cfg, eps, max_iters)
    force = "tpu" if (backend == "resident" and interpret) else None
    impl = _select_impl(problem, backend, batch=True, force_platform=force)
    c, m = problem.c, problem.m

    if problem.stencil is not None:
        if impl == "resident":
            from repro.kernels import ops as kops
            if interpret is None:
                interpret = kops._interpret_default()
            v, delta, iters, it = _stencil_batched_loop_resident(
                problem.features, c, m, problem.stencil.alpha,
                problem.stencil.neighbors, eps, max_iters, interpret)
        else:
            v, delta, iters, it = _stencil_batched_loop(
                problem.features, c, m, problem.stencil.alpha,
                problem.stencil.neighbors, eps, max_iters)
    else:
        feats, w = problem.rows()
        if impl in ("resident", "resident_streamed"):
            from repro.kernels import ops as kops
            if interpret is None:
                interpret = kops._interpret_default()
            if impl == "resident":
                v, delta, iters, it = _flat_batched_loop_resident(
                    feats, w, c, m, eps, max_iters, interpret)
            else:
                v, delta, iters, it = _flat_batched_loop_resident_streamed(
                    feats, w, c, m, eps, max_iters, interpret)
        else:
            v, delta, iters, it = _flat_batched_loop(feats, w, c, m, eps,
                                                     max_iters)
        if problem.scalar:
            v = v[..., 0]
    from repro import faults as FI
    inj = FI.get()
    if inj is not None:
        v = inj.corrupt("solve_batched", v)

    n_iters = np.asarray(iters)
    final_delta = np.asarray(delta)
    total = int(it)
    kind = "stencil" if problem.stencil is not None else "flat"

    cen = np.asarray(v)
    b = cen.shape[0]
    lane_tol = lane_tolerances(problem, eps)
    healthy = np.isfinite(cen.reshape(b, -1)).all(axis=1)
    converged = np.asarray(final_delta < lane_tol) \
        & np.isfinite(final_delta)

    # Per-lane salvage: poisoned lanes always re-solve on the reference
    # loop (finite math beats a NaN result); unconverged lanes re-solve
    # only when the primary impl wasn't already the reference step
    # (identical math would just exhaust max_iters again).
    salvaged = np.zeros(b, dtype=bool)
    bad = ~healthy
    if impl != "reference":
        bad = bad | ~converged
    if salvage and bad.any():
        idx = np.nonzero(bad)[0]
        if problem.stencil is not None:
            v2, d2, i2, it2 = _stencil_batched_loop(
                problem.features[idx], c, m, problem.stencil.alpha,
                problem.stencil.neighbors, eps, max_iters)
        else:
            feats, w = problem.rows()
            v2, d2, i2, it2 = _flat_batched_loop(
                feats[idx], w[idx], c, m, eps, max_iters)
            if problem.scalar:
                v2 = v2[..., 0]
        cen = np.array(cen, copy=True)
        cen[idx] = np.asarray(v2)
        n_iters = np.array(n_iters, copy=True)
        n_iters[idx] = np.asarray(i2)
        final_delta = np.array(final_delta, copy=True)
        final_delta[idx] = np.asarray(d2)
        total = max(total, int(it2))
        healthy = np.isfinite(cen.reshape(b, -1)).all(axis=1)
        converged = np.asarray(final_delta < lane_tol) \
            & np.isfinite(final_delta)
        salvaged[idx] = True
        v = jnp.asarray(cen)
        from repro import obs
        obs.default_registry().counter(
            "solver.salvaged_lanes", kind=kind).inc(len(idx))

    _record_telemetry(kind, impl, total,
                      float(np.nanmax(final_delta)), lane_iters=n_iters)
    return BatchedFCMResult(centers=v, n_iters=n_iters,
                            final_delta=final_delta,
                            total_iters=total,
                            converged=converged, healthy=healthy,
                            salvaged=salvaged)


# ---------------------------------------------------------------------------
# Host-loop backends: the paper-faithful staged pipeline + sequential CPU
# ---------------------------------------------------------------------------

def solve_staged(problem: FCMProblem, *, eps: float = 5e-3,
                 max_iters: int = 300, seed: int = 0, u0=None,
                 keep_membership: bool = False,
                 use_pallas: bool = False) -> F.FCMResult:
    """The paper's pipeline: staged 'kernels' with the membership array
    materialized between stages and the convergence test
    ``|u' - u|_inf < eps`` on the HOST each iteration (the paper copies
    the membership back), random membership init. What
    ``solve(..., backend="staged")`` and the deprecated
    :func:`repro.core.fcm.fit_baseline` run; ``use_pallas=True`` routes
    the per-stage math through the Pallas kernels."""
    if problem.stencil is not None or problem.weights is not None:
        raise ValueError("backend='staged' reproduces the paper's "
                         "unweighted pixel pipeline only")
    x = problem.features
    n = x.shape[0]
    c, m = problem.c, problem.m
    key = jax.random.PRNGKey(seed)
    u = (F.random_membership(key, c, n) if u0 is None
         else jnp.asarray(u0, jnp.float32))
    if use_pallas:
        from repro.kernels import ops as kops

    n_iters = 0
    delta = jnp.inf
    v = None
    for it in range(max_iters):
        if use_pallas and x.ndim == 1:
            num, den = kops.center_partials(x, u, m)
            v = F._stage_combine(num, den)
            v = v[:, 0]
            u_new = kops.membership(x, v, m)
        else:
            num_terms, den_terms = F._stage_terms(x, u, m)
            num = F._stage_reduce_num(num_terms)
            den = F._stage_reduce_den(den_terms)
            v = F._stage_combine(num, den)
            v = v[:, 0] if x.ndim == 1 else v
            u_new = F._stage_membership(x, v, m)
        # Host round-trip, as in the paper's block diagram.
        delta = float(jnp.max(jnp.abs(u_new - u)))
        u = u_new
        n_iters = it + 1
        if delta < eps:
            break
    if v is None:
        # max_iters=0: centers from the initial membership, so the result
        # is still well-defined.
        v = F.update_centers(x, u, m)
    return F.FCMResult(centers=v, labels=F.defuzzify(u), n_iters=n_iters,
                       final_delta=delta,
                       membership=u if keep_membership else None,
                       converged=bool(delta < eps),
                       healthy=bool(np.isfinite(np.asarray(v)).all()))


def _solve_sequential(problem: FCMProblem, eps: float, max_iters: int,
                      seed: int, u0) -> F.FCMResult:
    """The paper's CPU comparison floor: single-core numpy, same
    algorithm/init as the literal C-port (see core/sequential.py)."""
    from . import sequential as S
    if problem.stencil is not None or problem.weights is not None \
            or not problem.scalar:
        raise ValueError("backend='sequential' is the scalar unweighted "
                         "CPU baseline only")
    v, labels, it = S.fcm_sequential_numpy(
        np.asarray(problem.features), c=problem.c, m=problem.m, eps=eps,
        max_iters=max_iters, seed=seed, u0=u0)
    # The comparator reports no residual (final_delta=NaN), so converged
    # is inferred from the iteration budget.
    return F.FCMResult(centers=jnp.asarray(v, jnp.float32),
                       labels=jnp.asarray(labels),
                       n_iters=int(it), final_delta=float("nan"),
                       converged=bool(int(it) < max_iters),
                       healthy=bool(np.isfinite(np.asarray(v)).all()))
