"""Request-batching segmentation engine over the batched FCM core.

The LM :class:`~repro.serving.engine.ServeEngine` amortizes device
launches across a token batch; this engine does the same across *images*.
Histogram compression makes heterogeneous traffic regular: a request of
any pixel count reduces on ingest to one ``(n_bins,)`` vector, so a whole
queue becomes one ``(B, n_bins)`` :func:`repro.core.batched.fit_batched`
call. Two batching tricks keep XLA recompilation at zero:

* **Bucketing** — queued requests are padded up to the nearest size in
  ``batch_sizes`` (padding lanes are uniform histograms, dropped on
  output), so only ``len(batch_sizes)`` jit signatures ever compile.
* **Histogram-keyed LRU cache** — identical intensity histograms hit an
  exact-key lookup; near-identical ones (adjacent slices of a volume,
  repeat studies with fresh noise — L1 distance between normalized
  histograms below ``cache_tol``) hit a nearest-match scan. Either way
  the fit is skipped; only the cheap per-pixel defuzzification LUT
  gather runs. On phantom traffic, same-anatomy re-submissions sit at
  L1 ~ 0.1 while genuinely different content sits at ~0.5, so the
  default tolerance of 0.15 separates them with wide margin.

Beyond the histogram fast path the engine routes three more methods:
``pixel`` (uncompressed per-image fused FCM — the reference), ``spatial``
(FCM_S on the full grid, cache-bypassing), and ``superpixel`` (SLIC
compression on ingest to a (K, D) weighted payload, batched at fixed K
buckets through :func:`repro.core.vector_fcm.fit_vector_batched` — the
color/multi-channel analogue of the histogram trick, also
cache-bypassing since vector features have no 256-bin key).

Results are hard labels per request (same spatial shape as the input
image) plus the fitted centers; :meth:`FCMServeEngine.stats` exposes
queue / throughput / per-route request and cache-hit counters for the
ops dashboards every traffic-scaling PR after this one will need.
"""
from __future__ import annotations

import collections
import dataclasses
import time
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import batched as B
from repro.core import fcm as F
from repro.core import spatial as SP
from repro.core import vector_fcm as VF
from repro.superpixel import pipeline as SX

#: The serving routes, in the order of the README routing table.
METHODS = ("histogram", "pixel", "spatial", "superpixel")


@dataclasses.dataclass
class SegmentationResult:
    """Per-request output."""
    request_id: int
    labels: np.ndarray            # same spatial shape as the submitted image
    centers: np.ndarray           # (c,) scalar or (c, D) vector features
    n_iters: int                  # 0 for cache hits
    cache_hit: bool
    method: str = "histogram"


@dataclasses.dataclass
class _Pending:
    request_id: int
    shape: Tuple[int, ...]
    flat: np.ndarray              # clipped int image, flattened
    hist: np.ndarray              # (n_bins,) float32
    key: bytes


@dataclasses.dataclass
class _PendingSpatial:
    """A spatial request carries the full pixel payload: FCM_S needs the
    pixel grid, so it can neither histogram-compress nor share the
    histogram cache."""
    request_id: int
    pixels: np.ndarray            # original 2-D/3-D image, unreduced


@dataclasses.dataclass
class _PendingPixels:
    """A pixel request: uncompressed per-image fused FCM — the reference
    route every compression is measured against. (H, W, D) payloads
    cluster in D-dim feature space."""
    request_id: int
    pixels: np.ndarray


@dataclasses.dataclass
class _PendingSuperpixel:
    """A superpixel request after ingest-time SLIC compression: like the
    histogram route it carries only the reduced payload to the fit, but
    like the spatial route it bypasses the 1-D histogram LRU (vector
    features have no 256-bin key, and the compression already amortizes
    most of the fit cost). ``k`` = features.shape[0] buckets the batch."""
    request_id: int
    features: np.ndarray          # (K, D) superpixel mean features
    weights: np.ndarray           # (K,) pixel counts
    label_map: np.ndarray         # (H, W) int32 pixel -> superpixel
    slic_iters: int


class FCMServeEngine:
    """Static-bucket batching engine for FCM segmentation requests.

    ``submit`` ingests an image (any 2-D/3-D shape, 8-bit-range values),
    histograms it, and either answers from the cache or queues it.
    ``flush`` drains the queue through bucketed ``fit_batched`` calls.
    ``segment`` is the submit-all-then-flush convenience wrapper.
    """

    def __init__(self, cfg: F.FCMConfig = F.FCMConfig(),
                 batch_sizes: Sequence[int] = (1, 8, 64),
                 n_bins: int = 256,
                 cache_size: int = 256,
                 cache_tol: float = 0.15,
                 spatial_cfg: Optional[SP.SpatialFCMConfig] = None,
                 superpixel_cfg: Optional[SX.SuperpixelFCMConfig] = None):
        if not batch_sizes or any(b <= 0 for b in batch_sizes):
            raise ValueError(f"bad batch_sizes {batch_sizes!r}")
        self.cfg = cfg
        self.spatial_cfg = spatial_cfg or SP.SpatialFCMConfig(
            n_clusters=cfg.n_clusters, m=cfg.m, eps=cfg.eps,
            max_iters=cfg.max_iters)
        self.superpixel_cfg = superpixel_cfg or SX.SuperpixelFCMConfig(
            n_clusters=cfg.n_clusters, m=cfg.m, eps=cfg.eps,
            max_iters=cfg.max_iters)
        self.batch_sizes = tuple(sorted(set(int(b) for b in batch_sizes)))
        self.n_bins = n_bins
        self.cache_size = cache_size
        # Max L1 distance between normalized histograms for a near-match
        # cache hit; 0 restricts the cache to exact-histogram hits.
        self.cache_tol = cache_tol
        # key (exact histogram bytes) -> (centers, normalized histogram)
        self._cache: "collections.OrderedDict[bytes, Tuple[np.ndarray, np.ndarray]]" = \
            collections.OrderedDict()
        self._queue: List[_Pending] = []
        self._spatial_queue: List[_PendingSpatial] = []
        self._pixel_queue: List[_PendingPixels] = []
        self._superpixel_queue: List[_PendingSuperpixel] = []
        self._next_id = 0
        self._stats = {
            "requests": 0, "cache_hits": 0, "batches": 0,
            "batched_images": 0, "padded_lanes": 0,
            "fit_seconds": 0.0, "fit_iters": 0,
            "spatial_requests": 0, "spatial_seconds": 0.0,
            "spatial_iters": 0,
            "pixel_seconds": 0.0, "pixel_iters": 0,
            "superpixel_seconds": 0.0, "superpixel_iters": 0,
            "superpixel_batches": 0, "superpixel_padded_lanes": 0,
            "compress_seconds": 0.0,
        }
        # Per-route request/cache-hit counters (the route mix is what the
        # ops dashboards page on; only the histogram route can ever hit).
        self._method_requests = {m: 0 for m in METHODS}
        self._method_cache_hits = {m: 0 for m in METHODS}

    # -- ingest ------------------------------------------------------------

    def submit(self, img: np.ndarray, method: str = "histogram") -> int:
        """Queue one image; returns its request id. Cache hits are still
        materialized at flush time (the defuzzify LUT needs the pixels).

        Routes (see ``METHODS``):

        * ``"histogram"`` — the default scalar fast path: 256-bin
          compression on ingest, bucketed batched fits, LRU cache.
        * ``"pixel"`` — uncompressed per-image fused FCM; (H, W, D)
          payloads cluster in D-dim feature space. The reference route.
        * ``"spatial"`` — FCM_S on the full (H, W)/(D, H, W) pixel grid;
          bypasses the histogram cache (positions matter).
        * ``"superpixel"`` — SLIC compression on ingest to a (K, D)
          weighted payload; color/multi-channel (H, W, D) or grayscale
          (H, W). Batched at fixed K buckets; bypasses the 1-D
          histogram LRU like the spatial route.
        """
        if method not in METHODS:
            raise ValueError(f"unknown method {method!r}")
        img = np.asarray(img)
        # Reject bad payloads at ingest: a request failing inside flush()
        # would discard the whole drained batch's results.
        if method == "spatial" and img.ndim not in (2, 3):
            raise ValueError(f"spatial requests need a (H, W) or (D, H, W) "
                             f"pixel grid, got shape {img.shape}")
        if method == "superpixel" and img.ndim not in (2, 3):
            raise ValueError(f"superpixel requests need (H, W) or "
                             f"(H, W, D) input, got shape {img.shape}")
        if method == "pixel":
            # 3-D pixel payloads are channels-LAST feature stacks; a
            # (D, H, W) volume would silently cluster on W-dim rows, so
            # anything that doesn't look like trailing channels is
            # rejected here (volumes belong to histogram/spatial).
            if img.ndim not in (2, 3) or (
                    img.ndim == 3 and img.shape[-1] > 16):
                raise ValueError(
                    f"pixel requests need (H, W) or channels-last "
                    f"(H, W, D<=16) input, got shape {img.shape}; "
                    f"use method='histogram' or 'spatial' for volumes")
        rid = self._next_id
        self._next_id += 1
        self._stats["requests"] += 1
        self._method_requests[method] += 1
        if method == "spatial":
            self._stats["spatial_requests"] += 1
            self._spatial_queue.append(_PendingSpatial(rid, img))
            return rid
        if method == "pixel":
            self._pixel_queue.append(_PendingPixels(rid, img))
            return rid
        if method == "superpixel":
            t0 = time.perf_counter()
            comp = SX.compress(img.astype(np.float32), self.superpixel_cfg)
            self._stats["compress_seconds"] += time.perf_counter() - t0
            self._superpixel_queue.append(_PendingSuperpixel(
                rid, np.asarray(comp.features), np.asarray(comp.weights),
                np.asarray(comp.label_map), comp.slic_iters))
            return rid
        flat = np.clip(img.reshape(-1).astype(np.int64), 0, self.n_bins - 1)
        hist = np.bincount(flat, minlength=self.n_bins
                           ).astype(np.float32)[:self.n_bins]
        self._queue.append(_Pending(rid, img.shape, flat, hist,
                                    hist.tobytes()))
        return rid

    @staticmethod
    def _normalize(hist: np.ndarray) -> np.ndarray:
        return hist / max(float(hist.sum()), 1.0)

    # -- drain -------------------------------------------------------------

    def flush(self) -> List[SegmentationResult]:
        """Run every queued request; returns results in submit order."""
        results: Dict[int, SegmentationResult] = {}
        # 1. answer what the cache already knows
        misses: List[_Pending] = []
        for p in self._queue:
            centers = self._cache_get(p.key, p.hist)
            if centers is not None:
                self._stats["cache_hits"] += 1
                self._method_cache_hits["histogram"] += 1
                results[p.request_id] = self._materialize(
                    p, centers, n_iters=0, cache_hit=True)
            else:
                misses.append(p)
        self._queue.clear()
        # 2. intra-flush dedup: fit one representative per histogram key
        uniq: Dict[bytes, _Pending] = {}
        dups: List[_Pending] = []
        for p in misses:
            if p.key in uniq:
                dups.append(p)
            else:
                uniq[p.key] = p
        # 3. bucketed batched fits for the representatives; keep this
        # flush's centers locally so duplicates don't depend on the LRU
        # cache (which may be disabled, or evict mid-flush).
        fitted: Dict[bytes, np.ndarray] = {}
        reps = list(uniq.values())
        i = 0
        while i < len(reps):
            chunk = reps[i:i + self.batch_sizes[-1]]
            bucket = self._bucket_for(len(chunk))
            i += len(chunk)
            self._run_bucket(chunk, bucket, results, fitted)
        # 4. duplicates ride on their representative's centers
        for p in dups:
            self._stats["cache_hits"] += 1
            self._method_cache_hits["histogram"] += 1
            results[p.request_id] = self._materialize(
                p, fitted[p.key], n_iters=0, cache_hit=True)
        # 5. spatial requests: per-image FCM_S fits on full pixel grids,
        # never consulting or populating the histogram cache.
        spatial = self._spatial_queue
        self._spatial_queue = []
        for sp in spatial:
            results[sp.request_id] = self._run_spatial(sp)
        # 6. pixel requests: uncompressed per-image fused fits.
        pixels = self._pixel_queue
        self._pixel_queue = []
        for px in pixels:
            results[px.request_id] = self._run_pixels(px)
        # 7. superpixel requests: group the compressed (K, D) payloads by
        # (K, D) and run each group through bucketed batched vector fits.
        sps = self._superpixel_queue
        self._superpixel_queue = []
        groups: Dict[Tuple[int, int], List[_PendingSuperpixel]] = {}
        for q in sps:
            groups.setdefault(q.features.shape, []).append(q)
        for group in groups.values():
            i = 0
            while i < len(group):
                chunk = group[i:i + self.batch_sizes[-1]]
                i += len(chunk)
                self._run_superpixel_bucket(chunk,
                                            self._bucket_for(len(chunk)),
                                            results)
        return [results[rid] for rid in sorted(results)]

    def segment(self, imgs: Sequence[np.ndarray],
                method: str = "histogram") -> List[SegmentationResult]:
        ids = [self.submit(im, method=method) for im in imgs]
        by_id = {r.request_id: r for r in self.flush()}
        return [by_id[i] for i in ids]

    def _bucket_for(self, n: int) -> int:
        for b in self.batch_sizes:
            if n <= b:
                return b
        return self.batch_sizes[-1]

    def _run_bucket(self, chunk: List[_Pending], bucket: int,
                    results: Dict[int, SegmentationResult],
                    fitted: Dict[bytes, np.ndarray]):
        hists = np.stack([p.hist for p in chunk])
        n_pad = bucket - len(chunk)
        if n_pad:
            # Uniform-histogram padding lanes converge fast and are dropped.
            pad = np.ones((n_pad, self.n_bins), np.float32)
            hists = np.concatenate([hists, pad])
        t0 = time.perf_counter()
        res = B.fit_batched(jnp.asarray(hists), self.cfg,
                            n_bins=self.n_bins, compute_labels=False)
        centers = np.asarray(res.centers)
        self._stats["fit_seconds"] += time.perf_counter() - t0
        self._stats["batches"] += 1
        self._stats["batched_images"] += len(chunk)
        self._stats["padded_lanes"] += n_pad
        self._stats["fit_iters"] += int(res.total_iters)
        for lane, p in enumerate(chunk):
            fitted[p.key] = centers[lane]
            self._cache_put(p.key, centers[lane], p.hist)
            results[p.request_id] = self._materialize(
                p, centers[lane], n_iters=int(res.n_iters[lane]),
                cache_hit=False)

    def _run_spatial(self, sp: _PendingSpatial) -> SegmentationResult:
        t0 = time.perf_counter()
        res = SP.fit_spatial(sp.pixels.astype(np.float32), self.spatial_cfg)
        self._stats["spatial_seconds"] += time.perf_counter() - t0
        self._stats["spatial_iters"] += res.n_iters
        return SegmentationResult(sp.request_id, np.asarray(res.labels),
                                  np.asarray(res.centers), res.n_iters,
                                  cache_hit=False, method="spatial")

    def _run_pixels(self, px: _PendingPixels) -> SegmentationResult:
        img = px.pixels.astype(np.float32)
        # (H, W, D) clusters in D-dim feature space; (H, W)/(N,) is the
        # scalar case. Labels keep the spatial shape.
        spatial_shape = img.shape[:-1] if img.ndim == 3 else img.shape
        x = img.reshape(-1, img.shape[-1]) if img.ndim == 3 \
            else img.reshape(-1)
        t0 = time.perf_counter()
        res = F.fit_fused(x, self.cfg)
        self._stats["pixel_seconds"] += time.perf_counter() - t0
        self._stats["pixel_iters"] += res.n_iters
        return SegmentationResult(
            px.request_id, np.asarray(res.labels).reshape(spatial_shape),
            np.asarray(res.centers), res.n_iters, cache_hit=False,
            method="pixel")

    def _run_superpixel_bucket(self, chunk: List[_PendingSuperpixel],
                               bucket: int,
                               results: Dict[int, SegmentationResult]):
        k, d = chunk[0].features.shape
        feats = np.stack([q.features for q in chunk])
        ws = np.stack([q.weights for q in chunk])
        n_pad = bucket - len(chunk)
        if n_pad:
            # Benign padding lanes: a unit-weight feature ramp converges
            # in a handful of iterations and is dropped on output.
            ramp = np.broadcast_to(
                np.linspace(0.0, 1.0, k, dtype=np.float32)[:, None], (k, d))
            feats = np.concatenate(
                [feats, np.broadcast_to(ramp, (n_pad, k, d))])
            ws = np.concatenate([ws, np.ones((n_pad, k), np.float32)])
        t0 = time.perf_counter()
        # The superpixel config carries the FCM hyper-parameters for this
        # route (it defaults to self.cfg's, but a caller-supplied one
        # must govern the fit, not just the compression).
        res = VF.fit_vector_batched(jnp.asarray(feats), jnp.asarray(ws),
                                    self.superpixel_cfg)
        centers = np.asarray(res.centers)
        self._stats["superpixel_seconds"] += time.perf_counter() - t0
        self._stats["superpixel_batches"] += 1
        self._stats["superpixel_padded_lanes"] += n_pad
        self._stats["superpixel_iters"] += int(res.total_iters)
        for lane, q in enumerate(chunk):
            sp_labels = np.asarray(F.labels_from_centers(
                jnp.asarray(q.features), jnp.asarray(centers[lane])))
            labels = sp_labels[q.label_map]
            results[q.request_id] = SegmentationResult(
                q.request_id, labels, centers[lane],
                n_iters=int(res.n_iters[lane]), cache_hit=False,
                method="superpixel")

    def _materialize(self, p: _Pending, centers: np.ndarray,
                     n_iters: int, cache_hit: bool) -> SegmentationResult:
        # Defuzzify via a n_bins-entry LUT: label each bin once, gather.
        vals = jnp.arange(self.n_bins, dtype=jnp.float32)
        lut = np.asarray(F.labels_from_centers(vals, jnp.asarray(centers)))
        labels = lut[p.flat].reshape(p.shape)
        return SegmentationResult(p.request_id, labels,
                                  np.asarray(centers), n_iters, cache_hit)

    # -- cache -------------------------------------------------------------

    def _cache_get(self, key: bytes,
                   hist: Optional[np.ndarray] = None) -> Optional[np.ndarray]:
        if self.cache_size <= 0:
            return None
        entry = self._cache.get(key)
        if entry is not None:
            self._cache.move_to_end(key)
            return entry[0]
        if hist is None or self.cache_tol <= 0:
            return None
        # Nearest-match scan, most-recent first (the cache is small and a
        # 256-float L1 is trivial next to an FCM fit).
        q = self._normalize(hist)
        for k in reversed(self._cache):
            centers, dist = self._cache[k]
            if float(np.abs(dist - q).sum()) <= self.cache_tol:
                self._cache.move_to_end(k)
                return centers
        return None

    def _cache_put(self, key: bytes, centers: np.ndarray, hist: np.ndarray):
        if self.cache_size <= 0:
            return
        self._cache[key] = (np.asarray(centers), self._normalize(hist))
        self._cache.move_to_end(key)
        while len(self._cache) > self.cache_size:
            self._cache.popitem(last=False)

    # -- observability -----------------------------------------------------

    @property
    def queue_depth(self) -> int:
        return (len(self._queue) + len(self._spatial_queue)
                + len(self._pixel_queue) + len(self._superpixel_queue))

    def stats(self) -> Dict[str, float]:
        s = dict(self._stats)
        s["queue_depth"] = self.queue_depth
        s["cache_entries"] = len(self._cache)
        # Per-route request/cache-hit mix (only the histogram route is
        # cacheable, but the dashboards want all four columns).
        s["method_requests"] = dict(self._method_requests)
        s["method_cache_hits"] = dict(self._method_cache_hits)
        # Hit rate over cacheable (histogram) traffic only — the bypass
        # routes must not dilute it.
        cacheable = self._method_requests["histogram"]
        s["cache_hit_rate"] = (s["cache_hits"] / cacheable
                               if cacheable else 0.0)
        s["images_per_sec"] = (s["batched_images"] / s["fit_seconds"]
                               if s["fit_seconds"] > 0 else 0.0)
        return s
