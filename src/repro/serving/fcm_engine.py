"""Request-batching segmentation engine over the unified solver core.

The LM :class:`~repro.serving.engine.ServeEngine` amortizes device
launches across a token batch; this engine does the same across *images*.
Every serving method is a declarative :class:`RouteSpec` in a route
registry — an ingest transform (validate / compress), a bucket key
(requests sharing one may share one device launch), a problem builder
(payloads -> one batched :class:`repro.core.solver.FCMProblem`), a
materializer (per-request labels from fitted centers), and a cache
policy. ``flush`` is route-agnostic: group by bucket key, pad to a
fixed batch size, run ONE :func:`repro.core.solver.solve_batched` per
bucket. Adding an FCM variant to serving = registering a RouteSpec, not
hand-routing a new queue.

Because every route builds a solver problem, *all four* methods batch
across concurrent requests — including ``spatial`` (same-shape FCM_S
grids stack into one per-lane-masked stencil loop) and ``superpixel``
((K, D) payload groups), which previously ran one fit per request.
Two batching tricks keep XLA recompilation off the steady-state path:

* **Bucketing** — queued requests are padded up to the nearest size in
  ``batch_sizes`` (padding lanes are dropped on output), so only
  ``len(batch_sizes)`` jit signatures compile per payload shape (the
  pixel-exact route programs additionally key on payload size; both
  program caches are LRU-bounded so heterogeneous long-tail traffic
  recycles executables rather than accreting them).
* **Histogram-keyed LRU cache** — identical intensity histograms hit an
  exact-key lookup; near-identical ones (adjacent slices of a volume,
  repeat studies with fresh noise — L1 distance between normalized
  histograms below ``cache_tol``) hit a nearest-match scan. Either way
  the fit is skipped; only the cheap per-pixel defuzzification LUT
  gather runs. Only the histogram route is cacheable: spatial requests
  depend on pixel positions and vector features have no 256-bin key.

**Device-resident route programs** (the serving face of the paper's
"never leave the device" lesson): the hot routes additionally register a
:class:`RouteProgram` — one *jitted* ingest->solve->defuzzify pipeline
per (route, bucket, payload-shape), cached and reused across flushes —
so a drained bucket is ONE device dispatch instead of four
host-synchronized stages (host binning, bucket assembly, batched solve,
per-request label dispatches). On TPU the program's stages are the
Pallas binning / VMEM-resident whole-solve / fused defuzzify kernels;
off-TPU the binning runs as host numpy (XLA CPU has no fast scatter)
and the solve as the vmapped reference loop, still fused into one
dispatch. Re-registering a route bumps its generation and evicts its
compiled programs, so a replaced spec can never serve a stale pipeline.

Results are hard labels per request (same spatial shape as the input
image) plus the fitted centers; :meth:`FCMServeEngine.stats` exposes
queue / throughput / per-route request, batch and cache-hit counters,
plus a per-route ingest/solve/materialize stage-seconds breakdown.
"""
from __future__ import annotations

import collections
import dataclasses
import threading
import time
from typing import (Any, Callable, Dict, Hashable, List, Optional,
                    Sequence, Tuple)

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as _P

from repro import faults as FI
from repro import obs
from repro.core import distributed as DD
from repro.core import fcm as F
from repro.core import solver as SV
from repro.core import spatial as SP
from repro.core.batched import hist_rows
from repro.kernels import ops as kops
from repro.superpixel import pipeline as SX

from .admission import (DeadlineExceeded, EngineShutdown, InvalidInput,
                        Overloaded, SegmentationFuture, SolveFailed)


@dataclasses.dataclass
class SegmentationResult:
    """Per-request output."""
    request_id: int
    labels: np.ndarray            # same spatial shape as the submitted image
    centers: np.ndarray           # (c,) scalar or (c, D) vector features
    n_iters: int                  # 0 for cache hits
    cache_hit: bool
    method: str = "histogram"
    #: False when this request's lane exhausted its iteration budget
    #: without meeting the solver tolerance (the result is still the
    #: best available centers — degraded, not wrong-typed).
    converged: bool = True


def _validate_payload(img: np.ndarray) -> None:
    """Submit-time input guard: empty and non-finite float payloads are
    rejected with a typed :class:`InvalidInput` *before* they consume a
    request id or poison a shared batch lane. Integer payloads skip the
    finite scan (they cannot carry NaN/Inf) so the uint8 hot path pays
    nothing."""
    if img.size == 0:
        raise InvalidInput(f"empty image payload (shape {img.shape})")
    if img.dtype.kind == "f" and not np.isfinite(img).all():
        raise InvalidInput("image payload contains NaN/Inf pixels")


# ---------------------------------------------------------------------------
# Pending payloads (what each route's ingest produces)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class _Pending:
    """A histogram-route request. Ingest keeps only the clipped flat
    pixels: binning is deferred to the device program (Pallas on TPU) —
    ``hist``/``key`` are filled lazily and only when the LRU cache or
    the mixed-size fallback program actually needs them."""
    request_id: int
    shape: Tuple[int, ...]
    flat: np.ndarray              # flat bin indices: a zero-copy uint8
                                  # view for 8-bit payloads, clipped
                                  # int32 otherwise
    hist: Optional[np.ndarray] = None   # (n_bins,) float32, lazy
    key: Optional[bytes] = None         # cache/dedup key, lazy


@dataclasses.dataclass
class _PendingSpatial:
    """A spatial request carries the full pixel payload: FCM_S needs the
    pixel grid, so it can neither histogram-compress nor share the
    histogram cache. Same-shape grids still batch into one solve."""
    request_id: int
    pixels: np.ndarray            # original 2-D/3-D image, unreduced


@dataclasses.dataclass
class _PendingPixels:
    """A pixel request: uncompressed per-image fused FCM — the reference
    route every compression is measured against. (H, W, D) payloads
    cluster in D-dim feature space; same-shape payloads batch."""
    request_id: int
    pixels: np.ndarray


@dataclasses.dataclass
class _PendingSuperpixel:
    """A superpixel request after ingest-time SLIC compression: like the
    histogram route it carries only the reduced payload to the fit, but
    like the spatial route it bypasses the 1-D histogram LRU (vector
    features have no 256-bin key). ``features.shape`` buckets the batch."""
    request_id: int
    features: np.ndarray          # (K, D) superpixel mean features
    weights: np.ndarray           # (K,) pixel counts
    label_map: np.ndarray         # (H, W) int32 pixel -> superpixel
    slic_iters: int


# ---------------------------------------------------------------------------
# Route registry
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class RouteSpec:
    """One serving method, declaratively.

    ``ingest(engine, img, rid)`` validates and reduces the payload (it
    must raise before consuming a request id on bad input);
    ``bucket_key(engine, payload)`` decides which payloads may share one
    batched solve; ``build_problem(engine, chunk, bucket)`` stacks a
    chunk (plus padding lanes up to ``bucket``) into one batched
    :class:`~repro.core.solver.FCMProblem` and names the config whose
    eps/max_iters govern the fit; ``materialize`` turns one lane's
    fitted centers back into per-pixel labels. ``cacheable`` routes
    carry a ``.key``/``.hist`` payload and go through the histogram LRU
    + intra-flush dedup.
    """
    name: str
    ingest: Callable[["FCMServeEngine", np.ndarray, int], Any]
    bucket_key: Callable[["FCMServeEngine", Any], Hashable]
    build_problem: Callable[["FCMServeEngine", List[Any], int],
                            Tuple[SV.FCMProblem, F.FCMConfig]]
    materialize: Callable[["FCMServeEngine", Any, np.ndarray, int, bool],
                          SegmentationResult]
    #: optional vmapped materializer for a whole fitted chunk — one
    #: device launch instead of len(chunk); (engine, chunk, centers,
    #: n_iters) -> results. Routes whose per-request labeling is itself
    #: stencil-heavy (spatial) need this to keep the served throughput
    #: at the batched-fit level.
    materialize_batch: Optional[
        Callable[["FCMServeEngine", List[Any], np.ndarray, np.ndarray],
                 List[SegmentationResult]]] = None
    cacheable: bool = False
    stats_prefix: str = ""        # "" keeps the legacy histogram names
    #: device-resident fast path: ``program_key(engine, chunk)`` names
    #: the compiled-program shape a drained chunk can share (None =
    #: this chunk has no fused program) and ``make_program(engine, key,
    #: bucket)`` builds the :class:`RouteProgram` compiled once per
    #: (route generation, bucket, key) and cached on the engine.
    program_key: Optional[
        Callable[["FCMServeEngine", List[Any]], Optional[Hashable]]] = None
    make_program: Optional[
        Callable[["FCMServeEngine", Hashable, int], "RouteProgram"]] = None

    def stat(self, name: str) -> str:
        if not self.stats_prefix:   # the histogram route predates routes
            return {"seconds": "fit_seconds", "iters": "fit_iters",
                    "batches": "batches", "images": "batched_images",
                    "padded": "padded_lanes",
                    "ingest": "ingest_seconds",
                    "compress": "compress_seconds",
                    "materialize": "materialize_seconds"}[name]
        legacy = {"seconds": "seconds", "iters": "iters",
                  "batches": "batches", "images": "batched_images",
                  "padded": "padded_lanes", "ingest": "ingest_seconds",
                  "compress": "compress_seconds",
                  "materialize": "materialize_seconds"}[name]
        return f"{self.stats_prefix}_{legacy}"


@dataclasses.dataclass(frozen=True)
class RouteProgram:
    """One compiled single-dispatch serving pipeline.

    ``gather(engine, chunk, bucket)`` finishes ingest on the host
    (stack + pad payloads into fixed-shape device inputs);
    ``launch(*inputs)`` is ONE jitted device dispatch covering
    ingest-binning, the batched solve and defuzzification;
    ``scatter(engine, chunk, outputs)`` unpacks the device outputs into
    per-request results and returns ``(results, centers (B, ...),
    n_iters (B,), total_iters[, final_delta (B,)])`` so flush-side
    stats, convergence telemetry and the LRU cache see exactly what the
    staged path would have produced (the trailing per-lane residual is
    optional: pre-telemetry programs returning 4-tuples still run).
    """
    gather: Callable[["FCMServeEngine", List[Any], int], Tuple]
    launch: Callable[..., Tuple]
    scatter: Callable[["FCMServeEngine", List[Any], Tuple],
                      Tuple[List[SegmentationResult], np.ndarray,
                            np.ndarray, int]]


#: Module-level cache of *compiled* launch functions, keyed on the full
#: static math signature (route flavor, platform, bucket, shapes and
#: hyper-parameters). Engines hold their own RouteProgram cache for
#: generation-based eviction, but the jitted launch is shared here so a
#: fresh engine (cold LRU, same traffic shape) pays zero recompilation.
#: LRU-bounded: pixel-exact program flavors key on payload size, so
#: long-tail heterogeneous traffic must recycle executables instead of
#: retaining one per size ever seen for the process lifetime.
_LAUNCH_CACHE: "collections.OrderedDict[Hashable, Callable]" = \
    collections.OrderedDict()
_LAUNCH_CACHE_SIZE = 64


def _cached_launch(key: Hashable, build: Callable[[], Callable]) -> Callable:
    fn = _LAUNCH_CACHE.get(key)
    if fn is None:
        fn = build()
        _LAUNCH_CACHE[key] = fn
        while len(_LAUNCH_CACHE) > _LAUNCH_CACHE_SIZE:
            _LAUNCH_CACHE.popitem(last=False)
    else:
        _LAUNCH_CACHE.move_to_end(key)
    return fn


# ---------------------------------------------------------------------------
# Mesh dispatch: batch-axis-sharded launch programs
# ---------------------------------------------------------------------------

def _mesh_signature(mesh) -> Hashable:
    """A hashable identity for the mesh a launch was compiled against
    (device set + topology), so the module-level launch cache can never
    hand a program compiled for one mesh to an engine on another."""
    if mesh is None:
        return ("nomesh",)
    return ("mesh", tuple(mesh.axis_names), mesh.devices.shape,
            tuple(d.id for d in mesh.devices.flat))


def _shard_launch(mesh, launch_fn: Callable, n_in: int) -> Callable:
    """Wrap a RouteProgram launch body so its batch (leading) axis is
    sharded over every mesh axis. Lanes are independent images, so the
    body runs collective-free; only the scalar ``total`` (the shared
    trip count of each shard's masked loop) needs a pmax so every
    device reports the global batch's value. The wrapped function keeps
    the launch contract — ``(v, delta, iters, total, labels/lut)`` —
    and, on a one-device mesh, the identical math of the unsharded
    path (sharding a batch over one device is a no-op partition).
    """
    axes = DD.mesh_axes(mesh)
    bspec = _P(axes)

    def body(*inputs):
        v, delta, iters, total, tail = launch_fn(*inputs)
        return v, delta, iters, jax.lax.pmax(total, axes), tail

    return DD.shard_map(body, mesh=mesh,
                        in_specs=(bspec,) * n_in,
                        out_specs=(bspec, bspec, bspec, _P(), bspec))


def _jit_launch(eng: "FCMServeEngine", bucket: int, cache_key: Hashable,
                launch_fn: Callable, n_in: int,
                donate: Tuple[int, ...] = ()) -> Callable:
    """Compile (or fetch) the launch for this engine's mesh: sharded
    over the batch axis when the engine has a multi-device mesh that
    divides the bucket, the plain single-device jit otherwise. The mesh
    signature joins the cache key so single-device and per-mesh
    programs never collide."""
    mesh = eng._mesh_for_bucket(bucket)
    full_key = cache_key + (_mesh_signature(mesh),)
    if mesh is None:
        return _cached_launch(
            full_key, lambda: jax.jit(launch_fn, donate_argnums=donate))
    # No donation under shard_map: donated sharded buffers trip XLA
    # aliasing restrictions on some backends for zero win on this path.
    return _cached_launch(
        full_key, lambda: jax.jit(_shard_launch(mesh, launch_fn, n_in)))


ROUTES: "collections.OrderedDict[str, RouteSpec]" = collections.OrderedDict()

#: Route generations: bumped on every (re-)registration so engine-held
#: compiled programs for a replaced spec are evicted, never served stale.
_ROUTE_GEN: Dict[str, int] = collections.defaultdict(int)


def register_route(spec: RouteSpec) -> RouteSpec:
    """Add (or replace) a serving route; see the specs below for the
    shape. New FCM variants serve by registering here — ``flush`` and
    the stats plumbing need no changes. Replacing a spec invalidates
    any compiled route programs built from the old one."""
    ROUTES[spec.name] = spec
    _ROUTE_GEN[spec.name] += 1
    global METHODS
    METHODS = tuple(ROUTES)
    return spec


# -- histogram route --------------------------------------------------------

def _ingest_histogram(eng: "FCMServeEngine", img: np.ndarray,
                      rid: int) -> _Pending:
    # No binning here: the device program bins on-chip (Pallas kernel on
    # TPU); the histogram only materializes lazily for cache keys or the
    # mixed-size fallback program (see _ensure_hist). uint8 payloads
    # (the 8-bit serving case) cannot exceed the bin range, so ingest is
    # a zero-copy flat view — the request pipeline stays uint8 until the
    # device LUT gather.
    if img.dtype == np.uint8 and eng.n_bins >= 256:
        # .copy(), not a view: the caller may reuse its buffer between
        # submit() and flush() (a 16 KB memcpy, vs the clip+widen pass
        # the non-uint8 path pays).
        flat = img.reshape(-1).copy()
    else:
        flat = np.clip(img.reshape(-1), 0, eng.n_bins - 1).astype(np.int32)
    return _Pending(rid, img.shape, flat)


def _ensure_hist(eng: "FCMServeEngine", p: _Pending) -> _Pending:
    if p.hist is None:
        p.hist = np.bincount(p.flat, minlength=eng.n_bins
                             ).astype(np.float32)[:eng.n_bins]
        if p.key is None:       # dedup may have keyed on pixel bytes
            p.key = p.hist.tobytes()
    return p


def _build_histogram(eng, chunk, bucket):
    hists = np.stack([_ensure_hist(eng, p).hist for p in chunk])
    n_pad = bucket - len(chunk)
    if n_pad:
        # Uniform-histogram padding lanes converge fast and are dropped.
        pad = np.ones((n_pad, eng.n_bins), np.float32)
        hists = np.concatenate([hists, pad])
    hists = jnp.asarray(hists)
    return SV.batch_problems(hist_rows(hists), hists, cfg=eng.cfg), eng.cfg


def _label_lut(centers: np.ndarray, n_bins: int) -> np.ndarray:
    """n_bins-entry defuzzify LUT in plain numpy — identical f32
    arithmetic and tie-breaking to labels_from_centers, without a device
    dispatch per request (cache hits and duplicates ride this)."""
    vals = np.arange(n_bins, dtype=np.float32)
    c2 = np.asarray(centers, np.float32).reshape(-1, 1)
    return np.argmin((c2 - vals[None, :]) ** 2, axis=0).astype(np.int32)


def _materialize_histogram(eng, p, centers, n_iters, cache_hit):
    labels = _label_lut(centers, eng.n_bins)[p.flat].reshape(p.shape)
    return SegmentationResult(p.request_id, labels, np.asarray(centers),
                              n_iters, cache_hit)


def _histogram_program_key(eng, chunk):
    # Same-size payloads share the full pixels->binning->solve->labels
    # program (the defuzzify gather rides the dispatch: XLA's batched
    # gather beats a per-request numpy LUT loop even on CPU); mixed
    # sizes fall back to the histograms-only program + host LUT gather.
    sizes = {p.flat.size for p in chunk}
    return ("px", sizes.pop()) if len(sizes) == 1 else ("hist",)


def _make_histogram_program(eng, key, bucket) -> RouteProgram:
    cfg = eng.cfg
    c, m = cfg.n_clusters, float(cfg.m)
    eps, max_iters = float(cfg.eps), int(cfg.max_iters)
    nb = eng.n_bins
    platform = jax.default_backend()
    impl = kops.select_step("flat", platform=platform, n_feat=1,
                            batched=True, n_rows=nb, c=c).name
    vals = jnp.arange(nb, dtype=jnp.float32)

    def _solve_lut(hists):
        # feats derive from the *input* batch shape (not the bucket), so
        # the same body runs whole-bucket on one device or per-shard
        # under the mesh-sharded launch wrapper.
        feats = jnp.broadcast_to(vals[None, :, None], hists.shape + (1,))
        v, delta, iters, total = SV.flat_batched_solve(
            feats, hists, c, m, eps, max_iters, impl=impl)
        v2 = v[..., 0]
        lut = jax.vmap(lambda vv: F.labels_from_centers(vals, vv))(v2)
        return v2, delta, iters, total, lut

    def _gather_hists(eng_, chunk):
        hists = np.ones((bucket, nb), np.float32)
        for i, p in enumerate(chunk):
            hists[i] = _ensure_hist(eng_, p).hist
        return hists

    cache_key = ("histogram", platform, bucket, key, nb, c, m, eps,
                 max_iters, impl)

    if key[0] == "px":
        n = key[1]
        on_tpu = platform == "tpu"
        if on_tpu:
            def launch_fn(px):
                # Ingest binning on-chip: the Pallas one-pass kernel.
                # With the LRU enabled the cache lookup has already host-
                # binned these pixels for the key; the on-chip re-bin is
                # cheaper than widening the launch signature to ship the
                # host histograms in — the host bincount is the price of
                # a histogram-keyed cache, not of this program.
                hists = kops.histogram_counts(px, nb, interpret=False)
                v2, delta, iters, total, lut = _solve_lut(hists)
                return v2, delta, iters, total, \
                    jnp.take_along_axis(lut, px, axis=1)
            launch = _jit_launch(eng, bucket, cache_key, launch_fn, 1,
                                 donate=(0,))
        else:
            def launch_fn(px, hists):
                v2, delta, iters, total, lut = _solve_lut(hists)
                return v2, delta, iters, total, \
                    jnp.take_along_axis(lut, px, axis=1)
            launch = _jit_launch(eng, bucket, cache_key, launch_fn, 2)

        def gather(eng_, chunk, bucket_):
            # uint8 traffic stages uint8 (16 KB memcpy per lane); mixed
            # dtypes fall back to int32. Padding lanes replay lane 0.
            dtype = (np.uint8 if all(p.flat.dtype == np.uint8
                                     for p in chunk) else np.int32)
            px = np.empty((bucket_, n), dtype)
            for i, p in enumerate(chunk):
                px[i] = p.flat
            for i in range(len(chunk), bucket_):
                px[i] = px[0]
            if on_tpu:
                return (px,)
            return px, _gather_hists(eng_, chunk)

        def scatter(eng_, chunk, outs):
            v2, delta, iters, total, labels = outs
            centers = np.asarray(v2)
            iters_np = np.asarray(iters)
            labels_np = np.asarray(labels)
            res = [SegmentationResult(p.request_id,
                                      labels_np[i].reshape(p.shape),
                                      centers[i], int(iters_np[i]), False)
                   for i, p in enumerate(chunk)]
            return res, centers, iters_np, int(total), np.asarray(delta)

        return RouteProgram(gather, launch, scatter)

    # Mixed payload sizes: one solve dispatch on the stacked histograms,
    # per-request labels via the (cheap) host LUT gather.
    launch = _jit_launch(eng, bucket, cache_key,
                         lambda hists: _solve_lut(hists), 1)

    def gather(eng_, chunk, bucket_):
        return (_gather_hists(eng_, chunk),)

    def scatter(eng_, chunk, outs):
        v2, delta, iters, total, lut = outs
        centers = np.asarray(v2)
        iters_np = np.asarray(iters)
        lut_np = np.asarray(lut)
        res = [SegmentationResult(p.request_id,
                                  lut_np[i][p.flat].reshape(p.shape),
                                  centers[i], int(iters_np[i]), False)
               for i, p in enumerate(chunk)]
        return res, centers, iters_np, int(total), np.asarray(delta)

    return RouteProgram(gather, launch, scatter)


# -- pixel route ------------------------------------------------------------

def _ingest_pixel(eng, img, rid) -> _PendingPixels:
    # 3-D pixel payloads are channels-LAST feature stacks; a (D, H, W)
    # volume would silently cluster on W-dim rows, so anything that
    # doesn't look like trailing channels is rejected here (volumes
    # belong to histogram/spatial).
    if img.ndim not in (2, 3) or (img.ndim == 3 and img.shape[-1] > 16):
        raise ValueError(
            f"pixel requests need (H, W) or channels-last "
            f"(H, W, D<=16) input, got shape {img.shape}; "
            f"use method='histogram' or 'spatial' for volumes")
    return _PendingPixels(rid, img)


def _pixel_rows(img: np.ndarray) -> np.ndarray:
    imgf = img.astype(np.float32)
    return (imgf.reshape(-1, img.shape[-1]) if img.ndim == 3
            else imgf.reshape(-1))


def _build_pixel(eng, chunk, bucket):
    xs = np.stack([_pixel_rows(q.pixels) for q in chunk])
    n_pad = bucket - len(chunk)
    if n_pad:
        # Padding lanes replay the first image; frozen-lane masking makes
        # them cost one lane of compute, dropped on output.
        xs = np.concatenate([xs, np.repeat(xs[:1], n_pad, axis=0)])
    return SV.batch_problems(jnp.asarray(xs), cfg=eng.cfg), eng.cfg


def _materialize_pixel(eng, q, centers, n_iters, cache_hit):
    img = q.pixels
    spatial_shape = img.shape[:-1] if img.ndim == 3 else img.shape
    # Fused argmin labels: the (c, N) distance/membership matrix is
    # never materialized (Pallas kernel on TPU, reference elsewhere).
    labels = np.asarray(kops.defuzzify_labels(
        jnp.asarray(_pixel_rows(img)),
        jnp.asarray(centers))).reshape(spatial_shape)
    return SegmentationResult(q.request_id, labels, np.asarray(centers),
                              n_iters, cache_hit, method="pixel")


def _pixel_program_key(eng, chunk):
    return ("px",) + chunk[0].pixels.shape  # bucket_key groups by shape


def _make_pixel_program(eng, key, bucket) -> RouteProgram:
    shape = key[1:]
    scalar = len(shape) == 2
    d = 1 if scalar else shape[-1]
    n = int(np.prod(shape[:2]))
    cfg = eng.cfg
    c, m = cfg.n_clusters, float(cfg.m)
    eps, max_iters = float(cfg.eps), int(cfg.max_iters)
    platform = jax.default_backend()
    impl = kops.select_step("flat", platform=platform, n_feat=d,
                            batched=True, n_rows=n, c=c).name
    labels_impl = kops.select_step("labels", platform=platform,
                                   n_feat=d).name

    def launch_fn(xs):
        w = jnp.ones(xs.shape[:2], jnp.float32)
        feats = xs[..., None] if scalar else xs
        v, delta, iters, total = SV.flat_batched_solve(
            feats, w, c, m, eps, max_iters, impl=impl)
        if scalar:
            v2 = v[..., 0]
            labels = kops.defuzzify_labels_batched(
                xs, v2, impl=labels_impl, interpret=False)
            return v2, delta, iters, total, labels
        labels = jax.vmap(F.labels_from_centers)(feats, v)
        return v, delta, iters, total, labels

    launch = _jit_launch(
        eng, bucket,
        ("pixel", platform, bucket, key, c, m, eps, max_iters, impl,
         labels_impl),
        launch_fn, 1, donate=(0,) if platform == "tpu" else ())

    def gather(eng_, chunk, bucket_):
        xs = np.empty((bucket_, n) if scalar else (bucket_, n, d),
                      np.float32)
        for i, q in enumerate(chunk):
            xs[i] = _pixel_rows(q.pixels)
        # Padding lanes replay the first image (frozen-lane masking makes
        # them cost one lane of compute; dropped on output).
        for i in range(len(chunk), bucket_):
            xs[i] = xs[0]
        return (xs,)

    def scatter(eng_, chunk, outs):
        v, delta, iters, total, labels = outs
        centers = np.asarray(v)
        iters_np = np.asarray(iters)
        labels_np = np.asarray(labels)
        res = [SegmentationResult(q.request_id,
                                  labels_np[i].reshape(shape[:2]),
                                  centers[i], int(iters_np[i]), False,
                                  method="pixel")
               for i, q in enumerate(chunk)]
        return res, centers, iters_np, int(total), np.asarray(delta)

    return RouteProgram(gather, launch, scatter)


# -- spatial route ----------------------------------------------------------

def _ingest_spatial(eng, img, rid) -> _PendingSpatial:
    if img.ndim not in (2, 3):
        raise ValueError(f"spatial requests need a (H, W) or (D, H, W) "
                         f"pixel grid, got shape {img.shape}")
    return _PendingSpatial(rid, img)


def _spatial_neighbors(eng, ndim: int) -> int:
    return eng.spatial_cfg.neighbors if ndim == 2 else 6


def _build_spatial(eng, chunk, bucket):
    imgs = np.stack([q.pixels.astype(np.float32) for q in chunk])
    n_pad = bucket - len(chunk)
    if n_pad:
        imgs = np.concatenate([imgs, np.repeat(imgs[:1], n_pad, axis=0)])
    scfg = eng.spatial_cfg
    stencil = SV.StencilSpec(alpha=scfg.alpha,
                             neighbors=_spatial_neighbors(
                                 eng, imgs.ndim - 1))
    return SV.batch_problems(jnp.asarray(imgs), stencil=stencil,
                             cfg=scfg), scfg


def _materialize_spatial(eng, q, centers, n_iters, cache_hit):
    # Single-request face of the batch materializer (the route registers
    # materialize_batch, so flush() normally never calls this; it exists
    # for API symmetry and must not drift from the batch version).
    return _materialize_spatial_batch(eng, [q], np.asarray(centers)[None],
                                      np.asarray([n_iters]))[0]


def _materialize_spatial_batch(eng, chunk, centers, n_iters):
    """One vmapped stencil-membership + argmax launch for the whole
    chunk: the per-request labeling is as stencil-heavy as an FCM_S
    iteration, so batching it is what keeps served spatial throughput
    at the batched-fit level."""
    import jax

    scfg = eng.spatial_cfg
    neighbors = _spatial_neighbors(eng, chunk[0].pixels.ndim)
    imgs = jnp.asarray(np.stack([q.pixels for q in chunk]), jnp.float32)
    u = jax.vmap(lambda im, v: SP.spatial_membership(
        im, v, scfg.m, scfg.alpha, neighbors))(
            imgs, jnp.asarray(centers[:len(chunk)]))
    labels = np.asarray(jnp.argmax(u, axis=1).astype(jnp.int32))
    return [SegmentationResult(q.request_id, labels[i],
                               np.asarray(centers[i]), int(n_iters[i]),
                               False, method="spatial")
            for i, q in enumerate(chunk)]


def _spatial_program_key(eng, chunk):
    return ("sp",) + chunk[0].pixels.shape  # bucket_key groups by shape


def _make_spatial_program(eng, key, bucket) -> "RouteProgram":
    """The fused spatial pipeline: stack -> batched FCM_S solve ->
    stencil-membership labeling, ONE jitted dispatch per flush. On TPU
    the solve stage is the VMEM-resident whole-solve stencil kernel
    (when the grid fits its bounds); off-TPU it is the vmapped
    reference stencil loop — either way the route sheds the
    per-stage host synchronization that made spatial serving the
    highest-overhead route."""
    shape = key[1:]
    scfg = eng.spatial_cfg
    c, m = scfg.n_clusters, float(scfg.m)
    alpha = float(scfg.alpha)
    neighbors = _spatial_neighbors(eng, len(shape))
    eps, max_iters = float(scfg.eps), int(scfg.max_iters)
    platform = jax.default_backend()
    impl = kops.select_step("stencil", platform=platform, batched=True,
                            n_rows=int(np.prod(shape)), c=c).name

    def launch_fn(imgs):
        v, delta, iters, total = SV.stencil_batched_solve(
            imgs, c, m, alpha, neighbors, eps, max_iters, impl=impl)
        u = jax.vmap(lambda im, vv: SP.spatial_membership(
            im, vv, m, alpha, neighbors))(imgs, v)
        labels = jnp.argmax(u, axis=1).astype(jnp.int32)
        return v, delta, iters, total, labels

    launch = _jit_launch(
        eng, bucket,
        ("spatial", platform, bucket, key, c, m, alpha, neighbors, eps,
         max_iters, impl),
        launch_fn, 1)

    def gather(eng_, chunk, bucket_):
        imgs = np.empty((bucket_,) + shape, np.float32)
        for i, q in enumerate(chunk):
            imgs[i] = q.pixels
        # Padding lanes replay the first image (frozen-lane masking makes
        # them cost one lane of compute; dropped on output).
        for i in range(len(chunk), bucket_):
            imgs[i] = imgs[0]
        return (imgs,)

    def scatter(eng_, chunk, outs):
        v, delta, iters, total, labels = outs
        centers = np.asarray(v)
        iters_np = np.asarray(iters)
        labels_np = np.asarray(labels)
        res = [SegmentationResult(q.request_id, labels_np[i], centers[i],
                                  int(iters_np[i]), False,
                                  method="spatial")
               for i, q in enumerate(chunk)]
        return res, centers, iters_np, int(total), np.asarray(delta)

    return RouteProgram(gather, launch, scatter)


# -- superpixel route -------------------------------------------------------

def _ingest_superpixel(eng, img, rid) -> _PendingSuperpixel:
    if img.ndim not in (2, 3):
        raise ValueError(f"superpixel requests need (H, W) or "
                         f"(H, W, D) input, got shape {img.shape}")
    # Per-route span + stage counter (not a global stat key): compress
    # is a stage of *this* route's ingest, and any future compressing
    # route gets its own `<prefix>_compress_seconds` for free.
    with eng.tracer.span("compress", ring=False, route="superpixel") as sp:
        comp = SX.compress(img.astype(np.float32), eng.superpixel_cfg)
    eng._stage_seconds("superpixel", "compress").inc(sp.wall_s)
    return _PendingSuperpixel(rid, np.asarray(comp.features),
                              np.asarray(comp.weights),
                              np.asarray(comp.label_map), comp.slic_iters)


def _build_superpixel(eng, chunk, bucket):
    k, d = chunk[0].features.shape
    feats = np.stack([q.features for q in chunk])
    ws = np.stack([q.weights for q in chunk])
    n_pad = bucket - len(chunk)
    if n_pad:
        # Benign padding lanes: a unit-weight feature ramp converges in a
        # handful of iterations and is dropped on output.
        ramp = np.broadcast_to(
            np.linspace(0.0, 1.0, k, dtype=np.float32)[:, None], (k, d))
        feats = np.concatenate([feats, np.broadcast_to(ramp, (n_pad, k, d))])
        ws = np.concatenate([ws, np.ones((n_pad, k), np.float32)])
    # The superpixel config governs the fit (a caller-supplied one must
    # win over self.cfg, not just steer the compression).
    return SV.batch_problems(jnp.asarray(feats), jnp.asarray(ws),
                             cfg=eng.superpixel_cfg), eng.superpixel_cfg


def _materialize_superpixel(eng, q, centers, n_iters, cache_hit):
    sp_labels = np.asarray(F.labels_from_centers(jnp.asarray(q.features),
                                                 jnp.asarray(centers)))
    labels = sp_labels[q.label_map]
    return SegmentationResult(q.request_id, labels, np.asarray(centers),
                              n_iters, cache_hit, method="superpixel")


register_route(RouteSpec(
    name="histogram", ingest=_ingest_histogram,
    bucket_key=lambda eng, p: ("hist",),
    build_problem=_build_histogram, materialize=_materialize_histogram,
    cacheable=True,
    program_key=_histogram_program_key,
    make_program=_make_histogram_program))
register_route(RouteSpec(
    name="pixel", ingest=_ingest_pixel,
    bucket_key=lambda eng, p: ("pixel",) + p.pixels.shape,
    build_problem=_build_pixel, materialize=_materialize_pixel,
    stats_prefix="pixel",
    program_key=_pixel_program_key,
    make_program=_make_pixel_program))
register_route(RouteSpec(
    name="spatial", ingest=_ingest_spatial,
    bucket_key=lambda eng, p: ("spatial",) + p.pixels.shape,
    build_problem=_build_spatial, materialize=_materialize_spatial,
    materialize_batch=_materialize_spatial_batch,
    stats_prefix="spatial",
    program_key=_spatial_program_key,
    make_program=_make_spatial_program))
register_route(RouteSpec(
    name="superpixel", ingest=_ingest_superpixel,
    bucket_key=lambda eng, p: ("superpixel",) + p.features.shape,
    build_problem=_build_superpixel, materialize=_materialize_superpixel,
    stats_prefix="superpixel"))

#: The serving routes, in registration order (the README routing table).
METHODS = tuple(ROUTES)


class FCMServeEngine:
    """Static-bucket batching engine for FCM segmentation requests.

    ``submit`` ingests an image through its route (any 2-D/3-D shape,
    8-bit-range values) and either answers from the cache or queues it.
    ``flush`` drains every route's queue through bucketed
    ``solve_batched`` calls. ``segment`` is the submit-all-then-flush
    convenience wrapper.

    **Async admission** (the continuous-batching front door):
    ``submit_async`` queues through the same per-route queues but hands
    back a :class:`~repro.serving.admission.SegmentationFuture`; a lazy
    background flusher thread forms batches — flushing when a bucket
    group reaches the target shape (``batch_sizes[-1]``) or when the
    oldest waiting async request exceeds ``max_wait_ms`` — and resolves
    futures as results materialize. ``drain()`` flushes synchronously
    (deterministic tests), ``shutdown()`` stops the flusher and either
    drains or fails the in-flight futures. The synchronous API is a
    degenerate case (no futures, caller-driven flush) and is untouched
    by the async machinery until the first ``submit_async``.

    **Mesh dispatch**: with a multi-device ``mesh``, every RouteProgram
    launch whose bucket divides by ``mesh.size`` is compiled with its
    batch axis sharded over the mesh (``core/distributed.shard_map``);
    program caches key on the mesh generation so ``set_mesh`` can never
    serve a stale single-device (or other-mesh) executable. A one-device
    mesh (or ``mesh=None``) runs the exact single-device path.
    """

    def __init__(self, cfg: F.FCMConfig = F.FCMConfig(),
                 batch_sizes: Sequence[int] = (1, 8, 64),
                 n_bins: int = 256,
                 cache_size: int = 256,
                 cache_tol: float = 0.15,
                 spatial_cfg: Optional[SP.SpatialFCMConfig] = None,
                 superpixel_cfg: Optional[SX.SuperpixelFCMConfig] = None,
                 tracing: bool = True,
                 trace_ring: int = 64,
                 mesh=None,
                 max_wait_ms: float = 10.0,
                 faults: Optional[Any] = None,
                 retries: int = 2,
                 retry_backoff_s: float = 0.05,
                 breaker_threshold: int = 3,
                 breaker_cooldown_s: float = 5.0,
                 max_queue_depth: Optional[int] = None):
        if not batch_sizes or any(b <= 0 for b in batch_sizes):
            raise ValueError(f"bad batch_sizes {batch_sizes!r}")
        self.cfg = cfg
        self.spatial_cfg = spatial_cfg or SP.SpatialFCMConfig(
            n_clusters=cfg.n_clusters, m=cfg.m, eps=cfg.eps,
            max_iters=cfg.max_iters)
        self.superpixel_cfg = superpixel_cfg or SX.SuperpixelFCMConfig(
            n_clusters=cfg.n_clusters, m=cfg.m, eps=cfg.eps,
            max_iters=cfg.max_iters)
        self.batch_sizes = tuple(sorted(set(int(b) for b in batch_sizes)))
        self.n_bins = n_bins
        self.cache_size = cache_size
        # Max L1 distance between normalized histograms for a near-match
        # cache hit; 0 restricts the cache to exact-histogram hits.
        self.cache_tol = cache_tol
        # key (exact histogram bytes) -> (centers, normalized histogram)
        self._cache: "collections.OrderedDict[bytes, Tuple[np.ndarray, np.ndarray]]" = \
            collections.OrderedDict()
        self._queues: Dict[str, List[Any]] = {name: [] for name in ROUTES}
        #: compiled RouteProgram cache keyed on (route, generation,
        #: bucket, payload-shape key); the generation key is what makes
        #: re-registered routes drop their stale programs.
        self._programs: Dict[Hashable, RouteProgram] = {}
        self._next_id = 0
        # All engine instrumentation lives on the obs layer: a private
        # MetricsRegistry (stats() renders the legacy flat keys from it)
        # plus a Tracer whose ring keeps the last ``trace_ring`` flush
        # traces. ``tracing=False`` keeps every stats counter (they are
        # the backward-compatible API) but skips ring-buffer and
        # span-histogram recording — the knob the tracing-overhead
        # benchmark toggles.
        self.metrics = obs.MetricsRegistry()
        self.tracer = obs.Tracer(max_traces=trace_ring, enabled=tracing,
                                 metrics=self.metrics)
        # -- fault tolerance ------------------------------------------------
        #: bounded retry on transient launch failures (exponential
        #: backoff: retry_backoff_s * 2^attempt between attempts).
        self.retries = int(retries)
        self.retry_backoff_s = float(retry_backoff_s)
        #: consecutive post-retry launch failures before a route's
        #: compiled program is circuit-broken to the staged reference
        #: path; after breaker_cooldown_s one half-open probe launch
        #: tests recovery.
        self.breaker_threshold = int(breaker_threshold)
        self.breaker_cooldown_s = float(breaker_cooldown_s)
        #: queued-request ceiling: submits beyond it shed the lowest-
        #: urgency queued async request (or the incoming one) with a
        #: typed Overloaded error. None = unbounded (the default).
        self.max_queue_depth = (None if max_queue_depth is None
                                else int(max_queue_depth))
        if faults is None:
            self._faults: Optional[FI.FaultInjector] = None
        elif isinstance(faults, FI.FaultInjector):
            self._faults = faults
        else:
            self._faults = FI.FaultInjector(faults, registry=self.metrics)
        #: per-route breaker state {"state", "failures", "opened_t"};
        #: guarded by _lock.
        self._breakers: Dict[str, Dict[str, Any]] = {}
        #: hard (BaseException) flusher deaths observed; restarts are the
        #: "flusher.restarts" counter.
        self._flusher_kills = 0
        #: request id -> (submit perf_counter, route name); consumed when
        #: the request's result materializes, feeding the per-route
        #: submit->result latency histogram.
        self._submit_t: Dict[int, Tuple[float, str]] = {}
        # -- async admission state ----------------------------------------
        #: guards queues / futures / id allocation / shutdown flag; the
        #: condition wakes the flusher on submits and shutdown. RLock so
        #: submit_async can hold it across the whole enqueue+register
        #: critical section.
        self._lock = threading.RLock()
        self._cond = threading.Condition(self._lock)
        #: serializes flush *bodies* (flusher thread vs. drain/flush
        #: callers): queue swaps stay atomic under ``_lock``, the solve
        #: work runs outside it so submits never block on a device batch.
        self._flush_lock = threading.Lock()
        #: request id -> unresolved future (async requests only).
        self._futures: Dict[int, SegmentationFuture] = {}
        self.max_wait_ms = float(max_wait_ms)
        self._closed = False
        self._flusher: Optional[threading.Thread] = None
        # -- mesh dispatch state ------------------------------------------
        #: bumped by set_mesh; part of every program-cache key, so stale
        #: mesh programs are purged exactly like stale route generations.
        self._mesh_gen = 0
        self.mesh = None
        if mesh is not None:
            self.set_mesh(mesh)
        # Pre-register the schema for the routes known at construction
        # (zero-valued stats appear before any traffic; routes registered
        # later join lazily through the get-or-create registry).
        self.metrics.counter("requests")
        self.metrics.counter("cache_hits")
        self.metrics.gauge("queue.depth")
        self.metrics.counter("flusher.restarts")
        for route in ROUTES.values():
            self._route_counter("requests", route.name)
            self._route_counter("cache_hits", route.name)
            for k in ("batches", "images", "padded", "iters",
                      "deadline_expired", "retries", "shed", "salvaged",
                      "degraded", "breaker_trips", "invalid_input"):
                self._route_counter(k, route.name)
            self.metrics.gauge("route.breaker_state", route=route.name)
            for stage in ("ingest", "solve", "materialize", "compress"):
                self._stage_seconds(route.name, stage)
            self._latency_hist(route.name)
            self._iters_hist(route.name)
            self._occupancy_hist(route.name)
            self.metrics.gauge("queue.depth", route=route.name)
        # Hot-path handles: submit runs per request, so the registry
        # lookups (each a lock + labelled-key probe) are hoisted out of
        # the admission path; depth gauges update incrementally and
        # _set_queue_gauges re-bases the total on queue swaps.
        self._qtotal = 0
        self._depth_gauge = self.metrics.gauge("queue.depth")
        self._depth_gauges = {
            name: self.metrics.gauge("queue.depth", route=name)
            for name in ROUTES}
        self._req_counter = self.metrics.counter("requests")
        self._req_counters = {
            name: self._route_counter("requests", name) for name in ROUTES}
        #: per-route count of queued async requests (guarded by _lock);
        #: lets submit_async wake the flusher only when the wake can
        #: change its schedule (first async request -> a new window
        #: deadline, or a full target shape -> flush due now).
        self._async_n: Dict[str, int] = {}

    # -- mesh ---------------------------------------------------------------

    def set_mesh(self, mesh) -> None:
        """Attach (or replace, or with ``None`` detach) the device mesh
        RouteProgram launches shard over. Bumps the mesh generation so
        every program compiled against the previous mesh is evicted on
        next use — a mesh swap can never serve a stale executable."""
        with self._lock:
            self.mesh = mesh
            self._mesh_gen += 1

    def _mesh_for_bucket(self, bucket: int):
        """The mesh a ``bucket``-lane launch shards over, or None for
        the single-device path (no mesh, a one-device mesh, or a bucket
        the mesh does not divide — ragged shards would need per-device
        padding for no win at these batch sizes)."""
        mesh = self.mesh
        if mesh is None or mesh.size <= 1 or bucket % mesh.size != 0:
            return None
        return mesh

    # -- metric accessors --------------------------------------------------

    def _route_counter(self, name: str, route_name: str) -> obs.Counter:
        return self.metrics.counter(f"route.{name}", route=route_name)

    def _stage_seconds(self, route_name: str, stage: str) -> obs.Counter:
        return self.metrics.counter("route.stage_seconds",
                                    route=route_name, stage=stage)

    def _latency_hist(self, route_name: str) -> obs.Histogram:
        """Per-route submit->result latency (seconds)."""
        return self.metrics.histogram("route.latency_seconds",
                                      route=route_name)

    def _iters_hist(self, route_name: str) -> obs.Histogram:
        """Per-route iterations-to-converge, one sample per real lane."""
        return self.metrics.histogram("route.lane_iters",
                                      edges=obs.ITER_EDGES,
                                      route=route_name)

    def _occupancy_hist(self, route_name: str) -> obs.Histogram:
        """Per-route batch occupancy: real lanes / bucket size, one
        sample per launched bucket (1.0 = no padding waste)."""
        return self.metrics.histogram("route.batch_occupancy",
                                      edges=obs.UNIT_EDGES,
                                      route=route_name)

    def _depth_gauge_for(self, method: str) -> obs.Gauge:
        g = self._depth_gauges.get(method)
        if g is None:
            g = self._depth_gauges.setdefault(
                method, self.metrics.gauge("queue.depth", route=method))
        return g

    def _set_queue_gauges(self) -> None:
        """Re-base the per-route + global queue-depth gauges from the
        actual queues (caller holds ``_lock``; used on queue swaps —
        per-submit updates are incremental in ``_enqueue``)."""
        total = 0
        for name, q in self._queues.items():
            self._depth_gauge_for(name).set(len(q))
            total += len(q)
        self._qtotal = total
        self._depth_gauge.set(total)

    def _finish(self, route: RouteSpec, results: Dict[int, Any],
                r: SegmentationResult) -> None:
        """Record one materialized result + its submit->result latency,
        and resolve the request's future if it was submitted async."""
        results[r.request_id] = r
        sub = self._submit_t.pop(r.request_id, None)
        if sub is not None:
            self._latency_hist(route.name).record(
                time.perf_counter() - sub[0])
        fut = self._futures.pop(r.request_id, None)
        if fut is not None:
            fut.try_set_result(r)

    def _fail_request(self, p: Any, err: BaseException) -> bool:
        """Resolve one request's bookkeeping with a typed error; returns
        True when an async future took it (sync callers have no future —
        their flush must surface the error itself)."""
        self._submit_t.pop(p.request_id, None)
        fut = self._futures.pop(p.request_id, None)
        if fut is not None:
            fut.try_set_exception(err)
            return True
        return False

    # -- ingest ------------------------------------------------------------

    def _ingest(self, method: str, img: np.ndarray):
        """Validate + reduce one payload through its route (outside the
        admission lock: superpixel ingest runs SLIC)."""
        route = ROUTES.get(method)
        if route is None:
            raise ValueError(f"unknown method {method!r}; registered "
                             f"routes: {METHODS}")
        img = np.asarray(img)
        # Ingest validates eagerly: a request failing inside flush()
        # would discard the whole drained batch's results. A raise here
        # consumes neither a request id nor a queue slot (the span
        # records status="error" and re-raises before any counter but
        # the invalid-input tally moves).
        try:
            with self.tracer.span("ingest", ring=False, route=method) as sp:
                if self._faults is not None:
                    self._faults.maybe_fail("ingest", route=method)
                _validate_payload(img)
                pending = route.ingest(self, img, self._next_id)
        except InvalidInput:
            self._route_counter("invalid_input", method).inc()
            raise
        self._stage_seconds(method, "ingest").inc(sp.wall_s)
        return pending

    def _enqueue(self, method: str, pending, t_submit: float) -> int:
        """Allocate the request id and queue the payload (caller holds
        ``_lock``)."""
        if self._closed:
            raise EngineShutdown("engine is shut down; no new submits")
        rid = self._next_id
        self._next_id += 1
        # The id passed to ingest was advisory (allocation races with
        # other submitters); the queued payload carries the real one.
        pending.request_id = rid
        self._req_counter.inc()
        rc = self._req_counters.get(method)
        if rc is None:
            rc = self._req_counters.setdefault(
                method, self._route_counter("requests", method))
        rc.inc()
        self._submit_t[rid] = (t_submit, method)
        q = self._queues.setdefault(method, [])
        q.append(pending)
        self._depth_gauge_for(method).set(len(q))
        self._qtotal += 1
        self._depth_gauge.set(self._qtotal)
        return rid

    def submit(self, img: np.ndarray, method: str = "histogram") -> int:
        """Queue one image on a registered route; returns its request id.
        Cache hits are still materialized at flush time (the defuzzify
        LUT needs the pixels). See ``METHODS`` / the README routing
        table for the built-in routes."""
        t_submit = time.perf_counter()
        pending = self._ingest(method, img)
        with self._lock:
            return self._enqueue(method, pending, t_submit)

    def submit_async(self, img: np.ndarray, method: str = "histogram",
                     deadline: Optional[float] = None) -> SegmentationFuture:
        """Queue one image and return a future for its result.

        ``deadline`` is relative seconds from now: a request still
        queued when its deadline passes resolves with
        :class:`~repro.serving.admission.DeadlineExceeded` instead of
        running (a non-positive deadline fails at submit, consuming no
        request id or queue slot). Batches form in the background —
        when a bucket group reaches the target shape
        (``batch_sizes[-1]``) or the oldest waiting async request
        exceeds ``max_wait_ms`` — or deterministically via ``drain()``.
        Raises :class:`~repro.serving.admission.EngineShutdown` after
        ``shutdown()``.
        """
        t_submit = time.perf_counter()
        if method not in ROUTES:
            raise ValueError(f"unknown method {method!r}; registered "
                             f"routes: {METHODS}")
        if self._closed:
            raise EngineShutdown("engine is shut down; no new submits")
        if deadline is not None and deadline <= 0:
            fut = SegmentationFuture(-1, method, deadline=t_submit)
            fut.submit_t = t_submit
            self._route_counter("deadline_expired", method).inc()
            fut.set_exception(DeadlineExceeded(
                f"deadline {deadline}s already expired at submit"))
            return fut
        try:
            pending = self._ingest(method, img)
        except (InvalidInput, FI.InjectedFault) as e:
            # Same semantics as an already-expired deadline: a failed
            # future, no request id, no queue slot. Injected ingest
            # faults take the same door — a payload that dies during
            # decode must fail only its own submit.
            fut = SegmentationFuture(-1, method)
            fut.submit_t = t_submit
            fut.set_exception(e)
            return fut
        abs_deadline = None if deadline is None else t_submit + deadline
        with self._lock:
            if (self.max_queue_depth is not None
                    and self._qtotal >= self.max_queue_depth
                    and not self._shed_for(
                        float("inf") if abs_deadline is None
                        else abs_deadline)):
                # Every queued request is at least as urgent as this
                # one: shed the incoming request instead.
                self._route_counter("shed", method).inc()
                fut = SegmentationFuture(-1, method, deadline=abs_deadline)
                fut.submit_t = t_submit
                fut.set_exception(Overloaded(
                    f"queue depth {self._qtotal} at max_queue_depth="
                    f"{self.max_queue_depth}; request shed"))
                return fut
            rid = self._enqueue(method, pending, t_submit)
            fut = SegmentationFuture(rid, method, deadline=abs_deadline)
            fut.submit_t = t_submit
            self._futures[rid] = fut
            self._ensure_flusher()
            # Wake the flusher only when this submit can change its
            # schedule: the route's first queued async request starts a
            # max_wait window; every target-shape-multiple of queued
            # requests may complete a full bucket group (mixed-shape
            # groups that straddle the multiple still flush at the
            # window — the wake is an early trigger, not the backstop).
            n_async = self._async_n.get(method, 0) + 1
            self._async_n[method] = n_async
            if (n_async == 1
                    or len(self._queues[method]) % self.batch_sizes[-1]
                    == 0):
                self._cond.notify_all()
        return fut

    def _shed_for(self, incoming_deadline: float) -> bool:
        """Overload shedding (caller holds ``_lock``): fail the single
        *least urgent* queued async request — the one with the farthest
        (or no) deadline — with :class:`Overloaded`, freeing its slot
        for a strictly more urgent incoming request. Returns False when
        nothing queued is less urgent (ties shed the incoming request:
        it is the newest) or only sync requests are queued (their
        callers hold no future to fail)."""
        worst: Optional[Tuple[Tuple[float, int], str, Any]] = None
        for name, q in self._queues.items():
            for p in q:
                fut = self._futures.get(p.request_id)
                if fut is None:
                    continue
                d = (fut.deadline if fut.deadline is not None
                     else float("inf"))
                key = (d, p.request_id)
                if worst is None or key > worst[0]:
                    worst = (key, name, p)
        if worst is None or worst[0][0] <= incoming_deadline:
            return False
        (_, rid), name, p = worst
        self._queues[name].remove(p)
        self._qtotal -= 1
        self._depth_gauge.set(self._qtotal)
        self._depth_gauge_for(name).set(len(self._queues[name]))
        if self._async_n.get(name):
            self._async_n[name] -= 1
        self._route_counter("shed", name).inc()
        self._submit_t.pop(rid, None)
        fut = self._futures.pop(rid, None)
        if fut is not None:
            fut.try_set_exception(Overloaded(
                f"request {rid} shed under overload (queue at "
                f"max_queue_depth={self.max_queue_depth})"))
        return True

    @staticmethod
    def _normalize(hist: np.ndarray) -> np.ndarray:
        return hist / max(float(hist.sum()), 1.0)

    # -- drain -------------------------------------------------------------

    def flush(self, raise_errors: bool = True) -> List[SegmentationResult]:
        """Run every queued request; returns results in submit order.
        Route-agnostic: cache/dedup for cacheable routes, then group by
        bucket key and run one batched solve per bucket. Each flush
        leaves one root trace (per-bucket child spans inside) in
        ``tracer``'s ring.

        Thread-safe: the queue swap is atomic under the admission lock
        and flush bodies are serialized, so the background flusher and
        explicit flush/drain callers can never process one request
        twice. A route whose batch raises fails that route's
        unresolved futures with the error; with ``raise_errors`` (the
        synchronous default) the first error then propagates, while the
        background flusher passes ``False`` so one poisoned route never
        kills the thread serving the others."""
        results: Dict[int, SegmentationResult] = {}
        first_err: Optional[BaseException] = None
        with self._flush_lock:
            with self._lock:
                drained = {name: self._queues[name] for name in self._queues}
                for name in drained:
                    self._queues[name] = []
                self._async_n = {}
                self._set_queue_gauges()
            n_queued = sum(len(v) for v in drained.values())
            with self.tracer.span("flush", queued=n_queued):
                for route in ROUTES.values():
                    pend = self._admit_order(route,
                                             drained.get(route.name) or [])
                    if not pend:
                        continue
                    try:
                        self._flush_route(route, pend, results)
                    except BaseException as e:  # noqa: BLE001
                        for p in pend:
                            if p.request_id in results:
                                continue
                            self._fail_request(p, e)
                        if first_err is None:
                            first_err = e
        if first_err is not None and raise_errors:
            raise first_err
        return [results[rid] for rid in sorted(results)]

    def _admit_order(self, route: RouteSpec, pend: List[Any]) -> List[Any]:
        """Deadline admission on a drained route queue: expire overdue
        async requests (their futures fail with ``DeadlineExceeded``
        without spending a solver lane) and order survivors
        most-urgent-first, so tight-deadline requests land in the
        earliest chunk of their bucket group. Sync requests carry no
        deadline and keep their submit order."""
        now = time.perf_counter()
        keep: List[Any] = []
        for p in pend:
            fut = self._futures.get(p.request_id)
            if (fut is not None and fut.deadline is not None
                    and now > fut.deadline):
                self._futures.pop(p.request_id, None)
                self._submit_t.pop(p.request_id, None)
                self._route_counter("deadline_expired", route.name).inc()
                fut.try_set_exception(DeadlineExceeded(
                    f"request {p.request_id} missed its deadline "
                    f"while queued"))
                continue
            keep.append(p)

        def urgency(p):
            fut = self._futures.get(p.request_id)
            d = (fut.deadline
                 if fut is not None and fut.deadline is not None
                 else float("inf"))
            return (d, p.request_id)

        keep.sort(key=urgency)
        return keep

    def _flush_route(self, route: RouteSpec, pend: List[Any],
                     results: Dict[int, SegmentationResult]) -> None:
        """One route's share of a flush: cache/dedup, bucket, solve."""
        dups: List[Any] = []
        fitted: Dict[bytes, np.ndarray] = {}
        if route.cacheable:
            pend, dups = self._answer_from_cache(route, pend, results)
        groups: "collections.OrderedDict[Hashable, List[Any]]" = \
            collections.OrderedDict()
        for p in pend:
            groups.setdefault(route.bucket_key(self, p), []).append(p)
        for group in groups.values():
            i = 0
            while i < len(group):
                chunk = group[i:i + self.batch_sizes[-1]]
                i += len(chunk)
                self._run_bucket(route, chunk,
                                 self._bucket_for(len(chunk)),
                                 results, fitted)
        # duplicates ride on their representative's centers (kept
        # locally: the LRU may be disabled, or evict mid-flush)
        for p in dups:
            self.metrics.counter("cache_hits").inc()
            self._route_counter("cache_hits", route.name).inc()
            self._finish(route, results, route.materialize(
                self, p, fitted[p.key], 0, True))

    def drain(self) -> List[SegmentationResult]:
        """Deterministically flush everything queued, resolving every
        pending future; returns the materialized results. A zero-request
        drain is a cheap no-op returning ``[]``. If the background
        flusher is mid-flush, ``drain`` waits for that batch (flush
        bodies serialize), so every request submitted before the call
        is resolved when it returns."""
        return self.flush()

    def segment(self, imgs: Sequence[np.ndarray],
                method: str = "histogram") -> List[SegmentationResult]:
        ids = [self.submit(im, method=method) for im in imgs]
        by_id = {r.request_id: r for r in self.flush()}
        return [by_id[i] for i in ids]

    # -- background flusher ------------------------------------------------

    def _ensure_flusher(self) -> None:
        """Start the batch-formation thread lazily (caller holds
        ``_lock``): engines serving only the synchronous API never pay
        for — or behave differently because of — a background thread.
        Called on *every* async submit, so a flusher that died hard
        (anything escaping the supervised loop, including an injected
        :class:`~repro.faults.FlusherKilled`) is replaced before the new
        request could ever hang on a dead thread."""
        if self._flusher is not None and not self._flusher.is_alive():
            # Replacing a dead thread (supervised restarts inside a live
            # loop count themselves).
            self.metrics.counter("flusher.restarts").inc()
            self._flusher = None
        if self._flusher is None:
            self._flusher = threading.Thread(
                target=self._flusher_loop, name="fcm-serve-flusher",
                daemon=True)
            self._flusher.start()

    def _flush_due(self) -> Optional[float]:
        """Batch-formation policy (caller holds ``_lock``): seconds
        until the next flush is due — ``0.0`` for *due now* (some bucket
        group reached the target shape, or the oldest waiting async
        request exceeded ``max_wait_ms``), ``None`` for *nothing async
        waiting* (sleep until a submit wakes us)."""
        now = time.perf_counter()
        oldest: Optional[float] = None
        target = self.batch_sizes[-1]
        for name, q in self._queues.items():
            route = ROUTES.get(name)
            if route is None or not q:
                continue
            group_sizes: Dict[Hashable, int] = {}
            async_here = False
            for p in q:
                k = route.bucket_key(self, p)
                group_sizes[k] = group_sizes.get(k, 0) + 1
                if p.request_id in self._futures:
                    async_here = True
                    t = self._submit_t.get(p.request_id)
                    if t is not None and (oldest is None or t[0] < oldest):
                        oldest = t[0]
            # Target-shape trigger: only once async traffic is involved
            # (pure sync queues belong to their caller's flush).
            if async_here and any(n >= target
                                  for n in group_sizes.values()):
                return 0.0
        if oldest is None:
            return None
        return max(0.0, oldest + self.max_wait_ms / 1000.0 - now)

    def _flusher_loop(self) -> None:
        # Supervised: the whole iteration body is wrapped, so a raise
        # anywhere — _flush_due bookkeeping on a malformed payload, the
        # flush machinery itself — restarts the loop in place (counted
        # in flusher.restarts) instead of silently killing the thread
        # with async clients parked on it forever. Only BaseException
        # (thread-kill) escapes; _ensure_flusher replaces the thread on
        # the next async submit.
        while True:
            try:
                if self._faults is not None:
                    self._faults.maybe_fail("flusher")
                with self._lock:
                    while True:
                        if self._closed:
                            return
                        wait = self._flush_due()
                        if wait is not None and wait <= 0.0:
                            break
                        self._cond.wait(timeout=wait)
                # Outside the lock: the flush body serializes on
                # _flush_lock and swaps queues atomically; per-route
                # errors have already been routed into the affected
                # futures (raise_errors=False).
                self.flush(raise_errors=False)
            except FI.FlusherKilled:
                # Hard thread death. If work is still pending, spawn a
                # replacement before dying — parked futures must never
                # hang on a corpse (submit_async also re-ensures, but a
                # lone in-flight request has no later submit to do it).
                with self._lock:
                    self._flusher_kills += 1
                    self._flusher = None
                    if not self._closed and (
                            self._qtotal > 0
                            or sum(self._async_n.values()) > 0):
                        self.metrics.counter("flusher.restarts").inc()
                        self._ensure_flusher()
                return
            except Exception:   # noqa: BLE001 — supervised restart
                self.metrics.counter("flusher.restarts").inc()
                continue

    def shutdown(self, drain: bool = True) -> None:
        """Stop the background flusher and close admission. With
        ``drain`` (default), everything still queued is flushed and
        every future resolves with its result; with ``drain=False``,
        queued requests are dropped and their futures fail with
        :class:`~repro.serving.admission.EngineShutdown`. Subsequent
        submits raise ``EngineShutdown``; ``shutdown`` is idempotent."""
        with self._lock:
            already = self._closed
            self._closed = True
            self._cond.notify_all()
            flusher = self._flusher
        if flusher is not None and flusher.is_alive():
            flusher.join()
        if already:
            return
        if drain:
            self.flush(raise_errors=False)
            return
        with self._lock:
            dropped: List[Any] = []
            for name in self._queues:
                dropped.extend(self._queues[name])
                self._queues[name] = []
            self._async_n = {}
            self._set_queue_gauges()
        err = EngineShutdown("engine shut down with the request queued")
        for p in dropped:
            self._fail_request(p, err)

    @property
    def closed(self) -> bool:
        return self._closed

    def _answer_from_cache(self, route: RouteSpec, pend: List[Any],
                           results: Dict[int, SegmentationResult]):
        """Cache lookups + intra-flush dedup (one fit per distinct key);
        returns (representatives to fit, duplicates). With the LRU
        disabled neither histograms nor dedup keys are ever computed:
        duplicate payloads simply occupy identical lanes of the batched
        solve (identical lanes converge identically, so results match)
        — hashing 64 KB of pixels per request to *maybe* merge lanes
        inside an already-padded bucket costs more than it saves."""
        misses: List[Any] = []
        if self.cache_size <= 0:
            return pend, []
        for p in pend:
            _ensure_hist(self, p)
            centers = self._cache_get(p.key, p.hist)
            if centers is not None:
                self.metrics.counter("cache_hits").inc()
                self._route_counter("cache_hits", route.name).inc()
                self._finish(route, results, route.materialize(
                    self, p, centers, 0, True))
            else:
                misses.append(p)
        uniq: Dict[bytes, Any] = {}
        dups: List[Any] = []
        for p in misses:
            if p.key in uniq:
                dups.append(p)
            else:
                uniq[p.key] = p
        return list(uniq.values()), dups

    def _bucket_for(self, n: int) -> int:
        for b in self.batch_sizes:
            if n <= b:
                return b
        return self.batch_sizes[-1]

    def _program_for(self, route: RouteSpec,
                     chunk: List[Any], bucket: int) -> Optional[RouteProgram]:
        """The compiled single-dispatch program this chunk can ride, or
        None (route has no programs / chunk shape has none). Programs
        are cached per (route generation, mesh generation, bucket,
        shape key); stale generations — a re-registered route OR a
        swapped mesh — are purged here."""
        if route.make_program is None or route.program_key is None:
            return None
        key = route.program_key(self, chunk)
        if key is None:
            return None
        gen = _ROUTE_GEN[route.name]
        stale = [k for k in self._programs
                 if (k[0] == route.name and k[1] != gen)
                 or k[2] != self._mesh_gen]
        for k in stale:
            del self._programs[k]
        full_key = (route.name, gen, self._mesh_gen, bucket, key)
        prog = self._programs.get(full_key)
        if prog is None:
            prog = route.make_program(self, key, bucket)
            self._programs[full_key] = prog
            # Same bound rationale as _LAUNCH_CACHE: size-keyed program
            # flavors must not accumulate one entry per payload size.
            while len(self._programs) > _LAUNCH_CACHE_SIZE:
                oldest = next(iter(self._programs))
                del self._programs[oldest]
        return prog

    # -- circuit breaker + retry (the graceful-degradation ladder) ---------

    _BREAKER_GAUGE = {"closed": 0.0, "half_open": 0.5, "open": 1.0}

    def _breaker(self, route_name: str) -> Dict[str, Any]:
        b = self._breakers.get(route_name)
        if b is None:
            b = {"state": "closed", "failures": 0, "opened_t": 0.0}
            self._breakers[route_name] = b
        return b

    def _set_breaker(self, route_name: str, b: Dict[str, Any],
                     state: str) -> None:
        b["state"] = state
        self.metrics.gauge("route.breaker_state", route=route_name).set(
            self._BREAKER_GAUGE[state])

    def _breaker_allows(self, route_name: str) -> bool:
        """May this chunk ride the route's compiled program? ``closed``
        -> yes; ``open`` -> no until ``breaker_cooldown_s`` elapses,
        then exactly one half-open probe launch tests recovery;
        ``half_open`` -> no (a probe is already in flight)."""
        with self._lock:
            b = self._breaker(route_name)
            if b["state"] == "closed":
                return True
            if b["state"] == "open" and (
                    time.perf_counter() - b["opened_t"]
                    >= self.breaker_cooldown_s):
                self._set_breaker(route_name, b, "half_open")
                return True
            return False

    def _breaker_success(self, route_name: str) -> None:
        with self._lock:
            b = self._breaker(route_name)
            if b["state"] != "closed" or b["failures"]:
                b["failures"] = 0
                self._set_breaker(route_name, b, "closed")

    def _breaker_failure(self, route_name: str) -> None:
        """One post-retry launch failure: count toward the trip
        threshold (closed) or fail the recovery probe straight back to
        open with a fresh cooldown (half_open)."""
        with self._lock:
            b = self._breaker(route_name)
            if b["state"] == "half_open":
                b["opened_t"] = time.perf_counter()
                self._route_counter("breaker_trips", route_name).inc()
                self._set_breaker(route_name, b, "open")
                return
            b["failures"] += 1
            if (b["state"] == "closed"
                    and b["failures"] >= self.breaker_threshold):
                b["opened_t"] = time.perf_counter()
                self._route_counter("breaker_trips", route_name).inc()
                self._set_breaker(route_name, b, "open")

    def _launch_attempts(self, route: RouteSpec, prog: RouteProgram,
                         inputs: Tuple) -> Tuple:
        """One program launch under the bounded-retry policy: transient
        failures (injected faults, launch-time runtime errors) retry up
        to ``retries`` times with exponential backoff; programming
        errors (ValueError/TypeError) and the final failure propagate —
        the caller advances the breaker and degrades the chunk."""
        attempt = 0
        while True:
            try:
                if self._faults is not None:
                    self._faults.maybe_fail("launch", route=route.name)
                return prog.launch(*inputs)
            except (ValueError, TypeError):
                raise
            except Exception:
                if attempt >= self.retries:
                    raise
                self._route_counter("retries", route.name).inc()
                time.sleep(self.retry_backoff_s * (2 ** attempt))
                attempt += 1

    def _route_cfg(self, route: RouteSpec):
        """The config whose eps/max_iters govern this route's fits."""
        if route.name == "spatial":
            return self.spatial_cfg
        if route.name == "superpixel":
            return self.superpixel_cfg
        return self.cfg

    def _salvage_requests(self, route: RouteSpec, bad: List[Any],
                          results: Dict[int, SegmentationResult],
                          fitted: Dict[bytes, np.ndarray]) -> None:
        """Re-solve poisoned requests on the reference backend in their
        own mini-bucket and finish them from the clean centers — one
        non-finite lane degrades to a per-request reference re-solve
        instead of failing (or infecting) its whole batch. A request
        still non-finite after the reference pass fails with
        :class:`SolveFailed` (async: typed error on its future; sync:
        raised to the flushing caller)."""
        self._route_counter("salvaged", route.name).inc(len(bad))
        bucket = self._bucket_for(len(bad))
        problem, cfg = route.build_problem(self, bad, bucket)
        res = SV.solve_batched(problem, cfg, backend="reference")
        centers = np.asarray(res.centers)
        healthy = (np.ones(len(bad), bool) if res.healthy is None
                   else np.asarray(res.healthy))
        conv = (None if res.converged is None
                else np.asarray(res.converged))
        doomed: Optional[BaseException] = None
        for lane, p in enumerate(bad):
            if not bool(healthy[lane]):
                err = SolveFailed(
                    f"request {p.request_id}: non-finite centers even "
                    f"on the reference backend")
                if not self._fail_request(p, err) and doomed is None:
                    doomed = err
                continue
            r = route.materialize(self, p, centers[lane],
                                  int(res.n_iters[lane]), False)
            if conv is not None:
                r.converged = bool(conv[lane])
            self._finish(route, results, r)
            if route.cacheable and getattr(p, "key", None) is not None:
                fitted[p.key] = centers[lane]
                if self.cache_size > 0 and p.hist is not None:
                    self._cache_put(p.key, centers[lane], p.hist)
        if doomed is not None:
            raise doomed

    def _run_bucket(self, route: RouteSpec, chunk: List[Any], bucket: int,
                    results: Dict[int, SegmentationResult],
                    fitted: Dict[bytes, np.ndarray]):
        prog = self._program_for(route, chunk, bucket)
        use_prog = prog is not None and self._breaker_allows(route.name)
        degraded = prog is not None and not use_prog
        n_iters = None
        deltas = None
        max_iters = int(self._route_cfg(route).max_iters)
        bad_pend: List[Any] = []
        bad_ids: set = set()
        with self.tracer.span("bucket", route=route.name, bucket=bucket,
                              n=len(chunk), fused=use_prog,
                              requests=[p.request_id for p in chunk]):
            if use_prog:
                # Device-resident fast path: host-side stacking, ONE
                # jitted dispatch (ingest-binning + solve + defuzzify),
                # unpack. Launch failures surviving the retry budget
                # advance the breaker and degrade this chunk to the
                # staged reference path below.
                with self.tracer.span("gather", route=route.name) as sp_g:
                    inputs = prog.gather(self, chunk, bucket)
                try:
                    with self.tracer.span("launch",
                                          route=route.name) as sp_s:
                        outs = sp_s.fence(
                            self._launch_attempts(route, prog, inputs))
                except (ValueError, TypeError):
                    raise       # programming errors are not transient
                except Exception:
                    self._breaker_failure(route.name)
                    self._route_counter("degraded", route.name).inc()
                    use_prog, degraded = False, True
                else:
                    self._breaker_success(route.name)
                    with self.tracer.span("scatter",
                                          route=route.name) as sp_m:
                        scattered = prog.scatter(self, chunk, outs)
                    res_list, centers, n_iters, total_iters = scattered[:4]
                    if len(scattered) > 4:      # telemetry-aware program
                        deltas = np.asarray(scattered[4])
                    if self._faults is not None:
                        centers = np.asarray(self._faults.corrupt(
                            "solve", centers, route=route.name))
                    finite = np.isfinite(
                        centers.reshape(centers.shape[0], -1)).all(axis=1)
                    iters_np = np.asarray(n_iters)
                    for lane, (p, r) in enumerate(zip(chunk, res_list)):
                        if not bool(finite[lane]):
                            bad_pend.append(p)
                            bad_ids.add(p.request_id)
                            continue
                        r.converged = bool(iters_np[lane] < max_iters)
                        self._finish(route, results, r)
                    self._stage_seconds(route.name, "ingest").inc(
                        sp_g.wall_s)
                    self._stage_seconds(route.name, "solve").inc(
                        sp_s.wall_s)
                    self._stage_seconds(route.name, "materialize").inc(
                        sp_m.wall_s)
            if not use_prog:
                with self.tracer.span("build", route=route.name) as sp_g:
                    problem, cfg = route.build_problem(self, chunk, bucket)
                with self.tracer.span("solve", route=route.name) as sp_s:
                    res = sp_s.fence(SV.solve_batched(
                        problem, cfg,
                        backend="reference" if degraded else "auto"))
                with self.tracer.span("materialize",
                                      route=route.name) as sp_m:
                    centers = np.asarray(res.centers)
                    if self._faults is not None:
                        centers = np.asarray(self._faults.corrupt(
                            "solve", centers, route=route.name))
                    total_iters = int(res.total_iters)
                    n_iters = res.n_iters
                    deltas = np.asarray(res.final_delta)
                    finite = np.isfinite(
                        centers.reshape(centers.shape[0], -1)).all(axis=1)
                    conv = (None if res.converged is None
                            else np.asarray(res.converged))
                    good: List[Tuple[int, Any]] = []
                    for lane, p in enumerate(chunk):
                        if bool(finite[lane]):
                            good.append((lane, p))
                        else:
                            bad_pend.append(p)
                            bad_ids.add(p.request_id)
                    if route.materialize_batch is not None:
                        gchunk = [p for _, p in good]
                        if gchunk:
                            lanes = [lane for lane, _ in good]
                            for j, r in enumerate(route.materialize_batch(
                                    self, gchunk, centers[lanes],
                                    res.n_iters[lanes])):
                                if conv is not None:
                                    r.converged = bool(conv[lanes[j]])
                                self._finish(route, results, r)
                    else:
                        for lane, p in good:
                            r = route.materialize(
                                self, p, centers[lane],
                                int(res.n_iters[lane]), False)
                            if conv is not None:
                                r.converged = bool(conv[lane])
                            self._finish(route, results, r)
                self._stage_seconds(route.name, "ingest").inc(sp_g.wall_s)
                self._stage_seconds(route.name, "solve").inc(sp_s.wall_s)
                self._stage_seconds(route.name, "materialize").inc(
                    sp_m.wall_s)
            if bad_pend:
                # Poisoned lanes (injected or real non-finite centers):
                # per-request reference re-solve, healthy batchmates
                # already finished untouched above.
                with self.tracer.span("salvage", route=route.name,
                                      n=len(bad_pend)):
                    self._salvage_requests(route, bad_pend, results,
                                           fitted)
        self._route_counter("batches", route.name).inc()
        self._route_counter("images", route.name).inc(len(chunk))
        self._route_counter("padded", route.name).inc(bucket - len(chunk))
        self._route_counter("iters", route.name).inc(int(total_iters))
        self._occupancy_hist(route.name).record(len(chunk) / bucket)
        # Convergence telemetry: one sample per *real* lane (padding
        # lanes converge artificially fast and would skew the mix).
        if n_iters is not None:
            h = self._iters_hist(route.name)
            for it in np.asarray(n_iters)[:len(chunk)]:
                h.record(int(it))
        if deltas is not None and len(deltas):
            self.metrics.gauge("route.last_final_delta",
                               route=route.name).set(
                float(np.max(deltas[:len(chunk)])))
        if route.cacheable and self.cache_size > 0:
            for lane, p in enumerate(chunk):
                if p.request_id in bad_ids:
                    continue    # poisoned centers must never enter the LRU
                fitted[p.key] = centers[lane]
                self._cache_put(p.key, centers[lane], p.hist)

    # -- cache -------------------------------------------------------------

    def _cache_get(self, key: bytes,
                   hist: Optional[np.ndarray] = None) -> Optional[np.ndarray]:
        if self.cache_size <= 0:
            return None
        entry = self._cache.get(key)
        if entry is not None:
            self._cache.move_to_end(key)
            return entry[0]
        if hist is None or self.cache_tol <= 0:
            return None
        # Nearest-match scan, most-recent first (the cache is small and a
        # 256-float L1 is trivial next to an FCM fit).
        q = self._normalize(hist)
        for k in reversed(self._cache):
            centers, dist = self._cache[k]
            if float(np.abs(dist - q).sum()) <= self.cache_tol:
                self._cache.move_to_end(k)
                return centers
        return None

    def _cache_put(self, key: bytes, centers: np.ndarray, hist: np.ndarray):
        if self.cache_size <= 0:
            return
        self._cache[key] = (np.asarray(centers), self._normalize(hist))
        self._cache.move_to_end(key)
        while len(self._cache) > self.cache_size:
            self._cache.popitem(last=False)

    # -- observability -----------------------------------------------------

    # Legacy per-route queue attributes (pre-registry API, still used by
    # tests and external monitors).
    @property
    def _queue(self) -> List[_Pending]:
        return self._queues["histogram"]

    @property
    def _pixel_queue(self) -> List[_PendingPixels]:
        return self._queues["pixel"]

    @property
    def _spatial_queue(self) -> List[_PendingSpatial]:
        return self._queues["spatial"]

    @property
    def _superpixel_queue(self) -> List[_PendingSuperpixel]:
        return self._queues["superpixel"]

    @property
    def queue_depth(self) -> int:
        return sum(len(q) for q in self._queues.values())

    def stats(self) -> Dict[str, Any]:
        """The flat legacy stat keys (rendered from the metrics
        registry — the registry is the single source of truth) plus the
        per-route ``latency`` (submit->result percentiles) and
        ``convergence`` (iterations-to-converge) blocks. Everything in
        the returned dict is plain JSON-serializable."""
        s: Dict[str, Any] = {}
        s["requests"] = self.metrics.counter("requests").snapshot()
        s["cache_hits"] = self.metrics.counter("cache_hits").snapshot()
        for route in ROUTES.values():
            s[route.stat("seconds")] = \
                self._stage_seconds(route.name, "solve").snapshot()
            s[route.stat("ingest")] = \
                self._stage_seconds(route.name, "ingest").snapshot()
            s[route.stat("materialize")] = \
                self._stage_seconds(route.name, "materialize").snapshot()
            s[route.stat("compress")] = \
                self._stage_seconds(route.name, "compress").snapshot()
            for k in ("batches", "images", "padded", "iters"):
                s[route.stat(k)] = \
                    self._route_counter(k, route.name).snapshot()
        # Legacy aggregates: the pre-registry spatial counter, and
        # compress_seconds summed over routes (historically one global
        # key written by superpixel ingest; now per-route stage time).
        if "spatial" in ROUTES:
            s["spatial_requests"] = \
                self._route_counter("requests", "spatial").snapshot()
        s["compress_seconds"] = sum(
            self._stage_seconds(r.name, "compress").snapshot()
            for r in ROUTES.values())
        s["queue_depth"] = self.queue_depth
        s["cache_entries"] = len(self._cache)
        # Per-route request/cache-hit mix (only cacheable routes can hit,
        # but the dashboards want every column).
        s["method_requests"] = {
            r.name: self._route_counter("requests", r.name).snapshot()
            for r in ROUTES.values()}
        s["method_cache_hits"] = {
            r.name: self._route_counter("cache_hits", r.name).snapshot()
            for r in ROUTES.values()}
        # Hit rate over cacheable traffic only — the bypass routes must
        # not dilute it.
        cacheable = sum(s["method_requests"][r.name]
                        for r in ROUTES.values() if r.cacheable)
        s["cache_hit_rate"] = (s["cache_hits"] / cacheable
                               if cacheable else 0.0)
        fit_s = s.get("fit_seconds", 0.0)
        s["images_per_sec"] = (s.get("batched_images", 0) / fit_s
                               if fit_s > 0 else 0.0)
        # Per-route stage breakdown (ingest = submit validation + flush
        # stacking, solve = the device dispatch, materialize = unpack /
        # per-request labeling) — what overhead regressions page on.
        s["stage_seconds"] = {
            r.name: {"ingest": s[r.stat("ingest")],
                     "solve": s[r.stat("seconds")],
                     "materialize": s[r.stat("materialize")]}
            for r in ROUTES.values()}
        s["compiled_programs"] = len(self._programs)
        # Per-route submit->result latency percentiles and convergence
        # mix — the two new observability blocks.
        s["latency"] = {r.name: self._latency_hist(r.name).snapshot()
                        for r in ROUTES.values()}
        s["convergence"] = {}
        for r in ROUTES.values():
            h = self._iters_hist(r.name)
            g = self.metrics.peek("route.last_final_delta", route=r.name)
            s["convergence"][r.name] = {
                "lanes": h.count,
                "mean_iters": h.mean,
                "p50_iters": h.quantile(0.50),
                "p99_iters": h.quantile(0.99),
                "last_final_delta": g.snapshot() if g else None,
            }
        # Admission telemetry: live queue depths, per-launch batch
        # occupancy (real lanes / bucket), deadline misses, and the
        # count of futures still awaiting results.
        s["queue_depth_by_route"] = {
            r.name: len(self._queues.get(r.name, ()))
            for r in ROUTES.values()}
        s["batch_occupancy"] = {
            r.name: self._occupancy_hist(r.name).snapshot()
            for r in ROUTES.values()}
        s["deadline_expired"] = {
            r.name: self._route_counter("deadline_expired",
                                        r.name).snapshot()
            for r in ROUTES.values()}
        s["pending_futures"] = len(self._futures)
        # Fault-tolerance telemetry: the graceful-degradation ladder's
        # per-route counters plus breaker state and flusher health.
        with self._lock:
            breaker_state = {name: b["state"]
                             for name, b in self._breakers.items()}
        s["fault_tolerance"] = {
            "retries": {r.name: self._route_counter(
                "retries", r.name).snapshot() for r in ROUTES.values()},
            "shed": {r.name: self._route_counter(
                "shed", r.name).snapshot() for r in ROUTES.values()},
            "salvaged": {r.name: self._route_counter(
                "salvaged", r.name).snapshot() for r in ROUTES.values()},
            "degraded": {r.name: self._route_counter(
                "degraded", r.name).snapshot() for r in ROUTES.values()},
            "breaker_trips": {r.name: self._route_counter(
                "breaker_trips", r.name).snapshot()
                for r in ROUTES.values()},
            "invalid_input": {r.name: self._route_counter(
                "invalid_input", r.name).snapshot()
                for r in ROUTES.values()},
            "breaker_state": breaker_state,
            "flusher_restarts":
                self.metrics.counter("flusher.restarts").snapshot(),
            "flusher_kills": self._flusher_kills,
        }
        s["faults"] = (self._faults.snapshot() if self._faults is not None
                       else FI.clean_snapshot())
        return obs.json_safe(s)

    def healthy(self) -> bool:
        """Liveness: no route breaker stuck open AND (if async traffic
        is in flight) the flusher thread is alive. A tripped breaker is
        *degraded* — requests still complete via the reference fallback
        — so it flips readiness, not liveness; ``healthy()`` is False
        only when async requests are pending with no live flusher to
        drain them (and none can be restarted because we're shut down)."""
        with self._lock:
            if self._closed:
                return False
            if sum(self._async_n.values()) > 0 and (
                    self._flusher is None
                    or not self._flusher.is_alive()):
                # submit_async re-ensures the flusher, so a dead thread
                # here is only unhealthy once restarts are impossible.
                return False
        return True

    def readiness(self) -> Dict[str, Any]:
        """One JSON-safe health snapshot for probes: overall liveness,
        per-route breaker state, flusher aliveness/restarts, and queue
        pressure against the overload limit."""
        with self._lock:
            breaker_state = {r.name: self._breaker(r.name)["state"]
                             for r in ROUTES.values()}
            flusher_alive = (self._flusher is not None
                             and self._flusher.is_alive())
            depth = self._qtotal
        return obs.json_safe({
            "healthy": self.healthy(),
            "ready": not self._closed
            and all(st != "open" for st in breaker_state.values()),
            "breaker_state": breaker_state,
            "flusher_alive": flusher_alive,
            "flusher_restarts":
                self.metrics.counter("flusher.restarts").snapshot(),
            "flusher_kills": self._flusher_kills,
            "queue_depth": depth,
            "max_queue_depth": self.max_queue_depth,
        })

    def reset_stats(self) -> None:
        """Zero every counter/gauge/histogram and drop the trace ring;
        registered metric keys survive so the stats schema is unchanged
        after a reset (dashboards keep their columns)."""
        self.metrics.reset()
        self.tracer.clear()
        self._submit_t.clear()

    def snapshot(self) -> Dict[str, Any]:
        """One JSON-serializable observability dump: the stats dict, the
        raw metrics registry, and the recent flush traces."""
        return obs.json_safe({
            "stats": self.stats(),
            "metrics": self.metrics.snapshot(),
            "traces": self.tracer.traces(),
        })
