"""Segmentation serving: the route-registry engine + async admission.

``FCMServeEngine`` is the batching front door (sync ``submit``/``flush``
and async ``submit_async`` -> :class:`SegmentationFuture`); the LM
``ServeEngine`` moved to :mod:`repro.launch.serve` and is re-exported
here lazily (with a DeprecationWarning via ``repro.serving.engine``)
for old call sites.
"""
from . import fcm_engine  # noqa: F401
from .admission import (DeadlineExceeded, EngineShutdown,  # noqa: F401
                        InvalidInput, Overloaded, SegmentationFuture,
                        SolveFailed)
from .fcm_engine import FCMServeEngine, SegmentationResult  # noqa: F401


def __getattr__(name):
    # Lazy deprecated re-exports: importing repro.serving must not warn
    # (or pull the LM stack in) unless the legacy names are touched.
    if name == "ServeEngine":
        import warnings
        warnings.warn(
            "repro.serving.ServeEngine is deprecated: import it from "
            "repro.launch.serve", DeprecationWarning, stacklevel=2)
        from repro.launch.serve import ServeEngine
        return ServeEngine
    if name == "engine":
        from . import engine
        return engine
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
