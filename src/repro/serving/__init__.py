from . import engine  # noqa: F401
from .engine import ServeEngine  # noqa: F401
