from . import engine, fcm_engine  # noqa: F401
from .engine import ServeEngine  # noqa: F401
from .fcm_engine import FCMServeEngine, SegmentationResult  # noqa: F401
