"""Async admission for the serving engine: futures, deadlines, shutdown.

The synchronous front door (``submit`` then ``flush``) couples every
caller to the engine's batching cadence: a caller that wants one result
either flushes a batch of one (paying the whole dispatch for a single
lane) or waits for somebody else to flush. The admission layer decouples
them — ``FCMServeEngine.submit_async`` parks the request on the same
per-route queues and hands back a :class:`SegmentationFuture`; a
background flusher thread forms batches by the engine's policy (flush
when a bucket group reaches its target shape, or when the oldest queued
request has waited ``max_wait_ms``) and resolves futures as results
materialize. Continuous batching is where the throughput comes from:
concurrent callers share one RouteProgram dispatch instead of serializing
one-lane flushes.

This module is deliberately engine-agnostic plumbing: the future and
the typed admission/fault errors, nothing else. The queueing policy
lives on the engine (it owns the queues, buckets and programs).
"""
from __future__ import annotations

import threading
import time
from typing import Any, Optional

__all__ = ["SegmentationFuture", "DeadlineExceeded", "EngineShutdown",
           "InvalidInput", "Overloaded", "SolveFailed"]


class DeadlineExceeded(RuntimeError):
    """The request's deadline passed before its result materialized."""


class EngineShutdown(RuntimeError):
    """The engine was shut down with this request still pending (or a
    submit arrived after shutdown)."""


class InvalidInput(ValueError):
    """The payload was rejected at submit time (NaN/Inf floats, empty
    image) — before consuming a request id or poisoning a shared batch."""


class Overloaded(RuntimeError):
    """Shed under queue-depth overload: the engine failed this request
    (lowest urgency) fast rather than blowing deadlines for everyone."""


class SolveFailed(RuntimeError):
    """The solve produced non-finite centers even after the reference-
    backend salvage pass — the per-request terminal numerical error."""


class SegmentationFuture:
    """One async segmentation request's pending result.

    Resolved exactly once — by the flusher thread, a synchronous
    ``flush``/``drain``, or engine shutdown — with either a
    :class:`~repro.serving.fcm_engine.SegmentationResult` or an
    exception. ``result(timeout)`` blocks; ``done()`` polls. Timestamps
    (``submit_t``/``resolve_t``, ``time.perf_counter`` seconds) ride
    along so load generators can compute submit->result latency without
    wrapping the API.
    """

    __slots__ = ("request_id", "method", "deadline", "submit_t",
                 "resolve_t", "_lock", "_event", "_result", "_error")

    def __init__(self, request_id: int, method: str,
                 deadline: Optional[float] = None):
        self.request_id = request_id
        self.method = method
        #: absolute deadline on the perf_counter clock, or None
        self.deadline = deadline
        self.submit_t = time.perf_counter()
        self.resolve_t: Optional[float] = None
        self._lock = threading.Lock()
        self._event = threading.Event()
        self._result: Any = None
        self._error: Optional[BaseException] = None

    # -- resolution (engine side) ------------------------------------------

    def try_set_result(self, result: Any) -> bool:
        """Atomically resolve with a result; False if already resolved.
        The race-safe face ``set_result`` and the engine's concurrent
        resolvers (flusher vs shutdown vs sync flush) build on — the
        check-and-set is one critical section, so two racing resolvers
        can never both win (or both raise)."""
        with self._lock:
            if self._event.is_set():
                return False
            self._result = result
            self.resolve_t = time.perf_counter()
            self._event.set()
            return True

    def try_set_exception(self, err: BaseException) -> bool:
        """Atomically resolve with an exception; False if already
        resolved."""
        with self._lock:
            if self._event.is_set():
                return False
            self._error = err
            self.resolve_t = time.perf_counter()
            self._event.set()
            return True

    def set_result(self, result: Any) -> None:
        if not self.try_set_result(result):
            raise RuntimeError(
                f"future for request {self.request_id} resolved twice")

    def set_exception(self, err: BaseException) -> None:
        if not self.try_set_exception(err):
            raise RuntimeError(
                f"future for request {self.request_id} resolved twice")

    # -- readout (caller side) ---------------------------------------------

    def done(self) -> bool:
        return self._event.is_set()

    def exception(self) -> Optional[BaseException]:
        """The resolving exception, or None; does not block."""
        return self._error

    def result(self, timeout: Optional[float] = None) -> Any:
        """Block until resolved (or ``timeout`` seconds), then return the
        result or raise the resolving exception. Raises ``TimeoutError``
        if still unresolved at the timeout."""
        if not self._event.wait(timeout):
            raise TimeoutError(
                f"request {self.request_id} unresolved after {timeout}s")
        if self._error is not None:
            raise self._error
        return self._result

    @property
    def latency_s(self) -> Optional[float]:
        """submit->resolve wall seconds, or None while pending."""
        if self.resolve_t is None:
            return None
        return self.resolve_t - self.submit_t

    def __repr__(self) -> str:
        state = ("error" if self._error is not None
                 else "done" if self._event.is_set() else "pending")
        return (f"SegmentationFuture(id={self.request_id}, "
                f"method={self.method!r}, {state})")
