"""Batched serving engine: prefill + greedy/temperature decode loop over
the jitted ``lm.decode_step`` (the serve_step the dry-run lowers).
"""
from __future__ import annotations

from functools import partial
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import lm


class ServeEngine:
    """Static-batch engine: one prefill for the whole batch, then
    step-synchronous decode. ``max_len`` bounds the KV cache."""

    def __init__(self, cfg: ModelConfig, params, max_len: int,
                 batch_size: int):
        self.cfg = cfg
        self.params = params
        self.max_len = max_len
        self.batch_size = batch_size
        self._prefill = jax.jit(
            lambda p, t, c, kw: lm.prefill(p, t, c, cfg, **kw))
        self._step = jax.jit(
            lambda p, t, c, pos: lm.decode_step(p, t, c, pos, cfg))

    def generate(self, prompts: np.ndarray, n_new: int,
                 temperature: float = 0.0, seed: int = 0,
                 extra_inputs: Optional[Dict] = None) -> np.ndarray:
        """prompts (B, P) int32 -> (B, P + n_new) int32."""
        b, plen = prompts.shape
        assert b == self.batch_size
        assert plen + n_new <= self.max_len
        cache = lm.init_cache(self.cfg, b, self.max_len)
        logits, cache = self._prefill(self.params, jnp.asarray(prompts),
                                      cache, extra_inputs or {})
        key = jax.random.PRNGKey(seed)
        out = [jnp.asarray(prompts)]
        tok = self._sample(logits, temperature, key)
        out.append(tok)
        for i in range(1, n_new):
            pos = plen + i - 1
            logits, cache = self._step(self.params, tok, cache, pos)
            key, sub = jax.random.split(key)
            tok = self._sample(logits, temperature, sub)
            out.append(tok)
        return np.asarray(jnp.concatenate(out, axis=1))

    @staticmethod
    def _sample(logits, temperature, key):
        last = logits[:, -1]
        if temperature <= 0.0:
            return jnp.argmax(last, axis=-1).astype(jnp.int32)[:, None]
        return jax.random.categorical(
            key, last / temperature, axis=-1).astype(jnp.int32)[:, None]
