"""DEPRECATED shim — the LM :class:`ServeEngine` moved to
:mod:`repro.launch.serve` (its launcher's home), leaving this package to
the segmentation serving stack (:mod:`repro.serving.fcm_engine` +
:mod:`repro.serving.admission`). Import from ``repro.launch.serve``.
"""
from __future__ import annotations

import warnings

from repro.launch.serve import ServeEngine  # noqa: F401

warnings.warn(
    "repro.serving.engine is deprecated: ServeEngine moved to "
    "repro.launch.serve (this shim re-exports it and will be removed)",
    DeprecationWarning, stacklevel=2)
