# Launchers. NOTE: dryrun.py must be imported/run as the process entry
# (it sets XLA_FLAGS before jax init); do not import it from library code.
from . import mesh  # noqa: F401
