import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: AOT-lower + compile every (arch x input-shape) cell
on the production meshes (16,16) and (2,16,16), print memory/cost
analysis, parse collective traffic from the partitioned HLO, and append
roofline records to a JSONL the benchmarks/EXPERIMENTS.md read.

The two lines above MUST precede every other import (jax locks the device
count on first init); only this entry point sees 512 fake devices.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch all --mesh both
  PYTHONPATH=src python -m repro.launch.dryrun --arch mistral-nemo-12b \
      --shape train_4k --mesh single --force
"""
import argparse      # noqa: E402
import dataclasses   # noqa: E402
import json          # noqa: E402
import sys           # noqa: E402
import time          # noqa: E402
import traceback     # noqa: E402

import jax           # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro import configs                      # noqa: E402
from repro.analysis import hw, roofline        # noqa: E402
from repro.core import distributed as fcm_dist  # noqa: E402
from repro.core.fcm import FCMConfig           # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.models import lm                    # noqa: E402
from repro.models import sharding as sh        # noqa: E402
from repro.training import train_loop as tl    # noqa: E402

DEFAULT_OUT = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                           "experiments", "dryrun.jsonl")


def _sds(tree, shardings):
    """Abstract tree + sharding tree -> ShapeDtypeStruct-with-sharding."""
    return jax.tree_util.tree_map(
        lambda a, s: jax.ShapeDtypeStruct(a.shape, a.dtype, sharding=s),
        tree, shardings)


def _abstract_batch(cfg, shape):
    b, s = shape.global_batch, shape.seq_len
    out = {"tokens": jax.ShapeDtypeStruct((b, s), jnp.int32),
           "labels": jax.ShapeDtypeStruct((b, s), jnp.int32)}
    if cfg.is_encdec:
        out["frames"] = jax.ShapeDtypeStruct((b, s, cfg.d_model),
                                             jnp.float32)
    if cfg.n_img_tokens:
        out["image_embeds"] = jax.ShapeDtypeStruct(
            (b, cfg.n_img_tokens, cfg.d_model), jnp.float32)
    return out


# At-scale training config for the dry-run: bf16 Adam moments (fp32
# master weights kept) — the realistic memory budget for 100B+ on v5e.
TRAIN_CFG = tl.TrainConfig(
    optimizer=tl.opt.OptimizerConfig(moment_dtype="bfloat16"))

# deeper grad-accumulation for the giant configs (activation footprint)
MICROBATCH_OVERRIDE = {"deepseek-v2-236b": 16, "mistral-large-123b": 16,
                       "llama-3.2-vision-90b": 16}


def input_specs(cfg, shape, ctx):
    """ShapeDtypeStruct stand-ins (weak-type-correct, shardable, no
    allocation) for every input of this cell's step function."""
    b, s = shape.global_batch, shape.seq_len
    aparams = lm.abstract_params(cfg)
    pshard = sh.to_named_shardings(aparams, lm.param_specs(cfg), ctx)

    if shape.kind == "train":
        astate = tl.abstract_state(cfg, TRAIN_CFG)
        sshard = sh.to_named_shardings(astate, tl.state_specs(cfg), ctx)
        abatch = _abstract_batch(cfg, shape)
        bshard = sh.to_named_shardings(abatch, tl.batch_specs(cfg), ctx)
        return (_sds(astate, sshard), _sds(abatch, bshard)), sshard

    acache = jax.eval_shape(lambda: lm.init_cache(cfg, b, s))
    cshard = sh.to_named_shardings(acache, lm.cache_specs(cfg), ctx)
    cache_sds = _sds(acache, cshard)

    def dp_sharding(shape_tuple):
        spec = sh.prune_spec(ctx.pspec(*(("dp",) + (None,) *
                                         (len(shape_tuple) - 1))),
                             shape_tuple, ctx.mesh)
        return jax.sharding.NamedSharding(ctx.mesh, spec)

    if shape.kind == "prefill":
        tok = jax.ShapeDtypeStruct((b, s), jnp.int32,
                                   sharding=dp_sharding((b, s)))
        extra = {}
        if cfg.is_encdec:
            extra["frames"] = jax.ShapeDtypeStruct(
                (b, s, cfg.d_model), jnp.float32,
                sharding=dp_sharding((b, s, cfg.d_model)))
        if cfg.n_img_tokens:
            extra["memory"] = jax.ShapeDtypeStruct(
                (b, cfg.n_img_tokens, cfg.d_model), cfg.dtype,
                sharding=dp_sharding((b, cfg.n_img_tokens, cfg.d_model)))
        return (_sds(aparams, pshard), tok, cache_sds, extra), cshard

    # decode: one new token against a seq_len cache
    tok = jax.ShapeDtypeStruct((b, 1), jnp.int32,
                               sharding=dp_sharding((b, 1)))
    pos = jax.ShapeDtypeStruct((), jnp.int32)
    return (_sds(aparams, pshard), tok, cache_sds, pos), cshard


def lower_cell(cfg, shape, mesh, ctx):
    """Returns (lowered, out_shardings_hint)."""
    if shape.kind == "train":
        (state_sds, batch_sds), sshard = input_specs(cfg, shape, ctx)
        step = tl.make_train_step(cfg, TRAIN_CFG)
        fn = jax.jit(step, out_shardings=(sshard, None),
                     donate_argnums=(0,))          # state updated in place
        return fn.lower(state_sds, batch_sds)
    if shape.kind == "prefill":
        (p_sds, tok, cache_sds, extra), cshard = input_specs(cfg, shape, ctx)
        fn = jax.jit(lambda p, t, c, kw: lm.prefill(p, t, c, cfg, **kw),
                     out_shardings=(None, cshard),
                     donate_argnums=(2,))          # cache updated in place
        return fn.lower(p_sds, tok, cache_sds, extra)
    (p_sds, tok, cache_sds, pos), cshard = input_specs(cfg, shape, ctx)
    fn = jax.jit(lambda p, t, c, i: lm.decode_step(p, t, c, i, cfg),
                 out_shardings=(None, cshard), donate_argnums=(2,))
    return fn.lower(p_sds, tok, cache_sds, pos)


FCM_SHAPE = configs.ShapeConfig("fcm_1g", "fcm", 1 << 30, 1)


def lower_fcm(mesh, ctx):
    n = FCM_SHAPE.seq_len                       # 1 Gi voxels
    spec = jax.sharding.NamedSharding(
        mesh, jax.sharding.PartitionSpec(tuple(mesh.axis_names)))
    x = jax.ShapeDtypeStruct((n,), jnp.float32, sharding=spec)
    w = jax.ShapeDtypeStruct((n,), jnp.float32, sharding=spec)
    fit = fcm_dist.build_sharded_fit(mesh, FCMConfig())
    return fit.lower(x, w)


def run_cell(arch, shape, multi_pod, verbose=True, microbatches=8):
    mesh = make_production_mesh(multi_pod=multi_pod)
    ctx = sh.make_parallelism(mesh)
    label = "2x16x16" if multi_pod else "16x16"
    t0 = time.time()
    with mesh, sh.parallelism(ctx):
        if arch == "fcm-brainweb":
            cfg, sh_obj = None, FCM_SHAPE
            lowered = lower_fcm(mesh, ctx)
            shape = FCM_SHAPE
        else:
            cfg = configs.get_config(arch)
            if shape.kind == "train":
                mb = MICROBATCH_OVERRIDE.get(arch, microbatches)
                if mb > 1:
                    cfg = dataclasses.replace(cfg, microbatches=mb)
            lowered = lower_cell(cfg, shape, mesh, ctx)
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0
    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    text = compiled.as_text()
    # FCM's while-loop is data-dependent convergence, not a scan: report
    # per-iteration roofline terms (override trip counts to 1).
    rep = roofline.analyze(arch, shape, label, mesh.size, cost, mem,
                           text, cfg,
                           while_override=1 if arch == "fcm-brainweb"
                           else None)
    rec = rep.row()
    rec.update(lower_s=round(t_lower, 2), compile_s=round(t_compile, 2),
               hlo_bytes=len(text))
    if verbose:
        print(f"  memory_analysis: args={rep.mem_args_gb:.3f}GiB "
              f"temp={rep.mem_temp_gb:.3f}GiB out={rep.mem_out_gb:.3f}GiB "
              f"fits_hbm={rep.fits_hbm}")
        print(f"  cost_analysis: flops/dev={rep.flops_per_dev:.3e} "
              f"bytes/dev={rep.bytes_per_dev:.3e}")
        print(f"  collectives: wire={rep.wire_bytes:.3e}B "
              f"terms (s): compute={rep.t_compute:.4f} "
              f"memory={rep.t_memory:.4f} coll={rep.t_collective:.4f} "
              f"-> {rep.bottleneck}-bound")
    return rec


def cells(arch_filter, shape_filter):
    for arch in configs.list_archs() + ["fcm-brainweb"]:
        if arch_filter != "all" and arch not in arch_filter.split(","):
            continue
        if arch == "fcm-brainweb":
            yield arch, FCM_SHAPE
            continue
        cfg = configs.get_config(arch)
        for s in configs.applicable_shapes(cfg):
            if shape_filter != "all" and s.name not in shape_filter.split(","):
                continue
            yield arch, s


def load_done(path):
    done = set()
    if os.path.exists(path):
        with open(path) as f:
            for line in f:
                try:
                    r = json.loads(line)
                    done.add((r["arch"], r["shape"], r["mesh"]))
                except json.JSONDecodeError:
                    pass
    return done


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="both",
                    choices=["single", "multi", "both"])
    ap.add_argument("--out", default=os.path.normpath(DEFAULT_OUT))
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--list", action="store_true")
    ap.add_argument("--microbatches", type=int, default=8,
                    help="grad-accum microbatches for train cells")
    args = ap.parse_args(argv)

    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]
    todo = [(a, s, mp) for a, s in cells(args.arch, args.shape)
            for mp in meshes]
    if args.list:
        for a, s, mp in todo:
            print(a, s.name, "2x16x16" if mp else "16x16")
        return 0

    os.makedirs(os.path.dirname(args.out), exist_ok=True)
    done = set() if args.force else load_done(args.out)
    failures = []
    for arch, shape, mp in todo:
        label = "2x16x16" if mp else "16x16"
        key = (arch, shape.name, label)
        if key in done:
            print(f"[skip] {arch} x {shape.name} x {label}")
            continue
        print(f"[cell] {arch} x {shape.name} x {label}")
        try:
            rec = run_cell(arch, shape, mp, microbatches=args.microbatches)
            with open(args.out, "a") as f:
                f.write(json.dumps(rec) + "\n")
            print(f"  ok (lower {rec['lower_s']}s compile "
                  f"{rec['compile_s']}s)")
        except Exception as e:
            failures.append((key, repr(e)))
            traceback.print_exc()
    if failures:
        print(f"\n{len(failures)} FAILED cells:")
        for k, e in failures:
            print(" ", k, e)
        return 1
    print("\nall cells OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
