"""Production meshes.

Defined as FUNCTIONS (never module-level constants) so importing this
module never touches jax device state — the dry-run must set its
XLA_FLAGS before the first jax initialization.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: (16, 16) = 256 chips, axes (data, model).
    Multi-pod:  (2, 16, 16) = 512 chips, axes (pod, data, model)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes,
        axis_types=(jax.sharding.AxisType.Auto,) * len(axes))
