"""Serving launcher: loads (or random-inits) params for an arch, then
runs batched generation through the ServeEngine.

  PYTHONPATH=src python -m repro.launch.serve --arch llama3.2-1b \
      --reduced --batch 4 --prompt-len 16 --new-tokens 32
"""
from __future__ import annotations

import argparse
import sys

import numpy as np
import jax

from repro import configs
from repro.models import lm
from repro.serving import ServeEngine
from repro.training import checkpoint as ckpt


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=configs.list_archs())
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--new-tokens", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--ckpt-dir", default=None)
    args = ap.parse_args(argv)

    cfg = configs.get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    if args.ckpt_dir:
        state, _ = ckpt.load_checkpoint(
            args.ckpt_dir, {"params": params})
        params = state["params"]

    rng = np.random.default_rng(0)
    prompts = rng.integers(0, cfg.vocab_size,
                           (args.batch, args.prompt_len)).astype(np.int32)
    extra = {}
    if cfg.n_img_tokens:
        extra["memory"] = jax.numpy.asarray(rng.standard_normal(
            (args.batch, cfg.n_img_tokens, cfg.d_model)), cfg.dtype)
    if cfg.is_encdec:
        extra["frames"] = jax.numpy.asarray(rng.standard_normal(
            (args.batch, args.prompt_len, cfg.d_model)),
            jax.numpy.float32)

    engine = ServeEngine(cfg, params,
                         max_len=args.prompt_len + args.new_tokens,
                         batch_size=args.batch)
    out = engine.generate(prompts, args.new_tokens, args.temperature,
                          extra_inputs=extra)
    for b in range(args.batch):
        print(f"[{b}] prompt={prompts[b, :6].tolist()}... "
              f"-> {out[b, args.prompt_len:args.prompt_len + 12].tolist()}...")
    print(f"generated {args.batch}x{args.new_tokens} tokens")
    return 0


if __name__ == "__main__":
    sys.exit(main())
