"""LM serving: the static-batch token engine + its CLI launcher.

:class:`ServeEngine` (prefill + step-synchronous decode over the jitted
``lm.decode_step``) lives here with its launcher — it serves the LM side
of the repo and shares nothing with the image-segmentation serving stack
(``repro.serving.fcm_engine``), which owns the route registry, async
admission, and mesh dispatch. ``repro.serving.ServeEngine`` remains as a
deprecated re-export.

  PYTHONPATH=src python -m repro.launch.serve --arch llama3.2-1b \
      --reduced --batch 4 --prompt-len 16 --new-tokens 32
"""
from __future__ import annotations

import argparse
import sys
from typing import Dict, Optional

import numpy as np
import jax
import jax.numpy as jnp

from repro import configs
from repro.configs.base import ModelConfig
from repro.models import lm
from repro.training import checkpoint as ckpt


class ServeEngine:
    """Static-batch engine: one prefill for the whole batch, then
    step-synchronous decode. ``max_len`` bounds the KV cache."""

    def __init__(self, cfg: ModelConfig, params, max_len: int,
                 batch_size: int):
        self.cfg = cfg
        self.params = params
        self.max_len = max_len
        self.batch_size = batch_size
        self._prefill = jax.jit(
            lambda p, t, c, kw: lm.prefill(p, t, c, cfg, **kw))
        self._step = jax.jit(
            lambda p, t, c, pos: lm.decode_step(p, t, c, pos, cfg))

    def generate(self, prompts: np.ndarray, n_new: int,
                 temperature: float = 0.0, seed: int = 0,
                 extra_inputs: Optional[Dict] = None) -> np.ndarray:
        """prompts (B, P) int32 -> (B, P + n_new) int32."""
        b, plen = prompts.shape
        assert b == self.batch_size
        assert plen + n_new <= self.max_len
        cache = lm.init_cache(self.cfg, b, self.max_len)
        logits, cache = self._prefill(self.params, jnp.asarray(prompts),
                                      cache, extra_inputs or {})
        key = jax.random.PRNGKey(seed)
        out = [jnp.asarray(prompts)]
        tok = self._sample(logits, temperature, key)
        out.append(tok)
        for i in range(1, n_new):
            pos = plen + i - 1
            logits, cache = self._step(self.params, tok, cache, pos)
            key, sub = jax.random.split(key)
            tok = self._sample(logits, temperature, sub)
            out.append(tok)
        return np.asarray(jnp.concatenate(out, axis=1))

    @staticmethod
    def _sample(logits, temperature, key):
        last = logits[:, -1]
        if temperature <= 0.0:
            return jnp.argmax(last, axis=-1).astype(jnp.int32)[:, None]
        return jax.random.categorical(
            key, last / temperature, axis=-1).astype(jnp.int32)[:, None]


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=configs.list_archs())
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--new-tokens", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--ckpt-dir", default=None)
    args = ap.parse_args(argv)

    cfg = configs.get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    if args.ckpt_dir:
        state, _ = ckpt.load_checkpoint(
            args.ckpt_dir, {"params": params})
        params = state["params"]

    rng = np.random.default_rng(0)
    prompts = rng.integers(0, cfg.vocab_size,
                           (args.batch, args.prompt_len)).astype(np.int32)
    extra = {}
    if cfg.n_img_tokens:
        extra["memory"] = jax.numpy.asarray(rng.standard_normal(
            (args.batch, cfg.n_img_tokens, cfg.d_model)), cfg.dtype)
    if cfg.is_encdec:
        extra["frames"] = jax.numpy.asarray(rng.standard_normal(
            (args.batch, args.prompt_len, cfg.d_model)),
            jax.numpy.float32)

    engine = ServeEngine(cfg, params,
                         max_len=args.prompt_len + args.new_tokens,
                         batch_size=args.batch)
    out = engine.generate(prompts, args.new_tokens, args.temperature,
                          extra_inputs=extra)
    for b in range(args.batch):
        print(f"[{b}] prompt={prompts[b, :6].tolist()}... "
              f"-> {out[b, args.prompt_len:args.prompt_len + 12].tolist()}...")
    print(f"generated {args.batch}x{args.new_tokens} tokens")
    return 0


if __name__ == "__main__":
    sys.exit(main())
