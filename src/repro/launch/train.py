"""Production training launcher.

Wires the full substrate: arch config + shape -> mesh (elastic-planned
from the visible device count) -> sharded train state -> deterministic
host-sharded data pipeline -> jitted train step (donated state) -> async
checkpoints + straggler watchdog + crash-restart loop.

On this container it runs real (small) configs on one CPU device; on a
pod it is launched once per host with the same arguments (jax
distributed init is picked up from the environment if present).

  PYTHONPATH=src python -m repro.launch.train --arch llama3.2-1b \
      --reduced --steps 50 --batch 8 --seq 128 --ckpt-dir /tmp/ck
"""
from __future__ import annotations

import argparse
import dataclasses
import os
import sys
import time

import jax
import jax.numpy as jnp

from repro import configs
from repro.data import pipeline
from repro.models import sharding as sh
from repro.training import checkpoint as ckpt
from repro.training import elastic
from repro.training import optimizer as opt
from repro.training import train_loop as tl


def build(cfg, tcfg, mesh, resume_dir=None):
    ctx = sh.make_parallelism(mesh)
    with sh.parallelism(ctx):
        astate = tl.abstract_state(cfg, tcfg)
        shardings = sh.to_named_shardings(astate, tl.state_specs(cfg), ctx)
        if resume_dir and ckpt.latest_step(resume_dir) is not None:
            state, manifest = ckpt.load_checkpoint(
                resume_dir, astate, shardings=shardings)
            start = manifest["step"]
        else:
            state = tl.init_state(jax.random.PRNGKey(0), cfg, tcfg)
            if mesh is not None:
                state = jax.tree_util.tree_map(jax.device_put, state,
                                               shardings)
            start = 0
        step_fn = jax.jit(tl.make_train_step(cfg, tcfg),
                          donate_argnums=(0,))
    return state, step_fn, ctx, start


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=configs.list_archs())
    ap.add_argument("--reduced", action="store_true",
                    help="CPU-sized config of the same family")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--compress-cross-pod", action="store_true")
    ap.add_argument("--max-restarts", type=int, default=2,
                    help="crash-restart attempts (fault tolerance)")
    args = ap.parse_args(argv)

    cfg = configs.get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    cfg = dataclasses.replace(cfg, microbatches=args.microbatches)
    shape = configs.ShapeConfig("train", "train", args.seq, args.batch)
    tcfg = tl.TrainConfig(
        optimizer=opt.OptimizerConfig(lr=args.lr, warmup_steps=20,
                                      total_steps=args.steps),
        compress_cross_pod=args.compress_cross_pod)

    mesh = elastic.plan_mesh(len(jax.devices())) \
        if len(jax.devices()) > 1 else None
    print(f"arch={cfg.name} devices={len(jax.devices())} "
          f"mesh={mesh.shape if mesh else None}")

    restarts = 0
    while True:
        try:
            state, step_fn, ctx, start = build(cfg, tcfg, mesh,
                                               args.ckpt_dir)
            saver = (ckpt.AsyncCheckpointer(args.ckpt_dir)
                     if args.ckpt_dir else None)
            timer = elastic.StepTimer()
            with sh.parallelism(ctx):
                for i, batch in enumerate(
                        pipeline.batches(cfg, shape, start)):
                    step = start + i
                    if step >= args.steps:
                        break
                    timer.start()
                    state, metrics = step_fn(
                        state,
                        {k: jnp.asarray(v) for k, v in batch.items()})
                    slow = timer.stop()
                    if step % 10 == 0 or step == args.steps - 1:
                        print(f"step {step:5d} "
                              f"loss={float(metrics['loss']):.4f} "
                              f"gnorm={float(metrics['grad_norm']):.2f}"
                              + (" [straggler]" if slow else ""))
                    if saver and step and step % args.ckpt_every == 0:
                        saver.save(state, step)
            if saver:
                saver.save(state, int(state["step"]))
                saver.wait()
            print("training complete")
            return 0
        except Exception as e:                          # noqa: BLE001
            restarts += 1
            if restarts > args.max_restarts or not args.ckpt_dir:
                raise
            print(f"[fault] {e!r}; restart {restarts}/"
                  f"{args.max_restarts} from latest checkpoint",
                  file=sys.stderr)
            time.sleep(1.0)


if __name__ == "__main__":
    sys.exit(main())
