"""repro: multi-pod JAX framework reproducing and extending
"GPU-Based Fuzzy C-Means Clustering Algorithm for Image Segmentation"
(Almazrooie, Vadiveloo, Abdullah, 2016). See DESIGN.md."""

__version__ = "1.0.0"
