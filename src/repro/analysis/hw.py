"""Target-hardware constants (TPU v5e pod), per the assignment."""

PEAK_FLOPS_BF16 = 197e12      # per chip, bf16
HBM_BW = 819e9                # bytes/s per chip
ICI_LINK_BW = 50e9            # bytes/s per link
HBM_BYTES = 16 * 2 ** 30      # 16 GiB per chip
CHIPS_PER_POD = 256           # 16 x 16 mesh


def dtype_bytes(name: str) -> int:
    return {
        "pred": 1, "s8": 1, "u8": 1, "s4": 1, "u4": 1,
        "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
        "s32": 4, "u32": 4, "f32": 4,
        "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
    }.get(name, 4)
