"""Trip-count-aware cost accounting over compiled (post-SPMD) HLO text.

XLA's ``compiled.cost_analysis()`` visits each while/scan body ONCE, so
anything under ``lax.scan`` (layer groups, microbatches, flash-attention
chunks, SSM time steps) is undercounted by its trip count — useless for
roofline on scanned models. This walker parses the HLO module into
computations with per-computation symbol tables (operand shapes are not
printed in compiled HLO, so references are resolved to their defining
ops), builds the call graph, extracts scan trip counts from while-loop
condition constants, and accumulates per-device:

  flops — 2*prod(out)*prod(contracting) for every dot (MXU terms;
          elementwise ignored; reduce counted at 1 flop/element)
  bytes — HBM traffic at fusion boundaries: resolved operand sizes +
          result size for every non-control top-level op (fusion
          internals excluded: fusions are XLA's memory-access units)
  wire  — collective wire bytes from output shapes + ring semantics:
          AR 2(n-1)/n * data, AG (n-1)/n * out, RS (n-1) * out,
          A2A (n-1)/n * data, permute 1 * out   (per participant)

Shapes in a post-SPMD module are per-device, so flops/bytes are
per-device; wire is per-participant and scaled to global by the caller.
Validated in tests/test_roofline.py against hand-counted programs.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

from . import hw

_SHAPE_RE = re.compile(r"\b(pred|[suf]\d+|bf16|c64|c128)\[([\d,]*)\]")
_OP_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w\.\-_]+)\s*=\s*(.*)$")
_OPNAME_RE = re.compile(r"\s([a-z][\w\-]*)\(")
_REF_RE = re.compile(r"%([\w\.\-_]+)")
_CONST_S32_RE = re.compile(r"s32\[\]\s+constant\((\d+)\)")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_HDR_PARAM_RE = re.compile(r"%?([\w\.\-_]+)\s*:\s*([^,)]+)")
_GROUPS_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")
_GROUPS_V2_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")

_CONTROL_OPS = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "after-all", "partition-id", "replica-id", "while", "conditional",
    "call", "copy-start", "copy-done", "async-start", "async-update",
    "async-done", "domain", "opt-barrier", "rng-bit-generator",
    "rng-get-and-update-state", "get-dimension-size",
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter",
                "all-to-all", "collective-permute")


def _nbytes(shape_text: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(shape_text):
        n = 1
        for d in m.group(2).split(","):
            if d:
                n *= int(d)
        total += n * hw.dtype_bytes(m.group(1))
    return total


def _elems(shape_text: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(shape_text):
        n = 1
        for d in m.group(2).split(","):
            if d:
                n *= int(d)
        total += n
    return total


def _split_rhs(rhs: str) -> Tuple[str, str, str, str]:
    """rhs -> (result_shape_text, opname, operand_text, attr_text)."""
    m = _OPNAME_RE.search(" " + rhs)
    if not m:
        return rhs, "", "", ""
    opname = m.group(1)
    start = m.end()                     # index in " "+rhs just past "("
    shape_text = rhs[:m.start(1) - 1]
    # find matching close paren
    depth = 1
    i = start - 1                        # rhs index of char after "("
    while i < len(rhs) and depth > 0:
        if rhs[i] == "(":
            depth += 1
        elif rhs[i] == ")":
            depth -= 1
        i += 1
    return shape_text, opname, rhs[start - 1:i - 1], rhs[i:]


@dataclasses.dataclass
class Costs:
    flops: float = 0.0
    bytes: float = 0.0
    wire_by_kind: Dict[str, float] = dataclasses.field(
        default_factory=lambda: {k: 0.0 for k in _COLLECTIVES})
    n_coll_ops: int = 0

    def scaled(self, k: float) -> "Costs":
        return Costs(self.flops * k, self.bytes * k,
                     {n: v * k for n, v in self.wire_by_kind.items()},
                     self.n_coll_ops)

    def add(self, o: "Costs"):
        self.flops += o.flops
        self.bytes += o.bytes
        for n, v in o.wire_by_kind.items():
            self.wire_by_kind[n] += v
        self.n_coll_ops += o.n_coll_ops

    @property
    def wire(self) -> float:
        return sum(self.wire_by_kind.values())


class HloCostModel:
    def __init__(self, hlo_text: str, n_devices: int,
                 while_override: Optional[int] = None):
        self.n_devices = n_devices
        self.while_override = while_override
        self.comps: Dict[str, List[str]] = {}
        self.symbols: Dict[str, Dict[str, str]] = {}
        self.roots: Dict[str, str] = {}      # computation -> root op name
        self.entry: Optional[str] = None
        self._parse(hlo_text)
        self._memo: Dict[Tuple[str, bool], Costs] = {}

    # -- parsing ------------------------------------------------------------

    def _parse(self, text: str):
        cur: Optional[str] = None
        for raw in text.splitlines():
            line = raw.rstrip()
            if not line:
                continue
            stripped = line.strip()
            if not line.startswith(" ") and "->" in line and \
                    stripped.endswith("{"):
                hdr = stripped
                is_entry = hdr.startswith("ENTRY")
                hdr = hdr[5:].strip() if is_entry else hdr
                name = hdr.split("(", 1)[0].strip().lstrip("%").strip()
                cur = name
                self.comps[cur] = []
                self.symbols[cur] = {}
                if is_entry:
                    self.entry = cur
                # header params carry shapes: "(%p: f32[8,16], ...)"
                paren = hdr[hdr.index("("):hdr.rindex("->")]
                for pm in _HDR_PARAM_RE.finditer(paren):
                    self.symbols[cur][pm.group(1)] = pm.group(2)
                continue
            if stripped == "}":
                cur = None
                continue
            if cur is None:
                continue
            self.comps[cur].append(stripped)
            m = _OP_RE.match(stripped)
            if m:
                shape_text, op, _, _ = _split_rhs(m.group(2))
                self.symbols[cur][m.group(1)] = shape_text
                if stripped.startswith("ROOT"):
                    self.roots[cur] = op

    def _trip_count(self, cond_name: str) -> int:
        if self.while_override is not None:
            return self.while_override
        consts = []
        for line in self.comps.get(cond_name, []):
            consts += [int(x) for x in _CONST_S32_RE.findall(line)]
        return max(consts) if consts else 1

    def _operand_bytes(self, comp: str, operand_text: str) -> int:
        total = _nbytes(operand_text)            # inline-typed operands
        if total:
            return total
        table = self.symbols.get(comp, {})
        for ref in _REF_RE.findall(operand_text):
            total += _nbytes(table.get(ref, ""))
        return total

    def _operand_shape(self, comp: str, ref_text: str) -> str:
        m = _SHAPE_RE.search(ref_text)
        if m:
            return ref_text
        refs = _REF_RE.findall(ref_text)
        if refs:
            return self.symbols.get(comp, {}).get(refs[0], "")
        return ""

    # -- accounting ----------------------------------------------------------

    def _dot_flops(self, comp: str, shape_text: str, operand_text: str,
                   attrs: str) -> float:
        out_elems = _elems(shape_text)
        ops = [o.strip() for o in self._top_split(operand_text)]
        if not ops:
            return 0.0
        lhs_shape = self._operand_shape(comp, ops[0])
        sm = _SHAPE_RE.search(lhs_shape)
        if sm is None:
            return 0.0
        lhs_dims = [int(d) for d in sm.group(2).split(",") if d]
        contract = 1
        cm = _CONTRACT_RE.search(attrs) or _CONTRACT_RE.search(operand_text)
        if cm:
            for i in cm.group(1).split(","):
                if i:
                    contract *= lhs_dims[int(i)]
        return 2.0 * out_elems * contract

    @staticmethod
    def _top_split(text: str) -> List[str]:
        out, depth, start = [], 0, 0
        for i, ch in enumerate(text):
            if ch in "([{":
                depth += 1
            elif ch in ")]}":
                depth -= 1
            elif ch == "," and depth == 0:
                out.append(text[start:i])
                start = i + 1
        if text[start:].strip():
            out.append(text[start:])
        return out

    def _group_size(self, line: str) -> int:
        m = _GROUPS_V2_RE.search(line)
        if m:
            return int(m.group(2))
        m = _GROUPS_RE.search(line)
        if m:
            return len([x for x in m.group(1).split(",")
                        if x.strip() != ""])
        return self.n_devices

    def _collective_wire(self, kind: str, shape_text: str, line: str) -> float:
        out_b = _nbytes(shape_text)
        n = self._group_size(line)
        if kind == "all-reduce":
            return 2.0 * (n - 1) / max(n, 1) * out_b
        if kind == "all-gather":
            return float(n - 1) / max(n, 1) * out_b
        if kind == "reduce-scatter":
            return float(n - 1) * out_b           # input = out * n
        if kind == "all-to-all":
            return float(n - 1) / max(n, 1) * out_b
        return float(out_b)                        # collective-permute

    def comp_cost(self, name: str, count_bytes: bool = True) -> Costs:
        key = (name, count_bytes)
        if key in self._memo:
            return self._memo[key]
        total = Costs()
        self._memo[key] = total
        for line in self.comps.get(name, []):
            m = _OP_RE.match(line)
            if not m:
                continue
            rhs = m.group(2)
            shape_text, op, operands, attrs = _split_rhs(rhs)
            if op == "while":
                bm = re.search(r"body=%?([\w\.\-_]+)", rhs)
                cm = re.search(r"condition=%?([\w\.\-_]+)", rhs)
                if bm and cm:
                    trips = self._trip_count(cm.group(1))
                    inner = Costs()
                    inner.add(self.comp_cost(bm.group(1), count_bytes))
                    inner.add(self.comp_cost(cm.group(1), count_bytes))
                    total.add(inner.scaled(trips))
                continue
            if op in ("call", "conditional"):
                for c in re.findall(
                        r"(?:to_apply|calls|branch_computations=\{)"
                        r"=?%?([\w\.\-_]+)", rhs):
                    total.add(self.comp_cost(c, count_bytes))
                continue
            if op == "fusion":
                cm = re.search(r"calls=%?([\w\.\-_]+)", rhs)
                root = self.roots.get(cm.group(1), "") if cm else ""
                if cm:
                    total.add(self.comp_cost(cm.group(1),
                                             count_bytes=False))
                if count_bytes:
                    if root == "bitcast":
                        pass  # pure layout view: no HBM traffic of its own
                    elif root == "dynamic-update-slice":
                        # in-place on the aliased (largest) operand: only
                        # the update slice is read+written
                        ob = [self._operand_bytes(name, o)
                              for o in self._top_split(operands)]
                        total.bytes += 2 * (sum(ob) - max(ob, default=0))
                    else:
                        total.bytes += (_nbytes(shape_text)
                                        + self._operand_bytes(name,
                                                              operands))
                continue
            if op == "dot":
                total.flops += self._dot_flops(name, shape_text, operands,
                                               attrs)
                if count_bytes:
                    total.bytes += (_nbytes(shape_text)
                                    + self._operand_bytes(name, operands))
                continue
            coll = next((c for c in _COLLECTIVES
                         if op == c or op == c + "-start"), None)
            if coll:
                total.wire_by_kind[coll] += self._collective_wire(
                    coll, shape_text, rhs)
                total.n_coll_ops += 1
                if count_bytes:
                    total.bytes += (_nbytes(shape_text)
                                    + self._operand_bytes(name, operands))
                continue
            if op in _CONTROL_OPS or not op:
                continue
            if op == "reduce" or op.startswith("reduce-window"):
                total.flops += self._operand_bytes(name, operands) / 4.0
                if count_bytes:
                    total.bytes += (_nbytes(shape_text)
                                    + self._operand_bytes(name, operands))
                continue
            # generic elementwise / data-movement op at fusion granularity
            if count_bytes:
                total.bytes += (_nbytes(shape_text)
                                + self._operand_bytes(name, operands))
        self._memo[key] = total
        return total

    def total(self) -> Costs:
        assert self.entry is not None, "no ENTRY computation found"
        return self.comp_cost(self.entry)


def analyze_text(hlo_text: str, n_devices: int,
                 while_override: Optional[int] = None) -> Costs:
    return HloCostModel(hlo_text, n_devices, while_override).total()
