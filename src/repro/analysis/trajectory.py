"""Perf-trajectory ledger over the committed ``BENCH_pr*.json`` line.

Every PR commits one consolidated benchmark record
(``benchmarks/out/BENCH_pr<N>.json``); this module is what makes that
sequence *legible to machines*: it loads the whole ledger, normalizes
each record into a named per-metric time series (absorbing schema
evolution — e.g. BENCH_pr4 predates the explicit
``engine_overhead_vs_batched`` key, so the metric derives it from
``engine_s / batched_s``), and exposes

* :func:`series`       — ``{metric: [(pr, value), ...]}`` across PRs,
* :func:`diff`         — per-metric regression verdicts between two
  records under a declarative :class:`Policy` (what ``benchmarks/run.py``
  fails CI through, replacing the old single hardcoded B=64 gate),
* :func:`resolve_baseline` — the newest committed record below the
  current PR, so no benchmark script hand-names its baseline file,
* :func:`render_trajectory` / :func:`render_frontier` — the ledger and
  the accuracy-vs-speed sweep rendered as figures.

Metric directions are explicit (``lower``/``higher`` is better) and each
metric carries both a relative slack (how much worsening vs the baseline
is noise) and optional absolute bounds (ceilings/floors that gate even
when baseline and current are not wall-clock comparable, e.g. a ``tiny``
CI run against a committed full-size record).
"""
from __future__ import annotations

import dataclasses
import glob
import json
import math
import os
import re
from typing import Callable, Dict, List, Optional, Sequence, Tuple

__all__ = ["Metric", "METRICS", "Policy", "Verdict", "DiffResult",
           "load_bench", "load_ledger", "series", "resolve_baseline",
           "diff", "render_trajectory", "render_frontier"]

#: Default ledger directory (benchmarks/out of this repo checkout).
OUT_DIR = os.path.normpath(os.path.join(
    os.path.dirname(__file__), "..", "..", "..", "benchmarks", "out"))

_BENCH_RE = re.compile(r"BENCH_pr(\d+)\.json$")


# ---------------------------------------------------------------------------
# Metric extractors (schema-evolution tolerant)
# ---------------------------------------------------------------------------

def _hist64(bench: dict) -> dict:
    h = bench.get("batched_throughput", {}).get("histogram", {})
    cell = h.get("64") or h.get(64)
    return cell if isinstance(cell, dict) else {}


def _spatial(bench: dict) -> dict:
    s = bench.get("batched_throughput", {}).get("spatial", {})
    return s if isinstance(s, dict) else {}


def _ratio(cell: dict, key: str, num: str, den: str) -> Optional[float]:
    """cell[key], or num/den when the explicit key predates the schema
    (BENCH_pr4 has engine_s/batched_s but no overhead key)."""
    v = cell.get(key)
    if v is not None:
        return float(v)
    n, d = cell.get(num), cell.get(den)
    if n and d:
        return float(n) / float(d)
    return None


def _engine_s(bench):
    v = _hist64(bench).get("engine_s")
    return float(v) if v is not None else None


def _batched_s(bench):
    v = _hist64(bench).get("batched_s")
    return float(v) if v is not None else None


def _engine_overhead(bench):
    return _ratio(_hist64(bench), "engine_overhead_vs_batched",
                  "engine_s", "batched_s")


def _batched_speedup(bench):
    v = _hist64(bench).get("speedup_batched_vs_seq")
    return float(v) if v is not None else None


def _spatial_speedup(bench):
    v = _spatial(bench).get("speedup_batched_vs_one_at_a_time")
    return float(v) if v is not None else None


def _spatial_overhead(bench):
    return _ratio(_spatial(bench), "engine_overhead_vs_batched",
                  "engine_s", "batched_s")


def _superpixel_speedup(bench):
    v = bench.get("superpixel_fcm", {}).get("speedup_fit")
    return float(v) if v is not None else None


def _superpixel_parity(bench):
    v = bench.get("superpixel_fcm", {}).get("dsc_parity_max_delta")
    return float(v) if v is not None else None


def _spatial_dsc_gain_wm(bench):
    """FCM_S's DSC payoff at the heaviest noise level (spatial_ref minus
    plain, WM class) — the quality metric the speed metrics must not
    silently trade away."""
    levels = bench.get("spatial_fcm", {}).get("levels") or []
    if not levels:
        return None
    fits = levels[-1].get("fits", {})
    try:
        return (float(fits["spatial_ref"]["dsc"]["WM"])
                - float(fits["plain"]["dsc"]["WM"]))
    except KeyError:
        return None


def _tracing_overhead(bench):
    v = _hist64(bench) and bench["batched_throughput"]["histogram"].get(
        "tracing_overhead_ratio")
    return float(v) if v is not None else None


def _mean_iters(bench):
    v = (bench.get("batched_throughput", {}).get("histogram", {})
         .get("convergence", {}) or {}).get("mean_iters")
    return float(v) if v is not None else None


def _load_sustained(bench):
    v = (bench.get("load_gen", {}).get("sustained") or {}
         ).get("achieved_qps")
    return float(v) if v is not None else None


def _load_p99(bench):
    v = (bench.get("load_gen", {}).get("sustained") or {}).get("p99_s")
    return float(v) if v is not None else None


def _load_ratio(bench):
    v = bench.get("load_gen", {}).get("qps_ratio_vs_sync")
    return float(v) if v is not None else None


@dataclasses.dataclass(frozen=True)
class Metric:
    """One named series over the BENCH ledger.

    ``kind`` decides when the relative gate applies: ``"time"`` and
    ``"ratio"`` metrics only compare full-size-vs-full-size runs (a
    ``tiny`` CI record against a full committed baseline is
    wall-clock-incomparable); ``"quality"`` metrics compare whenever
    both records carry them. ``ceiling``/``floor`` are absolute bounds
    enforced on the *current* record regardless of comparability —
    they mirror the hard gates the benchmark sections themselves
    enforce, so a tiny CI run still fails through :func:`diff`.
    """
    name: str
    extract: Callable[[dict], Optional[float]]
    direction: str                      # "lower" | "higher" is better
    kind: str = "ratio"                 # "time" | "ratio" | "quality"
    #: Allowed fractional worsening vs baseline; None disables the
    #: relative gate entirely (the metric gates on its absolute bound
    #: only — right for quantities whose baseline is legitimately 0).
    rel_slack: Optional[float] = 0.5
    ceiling: Optional[float] = None     # absolute max (lower-is-better)
    floor: Optional[float] = None       # absolute min (higher-is-better)

    def worsening(self, base: float, cur: float) -> float:
        """Signed fractional change in the *bad* direction (positive =
        worse than baseline). Any move away from a zero baseline is an
        infinite relative change — never silently 'within slack'."""
        if base == 0:
            if cur == 0:
                return 0.0
            worse = (cur > 0) == (self.direction == "lower")
            return math.inf if worse else -math.inf
        rel = (cur - base) / abs(base)
        return rel if self.direction == "lower" else -rel


#: The ledger's metric set. Ceilings/floors mirror the hard gates in
#: benchmarks/batched_throughput.py (engine overhead <= 5x, tracing
#: <= 1.25x, batched-spatial speedup >= 5x) so `diff` fails the same
#: regressions even on a tiny run, and names them per-metric.
METRICS: Tuple[Metric, ...] = (
    Metric("engine_s_b64", _engine_s, "lower", kind="time"),
    Metric("batched_s_b64", _batched_s, "lower", kind="time"),
    Metric("engine_overhead_b64", _engine_overhead, "lower",
           rel_slack=0.6, ceiling=5.0),
    Metric("batched_speedup_b64", _batched_speedup, "higher",
           rel_slack=0.5),
    Metric("spatial_batched_speedup", _spatial_speedup, "higher",
           rel_slack=0.5, floor=5.0),
    Metric("spatial_engine_overhead", _spatial_overhead, "lower",
           rel_slack=0.6),
    Metric("superpixel_speedup_fit", _superpixel_speedup, "higher",
           rel_slack=0.6),
    Metric("superpixel_dsc_parity", _superpixel_parity, "lower",
           kind="quality", rel_slack=None, ceiling=0.05),
    Metric("spatial_dsc_gain_wm", _spatial_dsc_gain_wm, "higher",
           kind="quality", rel_slack=0.15),
    Metric("tracing_overhead_ratio", _tracing_overhead, "lower",
           rel_slack=0.3, ceiling=1.25),
    Metric("mean_iters_b64", _mean_iters, "lower", kind="quality",
           rel_slack=0.5),
    # Serving load-gen (PR 9): sustained throughput under the explicit
    # p99 budget, that point's p99, and the headline continuous-batching
    # claim. The ratio's floor mirrors the tiny gate in
    # benchmarks/load_gen.py (2.0 — the full-size artifact carries the
    # 3x claim through load_gen's own in-process gate), so even a tiny
    # CI record fails here if batching stops paying for itself.
    Metric("load_sustained_qps", _load_sustained, "higher", kind="time",
           rel_slack=0.5),
    Metric("load_p99_s", _load_p99, "lower", kind="time", rel_slack=1.0),
    Metric("load_qps_ratio_vs_sync", _load_ratio, "higher", kind="ratio",
           rel_slack=0.6, floor=2.0),
)

_BY_NAME = {m.name: m for m in METRICS}


# ---------------------------------------------------------------------------
# Ledger loading / series
# ---------------------------------------------------------------------------

def load_bench(path: str) -> dict:
    with open(path) as f:
        return json.load(f)


def ledger_paths(out_dir: Optional[str] = None) -> List[Tuple[int, str]]:
    """Sorted ``(pr, path)`` for every committed BENCH_pr*.json."""
    out_dir = out_dir or OUT_DIR
    found = []
    for p in glob.glob(os.path.join(out_dir, "BENCH_pr*.json")):
        m = _BENCH_RE.search(os.path.basename(p))
        if m:
            found.append((int(m.group(1)), p))
    return sorted(found)


def load_ledger(out_dir: Optional[str] = None) -> List[Tuple[int, dict]]:
    """Every committed record, oldest PR first."""
    return [(pr, load_bench(p)) for pr, p in ledger_paths(out_dir)]


def series(ledger: Sequence[Tuple[int, dict]],
           metrics: Sequence[Metric] = METRICS
           ) -> Dict[str, List[Tuple[int, Optional[float]]]]:
    """Normalize the ledger into per-metric time series; a record that
    predates a metric contributes ``None`` (kept, so gaps are visible
    rather than silently compacted)."""
    return {m.name: [(pr, m.extract(bench)) for pr, bench in ledger]
            for m in metrics}


def resolve_baseline(out_dir: Optional[str] = None,
                     before: Optional[int] = None) -> Optional[str]:
    """Path of the newest committed ``BENCH_pr*.json`` (strictly below
    PR ``before`` when given, so a PR gates against its predecessor and
    never against its own freshly-written record). ``None`` when the
    ledger is empty — the first PR has nothing to regress against."""
    cands = [(pr, p) for pr, p in ledger_paths(out_dir)
             if before is None or pr < before]
    return cands[-1][1] if cands else None


# ---------------------------------------------------------------------------
# diff: the per-metric regression gate
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Policy:
    """What :func:`diff` fails on.

    * ``on_regress`` — a relative worsening beyond the metric's slack,
      or an absolute ceiling/floor breach (``"fail"`` | ``"warn"``).
    * ``on_missing`` — a metric the baseline carries but the current
      record dropped (``"fail"`` | ``"warn"``): the trajectory must
      never silently lose a column.
    * ``gate_relative`` — enable baseline-relative gates (these only
      ever apply to wall-clock-comparable record pairs for
      ``time``/``ratio`` metrics).
    * ``gate_absolute`` — enable the per-metric ceilings/floors, which
      apply to every run including ``tiny`` CI smokes.
    * ``slack_scale`` — scales every metric's ``rel_slack`` (e.g. 2.0
      for a loose advisory pass).
    """
    on_regress: str = "fail"
    on_missing: str = "fail"
    gate_relative: bool = True
    gate_absolute: bool = True
    slack_scale: float = 1.0


@dataclasses.dataclass
class Verdict:
    metric: str
    status: str            # improved|ok|regressed|bound_breach|
    #                        missing_current|new_metric|absent|not_comparable
    baseline: Optional[float]
    current: Optional[float]
    fatal: bool
    detail: str = ""

    def line(self) -> str:
        def fmt(v):
            return "-" if v is None else f"{v:.4g}"
        mark = "FAIL" if self.fatal else {
            "improved": "  + ", "regressed": "WARN",
            "bound_breach": "WARN"}.get(self.status, "    ")
        return (f"{mark} {self.metric:26s} {fmt(self.baseline):>10s} -> "
                f"{fmt(self.current):>10s}  {self.status}"
                + (f" ({self.detail})" if self.detail else ""))


@dataclasses.dataclass
class DiffResult:
    baseline_pr: Optional[int]
    current_pr: Optional[int]
    comparable: bool
    verdicts: List[Verdict]

    @property
    def failures(self) -> List[Verdict]:
        return [v for v in self.verdicts if v.fatal]

    @property
    def ok(self) -> bool:
        return not self.failures

    def report(self) -> str:
        mode = ("comparable" if self.comparable
                else "tiny-vs-full: relative time/ratio gates off")
        head = (f"# trajectory.diff: PR {self.baseline_pr} -> "
                f"PR {self.current_pr} ({mode})")
        return "\n".join([head] + [v.line() for v in self.verdicts])


def diff(baseline: dict, current: dict, policy: Policy = Policy(),
         metrics: Sequence[Metric] = METRICS) -> DiffResult:
    """Per-metric comparison of two BENCH records under ``policy``.

    Never raises on a regression — it returns the verdict list and the
    caller (``benchmarks/run.py``) decides to ``SystemExit`` on
    ``result.failures``, so library users can render diffs without
    aborting."""
    comparable = not (current.get("tiny") and not baseline.get("tiny"))
    fatal_regress = policy.on_regress == "fail"
    fatal_missing = policy.on_missing == "fail"
    verdicts: List[Verdict] = []
    for m in metrics:
        b, c = m.extract(baseline), m.extract(current)
        if b is None and c is None:
            verdicts.append(Verdict(m.name, "absent", None, None, False,
                                    "metric in neither record"))
            continue
        if c is None:
            verdicts.append(Verdict(
                m.name, "missing_current", b, None, fatal_missing,
                "baseline carries this metric; current dropped it"))
            continue
        # Absolute bounds gate every run, tiny included.
        if policy.gate_absolute:
            if m.ceiling is not None and c > m.ceiling:
                verdicts.append(Verdict(
                    m.name, "bound_breach", b, c, fatal_regress,
                    f"exceeds absolute ceiling {m.ceiling}"))
                continue
            if m.floor is not None and c < m.floor:
                verdicts.append(Verdict(
                    m.name, "bound_breach", b, c, fatal_regress,
                    f"under absolute floor {m.floor}"))
                continue
        if b is None:
            verdicts.append(Verdict(m.name, "new_metric", None, c, False,
                                    "first record carrying this metric"))
            continue
        if m.kind in ("time", "ratio") and not comparable:
            verdicts.append(Verdict(m.name, "not_comparable", b, c, False,
                                    "tiny run vs full baseline"))
            continue
        if not policy.gate_relative or m.rel_slack is None:
            verdicts.append(Verdict(
                m.name, "ok", b, c, False,
                "relative gates disabled" if m.rel_slack is not None
                else "absolute bound only"))
            continue
        w = m.worsening(b, c)
        slack = m.rel_slack * policy.slack_scale
        if w > slack:
            verdicts.append(Verdict(
                m.name, "regressed", b, c, fatal_regress,
                f"{w:+.0%} in the bad direction (slack {slack:.0%})"))
        elif w < 0:
            verdicts.append(Verdict(m.name, "improved", b, c, False,
                                    f"{-w:+.0%}"))
        else:
            verdicts.append(Verdict(m.name, "ok", b, c, False,
                                    f"{w:+.0%} within slack {slack:.0%}"))
    return DiffResult(baseline.get("pr"), current.get("pr"), comparable,
                      verdicts)


# ---------------------------------------------------------------------------
# Figures: trajectory small-multiples + accuracy-vs-speed frontier
# ---------------------------------------------------------------------------

# Colorblind-validated categorical slots (fixed assignment order, never
# cycled) + per-variant marker shapes as the secondary encoding, so
# identity is not carried by color alone.
_VARIANT_STYLE = (
    ("pixel", "#2a78d6", "o"),
    ("histogram", "#eb6834", "s"),
    ("spatial", "#1baf7a", "^"),
    ("vector", "#eda100", "D"),
)
_INK = "#333333"
_GRID = "#e3e3e3"


def _mpl():
    try:
        import matplotlib
        matplotlib.use("Agg")
        import matplotlib.pyplot as plt
        return plt
    except Exception:
        return None


def _style_axes(ax):
    for side in ("top", "right"):
        ax.spines[side].set_visible(False)
    for side in ("left", "bottom"):
        ax.spines[side].set_color(_GRID)
    ax.tick_params(colors=_INK, labelsize=8)
    ax.grid(True, color=_GRID, linewidth=0.6, alpha=0.8)
    ax.set_axisbelow(True)


def render_trajectory(ledger: Sequence[Tuple[int, dict]], out_path: str,
                      metrics: Sequence[Metric] = METRICS
                      ) -> Optional[str]:
    """The ledger as small multiples: one panel per metric (single blue
    series each — no legend needed), x = PR number. Returns the path
    written, or None when matplotlib is unavailable or the ledger has
    fewer than two records."""
    plt = _mpl()
    if plt is None or len(ledger) < 2:
        return None
    ss = series(ledger, metrics)
    panels = [(name, [(pr, v) for pr, v in pts if v is not None])
              for name, pts in ss.items()]
    panels = [(n, p) for n, p in panels if len(p) >= 2]
    if not panels:
        return None
    ncols = 3
    nrows = (len(panels) + ncols - 1) // ncols
    fig, axes = plt.subplots(nrows, ncols,
                             figsize=(3.4 * ncols, 2.4 * nrows))
    axes = [ax for row in (axes if nrows > 1 else [axes]) for ax in row]
    for ax in axes[len(panels):]:
        ax.set_visible(False)
    for ax, (name, pts) in zip(axes, panels):
        xs = [pr for pr, _ in pts]
        ys = [v for _, v in pts]
        ax.plot(xs, ys, color="#2a78d6", linewidth=2, marker="o",
                markersize=4)
        ax.annotate(f"{ys[-1]:.3g}", (xs[-1], ys[-1]),
                    textcoords="offset points", xytext=(4, 4),
                    fontsize=8, color=_INK)
        ax.set_title(name, fontsize=9, color=_INK)
        ax.set_xticks(xs)
        ax.set_xticklabels([f"pr{x}" for x in xs], fontsize=7)
        _style_axes(ax)
    fig.suptitle("Perf trajectory across committed BENCH records",
                 fontsize=11, color=_INK)
    fig.tight_layout(rect=(0, 0, 1, 0.96))
    os.makedirs(os.path.dirname(out_path), exist_ok=True)
    fig.savefig(out_path, dpi=120)
    plt.close(fig)
    return out_path


def _pareto(points: List[Tuple[float, float]]) -> List[Tuple[float, float]]:
    """Non-dominated (wall_s, dsc) points, fastest first: a point is on
    the frontier when nothing is both at-least-as-fast and
    at-least-as-accurate (with one strict)."""
    front = []
    for x, y in sorted(points):
        if not front or y > front[-1][1]:
            front.append((x, y))
    return front


def render_frontier(bench: dict, out_path: str) -> Optional[str]:
    """Accuracy-vs-speed frontier from the sweep's solver cells: one
    point per (variant, backend, size) at batch=1 — mean DSC against
    fit wall-clock (log x). The paper's Table 3 / Fig. 7 live here as
    the sequential-vs-device pixel cells. Returns None when matplotlib
    is unavailable or no cell carries accuracy."""
    plt = _mpl()
    cells = [c for c in bench.get("sweep", {}).get("cells", [])
             if c.get("family") == "solver" and c.get("status") == "ok"
             and (c.get("accuracy") or {}).get("mean_dsc") is not None]
    if plt is None or not cells:
        return None
    fig, ax = plt.subplots(figsize=(7.0, 4.6))
    all_pts = []
    front = _pareto([(c["metrics"]["wall_s"], c["accuracy"]["mean_dsc"])
                     for c in cells])
    front_set = set(front)
    for variant, color, marker in _VARIANT_STYLE:
        vc = [c for c in cells if c["axes"].get("variant") == variant]
        if not vc:
            continue
        xs = [c["metrics"]["wall_s"] for c in vc]
        ys = [c["accuracy"]["mean_dsc"] for c in vc]
        all_pts += list(zip(xs, ys))
        ax.scatter(xs, ys, s=46, color=color, marker=marker,
                   label=variant, edgecolors="white", linewidths=1.2,
                   zorder=3)
        # Selective direct labels: only the non-dominated points get
        # named (labelling every cell collides where many hit DSC 1.0).
        for c, x, y in zip(vc, xs, ys):
            if (x, y) in front_set:
                front_set.discard((x, y))
                ax.annotate(
                    f"{variant} {c['axes'].get('backend', '')}"
                    f"/{c['axes'].get('size', '')}",
                    (x, y), textcoords="offset points", xytext=(5, 5),
                    fontsize=7, color=_INK)
    if len(front) > 1:
        ax.plot([x for x, _ in front], [y for _, y in front],
                color="#9a9a9a", linewidth=1.2, linestyle="--", zorder=2)
    ax.set_xscale("log")
    ax.set_xlabel("fit wall-clock (s, log)", fontsize=9, color=_INK)
    ax.set_ylabel("mean DSC vs phantom ground truth", fontsize=9,
                  color=_INK)
    ax.set_title("Variant-zoo accuracy-vs-speed frontier "
                 f"(PR {bench.get('pr')}, {bench.get('backend')})",
                 fontsize=11, color=_INK)
    ax.legend(frameon=False, fontsize=8, loc="lower left")
    _style_axes(ax)
    fig.tight_layout()
    os.makedirs(os.path.dirname(out_path), exist_ok=True)
    fig.savefig(out_path, dpi=120)
    plt.close(fig)
    return out_path
