"""Roofline-term derivation from the compiled dry-run artifact.

Per (arch, shape, mesh) cell we report three times (seconds/step):

  compute    = HLO_FLOPs_total   / (chips * 197 TF/s)
  memory     = HLO_bytes_total   / (chips * 819 GB/s)
  collective = wire_bytes_global / (chips * 50 GB/s)

``compiled.cost_analysis()`` reports per-device flops/bytes (verified in
tests against hand-counted einsums), so compute/memory terms divide by
one chip's peak directly. Collective bytes are NOT in cost_analysis:
:func:`collective_bytes` parses the post-SPMD HLO text and sums operand
sizes of all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute ops, scaled to wire traffic with standard ring
multipliers (all-reduce 2(n-1)/n, gather/scatter (n-1)/n, permute 1).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, Optional

import jax

from . import hw

_COLL_RE = re.compile(
    r"=\s+[^=]*?\b"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")
_SHAPE_RE = re.compile(r"\b(pred|[suf]\d+|bf16|c64|c128)\[([\d,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")
_GROUPS_V2_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _op_operand_bytes(line: str) -> int:
    """Sum of operand tensor sizes on an HLO op line (per-device)."""
    lhs, _, rhs = line.partition("(")
    total = 0
    for m in _SHAPE_RE.finditer(rhs):
        dt, dims = m.group(1), m.group(2)
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * hw.dtype_bytes(dt)
    return total


def _group_size(line: str, n_devices: int) -> int:
    m = _GROUPS_V2_RE.search(line)
    if m:                                  # [groups, size] iota form
        return int(m.group(2))
    m = _GROUPS_RE.search(line)
    if m:
        return len([x for x in m.group(1).split(",") if x.strip() != ""])
    return n_devices


_WIRE_MULT = {
    "all-reduce": lambda n: 2.0 * (n - 1) / max(n, 1),
    "all-gather": lambda n: float(n - 1) / max(n, 1),
    "reduce-scatter": lambda n: float(n - 1) / max(n, 1),
    "all-to-all": lambda n: float(n - 1) / max(n, 1),
    "collective-permute": lambda n: 1.0,
}


def collective_bytes(hlo_text: str, n_devices: int) -> Dict[str, float]:
    """Global wire bytes per collective kind for one execution."""
    out: Dict[str, float] = {k: 0.0 for k in _WIRE_MULT}
    out["n_ops"] = 0
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if m is None or "-done" in line.split("=", 1)[-1][:40]:
            continue
        kind = m.group(1)
        per_dev = _op_operand_bytes(line)
        n = _group_size(line, n_devices)
        wire = per_dev * _WIRE_MULT[kind](n) * n_devices
        out[kind] += wire
        out["n_ops"] += 1
    out["total"] = sum(out[k] for k in _WIRE_MULT)
    return out


# ---------------------------------------------------------------------------
# Model-FLOPs accounting (6*N*D / 2*N*D)
# ---------------------------------------------------------------------------

def count_params(cfg) -> Dict[str, float]:
    """Total and active (MoE-aware) parameter counts from the abstract
    param tree: expert-stacked FFN leaves (ndim 4: (G, E, d, f)) count at
    top_k/E toward active params."""
    from repro.models import lm
    tree = lm.abstract_params(cfg)
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    total = active = 0.0
    for path, leaf in flat:
        n = 1.0
        for s in leaf.shape:
            n *= s
        total += n
        keys = "/".join(str(getattr(k, "key", k)) for k in path)
        if "ffn" in keys and leaf.ndim == 4 and cfg.moe is not None \
                and leaf.shape[1] == cfg.moe.n_experts:
            active += n * cfg.moe.top_k / cfg.moe.n_experts
        else:
            active += n
    return {"total": total, "active": active}


def model_flops(cfg, shape) -> float:
    """6*N_active*D for training, 2*N_active*D for inference steps."""
    n = count_params(cfg)["active"]
    if shape.kind == "train":
        d = shape.global_batch * shape.seq_len
        return 6.0 * n * d
    if shape.kind == "prefill":
        d = shape.global_batch * shape.seq_len
        return 2.0 * n * d
    d = shape.global_batch * 1
    return 2.0 * n * d


# ---------------------------------------------------------------------------
# Cell report
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class RooflineReport:
    arch: str
    shape: str
    mesh: str
    n_devices: int
    flops_per_dev: float
    bytes_per_dev: float
    wire_bytes: float
    t_compute: float
    t_memory: float
    t_collective: float
    bottleneck: str
    model_flops_total: float
    useful_flops_frac: float
    mem_args_gb: float
    mem_temp_gb: float
    mem_out_gb: float
    fits_hbm: bool
    xla_flops_per_dev: float = 0.0     # raw cost_analysis, scan-unaware
    xla_bytes_per_dev: float = 0.0

    def row(self) -> dict:
        return dataclasses.asdict(self)


def analyze(arch: str, shape, mesh_label: str, n_devices: int,
            cost: Optional[dict], mem, hlo_text: str, cfg,
            while_override: Optional[int] = None) -> RooflineReport:
    """Roofline terms from the trip-count-aware HLO walker (hlo_cost.py).
    XLA's own cost_analysis undercounts scan bodies (visited once); its
    numbers are kept in the record for reference only."""
    from . import hlo_cost
    costs = hlo_cost.analyze_text(hlo_text, n_devices, while_override)
    flops_dev = costs.flops
    bytes_dev = costs.bytes
    coll = {"total": costs.wire, **costs.wire_by_kind}
    t_c = flops_dev / hw.PEAK_FLOPS_BF16
    t_m = bytes_dev / hw.HBM_BW
    t_x = coll["total"] / (n_devices * hw.ICI_LINK_BW)
    dominant = max((("compute", t_c), ("memory", t_m),
                    ("collective", t_x)), key=lambda kv: kv[1])[0]
    mf = model_flops(cfg, shape) if cfg is not None else 0.0
    hlo_total = flops_dev * n_devices
    args_gb = mem.argument_size_in_bytes / 2 ** 30 if mem else 0.0
    temp_gb = mem.temp_size_in_bytes / 2 ** 30 if mem else 0.0
    out_gb = mem.output_size_in_bytes / 2 ** 30 if mem else 0.0
    peak = (mem.argument_size_in_bytes + mem.temp_size_in_bytes
            + mem.output_size_in_bytes) if mem else 0
    return RooflineReport(
        arch=arch, shape=shape.name, mesh=mesh_label, n_devices=n_devices,
        flops_per_dev=flops_dev, bytes_per_dev=bytes_dev,
        wire_bytes=coll["total"],
        t_compute=t_c, t_memory=t_m,
        t_collective=t_x, bottleneck=dominant, model_flops_total=mf,
        useful_flops_frac=(mf / hlo_total if hlo_total else 0.0),
        mem_args_gb=args_gb, mem_temp_gb=temp_gb, mem_out_gb=out_gb,
        fits_hbm=bool(peak <= hw.HBM_BYTES),
        xla_flops_per_dev=float(cost.get("flops", 0.0)) if cost else 0.0,
        xla_bytes_per_dev=(float(cost.get("bytes accessed", 0.0))
                           if cost else 0.0),
    )


# ---------------------------------------------------------------------------
# Kernel roofline-vs-achieved cells (the registered FCM step kernels)
# ---------------------------------------------------------------------------

_F32 = 4  # every step kernel streams f32 (labels write int32: same width)


def kernel_step_costs(kind: str, *, n_rows: int = 0, c: int = 0,
                      n_feat: int = 1, n_bins: int = 256, b: int = 1,
                      h: int = 0, w: int = 0, d: int = 0,
                      neighbors: int = 4, n_iters: int = 1,
                      n_centers: int = 0) -> Dict[str, float]:
    """Analytic per-invocation FLOPs/bytes for one registered step kind.

    This is the *achieved-work numerator*: the intrinsic math of the
    step at the probe shape, independent of implementation (the Pallas
    custom-calls are opaque to the HLO walker, so the analytic model is
    the one number comparable across reference/pallas/resident impls of
    the same kind). Bytes are the minimal HBM traffic: inputs once,
    outputs once, plus the (c, N)-sized intermediate for kinds whose
    reference impl materializes it. Constants are documented inline;
    they bound achieved/roofline from above, not below.
    """
    if kind == "flat":
        # distances 3D, membership ~6 (pow, recip, normalize), weighted
        # partials 2(D+1) — per (row, cluster); per convergence iter.
        flops = n_rows * c * (5 * n_feat + 8) * n_iters
        bytes_ = _F32 * (n_rows * (n_feat + 1)   # feats + weights
                         + n_rows * c            # (c, N) membership
                         + 2 * c * n_feat) * n_iters
    elif kind == "stencil":
        # neighbor sum + distance/membership for center and neighbor
        # terms + partials — per (pixel, cluster), plus the stencil pass.
        flops = h * w * (2 * neighbors + c * (10 + neighbors)) * n_iters
        bytes_ = _F32 * (h * w * (2 + c) + 2 * c) * n_iters
    elif kind == "bin":
        flops = b * n_rows            # one increment per pixel
        bytes_ = _F32 * b * (n_rows + n_bins)
    elif kind == "labels":
        flops = n_rows * c * (3 * n_feat + 1)
        bytes_ = _F32 * (n_rows * (n_feat + 1) + c * n_feat)
    elif kind == "slic_assign":
        # 9 grid-cell candidates x joint distance over D+2 dims.
        flops = h * w * 9 * (3 * (d + 2) + 1)
        bytes_ = _F32 * (h * w * (d + 1) + n_centers * (d + 2))
    else:
        raise ValueError(f"no analytic cost model for step kind {kind!r}")
    return {"flops": float(flops), "bytes": float(bytes_)}


@dataclasses.dataclass
class KernelCell:
    """Roofline-vs-achieved for one (step kind, impl) registry cell."""
    kind: str
    impl: str
    backend: str
    interpret: bool               # Pallas interpret mode (off-platform)
    shape: Dict[str, int]
    flops: float                  # analytic model, one invocation
    bytes: float
    hlo_flops: float              # HLO walker (0 when the kernel is an
    hlo_bytes: float              # opaque custom-call)
    wall_s: float                 # median measured wall time
    achieved_flops_per_s: float
    achieved_bytes_per_s: float
    t_roofline: float             # max(flops/peak, bytes/bw)
    bound: str                    # "compute" | "memory"
    frac_of_roofline: float       # t_roofline / wall_s (1.0 = at roof)

    def row(self) -> dict:
        return dataclasses.asdict(self)


def kernel_cell(kind: str, impl: str, backend: str, shape: Dict[str, int],
                flops: float, bytes_: float, wall_s: float, *,
                interpret: bool = False, hlo_flops: float = 0.0,
                hlo_bytes: float = 0.0) -> KernelCell:
    """Fold one measured kernel invocation into its roofline cell."""
    t_c = flops / hw.PEAK_FLOPS_BF16
    t_m = bytes_ / hw.HBM_BW
    t_roof = max(t_c, t_m)
    return KernelCell(
        kind=kind, impl=impl, backend=backend, interpret=interpret,
        shape=dict(shape), flops=flops, bytes=bytes_,
        hlo_flops=hlo_flops, hlo_bytes=hlo_bytes, wall_s=wall_s,
        achieved_flops_per_s=flops / wall_s if wall_s > 0 else 0.0,
        achieved_bytes_per_s=bytes_ / wall_s if wall_s > 0 else 0.0,
        t_roofline=t_roof,
        bound="compute" if t_c >= t_m else "memory",
        frac_of_roofline=t_roof / wall_s if wall_s > 0 else 0.0,
    )
