from . import hw, roofline, trajectory  # noqa: F401
