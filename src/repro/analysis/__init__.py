from . import hw, roofline  # noqa: F401
