"""Deterministic fault injection for chaos-testing the serving stack.

Reliability claims are only as good as the failures they were tested
against, and real failures (a flaky accelerator launch, a NaN payload,
a dead flusher thread) are rare and unreproducible by nature. This
module makes them cheap and *deterministic*: a :class:`FaultPlan` is a
seedable list of :class:`FaultSpec` entries naming an injection *site*
(``"launch"``, ``"solve"``, ``"flusher"``, ``"kernel"``, ...), a fault
kind, and a firing rule (the Nth..Mth eligible hit, or an i.i.d.
probability drawn from the plan's seed). The hooks compiled into the
engine/solver/kernel layers are no-ops unless an injector is installed,
so the production path pays one ``is None`` check.

Sites wired in this repo:

===============  ============================================  =========
site             where the hook runs                           kinds
===============  ============================================  =========
``ingest``       engine ``_ingest`` (per request)              error/latency
``launch``       engine ``_run_bucket``, per launch *attempt*  error/latency
``solve``        engine post-solve centers (per chunk)         nan/inf
``solve_batched``global hook in ``core.solver.solve_batched``  nan/inf
``kernel``       global hook in ``kernels.ops.select_step``    error
``flusher``      top of each ``_flusher_loop`` iteration       error/kill
===============  ============================================  =========

Kinds: ``"error"`` raises :class:`InjectedFault` (transient, retryable);
``"kill"`` raises :class:`FlusherKilled` (a ``BaseException`` that
escapes ``except Exception`` supervision, simulating hard thread
death); ``"latency"`` sleeps ``latency_s``; ``"nan"``/``"inf"`` poison
the listed ``lanes`` of an array at a corrupt-site.

Engine-owned injectors are passed to ``FCMServeEngine(faults=...)`` and
count into the engine's metrics registry; the module-level
``install()``/``get()``/``clear()`` global injector reaches the
solver/kernel hooks that have no engine in scope.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from typing import Any, Dict, Optional, Sequence, Tuple

__all__ = [
    "FaultSpec", "FaultPlan", "FaultInjector", "InjectedFault",
    "FlusherKilled", "clean_snapshot", "install", "get", "clear",
]

KINDS = ("error", "nan", "inf", "latency", "kill")


class InjectedFault(RuntimeError):
    """A deliberately injected *transient* failure (retryable)."""


class FlusherKilled(BaseException):
    """Injected hard thread death. Deliberately a ``BaseException`` so
    it escapes ``except Exception`` supervision — the thread really
    dies, and recovery must come from re-ensuring a live flusher."""


@dataclasses.dataclass(frozen=True)
class FaultSpec:
    """One injection rule.

    Fires at a ``site`` (optionally only for one ``route``), on eligible
    hits ``after <= hit < after + times`` (``times=None`` = every hit
    from ``after`` on), or i.i.d. with probability ``p`` when ``p > 0``
    (drawn from the plan's seeded rng, so runs are reproducible).
    ``latency_s`` only matters for ``kind="latency"``; ``lanes`` names
    which batch lanes a ``nan``/``inf`` corrupt-site poisons.
    """
    site: str
    kind: str = "error"
    route: Optional[str] = None
    times: Optional[int] = 1
    after: int = 0
    p: float = 0.0
    latency_s: float = 0.0
    lanes: Tuple[int, ...] = (0,)

    def __post_init__(self):
        if self.kind not in KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r}; "
                             f"one of {KINDS}")


@dataclasses.dataclass(frozen=True)
class FaultPlan:
    """A seedable, ordered set of fault specs — the unit a chaos test
    pins: same plan, same traffic => same injected failures."""
    seed: int = 0
    specs: Tuple[FaultSpec, ...] = ()

    def __post_init__(self):
        object.__setattr__(self, "specs", tuple(self.specs))


class FaultInjector:
    """Executes a :class:`FaultPlan` at the hook sites.

    Thread-safe (the flusher thread and submitters share it); all
    firing decisions are deterministic given the plan: hit counters are
    per-spec, and probabilistic specs draw from a per-spec
    ``numpy.random.Generator`` seeded from ``(plan.seed, spec index)``.
    """

    def __init__(self, plan: FaultPlan,
                 registry: Optional[Any] = None):
        import numpy as np
        self.plan = plan
        self._registry = registry
        self._lock = threading.Lock()
        self._hits = [0] * len(plan.specs)
        self._rngs = [np.random.default_rng((plan.seed, i))
                      for i in range(len(plan.specs))]
        self._injected = 0
        self._by_site: Dict[str, int] = {}

    # -- firing decisions ---------------------------------------------------

    def _fire(self, i: int, spec: FaultSpec) -> bool:
        """Called under the lock; advances spec i's hit counter and
        decides whether it fires on this hit."""
        hit = self._hits[i]
        self._hits[i] = hit + 1
        if spec.p > 0.0:
            return bool(self._rngs[i].random() < spec.p)
        if hit < spec.after:
            return False
        return spec.times is None or hit < spec.after + spec.times

    def _matching(self, site: str, route: Optional[str]):
        for i, spec in enumerate(self.plan.specs):
            if spec.site != site:
                continue
            if spec.route is not None and spec.route != route:
                continue
            yield i, spec

    def _record(self, site: str, kind: str) -> None:
        self._injected += 1
        self._by_site[site] = self._by_site.get(site, 0) + 1
        if self._registry is not None:
            self._registry.counter("faults.injected", site=site,
                                   kind=kind).inc()

    # -- hook entry points --------------------------------------------------

    def maybe_fail(self, site: str, route: Optional[str] = None) -> None:
        """Raise/delay per the plan at an execution site. ``latency``
        specs sleep (outside the lock) then fall through; ``error``
        raises :class:`InjectedFault`; ``kill`` raises
        :class:`FlusherKilled`."""
        sleep_s = 0.0
        boom: Optional[BaseException] = None
        with self._lock:
            for i, spec in self._matching(site, route):
                if spec.kind in ("nan", "inf"):
                    continue            # corrupt-site specs don't raise
                if not self._fire(i, spec):
                    continue
                self._record(site, spec.kind)
                if spec.kind == "latency":
                    sleep_s += spec.latency_s
                elif spec.kind == "kill":
                    boom = FlusherKilled(f"injected kill at {site}")
                    break
                else:
                    boom = InjectedFault(
                        f"injected fault at {site}"
                        + (f" (route={route})" if route else ""))
                    break
        if sleep_s > 0.0:
            time.sleep(sleep_s)
        if boom is not None:
            raise boom

    def corrupt(self, site: str, arr, route: Optional[str] = None):
        """Poison lanes of a centers-like array per any firing
        ``nan``/``inf`` spec at this site. ``arr`` is numpy or jax,
        leading axis = batch lanes; returns a poisoned copy (numpy) or
        a functionally-updated array (jax), or ``arr`` untouched."""
        import numpy as np
        poison = []                     # (lanes, value) pairs
        with self._lock:
            for i, spec in self._matching(site, route):
                if spec.kind not in ("nan", "inf"):
                    continue
                if not self._fire(i, spec):
                    continue
                self._record(site, spec.kind)
                poison.append((spec.lanes,
                               np.nan if spec.kind == "nan" else np.inf))
        if not poison:
            return arr
        n = arr.shape[0]
        if isinstance(arr, np.ndarray):
            out = np.array(arr, copy=True)
            for lanes, val in poison:
                for lane in lanes:
                    if 0 <= lane < n:
                        out[lane] = val
            return out
        import jax.numpy as jnp
        out = arr
        for lanes, val in poison:
            for lane in lanes:
                if 0 <= lane < n:
                    out = out.at[lane].set(val)
        return out

    # -- introspection ------------------------------------------------------

    def snapshot(self) -> Dict[str, Any]:
        """The ``faults`` section of a benchmark/engine report: enough
        to tell an injected run from a clean one."""
        with self._lock:
            return {"seed": self.plan.seed,
                    "injected": self._injected,
                    "by_site": dict(self._by_site),
                    "chaos": self._injected > 0 or bool(self.plan.specs)}


def clean_snapshot() -> Dict[str, Any]:
    """What a run with no injector reports — the explicit 'no faults
    were injected here' marker ``bench_schema`` checks."""
    return {"seed": None, "injected": 0, "by_site": {}, "chaos": False}


# ---------------------------------------------------------------------------
# The global injector (solver/kernel hooks, which have no engine in scope)
# ---------------------------------------------------------------------------

_GLOBAL: Optional[FaultInjector] = None


def install(plan_or_injector) -> FaultInjector:
    """Install the process-global injector (solver + kernel hooks).
    Accepts a plan or a prebuilt injector; returns the injector.
    Callers/tests must pair this with :func:`clear`."""
    global _GLOBAL
    inj = (plan_or_injector if isinstance(plan_or_injector, FaultInjector)
           else FaultInjector(plan_or_injector))
    _GLOBAL = inj
    return inj


def get() -> Optional[FaultInjector]:
    return _GLOBAL


def clear() -> None:
    global _GLOBAL
    _GLOBAL = None
