"""Process-local observability: metrics, tracing, convergence telemetry.

This package is the measurement layer every perf-facing subsystem
reports through — the serving engine's per-route latency percentiles
and stage spans, the solver core's iterations-to-converge telemetry,
and the roofline-vs-achieved kernel report all sit on these three
primitives:

* :mod:`repro.obs.metrics` — counters, gauges and fixed-bucket
  histograms with p50/p90/p99 readout, grouped in a
  :class:`~repro.obs.metrics.MetricsRegistry` whose ``snapshot()`` is
  plain JSON-serializable.
* :mod:`repro.obs.tracing` — lightweight nested spans
  (``with tracer.span("solve", route=...)``) recording wall time and,
  via :meth:`~repro.obs.tracing.Span.fence`, ``block_until_ready``-
  fenced device time; finished root spans land in a ring buffer of the
  last N trace records.
* a module-level default registry (:func:`default_registry`) that the
  solver core records convergence telemetry into — see
  ``repro.core.solver._record_telemetry``.

Nothing here imports from ``repro.core``/``repro.serving``/
``repro.kernels``, so any layer can depend on it without cycles.
"""
from .metrics import (ITER_EDGES, LATENCY_EDGES, UNIT_EDGES,  # noqa: F401
                      Counter, Gauge, Histogram, MetricsRegistry,
                      default_registry, json_safe, scoped_registry)
from .tracing import Span, Tracer  # noqa: F401
