"""Lightweight nested spans with a ring buffer of finished traces.

A span measures one stage of work (``with tracer.span("solve",
route="histogram", bucket=64):``). Spans nest: closing a child attaches
it to its parent, closing a root appends the whole tree — as a plain
dict — to the tracer's ring buffer of the last N traces. Exceptions
propagate (the span records ``status="error"`` and the error repr on
the way out, and the stack unwinds correctly).

Device work is asynchronous under JAX, so a span that only brackets the
``launch`` call would time the dispatch, not the math.
:meth:`Span.fence` calls ``jax.block_until_ready`` on a launch result
and records the span-start -> ready interval as ``device_s`` — the
fenced device time — while returning the value, so the call site stays
one expression: ``outs = sp.fence(prog.launch(*inputs))``.

``Tracer(enabled=False)`` keeps timing semantics (spans still measure,
``fence`` still blocks) but skips ring-buffer and metrics recording —
what the tracing-overhead benchmark compares against. With a
:class:`~repro.obs.metrics.MetricsRegistry` attached, every finished
span also lands in a ``span_seconds{span=<name>}`` histogram.
"""
from __future__ import annotations

import collections
import threading
import time
from contextlib import contextmanager
from typing import Any, Deque, Dict, List, Optional

from . import metrics as M

__all__ = ["Span", "Tracer"]


class Span:
    """One timed stage. ``wall_s`` is set when the span closes;
    ``device_s`` only when :meth:`fence` ran inside it."""

    __slots__ = ("name", "attrs", "t_start", "wall_s", "device_s",
                 "status", "error", "children", "_t0")

    def __init__(self, name: str, attrs: Dict[str, Any]):
        self.name = name
        self.attrs = attrs
        self.t_start = time.time()
        self._t0 = time.perf_counter()
        self.wall_s: Optional[float] = None
        self.device_s: Optional[float] = None
        self.status = "ok"
        self.error: Optional[str] = None
        self.children: List["Span"] = []

    def fence(self, value):
        """Block until ``value``'s device work is ready; record the
        span-start -> ready interval as this span's device time."""
        import jax
        value = jax.block_until_ready(value)
        self.device_s = time.perf_counter() - self._t0
        return value

    def close(self, error: Optional[BaseException] = None) -> None:
        self.wall_s = time.perf_counter() - self._t0
        if error is not None:
            self.status = "error"
            self.error = repr(error)

    def to_dict(self) -> dict:
        d = {"name": self.name, "t_start": self.t_start,
             "wall_s": self.wall_s, "status": self.status}
        if self.attrs:
            d["attrs"] = M.json_safe(self.attrs)
        if self.device_s is not None:
            d["device_s"] = self.device_s
        if self.error is not None:
            d["error"] = self.error
        if self.children:
            d["children"] = [c.to_dict() for c in self.children]
        return d


class Tracer:
    """Span factory + ring buffer of the last ``max_traces`` root
    traces. The span stack is thread-local; the ring is shared."""

    def __init__(self, max_traces: int = 64, enabled: bool = True,
                 metrics: Optional[M.MetricsRegistry] = None,
                 span_metric: str = "span_seconds"):
        self.enabled = enabled
        self.metrics = metrics
        self.span_metric = span_metric
        self._ring: Deque[dict] = collections.deque(maxlen=max_traces)
        self._local = threading.local()

    def _stack(self) -> List[Span]:
        stack = getattr(self._local, "stack", None)
        if stack is None:
            stack = self._local.stack = []
        return stack

    @property
    def current_span(self) -> Optional[Span]:
        stack = self._stack()
        return stack[-1] if stack else None

    @contextmanager
    def span(self, name: str, ring: bool = True, **attrs):
        """Open a timed span. ``ring=False`` keeps a root span out of
        the trace ring (per-submit validation spans would otherwise
        drown the flush traces) while still timing and feeding metrics.
        Exceptions mark the span ``status="error"`` and propagate."""
        sp = Span(name, attrs)
        stack = self._stack()
        stack.append(sp)
        try:
            yield sp
        except BaseException as e:
            sp.close(e)
            raise
        finally:
            if sp.wall_s is None:       # non-error exit
                sp.close()
            stack.pop()
            if stack:
                stack[-1].children.append(sp)
            elif ring and self.enabled:
                self._ring.append(sp.to_dict())
            if self.enabled and self.metrics is not None:
                self.metrics.histogram(self.span_metric,
                                       span=name).record(sp.wall_s)

    def traces(self) -> List[dict]:
        """The finished root traces, oldest first (plain dicts)."""
        return list(self._ring)

    def clear(self) -> None:
        self._ring.clear()
