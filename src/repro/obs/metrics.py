"""Process-local counters, gauges, and fixed-bucket histograms.

The histogram is the workhorse: a fixed set of bucket edges (log-spaced
for latencies, unit-spaced for iteration counts) so ``record()`` is one
``bisect`` + increment — cheap enough for the serving hot path — while
``quantile()`` reads p50/p90/p99 by linear interpolation inside the
containing bucket. Quantiles are therefore approximate with error
bounded by the bucket width; the test suite pins them against numpy
percentiles at that tolerance.

Metrics live in a :class:`MetricsRegistry`, keyed by name plus optional
labels (``registry.histogram("request_latency", route="spatial")``).
``snapshot()`` renders the whole registry as one plain-JSON dict —
python ints/floats only, never numpy scalars — and ``reset()`` zeroes
every registered metric in place (the registry keeps the keys, so a
dashboard's schema survives a stats reset).
"""
from __future__ import annotations

import contextlib
import json
import math
import threading
from bisect import bisect_right
from typing import Dict, Hashable, List, Optional, Sequence, Tuple

__all__ = ["Counter", "Gauge", "Histogram", "MetricsRegistry",
           "default_registry", "scoped_registry", "json_safe",
           "LATENCY_EDGES", "ITER_EDGES", "UNIT_EDGES"]


#: Default latency bucket edges (seconds): eighth-decade log steps from
#: 1 µs to 100 s — quantile error bounded by a 10^(1/8) ≈ 1.33x factor.
LATENCY_EDGES: Tuple[float, ...] = tuple(
    10.0 ** (e / 8.0) for e in range(-48, 17))

#: Iteration-count bucket edges: unit-spaced through 64 (quantiles exact
#: to ±1 iteration in the regime FCM converges in), then coarsening
#: toward the solver's max_iters ceilings.
ITER_EDGES: Tuple[float, ...] = tuple(range(1, 65)) + (
    80, 96, 128, 160, 192, 256, 320, 384, 448, 512)

#: Unit-interval bucket edges (fractions: batch occupancy, hit rates) —
#: 1/32 steps so quantiles resolve to ~3% of full scale.
UNIT_EDGES: Tuple[float, ...] = tuple(i / 32.0 for i in range(0, 33))

#: One process-wide mutation lock shared by every Counter/Gauge/
#: Histogram. Metric writes are a handful of int ops, so a single
#: uncontended lock costs ~100ns and makes the async serving engine's
#: cross-thread recording (flusher thread vs. callers) race-free:
#: ``value += n`` and the histogram's multi-field update are
#: read-modify-write sequences the GIL alone does not make atomic.
_MUT = threading.Lock()


def json_safe(obj):
    """Recursively coerce a stats tree to plain JSON types (numpy
    scalars -> python ints/floats, tuples -> lists); raises on anything
    json could not represent rather than letting it leak out."""
    if obj is None or isinstance(obj, (bool, str)):
        return obj
    if isinstance(obj, int):
        return int(obj)
    if isinstance(obj, float):
        return float(obj)
    if isinstance(obj, dict):
        return {str(k): json_safe(v) for k, v in obj.items()}
    if isinstance(obj, (list, tuple)):
        return [json_safe(v) for v in obj]
    # numpy scalars (np.float32, np.int64, ...) expose item(); arrays
    # expose tolist(). Neither is imported here — duck-type them.
    if hasattr(obj, "item") and not hasattr(obj, "__len__"):
        return json_safe(obj.item())
    if hasattr(obj, "tolist"):
        return json_safe(obj.tolist())
    raise TypeError(f"not JSON-serializable: {type(obj).__name__}: {obj!r}")


class Counter:
    """Monotonic accumulator. Stays a python int while fed ints (batch
    and request counts), becomes a float once fed one (stage seconds)."""

    __slots__ = ("value",)

    def __init__(self):
        self.value = 0

    def inc(self, n=1):
        with _MUT:
            self.value += n

    def snapshot(self):
        return json_safe(self.value)


class Gauge:
    """Last-write-wins value (queue depth, last residual)."""

    __slots__ = ("value",)

    def __init__(self):
        self.value = 0.0

    def set(self, v):
        self.value = float(v)

    def snapshot(self):
        return float(self.value)


class Histogram:
    """Fixed-bucket histogram with quantile readout.

    ``edges`` are the bucket boundaries; bucket ``i`` covers
    ``[edges[i-1], edges[i])`` with an underflow bucket below
    ``edges[0]`` and an overflow bucket at ``>= edges[-1]``. Exact
    count/sum/min/max ride alongside, so ``mean`` is exact and
    quantile interpolation can clamp to the observed range.
    """

    __slots__ = ("edges", "counts", "count", "total", "vmin", "vmax")

    def __init__(self, edges: Sequence[float] = LATENCY_EDGES):
        if len(edges) < 1 or any(b <= a for a, b in zip(edges, edges[1:])):
            raise ValueError(f"edges must be strictly increasing, "
                             f"got {edges!r}")
        self.edges: Tuple[float, ...] = tuple(float(e) for e in edges)
        self.counts: List[int] = [0] * (len(self.edges) + 1)
        self.count = 0
        self.total = 0.0
        self.vmin = math.inf
        self.vmax = -math.inf

    def record(self, v) -> None:
        v = float(v)
        with _MUT:
            self.counts[bisect_right(self.edges, v)] += 1
            self.count += 1
            self.total += v
            if v < self.vmin:
                self.vmin = v
            if v > self.vmax:
                self.vmax = v

    def _bucket_bounds(self, i: int) -> Tuple[float, float]:
        lo = self.edges[i - 1] if i > 0 else min(self.vmin, self.edges[0])
        hi = self.edges[i] if i < len(self.edges) else max(self.vmax,
                                                           self.edges[-1])
        return lo, hi

    def quantile_info(self, q: float) -> Tuple[Optional[float], bool]:
        """``(value, overflow)``: the approximate q-quantile (numpy
        'linear' rank convention, linear-interpolated inside the
        containing bucket, clamped to the observed [min, max]) plus
        whether it landed in the overflow bucket. An overflow-derived
        quantile interpolates between ``edges[-1]`` and the tracked
        ``vmax`` — honest about the observed range (no silent clamp at
        the last edge), but with only two real anchor points, so
        consumers should treat it as a range estimate and widen the
        edges. ``(None, False)`` when empty."""
        if self.count == 0:
            return None, False
        if not 0.0 <= q <= 1.0:
            raise ValueError(f"quantile q must be in [0, 1], got {q}")
        overflow_bucket = len(self.edges)
        rank = q * (self.count - 1)
        cum = 0
        for i, c in enumerate(self.counts):
            if c and rank < cum + c:
                lo, hi = self._bucket_bounds(i)
                frac = (rank - cum + 0.5) / c
                val = lo + frac * (hi - lo)
                return (min(max(val, self.vmin), self.vmax),
                        i == overflow_bucket)
            cum += c
        return self.vmax, self.counts[overflow_bucket] > 0

    def quantile(self, q: float) -> Optional[float]:
        """Approximate q-quantile; see :meth:`quantile_info`."""
        return self.quantile_info(q)[0]

    @property
    def mean(self) -> Optional[float]:
        return self.total / self.count if self.count else None

    def snapshot(self) -> dict:
        empty = self.count == 0
        quants = {f"p{int(q * 100)}": self.quantile_info(q)
                  for q in (0.50, 0.90, 0.99)}
        out = {
            "count": int(self.count),
            "sum": float(self.total),
            "mean": None if empty else float(self.total / self.count),
            "min": None if empty else float(self.vmin),
            "max": None if empty else float(self.vmax),
        }
        for name, (val, over) in quants.items():
            out[name] = val
            # overflow-derived quantiles interpolate off the tracked max
            # rather than a real edge: flagged so dashboards can widen
            # the histogram edges instead of trusting the estimate.
            out[f"{name}_overflow"] = over
        return out


def _key(name: str, labels: Dict[str, str]) -> Hashable:
    return (name, tuple(sorted((k, str(v)) for k, v in labels.items())))


def _render(key: Hashable) -> str:
    name, labels = key
    if not labels:
        return name
    return name + "{" + ",".join(f"{k}={v}" for k, v in labels) + "}"


class MetricsRegistry:
    """Name+labels keyed metric store with a schema'd JSON snapshot."""

    def __init__(self):
        self._metrics: Dict[Hashable, object] = {}

    def _get(self, cls, name: str, labels: Dict[str, str], **kw):
        key = _key(name, labels)
        with _MUT:       # get-or-create must not race across threads
            m = self._metrics.get(key)
            if m is None:
                m = cls(**kw)
                self._metrics[key] = m
        if not isinstance(m, cls):
            raise TypeError(f"metric {_render(key)!r} already registered "
                            f"as {type(m).__name__}, not {cls.__name__}")
        return m

    def peek(self, name: str, **labels):
        """The metric registered under (name, labels), or None — a
        lookup that never creates (use it for 'has this ever been
        recorded' reads)."""
        return self._metrics.get(_key(name, labels))

    def counter(self, name: str, **labels) -> Counter:
        return self._get(Counter, name, labels)

    def gauge(self, name: str, **labels) -> Gauge:
        return self._get(Gauge, name, labels)

    def histogram(self, name: str, edges: Sequence[float] = LATENCY_EDGES,
                  **labels) -> Histogram:
        return self._get(Histogram, name, labels, edges=edges)

    def reset(self) -> None:
        """Zero every metric in place; registered keys survive so
        snapshots keep their schema after a stats reset."""
        for m in self._metrics.values():
            if isinstance(m, Histogram):
                m.counts = [0] * len(m.counts)
                m.count = 0
                m.total = 0.0
                m.vmin = math.inf
                m.vmax = -math.inf
            elif isinstance(m, Counter):
                m.value = 0
            else:
                m.value = 0.0

    def snapshot(self) -> dict:
        """{"counters": {...}, "gauges": {...}, "histograms": {...}},
        keys rendered ``name{label=value,...}``, values plain JSON."""
        out = {"counters": {}, "gauges": {}, "histograms": {}}
        for key in sorted(self._metrics, key=_render):
            m = self._metrics[key]
            group = ("counters" if isinstance(m, Counter)
                     else "gauges" if isinstance(m, Gauge)
                     else "histograms")
            out[group][_render(key)] = m.snapshot()
        return out

    def to_json(self, **json_kw) -> str:
        return json.dumps(self.snapshot(), **json_kw)


#: Registry stack: the bottom entry is the process-wide default; a
#: :func:`scoped_registry` context pushes a fresh registry on top so
#: telemetry recorded inside the scope is captured in isolation.
_REGISTRY_STACK: List[MetricsRegistry] = [MetricsRegistry()]


def default_registry() -> MetricsRegistry:
    """The currently-active registry: the process-wide one (solver
    convergence telemetry lands here; the serving engine keeps its own
    per-instance registry), or — inside a :func:`scoped_registry`
    block — the innermost scoped registry."""
    return _REGISTRY_STACK[-1]


@contextlib.contextmanager
def scoped_registry(registry: Optional[MetricsRegistry] = None):
    """Route :func:`default_registry` telemetry to a private registry
    for the duration of the block — the cell-scoped capture the sweep
    harness wraps around each grid cell, so one cell's convergence
    telemetry never bleeds into another's record::

        with obs.scoped_registry() as reg:
            solver.solve(problem)          # telemetry -> reg
        cell["obs"] = reg.snapshot()

    Scopes nest (innermost wins) and the process-wide default registry
    is untouched throughout.
    """
    reg = MetricsRegistry() if registry is None else registry
    _REGISTRY_STACK.append(reg)
    try:
        yield reg
    finally:
        # Remove *this* scope even if an inner scope leaked; never pop
        # the process-wide default at the bottom of the stack.
        for i in range(len(_REGISTRY_STACK) - 1, 0, -1):
            if _REGISTRY_STACK[i] is reg:
                del _REGISTRY_STACK[i]
                break
