"""Synthetic digital brain phantom (BrainWeb-like) + metrics.

The paper segments the BrainWeb simulated brain phantom (Collins et al.
1998) into WM / GM / CSF / background. That dataset is not
redistributable, so this module synthesizes axial-slice-like images with
the same statistical structure: four piecewise-constant tissue classes
arranged as nested regions (background, CSF rim + ventricles, GM ribbon,
WM core) with additive Gaussian noise — plus exact ground-truth masks,
which is what the paper's DSC evaluation (Fig. 6/7) requires.

Classes: 0=background, 1=CSF, 2=GM, 3=WM.
"""
from __future__ import annotations

import numpy as np

N_CLASSES = 4
CLASS_NAMES = ("background", "CSF", "GM", "WM")
# Mean intensities roughly matching a T1 BrainWeb slice.
CLASS_MEANS = np.array([0.0, 52.0, 106.0, 168.0])
# Per-class (T1, T2, PD)-like channel means for the multi-modal phantom:
# CSF is dark on T1 but bright on T2/PD, WM the other way around — the
# contrast inversion that makes multi-channel clustering genuinely
# multi-dimensional (no single channel separates all four classes).
CLASS_MEANS_MULTI = np.array([
    [0.0, 0.0, 0.0],          # background
    [52.0, 230.0, 190.0],     # CSF
    [106.0, 120.0, 150.0],    # GM
    [168.0, 70.0, 110.0],     # WM
])
# A colorized-atlas-style RGB rendering of the same anatomy.
CLASS_MEANS_RGB = np.array([
    [0.0, 0.0, 0.0],          # background: black
    [50.0, 80.0, 200.0],      # CSF: blue
    [110.0, 200.0, 110.0],    # GM: green
    [230.0, 170.0, 60.0],     # WM: amber
])


def _ellipse(h, w, cy, cx, ry, rx, yy=None, xx=None):
    if yy is None:
        yy, xx = np.mgrid[0:h, 0:w]
    return ((yy - cy) / ry) ** 2 + ((xx - cx) / rx) ** 2 <= 1.0


def phantom_labels(height: int, width: int, slice_pos: float = 0.5):
    """The noise-free anatomy: int32 (H, W) class labels shared by every
    phantom flavor (grayscale, RGB, multi-modal).

    ``slice_pos`` in [0, 1] scales the anatomy like moving through axial
    slices (the paper shows the 91st/96th/101st/111th slices).
    """
    h, w = height, width
    yy, xx = np.mgrid[0:h, 0:w]
    cy, cx = h / 2.0, w / 2.0
    scale = 0.75 + 0.5 * slice_pos          # anatomy grows/shrinks by slice

    labels = np.zeros((h, w), np.int32)
    # head outline: CSF-filled skull interior (skull itself stripped, as in
    # the paper's preprocessing)
    head = _ellipse(h, w, cy, cx, 0.46 * h * scale, 0.42 * w * scale, yy, xx)
    labels[head] = 1
    # GM ribbon
    gm = _ellipse(h, w, cy, cx, 0.42 * h * scale, 0.38 * w * scale, yy, xx)
    labels[gm] = 2
    # WM core (two lobes for a non-convex boundary)
    wm = (_ellipse(h, w, cy, cx - 0.10 * w, 0.30 * h * scale,
                   0.20 * w * scale, yy, xx)
          | _ellipse(h, w, cy, cx + 0.10 * w, 0.30 * h * scale,
                     0.20 * w * scale, yy, xx))
    labels[wm & gm] = 3
    # lateral ventricles: CSF pockets inside WM
    vent = (_ellipse(h, w, cy - 0.02 * h, cx - 0.08 * w, 0.09 * h * scale,
                     0.035 * w * scale, yy, xx)
            | _ellipse(h, w, cy - 0.02 * h, cx + 0.08 * w, 0.09 * h * scale,
                       0.035 * w * scale, yy, xx))
    labels[vent] = 1
    return labels


def phantom_slice(height: int = 217, width: int = 181,
                  slice_pos: float = 0.5, noise: float = 4.0,
                  seed: int = 0):
    """Returns (image uint8 (H, W), labels int32 (H, W))."""
    rng = np.random.default_rng(seed)
    h, w = height, width
    labels = phantom_labels(h, w, slice_pos)
    img = CLASS_MEANS[labels] + rng.normal(0.0, noise, size=(h, w))
    img = np.clip(img, 0, 255)
    # background stays exactly 0 outside the head (skull-stripped)
    img[labels == 0] = np.clip(
        rng.normal(0.0, noise * 0.25, size=(h, w)), 0, 255)[labels == 0]
    return img.astype(np.uint8), labels


def phantom_slice_channels(height: int = 217, width: int = 181,
                           slice_pos: float = 0.5, noise: float = 4.0,
                           seed: int = 0,
                           class_means: np.ndarray = CLASS_MEANS_MULTI):
    """Multi-channel phantom: (image uint8 (H, W, D), labels (H, W)).

    ``class_means`` is a (n_classes, D) table of per-class channel means
    (:data:`CLASS_MEANS_MULTI` for T1/T2/PD-like stacks,
    :data:`CLASS_MEANS_RGB` for the colorized rendering). Noise is
    i.i.d. per channel; background gets the same reduced-noise
    skull-stripped treatment as the grayscale phantom.
    """
    rng = np.random.default_rng(seed)
    h, w = height, width
    means = np.asarray(class_means, np.float64)
    d = means.shape[1]
    labels = phantom_labels(h, w, slice_pos)
    img = means[labels] + rng.normal(0.0, noise, size=(h, w, d))
    img = np.clip(img, 0, 255)
    bg = np.clip(rng.normal(0.0, noise * 0.25, size=(h, w, d)), 0, 255)
    img[labels == 0] = bg[labels == 0]
    return img.astype(np.uint8), labels


def phantom_slice_rgb(height: int = 217, width: int = 181,
                      slice_pos: float = 0.5, noise: float = 4.0,
                      seed: int = 0):
    """RGB phantom: (image uint8 (H, W, 3), labels (H, W))."""
    return phantom_slice_channels(height, width, slice_pos, noise, seed,
                                  class_means=CLASS_MEANS_RGB)


def add_impulse_noise(img: np.ndarray, frac: float = 0.05, seed: int = 0,
                      salt: int = 255, pepper: int = 0) -> np.ndarray:
    """Salt-and-pepper corruption: a ``frac`` fraction of pixels is
    replaced by ``salt`` or ``pepper`` (50/50). Returns a copy."""
    rng = np.random.default_rng(seed)
    out = np.array(img, copy=True)
    n = out.size
    k = int(round(frac * n))
    if k == 0:
        return out
    idx = rng.choice(n, size=k, replace=False)
    vals = np.where(rng.random(k) < 0.5, salt, pepper).astype(out.dtype)
    out.reshape(-1)[idx] = vals
    return out


# (gaussian sigma, salt-and-pepper fraction) sweep for the noise-
# robustness benchmark; the last level is the headline noisy-MRI case.
NOISE_LEVELS = ((4.0, 0.0), (8.0, 0.02), (12.0, 0.05), (16.0, 0.10))


def noisy_phantom_slice(height: int = 217, width: int = 181,
                        slice_pos: float = 0.5, noise: float = 12.0,
                        impulse: float = 0.05, seed: int = 0):
    """The noisy-MRI workload: a phantom slice with heavier Gaussian
    noise plus salt-and-pepper impulse corruption, and exact ground
    truth. Returns (image uint8 (H, W), labels int32 (H, W))."""
    img, labels = phantom_slice(height, width, slice_pos, noise, seed)
    return add_impulse_noise(img, impulse, seed=seed + 1), labels


def noisy_phantom_volume(depth: int = 8, height: int = 64, width: int = 64,
                         noise: float = 12.0, impulse: float = 0.05,
                         seed: int = 0):
    """A small noisy volume (stacked noisy slices with drifting anatomy)
    for the 3-D 6-neighbor spatial path. Returns (uint8 (D, H, W),
    int32 (D, H, W))."""
    imgs, labs = [], []
    for z in range(depth):
        im, la = noisy_phantom_slice(height, width,
                                     slice_pos=0.3 + 0.4 * z / max(depth, 1),
                                     noise=noise, impulse=impulse,
                                     seed=seed + z)
        imgs.append(im)
        labs.append(la)
    return np.stack(imgs), np.stack(labs)


def phantom_of_bytes(n_bytes: int, noise: float = 4.0, seed: int = 0):
    """A phantom whose uint8 image is exactly ``n_bytes`` (paper Table 3
    scales the dataset from 20 KB to 1 MB; 1 byte per pixel)."""
    width = 256
    height = max(n_bytes // width, 8)
    img, lab = phantom_slice(height, width, 0.5, noise, seed)
    img = img.ravel()[:n_bytes // width * width]
    lab = lab.ravel()[:img.size]
    return img, lab


def dice(pred_mask: np.ndarray, gt_mask: np.ndarray) -> float:
    """Dice Similarity Coefficient (paper Eq. 5)."""
    pred = np.asarray(pred_mask, bool)
    gt = np.asarray(gt_mask, bool)
    s = pred.sum() + gt.sum()
    if s == 0:
        return 1.0
    return 2.0 * np.logical_and(pred, gt).sum() / s


def dice_per_class(pred_labels, gt_labels, n_classes: int = N_CLASSES):
    """DSC per tissue class after matching predicted clusters to classes
    by mean intensity rank (FCM labels are permutation-arbitrary)."""
    return [dice(pred_labels == k, gt_labels == k) for k in range(n_classes)]


def match_labels_to_classes(labels, centers):
    """Relabel FCM clusters so cluster rank by center intensity matches
    class rank (background < CSF < GM < WM)."""
    order = np.argsort(np.asarray(centers).ravel())
    remap = np.empty_like(order)
    remap[order] = np.arange(len(order))
    return remap[np.asarray(labels)]


def match_labels_to_means(labels, centers, class_means):
    """Vector-feature analogue of :func:`match_labels_to_classes`: map
    each cluster to the class whose (D,)-mean row is nearest to the
    cluster's (D,) center. Intensity *rank* is meaningless for
    multi-modal contrast (CSF is dark on T1 but bright on T2), so the
    scalar matcher mis-ranks those; nearest-mean matching is
    contrast-agnostic. Non-injective maps are allowed (a degenerate fit
    may merge classes — DSC then punishes it)."""
    centers = np.asarray(centers, np.float64)
    if centers.ndim == 1:                    # scalar centers: (c,) -> (c, 1)
        centers = centers[:, None]
    means = np.asarray(class_means, np.float64)
    d2 = ((centers[:, None, :] - means[None, :, :]) ** 2).sum(-1)
    remap = np.argmin(d2, axis=1).astype(np.int64)
    return remap[np.asarray(labels)]
