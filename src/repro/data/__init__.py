from . import phantom  # noqa: F401
