"""Deterministic sharded data pipeline for LM training.

Synthetic token streams (no external datasets in this container) that are
*stateless*: batch contents are a pure function of (seed, step, global
position), so (a) every host generates exactly its own shard with zero
coordination, (b) restart/elastic re-mesh reproduces the identical
stream from the checkpointed step — data-parallel determinism is what
makes checkpoint/restart byte-reproducible.

The "language" is a Zipf-distributed token process with local n-gram
structure (next-token depends on previous token), so models actually
reduce loss on it — used by examples/train_lm.py.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional

import numpy as np

from repro.configs.base import ModelConfig, ShapeConfig


@dataclasses.dataclass(frozen=True)
class DataConfig:
    seed: int = 1234
    zipf_a: float = 1.3
    mix: float = 0.7        # weight of the n-gram component


def _rng_for(seed: int, step: int, host: int) -> np.random.Generator:
    return np.random.default_rng(
        np.random.SeedSequence([seed, step, host]))


def make_batch(cfg: ModelConfig, shape: ShapeConfig, step: int,
               dcfg: DataConfig = DataConfig(), host: int = 0,
               n_hosts: int = 1) -> Dict[str, np.ndarray]:
    """The host-local shard of the global batch for ``step``."""
    assert shape.global_batch % n_hosts == 0
    b = shape.global_batch // n_hosts
    s = shape.seq_len
    rng = _rng_for(dcfg.seed, step, host)
    v = cfg.vocab_size
    base = rng.zipf(dcfg.zipf_a, size=(b, s)).astype(np.int64) % v
    # first-order structure: with prob `mix`, token t = f(token_{t-1})
    shift = (base * 2654435761 + 12345) % v
    prev = np.roll(shift, 1, axis=1)
    gate = rng.random((b, s)) < dcfg.mix
    tokens = np.where(gate, prev, base).astype(np.int32)
    labels = np.roll(tokens, -1, axis=1)
    labels[:, -1] = 0
    out = {"tokens": tokens, "labels": labels}
    if cfg.is_encdec:
        out["frames"] = rng.standard_normal(
            (b, s, cfg.d_model)).astype(np.float32)
    if cfg.n_img_tokens:
        out["image_embeds"] = rng.standard_normal(
            (b, cfg.n_img_tokens, cfg.d_model)).astype(np.float32)
    return out


def batches(cfg: ModelConfig, shape: ShapeConfig, start_step: int = 0,
            dcfg: DataConfig = DataConfig(), host: int = 0,
            n_hosts: int = 1) -> Iterator[Dict[str, np.ndarray]]:
    step = start_step
    while True:
        yield make_batch(cfg, shape, step, dcfg, host, n_hosts)
        step += 1
