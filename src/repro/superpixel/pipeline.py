"""Superpixel compression pipeline: N pixels -> K superpixels -> FCM.

The multi-channel analogue of the histogram fast path. For grayscale,
``core/histogram.py`` compresses N pixels to 256 (value, count) pairs
and fits weighted FCM on those; for vector features no histogram
exists, but a SLIC over-segmentation plays the same role: K compact
superpixels with mean features and pixel counts are a weighted (K, D)
FCM problem, and the per-iteration cost drops from O(N·c·D) to
O(K·c·D) — N/K is typically 1000x. Segmentation quality survives
because superpixels adhere to boundaries (their within-group feature
variance is what the compression discards, exactly as the histogram
discards within-bin variance of 0 for 8-bit data).

Pipeline: :func:`compress` (SLIC -> features/weights/label_map), then
:func:`repro.core.vector_fcm.fit_vector_fcm` over the superpixel rows,
then a gather broadcasts each superpixel's cluster back through the
label map to full resolution.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import fcm as F

from . import slic as SL


@dataclasses.dataclass(frozen=True)
class SuperpixelFCMConfig(F.FCMConfig):
    """FCM hyper-parameters plus the SLIC compression knobs."""
    n_segments: int = 256
    compactness: float = 10.0
    slic_iters: int = 10
    slic_tol: float = 0.25

    def slic_params(self) -> SL.SLICParams:
        return SL.SLICParams(n_segments=self.n_segments,
                             compactness=self.compactness,
                             max_iters=self.slic_iters, tol=self.slic_tol)


@dataclasses.dataclass
class SuperpixelCompression:
    """The compressed payload: everything FCM needs, nothing per-pixel.
    ``weights`` may contain zeros (superpixels that lost every pixel);
    zero-weight rows are inert in the weighted fit and unreachable
    through ``label_map``."""
    features: jax.Array        # (K, D) mean feature per superpixel
    weights: jax.Array         # (K,) pixel counts
    label_map: jax.Array       # (H, W) int32 pixel -> superpixel id
    gy: int
    gx: int
    slic_iters: int


def compress(img, cfg: SuperpixelFCMConfig = SuperpixelFCMConfig(),
             use_pallas: Optional[bool] = None,
             interpret: Optional[bool] = None) -> SuperpixelCompression:
    """SLIC-compress an (H, W) or (H, W, D) image to (features, weights,
    label_map). The superpixel mean features come straight from the SLIC
    center rows (the update step already maintains them).

    ``use_pallas=None`` (the default — and what the serving engine's
    ingest uses) defers to the :mod:`repro.kernels.ops` dispatch
    registry: the Pallas assignment kernel on TPU, the jnp reference
    elsewhere (interpret-mode kernels are only for correctness tests,
    not serving)."""
    if use_pallas is None:
        from repro.kernels import ops as kops
        use_pallas = kops.select_step("slic_assign").name == "pallas"
    res = SL.fit_slic(img, cfg.slic_params(), use_pallas=use_pallas,
                      interpret=interpret)
    n_feat = res.centers.shape[1] - 2
    return SuperpixelCompression(features=res.centers[:, :n_feat],
                                 weights=res.counts,
                                 label_map=res.labels,
                                 gy=res.gy, gx=res.gx,
                                 slic_iters=res.n_iters)


def broadcast_labels(sp_labels: jax.Array,
                     label_map: jax.Array) -> jax.Array:
    """Per-superpixel cluster ids (K,) -> per-pixel labels (H, W) via one
    gather through the superpixel map."""
    return jnp.asarray(sp_labels, jnp.int32)[label_map]


def fit_superpixel(img, cfg: SuperpixelFCMConfig = SuperpixelFCMConfig(),
                   use_pallas: Optional[bool] = None,
                   interpret: Optional[bool] = None,
                   comp: Optional[SuperpixelCompression] = None,
                   ) -> Tuple[F.FCMResult, SuperpixelCompression]:
    """End-to-end superpixel-compressed FCM segmentation.

    Returns the :class:`repro.core.fcm.FCMResult` with full-resolution
    (H, W) labels plus the compression it rode on (pass ``comp`` to
    reuse an existing compression, e.g. the serving engine's
    ingest-time one)."""
    if comp is None:
        comp = compress(img, cfg, use_pallas=use_pallas, interpret=interpret)
    from repro.core import solver as SV
    res = SV.solve(SV.vector_problem(comp.features, comp.weights, cfg), cfg)
    labels = broadcast_labels(res.labels, comp.label_map)
    return F.FCMResult(centers=res.centers, labels=labels,
                       n_iters=res.n_iters, final_delta=res.final_delta,
                       membership=res.membership), comp
