"""Superpixel compression subsystem: SLIC over-segmentation as the
multi-channel analogue of the 1-D intensity histogram, plus the
compress -> weighted-vector-FCM -> broadcast pipeline."""
from .slic import SLICParams, SLICResult, fit_slic  # noqa: F401
from .pipeline import (  # noqa: F401
    SuperpixelCompression, SuperpixelFCMConfig, compress, fit_superpixel)
