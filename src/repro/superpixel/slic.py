"""SLIC superpixels in JAX (grid-seeded local k-means, gSLICr-style).

SLIC (Achanta et al. 2012; GPU formulation gSLICr, Ren et al. 2015)
over-segments an image into K compact clusters by k-means in the joint
(feature, position) space, with one crucial restriction that makes it
O(N) per iteration instead of O(N·K): centers live on a (gy, gx) grid
and each pixel only ever competes among the ≤ 9 centers of its own and
adjacent grid cells. Both update equations are the weighted sums FCM
already uses, so the whole fit runs device-resident as the same
``centers -> centers'`` fixed point inside
:func:`repro.core.fcm._while_centers`.

Distance (squared, per candidate center k):

    d2 = ||f_i - f_k||^2 + (compactness / S)^2 * ||p_i - p_k||^2

with ``S = sqrt(sy * sx)`` the seed-grid interval, so ``compactness``
trades color fidelity against spatial regularity in the units of the
feature range (10 is the standard choice for 0..255 data).

Two assignment implementations drive the same loop:

* :func:`assign_ref` — pure-jnp: gather the 3x3 candidate centers per
  pixel and keep a running argmin (this module), and
* the Pallas kernel in :mod:`repro.kernels.slic_assign`
  (``use_pallas=True``), which tiles pixels into row blocks with the
  whole (small) center grid resident in VMEM.

Both accumulate the distance terms in the same order, so interpret-mode
parity is exact up to genuine distance ties (which both resolve to the
lowest center index). They are registered in the
:mod:`repro.kernels.ops` dispatch registry under kind ``"slic_assign"``;
``use_pallas=None`` lets the registry pick by platform.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import fcm as F
from repro.core import solver as SV

_BIG = 3.4e38


@dataclasses.dataclass(frozen=True)
class SLICParams:
    """``n_segments`` is the *target* K; the actual K = gy * gx comes
    from :func:`grid_shape` and matches the image aspect. ``tol`` is the
    max center movement (joint feature/pixel units) that counts as
    converged — SLIC needs no fine tolerance, ~10 iterations suffice."""
    n_segments: int = 256
    compactness: float = 10.0
    max_iters: int = 10
    tol: float = 0.25


@dataclasses.dataclass
class SLICResult:
    labels: jax.Array          # (H, W) int32 superpixel ids in [0, K)
    centers: jax.Array         # (K, D+2) rows [features..., y, x]
    counts: jax.Array          # (K,) pixels per superpixel (may be 0)
    gy: int
    gx: int
    n_iters: int
    final_delta: float


def _as_hwd(img: jax.Array) -> jax.Array:
    """Promote (H, W) grayscale to (H, W, 1)."""
    img = jnp.asarray(img, jnp.float32)
    if img.ndim == 2:
        img = img[:, :, None]
    if img.ndim != 3:
        raise ValueError(f"SLIC needs (H, W) or (H, W, D) input, "
                         f"got shape {img.shape}")
    return img


def grid_shape(h: int, w: int, n_segments: int) -> Tuple[int, int]:
    """Seed-grid dims (gy, gx) with roughly square cells and
    gy * gx ~ n_segments."""
    step = max((h * w / max(n_segments, 1)) ** 0.5, 1.0)
    return max(int(round(h / step)), 1), max(int(round(w / step)), 1)


def spatial_weight(h: int, w: int, gy: int, gx: int,
                   compactness: float) -> float:
    """(compactness / S)^2 for the joint distance, S the grid interval."""
    s2 = (h / gy) * (w / gx)
    return float(compactness) ** 2 / s2


def seed_centers(img: jax.Array, gy: int, gx: int) -> jax.Array:
    """Grid seeding: one center per cell at the cell-center pixel,
    features sampled there. Returns (gy*gx, D+2) rows [feat..., y, x]."""
    img = _as_hwd(img)
    h, w, _ = img.shape
    ys = jnp.clip(((jnp.arange(gy) + 0.5) * (h / gy)).astype(jnp.int32),
                  0, h - 1)
    xs = jnp.clip(((jnp.arange(gx) + 0.5) * (w / gx)).astype(jnp.int32),
                  0, w - 1)
    yy, xx = jnp.meshgrid(ys, xs, indexing="ij")
    feats = img[yy, xx]                              # (gy, gx, D)
    pos = jnp.stack([yy.astype(jnp.float32), xx.astype(jnp.float32)],
                    axis=-1)
    return jnp.concatenate([feats, pos], axis=-1).reshape(gy * gx, -1)


def assign_ref(img: jax.Array, centers: jax.Array, gy: int, gx: int,
               sw: float) -> jax.Array:
    """Pure-jnp assignment: each pixel's label is the argmin of the joint
    distance over the ≤ 9 centers of its 3x3 grid-cell neighborhood
    (running min in candidate order == lowest center index on ties, the
    same resolution as the kernel's argmin). Returns (H, W) int32."""
    img = _as_hwd(img)
    h, w, d = img.shape
    grid = centers.reshape(gy, gx, d + 2)
    yy = jax.lax.broadcasted_iota(jnp.float32, (h, w), 0)
    xx = jax.lax.broadcasted_iota(jnp.float32, (h, w), 1)
    # Multiply by the f32 reciprocal (not divide): the Pallas kernel does
    # the same, so cell coords agree bitwise at cell boundaries.
    inv_sy = jnp.float32(1.0 / (h / gy))
    inv_sx = jnp.float32(1.0 / (w / gx))
    pcy = jnp.clip((yy * inv_sy).astype(jnp.int32), 0, gy - 1)
    pcx = jnp.clip((xx * inv_sx).astype(jnp.int32), 0, gx - 1)
    best_d = jnp.full((h, w), _BIG, jnp.float32)
    best_k = jnp.zeros((h, w), jnp.int32)
    for dy in (-1, 0, 1):
        for dx in (-1, 0, 1):
            cyc = jnp.clip(pcy + dy, 0, gy - 1)
            cxc = jnp.clip(pcx + dx, 0, gx - 1)
            cand = grid[cyc, cxc]                    # (H, W, D+2)
            d2 = jnp.zeros((h, w), jnp.float32)
            for ch in range(d):                      # same order as kernel
                d2 = d2 + (img[..., ch] - cand[..., ch]) ** 2
            d2 = d2 + sw * (yy - cand[..., d]) ** 2
            d2 = d2 + sw * (xx - cand[..., d + 1]) ** 2
            k = (cyc * gx + cxc).astype(jnp.int32)
            better = d2 < best_d
            best_d = jnp.where(better, d2, best_d)
            best_k = jnp.where(better, k, best_k)
    return best_k


def update_centers(img: jax.Array, labels: jax.Array, old: jax.Array,
                   weights: Optional[jax.Array] = None):
    """Scatter-add center update: each superpixel's new row is the mean
    [feature..., y, x] of its pixels (``weights`` zeroes padded pixels in
    the Pallas path). Empty superpixels keep their old row. Returns
    (centers (K, D+2), counts (K,))."""
    img = _as_hwd(img)
    h, w, d = img.shape
    k = old.shape[0]
    yy = jax.lax.broadcasted_iota(jnp.float32, (h, w), 0)
    xx = jax.lax.broadcasted_iota(jnp.float32, (h, w), 1)
    fp = jnp.concatenate([img, yy[..., None], xx[..., None]],
                         axis=-1).reshape(-1, d + 2)
    wt = (jnp.ones((h * w,), jnp.float32) if weights is None
          else jnp.asarray(weights, jnp.float32).reshape(-1))
    lab = labels.reshape(-1)
    sums = jnp.zeros((k, d + 2), jnp.float32).at[lab].add(wt[:, None] * fp)
    cnt = jnp.zeros((k,), jnp.float32).at[lab].add(wt)
    new = jnp.where(cnt[:, None] > 0, sums / jnp.maximum(cnt, 1.0)[:, None],
                    old)
    return new, cnt


# ---------------------------------------------------------------------------
# Fused fit: assign + update as one center fixed point
# ---------------------------------------------------------------------------

@partial(jax.jit, static_argnames=("gy", "gx", "sw", "tol", "max_iters"))
def _slic_loop_ref(img, v0, gy, gx, sw, tol, max_iters):
    from repro.kernels import ops as kops
    assign = kops.build_step("slic_assign", "reference", gy=gy, gx=gx,
                             sw=sw)
    step = lambda v: update_centers(img, assign(img, v), v)[0]
    return SV.while_centers(step, v0, tol, max_iters)


@partial(jax.jit, static_argnames=("h", "w", "gy", "gx", "sw", "tol",
                                   "max_iters", "block_rows", "interpret"))
def _slic_loop_pallas(xpad, wpad, v0, h, w, gy, gx, sw, tol, max_iters,
                      block_rows, interpret):
    from repro.kernels import ops as kops
    assign = kops.build_step("slic_assign", "pallas", h=h, w=w, gy=gy,
                             gx=gx, sw=sw, block_rows=block_rows,
                             interpret=interpret)

    def step(v):
        return update_centers(jnp.moveaxis(xpad, 0, -1), assign(xpad, v),
                              v, weights=wpad)[0]

    return SV.while_centers(step, v0, tol, max_iters)


def fit_slic(img, params: SLICParams = SLICParams(),
             use_pallas: Optional[bool] = False,
             block_rows: Optional[int] = None,
             interpret: Optional[bool] = None) -> SLICResult:
    """Run SLIC to convergence (or ``max_iters``) on a 2-D grayscale or
    (H, W, D) multi-channel image; the assign+update iteration is one
    device-resident ``while_loop`` driven by the solver core's
    convergence test. ``use_pallas=True`` swaps the assignment for the
    tiled Pallas kernel (padding happens once, outside the loop);
    ``use_pallas=None`` lets the :mod:`repro.kernels.ops` registry pick
    by platform; ``block_rows=None`` sizes the kernel's row blocks to
    the VMEM budget for this (K, W)."""
    if use_pallas is None:
        from repro.kernels import ops as kops
        use_pallas = kops.select_step("slic_assign").name == "pallas"
    img = _as_hwd(img)
    h, w, d = img.shape
    gy, gx = grid_shape(h, w, params.n_segments)
    sw = spatial_weight(h, w, gy, gx, params.compactness)
    v0 = seed_centers(img, gy, gx)
    if use_pallas:
        from repro.kernels import ops as kops
        from repro.kernels.slic_assign import auto_block_rows
        if block_rows is None:
            block_rows = auto_block_rows(gy * gx, w)
        xpad, wpad = kops.tile_channels(img, block_rows)
        v, delta, it = _slic_loop_pallas(
            xpad, wpad, v0, h, w, gy, gx, sw, params.tol,
            params.max_iters, block_rows, interpret)
        labels = kops.slic_assign(xpad, v, h, w, gy, gx, sw, block_rows,
                                  interpret)
        _, counts = update_centers(jnp.moveaxis(xpad, 0, -1), labels, v,
                                   weights=wpad)
        labels = labels[:h, :w]
    else:
        v, delta, it = _slic_loop_ref(img, v0, gy, gx, sw, params.tol,
                                      params.max_iters)
        labels = assign_ref(img, v, gy, gx, sw)
        _, counts = update_centers(img, labels, v)
    return SLICResult(labels=labels, centers=v, counts=counts, gy=gy,
                      gx=gx, n_iters=int(it), final_delta=float(delta))
