"""Pallas TPU kernel for the SLIC assignment step.

The assignment is where SLIC spends its time — every pixel, every
iteration, evaluates a joint color+spatial distance against its 3x3
neighborhood of grid centers. Here each grid step loads one
``(block_rows, Wp)`` row block of every channel plane plus the *entire*
center grid into VMEM (K superpixel centers are a few KB — far smaller
than a pixel tile), computes the distances to all K centers with the
channel/spatial terms accumulated in the reference's order, masks
centers outside the pixel's 3x3 grid-cell neighborhood to +inf, and
writes the per-pixel argmin label tile.

Masking instead of gathering keeps the kernel gather-free: a pixel's
candidate set is exactly {k : |cell(k) - cell(pixel)| <= 1 per axis},
which is a pure iota/compare predicate on the (Kp, R, Wp) distance
block. ``jnp.argmin`` ties resolve to the lowest center index, matching
the reference's running-min candidate order.

VMEM envelope: the distance block is Kp * block_rows * Wp floats (Kp is
K rounded up to 128 lanes) — ~4 MB for K=256, block_rows=8, Wp=512.
Larger center grids need smaller ``block_rows``.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANES = 128
_BIG = 3.4e38


def auto_block_rows(k: int, width: int,
                    budget_bytes: int = 4 * 1024 * 1024) -> int:
    """Pick block_rows so the (Kp, block_rows, Wp) distance block stays
    within ``budget_bytes`` of VMEM: wide images or large center grids
    get shallower row blocks (down to 1) instead of overflowing, small
    ones get deeper blocks (up to 64, multiples of 8 for sublane
    alignment)."""
    kp = k + (-k) % LANES
    wp = width + (-width) % LANES
    rows = budget_bytes // (kp * wp * 4)
    if rows >= 8:
        return min(rows - rows % 8, 64)
    return max(int(rows), 1)


def _slic_assign_kernel(x_ref, cf_ref, cyx_ref, lab_ref, *, n_channels,
                        k, gy, gx, inv_sy, inv_sx, sw, block_rows):
    i = pl.program_id(0)
    xs = x_ref[...].astype(jnp.float32)             # (D, R, Wp)
    cf = cf_ref[...].astype(jnp.float32)            # (D, Kp)
    cyx = cyx_ref[...].astype(jnp.float32)          # (2, Kp)
    r, wp = xs.shape[1], xs.shape[2]
    kp = cf.shape[1]
    # Global pixel coordinates of this row block.
    y = (i * block_rows
         + jax.lax.broadcasted_iota(jnp.float32, (r, wp), 0))
    x = jax.lax.broadcasted_iota(jnp.float32, (r, wp), 1)
    # Pixel and center grid-cell coords (reciprocal-multiply, bitwise
    # identical to assign_ref's).
    pcy = jnp.clip((y * inv_sy).astype(jnp.int32), 0, gy - 1)
    pcx = jnp.clip((x * inv_sx).astype(jnp.int32), 0, gx - 1)
    kk = jax.lax.broadcasted_iota(jnp.int32, (kp, 1, 1), 0)
    kgy = kk // gx
    kgx = kk - kgy * gx
    # Joint distances to every center, channel terms first (same
    # accumulation order as assign_ref), then the weighted spatial terms.
    d2 = jnp.zeros((kp, r, wp), jnp.float32)
    for ch in range(n_channels):
        d2 = d2 + (xs[ch][None] - cf[ch][:, None, None]) ** 2
    d2 = d2 + sw * (y[None] - cyx[0][:, None, None]) ** 2
    d2 = d2 + sw * (x[None] - cyx[1][:, None, None]) ** 2
    # 3x3 grid-cell candidate mask (+ lane padding beyond K).
    valid = (jnp.abs(kgy - pcy[None]) <= 1) \
        & (jnp.abs(kgx - pcx[None]) <= 1) & (kk < k)
    d2 = jnp.where(valid, d2, _BIG)
    lab_ref[...] = jnp.argmin(d2, axis=0).astype(jnp.int32)


def slic_assign_pallas(xp: jax.Array, centers: jax.Array, gy: int, gx: int,
                       sy: float, sx: float, sw: float,
                       block_rows: int = 8,
                       interpret: bool = False) -> jax.Array:
    """xp (D, Hp, Wp) padded channel planes, centers (K, D+2) rows
    [features..., y, x] -> labels (Hp, Wp) int32. Hp must divide by
    block_rows and Wp by 128 (``ops.tile_channels`` pads); padded pixels
    get well-formed labels which the caller's validity weights drop."""
    d, hp, wp = xp.shape
    assert hp % block_rows == 0 and wp % LANES == 0, (xp.shape, block_rows)
    k = centers.shape[0]
    assert k == gy * gx and centers.shape[1] == d + 2, (centers.shape, gy, gx)
    kpad = (-k) % LANES
    cpad = jnp.concatenate(
        [centers.astype(jnp.float32),
         jnp.zeros((kpad, d + 2), jnp.float32)])     # masked via kk < k
    cf = cpad[:, :d].T                               # (D, Kp)
    cyx = cpad[:, d:].T                              # (2, Kp)
    kp = k + kpad
    kernel = partial(_slic_assign_kernel, n_channels=d, k=k, gy=gy, gx=gx,
                     inv_sy=float(1.0 / sy), inv_sx=float(1.0 / sx),
                     sw=float(sw), block_rows=block_rows)
    return pl.pallas_call(
        kernel,
        grid=(hp // block_rows,),
        in_specs=[
            pl.BlockSpec((d, block_rows, wp), lambda i: (0, i, 0)),
            pl.BlockSpec((d, kp), lambda i: (0, 0)),
            pl.BlockSpec((2, kp), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_rows, wp), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((hp, wp), jnp.int32),
        interpret=interpret,
    )(xp, cf, cyx)
