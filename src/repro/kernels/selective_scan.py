"""Pallas TPU kernel: Mamba selective-scan (hillclimb #1, beyond-paper).

Why a kernel: the SSM recurrence h_t = exp(dt_t A) h_t-1 + (dt_t u_t) B_t
is elementwise-diagonal over (d_inner, d_state) with decay coupled in
BOTH dims, so unlike RWKV/GLA there is no jnp chunked form that avoids
materializing state-sized tensors per step — XLA cannot fuse across
`lax.scan` steps and the measured HBM traffic of the scan lowering is
~100 MB/step/device (EXPERIMENTS.md §Perf). This kernel keeps the state
resident in VMEM for a whole sequence block and streams u/dt/B/C through:
HBM traffic collapses to the kernel's own IO.

Tiling: grid over (batch, d_inner tiles, seq blocks). Each grid step
loads (seq_blk, di_tile) slabs of u/dt plus (seq_blk, d_state) B/C,
iterates time in-VMEM with a fori_loop, writes the (seq_blk, di_tile) y
slab. State (di_tile, d_state) is carried across seq blocks in a VMEM
accumulator (TPU grids iterate sequentially, so the rightmost grid dim
walks the sequence with the state block pinned).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _selective_scan_kernel(u_ref, dt_ref, b_ref, c_ref, a_ref, y_ref,
                           h_ref, *, seq_blk: int):
    sblk = pl.program_id(2)

    @pl.when(sblk == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    a = a_ref[...]                                   # (di_t, ds)
    u = u_ref[...][0]                                # (seq_blk, di_t)
    dt = dt_ref[...][0]
    bmat = b_ref[...][0]                             # (seq_blk, ds)
    cmat = c_ref[...][0]

    def step(t, carry):
        h, y = carry
        dt_t = dt[t][:, None]                        # (di_t, 1)
        da = jnp.exp(dt_t * a)                       # (di_t, ds)
        h = da * h + (dt_t * u[t][:, None]) * bmat[t][None, :]
        y = y.at[t].set(jnp.sum(h * cmat[t][None, :], axis=-1))
        return h, y

    y0 = jnp.zeros(u.shape, jnp.float32)
    h, y = jax.lax.fori_loop(0, seq_blk, step,
                             (h_ref[...].astype(jnp.float32), y0))
    h_ref[...] = h
    y_ref[...] = y[None].astype(y_ref.dtype)


def selective_scan_pallas(u, dt, bmat, cmat, a, *, di_tile: int = 512,
                          seq_blk: int = 128, interpret: bool = False):
    """u, dt: (B, S, di); bmat, cmat: (B, S, ds); a: (di, ds) ->
    y (B, S, di) fp32. S % seq_blk == 0, di % di_tile == 0."""
    bsz, s, di = u.shape
    ds = bmat.shape[-1]
    di_tile = min(di_tile, di)
    seq_blk = min(seq_blk, s)
    assert s % seq_blk == 0 and di % di_tile == 0
    grid = (bsz, di // di_tile, s // seq_blk)
    y, _ = pl.pallas_call(
        partial(_selective_scan_kernel, seq_blk=seq_blk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, seq_blk, di_tile), lambda b, d, t: (b, t, d)),
            pl.BlockSpec((1, seq_blk, di_tile), lambda b, d, t: (b, t, d)),
            pl.BlockSpec((1, seq_blk, ds), lambda b, d, t: (b, t, 0)),
            pl.BlockSpec((1, seq_blk, ds), lambda b, d, t: (b, t, 0)),
            pl.BlockSpec((di_tile, ds), lambda b, d, t: (d, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, seq_blk, di_tile), lambda b, d, t: (b, t, d)),
            pl.BlockSpec((di_tile, ds), lambda b, d, t: (d, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bsz, s, di), jnp.float32),
            jax.ShapeDtypeStruct((di_tile, ds), jnp.float32),
        ],
        interpret=interpret,
    )(u, dt, bmat, cmat, a)
    return y
