# Pallas TPU kernels for the FCM compute hot-spots (the paper's CUDA
# kernels, adapted to VMEM tiling — see DESIGN.md §2). Validated against
# ref.py oracles with interpret=True on CPU.
from . import (defuzzify, fcm_centers, fcm_membership, fcm_resident,  # noqa: F401,E501
               fcm_spatial, histogram_bin, ops, ref)
