"""Jitted public wrappers around the FCM Pallas kernels, plus the step
dispatch registry the solver core routes through.

Handles 1-D <-> (rows, 128) tiling, padding with validity weights, and
interpret-mode fallback on non-TPU backends (kernel bodies execute in
Python on CPU for correctness validation, per the Pallas docs).

The registry at the bottom maps a step *kind* (``"flat"`` weighted-row
update, ``"stencil"`` FCM_S update, ``"slic_assign"``) to its available
implementations (``"pallas"`` kernels here, ``"reference"`` pure-jnp),
and :func:`select_step` picks one by platform and problem shape. New
variants register a builder instead of growing per-module wrappers.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from . import fcm_centers as KC
from . import fcm_membership as KM
from . import fcm_spatial as KS
from . import slic_assign as KSL

LANES = KM.LANES


def _interpret_default() -> bool:
    return jax.default_backend() != "tpu"


def _tile(x: jax.Array, block_rows: int):
    """(N,) -> ((M,128) pixels, (M,128) weights, N) with M % block_rows == 0."""
    n = x.shape[0]
    per_block = block_rows * LANES
    n_pad = (-n) % per_block
    xp = jnp.concatenate([x.astype(jnp.float32),
                          jnp.zeros((n_pad,), jnp.float32)])
    w = jnp.concatenate([jnp.ones((n,), jnp.float32),
                         jnp.zeros((n_pad,), jnp.float32)])
    m_rows = (n + n_pad) // LANES
    return xp.reshape(m_rows, LANES), w.reshape(m_rows, LANES), n


def tile_rows(x: jax.Array, w: jax.Array, block_rows: int):
    """Weighted analogue of :func:`_tile`: tiles pixels AND their row
    weights (histogram counts, superpixel sizes; padding weighs 0), so
    the fused-partials kernel runs weighted flat problems unchanged."""
    n = x.shape[0]
    per_block = block_rows * LANES
    n_pad = (-n) % per_block
    xp = jnp.concatenate([x.astype(jnp.float32),
                          jnp.zeros((n_pad,), jnp.float32)])
    wp = jnp.concatenate([w.astype(jnp.float32),
                          jnp.zeros((n_pad,), jnp.float32)])
    m_rows = (n + n_pad) // LANES
    return xp.reshape(m_rows, LANES), wp.reshape(m_rows, LANES)


def tile_grid(img: jax.Array, block_rows: int = 64):
    """Shape-preserving analogue of :func:`_tile` for stencil kernels:
    pads a 2-D image to (Hp % block_rows == 0, Wp % 128 == 0) or a 3-D
    volume to (D, Hp % 8 == 0, Wp % 128 == 0) and returns the padded
    pixels plus matching validity weights (0 on padding)."""
    img = jnp.asarray(img, jnp.float32)
    if img.ndim == 2:
        h, w = img.shape
        pad = ((0, (-h) % block_rows), (0, (-w) % LANES))
    elif img.ndim == 3:
        _, h, w = img.shape
        pad = ((0, 0), (0, (-h) % 8), (0, (-w) % LANES))
    else:
        raise ValueError(f"tile_grid needs rank 2 or 3, got {img.shape}")
    return jnp.pad(img, pad), jnp.pad(jnp.ones(img.shape, jnp.float32), pad)


def tile_channels(img: jax.Array, block_rows: int = 8):
    """Channel-major analogue of :func:`tile_grid` for the SLIC kernel:
    an (H, W, D) image (or (H, W) grayscale) becomes (D, Hp, Wp) planes
    with Hp % block_rows == 0 and Wp % 128 == 0, plus a single (Hp, Wp)
    validity sheet (0 on padding) shared by every channel."""
    img = jnp.asarray(img, jnp.float32)
    if img.ndim == 2:
        img = img[:, :, None]
    if img.ndim != 3:
        raise ValueError(f"tile_channels needs (H, W[, D]), got {img.shape}")
    h, w, _ = img.shape
    pad = ((0, (-h) % block_rows), (0, (-w) % LANES), (0, 0))
    xpad = jnp.moveaxis(jnp.pad(img, pad), -1, 0)
    wpad = jnp.pad(jnp.ones((h, w), jnp.float32), pad[:2])
    return xpad, wpad


@partial(jax.jit, static_argnames=("h", "w", "gy", "gx", "sw", "block_rows",
                                   "interpret"))
def _slic_assign_impl(xpad, centers, h, w, gy, gx, sw, block_rows,
                      interpret):
    return KSL.slic_assign_pallas(xpad, centers, gy, gx, h / gy, w / gx,
                                  sw, block_rows, interpret)


def slic_assign(xpad, centers, h: int, w: int, gy: int, gx: int, sw: float,
                block_rows: int = 8, interpret=None) -> jax.Array:
    """SLIC assignment via Pallas: pre-tiled (D, Hp, Wp) planes from
    :func:`tile_channels` + (K, D+2) centers -> (Hp, Wp) int32 labels.
    ``h``/``w`` are the *unpadded* dims (they set the cell intervals)."""
    if interpret is None:
        interpret = _interpret_default()
    return _slic_assign_impl(xpad, centers, h, w, gy, gx, sw, block_rows,
                             interpret)


@partial(jax.jit, static_argnames=("m", "block_rows", "interpret"))
def _membership_impl(x, v, m, block_rows, interpret):
    x2d, _, n = _tile(x, block_rows)
    u = KM.membership_pallas(x2d, v, m, block_rows, interpret)
    c = v.shape[0]
    return u.reshape(c, -1)[:, :n]


def membership(x, v, m: float = 2.0, block_rows: int = 64,
               interpret=None) -> jax.Array:
    """Eq. 4 membership via Pallas; x (N,), v (c,) -> u (c, N)."""
    if interpret is None:
        interpret = _interpret_default()
    return _membership_impl(x, v, m, block_rows, interpret)


@partial(jax.jit, static_argnames=("m", "block_rows", "interpret"))
def _center_partials_impl(x, u, m, block_rows, interpret):
    x2d, w2d, n = _tile(x, block_rows)
    c = u.shape[0]
    pad = x2d.size - n
    u_p = jnp.concatenate(
        [u.astype(jnp.float32), jnp.zeros((c, pad), jnp.float32)], axis=1)
    u3d = u_p.reshape(c, -1, LANES)
    num, den = KC.center_partials_pallas(x2d, u3d, w2d, m, block_rows,
                                         interpret)
    return num[:, None], den          # num (c,1) matches (c,F) center layout


def center_partials(x, u, m: float = 2.0, block_rows: int = 64,
                    interpret=None):
    """Eq. 3 partial sums from materialized membership (paper-faithful)."""
    if interpret is None:
        interpret = _interpret_default()
    return _center_partials_impl(x, u, m, block_rows, interpret)


@partial(jax.jit, static_argnames=("m", "block_rows", "interpret"))
def _fused_step_impl(x, v, m, block_rows, interpret):
    x2d, w2d, n = _tile(x, block_rows)
    num, den = KC.fused_partials_pallas(x2d, w2d, v, m, block_rows, interpret)
    return num / jnp.maximum(den, 1e-12)


def fused_step(x, v, m: float = 2.0, block_rows: int = 64, interpret=None):
    """One fused v -> v' FCM iteration (single kernel launch)."""
    if interpret is None:
        interpret = _interpret_default()
    return _fused_step_impl(x, v, m, block_rows, interpret)


def fused_partials(x2d, w2d, v, m: float = 2.0, block_rows: int = 64,
                   interpret=None):
    """Raw pre-tiled partials — used by the distributed fit where the
    psum happens outside the kernel."""
    if interpret is None:
        interpret = _interpret_default()
    return KC.fused_partials_pallas(x2d, w2d, v, m, block_rows, interpret)


def spatial_partials(xpad, wpad, v, m: float = 2.0, alpha: float = 1.0,
                     neighbors: int = 4, block_rows: int = 64,
                     interpret=None):
    """Raw pre-tiled FCM_S partials (Eq. 3' numerator/denominator) from
    the fused stencil kernel; inputs from :func:`tile_grid`. 3-D volumes
    always use the 6-connected stencil."""
    if interpret is None:
        interpret = _interpret_default()
    if xpad.ndim == 2:
        return KS.spatial_partials_pallas_2d(xpad, wpad, v, m, alpha,
                                             neighbors, block_rows, interpret)
    if neighbors != 6:
        raise ValueError(f"3-D neighborhoods are 6-connected, "
                         f"got {neighbors}")
    return KS.spatial_partials_pallas_3d(xpad, wpad, v, m, alpha, interpret)


@partial(jax.jit, static_argnames=("m", "alpha", "neighbors", "block_rows",
                                   "interpret"))
def _spatial_step_impl(img, v, m, alpha, neighbors, block_rows, interpret):
    xpad, wpad = tile_grid(img, block_rows)
    num, den = spatial_partials(xpad, wpad, v, m, alpha, neighbors,
                                block_rows, interpret)
    return num / jnp.maximum((1.0 + alpha) * den, 1e-12)


def spatial_step(img, v, m: float = 2.0, alpha: float = 1.0,
                 neighbors: int = 4, block_rows: int = 64, interpret=None):
    """One fused FCM_S v -> v' iteration over a 2-D image or 3-D volume
    (stencil average + membership + center reduction, single launch)."""
    if interpret is None:
        interpret = _interpret_default()
    return _spatial_step_impl(img, v, m, alpha, neighbors, block_rows,
                              interpret)


# ---------------------------------------------------------------------------
# Step dispatch registry (what repro.core.solver routes through)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class StepImpl:
    """One registered step implementation.

    ``build(**params) -> callable`` constructs the actual step (called
    at trace time inside the solver's jitted loops); ``platforms``
    limits compiled execution (off-platform falls back to interpret
    mode for Pallas impls); ``scalar_only`` marks impls restricted to
    1-D feature rows; ``batched`` marks impls safe under ``vmap``.
    """
    kind: str
    name: str
    build: Callable[..., Callable]
    platforms: Tuple[str, ...] = ("cpu", "gpu", "tpu")
    scalar_only: bool = False
    batched: bool = True


_STEP_REGISTRY: Dict[Tuple[str, str], StepImpl] = {}


def register_step(kind: str, name: str, *, platforms=("cpu", "gpu", "tpu"),
                  scalar_only: bool = False, batched: bool = True):
    """Decorator: register a step builder under (kind, name). Adding an
    FCM variant = registering its step here + a problem factory in
    ``core/solver.py`` — no new fit module."""
    def deco(build):
        _STEP_REGISTRY[(kind, name)] = StepImpl(
            kind=kind, name=name, build=build, platforms=tuple(platforms),
            scalar_only=scalar_only, batched=batched)
        return build
    return deco


def step_impls(kind: Optional[str] = None):
    """All registered implementations (of one kind, if given)."""
    return [impl for (k, _), impl in sorted(_STEP_REGISTRY.items())
            if kind is None or k == kind]


def select_step(kind: str, *, prefer: Optional[str] = None,
                platform: Optional[str] = None, n_feat: int = 1,
                batched: bool = False) -> StepImpl:
    """Dispatch: pick the step implementation for a problem shape and
    platform. ``prefer`` forces a name; otherwise the Pallas kernel wins
    on TPU when eligible (right platform, feature-dim and vmap support)
    and the pure-jnp reference runs everywhere else."""
    kinds = sorted({k for k, _ in _STEP_REGISTRY})
    if kind not in kinds:
        raise ValueError(f"unknown step kind {kind!r}; one of {kinds}")
    if prefer is not None:
        impl = _STEP_REGISTRY.get((kind, prefer))
        if impl is None:
            names = [i.name for i in step_impls(kind)]
            raise ValueError(f"no {kind!r} step implementation named "
                             f"{prefer!r}; registered: {names}")
        if impl.scalar_only and n_feat != 1:
            raise ValueError(f"{kind}/{prefer} handles scalar (D=1) "
                             f"features only, got D={n_feat}")
        if batched and not impl.batched:
            raise ValueError(f"{kind}/{prefer} does not support batched "
                             f"(vmapped) solves")
        return impl
    platform = platform or jax.default_backend()
    pallas = _STEP_REGISTRY.get((kind, "pallas"))
    if (pallas is not None and platform in pallas.platforms
            and not (pallas.scalar_only and n_feat != 1)
            and not (batched and not pallas.batched)):
        return pallas
    return _STEP_REGISTRY[(kind, "reference")]


def build_step(kind: str, name: str, **params) -> Callable:
    """Construct the (kind, name) step with the given problem arrays."""
    return _STEP_REGISTRY[(kind, name)].build(**params)


# -- registered implementations ---------------------------------------------
# Builders import the reference math lazily: repro.core imports this
# module lazily too, and resolving both at call time keeps the package
# import graph acyclic.

@register_step("flat", "reference")
def _flat_reference(feats, weights, m, **_):
    """Canonical pure-jnp weighted-row update (repro.core.solver)."""
    from repro.core import solver as SV
    return lambda v: SV.weighted_center_step(feats, weights, v, m)


@register_step("flat", "pallas", platforms=("tpu",), scalar_only=True,
               batched=False)
def _flat_pallas(x2d, w2d, m, block_rows=64, interpret=None, **_):
    """Fused membership+center-partials kernel over pre-tiled rows."""
    if interpret is None:
        interpret = _interpret_default()

    def step(v):
        num, den = KC.fused_partials_pallas(x2d, w2d, v[:, 0], m,
                                            block_rows, interpret)
        return (num / jnp.maximum(den, 1e-12))[:, None]
    return step


@register_step("stencil", "reference")
def _stencil_reference(img, m, alpha, neighbors, **_):
    """Pure-jnp shifted-array FCM_S step (repro.core.spatial)."""
    from repro.core import spatial as SP
    return lambda v: SP.spatial_center_step(img, v[:, 0], m, alpha,
                                            neighbors)[:, None]


@register_step("stencil", "pallas", platforms=("tpu",), batched=False)
def _stencil_pallas(xpad, wpad, m, alpha, neighbors, block_rows=64,
                    interpret=None, **_):
    """Fused stencil+membership+center-reduction kernel over a pre-tiled
    grid (inputs from :func:`tile_grid`)."""
    if interpret is None:
        interpret = _interpret_default()

    def step(v):
        num, den = spatial_partials(xpad, wpad, v[:, 0], m, alpha,
                                    neighbors, block_rows, interpret)
        return (num / jnp.maximum((1.0 + alpha) * den, 1e-12))[:, None]
    return step


@register_step("slic_assign", "reference", batched=False)
def _slic_reference(gy, gx, sw, **_):
    """Pure-jnp 3x3-candidate SLIC assignment (repro.superpixel.slic)."""
    from repro.superpixel import slic as SL
    return lambda img, centers: SL.assign_ref(img, centers, gy, gx, sw)


@register_step("slic_assign", "pallas", platforms=("tpu",), batched=False)
def _slic_pallas(h, w, gy, gx, sw, block_rows=8, interpret=None, **_):
    """Tiled Pallas SLIC assignment (pre-tiled planes from
    :func:`tile_channels`)."""
    return lambda xpad, centers: slic_assign(xpad, centers, h, w, gy, gx,
                                             sw, block_rows, interpret)
