"""Jitted public wrappers around the FCM Pallas kernels, plus the step
dispatch registry the solver core routes through.

Handles 1-D <-> (rows, 128) tiling, padding with validity weights, and
interpret-mode fallback on non-TPU backends (kernel bodies execute in
Python on CPU for correctness validation, per the Pallas docs).

The registry at the bottom maps a step *kind* (``"flat"`` weighted-row
update, ``"stencil"`` FCM_S update, ``"slic_assign"``) to its available
implementations (``"pallas"`` kernels here, ``"reference"`` pure-jnp),
and :func:`select_step` picks one by platform and problem shape. New
variants register a builder instead of growing per-module wrappers.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from . import defuzzify as KD
from . import fcm_centers as KC
from . import fcm_membership as KM
from . import fcm_resident as KR
from . import fcm_spatial as KS
from . import histogram_bin as KB
from . import slic_assign as KSL

LANES = KM.LANES


def _interpret_default() -> bool:
    return jax.default_backend() != "tpu"


def _tile(x: jax.Array, block_rows: int):
    """(N,) -> ((M,128) pixels, (M,128) weights, N) with M % block_rows == 0."""
    n = x.shape[0]
    per_block = block_rows * LANES
    n_pad = (-n) % per_block
    xp = jnp.concatenate([x.astype(jnp.float32),
                          jnp.zeros((n_pad,), jnp.float32)])
    w = jnp.concatenate([jnp.ones((n,), jnp.float32),
                         jnp.zeros((n_pad,), jnp.float32)])
    m_rows = (n + n_pad) // LANES
    return xp.reshape(m_rows, LANES), w.reshape(m_rows, LANES), n


def tile_rows(x: jax.Array, w: jax.Array, block_rows: int):
    """Weighted analogue of :func:`_tile`: tiles pixels AND their row
    weights (histogram counts, superpixel sizes; padding weighs 0), so
    the fused-partials kernel runs weighted flat problems unchanged."""
    n = x.shape[0]
    per_block = block_rows * LANES
    n_pad = (-n) % per_block
    xp = jnp.concatenate([x.astype(jnp.float32),
                          jnp.zeros((n_pad,), jnp.float32)])
    wp = jnp.concatenate([w.astype(jnp.float32),
                          jnp.zeros((n_pad,), jnp.float32)])
    m_rows = (n + n_pad) // LANES
    return xp.reshape(m_rows, LANES), wp.reshape(m_rows, LANES)


def tile_rows_batched(feats: jax.Array, w: jax.Array,
                      rows_multiple: int = 1):
    """Batched analogue of :func:`tile_rows` for the VMEM-resident
    solve: ``(B, K, D)`` feature rows + ``(B, K)`` weights become
    ``(B, D, R, 128)`` row tiles and ``(B, R, 128)`` weights with K
    padded to a 128 multiple at zero weight (padding rows are inert in
    the weighted center step). ``rows_multiple`` additionally pads R to
    a multiple of it — the HBM-streamed solve DMAs fixed
    ``STREAM_CHUNK_ROWS``-row chunks."""
    b, k, d = feats.shape
    per = rows_multiple * LANES
    n_pad = (-k) % per
    xp = jnp.pad(feats.astype(jnp.float32), ((0, 0), (0, n_pad), (0, 0)))
    wp = jnp.pad(w.astype(jnp.float32), ((0, 0), (0, n_pad)))
    r = (k + n_pad) // LANES
    return jnp.moveaxis(xp, -1, 1).reshape(b, d, r, LANES), \
        wp.reshape(b, r, LANES)


def tile_pixels_batched(px: jax.Array, block_rows: int = 8):
    """(B, N) flat pixel payloads -> ((B, M, 128) f32 tiles, (B, M, 128)
    validity weights) with M a ``block_rows`` multiple — the layout the
    binning and defuzzify kernels stream."""
    b, n = px.shape
    per = block_rows * LANES
    n_pad = (-n) % per
    xp = jnp.pad(px.astype(jnp.float32), ((0, 0), (0, n_pad)))
    wp = jnp.pad(jnp.ones((b, n), jnp.float32), ((0, 0), (0, n_pad)))
    m_rows = (n + n_pad) // LANES
    return xp.reshape(b, m_rows, LANES), wp.reshape(b, m_rows, LANES)


def tile_grid(img: jax.Array, block_rows: int = 64):
    """Shape-preserving analogue of :func:`_tile` for stencil kernels:
    pads a 2-D image to (Hp % block_rows == 0, Wp % 128 == 0) or a 3-D
    volume to (D, Hp % 8 == 0, Wp % 128 == 0) and returns the padded
    pixels plus matching validity weights (0 on padding)."""
    img = jnp.asarray(img, jnp.float32)
    if img.ndim == 2:
        h, w = img.shape
        pad = ((0, (-h) % block_rows), (0, (-w) % LANES))
    elif img.ndim == 3:
        _, h, w = img.shape
        pad = ((0, 0), (0, (-h) % 8), (0, (-w) % LANES))
    else:
        raise ValueError(f"tile_grid needs rank 2 or 3, got {img.shape}")
    return jnp.pad(img, pad), jnp.pad(jnp.ones(img.shape, jnp.float32), pad)


def tile_grid_batched(imgs: jax.Array, block_rows: int = 8):
    """Batched :func:`tile_grid` for the resident stencil solve: a
    stack of same-shape grids ``(B, H, W)`` / ``(B, D, H, W)`` becomes
    the padded stack plus a matching validity stack (0 on padding)."""
    imgs = jnp.asarray(imgs, jnp.float32)
    if imgs.ndim == 3:
        _, h, w = imgs.shape
        pad = ((0, 0), (0, (-h) % block_rows), (0, (-w) % LANES))
    elif imgs.ndim == 4:
        _, _, h, w = imgs.shape
        pad = ((0, 0), (0, 0), (0, (-h) % 8), (0, (-w) % LANES))
    else:
        raise ValueError(f"tile_grid_batched needs rank 3 or 4, got "
                         f"{imgs.shape}")
    return jnp.pad(imgs, pad), jnp.pad(jnp.ones(imgs.shape, jnp.float32),
                                       pad)


def tile_channels(img: jax.Array, block_rows: int = 8):
    """Channel-major analogue of :func:`tile_grid` for the SLIC kernel:
    an (H, W, D) image (or (H, W) grayscale) becomes (D, Hp, Wp) planes
    with Hp % block_rows == 0 and Wp % 128 == 0, plus a single (Hp, Wp)
    validity sheet (0 on padding) shared by every channel."""
    img = jnp.asarray(img, jnp.float32)
    if img.ndim == 2:
        img = img[:, :, None]
    if img.ndim != 3:
        raise ValueError(f"tile_channels needs (H, W[, D]), got {img.shape}")
    h, w, _ = img.shape
    pad = ((0, (-h) % block_rows), (0, (-w) % LANES), (0, 0))
    xpad = jnp.moveaxis(jnp.pad(img, pad), -1, 0)
    wpad = jnp.pad(jnp.ones((h, w), jnp.float32), pad[:2])
    return xpad, wpad


@partial(jax.jit, static_argnames=("h", "w", "gy", "gx", "sw", "block_rows",
                                   "interpret"))
def _slic_assign_impl(xpad, centers, h, w, gy, gx, sw, block_rows,
                      interpret):
    return KSL.slic_assign_pallas(xpad, centers, gy, gx, h / gy, w / gx,
                                  sw, block_rows, interpret)


def slic_assign(xpad, centers, h: int, w: int, gy: int, gx: int, sw: float,
                block_rows: int = 8, interpret=None) -> jax.Array:
    """SLIC assignment via Pallas: pre-tiled (D, Hp, Wp) planes from
    :func:`tile_channels` + (K, D+2) centers -> (Hp, Wp) int32 labels.
    ``h``/``w`` are the *unpadded* dims (they set the cell intervals)."""
    if interpret is None:
        interpret = _interpret_default()
    return _slic_assign_impl(xpad, centers, h, w, gy, gx, sw, block_rows,
                             interpret)


@partial(jax.jit, static_argnames=("m", "block_rows", "interpret"))
def _membership_impl(x, v, m, block_rows, interpret):
    x2d, _, n = _tile(x, block_rows)
    u = KM.membership_pallas(x2d, v, m, block_rows, interpret)
    c = v.shape[0]
    return u.reshape(c, -1)[:, :n]


def membership(x, v, m: float = 2.0, block_rows: int = 64,
               interpret=None) -> jax.Array:
    """Eq. 4 membership via Pallas; x (N,), v (c,) -> u (c, N)."""
    if interpret is None:
        interpret = _interpret_default()
    return _membership_impl(x, v, m, block_rows, interpret)


@partial(jax.jit, static_argnames=("m", "block_rows", "interpret"))
def _center_partials_impl(x, u, m, block_rows, interpret):
    x2d, w2d, n = _tile(x, block_rows)
    c = u.shape[0]
    pad = x2d.size - n
    u_p = jnp.concatenate(
        [u.astype(jnp.float32), jnp.zeros((c, pad), jnp.float32)], axis=1)
    u3d = u_p.reshape(c, -1, LANES)
    num, den = KC.center_partials_pallas(x2d, u3d, w2d, m, block_rows,
                                         interpret)
    return num[:, None], den          # num (c,1) matches (c,F) center layout


def center_partials(x, u, m: float = 2.0, block_rows: int = 64,
                    interpret=None):
    """Eq. 3 partial sums from materialized membership (paper-faithful)."""
    if interpret is None:
        interpret = _interpret_default()
    return _center_partials_impl(x, u, m, block_rows, interpret)


@partial(jax.jit, static_argnames=("m", "block_rows", "interpret"))
def _fused_step_impl(x, v, m, block_rows, interpret):
    x2d, w2d, n = _tile(x, block_rows)
    num, den = KC.fused_partials_pallas(x2d, w2d, v, m, block_rows, interpret)
    return num / jnp.maximum(den, 1e-12)


def fused_step(x, v, m: float = 2.0, block_rows: int = 64, interpret=None):
    """One fused v -> v' FCM iteration (single kernel launch)."""
    if interpret is None:
        interpret = _interpret_default()
    return _fused_step_impl(x, v, m, block_rows, interpret)


def fused_partials(x2d, w2d, v, m: float = 2.0, block_rows: int = 64,
                   interpret=None):
    """Raw pre-tiled partials — used by the distributed fit where the
    psum happens outside the kernel."""
    if interpret is None:
        interpret = _interpret_default()
    return KC.fused_partials_pallas(x2d, w2d, v, m, block_rows, interpret)


def spatial_partials(xpad, wpad, v, m: float = 2.0, alpha: float = 1.0,
                     neighbors: int = 4, block_rows: int = 64,
                     interpret=None):
    """Raw pre-tiled FCM_S partials (Eq. 3' numerator/denominator) from
    the fused stencil kernel; inputs from :func:`tile_grid`. 3-D volumes
    always use the 6-connected stencil."""
    if interpret is None:
        interpret = _interpret_default()
    if xpad.ndim == 2:
        return KS.spatial_partials_pallas_2d(xpad, wpad, v, m, alpha,
                                             neighbors, block_rows, interpret)
    if neighbors != 6:
        raise ValueError(f"3-D neighborhoods are 6-connected, "
                         f"got {neighbors}")
    return KS.spatial_partials_pallas_3d(xpad, wpad, v, m, alpha, interpret)


@partial(jax.jit, static_argnames=("m", "alpha", "neighbors", "block_rows",
                                   "interpret"))
def _spatial_step_impl(img, v, m, alpha, neighbors, block_rows, interpret):
    xpad, wpad = tile_grid(img, block_rows)
    num, den = spatial_partials(xpad, wpad, v, m, alpha, neighbors,
                                block_rows, interpret)
    return num / jnp.maximum((1.0 + alpha) * den, 1e-12)


def spatial_step(img, v, m: float = 2.0, alpha: float = 1.0,
                 neighbors: int = 4, block_rows: int = 64, interpret=None):
    """One fused FCM_S v -> v' iteration over a 2-D image or 3-D volume
    (stencil average + membership + center reduction, single launch)."""
    if interpret is None:
        interpret = _interpret_default()
    return _spatial_step_impl(img, v, m, alpha, neighbors, block_rows,
                              interpret)


def histogram_counts(px: jax.Array, n_bins: int = 256, block_rows: int = 8,
                     interpret=None) -> jax.Array:
    """Device-resident intensity binning: ``(N,)`` or ``(B, N)`` pixel
    values -> ``(n_bins,)`` / ``(B, n_bins)`` float32 counts via the
    Pallas one-pass binning kernel. Traceable (used inside the serving
    engine's fused route programs). Bin semantics match
    :func:`repro.core.histogram.intensity_histogram`'s clamp-to-range."""
    if interpret is None:
        interpret = _interpret_default()
    squeeze = px.ndim == 1
    if squeeze:
        px = px[None]
    # Unit-weight fast path: no validity stream (it would double the
    # kernel's input bandwidth); zero-padding lands in bin 0 and the
    # static pad count is subtracted inside histogram_bin_pallas.
    b, n = px.shape
    n_pad = (-n) % (block_rows * LANES)
    xp = jnp.pad(px.astype(jnp.float32), ((0, 0), (0, n_pad)))
    x3 = xp.reshape(b, -1, LANES)
    h = KB.histogram_bin_pallas(x3, None, n_bins, block_rows, interpret,
                                n_pad=n_pad)
    return h[0] if squeeze else h


def defuzzify_labels(x: jax.Array, v: jax.Array, block_rows: int = 64,
                     interpret=None) -> jax.Array:
    """Hard labels straight from centers — one fused O(N) argmin pass
    (Pallas on TPU for scalar features, the pure-jnp reference
    elsewhere); the ``(c, N)`` distance/membership matrix never hits
    HBM. ``x`` (N,) or (N, D), ``v`` (c,) or (c, D) -> (N,) int32."""
    if x.ndim == 2 and x.shape[-1] == 1:        # (N, 1) == scalar rows
        x = x[:, 0]
        v = v[:, 0] if v.ndim == 2 else v
    n_feat = 1 if x.ndim == 1 else x.shape[-1]
    impl = select_step("labels", n_feat=n_feat)
    return impl.build(block_rows=block_rows, interpret=interpret)(x, v)


def defuzzify_labels_batched(xs: jax.Array, v: jax.Array,
                             block_rows: int = 64, interpret=None,
                             impl: Optional[str] = None) -> jax.Array:
    """Batched fused defuzzify: ``(B, N)`` scalar pixel lanes + ``(B, c)``
    centers -> ``(B, N)`` int32 labels in one launch. ``impl`` pins a
    registry implementation (the engine's route programs resolve it at
    build time); default is platform dispatch."""
    sel = select_step("labels", prefer=impl, n_feat=1)
    if sel.name == "pallas":
        if interpret is None:
            interpret = _interpret_default()
        n = xs.shape[1]
        x3, _ = tile_pixels_batched(xs, block_rows)
        lab = KD.labels_pallas(x3, v, block_rows, interpret)
        return lab.reshape(xs.shape[0], -1)[:, :n]
    from repro.core import fcm as F
    return jax.vmap(F.labels_from_centers)(xs, v)


# ---------------------------------------------------------------------------
# Step dispatch registry (what repro.core.solver routes through)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class StepImpl:
    """One registered step implementation.

    ``build(**params) -> callable`` constructs the actual step (called
    at trace time inside the solver's jitted loops); ``platforms``
    limits compiled execution (off-platform falls back to interpret
    mode for Pallas impls); ``scalar_only`` marks impls restricted to
    1-D feature rows; ``batched`` marks impls safe under ``vmap``.
    """
    kind: str
    name: str
    build: Callable[..., Callable]
    platforms: Tuple[str, ...] = ("cpu", "gpu", "tpu")
    scalar_only: bool = False
    batched: bool = True
    #: VMEM-residency bounds (None = unbounded). An impl with bounds is
    #: only eligible when the problem size is known and fits.
    max_rows: Optional[int] = None
    max_c: Optional[int] = None
    max_feat: Optional[int] = None
    #: name to dispatch to instead when the platform doesn't match
    #: (the documented off-TPU behavior of the resident whole-solve).
    fallback: Optional[str] = None

    def fits(self, n_feat: int, n_rows: Optional[int],
             c: Optional[int]) -> bool:
        if self.max_feat is not None and n_feat > self.max_feat:
            return False
        if self.max_rows is not None and (n_rows is None
                                          or n_rows > self.max_rows):
            return False
        if self.max_c is not None and (c is None or c > self.max_c):
            return False
        return True


_STEP_REGISTRY: Dict[Tuple[str, str], StepImpl] = {}


def register_step(kind: str, name: str, *, platforms=("cpu", "gpu", "tpu"),
                  scalar_only: bool = False, batched: bool = True,
                  max_rows: Optional[int] = None, max_c: Optional[int] = None,
                  max_feat: Optional[int] = None,
                  fallback: Optional[str] = None):
    """Decorator: register a step builder under (kind, name). Adding an
    FCM variant = registering its step here + a problem factory in
    ``core/solver.py`` — no new fit module."""
    def deco(build):
        _STEP_REGISTRY[(kind, name)] = StepImpl(
            kind=kind, name=name, build=build, platforms=tuple(platforms),
            scalar_only=scalar_only, batched=batched, max_rows=max_rows,
            max_c=max_c, max_feat=max_feat, fallback=fallback)
        return build
    return deco


def step_impls(kind: Optional[str] = None):
    """All registered implementations (of one kind, if given)."""
    return [impl for (k, _), impl in sorted(_STEP_REGISTRY.items())
            if kind is None or k == kind]


def select_step(kind: str, *, prefer: Optional[str] = None,
                platform: Optional[str] = None, n_feat: int = 1,
                batched: bool = False, n_rows: Optional[int] = None,
                c: Optional[int] = None) -> StepImpl:
    """Dispatch: pick the step implementation for a problem shape and
    platform. ``prefer`` forces a name; otherwise the VMEM-resident
    whole-solve wins on TPU when the problem is known to fit
    (``n_rows``/``c`` within its bounds), then its HBM-streamed variant,
    then the Pallas step kernel when eligible (right platform,
    feature-dim and vmap support), and the pure-jnp reference runs
    everywhere else. A preferred impl with a declared ``fallback``
    degrades off its platforms by walking the whole fallback chain
    (e.g. resident_streamed -> resident -> reference), skipping links
    that are themselves ineligible, and raises only when the chain is
    exhausted."""
    kinds = sorted({k for k, _ in _STEP_REGISTRY})
    if kind not in kinds:
        raise ValueError(f"unknown step kind {kind!r}; one of {kinds}")
    from repro import faults as FI
    _inj = FI.get()
    if _inj is not None:
        # Chaos hook: lets tests inject a dispatch-time launch failure
        # for a specific (kind, impl) without monkeypatching internals.
        _inj.maybe_fail("kernel", route=f"{kind}/{prefer or 'auto'}")
    if prefer is not None:
        impl = _STEP_REGISTRY.get((kind, prefer))
        if impl is None:
            names = [i.name for i in step_impls(kind)]
            raise ValueError(f"no {kind!r} step implementation named "
                             f"{prefer!r}; registered: {names}")
        if impl.scalar_only and n_feat != 1:
            raise ValueError(f"{kind}/{prefer} handles scalar (D=1) "
                             f"features only, got D={n_feat}")
        if batched and not impl.batched:
            raise ValueError(f"{kind}/{prefer} does not support batched "
                             f"(vmapped) solves")
        if not impl.fits(n_feat, n_rows, c):
            raise ValueError(
                f"{kind}/{prefer} needs a VMEM-resident problem "
                f"(rows <= {impl.max_rows}, c <= {impl.max_c}, "
                f"D <= {impl.max_feat}); got rows={n_rows}, c={c}, "
                f"D={n_feat}")
        platform = platform or jax.default_backend()
        if platform in impl.platforms or impl.fallback is None:
            # Off-platform with no declared fallback = run the Pallas
            # body in interpret mode (the documented parity-test path).
            return impl
        # Walk the fallback chain iteratively: a link that is itself
        # off-platform (without being terminal) or ineligible for this
        # problem is skipped, not an error — only an exhausted chain
        # raises. (A single forced-`prefer` recursion used to re-apply
        # the hard eligibility checks to the first link and blow up on
        # 2-hop chains like resident_streamed -> resident -> reference.)
        seen = {impl.name}
        cur = impl
        walked = []
        while cur.fallback is not None and cur.fallback not in seen:
            seen.add(cur.fallback)
            nxt = _STEP_REGISTRY.get((kind, cur.fallback))
            if nxt is None:
                break
            walked.append(nxt.name)
            eligible = (not (nxt.scalar_only and n_feat != 1)
                        and not (batched and not nxt.batched)
                        and nxt.fits(n_feat, n_rows, c))
            if eligible and (platform in nxt.platforms
                             or nxt.fallback is None):
                return nxt
            cur = nxt
        raise ValueError(
            f"{kind}/{prefer} is unavailable on platform {platform!r} "
            f"and its fallback chain {walked} has no eligible "
            f"implementation for rows={n_rows}, c={c}, D={n_feat}")
    platform = platform or jax.default_backend()
    for name in ("resident", "resident_streamed", "pallas"):
        impl = _STEP_REGISTRY.get((kind, name))
        if (impl is not None and platform in impl.platforms
                and not (impl.scalar_only and n_feat != 1)
                and not (batched and not impl.batched)
                and impl.fits(n_feat, n_rows, c)):
            return impl
    return _STEP_REGISTRY[(kind, "reference")]


def build_step(kind: str, name: str, **params) -> Callable:
    """Construct the (kind, name) step with the given problem arrays."""
    return _STEP_REGISTRY[(kind, name)].build(**params)


# -- registered implementations ---------------------------------------------
# Builders import the reference math lazily: repro.core imports this
# module lazily too, and resolving both at call time keeps the package
# import graph acyclic.

@register_step("flat", "reference")
def _flat_reference(feats, weights, m, **_):
    """Canonical pure-jnp weighted-row update (repro.core.solver)."""
    from repro.core import solver as SV
    return lambda v: SV.weighted_center_step(feats, weights, v, m)


@register_step("flat", "pallas", platforms=("tpu",), scalar_only=True,
               batched=False)
def _flat_pallas(x2d, w2d, m, block_rows=64, interpret=None, **_):
    """Fused membership+center-partials kernel over pre-tiled rows."""
    if interpret is None:
        interpret = _interpret_default()

    def step(v):
        num, den = KC.fused_partials_pallas(x2d, w2d, v[:, 0], m,
                                            block_rows, interpret)
        return (num / jnp.maximum(den, 1e-12))[:, None]
    return step


@register_step("flat", "resident", platforms=("tpu",), batched=True,
               max_rows=KR.MAX_ROWS, max_c=KR.MAX_C, max_feat=KR.MAX_FEAT,
               fallback="reference")
def _flat_resident(x4, w3, m, max_iters, interpret=None, **_):
    """The VMEM-resident whole-solve: unlike the other builders this
    returns a complete ``(v0, tol) -> (v, delta, iters)`` solver, not a
    ``v -> v'`` step — the convergence loop runs INSIDE the kernel.
    Inputs are pre-tiled by :func:`tile_rows_batched` (lanes of
    ``(D, R, 128)`` rows + ``(R, 128)`` weights)."""
    if interpret is None:
        interpret = _interpret_default()

    def solve_fn(v0, tol):
        return KR.resident_solve_pallas(x4, w3, v0, tol, m, max_iters,
                                        interpret)
    return solve_fn


@register_step("flat", "resident_streamed", platforms=("tpu",), batched=True,
               max_rows=KR.STREAM_MAX_ROWS, max_c=KR.MAX_C,
               max_feat=KR.MAX_FEAT, fallback="resident")
def _flat_resident_streamed(x4, w3, m, max_iters, interpret=None, **_):
    """HBM-streamed whole-solve: same ``(v0, tol) -> (v, delta, iters)``
    contract as ``flat/resident`` but rows stream from HBM in
    double-buffered chunks, so the bound is ``STREAM_MAX_ROWS`` (its
    wall-clock validation lives in benchmarks/roofline_report.py).
    Inputs from ``tile_rows_batched(...,
    rows_multiple=KR.STREAM_CHUNK_ROWS)``. Off-TPU the fallback chain
    degrades through ``resident`` to ``reference``."""
    if interpret is None:
        interpret = _interpret_default()

    def solve_fn(v0, tol):
        return KR.resident_streamed_solve_pallas(x4, w3, v0, tol, m,
                                                 max_iters, interpret)
    return solve_fn


@register_step("bin", "reference")
def _bin_reference(n_bins=256, **_):
    """Scatter-add binning (what ``intensity_histogram`` jits); the
    algebraic oracle for the Pallas one-pass kernel."""
    def counts(px):
        def one(p):
            idx = jnp.clip(p.astype(jnp.int32), 0, n_bins - 1)
            return jnp.zeros((n_bins,), jnp.float32).at[idx].add(1.0)
        return one(px) if px.ndim == 1 else jax.vmap(one)(px)
    return counts


@register_step("bin", "pallas", platforms=("tpu",))
def _bin_pallas(n_bins=256, block_rows=8, interpret=None, **_):
    """One-pass comparison-binning kernel over (B, M, 128) tiles."""
    return lambda px: histogram_counts(px, n_bins, block_rows, interpret)


@register_step("labels", "reference")
def _labels_reference(**_):
    """argmin-distance labels via the pure-jnp (c, N) distance matrix."""
    from repro.core import fcm as F
    return lambda x, v: F.labels_from_centers(x, v)


@register_step("labels", "pallas", platforms=("tpu",), scalar_only=True)
def _labels_pallas(block_rows=64, interpret=None, **_):
    """Fused O(N) argmin tile kernel (scalar features)."""
    if interpret is None:
        interpret = _interpret_default()

    def labels(x, v):
        x3, _ = tile_pixels_batched(x[None], block_rows)
        lab = KD.labels_pallas(x3, v[None], block_rows, interpret)
        return lab.reshape(-1)[:x.shape[0]]
    return labels


@register_step("stencil", "reference")
def _stencil_reference(img, m, alpha, neighbors, **_):
    """Pure-jnp shifted-array FCM_S step (repro.core.spatial)."""
    from repro.core import spatial as SP
    return lambda v: SP.spatial_center_step(img, v[:, 0], m, alpha,
                                            neighbors)[:, None]


@register_step("stencil", "pallas", platforms=("tpu",), batched=False)
def _stencil_pallas(xpad, wpad, m, alpha, neighbors, block_rows=64,
                    interpret=None, **_):
    """Fused stencil+membership+center-reduction kernel over a pre-tiled
    grid (inputs from :func:`tile_grid`)."""
    if interpret is None:
        interpret = _interpret_default()

    def step(v):
        num, den = spatial_partials(xpad, wpad, v[:, 0], m, alpha,
                                    neighbors, block_rows, interpret)
        return (num / jnp.maximum((1.0 + alpha) * den, 1e-12))[:, None]
    return step


@register_step("stencil", "resident", platforms=("tpu",), batched=True,
               max_rows=KR.STENCIL_MAX_PIXELS, max_c=KR.STENCIL_MAX_C,
               fallback="reference")
def _stencil_resident(xpad, vpad, m, alpha, neighbors, max_iters,
                      interpret=None, **_):
    """VMEM-resident whole-solve FCM_S: the complete Eq. 4'/Eq. 3'
    fixed point of every lane runs inside one kernel (inputs from
    :func:`tile_grid_batched`; ``max_rows`` bounds the per-lane PIXEL
    count — ``FCMProblem.n_rows`` reports it for stencil problems).
    Returns a ``(v0, tol) -> (v, delta, iters)`` solver like the other
    resident builders."""
    if interpret is None:
        interpret = _interpret_default()

    def solve_fn(v0, tol):
        return KR.resident_stencil_solve_pallas(xpad, vpad, v0, tol, m,
                                                alpha, neighbors,
                                                max_iters, interpret)
    return solve_fn


@register_step("slic_assign", "reference", batched=False)
def _slic_reference(gy, gx, sw, **_):
    """Pure-jnp 3x3-candidate SLIC assignment (repro.superpixel.slic)."""
    from repro.superpixel import slic as SL
    return lambda img, centers: SL.assign_ref(img, centers, gy, gx, sw)


@register_step("slic_assign", "pallas", platforms=("tpu",), batched=False)
def _slic_pallas(h, w, gy, gx, sw, block_rows=8, interpret=None, **_):
    """Tiled Pallas SLIC assignment (pre-tiled planes from
    :func:`tile_channels`)."""
    return lambda xpad, centers: slic_assign(xpad, centers, h, w, gy, gx,
                                             sw, block_rows, interpret)
