"""Pallas TPU stencil kernels for spatially-regularized FCM (FCM_S).

One grid step loads a pixel tile plus its grid-overlapped neighbor
tiles (the halo), forms the 4/8-neighbor (2-D) or 6-neighbor (3-D)
stencil average of per-cluster squared distances entirely in VMEM,
applies the Eq. 4' membership update on the effective distances
``d2 + alpha * mean_r d2_r``, and immediately accumulates the Eq. 3'
partial sums — neither the (c, N) membership nor the (c, N) neighbor
distance field ever touches HBM, so one FCM_S iteration stays a single
O(N)-read kernel launch like :func:`fcm_centers.fused_partials_pallas`.

Halo rows via grid overlap: the pixel and validity arrays are each
passed three times with clamped index maps (block ``i-1``, ``i``,
``i+1`` for 2-D row blocks; slice ``i-1``, ``i``, ``i+1`` for 3-D
volumes), so every step also sees the row/slice just outside its tile.
At the grid edges the clamped neighbor tile aliases the center tile and
its contribution is zeroed through the validity weights (gated on
``pl.program_id``). Lane-direction (W) neighbors never cross a tile
boundary because tiles span the full padded width.

Border pixels need no special casing: each stencil direction carries
the shifted validity weights, so a pixel's neighbor count is the number
of *valid in-image* neighbors it actually has and the stencil mean is
exact at edges, corners, and against padding.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .fcm_centers import _accumulate
from .fcm_membership import membership_from_d2_tile

LANES = 128
_D2_FLOOR = 1e-12


# -- in-tile shifts (zero fill; validity weights make the fill inert) --------

def _shift_right(a):
    """out[..., j] = a[..., j-1]."""
    z = jnp.zeros_like(a[..., :1])
    return jnp.concatenate([z, a[..., :-1]], axis=-1)


def _shift_left(a):
    """out[..., j] = a[..., j+1]."""
    z = jnp.zeros_like(a[..., :1])
    return jnp.concatenate([a[..., 1:], z], axis=-1)


def _reduce_tile(xc, wc, pairs, v_ref, num_ref, den_ref, *, m, alpha):
    """Shared tail of both kernels: stencil-average the per-cluster
    distances over ``pairs`` of (shifted pixels, shifted validity),
    run the membership update on the effective distances, and
    accumulate the center partial sums. xc/wc are (R, W) tiles."""
    v = v_ref[...][:, 0].astype(jnp.float32)        # (c,)
    vb = v[:, None, None]
    nb_d2 = jnp.zeros((v.shape[0],) + xc.shape, jnp.float32)
    cnt = jnp.zeros_like(xc)
    sx = jnp.zeros_like(xc)
    for xs, ws in pairs:
        nb_d2 = nb_d2 + ws[None] * (vb - xs[None]) ** 2
        cnt = cnt + ws
        sx = sx + ws * xs
    cnt = jnp.maximum(cnt, 1.0)
    d2_eff = (vb - xc[None]) ** 2 + alpha * (nb_d2 / cnt[None])
    # Eq. 4' on the effective distances (same zero handling as the
    # plain-FCM kernels).
    u = membership_from_d2_tile(d2_eff, m)
    um = (u ** m) * wc[None]
    x_eff = xc + alpha * (sx / cnt)
    pnum = jnp.sum(um * x_eff[None], axis=1)        # (c, W) per-lane partials
    pden = jnp.sum(um, axis=1)
    _accumulate(num_ref, den_ref, pnum, pden)


def _spatial2d_kernel(xp_ref, xc_ref, xn_ref, wp_ref, wc_ref, wn_ref, v_ref,
                      num_ref, den_ref, *, m, alpha, neighbors):
    i = pl.program_id(0)
    xc = xc_ref[...].astype(jnp.float32)            # (R, Wp)
    wc = wc_ref[...].astype(jnp.float32)
    # Halo rows: last row of the previous block / first row of the next,
    # with validity zeroed where the clamped index map aliased us.
    gp = jnp.where(i == 0, 0.0, 1.0)
    gn = jnp.where(i == pl.num_programs(0) - 1, 0.0, 1.0)
    top_x = xp_ref[...][-1:, :].astype(jnp.float32)
    top_w = wp_ref[...][-1:, :].astype(jnp.float32) * gp
    bot_x = xn_ref[...][:1, :].astype(jnp.float32)
    bot_w = wn_ref[...][:1, :].astype(jnp.float32) * gn
    x_u = jnp.concatenate([top_x, xc[:-1]], axis=0)   # up neighbor of row r
    w_u = jnp.concatenate([top_w, wc[:-1]], axis=0)
    x_d = jnp.concatenate([xc[1:], bot_x], axis=0)    # down neighbor
    w_d = jnp.concatenate([wc[1:], bot_w], axis=0)
    pairs = [(x_u, w_u), (x_d, w_d),
             (_shift_right(xc), _shift_right(wc)),    # left neighbor
             (_shift_left(xc), _shift_left(wc))]      # right neighbor
    if neighbors == 8:
        for xs, ws in ((x_u, w_u), (x_d, w_d)):
            pairs.append((_shift_right(xs), _shift_right(ws)))
            pairs.append((_shift_left(xs), _shift_left(ws)))
    _reduce_tile(xc, wc, pairs, v_ref, num_ref, den_ref, m=m, alpha=alpha)


def _spatial3d_kernel(xp_ref, xc_ref, xn_ref, wp_ref, wc_ref, wn_ref, v_ref,
                      num_ref, den_ref, *, m, alpha):
    i = pl.program_id(0)
    xc = xc_ref[...][0].astype(jnp.float32)         # (Hp, Wp) slice
    wc = wc_ref[...][0].astype(jnp.float32)
    # z-neighbors are whole halo slices from the grid-overlapped blocks.
    gp = jnp.where(i == 0, 0.0, 1.0)
    gn = jnp.where(i == pl.num_programs(0) - 1, 0.0, 1.0)
    xz0 = xp_ref[...][0].astype(jnp.float32)
    wz0 = wp_ref[...][0].astype(jnp.float32) * gp
    xz1 = xn_ref[...][0].astype(jnp.float32)
    wz1 = wn_ref[...][0].astype(jnp.float32) * gn
    # y-neighbors: the full slice is resident, so shift with zero fill.
    zr = jnp.zeros_like(xc[:1])
    x_u = jnp.concatenate([zr, xc[:-1]], axis=0)
    w_u = jnp.concatenate([zr, wc[:-1]], axis=0)
    x_d = jnp.concatenate([xc[1:], zr], axis=0)
    w_d = jnp.concatenate([wc[1:], zr], axis=0)
    pairs = [(xz0, wz0), (xz1, wz1), (x_u, w_u), (x_d, w_d),
             (_shift_right(xc), _shift_right(wc)),
             (_shift_left(xc), _shift_left(wc))]
    _reduce_tile(xc, wc, pairs, v_ref, num_ref, den_ref, m=m, alpha=alpha)


# -- pallas_call wrappers ----------------------------------------------------

def _call_spatial(kernel, grid, block, arrays, v, wp, interpret):
    """Common pallas_call plumbing: each pixel/validity array goes in
    three times under clamped prev/cur/next index maps (the grid
    overlap), centers are broadcast, partials accumulate in (c, Wp)."""
    c = v.shape[0]
    g = grid[0]
    ndim = len(block)
    tail = (0,) * (ndim - 1)
    prev = lambda i: (jnp.maximum(i - 1, 0),) + tail
    cur = lambda i: (i,) + tail
    nxt = lambda i: (jnp.minimum(i + 1, g - 1),) + tail
    vb = jnp.broadcast_to(v.astype(jnp.float32)[:, None], (c, LANES))
    x, w = arrays
    num, den = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec(block, prev), pl.BlockSpec(block, cur),
            pl.BlockSpec(block, nxt),
            pl.BlockSpec(block, prev), pl.BlockSpec(block, cur),
            pl.BlockSpec(block, nxt),
            pl.BlockSpec((c, LANES), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((c, wp), lambda i: (0, 0)),
            pl.BlockSpec((c, wp), lambda i: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((c, wp), jnp.float32),
            jax.ShapeDtypeStruct((c, wp), jnp.float32),
        ],
        interpret=interpret,
    )(x, x, x, w, w, w, vb)
    return jnp.sum(num, axis=1), jnp.sum(den, axis=1)


def spatial_partials_pallas_2d(x2d, w2d, v, m: float, alpha: float,
                               neighbors: int = 4, block_rows: int = 64,
                               interpret: bool = False):
    """x2d/w2d (Hp, Wp) padded image + validity, v (c,) ->
    (num (c,), den (c,)) of Eq. 3'; caller divides num / ((1+alpha) den).
    Hp must divide by block_rows and Wp by 128 (ops.tile_grid pads)."""
    hp, wp = x2d.shape
    assert hp % block_rows == 0 and wp % LANES == 0, (x2d.shape, block_rows)
    assert neighbors in (4, 8), neighbors
    kernel = partial(_spatial2d_kernel, m=m, alpha=alpha, neighbors=neighbors)
    return _call_spatial(kernel, (hp // block_rows,), (block_rows, wp),
                         (x2d, w2d), v, wp, interpret)


def spatial_partials_pallas_3d(x3d, w3d, v, m: float, alpha: float,
                               interpret: bool = False):
    """x3d/w3d (D, Hp, Wp) padded volume + validity, v (c,) -> 6-neighbor
    FCM_S partials (num (c,), den (c,)). One depth slice per grid step;
    Wp must divide by 128."""
    d, hp, wp = x3d.shape
    assert wp % LANES == 0, x3d.shape
    kernel = partial(_spatial3d_kernel, m=m, alpha=alpha)
    return _call_spatial(kernel, (d,), (1, hp, wp), (x3d, w3d), v, wp,
                         interpret)
