"""Pallas TPU kernel: the VMEM-resident whole-solve FCM loop.

Histogram- and superpixel-compressed problems are tiny — at most 256
weighted rows and a handful of centers — so the *entire* fixed point
fits in VMEM. Instead of dispatching one fused-step kernel per
iteration (every iteration pays a launch plus an HBM round-trip for the
centers), this kernel runs the complete convergence loop
(``lax.while_loop`` over the weighted center step with the
``max|v' - v| < tol`` stop test of
:func:`repro.core.solver.while_centers`) inside ONE ``pallas_call``:
zero HBM traffic after the initial row load, zero per-iteration
dispatch. That is the paper's 245x lesson (all stages device-resident,
§5) taken to its limit for the compressed problems the serving engine
actually runs.

Batched form: the grid iterates over lanes, each grid step solving its
lane to ITS OWN convergence point — per-lane trajectories are identical
to solo :func:`repro.core.solver.while_centers` runs, with no frozen-lane
masking work at all.

Rows are tiled ``(D, R, 128)`` per lane with zero-weight padding;
centers travel lane-broadcast as ``(c, D, 128)`` blocks.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .fcm_membership import membership_from_d2_tile

LANES = 128
_D2_FLOOR = 1e-12

#: VMEM eligibility bounds (what "the problem fits" means for dispatch).
MAX_ROWS = 256
MAX_C = 8
MAX_FEAT = 8


def _resident_kernel(x_ref, w_ref, v0_ref, tol_ref,
                     v_ref, delta_ref, it_ref, *, m: float, max_iters: int):
    x = x_ref[...][0].astype(jnp.float32)            # (D, R, 128)
    w = w_ref[...][0].astype(jnp.float32)            # (R, 128)
    v0 = v0_ref[...][0, :, :, 0].astype(jnp.float32)  # (c, D)
    tol = tol_ref[...][0, 0]

    def step(v):
        d2 = jnp.sum((v[:, :, None, None] - x[None, :, :, :]) ** 2, axis=1)
        u = membership_from_d2_tile(d2, m)           # (c, R, 128)
        um = (u ** m) * w[None, :, :]
        den = jnp.sum(um, axis=(1, 2))               # (c,)
        num = jnp.sum(um[:, None, :, :] * x[None, :, :, :], axis=(2, 3))
        return num / jnp.maximum(den, _D2_FLOOR)[:, None]

    def cond(state):
        _, delta, it = state
        return jnp.logical_and(delta >= tol, it < max_iters)

    def body(state):
        v, _, it = state
        v_new = step(v)
        return v_new, jnp.max(jnp.abs(v_new - v)), it + 1

    v, delta, it = jax.lax.while_loop(
        cond, body, (v0, jnp.asarray(jnp.inf, jnp.float32),
                     jnp.asarray(0, jnp.int32)))
    v_ref[...] = jnp.broadcast_to(v[None, :, :, None], v_ref.shape)
    delta_ref[...] = jnp.broadcast_to(delta, delta_ref.shape)
    it_ref[...] = jnp.broadcast_to(it, it_ref.shape)


def resident_solve_pallas(x4: jax.Array, w3: jax.Array, v0: jax.Array,
                          tol: jax.Array, m: float, max_iters: int,
                          interpret: bool = False):
    """x4 (B, D, R, 128) tiled rows, w3 (B, R, 128) row weights (0 on
    padding), v0 (B, c, D) init centers, tol (B,) per-lane stop
    tolerances -> (v (B, c, D), delta (B,), iters (B,) int32), each
    lane run to its own convergence inside one kernel launch."""
    b, d, r, _ = x4.shape
    c = v0.shape[1]
    v0b = jnp.broadcast_to(v0.astype(jnp.float32)[..., None], (b, c, d, LANES))
    tolb = jnp.broadcast_to(tol.astype(jnp.float32)[:, None], (b, LANES))
    grid = (b,)
    v, delta, it = pl.pallas_call(
        partial(_resident_kernel, m=m, max_iters=max_iters),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, d, r, LANES), lambda i: (i, 0, 0, 0)),
            pl.BlockSpec((1, r, LANES), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, c, d, LANES), lambda i: (i, 0, 0, 0)),
            pl.BlockSpec((1, LANES), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, c, d, LANES), lambda i: (i, 0, 0, 0)),
            pl.BlockSpec((1, LANES), lambda i: (i, 0)),
            pl.BlockSpec((1, LANES), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, c, d, LANES), jnp.float32),
            jax.ShapeDtypeStruct((b, LANES), jnp.float32),
            jax.ShapeDtypeStruct((b, LANES), jnp.int32),
        ],
        interpret=interpret,
    )(x4, w3, v0b, tolb)
    return v[..., 0], delta[:, 0], it[:, 0]
