"""Pallas TPU kernel: the VMEM-resident whole-solve FCM loop.

Histogram- and superpixel-compressed problems are tiny — at most 256
weighted rows and a handful of centers — so the *entire* fixed point
fits in VMEM. Instead of dispatching one fused-step kernel per
iteration (every iteration pays a launch plus an HBM round-trip for the
centers), this kernel runs the complete convergence loop
(``lax.while_loop`` over the weighted center step with the
``max|v' - v| < tol`` stop test of
:func:`repro.core.solver.while_centers`) inside ONE ``pallas_call``:
zero HBM traffic after the initial row load, zero per-iteration
dispatch. That is the paper's 245x lesson (all stages device-resident,
§5) taken to its limit for the compressed problems the serving engine
actually runs.

Batched form: the grid iterates over lanes, each grid step solving its
lane to ITS OWN convergence point — per-lane trajectories are identical
to solo :func:`repro.core.solver.while_centers` runs, with no frozen-lane
masking work at all.

Rows are tiled ``(D, R, 128)`` per lane with zero-weight padding;
centers travel lane-broadcast as ``(c, D, 128)`` blocks.

Two residency extensions lift the whole-solve shape to real workloads:

* :func:`resident_streamed_solve_pallas` — same convergence loop, but
  the rows live in HBM and are double-buffered into VMEM in
  ``(STREAM_CHUNK_ROWS, 128)`` tiles per center step (async copy into
  one buffer slot while the other is reduced), so only the centers and
  the running Eq. 3 partials stay resident. That lifts the row bound
  from ``MAX_ROWS`` (256) to ``STREAM_MAX_ROWS`` (tens of thousands):
  superpixel/vector problems run their complete fixed point in ONE
  ``pallas_call``.
* :func:`resident_stencil_solve_pallas` — the FCM_S analogue: a whole
  padded pixel grid (plus validity sheet) sits in VMEM and the fused
  stencil + membership + center reduction iterates to convergence
  inside the kernel, collapsing the spatial route's per-iteration
  dispatch entirely. Stencil semantics (zero-filled shifts, per-pixel
  neighbor counts, Eq. 3' on the effective pixels) mirror
  :func:`repro.core.spatial.neighbor_fields` /
  :func:`~repro.core.spatial.spatial_center_step` exactly, with the
  validity sheet standing in for the image border.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .fcm_membership import membership_from_d2_tile

LANES = 128
_D2_FLOOR = 1e-12

#: VMEM eligibility bounds (what "the problem fits" means for dispatch).
MAX_ROWS = 256
MAX_C = 8
MAX_FEAT = 8

#: HBM-streamed variant: rows per DMA chunk (R axis), and the row bound
#: for dispatch. VMEM holding the stream is only the double buffer
#: (2 * D * STREAM_CHUNK_ROWS * 128 f32 = 512 KiB at D=8), so the row
#: bound is a wall-clock choice, not a fit constraint: the roofline
#: report (benchmarks/roofline_report.py) measures the streamed cell at
#: probe sizes up to this bound to keep it honest.
STREAM_CHUNK_ROWS = 8
STREAM_MAX_ROWS = 131072

#: Resident stencil bounds: the padded grid, validity sheet, the
#: hoisted neighborhood fields and the (c, *grid) membership
#: temporaries must all sit in VMEM: ~(6 + 4c) * pixels * 4 bytes,
#: about 10 MiB at the c=8 / 64k-pixel corner.
STENCIL_MAX_PIXELS = 65536
STENCIL_MAX_C = 8


def _resident_kernel(x_ref, w_ref, v0_ref, tol_ref,
                     v_ref, delta_ref, it_ref, *, m: float, max_iters: int):
    x = x_ref[...][0].astype(jnp.float32)            # (D, R, 128)
    w = w_ref[...][0].astype(jnp.float32)            # (R, 128)
    v0 = v0_ref[...][0, :, :, 0].astype(jnp.float32)  # (c, D)
    tol = tol_ref[...][0, 0]

    def step(v):
        d2 = jnp.sum((v[:, :, None, None] - x[None, :, :, :]) ** 2, axis=1)
        u = membership_from_d2_tile(d2, m)           # (c, R, 128)
        um = (u ** m) * w[None, :, :]
        den = jnp.sum(um, axis=(1, 2))               # (c,)
        num = jnp.sum(um[:, None, :, :] * x[None, :, :, :], axis=(2, 3))
        return num / jnp.maximum(den, _D2_FLOOR)[:, None]

    def cond(state):
        _, delta, it = state
        return jnp.logical_and(delta >= tol, it < max_iters)

    def body(state):
        v, _, it = state
        v_new = step(v)
        return v_new, jnp.max(jnp.abs(v_new - v)), it + 1

    v, delta, it = jax.lax.while_loop(
        cond, body, (v0, jnp.asarray(jnp.inf, jnp.float32),
                     jnp.asarray(0, jnp.int32)))
    v_ref[...] = jnp.broadcast_to(v[None, :, :, None], v_ref.shape)
    delta_ref[...] = jnp.broadcast_to(delta, delta_ref.shape)
    it_ref[...] = jnp.broadcast_to(it, it_ref.shape)


def resident_solve_pallas(x4: jax.Array, w3: jax.Array, v0: jax.Array,
                          tol: jax.Array, m: float, max_iters: int,
                          interpret: bool = False):
    """x4 (B, D, R, 128) tiled rows, w3 (B, R, 128) row weights (0 on
    padding), v0 (B, c, D) init centers, tol (B,) per-lane stop
    tolerances -> (v (B, c, D), delta (B,), iters (B,) int32), each
    lane run to its own convergence inside one kernel launch."""
    b, d, r, _ = x4.shape
    c = v0.shape[1]
    v0b = jnp.broadcast_to(v0.astype(jnp.float32)[..., None], (b, c, d, LANES))
    tolb = jnp.broadcast_to(tol.astype(jnp.float32)[:, None], (b, LANES))
    grid = (b,)
    v, delta, it = pl.pallas_call(
        partial(_resident_kernel, m=m, max_iters=max_iters),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, d, r, LANES), lambda i: (i, 0, 0, 0)),
            pl.BlockSpec((1, r, LANES), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, c, d, LANES), lambda i: (i, 0, 0, 0)),
            pl.BlockSpec((1, LANES), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, c, d, LANES), lambda i: (i, 0, 0, 0)),
            pl.BlockSpec((1, LANES), lambda i: (i, 0)),
            pl.BlockSpec((1, LANES), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, c, d, LANES), jnp.float32),
            jax.ShapeDtypeStruct((b, LANES), jnp.float32),
            jax.ShapeDtypeStruct((b, LANES), jnp.int32),
        ],
        interpret=interpret,
    )(x4, w3, v0b, tolb)
    return v[..., 0], delta[:, 0], it[:, 0]


# ---------------------------------------------------------------------------
# HBM-streamed whole-solve (rows beyond VMEM, centers + partials resident)
# ---------------------------------------------------------------------------

def _streamed_kernel(x_hbm, w_hbm, v0_ref, tol_ref,
                     v_ref, delta_ref, it_ref,
                     xbuf, wbuf, xsem, wsem,
                     *, m: float, max_iters: int, n_chunks: int):
    lane = pl.program_id(0)
    v0 = v0_ref[...][0, :, :, 0].astype(jnp.float32)  # (c, D)
    tol = tol_ref[...][0, 0]
    c, d = v0.shape
    chunk = xbuf.shape[2]                             # (2, D, chunk, 128)

    def copies(k, slot):
        return (pltpu.make_async_copy(
                    x_hbm.at[lane, :, pl.ds(k * chunk, chunk), :],
                    xbuf.at[slot], xsem.at[slot]),
                pltpu.make_async_copy(
                    w_hbm.at[lane, pl.ds(k * chunk, chunk), :],
                    wbuf.at[slot], wsem.at[slot]))

    def step(v):
        # Prime slot 0, then stream: start chunk k+1 into the other
        # slot while chunk k is reduced into the Eq. 3 partials. Every
        # started copy is waited exactly once (k+1 starts are gated on
        # k + 1 < n_chunks; chunk k's wait reconstructs the same
        # (ref, sem) descriptor — the documented Pallas-TPU pattern).
        for cp in copies(0, 0):
            cp.start()

        def chunk_body(k, acc):
            num, den = acc
            slot = jax.lax.rem(k, 2)
            nxt = jax.lax.rem(k + 1, 2)

            @pl.when(k + 1 < n_chunks)
            def _():
                for cp in copies(k + 1, nxt):
                    cp.start()

            for cp in copies(k, slot):
                cp.wait()
            x = xbuf[slot]                         # (D, chunk, 128)
            w = wbuf[slot]                         # (chunk, 128)
            d2 = jnp.sum((v[:, :, None, None] - x[None]) ** 2, axis=1)
            u = membership_from_d2_tile(d2, m)     # (c, chunk, 128)
            um = (u ** m) * w[None]
            den = den + jnp.sum(um, axis=(1, 2))
            num = num + jnp.sum(um[:, None] * x[None], axis=(2, 3))
            return num, den

        num, den = jax.lax.fori_loop(
            0, n_chunks, chunk_body,
            (jnp.zeros((c, d), jnp.float32), jnp.zeros((c,), jnp.float32)))
        return num / jnp.maximum(den, _D2_FLOOR)[:, None]

    def cond(state):
        _, delta, it = state
        return jnp.logical_and(delta >= tol, it < max_iters)

    def body(state):
        v, _, it = state
        v_new = step(v)
        return v_new, jnp.max(jnp.abs(v_new - v)), it + 1

    v, delta, it = jax.lax.while_loop(
        cond, body, (v0, jnp.asarray(jnp.inf, jnp.float32),
                     jnp.asarray(0, jnp.int32)))
    v_ref[...] = jnp.broadcast_to(v[None, :, :, None], v_ref.shape)
    delta_ref[...] = jnp.broadcast_to(delta, delta_ref.shape)
    it_ref[...] = jnp.broadcast_to(it, it_ref.shape)


def resident_streamed_solve_pallas(x4: jax.Array, w3: jax.Array,
                                   v0: jax.Array, tol: jax.Array, m: float,
                                   max_iters: int, interpret: bool = False):
    """HBM-streamed twin of :func:`resident_solve_pallas`, same
    signature and per-lane convergence semantics. ``x4``/``w3`` must
    have ``R % STREAM_CHUNK_ROWS == 0`` (``tile_rows_batched`` pads
    with ``rows_multiple=STREAM_CHUNK_ROWS``); the row tiles stay in
    HBM and are double-buffered through a 2-slot VMEM scratch."""
    b, d, r, _ = x4.shape
    c = v0.shape[1]
    if r % STREAM_CHUNK_ROWS != 0:
        raise ValueError(f"streamed solve needs R % {STREAM_CHUNK_ROWS} "
                         f"== 0, got R={r} (pad with tile_rows_batched("
                         f"..., rows_multiple=STREAM_CHUNK_ROWS))")
    n_chunks = r // STREAM_CHUNK_ROWS
    v0b = jnp.broadcast_to(v0.astype(jnp.float32)[..., None],
                           (b, c, d, LANES))
    tolb = jnp.broadcast_to(tol.astype(jnp.float32)[:, None], (b, LANES))
    v, delta, it = pl.pallas_call(
        partial(_streamed_kernel, m=m, max_iters=max_iters,
                n_chunks=n_chunks),
        grid=(b,),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.ANY),
            pl.BlockSpec(memory_space=pltpu.ANY),
            pl.BlockSpec((1, c, d, LANES), lambda i: (i, 0, 0, 0)),
            pl.BlockSpec((1, LANES), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, c, d, LANES), lambda i: (i, 0, 0, 0)),
            pl.BlockSpec((1, LANES), lambda i: (i, 0)),
            pl.BlockSpec((1, LANES), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, c, d, LANES), jnp.float32),
            jax.ShapeDtypeStruct((b, LANES), jnp.float32),
            jax.ShapeDtypeStruct((b, LANES), jnp.int32),
        ],
        scratch_shapes=[
            pltpu.VMEM((2, d, STREAM_CHUNK_ROWS, LANES), jnp.float32),
            pltpu.VMEM((2, STREAM_CHUNK_ROWS, LANES), jnp.float32),
            pltpu.SemaphoreType.DMA((2,)),
            pltpu.SemaphoreType.DMA((2,)),
        ],
        interpret=interpret,
    )(x4.astype(jnp.float32), w3.astype(jnp.float32), v0b, tolb)
    return v[..., 0], delta[:, 0], it[:, 0]


# ---------------------------------------------------------------------------
# VMEM-resident FCM_S stencil whole-solve
# ---------------------------------------------------------------------------

def _shift_grid(a: jax.Array, off) -> jax.Array:
    """Zero-filled shift, out[i] = a[i - off] per axis — the VMEM-array
    face of :func:`repro.core.spatial._shift` (same border semantics)."""
    pads, slices = [], []
    for ax, o in enumerate(off):
        n = a.shape[ax]
        if o >= 0:
            pads.append((o, 0))
            slices.append(slice(0, n))
        else:
            pads.append((0, -o))
            slices.append(slice(-o, None))
    return jnp.pad(a, pads)[tuple(slices)]


def _resident_stencil_kernel(x_ref, valid_ref, v0_ref, tol_ref,
                             v_ref, delta_ref, it_ref, *, m: float,
                             alpha: float, offsets, max_iters: int):
    x = x_ref[...][0].astype(jnp.float32)          # (Hp, Wp) / (D, Hp, Wp)
    valid = valid_ref[...][0].astype(jnp.float32)
    v0 = v0_ref[...][0, :, 0].astype(jnp.float32)  # (c,)
    tol = tol_ref[...][0, 0]
    c = v0.shape[0]
    axes = tuple(range(1, 1 + x.ndim))

    # Iteration-invariant neighborhood fields. The validity sheet plays
    # the border role: padding pixels carry valid=0 and x=0, so shifts
    # that cross the true image edge contribute nothing — exactly the
    # zero-filled out-of-bounds semantics of core.spatial.neighbor_fields
    # (per-pixel neighbor counts included).
    xv = x * valid
    cnt = jnp.zeros_like(x)
    sx = jnp.zeros_like(x)
    for off in offsets:
        cnt = cnt + _shift_grid(valid, off)
        sx = sx + _shift_grid(xv, off)
    cnt = jnp.maximum(cnt, 1.0)
    xbar = sx / cnt
    # Eq. 3' as plain Eq. 3 on the effective pixels (the reference
    # form: the (1 + alpha) divisor folded into x_eff, not the sums).
    x_eff = (x + alpha * xbar) / (1.0 + alpha)

    def step(v):
        vb = v.reshape((c,) + (1,) * x.ndim)
        d2 = (vb - x[None]) ** 2                   # (c, *grid)
        d2v = d2 * valid[None]
        nb = jnp.zeros_like(d2)
        for off in offsets:
            nb = nb + _shift_grid(d2v, (0,) + tuple(off))
        u = membership_from_d2_tile(d2 + alpha * (nb / cnt[None]), m)
        um = (u ** m) * valid[None]
        den = jnp.sum(um, axis=axes)               # (c,)
        num = jnp.sum(um * x_eff[None], axis=axes)
        return num / jnp.maximum(den, _D2_FLOOR)

    def cond(state):
        _, delta, it = state
        return jnp.logical_and(delta >= tol, it < max_iters)

    def body(state):
        v, _, it = state
        v_new = step(v)
        return v_new, jnp.max(jnp.abs(v_new - v)), it + 1

    v, delta, it = jax.lax.while_loop(
        cond, body, (v0, jnp.asarray(jnp.inf, jnp.float32),
                     jnp.asarray(0, jnp.int32)))
    v_ref[...] = jnp.broadcast_to(v[None, :, None], v_ref.shape)
    delta_ref[...] = jnp.broadcast_to(delta, delta_ref.shape)
    it_ref[...] = jnp.broadcast_to(it, it_ref.shape)


def resident_stencil_solve_pallas(xpad: jax.Array, vpad: jax.Array,
                                  v0: jax.Array, tol: jax.Array, m: float,
                                  alpha: float, neighbors: int,
                                  max_iters: int, interpret: bool = False):
    """Whole-solve FCM_S: ``xpad`` (B, Hp, Wp) or (B, D, Hp, Wp) padded
    pixel grids with matching validity ``vpad`` (0 on padding; from
    ``ops.tile_grid_batched``), ``v0`` (B, c) scalar init centers,
    ``tol`` (B,) -> (v (B, c), delta (B,), iters (B,) int32). Each
    lane's complete Eq. 4'/Eq. 3' fixed point runs inside one kernel."""
    from repro.core.spatial import neighbor_offsets
    b = xpad.shape[0]
    grid_shape = xpad.shape[1:]
    c = v0.shape[1]
    offsets = neighbor_offsets(len(grid_shape), neighbors)
    v0b = jnp.broadcast_to(v0.astype(jnp.float32)[..., None], (b, c, LANES))
    tolb = jnp.broadcast_to(tol.astype(jnp.float32)[:, None], (b, LANES))
    gblock = (1,) + grid_shape
    gmap = (lambda i: (i,) + (0,) * len(grid_shape))
    v, delta, it = pl.pallas_call(
        partial(_resident_stencil_kernel, m=m, alpha=alpha,
                offsets=offsets, max_iters=max_iters),
        grid=(b,),
        in_specs=[
            pl.BlockSpec(gblock, gmap),
            pl.BlockSpec(gblock, gmap),
            pl.BlockSpec((1, c, LANES), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, LANES), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, c, LANES), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, LANES), lambda i: (i, 0)),
            pl.BlockSpec((1, LANES), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, c, LANES), jnp.float32),
            jax.ShapeDtypeStruct((b, LANES), jnp.float32),
            jax.ShapeDtypeStruct((b, LANES), jnp.int32),
        ],
        interpret=interpret,
    )(xpad.astype(jnp.float32), vpad.astype(jnp.float32), v0b, tolb)
    return v[..., 0], delta[:, 0], it[:, 0]
