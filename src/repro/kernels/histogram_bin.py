"""Pallas TPU kernel: one-pass intensity binning at serving ingest.

The device-resident request pipeline (gSLICr's lesson: the speedup is in
never leaving the device, not in faster math) needs the 256-bin weighted
histogram computed on-chip from the raw pixel tiles, so a request batch
goes pixels -> histogram -> solve -> labels in ONE dispatch. TPUs have
no fast scatter, so the kernel bins by comparison instead: a
``(block_rows, 128)`` pixel tile is tested against the
``(n_bins, 1, 1)`` bin iota and the resulting one-hot mass (times the
validity weight, so padding contributes zero) is reduced over the
sublane axis into a per-lane ``(n_bins, 128)`` VMEM accumulator —
same sequential-grid ``+=`` idiom as the center-partials kernels. The
final 128-lane fold happens outside the kernel and never touches the
host.

Bin index semantics match :func:`repro.core.histogram.intensity_histogram`:
``clip(int(x), 0, n_bins - 1)`` (truncation on the float pixel values,
which are integral for 8-bit data).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANES = 128


def _bin_accumulate(hist_ref, partial_hist):
    @pl.when(pl.program_id(1) == 0)
    def _init():
        hist_ref[...] = jnp.zeros_like(hist_ref)

    hist_ref[...] += partial_hist[None]


def _bin_kernel(x_ref, w_ref, hist_ref, *, n_bins: int):
    x = x_ref[...][0].astype(jnp.float32)            # (R, 128)
    w = w_ref[...][0].astype(jnp.float32)
    xi = jnp.clip(x.astype(jnp.int32), 0, n_bins - 1)
    bins = jax.lax.broadcasted_iota(jnp.int32, (n_bins, 1, 1), 0)
    mass = jnp.where(xi[None, :, :] == bins, w[None, :, :], 0.0)
    _bin_accumulate(hist_ref, jnp.sum(mass, axis=1))  # (n_bins, 128)


def _bin_kernel_unweighted(x_ref, hist_ref, *, n_bins: int):
    x = x_ref[...][0].astype(jnp.float32)            # (R, 128)
    xi = jnp.clip(x.astype(jnp.int32), 0, n_bins - 1)
    bins = jax.lax.broadcasted_iota(jnp.int32, (n_bins, 1, 1), 0)
    hit = (xi[None, :, :] == bins).astype(jnp.float32)
    _bin_accumulate(hist_ref, jnp.sum(hit, axis=1))


def histogram_bin_pallas(x3d: jax.Array, w3d=None, n_bins: int = 256,
                         block_rows: int = 8, interpret: bool = False,
                         n_pad: int = 0) -> jax.Array:
    """x3d (B, M, 128) pixels [+ w3d (B, M, 128) weights] ->
    (B, n_bins) weighted histograms. M must divide by ``block_rows``
    (ops.py pads).

    ``w3d=None`` is the unit-weight fast path the serving ingest runs:
    the validity stream would double the kernel's input bandwidth just
    to zero out padding, so instead zero-padded pixels are counted into
    bin 0 and the statically known per-lane pad count ``n_pad`` is
    subtracted afterwards."""
    b, mrows, _ = x3d.shape
    assert mrows % block_rows == 0, (mrows, block_rows)
    grid = (b, mrows // block_rows)
    x_spec = pl.BlockSpec((1, block_rows, LANES), lambda i, j: (i, j, 0))
    if w3d is None:
        hist = pl.pallas_call(
            partial(_bin_kernel_unweighted, n_bins=n_bins),
            grid=grid,
            in_specs=[x_spec],
            out_specs=pl.BlockSpec((1, n_bins, LANES),
                                   lambda i, j: (i, 0, 0)),
            out_shape=jax.ShapeDtypeStruct((b, n_bins, LANES), jnp.float32),
            interpret=interpret,
        )(x3d)
        hist = jnp.sum(hist, axis=-1)
        if n_pad:
            hist = hist.at[:, 0].add(-float(n_pad))
        return hist
    hist = pl.pallas_call(
        partial(_bin_kernel, n_bins=n_bins),
        grid=grid,
        in_specs=[x_spec,
                  pl.BlockSpec((1, block_rows, LANES),
                               lambda i, j: (i, j, 0))],
        out_specs=pl.BlockSpec((1, n_bins, LANES), lambda i, j: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, n_bins, LANES), jnp.float32),
        interpret=interpret,
    )(x3d, w3d)
    return jnp.sum(hist, axis=-1)
