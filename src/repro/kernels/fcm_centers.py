"""Pallas TPU kernels for the FCM cluster-center reduction (Eq. 3).

Two kernels:

* :func:`center_partials_pallas` — the paper-faithful reduction: reads a
  *materialized* membership tile plus the pixel tile and accumulates the
  numerator/denominator partial sums. This is the TPU analogue of the
  paper's Algorithm-2 shared-memory tree reduction: each grid step
  accumulates its (block_rows, 128) tile into a per-lane (c, 128) VMEM
  accumulator (TPU grid steps are sequential on a core, so `+=` on an
  output block mapped to a fixed index is the idiomatic reduction), and
  the final 128-lane fold happens outside — the moral equivalent of the
  paper's one-thread final-sum kernel, except it never leaves the device.

* :func:`fused_partials_pallas` — beyond-paper: computes the membership
  *inside* the kernel from the centers and immediately reduces, so the
  (c, N) membership array never touches HBM. One O(N) read per FCM
  iteration instead of the baseline's ~(3c+2)·N HBM traffic.

Both use a validity-weight tile so padded pixels contribute zero.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .fcm_membership import membership_from_d2_tile

LANES = 128
_D2_FLOOR = 1e-12


def _accumulate(num_ref, den_ref, pnum, pden):
    @pl.when(pl.program_id(0) == 0)
    def _init():
        num_ref[...] = jnp.zeros_like(num_ref)
        den_ref[...] = jnp.zeros_like(den_ref)

    num_ref[...] += pnum
    den_ref[...] += pden


def _center_partials_kernel(x_ref, u_ref, w_ref, num_ref, den_ref,
                            *, m: float):
    x = x_ref[...].astype(jnp.float32)          # (R, 128)
    u = u_ref[...].astype(jnp.float32)          # (c, R, 128)
    w = w_ref[...].astype(jnp.float32)          # (R, 128)
    um = (u ** m) * w[None, :, :]
    pnum = jnp.sum(um * x[None, :, :], axis=1)  # (c, 128) per-lane partials
    pden = jnp.sum(um, axis=1)
    _accumulate(num_ref, den_ref, pnum, pden)


def _fused_partials_kernel(x_ref, w_ref, v_ref, num_ref, den_ref,
                           *, m: float, c: int):
    x = x_ref[...].astype(jnp.float32)              # (R, 128)
    w = w_ref[...].astype(jnp.float32)
    v = v_ref[...][:, 0].astype(jnp.float32)        # (c,)
    d2 = (v[:, None, None] - x[None, :, :]) ** 2
    u = membership_from_d2_tile(d2, m)
    um = (u ** m) * w[None, :, :]
    pnum = jnp.sum(um * x[None, :, :], axis=1)
    pden = jnp.sum(um, axis=1)
    _accumulate(num_ref, den_ref, pnum, pden)


def center_partials_pallas(x2d, u3d, w2d, m: float, block_rows: int = 64,
                           interpret: bool = False):
    """x2d (M,128), u3d (c,M,128), w2d (M,128) -> num (c,), den (c,)."""
    mrows = x2d.shape[0]
    c = u3d.shape[0]
    assert mrows % block_rows == 0
    grid = (mrows // block_rows,)
    num, den = pl.pallas_call(
        partial(_center_partials_kernel, m=m),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_rows, LANES), lambda i: (i, 0)),
            pl.BlockSpec((c, block_rows, LANES), lambda i: (0, i, 0)),
            pl.BlockSpec((block_rows, LANES), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((c, LANES), lambda i: (0, 0)),
            pl.BlockSpec((c, LANES), lambda i: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((c, LANES), jnp.float32),
            jax.ShapeDtypeStruct((c, LANES), jnp.float32),
        ],
        interpret=interpret,
    )(x2d, u3d, w2d)
    return jnp.sum(num, axis=1), jnp.sum(den, axis=1)


def fused_partials_pallas(x2d, w2d, v, m: float, block_rows: int = 64,
                          interpret: bool = False):
    """x2d (M,128), w2d (M,128), v (c,) -> num (c,), den (c,).

    Membership never materialized: the whole FCM iteration is one kernel.
    """
    mrows = x2d.shape[0]
    c = v.shape[0]
    assert mrows % block_rows == 0
    vb = jnp.broadcast_to(v.astype(jnp.float32)[:, None], (c, LANES))
    grid = (mrows // block_rows,)
    num, den = pl.pallas_call(
        partial(_fused_partials_kernel, m=m, c=c),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_rows, LANES), lambda i: (i, 0)),
            pl.BlockSpec((block_rows, LANES), lambda i: (i, 0)),
            pl.BlockSpec((c, LANES), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((c, LANES), lambda i: (0, 0)),
            pl.BlockSpec((c, LANES), lambda i: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((c, LANES), jnp.float32),
            jax.ShapeDtypeStruct((c, LANES), jnp.float32),
        ],
        interpret=interpret,
    )(x2d, w2d, vb)
    return jnp.sum(num, axis=1), jnp.sum(den, axis=1)
