"""Pallas TPU kernel: fused defuzzification (argmin-distance labels).

For any m > 1, ``argmax_c u`` equals ``argmin_c d2``, so hard labels
need neither the membership nor the full ``(c, N)`` distance matrix in
HBM: each ``(block_rows, 128)`` pixel tile computes its per-cluster
squared distances in VMEM and writes the int32 argmin tile directly —
one O(N) pass, the device-resident closer of the serving pipeline.
``jnp.argmin`` ties resolve to the lowest cluster index, matching
:func:`repro.core.fcm.labels_from_centers` exactly.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANES = 128


def _labels_kernel(x_ref, v_ref, lab_ref):
    x = x_ref[...][0].astype(jnp.float32)            # (R, 128)
    v = v_ref[...][0, :, 0].astype(jnp.float32)      # (c,)
    d2 = (v[:, None, None] - x[None, :, :]) ** 2
    lab_ref[...] = jnp.argmin(d2, axis=0).astype(jnp.int32)[None]


def labels_pallas(x3d: jax.Array, v: jax.Array, block_rows: int = 64,
                  interpret: bool = False) -> jax.Array:
    """x3d (B, M, 128) pixels + v (B, c) per-lane scalar centers ->
    (B, M, 128) int32 labels. M must divide by ``block_rows``; padded
    pixels get a (discarded) label like any other."""
    b, mrows, _ = x3d.shape
    c = v.shape[-1]
    assert mrows % block_rows == 0, (mrows, block_rows)
    vb = jnp.broadcast_to(v.astype(jnp.float32)[:, :, None], (b, c, LANES))
    grid = (b, mrows // block_rows)
    return pl.pallas_call(
        _labels_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_rows, LANES), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, c, LANES), lambda i, j: (i, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_rows, LANES), lambda i, j: (i, j, 0)),
        out_shape=jax.ShapeDtypeStruct((b, mrows, LANES), jnp.int32),
        interpret=interpret,
    )(x3d, vb)
