"""Pallas TPU kernel: FCM membership update (Eq. 4), one pass over pixels.

TPU adaptation of the paper's per-pixel CUDA membership kernel (§4.3):
instead of one scalar thread per pixel, pixels are laid out (rows, 128)
so every VPU lane holds one pixel; a grid step processes a
(block_rows, 128) VMEM tile and writes the (c, block_rows, 128)
cluster-major membership tile. Centers are tiny and broadcast to every
grid step.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANES = 128
_D2_FLOOR = 1e-12


def membership_from_d2_tile(d2: jax.Array, m: float) -> jax.Array:
    """Eq. 4 membership from a (c, ...) tile of squared distances, with
    the exact-zero one-hot handling. Shared by every kernel body that
    computes memberships in VMEM (plain, fused, and spatial)."""
    p = jnp.clip(d2, _D2_FLOOR, None) ** (-1.0 / (m - 1.0))
    u = p / jnp.sum(p, axis=0, keepdims=True)
    zero = (d2 <= 0.0)
    any_zero = jnp.any(zero, axis=0, keepdims=True)
    zcount = jnp.maximum(jnp.sum(zero, axis=0, keepdims=True), 1)
    return jnp.where(any_zero,
                     zero.astype(u.dtype) / zcount.astype(u.dtype), u)


def _membership_kernel(x_ref, v_ref, u_ref, *, m: float, c: int):
    x = x_ref[...].astype(jnp.float32)              # (R, 128)
    v = v_ref[...][:, 0].astype(jnp.float32)        # (c,)
    d2 = (v[:, None, None] - x[None, :, :]) ** 2    # (c, R, 128)
    u = membership_from_d2_tile(d2, m)
    u_ref[...] = u.astype(u_ref.dtype)


def membership_pallas(x2d: jax.Array, v: jax.Array, m: float,
                      block_rows: int = 64,
                      interpret: bool = False) -> jax.Array:
    """x2d: (M, 128) pixels; v: (c,) centers -> u: (c, M, 128).

    M must be a multiple of block_rows (ops.py pads).
    """
    mrows = x2d.shape[0]
    c = v.shape[0]
    assert mrows % block_rows == 0, (mrows, block_rows)
    vb = jnp.broadcast_to(v.astype(jnp.float32)[:, None], (c, LANES))
    grid = (mrows // block_rows,)
    return pl.pallas_call(
        partial(_membership_kernel, m=m, c=c),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_rows, LANES), lambda i: (i, 0)),
            pl.BlockSpec((c, LANES), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((c, block_rows, LANES), lambda i: (0, i, 0)),
        out_shape=jax.ShapeDtypeStruct((c, mrows, LANES), jnp.float32),
        interpret=interpret,
    )(x2d, vb)
