"""Pure-jnp oracles for the FCM Pallas kernels.

All references operate on grayscale pixels ``x: (N,)`` with cluster-major
memberships ``u: (c, N)`` and optional validity weights ``w: (N,)``.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core import fcm as F


def membership_ref(x, v, m):
    """Eq. 4; (c, N) float32."""
    return F.update_membership(jnp.asarray(x, jnp.float32),
                               jnp.asarray(v, jnp.float32), m)


def center_partials_ref(x, u, m, w=None):
    """Summed numerator/denominator of Eq. 3: num (c,), den (c,)."""
    x = jnp.asarray(x, jnp.float32)
    um = jnp.asarray(u, jnp.float32) ** m
    if w is not None:
        um = um * jnp.asarray(w, jnp.float32)[None, :]
    return um @ x, jnp.sum(um, axis=1)


def fused_partials_ref(x, v, m, w=None):
    """Membership (Eq. 4) substituted into Eq. 3 partial sums, without
    materializing u: num (c,), den (c,)."""
    u = membership_ref(x, v, m)
    return center_partials_ref(x, u, m, w)


def fused_step_ref(x, v, m, w=None):
    """One fused v -> v' center iteration."""
    num, den = fused_partials_ref(x, v, m, w)
    return num / jnp.maximum(den, 1e-12)


def selective_scan_ref(u, dt, bmat, cmat, a):
    """Oracle for the Mamba selective-scan kernel: the exact lax.scan
    recurrence from repro.models.ssm (no skip term, zero init)."""
    import jax.numpy as jnp2
    from repro.models.ssm import _ssm_scan
    bsz, _, di = u.shape
    ds = bmat.shape[-1]
    h0 = jnp2.zeros((bsz, di, ds), jnp2.float32)
    y, _ = _ssm_scan(u.astype(jnp2.float32), dt.astype(jnp2.float32),
                     bmat.astype(jnp2.float32), cmat.astype(jnp2.float32),
                     a, jnp2.zeros((di,), jnp2.float32), h0)
    return y
