"""Attention mixers: GQA (flash-style chunked softmax in pure jnp) and
MLA (DeepSeek-V2 multi-head latent attention with compressed KV cache and
absorbed decode matmuls).

Shapes: activations (B, S, D); q/k/v (B, H, S, hd); caches are per-layer
dicts (stacked over scan groups by the caller).
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from . import layers as L
from . import sharding as sh

_NEG_INF = -1e30


# ---------------------------------------------------------------------------
# Softmax attention cores
# ---------------------------------------------------------------------------

def _plain_attention(q, k, v, causal: bool, q_offset: int = 0,
                     kv_len: Optional[jax.Array] = None):
    """q (B,K,G,Sq,hd) grouped-query vs k/v (B,K,Skv,hd)."""
    b, kh, g, sq, hd = q.shape
    skv = k.shape[2]
    scores = jnp.einsum("bkgqh,bkth->bkgqt", q, k).astype(jnp.float32)
    scores = scores / jnp.sqrt(jnp.asarray(hd, jnp.float32))
    if causal:
        qpos = jnp.arange(sq) + q_offset
        kpos = jnp.arange(skv)
        mask = kpos[None, :] <= qpos[:, None]
        scores = jnp.where(mask[None, None, None], scores, _NEG_INF)
    if kv_len is not None:
        mask = jnp.arange(skv)[None, :] < kv_len[:, None]          # (B,Skv)
        scores = jnp.where(mask[:, None, None, None], scores, _NEG_INF)
    # softmax with f32 row-max/denominator but bf16 exponentials: the
    # S x S tensors on the HBM path are half as wide (§Perf hillclimb
    # #2, iteration c — max-subtracted exp is in [0,1], so bf16's 8
    # mantissa bits cost ~1e-3 relative error on the normalized weights)
    m = jax.lax.stop_gradient(jnp.max(scores, axis=-1, keepdims=True))
    p = jnp.exp((scores - m).astype(q.dtype))           # bf16 exp in [0,1]
    denom = jnp.sum(p, axis=-1, keepdims=True, dtype=jnp.float32)
    w = p / denom.astype(q.dtype)
    return jnp.einsum("bkgqt,bkth->bkgqh", w, v)


def _flash_attention(q, k, v, causal: bool, q_chunk: int, kv_chunk: int):
    """Online-softmax chunked attention: O(Sq*ckv) live memory instead of
    O(Sq*Skv). Pure jnp (lax.scan over kv chunks inside a scan over q
    chunks) — the TPU-native replacement for materialized scores."""
    b, kh, g, sq, hd = q.shape
    hd_v = v.shape[-1]                      # MLA: v head dim != q head dim
    skv = k.shape[2]
    q_chunk = min(q_chunk, sq)
    kv_chunk = min(kv_chunk, skv)
    assert sq % q_chunk == 0 and skv % kv_chunk == 0, (sq, skv)
    nq, nk = sq // q_chunk, skv // kv_chunk
    scale = 1.0 / jnp.sqrt(jnp.asarray(hd, jnp.float32))

    kc = k.reshape(b, kh, nk, kv_chunk, hd)
    vc = v.reshape(b, kh, nk, kv_chunk, hd_v)

    def q_step(qi, q_blk):
        # q_blk: (B,K,G,cq,hd)
        def kv_step(carry, kj):
            m, l, acc = carry
            kb = jax.lax.dynamic_index_in_dim(kc, kj, 2, keepdims=False)
            vb = jax.lax.dynamic_index_in_dim(vc, kj, 2, keepdims=False)
            s = jnp.einsum("bkgqh,bkth->bkgqt", q_blk, kb)
            s = s.astype(jnp.float32) * scale
            if causal:
                qpos = qi * q_chunk + jnp.arange(q_chunk)
                kpos = kj * kv_chunk + jnp.arange(kv_chunk)
                mask = kpos[None, :] <= qpos[:, None]
                s = jnp.where(mask[None, None, None], s, _NEG_INF)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            alpha = jnp.exp(m - m_new)
            l_new = l * alpha + jnp.sum(p, axis=-1)
            acc_new = acc * alpha[..., None] + jnp.einsum(
                "bkgqt,bkth->bkgqh", p.astype(vb.dtype), vb).astype(jnp.float32)
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((b, kh, g, q_chunk), _NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, kh, g, q_chunk), jnp.float32)
        a0 = jnp.zeros((b, kh, g, q_chunk, hd_v), jnp.float32)
        # causal: kv chunks beyond this q chunk contribute nothing but are
        # still scanned (masked) — keeps the scan length static.
        (m, l, acc), _ = jax.lax.scan(kv_step, (m0, l0, a0),
                                      jnp.arange(nk))
        return acc / jnp.maximum(l[..., None], 1e-30)

    qs = q.reshape(b, kh, g, nq, q_chunk, hd).transpose(3, 0, 1, 2, 4, 5)
    out = jax.lax.map(lambda args: q_step(*args),
                      (jnp.arange(nq), qs))
    out = out.transpose(1, 2, 3, 0, 4, 5).reshape(b, kh, g, sq, hd_v)
    return out.astype(q.dtype)


def grouped_attention(q, k, v, causal: bool, q_offset: int = 0,
                      kv_len=None, flash_threshold: int = 4096,
                      q_chunk: int = 512, kv_chunk: int = 1024):
    """Dispatch between plain and flash paths. q (B,Hq,Sq,hd),
    k/v (B,Hkv,Skv,hd); Hq % Hkv == 0.

    K/V are expanded to the full query-head count first: a (Hkv, group)
    reshape would break head sharding whenever Hkv < tp (GQA kv=8 on
    tp=16 replicates the S x S score tensor on every device — measured
    6.4 GiB/device on mistral-large). The repeat costs one K/V-sized
    broadcast and keeps scores sharded over tp."""
    b, hq, sq, hd = q.shape
    hkv = k.shape[1]
    if hkv != hq:
        k = jnp.repeat(k, hq // hkv, axis=1)
        v = jnp.repeat(v, hq // hkv, axis=1)
    qg = q.reshape(b, hq, 1, sq, hd)
    skv = k.shape[2]
    flash_ok = (sq % min(q_chunk, sq) == 0
                and skv % min(kv_chunk, skv) == 0 and skv > kv_chunk)
    if not flash_ok or (sq * skv <= flash_threshold * flash_threshold
                        and sq <= flash_threshold):
        out = _plain_attention(qg, k, v, causal, q_offset, kv_len)
    else:
        assert kv_len is None, "flash path is for full-length prefill/train"
        out = _flash_attention(qg, k, v, causal, q_chunk, kv_chunk)
    return out.reshape(b, hq, sq, out.shape[-1])   # v head dim (MLA: != q's)


# ---------------------------------------------------------------------------
# GQA attention block
# ---------------------------------------------------------------------------

def init_gqa(key, cfg):
    d, hq, hkv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 4)
    return {
        "wq": L.init_dense(ks[0], (d, hq, hd), d),
        "wk": L.init_dense(ks[1], (d, hkv, hd), d),
        "wv": L.init_dense(ks[2], (d, hkv, hd), d),
        "wo": L.init_dense(ks[3], (hq, hd, d), hq * hd),
    }


def spec_gqa():
    return {"wq": ("fsdp", "tp", None), "wk": ("fsdp", "tp", None),
            "wv": ("fsdp", "tp", None), "wo": ("tp", None, "fsdp")}


def gqa_qkv(p, x, positions, cfg):
    dtype = cfg.dtype
    q = jnp.einsum("bsd,dhk->bhsk", x,
                   L.gathered(p["wq"], dtype, None, "tp", None),
                   preferred_element_type=dtype)
    k = jnp.einsum("bsd,dhk->bhsk", x,
                   L.gathered(p["wk"], dtype, None, "tp", None),
                   preferred_element_type=dtype)
    v = jnp.einsum("bsd,dhk->bhsk", x,
                   L.gathered(p["wv"], dtype, None, "tp", None),
                   preferred_element_type=dtype)
    q = L.apply_rope(q.swapaxes(1, 2), positions, cfg.rope_theta).swapaxes(1, 2)
    k = L.apply_rope(k.swapaxes(1, 2), positions, cfg.rope_theta).swapaxes(1, 2)
    q = sh.shard(q, "dp", "tp", None, None)
    k = sh.shard(k, "dp", "tp", None, None)
    v = sh.shard(v, "dp", "tp", None, None)
    return q, k, v


def gqa_forward(p, x, positions, cfg, causal=True, return_kv=False):
    """Train / prefill path."""
    q, k, v = gqa_qkv(p, x, positions, cfg)
    out = grouped_attention(q, k, v, causal,
                            flash_threshold=cfg.flash_threshold,
                            q_chunk=cfg.q_chunk, kv_chunk=cfg.kv_chunk)
    y = jnp.einsum("bhsk,hkd->bsd", out,
                   L.gathered(p["wo"], cfg.dtype, "tp", None, None),
                   preferred_element_type=cfg.dtype)
    y = sh.shard(y, "dp", None, None)
    return (y, (k, v)) if return_kv else y


def init_gqa_cache(cfg, batch, max_len, dtype):
    shape = (batch, cfg.n_kv_heads, max_len, cfg.head_dim)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def gqa_cache_spec(cfg):
    """KV heads rarely divide tp=16 (GQA kv=8), so the long cache is
    sequence-sharded over tp instead — decode attention then runs as
    sequence-parallel partial-softmax with tiny all-reduces (GSPMD)."""
    if cfg.n_kv_heads % 16 == 0:
        kv = ("dp", "tp", None, None)
    else:
        kv = ("dp", None, "tp", None)
    return {"k": kv, "v": kv}


def gqa_decode(p, x, cache, pos, cfg):
    """One-token decode: x (B,1,D); cache k/v (B,Hkv,Smax,hd); pos scalar."""
    positions = jnp.full((x.shape[0], 1), pos, jnp.int32)
    q, k_new, v_new = gqa_qkv(p, x, positions, cfg)
    k = jax.lax.dynamic_update_slice_in_dim(cache["k"], k_new, pos, axis=2)
    v = jax.lax.dynamic_update_slice_in_dim(cache["v"], v_new, pos, axis=2)
    kv_len = jnp.full((x.shape[0],), pos + 1, jnp.int32)
    out = grouped_attention(q, k, v, causal=False, kv_len=kv_len,
                            flash_threshold=1 << 30)
    y = jnp.einsum("bhsk,hkd->bsd", out, p["wo"].astype(cfg.dtype))
    return y, {"k": k, "v": v}


# ---------------------------------------------------------------------------
# Cross-attention (whisper decoder / llama-vision gated cross blocks)
# ---------------------------------------------------------------------------

def init_cross(key, cfg, gated: bool):
    d, hq, hkv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 4)
    p = {
        "wq": L.init_dense(ks[0], (d, hq, hd), d),
        "wk": L.init_dense(ks[1], (d, hkv, hd), d),
        "wv": L.init_dense(ks[2], (d, hkv, hd), d),
        "wo": L.init_dense(ks[3], (hq, hd, d), hq * hd),
    }
    if gated:
        p["gate"] = jnp.zeros((1,), jnp.float32)   # tanh-gated, starts closed
    return p


def spec_cross(gated: bool):
    s = {"wq": ("fsdp", "tp", None), "wk": ("fsdp", "tp", None),
         "wv": ("fsdp", "tp", None), "wo": ("tp", None, "fsdp")}
    if gated:
        s["gate"] = (None,)
    return s


def cross_kv(p, memory, cfg):
    """Precompute K/V from encoder/image memory (B, M, D)."""
    dtype = cfg.dtype
    k = jnp.einsum("bmd,dhk->bhmk", memory, p["wk"].astype(dtype))
    v = jnp.einsum("bmd,dhk->bhmk", memory, p["wv"].astype(dtype))
    return sh.shard(k, "dp", "tp", None, None), sh.shard(v, "dp", "tp", None, None)


def cross_forward(p, x, kv, cfg):
    k, v = kv
    q = jnp.einsum("bsd,dhk->bhsk", x, p["wq"].astype(cfg.dtype))
    out = grouped_attention(q, k, v, causal=False,
                            flash_threshold=cfg.flash_threshold,
                            q_chunk=cfg.q_chunk, kv_chunk=cfg.kv_chunk)
    y = jnp.einsum("bhsk,hkd->bsd", out, p["wo"].astype(cfg.dtype))
    if "gate" in p:
        y = jnp.tanh(p["gate"]).astype(cfg.dtype) * y
    return sh.shard(y, "dp", None, None)


# ---------------------------------------------------------------------------
# MLA — multi-head latent attention (DeepSeek-V2)
# ---------------------------------------------------------------------------

def init_mla(key, cfg):
    m = cfg.mla
    d, h = cfg.d_model, cfg.n_heads
    ks = jax.random.split(key, 7)
    return {
        "w_dq": L.init_dense(ks[0], (d, m.q_lora_rank), d),
        "w_uq": L.init_dense(ks[1], (m.q_lora_rank, h,
                                     m.qk_nope_head_dim + m.qk_rope_head_dim),
                             m.q_lora_rank),
        "w_dkv": L.init_dense(ks[2], (d, m.kv_lora_rank), d),
        "w_uk": L.init_dense(ks[3], (m.kv_lora_rank, h, m.qk_nope_head_dim),
                             m.kv_lora_rank),
        "w_uv": L.init_dense(ks[4], (m.kv_lora_rank, h, m.v_head_dim),
                             m.kv_lora_rank),
        "w_kr": L.init_dense(ks[5], (d, m.qk_rope_head_dim), d),
        "wo": L.init_dense(ks[6], (h, m.v_head_dim, d), h * m.v_head_dim),
    }


def spec_mla():
    return {"w_dq": ("fsdp", None), "w_uq": (None, "tp", None),
            "w_dkv": ("fsdp", None), "w_uk": (None, "tp", None),
            "w_uv": (None, "tp", None), "w_kr": ("fsdp", None),
            "wo": ("tp", None, "fsdp")}


def _mla_q(p, x, positions, cfg):
    m, dtype = cfg.mla, cfg.dtype
    cq = jnp.einsum("bsd,dr->bsr", x, p["w_dq"].astype(dtype))
    q = jnp.einsum("bsr,rhk->bhsk", cq, p["w_uq"].astype(dtype))
    q_nope = q[..., :m.qk_nope_head_dim]
    q_rope = L.apply_rope(q[..., m.qk_nope_head_dim:].swapaxes(1, 2),
                          positions, cfg.rope_theta).swapaxes(1, 2)
    return q_nope, q_rope


def _mla_ckv(p, x, positions, cfg):
    dtype = cfg.dtype
    c_kv = jnp.einsum("bsd,dr->bsr", x, p["w_dkv"].astype(dtype))
    k_rope = jnp.einsum("bsd,dk->bsk", x, p["w_kr"].astype(dtype))
    k_rope = L.apply_rope(k_rope, positions, cfg.rope_theta)
    return c_kv, k_rope


def mla_forward(p, x, positions, cfg, causal=True, return_kv=False):
    """Training/prefill: decompress K,V and run standard MHA (flash)."""
    m, dtype = cfg.mla, cfg.dtype
    q_nope, q_rope = _mla_q(p, x, positions, cfg)
    c_kv, k_rope = _mla_ckv(p, x, positions, cfg)
    k_nope = jnp.einsum("bsr,rhk->bhsk", c_kv, p["w_uk"].astype(dtype))
    v = jnp.einsum("bsr,rhk->bhsk", c_kv, p["w_uv"].astype(dtype))
    kr = jnp.broadcast_to(k_rope[:, None], (x.shape[0], cfg.n_heads)
                          + k_rope.shape[1:])
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    k = jnp.concatenate([k_nope, kr], axis=-1)
    out = grouped_attention(q, k, v, causal,
                            flash_threshold=cfg.flash_threshold,
                            q_chunk=cfg.q_chunk, kv_chunk=cfg.kv_chunk)
    y = jnp.einsum("bhsk,hkd->bsd", out, p["wo"].astype(dtype))
    y = sh.shard(y, "dp", None, None)
    return (y, (c_kv, k_rope)) if return_kv else y


def init_mla_cache(cfg, batch, max_len, dtype):
    m = cfg.mla
    return {"c_kv": jnp.zeros((batch, max_len, m.kv_lora_rank), dtype),
            "k_rope": jnp.zeros((batch, max_len, m.qk_rope_head_dim), dtype)}


def mla_cache_spec(cfg):
    # compressed cache has no head dim: shard sequence over tp
    return {"c_kv": ("dp", "tp", None), "k_rope": ("dp", "tp", None)}


def mla_decode(p, x, cache, pos, cfg):
    """Absorbed decode: scores and values computed against the compressed
    cache; per-token cache is kv_lora+rope_dim (576 for DS-V2) instead of
    2*H*hd — the MLA memory win, reproduced faithfully."""
    m, dtype = cfg.mla, cfg.dtype
    b = x.shape[0]
    positions = jnp.full((b, 1), pos, jnp.int32)
    q_nope, q_rope = _mla_q(p, x, positions, cfg)      # (B,H,1,*)
    c_new, kr_new = _mla_ckv(p, x, positions, cfg)     # (B,1,r), (B,1,kr)
    c_kv = jax.lax.dynamic_update_slice_in_dim(cache["c_kv"], c_new, pos, 1)
    k_rope = jax.lax.dynamic_update_slice_in_dim(cache["k_rope"], kr_new,
                                                 pos, 1)
    # absorb W_uk into q:  (B,H,1,nope) x (r,H,nope) -> (B,H,1,r)
    q_abs = jnp.einsum("bhsk,rhk->bhsr", q_nope, p["w_uk"].astype(dtype))
    scores = (jnp.einsum("bhsr,btr->bhst", q_abs, c_kv)
              + jnp.einsum("bhsk,btk->bhst", q_rope, k_rope))
    scale = 1.0 / jnp.sqrt(jnp.asarray(m.qk_nope_head_dim
                                       + m.qk_rope_head_dim, jnp.float32))
    scores = scores.astype(jnp.float32) * scale
    mask = jnp.arange(c_kv.shape[1])[None, :] <= pos
    scores = jnp.where(mask[:, None, None, :], scores, _NEG_INF)
    w = jax.nn.softmax(scores, axis=-1).astype(dtype)
    ctx = jnp.einsum("bhst,btr->bhsr", w, c_kv)
    out = jnp.einsum("bhsr,rhk->bhsk", ctx, p["w_uv"].astype(dtype))
    y = jnp.einsum("bhsk,hkd->bsd", out, p["wo"].astype(dtype))
    return y, {"c_kv": c_kv, "k_rope": k_rope}
