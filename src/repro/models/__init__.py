from . import (attention, blocks, layers, lm, moe, sharding,  # noqa: F401
               ssm)
