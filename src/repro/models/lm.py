"""Model assembly: embeddings + scan-over-groups block stacks + head.

Three entry points per model (all pure functions of (params, inputs)):

* :func:`forward`     — training path (full sequence, no cache)
* :func:`prefill`     — fills the decode cache, returns last-pos logits
* :func:`decode_step` — one token with cache (the ``serve_step`` the
                        decode_* dry-run shapes lower)

Layer groups are scanned with stacked params, so HLO size and compile
time are O(group) not O(n_layers) — 88-layer configs compile in seconds.
Encoder-decoder (whisper) and VLM (image-memory cross-attn) are handled
with the same machinery via an optional ``memory`` input.
"""
from __future__ import annotations

from functools import partial
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import BlockDesc, ModelConfig
from . import blocks as B
from . import layers as L
from . import sharding as sh

ENC_DESC = BlockDesc(mixer="gqa", ffn="gelu")


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------

def _init_group(key, cfg, layout):
    ks = jax.random.split(key, len(layout))
    return {f"b{i}": B.init_block(ks[i], cfg, d)
            for i, d in enumerate(layout)}


def _stacked_groups(key, cfg, layout, n_groups):
    keys = jax.random.split(key, n_groups)
    return jax.vmap(lambda k: _init_group(k, cfg, layout))(keys)


def init_params(key, cfg: ModelConfig):
    k_emb, k_groups, k_enc = jax.random.split(key, 3)
    params: Dict[str, Any] = {
        "embed": L.init_embedding(k_emb, cfg.vocab_size, cfg.d_model),
        "groups": _stacked_groups(k_groups, cfg, cfg.group_layout,
                                  cfg.n_groups),
        "final_norm": L.init_rmsnorm(cfg.d_model),
    }
    if cfg.is_encdec:
        params["enc_groups"] = _stacked_groups(k_enc, cfg, (ENC_DESC,),
                                               cfg.enc_layers)
        params["enc_norm"] = L.init_rmsnorm(cfg.d_model)
    return params


def param_specs(cfg: ModelConfig):
    group_spec = {f"b{i}": B.spec_block(cfg, d)
                  for i, d in enumerate(cfg.group_layout)}
    specs: Dict[str, Any] = {
        "embed": L.spec_embedding(),
        "groups": sh.stack_spec(group_spec),
        "final_norm": L.spec_rmsnorm(),
    }
    if cfg.is_encdec:
        specs["enc_groups"] = sh.stack_spec(
            {"b0": B.spec_block(cfg, ENC_DESC)})
        specs["enc_norm"] = L.spec_rmsnorm()
    return specs


def abstract_params(cfg: ModelConfig):
    """Param ShapeDtypeStructs without allocating (dry-run of 100B+)."""
    return jax.eval_shape(lambda k: init_params(k, cfg),
                          jax.ShapeDtypeStruct((2,), jnp.uint32))


# ---------------------------------------------------------------------------
# Encoder (whisper) — frame embeddings are a precomputed stub input
# ---------------------------------------------------------------------------

def encode(params, frames, cfg):
    x = sh.shard(frames.astype(cfg.dtype), "dp", None, None)
    positions = jnp.arange(x.shape[1], dtype=jnp.int32)[None]

    def body(carry, gp):
        y, _ = B.block_forward(gp["b0"], carry, cfg, ENC_DESC,
                               positions=positions, causal=False)
        return y, None

    fn = jax.checkpoint(body) if cfg.remat else body
    x, _ = jax.lax.scan(fn, x, params["enc_groups"])
    return L.rmsnorm(params["enc_norm"], x, cfg.norm_eps)


# ---------------------------------------------------------------------------
# Train forward
# ---------------------------------------------------------------------------

def _sqrt_factor(n: int) -> int:
    """Largest divisor of n that is <= sqrt(n)."""
    best = 1
    d = 1
    while d * d <= n:
        if n % d == 0:
            best = d
        d += 1
    return best


def _scan_groups_remat(body, carry, stacked, n_groups: int, remat: bool):
    """Scan over layer-group params with O(sqrt(L)) activation memory:
    an outer remat scan over super-groups, each an inner remat scan.
    Saved residuals = outer + inner boundaries instead of one per group
    (88-layer configs: 19 saves instead of 88)."""
    if not remat:
        carry, _ = jax.lax.scan(body, carry, stacked)
        return carry
    outer = _sqrt_factor(n_groups)
    if outer <= 1:
        carry, _ = jax.lax.scan(jax.checkpoint(body), carry, stacked)
        return carry
    inner = n_groups // outer
    restacked = jax.tree_util.tree_map(
        lambda a: a.reshape((outer, inner) + a.shape[1:]), stacked)

    @jax.checkpoint
    def super_body(c, super_gp):
        c, _ = jax.lax.scan(jax.checkpoint(body), c, super_gp)
        return c, None

    carry, _ = jax.lax.scan(super_body, carry, restacked)
    return carry


def forward(params, tokens, cfg: ModelConfig, memory: Optional[jax.Array] = None,
            frames: Optional[jax.Array] = None, return_features: bool = False):
    """tokens (B,S) -> (logits (B,S,V) fp32, aux_loss scalar).
    With ``return_features``: (features (B,S,D) post-final-norm, aux) —
    used by the chunked-xent training loss to avoid materializing the
    full fp32 logits tensor."""
    if cfg.is_encdec:
        memory = encode(params, frames, cfg)
    if memory is not None:
        memory = memory.astype(cfg.dtype)
    x = L.embed(params["embed"], tokens, cfg.dtype)
    positions = jnp.arange(tokens.shape[1], dtype=jnp.int32)[None]

    def body(carry, gp):
        x, aux = carry
        for i, desc in enumerate(cfg.group_layout):
            x, a = B.block_forward(gp[f"b{i}"], x, cfg, desc,
                                   positions=positions, memory=memory)
            aux = aux + a
        x = sh.shard(x, "dp", None, None)
        return (x, aux), None

    x, aux = _scan_groups_remat(body, (x, jnp.zeros((), jnp.float32)),
                                params["groups"], cfg.n_groups, cfg.remat)
    x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    if return_features:
        return x, aux
    logits = L.unembed(params["embed"], x, cfg.dtype)
    return logits, aux


# ---------------------------------------------------------------------------
# Serving: cache init / prefill / decode
# ---------------------------------------------------------------------------

def init_cache(cfg: ModelConfig, batch: int, max_len: int):
    """Stacked (leading n_groups dim) decode state."""
    n_mem = _memory_len(cfg, max_len)
    group = {f"b{i}": B.init_block_cache(cfg, d, batch, max_len, n_mem)
             for i, d in enumerate(cfg.group_layout)}
    return jax.tree_util.tree_map(
        lambda a: jnp.broadcast_to(a, (cfg.n_groups,) + a.shape), group)


def cache_specs(cfg: ModelConfig):
    group = {f"b{i}": B.block_cache_spec(cfg, d)
             for i, d in enumerate(cfg.group_layout)}
    return sh.stack_spec(group)


def _memory_len(cfg, max_len):
    if cfg.is_encdec:
        return max_len
    if cfg.n_img_tokens:
        return cfg.n_img_tokens
    return 1


def prefill(params, tokens, cache, cfg: ModelConfig, memory=None,
            frames=None):
    """Fills cache from a full prompt; returns (last-pos logits, cache)."""
    if cfg.is_encdec:
        memory = encode(params, frames, cfg)
    if memory is not None:
        memory = memory.astype(cfg.dtype)
    x = L.embed(params["embed"], tokens, cfg.dtype)
    positions = jnp.arange(tokens.shape[1], dtype=jnp.int32)[None]

    def body(x, xs):
        gp, gc = xs
        new_gc = {}
        for i, desc in enumerate(cfg.group_layout):
            x, new_gc[f"b{i}"] = B.block_prefill(
                gp[f"b{i}"], x, cfg, desc, gc[f"b{i}"],
                positions=positions, memory=memory)
        return x, new_gc

    fn = jax.checkpoint(body) if cfg.remat else body
    x, new_cache = jax.lax.scan(fn, x, (params["groups"], cache))
    x = L.rmsnorm(params["final_norm"], x[:, -1:], cfg.norm_eps)
    logits = L.unembed(params["embed"], x, cfg.dtype)
    return logits, new_cache


def decode_step(params, token, cache, pos, cfg: ModelConfig):
    """serve_step: one new token (B,1) given cache at position ``pos``.
    Returns (logits (B,1,V), new_cache)."""
    x = L.embed(params["embed"], token, cfg.dtype)

    def body(x, xs):
        gp, gc = xs
        new_gc = {}
        for i, desc in enumerate(cfg.group_layout):
            x, new_gc[f"b{i}"] = B.block_decode(gp[f"b{i}"], x, cfg, desc,
                                                gc[f"b{i}"], pos=pos)
        return x, new_gc

    x, new_cache = jax.lax.scan(body, x, (params["groups"], cache))
    x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = L.unembed(params["embed"], x, cfg.dtype)
    return logits, new_cache
