"""Composable transformer/SSM blocks.

A block = pre-norm mixer sub-layer (+ optional cross-attention sub-layer)
+ pre-norm FFN sub-layer, all residual. The mixer and FFN kinds are
static strings from the arch config's group layout, so heterogeneous
stacks (Jamba's 1:7 attn:mamba interleave, Llama-vision's every-5th
cross block, Whisper's decoder) compose from one code path.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from . import attention as A
from . import layers as L
from . import moe as M
from . import sharding as sh
from . import ssm as S


# --- gelu MLP (whisper) -----------------------------------------------------

def init_gelu_mlp(key, d, f):
    k1, k2 = jax.random.split(key)
    return {"w_in": L.init_dense(k1, (d, f), d),
            "w_out": L.init_dense(k2, (f, d), f)}


def spec_gelu_mlp():
    return {"w_in": ("fsdp", "tp"), "w_out": ("tp", "fsdp")}


def gelu_mlp(p, x, dtype):
    h = jax.nn.gelu(jnp.einsum("bsd,df->bsf", x, p["w_in"].astype(dtype)))
    return jnp.einsum("bsf,fd->bsd", h, p["w_out"].astype(dtype))


# --- block ------------------------------------------------------------------

def init_block(key, cfg, desc):
    d = cfg.d_model
    ks = jax.random.split(key, 4)
    p = {"norm1": L.init_rmsnorm(d), "norm2": L.init_rmsnorm(d)}
    if desc.mixer == "gqa":
        p["mixer"] = A.init_gqa(ks[0], cfg)
    elif desc.mixer == "mla":
        p["mixer"] = A.init_mla(ks[0], cfg)
    elif desc.mixer == "cross":
        p["mixer"] = A.init_cross(ks[0], cfg, gated=desc.gated)
    elif desc.mixer == "rwkv6":
        p["mixer"] = S.init_rwkv6(ks[0], cfg)
    elif desc.mixer == "mamba":
        p["mixer"] = S.init_mamba(ks[0], cfg)
    else:
        raise ValueError(desc.mixer)
    if desc.cross:                      # extra cross sub-layer (whisper dec)
        p["norm_x"] = L.init_rmsnorm(d)
        p["cross"] = A.init_cross(ks[1], cfg, gated=desc.gated)
    if desc.ffn == "swiglu":
        p["ffn"] = L.init_mlp(ks[2], d, cfg.d_ff)
    elif desc.ffn == "gelu":
        p["ffn"] = init_gelu_mlp(ks[2], d, cfg.d_ff)
    elif desc.ffn == "moe":
        p["ffn"] = M.init_moe(ks[2], cfg)
    elif desc.ffn == "rwkv_cm":
        p["ffn"] = S.init_rwkv_cm(ks[2], cfg)
    else:
        raise ValueError(desc.ffn)
    return p


def spec_block(cfg, desc):
    s = {"norm1": L.spec_rmsnorm(), "norm2": L.spec_rmsnorm()}
    s["mixer"] = {"gqa": A.spec_gqa, "mla": A.spec_mla,
                  "cross": lambda: A.spec_cross(desc.gated),
                  "rwkv6": S.spec_rwkv6, "mamba": S.spec_mamba}[desc.mixer]()
    if desc.cross:
        s["norm_x"] = L.spec_rmsnorm()
        s["cross"] = A.spec_cross(desc.gated)
    s["ffn"] = {"swiglu": L.spec_mlp, "gelu": spec_gelu_mlp,
                "moe": lambda: M.spec_moe(cfg),
                "rwkv_cm": S.spec_rwkv_cm}[desc.ffn]()
    return s


def init_block_cache(cfg, desc, batch, max_len, n_memory):
    """Decode-time state for one block (None-free: scan needs static
    structure)."""
    cache = {}
    if desc.mixer == "gqa":
        cache["attn"] = A.init_gqa_cache(cfg, batch, max_len, cfg.dtype)
    elif desc.mixer == "mla":
        cache["attn"] = A.init_mla_cache(cfg, batch, max_len, cfg.dtype)
    elif desc.mixer == "rwkv6":
        cache["rwkv"] = S.init_rwkv6_state(cfg, batch)
        cache["cm_prev"] = jnp.zeros((batch, cfg.d_model), cfg.dtype)
    elif desc.mixer == "mamba":
        cache["mamba"] = S.init_mamba_state(cfg, batch)
    if desc.mixer == "cross" or desc.cross:
        hkv, hd = cfg.n_kv_heads, cfg.head_dim
        cache["cross_kv"] = {
            "k": jnp.zeros((batch, hkv, n_memory, hd), cfg.dtype),
            "v": jnp.zeros((batch, hkv, n_memory, hd), cfg.dtype)}
    if desc.ffn == "rwkv_cm":
        cache["cm_prev"] = jnp.zeros((batch, cfg.d_model), cfg.dtype)
    return cache


def block_cache_spec(cfg, desc):
    spec = {}
    if desc.mixer in ("gqa", "mla"):
        spec["attn"] = (A.gqa_cache_spec(cfg) if desc.mixer == "gqa"
                        else A.mla_cache_spec(cfg))
    elif desc.mixer == "rwkv6":
        spec["rwkv"] = S.rwkv6_state_spec(cfg)
        spec["cm_prev"] = ("dp", None)
    elif desc.mixer == "mamba":
        spec["mamba"] = S.mamba_state_spec(cfg)
    if desc.mixer == "cross" or desc.cross:
        kv = (("dp", "tp", None, None) if cfg.n_kv_heads % 16 == 0
              else ("dp", None, "tp", None))
        spec["cross_kv"] = {"k": kv, "v": kv}
    if desc.ffn == "rwkv_cm":
        spec["cm_prev"] = ("dp", None)
    return spec


def _apply_ffn(p, x, cfg, desc, cache, mode):
    """Returns (out, aux, new_cm_prev or None)."""
    if desc.ffn == "swiglu":
        return L.mlp(p["ffn"], x, cfg.dtype), 0.0, None
    if desc.ffn == "gelu":
        return gelu_mlp(p["ffn"], x, cfg.dtype), 0.0, None
    if desc.ffn == "moe":
        out, aux = M.moe_ffn(p["ffn"], x, cfg)
        return out, aux, None
    if desc.ffn == "rwkv_cm":
        prev = cache.get("cm_prev") if cache else None
        out, new_prev = S.rwkv_cm_forward(p["ffn"], x, cfg, prev,
                                          return_state=True)
        return out, 0.0, new_prev
    raise ValueError(desc.ffn)


def block_forward(p, x, cfg, desc, *, positions=None, memory=None,
                  causal=True):
    """Train / encoder path: full sequence, no cache. Returns (x, aux)."""
    h = L.rmsnorm(p["norm1"], x, cfg.norm_eps)
    if desc.mixer == "gqa":
        y = A.gqa_forward(p["mixer"], h, positions, cfg, causal=causal)
    elif desc.mixer == "mla":
        y = A.mla_forward(p["mixer"], h, positions, cfg, causal=causal)
    elif desc.mixer == "cross":
        kv = A.cross_kv(p["mixer"], memory, cfg)
        y = A.cross_forward(p["mixer"], h, kv, cfg)
    elif desc.mixer == "rwkv6":
        y = S.rwkv6_forward(p["mixer"], h, cfg)
    elif desc.mixer == "mamba":
        y = S.mamba_forward(p["mixer"], h, cfg)
    x = x + y
    if desc.cross:
        h = L.rmsnorm(p["norm_x"], x, cfg.norm_eps)
        kv = A.cross_kv(p["cross"], memory, cfg)
        x = x + A.cross_forward(p["cross"], h, kv, cfg)
    h = L.rmsnorm(p["norm2"], x, cfg.norm_eps)
    out, aux, _ = _apply_ffn(p, h, cfg, desc, None, "train")
    return x + out, aux


def block_prefill(p, x, cfg, desc, cache, *, positions, memory=None):
    """Prefill: full sequence, fills the decode cache. Returns (x, cache)."""
    new_cache = dict(cache)
    h = L.rmsnorm(p["norm1"], x, cfg.norm_eps)
    if desc.mixer == "gqa":
        y, (k, v) = A.gqa_forward(p["mixer"], h, positions, cfg, causal=True,
                                  return_kv=True)
        s = k.shape[2]
        new_cache["attn"] = {
            "k": jax.lax.dynamic_update_slice_in_dim(cache["attn"]["k"], k, 0, 2),
            "v": jax.lax.dynamic_update_slice_in_dim(cache["attn"]["v"], v, 0, 2)}
    elif desc.mixer == "mla":
        y, (c_kv, k_rope) = A.mla_forward(p["mixer"], h, positions, cfg,
                                          causal=True, return_kv=True)
        new_cache["attn"] = {
            "c_kv": jax.lax.dynamic_update_slice_in_dim(
                cache["attn"]["c_kv"], c_kv, 0, 1),
            "k_rope": jax.lax.dynamic_update_slice_in_dim(
                cache["attn"]["k_rope"], k_rope, 0, 1)}
    elif desc.mixer == "cross":
        kv = A.cross_kv(p["mixer"], memory, cfg)
        y = A.cross_forward(p["mixer"], h, kv, cfg)
        new_cache["cross_kv"] = {"k": kv[0], "v": kv[1]}
    elif desc.mixer == "rwkv6":
        y, st = S.rwkv6_forward(p["mixer"], h, cfg, return_state=True)
        new_cache["rwkv"] = st
    elif desc.mixer == "mamba":
        y, st = S.mamba_forward(p["mixer"], h, cfg, return_state=True)
        new_cache["mamba"] = st
    x = x + y
    if desc.cross:
        h = L.rmsnorm(p["norm_x"], x, cfg.norm_eps)
        kv = A.cross_kv(p["cross"], memory, cfg)
        x = x + A.cross_forward(p["cross"], h, kv, cfg)
        new_cache["cross_kv"] = {"k": kv[0], "v": kv[1]}
    h = L.rmsnorm(p["norm2"], x, cfg.norm_eps)
    out, _, cm_prev = _apply_ffn(p, h, cfg, desc, cache, "prefill")
    if cm_prev is not None:
        new_cache["cm_prev"] = cm_prev
    return x + out, new_cache


def block_decode(p, x, cfg, desc, cache, *, pos):
    """One-token decode. x (B,1,D). Returns (x, new_cache)."""
    new_cache = dict(cache)
    h = L.rmsnorm(p["norm1"], x, cfg.norm_eps)
    if desc.mixer == "gqa":
        y, new_cache["attn"] = A.gqa_decode(p["mixer"], h, cache["attn"],
                                            pos, cfg)
    elif desc.mixer == "mla":
        y, new_cache["attn"] = A.mla_decode(p["mixer"], h, cache["attn"],
                                            pos, cfg)
    elif desc.mixer == "cross":
        kv = (cache["cross_kv"]["k"], cache["cross_kv"]["v"])
        y = A.cross_forward(p["mixer"], h, kv, cfg)
    elif desc.mixer == "rwkv6":
        y, new_cache["rwkv"] = S.rwkv6_forward(p["mixer"], h, cfg,
                                               state=cache["rwkv"],
                                               return_state=True)
    elif desc.mixer == "mamba":
        y, new_cache["mamba"] = S.mamba_forward(p["mixer"], h, cfg,
                                                state=cache["mamba"],
                                                return_state=True)
    x = x + y
    if desc.cross:
        h = L.rmsnorm(p["norm_x"], x, cfg.norm_eps)
        kv = (cache["cross_kv"]["k"], cache["cross_kv"]["v"])
        x = x + A.cross_forward(p["cross"], h, kv, cfg)
    h = L.rmsnorm(p["norm2"], x, cfg.norm_eps)
    out, _, cm_prev = _apply_ffn(p, h, cfg, desc, cache, "decode")
    if cm_prev is not None:
        new_cache["cm_prev"] = cm_prev
    return x + out, new_cache
