"""Attention-free mixers: RWKV6 ("Finch", data-dependent decay linear
attention) and Mamba (selective SSM, used by Jamba's hybrid blocks).

Both are implemented as exact linear recurrences with ``lax.scan`` over
time for train/prefill and an O(1)-state single step for decode — which
is why these archs (unlike full attention) take the ``long_500k`` shape:
serve-state is O(d·state), independent of context length.

States:
  rwkv6: {"wkv": (B, nh, hd, hd), "x_prev": (B, D), "x_prev_cm": (B, D)}
  mamba: {"ssm": (B, d_inner, d_state), "conv": (B, d_inner, k-1)}
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from . import layers as L
from . import sharding as sh

_TSZ = 32      # rwkv6 ddlerp lora rank
_DSZ = 64      # rwkv6 decay lora rank


# ===========================================================================
# RWKV6 time-mix
# ===========================================================================

def init_rwkv6(key, cfg):
    d = cfg.d_model
    nh, hd = d // cfg.rwkv_head_dim, cfg.rwkv_head_dim
    ks = jax.random.split(key, 12)
    return {
        "mu": jax.random.uniform(ks[0], (5, d), jnp.float32, 0.0, 1.0),
        "ddlerp_a": L.init_dense(ks[1], (d, 5 * _TSZ), d),
        "ddlerp_b": L.init_dense(ks[2], (5, _TSZ, d), _TSZ),
        "w0": jnp.full((d,), -2.0, jnp.float32),
        "w_a": L.init_dense(ks[3], (d, _DSZ), d),
        "w_b": L.init_dense(ks[4], (_DSZ, d), _DSZ),
        "u": jax.random.normal(ks[5], (nh, hd), jnp.float32) * 0.1,
        "wr": L.init_dense(ks[6], (d, d), d),
        "wk": L.init_dense(ks[7], (d, d), d),
        "wv": L.init_dense(ks[8], (d, d), d),
        "wg": L.init_dense(ks[9], (d, d), d),
        "wo": L.init_dense(ks[10], (d, d), d),
        "ln_x": jnp.ones((d,), jnp.float32),
    }


def spec_rwkv6():
    return {"mu": (None, None), "ddlerp_a": ("fsdp", None),
            "ddlerp_b": (None, None, "fsdp"), "w0": (None,),
            "w_a": ("fsdp", None), "w_b": (None, "fsdp"),
            "u": ("tp", None), "wr": ("fsdp", "tp"), "wk": ("fsdp", "tp"),
            "wv": ("fsdp", "tp"), "wg": ("fsdp", "tp"), "wo": ("tp", "fsdp"),
            "ln_x": (None,)}


def _rwkv_inputs(p, x, x_prev, cfg):
    """Data-dependent token-shift (ddlerp) + projections.
    x (B,S,D); x_prev (B,D) is the token before x[:,0]."""
    dtype = cfg.dtype
    shifted = jnp.concatenate([x_prev[:, None], x[:, :-1]], axis=1)
    xx = shifted - x
    base = x + xx * p["mu"][0].astype(dtype)
    lora = jnp.tanh(jnp.einsum("bsd,dr->bsr", base,
                               p["ddlerp_a"].astype(dtype)))
    lora = lora.reshape(*lora.shape[:-1], 5, _TSZ)
    offs = jnp.einsum("bsir,ird->ibsd", lora, p["ddlerp_b"].astype(dtype))
    mixed = [x + xx * (p["mu"][i].astype(dtype) + offs[i]) for i in range(5)]
    xw, xk, xv, xr, xg = mixed
    # data-dependent per-channel decay w_t in (0,1)
    dw = jnp.einsum("bsr,rd->bsd", jnp.tanh(
        jnp.einsum("bsd,dr->bsr", xw, p["w_a"].astype(dtype))),
        p["w_b"].astype(dtype))
    logw = -jnp.exp(jnp.clip(p["w0"].astype(jnp.float32)
                             + dw.astype(jnp.float32), -8.0, 4.0))
    r = jnp.einsum("bsd,de->bse", xr, p["wr"].astype(dtype))
    k = jnp.einsum("bsd,de->bse", xk, p["wk"].astype(dtype))
    v = jnp.einsum("bsd,de->bse", xv, p["wv"].astype(dtype))
    g = jax.nn.silu(jnp.einsum("bsd,de->bse", xg, p["wg"].astype(dtype)))
    return r, k, v, g, logw


def _heads(t, nh, hd):
    return t.reshape(*t.shape[:-1], nh, hd)


def _group_norm(y, scale, nh, eps):
    """Per-head layer norm on (B,S,nh,hd) flattened output."""
    b, s, d = y.shape
    yh = y.reshape(b, s, nh, d // nh).astype(jnp.float32)
    mean = yh.mean(axis=-1, keepdims=True)
    var = yh.var(axis=-1, keepdims=True)
    yh = (yh - mean) * jax.lax.rsqrt(var + eps)
    return (yh.reshape(b, s, d) * scale).astype(y.dtype)


def _wkv_scan(r, k, v, logw, u, s0):
    """Exact WKV6 recurrence. r/k/v (B,S,nh,hd); logw (B,S,nh,hd) log-decay;
    u (nh,hd); s0 (B,nh,hd,hd). Returns (y (B,S,nh,hd), s_final)."""
    def step(s, inp):
        r_t, k_t, v_t, lw_t = inp                      # (B,nh,hd)
        kv = jnp.einsum("bhk,bhv->bhkv", k_t, v_t)     # rank-1 update
        y_t = jnp.einsum("bhk,bhkv->bhv", r_t, s + u[None, :, :, None] * kv)
        s = jnp.exp(lw_t)[..., None] * s + kv
        return s, y_t

    xs = jax.tree_util.tree_map(lambda t: t.swapaxes(0, 1).astype(jnp.float32),
                                (r, k, v, logw))
    s_f, ys = jax.lax.scan(step, s0.astype(jnp.float32), xs)
    return ys.swapaxes(0, 1), s_f


def rwkv6_forward(p, x, cfg, state=None, return_state=False):
    """x (B,S,D). state carries (wkv, x_prev) across segments/decode."""
    b, s, d = x.shape
    nh, hd = d // cfg.rwkv_head_dim, cfg.rwkv_head_dim
    if state is None:
        x_prev = jnp.zeros((b, d), cfg.dtype)
        s0 = jnp.zeros((b, nh, hd, hd), jnp.float32)
    else:
        x_prev, s0 = state["x_prev"], state["wkv"]
    r, k, v, g, logw = _rwkv_inputs(p, x, x_prev, cfg)
    y, s_f = _wkv_scan(_heads(r, nh, hd), _heads(k, nh, hd),
                       _heads(v, nh, hd), _heads(logw, nh, hd),
                       p["u"].astype(jnp.float32), s0)
    y = y.reshape(b, s, d).astype(cfg.dtype)
    y = _group_norm(y, p["ln_x"].astype(jnp.float32), nh, cfg.norm_eps) * g
    out = jnp.einsum("bse,ed->bsd", y, p["wo"].astype(cfg.dtype))
    out = sh.shard(out, "dp", None, None)
    if return_state:
        return out, {"x_prev": x[:, -1].astype(cfg.dtype), "wkv": s_f}
    return out


def init_rwkv6_state(cfg, batch):
    d = cfg.d_model
    nh, hd = d // cfg.rwkv_head_dim, cfg.rwkv_head_dim
    return {"x_prev": jnp.zeros((batch, d), cfg.dtype),
            "wkv": jnp.zeros((batch, nh, hd, hd), jnp.float32)}


def rwkv6_state_spec(cfg):
    return {"x_prev": ("dp", None), "wkv": ("dp", "tp", None, None)}


# --- rwkv channel-mix (its FFN counterpart; token-shifted squared relu) ----

def init_rwkv_cm(key, cfg):
    d, f = cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 3)
    return {"mu_k": jax.random.uniform(ks[0], (d,), jnp.float32, 0, 1),
            "mu_r": jax.random.uniform(ks[1], (d,), jnp.float32, 0, 1),
            "wk": L.init_dense(ks[0], (d, f), d),
            "wv": L.init_dense(ks[1], (f, d), f),
            "wr": L.init_dense(ks[2], (d, d), d)}


def spec_rwkv_cm():
    return {"mu_k": (None,), "mu_r": (None,), "wk": ("fsdp", "tp"),
            "wv": ("tp", "fsdp"), "wr": ("fsdp", None)}


def rwkv_cm_forward(p, x, cfg, x_prev=None, return_state=False):
    dtype = cfg.dtype
    b = x.shape[0]
    if x_prev is None:
        x_prev = jnp.zeros((b, x.shape[-1]), dtype)
    shifted = jnp.concatenate([x_prev[:, None], x[:, :-1]], axis=1)
    xx = shifted - x
    xk = x + xx * p["mu_k"].astype(dtype)
    xr = x + xx * p["mu_r"].astype(dtype)
    kk = jnp.einsum("bsd,df->bsf", xk, p["wk"].astype(dtype))
    kk = jnp.square(jax.nn.relu(kk))
    out = jnp.einsum("bsf,fd->bsd", kk, p["wv"].astype(dtype))
    r = jax.nn.sigmoid(jnp.einsum("bsd,de->bse", xr, p["wr"].astype(dtype)))
    out = r * out
    out = sh.shard(out, "dp", None, None)
    if return_state:
        return out, x[:, -1].astype(dtype)
    return out


# ===========================================================================
# Mamba (selective SSM) — Jamba's dominant mixer
# ===========================================================================

def init_mamba(key, cfg):
    d = cfg.d_model
    di = cfg.mamba_expand * d
    ds, kconv = cfg.mamba_d_state, cfg.mamba_conv
    dt_rank = max(d // 16, 1)
    ks = jax.random.split(key, 6)
    a = jnp.tile(jnp.arange(1, ds + 1, dtype=jnp.float32)[None], (di, 1))
    return {
        "in_proj": L.init_dense(ks[0], (d, 2 * di), d),
        "conv_w": L.init_dense(ks[1], (di, kconv), kconv),
        "conv_b": jnp.zeros((di,), jnp.float32),
        "x_proj": L.init_dense(ks[2], (di, dt_rank + 2 * ds), di),
        "dt_proj": L.init_dense(ks[3], (dt_rank, di), dt_rank),
        "dt_bias": jnp.log(jnp.expm1(  # softplus^-1 of dt in [1e-3, 1e-1]
            jnp.exp(jax.random.uniform(ks[4], (di,), jnp.float32,
                                       jnp.log(1e-3), jnp.log(1e-1))))),
        "a_log": jnp.log(a),
        "d_skip": jnp.ones((di,), jnp.float32),
        "out_proj": L.init_dense(ks[5], (di, d), di),
    }


def spec_mamba():
    return {"in_proj": ("fsdp", "tp"), "conv_w": ("tp", None),
            "conv_b": ("tp",), "x_proj": ("tp", None),
            "dt_proj": (None, "tp"), "dt_bias": ("tp",),
            "a_log": ("tp", None), "d_skip": ("tp",),
            "out_proj": ("tp", "fsdp")}


def _causal_depthwise_conv(x, w, b, conv_state=None):
    """x (B,S,di); w (di,k). Returns conv output and new conv state
    (last k-1 inputs)."""
    bsz, s, di = x.shape
    k = w.shape[1]
    if conv_state is None:
        conv_state = jnp.zeros((bsz, k - 1, di), x.dtype)
    xp = jnp.concatenate([conv_state, x], axis=1)          # (B, S+k-1, di)
    out = jnp.zeros((bsz, s, di), jnp.float32)
    for i in range(k):                                      # k is tiny (4)
        out = out + (xp[:, i:i + s] * w[:, i]).astype(jnp.float32)
    out = out + b
    new_state = xp[:, -(k - 1):] if k > 1 else conv_state
    return out.astype(x.dtype), new_state


def _ssm_scan(u, dt, bmat, cmat, a, d_skip, h0):
    """Selective-SSM recurrence.
    u (B,S,di) conv'd input; dt (B,S,di); bmat/cmat (B,S,ds); a (di,ds);
    h0 (B,di,ds). Returns y (B,S,di), h_final."""
    def step(h, inp):
        u_t, dt_t, b_t, c_t = inp
        da = jnp.exp(dt_t[..., None] * a[None])            # (B,di,ds)
        h = da * h + (dt_t * u_t)[..., None] * b_t[:, None, :]
        y_t = jnp.einsum("bds,bs->bd", h, c_t) + d_skip * u_t
        return h, y_t

    xs = jax.tree_util.tree_map(
        lambda t: t.swapaxes(0, 1).astype(jnp.float32), (u, dt, bmat, cmat))
    h_f, ys = jax.lax.scan(step, h0.astype(jnp.float32), xs)
    return ys.swapaxes(0, 1), h_f


@jax.custom_vjp
def _selscan_fused(u, dt, bmat, cmat, a):
    """Pallas selective-scan (kernels/selective_scan.py): state resident
    in VMEM, HBM traffic = kernel IO. Backward recomputes through the
    exact lax.scan reference (standard recompute-VJP until the mirror
    backward kernel lands)."""
    from repro.kernels.selective_scan import selective_scan_pallas
    interp = jax.default_backend() != "tpu"
    return selective_scan_pallas(u, dt, bmat, cmat, a, interpret=interp)


def _selscan_ref(u, dt, bmat, cmat, a):
    b, _, di = u.shape
    h0 = jnp.zeros((b, di, bmat.shape[-1]), jnp.float32)
    y, _ = _ssm_scan(u, dt, bmat, cmat, a, jnp.zeros((di,), jnp.float32),
                     h0)
    return y


def _selscan_fwd(u, dt, bmat, cmat, a):
    return _selscan_fused(u, dt, bmat, cmat, a), (u, dt, bmat, cmat, a)


def _selscan_bwd(res, g):
    _, vjp = jax.vjp(_selscan_ref, *res)
    return vjp(g)


_selscan_fused.defvjp(_selscan_fwd, _selscan_bwd)


def mamba_forward(p, x, cfg, state=None, return_state=False):
    dtype = cfg.dtype
    b, s, d = x.shape
    di = cfg.mamba_expand * d
    ds = cfg.mamba_d_state
    dt_rank = max(d // 16, 1)
    xz = jnp.einsum("bsd,de->bse", x, p["in_proj"].astype(dtype))
    xin, z = jnp.split(xz, 2, axis=-1)
    xin = sh.shard(xin, "dp", None, "tp")
    conv_state = state["conv"] if state is not None else None
    xc, conv_state = _causal_depthwise_conv(
        xin, p["conv_w"].astype(dtype), p["conv_b"].astype(dtype), conv_state)
    xc = jax.nn.silu(xc)
    proj = jnp.einsum("bse,er->bsr", xc, p["x_proj"].astype(dtype))
    dt, bmat, cmat = jnp.split(proj, [dt_rank, dt_rank + ds], axis=-1)
    dt = jax.nn.softplus(jnp.einsum("bsr,re->bse", dt,
                                    p["dt_proj"].astype(dtype)).astype(
        jnp.float32) + p["dt_bias"])
    a = -jnp.exp(p["a_log"])
    h0 = (state["ssm"] if state is not None
          else jnp.zeros((b, di, ds), jnp.float32))
    if (cfg.mamba_pallas and state is None and not return_state
            and s % 64 == 0 and di % 64 == 0):
        y = (_selscan_fused(xc.astype(jnp.float32), dt,
                            bmat.astype(jnp.float32),
                            cmat.astype(jnp.float32), a)
             + p["d_skip"] * xc.astype(jnp.float32))
        h_f = h0
    else:
        y, h_f = _ssm_scan(xc, dt, bmat.astype(jnp.float32),
                           cmat.astype(jnp.float32), a, p["d_skip"], h0)
    y = (y.astype(dtype) * jax.nn.silu(z))
    out = jnp.einsum("bse,ed->bsd", y, p["out_proj"].astype(dtype))
    out = sh.shard(out, "dp", None, None)
    if return_state:
        return out, {"conv": conv_state, "ssm": h_f}
    return out


def init_mamba_state(cfg, batch):
    di = cfg.mamba_expand * cfg.d_model
    return {"conv": jnp.zeros((batch, cfg.mamba_conv - 1, di), cfg.dtype),
            "ssm": jnp.zeros((batch, di, cfg.mamba_d_state), jnp.float32)}


def mamba_state_spec(cfg):
    return {"conv": ("dp", None, "tp"), "ssm": ("dp", "tp", None)}
