"""Logical-axis sharding for the model zoo.

Parameters and activations are annotated with *logical* axes which a
:class:`Parallelism` context resolves onto physical mesh axes:

  "fsdp"  -> ("pod", "data") (multi-pod) / ("data",) — ZeRO-style weight
             sharding over the batch axes
  "tp"    -> "model" — tensor parallel (heads / d_ff / experts / vocab)
  "dp"    -> ("pod", "data") — batch sharding
  None    -> replicated

On a single device (CPU tests) the context is empty and every annotation
is a no-op, so the same model code runs everywhere.
"""
from __future__ import annotations

import dataclasses
import threading
from contextlib import contextmanager
from typing import Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

Logical = Union[str, Tuple[str, ...], None]


@dataclasses.dataclass(frozen=True)
class Parallelism:
    mesh: Optional[Mesh] = None
    fsdp_axes: Tuple[str, ...] = ()
    tp_axis: Optional[str] = None
    dp_axes: Tuple[str, ...] = ()

    @property
    def tp_size(self) -> int:
        if self.mesh is None or self.tp_axis is None:
            return 1
        return self.mesh.shape[self.tp_axis]

    def resolve(self, logical: Logical):
        """Logical axis name(s) -> physical mesh axis entry for P(...)."""
        if logical is None:
            return None
        if isinstance(logical, tuple):
            out = []
            for l in logical:
                r = self.resolve(l)
                if r is None:
                    continue
                out.extend(r if isinstance(r, tuple) else (r,))
            return tuple(out) if out else None
        if logical == "fsdp":
            return self.fsdp_axes if self.fsdp_axes else None
        if logical == "tp":
            return self.tp_axis
        if logical == "dp":
            return self.dp_axes if self.dp_axes else None
        raise ValueError(f"unknown logical axis {logical!r}")

    def pspec(self, *logical: Logical) -> P:
        return P(*(self.resolve(l) for l in logical))

    def sharding(self, *logical: Logical) -> Optional[NamedSharding]:
        if self.mesh is None:
            return None
        return NamedSharding(self.mesh, self.pspec(*logical))


_STATE = threading.local()


def current() -> Parallelism:
    return getattr(_STATE, "ctx", None) or Parallelism()


@contextmanager
def parallelism(ctx: Parallelism):
    prev = getattr(_STATE, "ctx", None)
    _STATE.ctx = ctx
    try:
        yield ctx
    finally:
        _STATE.ctx = prev


def make_parallelism(mesh: Optional[Mesh]) -> Parallelism:
    """Infer logical->physical mapping from mesh axis names."""
    if mesh is None:
        return Parallelism()
    names = tuple(mesh.axis_names)
    batchy = tuple(n for n in names if n in ("pod", "data", "replica"))
    tp = "model" if "model" in names else None
    return Parallelism(mesh=mesh, fsdp_axes=batchy, tp_axis=tp,
                       dp_axes=batchy)


def prune_spec(spec: P, shape, mesh: Mesh) -> P:
    """Drop mesh axes that do not evenly divide the corresponding dim
    (e.g. batch=1 on the dp axes, 24 heads on tp=16, vocab=49155). Axes
    are dropped left-to-right ("pod" before "data") until the remainder
    divides."""
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    out = []
    for d, entry in enumerate(spec):
        if entry is None or d >= len(shape):
            out.append(entry)
            continue
        axes = entry if isinstance(entry, tuple) else (entry,)
        axes = list(axes)
        while axes and shape[d] % _prod(sizes[a] for a in axes) != 0:
            axes.pop(0)
        out.append(tuple(axes) if len(axes) > 1 else (axes[0] if axes
                                                      else None))
    return P(*out)


def _prod(it):
    r = 1
    for v in it:
        r *= v
    return r


def shard(x: jax.Array, *logical: Logical) -> jax.Array:
    """Activation sharding constraint (no-op without a mesh); prunes
    annotations that don't divide the shape."""
    ctx = current()
    if ctx.mesh is None:
        return x
    spec = prune_spec(ctx.pspec(*logical), x.shape, ctx.mesh)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(ctx.mesh, spec))


# --------------------------------------------------------------------------
# Parameter trees with attached logical specs
# --------------------------------------------------------------------------

def to_named_shardings(abstract_tree, spec_tree, ctx: Parallelism):
    """(ShapeDtypeStruct tree, logical-spec tree) -> NamedSharding tree,
    with per-dim divisibility pruning."""
    def conv(aval, spec):
        if ctx.mesh is None:
            return None
        p = prune_spec(ctx.pspec(*spec), aval.shape, ctx.mesh)
        return NamedSharding(ctx.mesh, p)

    avals, tdef = jax.tree_util.tree_flatten(abstract_tree)
    specs, _ = jax.tree_util.tree_flatten(
        spec_tree, is_leaf=lambda s: isinstance(s, tuple))
    assert len(avals) == len(specs), (len(avals), len(specs))
    return jax.tree_util.tree_unflatten(tdef, [conv(a, s)
                                               for a, s in zip(avals, specs)])


def stack_spec(spec_tree):
    """Prepend a replicated leading (scan/stack) dim to every leaf spec."""
    return jax.tree_util.tree_map(lambda s: (None,) + s, spec_tree,
                                  is_leaf=lambda s: isinstance(s, tuple))
