"""Mixture-of-Experts FFN with expert parallelism.

Dispatch strategy (TPU-native, see DESIGN.md §4): activations are already
replicated across the "model" (tp) axis by the surrounding tensor
parallelism, so expert parallelism needs **no all-to-all**: each tp rank
owns E/tp experts, locally gathers the tokens routed to its experts into
a capacity-bounded buffer (sort-free scatter via running-rank), runs the
expert GEMMs, and the combine is a single psum over tp — the same
collective shape as a TP MLP output reduction.

Routers: "softmax" (learned top-k, the standard) and "fcm" — the paper's
fuzzy-membership bridge: experts act as cluster centers over token
embeddings and the gate is the FCM membership (Eq. 4, m=2) truncated to
top-k. See DESIGN.md §5.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from . import layers as L
from . import sharding as sh


def init_moe(key, cfg):
    e = cfg.moe
    d, f = cfg.d_model, e.d_ff_expert
    ks = jax.random.split(key, 5)
    p = {
        "router": L.init_dense(ks[0], (d, e.n_experts), d),
        "w_gate": L.init_dense(ks[1], (e.n_experts, d, f), d),
        "w_up": L.init_dense(ks[2], (e.n_experts, d, f), d),
        "w_down": L.init_dense(ks[3], (e.n_experts, f, d), f),
    }
    if e.n_shared > 0:
        p["shared"] = L.init_mlp(ks[4], d, e.n_shared * f)
    return p


def spec_moe(cfg):
    s = {"router": ("fsdp", None),
         "w_gate": ("tp", "fsdp", None), "w_up": ("tp", "fsdp", None),
         "w_down": ("tp", None, "fsdp")}
    if cfg.moe.n_shared > 0:
        s["shared"] = L.spec_mlp()
    return s


def _route(xf, router_w, cfg):
    """Token -> (top-k ids, gates, aux load-balance loss). xf (T, D)."""
    e = cfg.moe
    if e.router == "fcm":
        # FCM bridge: router rows are cluster centers; gate = fuzzy
        # membership with m=2 (Eq. 4 of the paper): u_e ∝ 1/d2_e.
        centers = router_w.T.astype(jnp.float32)             # (E, D)
        x32 = xf.astype(jnp.float32)
        d2 = (jnp.sum(x32 * x32, -1, keepdims=True)
              - 2.0 * x32 @ centers.T
              + jnp.sum(centers * centers, -1)[None, :])
        p = 1.0 / jnp.clip(d2, 1e-6, None)
        probs = p / jnp.sum(p, axis=-1, keepdims=True)
    else:
        logits = (xf.astype(jnp.float32)
                  @ router_w.astype(jnp.float32))             # (T, E)
        probs = jax.nn.softmax(logits, axis=-1)
    gates, idx = jax.lax.top_k(probs, e.top_k)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)
    # Switch-style load-balance aux loss.
    density = jnp.mean(jax.nn.one_hot(idx[:, 0], e.n_experts,
                                      dtype=jnp.float32), axis=0)
    mean_prob = jnp.mean(probs, axis=0)
    aux = e.n_experts * jnp.sum(density * mean_prob)
    return idx, gates.astype(xf.dtype), aux


def _local_expert_ffn(xf, idx, gates, wg, wu, wd, e_start, capacity, dtype):
    """Capacity-bounded local dispatch for the expert slice
    [e_start, e_start+E_loc). xf (T, D); idx/gates (T, K)."""
    t, dmodel = xf.shape
    k = idx.shape[1]
    e_loc = wg.shape[0]
    flat_e = idx.reshape(-1)                                 # (T*K,)
    le = flat_e - e_start
    local = (le >= 0) & (le < e_loc)
    le_c = jnp.where(local, le, e_loc)                       # overflow bucket
    # running rank within each local expert (first-come capacity policy)
    onehot = jax.nn.one_hot(le_c, e_loc, dtype=jnp.int32)    # (T*K, E_loc)
    rank = jnp.cumsum(onehot, axis=0) - onehot               # entries before
    pos = jnp.sum(rank * onehot, axis=-1)                    # (T*K,)
    keep = local & (pos < capacity)
    slot = jnp.where(keep, le_c * capacity + pos, e_loc * capacity)
    # Index-based dispatch: scatter token *ids*, gather rows — avoids
    # materializing the (T*K, D) repeated-token matrix.
    tok_id = jnp.arange(t * k, dtype=jnp.int32) // k         # (T*K,)
    buf_tok = jnp.full((e_loc * capacity + 1,), t, jnp.int32)
    buf_tok = buf_tok.at[slot].set(jnp.where(keep, tok_id, t))
    xf_ext = jnp.concatenate([xf.astype(dtype),
                              jnp.zeros((1, dmodel), dtype)], axis=0)
    xe = xf_ext[buf_tok[:-1]].reshape(e_loc, capacity, dmodel)
    h = jnp.einsum("ecd,edf->ecf", xe, wg.astype(dtype))
    u = jnp.einsum("ecd,edf->ecf", xe, wu.astype(dtype))
    h = jax.nn.silu(h) * u
    ye = jnp.einsum("ecf,efd->ecd", h, wd.astype(dtype))
    rows = jnp.concatenate(
        [ye.reshape(-1, dmodel), jnp.zeros((1, dmodel), dtype)], axis=0)
    contrib = rows[slot] * jnp.where(keep, gates.reshape(-1), 0.0)[:, None]
    return contrib.reshape(t, k, dmodel).sum(axis=1)         # (T, D)


def _capacity(e, t_local: int) -> int:
    return int(max(e.top_k * t_local / e.n_experts * e.capacity_factor, 4))


def moe_ffn(p, x, cfg):
    """x (B, S, D) -> (out (B, S, D), aux_loss scalar)."""
    e = cfg.moe
    b, s, d = x.shape
    t = b * s
    xf = x.reshape(t, d)
    idx, gates, aux = _route(xf, p["router"], cfg)
    ctx = sh.current()
    tp = ctx.tp_size
    if tp > 1:
        # EP over the tp axis: expert stacks padded to a multiple of tp
        # (granite's 40 experts on tp=16 -> 48 with 3 dead slots; dead
        # experts are never routed to, so numerics are unchanged).
        e_pad = -(-e.n_experts // tp) * tp
        wg, wu, wd = (p["w_gate"], p["w_up"], p["w_down"])
        if e_pad != e.n_experts:
            padn = e_pad - e.n_experts
            pad = lambda w: jnp.concatenate(
                [w, jnp.zeros((padn,) + w.shape[1:], w.dtype)], axis=0)
            wg, wu, wd = pad(wg), pad(wu), pad(wd)
        e_loc = e_pad // tp
        mesh = ctx.mesh
        xspec = sh.prune_spec(
            jax.sharding.PartitionSpec(ctx.resolve("dp"), None),
            (t, d), mesh)
        sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
        entry = xspec[0]
        t_loc = t
        if entry is not None:
            for a in (entry if isinstance(entry, tuple) else (entry,)):
                t_loc //= sizes[a]
        capacity = _capacity(e, t_loc)
        espec = jax.sharding.PartitionSpec(ctx.tp_axis)

        def shard_body(xf_l, idx_l, gates_l, wg_l, wu_l, wd_l):
            tp_rank = jax.lax.axis_index(ctx.tp_axis)
            out = _local_expert_ffn(xf_l, idx_l, gates_l,
                                    wg_l, wu_l, wd_l,
                                    tp_rank * e_loc, capacity, cfg.dtype)
            return jax.lax.psum(out, ctx.tp_axis)

        out = jax.shard_map(
            shard_body, mesh=mesh,
            in_specs=(xspec, xspec, xspec, espec, espec, espec),
            out_specs=xspec,
            check_vma=False,
        )(xf, idx, gates, wg, wu, wd)
    else:
        out = _local_expert_ffn(xf, idx, gates, p["w_gate"], p["w_up"],
                                p["w_down"], 0, _capacity(e, t), cfg.dtype)
    if "shared" in p:
        out = out + L.mlp(p["shared"], x, cfg.dtype).reshape(b * s, d)
    out = out.reshape(b, s, d)
    return sh.shard(out, "dp", None, None), aux
