"""Elementary layers: norms, embeddings, RoPE, SwiGLU MLP.

Every module provides ``init_*(key, cfg) -> params`` and a structurally
identical ``spec_*(cfg) -> logical-axis tuples`` tree (verified to match
in tests/test_configs.py). Params are stored fp32 (master copy) and cast
to the compute dtype at use.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from . import sharding as sh


def _normal(key, shape, scale):
    return (jax.random.normal(key, shape, jnp.float32) * scale)


def gathered(w, dtype, *use_spec):
    """Cast a weight to compute dtype, optionally constraining it to its
    use sharding. NOTE (§Perf hillclimb #2, refuted): forcing the
    weights replicated over the fsdp axes (all-gather-at-use) was
    measured WORSE on mistral-large train (wire +3%, compute +20%) —
    GSPMD's partial-sum plan shards the contraction over data x tp (256
    ways), which beats weight-gathering on compute and isn't worse on
    wire once backward wgrad reductions are counted. Constraint disabled;
    kept for documentation and future per-layer tuning."""
    del use_spec
    return w.astype(dtype)


# ---------------------------------------------------------------------------
# RMSNorm
# ---------------------------------------------------------------------------

def init_rmsnorm(d):
    return {"scale": jnp.ones((d,), jnp.float32)}


def spec_rmsnorm():
    return {"scale": (None,)}


def rmsnorm(p, x, eps):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * p["scale"]).astype(x.dtype)


# ---------------------------------------------------------------------------
# Embedding / unembedding
# ---------------------------------------------------------------------------

def init_embedding(key, vocab, d):
    return {"table": _normal(key, (vocab, d), d ** -0.5)}


def spec_embedding():
    return {"table": ("tp", "fsdp")}


def embed(p, tokens, dtype):
    out = jnp.take(p["table"].astype(dtype), tokens, axis=0)
    return sh.shard(out, "dp", None, None)


def unembed(p, x, dtype):
    """Logits in fp32 (softmax stability), vocab sharded on tp."""
    logits = jnp.einsum("bsd,vd->bsv", x.astype(dtype),
                        p["table"].astype(dtype)).astype(jnp.float32)
    return sh.shard(logits, "dp", None, "tp")


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_freqs(head_dim, theta):
    exponents = jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim
    return 1.0 / (theta ** exponents)                      # (head_dim/2,)


def apply_rope(x, positions, theta):
    """x: (..., S, H, head_dim) or (..., S, head_dim);
    positions: (S,) or (B, S)."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # (...,S,hd/2)
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    # insert head axes between S and head_dim
    while cos.ndim < x.ndim:
        cos, sin = cos[..., None, :], sin[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# SwiGLU MLP
# ---------------------------------------------------------------------------

def init_mlp(key, d, f):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w_gate": _normal(k1, (d, f), d ** -0.5),
        "w_up": _normal(k2, (d, f), d ** -0.5),
        "w_down": _normal(k3, (f, d), f ** -0.5),
    }


def spec_mlp():
    return {"w_gate": ("fsdp", "tp"), "w_up": ("fsdp", "tp"),
            "w_down": ("tp", "fsdp")}


def mlp(p, x, dtype):
    h = jnp.einsum("bsd,df->bsf", x, gathered(p["w_gate"], dtype, None, "tp"),
                   preferred_element_type=dtype)
    u = jnp.einsum("bsd,df->bsf", x, gathered(p["w_up"], dtype, None, "tp"),
                   preferred_element_type=dtype)
    h = jax.nn.silu(h) * u
    out = jnp.einsum("bsf,fd->bsd", h, gathered(p["w_down"], dtype, "tp", None),
                     preferred_element_type=dtype)
    return sh.shard(out, "dp", None, None)


# ---------------------------------------------------------------------------
# Dense projection helper
# ---------------------------------------------------------------------------

def init_dense(key, shape, fan_in):
    return _normal(key, shape, fan_in ** -0.5)
