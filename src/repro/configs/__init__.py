"""Architecture registry: ``get_config(name)`` / ``list_archs()``."""
from __future__ import annotations

from . import (base, deepseek_v2_236b, fcm_brainweb, granite_moe_3b,
               jamba_52b, llama32_1b, llama32_3b, llama32_vision_90b,
               mistral_large_123b, mistral_nemo_12b, rwkv6_1b6,
               whisper_tiny)
from .base import (SHAPES, BlockDesc, MLAConfig, ModelConfig,  # noqa: F401
                   MoEConfig, ShapeConfig, applicable_shapes)

_REGISTRY = {
    "mistral-nemo-12b": mistral_nemo_12b.make_config,
    "mistral-large-123b": mistral_large_123b.make_config,
    "llama3.2-3b": llama32_3b.make_config,
    "llama3.2-1b": llama32_1b.make_config,
    "rwkv6-1.6b": rwkv6_1b6.make_config,
    "deepseek-v2-236b": deepseek_v2_236b.make_config,
    "granite-moe-3b-a800m": granite_moe_3b.make_config,
    "whisper-tiny": whisper_tiny.make_config,
    "llama-3.2-vision-90b": llama32_vision_90b.make_config,
    "jamba-v0.1-52b": jamba_52b.make_config,
}


def list_archs():
    return sorted(_REGISTRY)


def get_config(name: str) -> ModelConfig:
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; available: {list_archs()}")
    return _REGISTRY[name]()
