"""granite-moe-3b-a800m [moe] 32L d1536 24H (GQA kv=8) expert d_ff=512,
MoE 40 experts top-8, vocab=49155. [hf:ibm-granite/granite-3.0-3b-a800m]"""
from .base import BlockDesc, ModelConfig, MoEConfig


def make_config() -> ModelConfig:
    return ModelConfig(
        name="granite-moe-3b-a800m", family="moe",
        n_layers=32, d_model=1536, n_heads=24, n_kv_heads=8,
        head_dim=64, d_ff=512, vocab_size=49155,
        group_layout=(BlockDesc(mixer="gqa", ffn="moe"),),
        moe=MoEConfig(n_experts=40, top_k=8, d_ff_expert=512),
        rope_theta=1e4, sub_quadratic=False,
    )
