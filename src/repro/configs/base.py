"""Config schema: architectures (assigned pool + the paper's own FCM
config) and the assigned input-shape registry."""
from __future__ import annotations

import dataclasses
from typing import Any, Optional, Tuple

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class BlockDesc:
    mixer: str = "gqa"          # gqa | mla | mamba | rwkv6 | cross
    ffn: str = "swiglu"         # swiglu | gelu | moe | rwkv_cm
    cross: bool = False         # extra cross-attn sub-layer (whisper dec)
    gated: bool = False         # gated cross-attn (llama-vision)


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_ff_expert: int
    n_shared: int = 0
    capacity_factor: float = 1.25
    router: str = "softmax"     # softmax | fcm (paper bridge)


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    kv_lora_rank: int = 512
    q_lora_rank: int = 1536
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                 # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None
    group_layout: Tuple[BlockDesc, ...] = (BlockDesc(),)
    enc_layers: int = 0         # >0 -> encoder-decoder (whisper)
    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    n_img_tokens: int = 0       # vlm stub frontend tokens
    audio_frames: bool = False  # input is precomputed frame embeddings
    rope_theta: float = 1e6
    norm_eps: float = 1e-5
    rwkv_head_dim: int = 64
    mamba_d_state: int = 16
    mamba_expand: int = 2
    mamba_conv: int = 4
    mamba_pallas: bool = False   # Pallas selective-scan kernel (train fwd)
    sub_quadratic: bool = False  # True -> long_500k shape applies
    dtype: Any = jnp.bfloat16
    # execution knobs. flash (chunked online-softmax) pays off for long
    # prefill; at train_4k the plain path + remat is lighter because
    # backward through the chunk scans stacks residuals.
    flash_threshold: int = 4096  # above this seq, use chunked attention
    q_chunk: int = 512
    kv_chunk: int = 1024
    remat: bool = True
    microbatches: int = 1

    def __post_init__(self):
        if self.head_dim is None:
            object.__setattr__(self, "head_dim",
                               self.d_model // self.n_heads)
        assert self.n_layers % len(self.group_layout) == 0, (
            self.name, self.n_layers, len(self.group_layout))

    @property
    def n_groups(self) -> int:
        return self.n_layers // len(self.group_layout)

    @property
    def is_encdec(self) -> bool:
        return self.enc_layers > 0

    def reduced(self) -> "ModelConfig":
        """Tiny same-family config for CPU smoke tests: one scan group,
        narrow dims, few experts — same code paths."""
        # capacity_factor=8: drop-free at smoke sizes so prefill/decode
        # parity tests are exact (capacity-policy drops depend on batch
        # composition, which differs between full-fwd and prefill runs).
        moe = (MoEConfig(n_experts=min(8, self.moe.n_experts),
                         top_k=min(2, self.moe.top_k), d_ff_expert=64,
                         n_shared=min(1, self.moe.n_shared),
                         capacity_factor=8.0,
                         router=self.moe.router)
               if self.moe else None)
        mla = (MLAConfig(kv_lora_rank=32, q_lora_rank=48,
                         qk_nope_head_dim=16, qk_rope_head_dim=8,
                         v_head_dim=16) if self.mla else None)
        return dataclasses.replace(
            self,
            name=self.name + "-smoke",
            n_layers=len(self.group_layout),
            d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
            d_ff=128, vocab_size=512, enc_layers=min(self.enc_layers, 2),
            moe=moe, mla=mla, n_img_tokens=8 if self.n_img_tokens else 0,
            rwkv_head_dim=16, mamba_d_state=4,
            flash_threshold=2048, microbatches=1,
            dtype=jnp.float32,    # exact parity checks on CPU
        )


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    kind: str            # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES = {
    "train_4k": ShapeConfig("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeConfig("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeConfig("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeConfig("long_500k", "decode", 524288, 1),
}


def applicable_shapes(cfg: ModelConfig):
    """The assigned 4 shapes minus the sub-quadratic rule skips
    (DESIGN.md §5)."""
    out = []
    for s in SHAPES.values():
        if s.name == "long_500k" and not cfg.sub_quadratic:
            continue                      # quadratic-attention skip
        out.append(s)
    return out
