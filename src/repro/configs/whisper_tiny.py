"""whisper-tiny [audio] enc-dec 4+4L d384 6H d_ff=1536 vocab=51865 —
conv frontend is a STUB per assignment: input_specs provides precomputed
frame embeddings (B, S, d). [arXiv:2212.04356]"""
from .base import BlockDesc, ModelConfig


def make_config() -> ModelConfig:
    return ModelConfig(
        name="whisper-tiny", family="audio",
        n_layers=4, d_model=384, n_heads=6, n_kv_heads=6,
        head_dim=64, d_ff=1536, vocab_size=51865,
        enc_layers=4, audio_frames=True,
        group_layout=(BlockDesc(mixer="gqa", ffn="gelu", cross=True),),
        rope_theta=1e4, sub_quadratic=False,
    )
