"""rwkv6-1.6b [ssm] "Finch" 24L d2048 attn-free, d_ff=7168 vocab=65536 —
data-dependent decay linear attention. [arXiv:2404.05892]"""
from .base import BlockDesc, ModelConfig


def make_config() -> ModelConfig:
    return ModelConfig(
        name="rwkv6-1.6b", family="ssm",
        n_layers=24, d_model=2048, n_heads=32, n_kv_heads=32,
        head_dim=64, d_ff=7168, vocab_size=65536,
        group_layout=(BlockDesc(mixer="rwkv6", ffn="rwkv_cm"),),
        rwkv_head_dim=64,
        sub_quadratic=True,          # O(1) state: long_500k applies
    )
