"""The paper's own configuration: FCM segmentation of brain phantom
slices into WM/GM/CSF/background (c=4, m=2, eps=0.005), dataset scaled
20 KB -> 1 MB (paper Table 3), plus a pod-scale 1 GB volume cell for the
dry-run."""
import dataclasses

from repro.core.fcm import FCMConfig


@dataclasses.dataclass(frozen=True)
class FCMJobConfig:
    name: str = "fcm-brainweb"
    fcm: FCMConfig = FCMConfig(n_clusters=4, m=2.0, eps=5e-3, max_iters=300)
    # paper Table 3 dataset sizes (bytes)
    table3_sizes = tuple(int(k * 1024) for k in
                         (20, 40, 60, 80, 100, 120, 140, 160, 180, 200,
                          300, 500, 700, 1000))
    # pod-scale dry-run: a 1 GiB voxel volume sharded over all chips
    dryrun_bytes: int = 1 << 30


def make_config() -> FCMJobConfig:
    return FCMJobConfig()
