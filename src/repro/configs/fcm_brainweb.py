"""The paper's own configuration: FCM segmentation of brain phantom
slices into WM/GM/CSF/background (c=4, m=2, eps=0.005), dataset scaled
20 KB -> 1 MB (paper Table 3), plus a pod-scale 1 GB volume cell for the
dry-run, and the spatially-regularized (FCM_S) cell for the noisy-MRI
workload."""
import dataclasses

from repro.core.fcm import FCMConfig
from repro.core.spatial import SpatialFCMConfig  # noqa: F401  (re-export)
from repro.superpixel.pipeline import SuperpixelFCMConfig  # noqa: F401
from repro.data.phantom import NOISE_LEVELS


@dataclasses.dataclass(frozen=True)
class FCMJobConfig:
    name: str = "fcm-brainweb"
    fcm: FCMConfig = FCMConfig(n_clusters=4, m=2.0, eps=5e-3, max_iters=300)
    # FCM_S for the noisy-MRI workload: 8-neighbor stencil, alpha=1
    # (the sweep in benchmarks/spatial_fcm.py backs these choices).
    spatial: SpatialFCMConfig = SpatialFCMConfig(
        n_clusters=4, m=2.0, eps=5e-3, max_iters=300,
        alpha=1.0, neighbors=8)
    # Superpixel compression for color / multi-modal stacks: ~256
    # superpixels replace N pixels in the fit (the vector analogue of
    # the 256-bin histogram); compactness 10 suits 0..255 features.
    superpixel: SuperpixelFCMConfig = SuperpixelFCMConfig(
        n_clusters=4, m=2.0, eps=5e-3, max_iters=300,
        n_segments=256, compactness=10.0, slic_iters=10)
    # Serving: the static bucket ladder every route pads to (one jit
    # signature per (bucket, payload shape); see serving/fcm_engine.py
    # route registry), shared by the examples and the throughput bench.
    serving_batch_sizes: tuple = (1, 8, 16, 64)
    # (gaussian sigma, impulse fraction) noise sweep for robustness evals
    noise_levels = NOISE_LEVELS
    # paper Table 3 dataset sizes (bytes)
    table3_sizes = tuple(int(k * 1024) for k in
                         (20, 40, 60, 80, 100, 120, 140, 160, 180, 200,
                          300, 500, 700, 1000))
    # pod-scale dry-run: a 1 GiB voxel volume sharded over all chips
    dryrun_bytes: int = 1 << 30


def make_config() -> FCMJobConfig:
    return FCMJobConfig()
