"""llama-3.2-vision-90b [vlm] 100L d8192 64H (GQA kv=8) d_ff=28672
vocab=128256 — gated cross-attn image layers every 5th layer; the vision
frontend is a STUB (input_specs provides patch embeddings).
[hf:meta-llama/Llama-3.2-90B-Vision]"""
from .base import BlockDesc, ModelConfig


def make_config() -> ModelConfig:
    self_blk = BlockDesc(mixer="gqa", ffn="swiglu")
    cross_blk = BlockDesc(mixer="cross", ffn="swiglu", gated=True)
    return ModelConfig(
        name="llama-3.2-vision-90b", family="vlm",
        n_layers=100, d_model=8192, n_heads=64, n_kv_heads=8,
        head_dim=128, d_ff=28672, vocab_size=128256,
        group_layout=(cross_blk, self_blk, self_blk, self_blk, self_blk),
        n_img_tokens=1601,          # one vision tile of 1601 patches
        rope_theta=5e5, sub_quadratic=False,
    )
