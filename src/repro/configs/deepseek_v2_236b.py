"""deepseek-v2-236b [moe] 60L d5120 128H, MLA (kv_lora=512), MoE 160
routed top-6 + 2 shared, expert d_ff=1536, vocab=102400.
[arXiv:2405.04434]

Simplification vs. the HF checkpoint: every layer is MoE (the real model
has one dense first layer); noted in DESIGN.md §Arch-applicability.
"""
from .base import BlockDesc, MLAConfig, ModelConfig, MoEConfig


def make_config() -> ModelConfig:
    return ModelConfig(
        name="deepseek-v2-236b", family="moe",
        n_layers=60, d_model=5120, n_heads=128, n_kv_heads=128,
        head_dim=128, d_ff=1536, vocab_size=102400,
        group_layout=(BlockDesc(mixer="mla", ffn="moe"),),
        mla=MLAConfig(kv_lora_rank=512, q_lora_rank=1536,
                      qk_nope_head_dim=128, qk_rope_head_dim=64,
                      v_head_dim=128),
        moe=MoEConfig(n_experts=160, top_k=6, d_ff_expert=1536,
                      n_shared=2),
        rope_theta=1e4, sub_quadratic=False,
    )
