"""jamba-v0.1-52b [hybrid] 32L d4096 32H (GQA kv=8) d_ff=14336
vocab=65536 — Mamba:attn 7:1 interleave, MoE 16 experts top-2 on every
other layer. [arXiv:2403.19887]"""
from .base import BlockDesc, ModelConfig, MoEConfig


def make_config() -> ModelConfig:
    # period-8 group: attention at index 4 (1:7 ratio), MoE on odd layers
    layout = tuple(
        BlockDesc(mixer=("gqa" if i == 4 else "mamba"),
                  ffn=("moe" if i % 2 == 1 else "swiglu"))
        for i in range(8))
    return ModelConfig(
        name="jamba-v0.1-52b", family="hybrid",
        n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8,
        head_dim=128, d_ff=14336, vocab_size=65536,
        group_layout=layout,
        moe=MoEConfig(n_experts=16, top_k=2, d_ff_expert=14336),
        mamba_d_state=16, mamba_expand=2, mamba_conv=4,
        rope_theta=1e6,
        sub_quadratic=True,      # mamba-dominant: long_500k applies
    )
