"""Train step assembly: loss, microbatch gradient accumulation, optional
int8 cross-pod gradient sync, AdamW update.

``make_train_step`` returns a pure (state, batch) -> (state, metrics)
function plus the logical sharding specs for state and batch, ready for
``jax.jit(..., in_shardings=..., out_shardings=...)`` — the launcher and
the dry-run both consume it. Build/trace it under
``sharding.parallelism(ctx)`` so activation constraints resolve.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import lm
from repro.models import sharding as sh
from . import grad_compress as gc
from . import optimizer as opt


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    optimizer: opt.OptimizerConfig = opt.OptimizerConfig()
    aux_loss_weight: float = 0.01
    # int8-compress the cross-pod gradient mean (pods become pure DP
    # replicas: fsdp stays within a pod). See grad_compress.py.
    compress_cross_pod: bool = False


# ---------------------------------------------------------------------------
# Loss
# ---------------------------------------------------------------------------

XENT_CHUNK = 512      # sequence positions per streamed-xent chunk


def _chunked_xent(params, x, labels, cfg: ModelConfig) -> jax.Array:
    """Streaming cross-entropy: unembed + softmax one sequence chunk at a
    time under remat, so the (B, S, V) fp32 logits tensor (3-6 GiB/dev on
    100k-vocab configs) never exists; backward recomputes per chunk."""
    from repro.models import layers as L
    b, s, d = x.shape
    chunk = min(XENT_CHUNK, s)
    if s % chunk != 0:
        chunk = s
    n = s // chunk
    xc = x.reshape(b, n, chunk, d).swapaxes(0, 1)          # (n,B,c,D)
    yc = labels.reshape(b, n, chunk).swapaxes(0, 1)

    @jax.checkpoint
    def body(acc, xs):
        x_c, y_c = xs
        logits = L.unembed(params["embed"], x_c, cfg.dtype)
        logits = logits.astype(jnp.float32)
        lse = jax.nn.logsumexp(logits, axis=-1)
        tgt = jnp.take_along_axis(logits, y_c[..., None], -1)[..., 0]
        return acc + jnp.sum(lse - tgt), None

    total, _ = jax.lax.scan(body, jnp.zeros((), jnp.float32), (xc, yc))
    return total / (b * s)


def loss_fn(params, batch: Dict[str, Any], cfg: ModelConfig,
            aux_weight: float):
    kwargs = {}
    if cfg.is_encdec:
        kwargs["frames"] = batch["frames"]
    if cfg.n_img_tokens:
        kwargs["memory"] = batch["image_embeds"]
    x, aux = lm.forward(params, batch["tokens"], cfg,
                        return_features=True, **kwargs)
    loss = _chunked_xent(params, x, batch["labels"], cfg)
    total = loss + aux_weight * aux
    return total, {"loss": loss, "aux_loss": aux,
                   "perplexity": jnp.exp(jnp.clip(loss, 0, 20.0))}


def _microbatch_grads(params, batch, cfg: ModelConfig, tcfg: TrainConfig):
    """Gradient accumulation over cfg.microbatches via lax.scan; the
    reduce-scatter of each microbatch's grads overlaps the next
    microbatch's compute under XLA's scheduler."""
    nmb = cfg.microbatches
    vg = jax.value_and_grad(loss_fn, has_aux=True)
    if nmb <= 1:
        (_, metrics), grads = vg(params, batch, cfg, tcfg.aux_loss_weight)
        return grads, metrics

    def split(x):
        return x.reshape((nmb, x.shape[0] // nmb) + x.shape[1:])

    mb = jax.tree_util.tree_map(split, batch)
    gz = jax.eval_shape(lambda p: vg(p, jax.tree_util.tree_map(
        lambda x: x[0], mb), cfg, tcfg.aux_loss_weight)[1], params)
    grads0 = jax.tree_util.tree_map(
        lambda s: jnp.zeros(s.shape, s.dtype), gz)

    def body(carry, mbatch):
        grads_acc, metrics_acc = carry
        (_, metrics), grads = vg(params, mbatch, cfg, tcfg.aux_loss_weight)
        grads_acc = jax.tree_util.tree_map(jnp.add, grads_acc, grads)
        metrics_acc = jax.tree_util.tree_map(jnp.add, metrics_acc, metrics)
        return (grads_acc, metrics_acc), None

    m0 = {"loss": jnp.zeros(()), "aux_loss": jnp.zeros(()),
          "perplexity": jnp.zeros(())}
    (grads, metrics), _ = jax.lax.scan(body, (grads0, m0), mb)
    grads = jax.tree_util.tree_map(lambda g: g / nmb, grads)
    metrics = jax.tree_util.tree_map(lambda m: m / nmb, metrics)
    return grads, metrics


# ---------------------------------------------------------------------------
# Train step
# ---------------------------------------------------------------------------

def init_state(key, cfg: ModelConfig,
               tcfg: TrainConfig = TrainConfig()):
    params = lm.init_params(key, cfg)
    return {"params": params,
            "opt": opt.init_opt_state(params,
                                      tcfg.optimizer.moment_dtype),
            "step": jnp.zeros((), jnp.int32)}


def abstract_state(cfg: ModelConfig, tcfg: TrainConfig = TrainConfig()):
    return jax.eval_shape(lambda k: init_state(k, cfg, tcfg),
                          jax.ShapeDtypeStruct((2,), jnp.uint32))


def state_specs(cfg: ModelConfig):
    pspec = lm.param_specs(cfg)
    return {"params": pspec,
            "opt": {"m": pspec, "v": pspec},
            "step": ()}


def batch_specs(cfg: ModelConfig):
    spec = {"tokens": ("dp", None), "labels": ("dp", None)}
    if cfg.is_encdec:
        spec["frames"] = ("dp", None, None)
    if cfg.n_img_tokens:
        spec["image_embeds"] = ("dp", None, None)
    return spec


def make_train_step(cfg: ModelConfig, tcfg: TrainConfig = TrainConfig()):
    """Returns train_step(state, batch) -> (state, metrics)."""

    def train_step(state, batch):
        ctx = sh.current()
        use_pod_compress = (tcfg.compress_cross_pod and ctx.mesh is not None
                            and "pod" in ctx.mesh.axis_names)
        if use_pod_compress:
            # Pods are pure DP replicas: grads computed per pod under
            # manual-'pod' shard_map (auto GSPMD within the pod), then
            # int8-compressed mean over the DCN axis.
            from jax.sharding import PartitionSpec as P

            def per_pod(params, batch):
                grads, metrics = _microbatch_grads(params, batch, cfg, tcfg)
                grads = gc.compressed_psum_mean(grads, "pod")
                metrics = jax.tree_util.tree_map(
                    lambda m: jax.lax.pmean(m, "pod"), metrics)
                return grads, metrics

            n_leaves_s = len(jax.tree_util.tree_leaves(state["params"]))
            n_leaves_b = len(jax.tree_util.tree_leaves(batch))
            grads, metrics = jax.shard_map(
                per_pod, mesh=ctx.mesh,
                in_specs=(jax.tree_util.tree_map(lambda _: P(), state["params"]),
                          jax.tree_util.tree_map(lambda _: P("pod"), batch)),
                out_specs=(jax.tree_util.tree_map(lambda _: P(), state["params"]),
                           {"loss": P(), "aux_loss": P(), "perplexity": P()}),
                axis_names={"pod"}, check_vma=False,
            )(state["params"], batch)
        else:
            grads, metrics = _microbatch_grads(state["params"], batch,
                                               cfg, tcfg)
        params, opt_state, om = opt.adamw_step(
            state["params"], grads, state["opt"], state["step"],
            tcfg.optimizer)
        metrics = dict(metrics, **om)
        new_state = {"params": params, "opt": opt_state,
                     "step": state["step"] + 1}
        return new_state, metrics

    return train_step
