"""Elastic scaling + straggler mitigation.

When nodes fail, the coordinator re-forms a mesh over the surviving
device set, restores the latest checkpoint with the new shardings, and
continues — checkpoints are mesh-agnostic (see checkpoint.py). The FCM
path is even cheaper: its whole state is c floats, so any surviving pod
resumes from centers alone.

``plan_mesh`` picks the largest usable (data, model) factorization for a
device count; ``reshard_state`` moves a restored state onto a new mesh.
``StepTimer`` is the straggler watchdog: per-step durations, outlier
flagging (> k x rolling median), and a hook the launcher uses to decide
when to checkpoint-and-rebalance.
"""
from __future__ import annotations

import time
from collections import deque
from typing import Callable, Optional, Sequence, Tuple

import jax

from repro.models import sharding as sh


def plan_mesh(n_devices: int, model_parallel: Optional[int] = None,
              pods: int = 1):
    """Largest mesh (pod, data, model) using <= n_devices. Prefers tp=16
    (one v5e tray), degrading to the largest power-of-two divisor."""
    per_pod = n_devices // pods
    if model_parallel is None:
        for tp in (16, 8, 4, 2, 1):
            if per_pod % tp == 0 and per_pod >= tp:
                model_parallel = tp
                break
    data = per_pod // model_parallel
    assert data >= 1
    devs = jax.devices()[:pods * data * model_parallel]
    import numpy as np
    if pods > 1:
        arr = np.array(devs).reshape(pods, data, model_parallel)
        return jax.sharding.Mesh(arr, ("pod", "data", "model"))
    arr = np.array(devs).reshape(data, model_parallel)
    return jax.sharding.Mesh(arr, ("data", "model"))


def reshard_state(state, specs, new_mesh) -> Tuple[object, sh.Parallelism]:
    """Move a (host or device) state tree onto a new mesh per logical
    specs. Returns (state, new Parallelism ctx)."""
    ctx = sh.make_parallelism(new_mesh)
    abstract = jax.tree_util.tree_map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), state)
    shardings = sh.to_named_shardings(abstract, specs, ctx)
    state = jax.tree_util.tree_map(
        lambda x, s: jax.device_put(x, s), state, shardings)
    return state, ctx


class StepTimer:
    """Rolling straggler detector: flags steps slower than
    ``threshold`` x the rolling median and counts consecutive slow steps
    so the launcher can trigger a checkpoint + re-mesh."""

    def __init__(self, window: int = 32, threshold: float = 2.0,
                 consecutive_limit: int = 5,
                 on_straggler: Optional[Callable[[float, float], None]] = None):
        self.durations = deque(maxlen=window)
        self.threshold = threshold
        self.consecutive_limit = consecutive_limit
        self.consecutive_slow = 0
        self.total_flagged = 0
        self.on_straggler = on_straggler
        self._t0: Optional[float] = None

    def start(self):
        self._t0 = time.perf_counter()

    def stop(self) -> bool:
        """Record; returns True if rebalance is recommended."""
        assert self._t0 is not None
        dt = time.perf_counter() - self._t0
        self._t0 = None
        med = self.median()
        self.durations.append(dt)
        if med is not None and dt > self.threshold * med:
            self.total_flagged += 1
            self.consecutive_slow += 1
            if self.on_straggler:
                self.on_straggler(dt, med)
        else:
            self.consecutive_slow = 0
        return self.consecutive_slow >= self.consecutive_limit

    def median(self) -> Optional[float]:
        if len(self.durations) < 4:
            return None
        s = sorted(self.durations)
        return s[len(s) // 2]
