"""AdamW with warmup+cosine schedule, global-norm clipping, fp32 moments
sharded exactly like the (fp32 master) parameters."""
from __future__ import annotations

import dataclasses
from typing import Any, Dict

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptimizerConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10000
    min_lr_frac: float = 0.1
    # "float32" or "bfloat16": bf16 moments halve optimizer HBM — the
    # at-scale default for the 100B+ dry-run configs.
    moment_dtype: str = "float32"


def schedule(step, cfg: OptimizerConfig):
    step = step.astype(jnp.float32)
    warm = cfg.lr * step / max(cfg.warmup_steps, 1)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = cfg.lr * (cfg.min_lr_frac + (1 - cfg.min_lr_frac)
                    * 0.5 * (1 + jnp.cos(jnp.pi * prog)))
    return jnp.where(step < cfg.warmup_steps, warm, cos)


def init_opt_state(params, moment_dtype: str = "float32") -> Dict[str, Any]:
    dt = jnp.dtype(moment_dtype)
    zeros = lambda p: jnp.zeros(p.shape, dt)
    return {"m": jax.tree_util.tree_map(zeros, params),
            "v": jax.tree_util.tree_map(zeros, params)}


def global_norm(tree) -> jax.Array:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def adamw_step(params, grads, opt_state, step, cfg: OptimizerConfig):
    """Returns (new_params, new_opt_state, metrics)."""
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9))
    lr = schedule(step, cfg)
    t = (step + 1).astype(jnp.float32)
    bc1 = 1.0 - cfg.b1 ** t
    bc2 = 1.0 - cfg.b2 ** t

    def upd(p, g, m, v):
        mdt = m.dtype
        g = g.astype(jnp.float32) * scale
        m = cfg.b1 * m.astype(jnp.float32) + (1 - cfg.b1) * g
        v = cfg.b2 * v.astype(jnp.float32) + (1 - cfg.b2) * g * g
        mh = m / bc1
        vh = v / bc2
        step_ = mh / (jnp.sqrt(vh) + cfg.eps)
        # decoupled weight decay on matrices only (ndim >= 2)
        wd = cfg.weight_decay if p.ndim >= 2 else 0.0
        newp = p.astype(jnp.float32) - lr * (step_ + wd * p.astype(jnp.float32))
        return newp.astype(p.dtype), m.astype(mdt), v.astype(mdt)

    flat_p, tdef = jax.tree_util.tree_flatten(params)
    flat_g = jax.tree_util.tree_leaves(grads)
    flat_m = jax.tree_util.tree_leaves(opt_state["m"])
    flat_v = jax.tree_util.tree_leaves(opt_state["v"])
    out = [upd(p, g, m, v) for p, g, m, v
           in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree_util.tree_unflatten(tdef, [o[0] for o in out])
    new_m = jax.tree_util.tree_unflatten(tdef, [o[1] for o in out])
    new_v = jax.tree_util.tree_unflatten(tdef, [o[2] for o in out])
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_p, {"m": new_m, "v": new_v}, metrics
