"""Fault-tolerant checkpointing: atomic npz-shard snapshots + JSON
manifest, async (off the critical path) writes, latest-checkpoint
restore, and mesh-agnostic load (arrays are saved unsharded; restore
``device_put``s onto whatever mesh the surviving job re-formed — the
elastic path).

Layout:
    <dir>/step_00001230/
        manifest.json     {"step": ..., "leaf_paths": [...], "extra": ...}
        arrays.npz        one entry per state leaf (flattened key paths)
    <dir>/LATEST          text file: "step_00001230"

Writes go to ``<name>.tmp`` and are committed with an atomic rename, so
a job killed mid-save never corrupts the previous checkpoint — restart
always finds a complete snapshot (crash-consistency is tested).
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np


def _flatten_with_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    keys = ["/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                     for k in path) for path, _ in flat]
    leaves = [leaf for _, leaf in flat]
    return keys, leaves, jax.tree_util.tree_structure(tree)


def save_checkpoint(ckpt_dir: str, state, step: int,
                    extra: Optional[Dict[str, Any]] = None) -> str:
    """Synchronous atomic save. Returns the committed directory."""
    os.makedirs(ckpt_dir, exist_ok=True)
    name = f"step_{step:08d}"
    final = os.path.join(ckpt_dir, name)
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    keys, leaves, _ = _flatten_with_paths(state)
    arrays = {}
    for k, leaf in zip(keys, leaves):
        a = np.asarray(jax.device_get(leaf))
        if a.dtype == jax.numpy.bfloat16:
            arrays[k + "::bf16"] = a.view(np.uint16)
        else:
            arrays[k] = a
    np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump({"step": int(step), "leaf_paths": keys,
                   "extra": extra or {}}, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)                      # atomic commit
    latest_tmp = os.path.join(ckpt_dir, "LATEST.tmp")
    with open(latest_tmp, "w") as f:
        f.write(name)
    os.replace(latest_tmp, os.path.join(ckpt_dir, "LATEST"))
    return final


def latest_step(ckpt_dir: str) -> Optional[int]:
    marker = os.path.join(ckpt_dir, "LATEST")
    if not os.path.exists(marker):
        return None
    with open(marker) as f:
        name = f.read().strip()
    if not os.path.isdir(os.path.join(ckpt_dir, name)):
        return None
    return int(name.split("_")[1])


def load_checkpoint(ckpt_dir: str, like, step: Optional[int] = None,
                    shardings=None) -> Tuple[Any, Dict[str, Any]]:
    """Restore into the structure of ``like`` (a pytree of arrays or
    ShapeDtypeStructs). ``shardings``: optional matching tree of
    NamedShardings for elastic re-mesh restore."""
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoint under {ckpt_dir}")
    d = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    data = np.load(os.path.join(d, "arrays.npz"))
    keys, leaves, treedef = _flatten_with_paths(like)
    shard_leaves = (jax.tree_util.tree_leaves(
        shardings, is_leaf=lambda x: x is None or hasattr(x, "spec"))
        if shardings is not None else [None] * len(leaves))
    out = []
    for k, leaf, shd in zip(keys, leaves, shard_leaves):
        if k in data:
            a = data[k]
        elif k + "::bf16" in data:
            a = data[k + "::bf16"].view(jax.numpy.bfloat16)
        else:
            raise KeyError(f"checkpoint missing leaf {k}")
        assert a.shape == tuple(leaf.shape), (k, a.shape, leaf.shape)
        out.append(jax.device_put(a, shd) if shd is not None
                   else jax.numpy.asarray(a))
    return jax.tree_util.tree_unflatten(treedef, out), manifest


def gc_old_checkpoints(ckpt_dir: str, keep: int = 3):
    if not os.path.isdir(ckpt_dir):
        return
    steps = sorted(
        int(n.split("_")[1]) for n in os.listdir(ckpt_dir)
        if n.startswith("step_") and not n.endswith(".tmp"))
    for s in steps[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s:08d}"),
                      ignore_errors=True)


class AsyncCheckpointer:
    """Fire-and-forget saves on a worker thread; the train loop only
    blocks to snapshot device arrays to host (device_get), never on
    disk I/O. At most one save in flight — a newer request while busy
    is queued, older pending ones are dropped."""

    def __init__(self, ckpt_dir: str, keep: int = 3):
        self.ckpt_dir = ckpt_dir
        self.keep = keep
        self._lock = threading.Lock()
        self._pending = None
        self._thread: Optional[threading.Thread] = None
        self.last_error: Optional[Exception] = None

    def save(self, state, step: int, extra=None):
        host_state = jax.tree_util.tree_map(
            lambda x: np.asarray(jax.device_get(x)), state)
        with self._lock:
            self._pending = (host_state, step, extra)
            if self._thread is None or not self._thread.is_alive():
                self._thread = threading.Thread(target=self._drain,
                                                daemon=True)
                self._thread.start()

    def _drain(self):
        while True:
            with self._lock:
                item, self._pending = self._pending, None
                if item is None:
                    return
            try:
                save_checkpoint(self.ckpt_dir, item[0], item[1], item[2])
                gc_old_checkpoints(self.ckpt_dir, self.keep)
            except Exception as e:          # pragma: no cover
                self.last_error = e

    def wait(self):
        t = self._thread
        if t is not None:
            t.join()
