from . import (checkpoint, elastic, grad_compress, optimizer,  # noqa: F401
               train_loop)
