"""int8 gradient compression for cross-pod data parallelism.

Within a pod, gradients reduce over fast ICI at full precision (GSPMD
reduce-scatter). Across pods the DCN link is the bottleneck, so the
cross-pod mean runs on int8-quantized gradients: per-leaf symmetric
scales, quantize -> psum over "pod" -> dequantize. 4x fewer DCN bytes
than fp32 (2x vs bf16), with bounded error (|err| <= scale/2 per
element), unit-tested in tests/test_grad_compress.py.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def quantize_int8(x: jax.Array):
    """Symmetric per-tensor int8: returns (q int8, scale f32)."""
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)))
    scale = jnp.maximum(amax, 1e-30) / 127.0
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127)
    return q.astype(jnp.int8), scale


def dequantize_int8(q: jax.Array, scale: jax.Array):
    return q.astype(jnp.float32) * scale


def compressed_psum_mean(tree, axis_name: str):
    """Mean over ``axis_name`` with int8 on the wire. Scales are
    max-reduced first so all participants share one scale per leaf
    (extra traffic: one f32 per leaf)."""
    n = jax.lax.psum(1, axis_name)

    def one(x):
        amax = jnp.max(jnp.abs(x.astype(jnp.float32)))
        amax = jax.lax.pmax(amax, axis_name)
        scale = jnp.maximum(amax, 1e-30) / 127.0
        q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127)
        # int8 payload on the wire; accumulate in int32 to avoid overflow
        s = jax.lax.psum(q.astype(jnp.int32), axis_name)
        return (s.astype(jnp.float32) * scale / n).astype(x.dtype)

    return jax.tree_util.tree_map(one, tree)


# The cross-pod wrapper lives in train_loop.make_train_step: the whole
# grad computation runs under shard_map(axis_names={"pod"}) (manual over
# the DCN axis, GSPMD-auto within the pod) and calls
# compressed_psum_mean(grads, "pod") for the int8 DCN sync.
