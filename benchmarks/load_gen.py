"""Open-loop Poisson load generator for the async serving engine.

The proof obligation behind PR 9's continuous batching: drive
``FCMServeEngine.submit_async`` with open-loop Poisson arrivals (the
generator does NOT wait for responses before submitting — arrival times
are drawn up front, so a slow server cannot secretly throttle its own
offered load) across a ladder of arrival rates, and compare the
sustained throughput + submit->result latency percentiles against the
synchronous front door (per-request ``submit`` + ``flush``, i.e. a
bucket-1 launch per image — exactly how callers used the engine before
async admission existed).

Every trial reuses one engine (compile once) with the default
``batch_sizes=(1, 8, 64)`` target shapes, a distinct phantom image per
request (so the within-flush dedup cannot collapse the load), and the
cache disabled. Per-rate records carry achieved vs offered QPS,
p50/p99 latency, the peak ``queue.depth`` gauge observed during
submission, and the per-trial mean ``route.batch_occupancy`` (how full
the B=64 target shape actually ran).

The p99 budget is explicit, not implicit: continuous batching's
structural latency floor is ``sync_p99 + max_wait + batch_service``
(you queue for at most the admission window, then ride behind at most
one full target-shape launch), so that sum IS the "equal p99" bar the
sweep holds the async engine to. The *sustained* point is the rate
ladder's best achieved QPS among trials whose p99 stayed inside that
budget — overload trials whose queues blow the budget are recorded but
can never be the sustained claim.

The section is validated by ``bench_schema.check_load_gen_section``,
folded into ``BENCH_pr9.json`` by ``benchmarks/run.py``, and gated two
ways: the in-process gate here (sustained QPS >= ``--min-ratio`` x the
sync baseline, default 3.0) and the ``load_*`` ledger metrics in
``repro.analysis.trajectory``.

Run:  PYTHONPATH=src python -m benchmarks.load_gen [--tiny] [--out PATH]
"""
from __future__ import annotations

import argparse
import json
import os
import time
from typing import Any, Dict, List, Optional

import numpy as np

try:
    from .common import emit
except ImportError:                      # run as a plain script
    from common import emit

OUT_PATH = os.path.join(os.path.dirname(__file__), "out", "load_gen.json")


def _percentile(xs: List[float], q: float) -> float:
    return float(np.percentile(np.asarray(xs, dtype=np.float64), q))


def _image_pool(n: int, size: int) -> List[np.ndarray]:
    """n distinct noisy phantoms — distinct content per request, so the
    engine's within-flush dedup cannot collapse the offered load.
    Quantized to uint8: the 8-bit grayscale payload a segmentation
    service actually receives, and the dtype both front doors ingest
    through the engine's zero-copy fast path."""
    from repro.data import phantom
    return [np.clip(phantom.phantom_slice(size, size, noise=4.0 + (i % 5),
                                          seed=1000 + i)[0],
                    0, 255).astype(np.uint8)
            for i in range(n)]


def _occupancy_delta(eng, route: str, before: Dict[str, float]):
    """Per-trial mean batch occupancy from the cumulative histogram
    (snapshot deltas, since the engine is reused across trials)."""
    h = eng._occupancy_hist(route)
    d_count = h.count - before["count"]
    d_sum = h.total - before["sum"]
    occ = d_sum / d_count if d_count else 0.0
    return {"count": h.count, "sum": h.total}, occ


def sync_baseline(eng, imgs: List[np.ndarray], route: str,
                  reps: int = 3) -> Dict[str, Any]:
    """Closed-loop per-request submit+flush: the pre-async usage
    pattern, one bucket-1 launch per image. Best-of-``reps`` (the
    repo's standing statistic for noisy wall-clock — single-core
    scheduling jitter moves this baseline +-15% run to run), which is
    also the conservative side of the QPS-ratio gate: the async engine
    must beat the sync path at its *fastest*."""
    best = None
    for _ in range(reps):
        lats = []
        t0 = time.perf_counter()
        for img in imgs:
            t = time.perf_counter()
            eng.submit(img, method=route)
            eng.flush()
            lats.append(time.perf_counter() - t)
        wall = time.perf_counter() - t0
        rec = {"qps": len(imgs) / wall, "p50_s": _percentile(lats, 50),
               "p99_s": _percentile(lats, 99), "n_requests": len(imgs),
               "reps": reps}
        if best is None or rec["qps"] > best["qps"]:
            best = rec
    return best


def run_rate(eng, imgs: List[np.ndarray], route: str,
             offered_qps: float, seed: int = 0) -> Dict[str, Any]:
    """One open-loop trial: Poisson arrivals at ``offered_qps``, then
    wait for every future and report what actually happened."""
    rng = np.random.default_rng(seed)
    arrivals = np.cumsum(rng.exponential(1.0 / offered_qps,
                                         size=len(imgs)))
    depth_gauge = eng.metrics.gauge("queue.depth")
    occ_before, _ = _occupancy_delta(eng, route, {"count": 0, "sum": 0.0})
    peak_depth = 0.0
    futures = []
    t0 = time.perf_counter()
    for img, due in zip(imgs, arrivals):
        wait = t0 + due - time.perf_counter()
        if wait > 0:
            time.sleep(wait)
        futures.append(eng.submit_async(img, method=route))
        peak_depth = max(peak_depth, depth_gauge.value)
    for fut in futures:
        fut.result(timeout=120.0)
    wall = time.perf_counter() - t0
    eng.drain()                           # leave the engine quiescent
    _, occupancy = _occupancy_delta(eng, route, occ_before)
    lats = [f.latency_s for f in futures]
    return {
        "offered_qps": float(offered_qps),
        "achieved_qps": len(futures) / wall,
        "completed": len(futures),
        "p50_s": _percentile(lats, 50),
        "p99_s": _percentile(lats, 99),
        "queue_depth": float(peak_depth),
        "batch_occupancy": float(occupancy),
    }


def run_load_gen(tiny: bool = False, route: str = "histogram",
                 min_ratio: Optional[float] = None,
                 enforce_gate: bool = True,
                 mesh: bool = False,
                 rate_multipliers=(2.0, 4.0, 6.0, 8.0, 16.0)) -> Dict[str, Any]:
    """The full sweep: sync baseline, then the rate ladder (offered =
    multiplier x sync QPS, each rate measured twice — best-of-reps is
    this repo's standing statistic for noisy wall-clock, and every
    trial is recorded in ``rates``), then the sustained point + gate
    verdict.

    ``min_ratio`` defaults to 3.0 full-size; tiny runs gate at 2.0 —
    at 32px the per-request ingest floor (unamortizable host work both
    paths share) is a much larger fraction of the sync baseline, so the
    batching headroom the full-size record demonstrates is structurally
    compressed. The full-size committed artifact carries the 3x claim.

    ``mesh`` attaches a 1-D mesh over every local device, so the
    target-shape launches run batch-axis-sharded (requires the process
    to see >1 device — e.g.
    ``XLA_FLAGS=--xla_force_host_platform_device_count=8``). On fake
    host devices this measures the sharded *machinery* under load, not
    a speedup: the devices share one physical CPU.
    """
    import jax

    from repro.serving.fcm_engine import FCMServeEngine

    if min_ratio is None:
        min_ratio = 2.0 if tiny else 3.0
    size = 32 if tiny else 64
    n_req = 128 if tiny else 256
    dev_mesh = None
    if mesh:
        n_dev = jax.device_count()
        if n_dev < 2:
            raise SystemExit(
                "--mesh needs >1 device; set XLA_FLAGS="
                "--xla_force_host_platform_device_count=8 before jax "
                "initializes")
        kwargs = ({"axis_types": (jax.sharding.AxisType.Auto,)}
                  if hasattr(jax.sharding, "AxisType") else {})
        dev_mesh = jax.make_mesh((n_dev,), ("data",), **kwargs)
    # tracing=False drops the debug span ring, not the serving
    # telemetry: queue-depth gauges, batch-occupancy, latency and
    # deadline counters all live on the metrics registry and keep
    # flowing (the tracing overhead itself is measured and gated by
    # benchmarks/batched_throughput.py).
    eng = FCMServeEngine(cache_size=0, max_wait_ms=5.0, tracing=False,
                         mesh=dev_mesh)
    imgs = _image_pool(n_req, size)

    for b in eng.batch_sizes:            # warm-compile every bucket
        for img in imgs[:b]:
            eng.submit(img, method=route)
        eng.flush()

    # One warm target-shape launch: the service time a request rides
    # behind at worst, and the budget's third term.
    target = eng.batch_sizes[-1]
    for img in imgs[:target]:
        eng.submit(img, method=route)
    t = time.perf_counter()
    eng.flush()
    batch_service_s = time.perf_counter() - t

    # The structural p99 floor of continuous batching: a request
    # arriving as a window closes waits out its own full window, the
    # target-shape launch already in flight, and then its own launch —
    # window + 2 services (+ the sync path's own p99 for the shared
    # ingest/materialize work). That sum is the "equal p99" bar.
    sync = sync_baseline(eng, imgs[: max(32, n_req // 4)], route)
    p99_budget_s = (sync["p99_s"] + eng.max_wait_ms / 1e3
                    + 2.0 * batch_service_s)
    emit(f"load_gen/{route}/sync", 1e6 / sync["qps"],
         f"qps={sync['qps']:.1f} p99_ms={sync['p99_s'] * 1e3:.2f} "
         f"budget_ms={p99_budget_s * 1e3:.2f}")

    rates = []
    for rep in range(2):
        for mult in rate_multipliers:
            rec = run_rate(eng, imgs, route,
                           offered_qps=sync["qps"] * mult,
                           seed=int(mult * 10) + 1000 * rep)
            rates.append(rec)
            emit(f"load_gen/{route}/x{mult:g}.{rep}",
                 1e6 / rec["achieved_qps"],
                 f"qps={rec['achieved_qps']:.1f} "
                 f"p99_ms={rec['p99_s'] * 1e3:.2f} "
                 f"occ={rec['batch_occupancy']:.2f}")

    # Sustained = best achieved QPS inside the explicit p99 budget;
    # fall back to the first point so the record (and a failing gate
    # verdict) always carries a concrete measurement.
    kept = [r for r in rates if r["p99_s"] <= p99_budget_s]
    sustained = (max(kept, key=lambda r: r["achieved_qps"]) if kept
                 else rates[0])
    ratio = sustained["achieved_qps"] / sync["qps"]
    gate_ok = ratio >= min_ratio and bool(kept)
    section = {
        "tiny": tiny,
        "backend": jax.default_backend(),
        "devices": jax.device_count(),
        "mesh_devices": dev_mesh.size if dev_mesh is not None else 1,
        "route": route,
        "target_batch": target,
        "max_wait_ms": eng.max_wait_ms,
        "batch_service_s": float(batch_service_s),
        "p99_budget_s": float(p99_budget_s),
        "n_requests_per_rate": n_req,
        "sync_baseline": sync,
        "rates": rates,
        "sustained": sustained,
        "qps_ratio_vs_sync": float(ratio),
        "gate": {"enforced": bool(enforce_gate),
                 "min_ratio": float(min_ratio), "ok": bool(gate_ok)},
    }
    eng.shutdown()
    emit(f"load_gen/{route}/sustained", 1e6 / sustained["achieved_qps"],
         f"ratio_vs_sync={ratio:.1f}x gate_ok={gate_ok}")
    return section


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--tiny", action="store_true",
                    help="CI smoke: 32px images, short rate ladder")
    ap.add_argument("--route", default="histogram")
    ap.add_argument("--out", default=OUT_PATH,
                    help="where to write the load_gen section JSON")
    ap.add_argument("--min-ratio", type=float, default=None,
                    help="gate: sustained QPS must beat the sync "
                         "baseline by this factor (default 3.0, or "
                         "2.0 with --tiny)")
    ap.add_argument("--no-gate", action="store_true",
                    help="record the verdict without failing on it")
    ap.add_argument("--mesh", action="store_true",
                    help="shard target-shape launches over a 1-D mesh "
                         "of every local device (needs >1 device)")
    args = ap.parse_args(argv)

    try:
        from . import bench_schema
    except ImportError:
        import bench_schema

    print("benchmark,us_per_call,derived")
    section = run_load_gen(tiny=args.tiny, route=args.route,
                           min_ratio=args.min_ratio,
                           enforce_gate=not args.no_gate,
                           mesh=args.mesh)
    if args.no_gate:
        section["gate"]["ok"] = True      # recorded, not enforced
    bench_schema.check_load_gen_section(section)
    print("# load_gen schema OK")
    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    with open(args.out, "w") as f:
        json.dump(section, f, indent=1)
    print(f"wrote {args.out}")
    if section["gate"]["enforced"] and not section["gate"]["ok"]:
        raise SystemExit(
            f"FAIL load-gen gate: sustained QPS ratio "
            f"{section['qps_ratio_vs_sync']:.2f}x < "
            f"{section['gate']['min_ratio']}x the sync baseline")
    return section


if __name__ == "__main__":
    main()
