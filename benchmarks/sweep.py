"""Declarative variant-zoo sweep runner.

The repo's zoo — plain pixel / histogram / vector(superpixel) / spatial
FCM, times solver backends, problem sizes, batch sizes and seeds — is
measured here from ONE grid declaration instead of hand-rolled per-PR
scripts (the zoology pattern: a config-generated experiment grid whose
results render into figures). A :class:`SweepSpec` names ordered axes
plus skip predicates; :func:`expand` turns it into deterministic cells
(stable, human-readable ``cell_id``s); each cell executes through the
unified ``solve()`` / ``solve_batched()`` / ``FCMServeEngine`` entry
points with the obs layer scoped to the cell — latency percentiles,
per-lane convergence telemetry — and the kernel family folds in the
roofline achieved-vs-bound probe for every registered (kind, impl)
dispatch cell. Skipped cells are recorded WITH their reason: the grid
accounts for every declared combination, nothing is silently dropped.

Four families:

* ``solver``  — variant x backend x size x batch x seed through the one
  solver entry point; batch=1 cells also score per-class DSC against
  the phantom ground truth, so accuracy-vs-speed frontiers (the paper's
  Table 3 and Fig. 7 are the ``pixel/sequential`` and ``pixel/auto``
  cells of this grid) come straight from the records.
* ``serving`` — every registered engine route x batch, cold-cache
  end-to-end with the engine's per-route latency / convergence /
  stage-seconds blocks.
* ``kernel``  — one roofline achieved-vs-bound cell per (kind, impl) in
  the ``kernels/ops.py`` dispatch registry (reuses the
  ``roofline_report`` probes; coverage asserted by ``bench_schema``).
* ``distributed`` — shard_map solver cells under 8 fake host devices
  (subprocess, see ``_dist_cells.py``): batch-axis sharding on a ragged
  histogram batch plus pixel-axis sharding of one image, each with a
  parity block vs its single-device twin.

Each cell record is validated against ``bench_schema.validate_cell``
before it is emitted — one JSON record per cell under
``benchmarks/out/sweep/`` plus the consolidated section
``benchmarks/run.py`` folds into ``BENCH_pr8.json``.

Run:  PYTHONPATH=src python -m benchmarks.sweep [--tiny] [--out PATH]
"""
from __future__ import annotations

import argparse
import dataclasses
import itertools
import json
import os
from typing import (Any, Callable, Dict, List, Mapping, Optional,
                    Sequence, Tuple)

import numpy as np

try:
    from .common import emit, time_fn
except ImportError:                      # run as a plain script
    from common import emit, time_fn

SWEEP_DIR = os.path.join(os.path.dirname(__file__), "out", "sweep")

#: Interpret-mode Pallas cells (off-TPU) time the Python interpreter,
#: not the kernel; above this many pixels they are skipped off-TPU
#: (the kernel family still probes every impl in interpret mode).
INTERPRET_MAX_PIXELS = 48 * 48


# ---------------------------------------------------------------------------
# Grid declaration
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class SweepSpec:
    """One declarative grid: named axes (each a value tuple) expanded as
    a cartesian product, minus the cells a ``skip`` predicate claims.
    Predicates take the cell's axes dict and return a human-readable
    reason string (skip) or None (run)."""
    name: str
    family: str
    axes: Mapping[str, Tuple[Any, ...]]
    skip: Tuple[Callable[[Dict[str, Any]], Optional[str]], ...] = ()


def cell_id(family: str, axes: Mapping[str, Any]) -> str:
    """Deterministic, order-independent cell id:
    ``family/key=value,...`` with keys sorted — the stable primary key
    per-cell records and resume logic can rely on."""
    return family + "/" + ",".join(
        f"{k}={axes[k]}" for k in sorted(axes))


def expand(spec: SweepSpec) -> Tuple[List[Dict[str, Any]],
                                     List[Dict[str, Any]]]:
    """(runnable cells, skipped cells). Axis order inside the product
    follows sorted axis names so the expansion order is deterministic
    regardless of how the axes dict was declared."""
    names = sorted(spec.axes)
    cells, skipped = [], []
    for combo in itertools.product(*(spec.axes[n] for n in names)):
        axes = dict(zip(names, combo))
        base = {"cell_id": cell_id(spec.family, axes),
                "family": spec.family, "axes": axes}
        reason = next((r for r in (p(axes) for p in spec.skip) if r), None)
        if reason:
            skipped.append({**base, "status": "skipped",
                            "skip_reason": reason})
        else:
            cells.append(base)
    return cells, skipped


# -- solver-family skip predicates (platform passed in, so tests can
#    exercise both sides deterministically) --------------------------------

def solver_skips(platform: str):
    """The solver grid's eligibility rules, as named predicates."""

    def backend_variant(ax):
        v, b = ax["variant"], ax["backend"]
        if b == "sequential" and v != "pixel":
            return ("sequential is the scalar unweighted pixel CPU "
                    "baseline only")
        if b == "pallas" and v == "vector":
            return "flat pallas step is scalar-only; vector rows are D=3"
        if b == "resident" and v in ("pixel", "vector"):
            return ("rows exceed the VMEM-resident bounds; streamed "
                    "coverage lives in the kernel family")
        return None

    def batched_backend(ax):
        if ax["batch"] > 1 and ax["backend"] not in ("reference",
                                                     "resident"):
            return ("solve_batched runs the reference or resident "
                    "impls only")
        return None

    def vector_batching(ax):
        if ax["variant"] == "vector" and ax["batch"] > 1:
            return ("superpixel K varies per image; cross-request "
                    "batching is measured on the serving route")
        return None

    def interpret_cost(ax):
        if platform == "tpu" or ax["backend"] not in ("pallas",
                                                      "resident"):
            return None
        if ax["size"] * ax["size"] > INTERPRET_MAX_PIXELS:
            return (f"off-{platform} interpret mode times the "
                    "interpreter, not the kernel; size capped at "
                    f"{INTERPRET_MAX_PIXELS} pixels")
        return None

    return (backend_variant, batched_backend, vector_batching,
            interpret_cost)


def default_specs(tiny: bool, platform: str) -> List[SweepSpec]:
    """The standing grid. ``--tiny`` shrinks sizes/reps but keeps full
    *coverage*: every variant, every eligible backend, every serving
    route (the acceptance surface CI validates)."""
    from repro.serving import fcm_engine as FE

    sizes = (32, 48) if tiny else (64, 128)
    batches = (1, 4) if tiny else (1, 8)
    seeds = (0,) if tiny else (0, 1)
    backends = ("reference", "sequential", "pallas", "resident")
    solver = SweepSpec(
        name="solver-zoo", family="solver",
        axes={"variant": ("pixel", "histogram", "spatial", "vector"),
              "backend": backends, "size": sizes, "batch": batches,
              "seed": seeds},
        skip=solver_skips(platform))
    serving = SweepSpec(
        name="serving-routes", family="serving",
        axes={"route": tuple(FE.METHODS),
              "batch": (2,) if tiny else (4, 16)})
    return [solver, serving]


# ---------------------------------------------------------------------------
# Cell executors
# ---------------------------------------------------------------------------

def _cfgs():
    from repro.core import fcm as F
    from repro.core import spatial as SP
    from repro.superpixel import pipeline as SX
    cfg = F.FCMConfig(max_iters=300)
    scfg = SP.SpatialFCMConfig(max_iters=300, neighbors=8)
    spcfg = SX.SuperpixelFCMConfig(max_iters=300)
    return cfg, scfg, spcfg


def _gray(size: int, seed: int, i: int = 0):
    from repro.data import phantom
    return phantom.phantom_slice(size, size, noise=4.0 + (i % 3),
                                 seed=seed * 101 + i)


def _rgb(size: int, seed: int, i: int = 0):
    from repro.data import phantom
    return phantom.phantom_slice_rgb(size, size, noise=4.0 + (i % 3),
                                     seed=seed * 101 + i)


def _mean_dsc(dsc: Dict[str, float]) -> float:
    return float(np.mean(list(dsc.values())))


def _dsc_gray(labels, centers, gt):
    from repro.data import phantom
    pred = phantom.match_labels_to_classes(np.asarray(labels),
                                           np.asarray(centers))
    d = phantom.dice_per_class(pred, gt)
    return {n: round(float(v), 4)
            for n, v in zip(phantom.CLASS_NAMES, d)}


def _convergence_block(reg) -> Dict[str, Any]:
    """Cell-scoped solver telemetry -> the record's convergence block
    (same keys as the engine's per-route block, so downstream tooling
    reads one schema)."""
    h = None
    for kind in ("flat", "stencil"):
        cand = reg.peek("solver.iters", kind=kind)
        if cand is not None and cand.count:
            h = cand
            break
    g = (reg.peek("solver.last_final_delta", kind="flat")
         or reg.peek("solver.last_final_delta", kind="stencil"))
    return {
        "lanes": h.count if h else 0,
        "mean_iters": h.mean if h else None,
        "p50_iters": h.quantile(0.50) if h else None,
        "p99_iters": h.quantile(0.99) if h else None,
        "last_final_delta": g.snapshot() if g else None,
    }


def _run_solver_cell(cell: Dict[str, Any], tiny: bool) -> Dict[str, Any]:
    """One (variant, backend, size, batch, seed) cell through the one
    solver entry point, obs-scoped."""
    import jax

    from repro import obs
    from repro.core import batched as B
    from repro.core import solver as SV
    from repro.superpixel import pipeline as SX

    ax = cell["axes"]
    variant, backend = ax["variant"], ax["backend"]
    size, batch, seed = ax["size"], ax["batch"], ax["seed"]
    cfg, scfg, spcfg = _cfgs()
    interpret = (backend in ("pallas", "resident")
                 and jax.default_backend() != "tpu") or None
    reps = 1 if tiny else 3
    compress_s = 0.0
    accuracy = None

    if batch == 1:
        if variant == "vector":
            img, gt = _rgb(size, seed)
            imgf = img.astype(np.float32)
            if size <= 96:
                spcfg = dataclasses.replace(spcfg, n_segments=64)
            comp = SX.compress(imgf, spcfg)
            compress_s = time_fn(lambda: SX.compress(imgf, spcfg),
                                 iters=reps)
            problem = SV.vector_problem(comp.features, comp.weights, spcfg)
        else:
            img, gt = _gray(size, seed)
            x = img.ravel().astype(np.float32)
            if variant == "pixel":
                problem = SV.pixel_problem(x, cfg)
            elif variant == "histogram":
                problem = SV.histogram_problem(x, cfg)
            else:
                problem = SV.spatial_problem(img.astype(np.float32), scfg)

        def run():
            return SV.solve(problem, backend=backend, interpret=interpret)

        with obs.scoped_registry() as reg:
            res = run()                                   # warm + result
            lat = reg.histogram("sweep.cell_seconds",
                                edges=obs.LATENCY_EDGES)
            for _ in range(reps):
                lat.record(time_fn(run, warmup=0, iters=1))
            # best-of-reps is the stablest single-cell statistic on a
            # noisy box; the full distribution rides in the latency block
            fit_s = lat.vmin
            latency = lat.snapshot()
            convergence = _convergence_block(reg)
            obs_snapshot = reg.snapshot()

        if variant == "vector":
            labels = SX.broadcast_labels(res.labels, comp.label_map)
            from repro.data import phantom
            pred = phantom.match_labels_to_means(
                np.asarray(labels), np.asarray(res.centers),
                phantom.CLASS_MEANS_RGB)
            d = phantom.dice_per_class(pred, gt)
            dsc = {n: round(float(v), 4)
                   for n, v in zip(phantom.CLASS_NAMES, d)}
        elif variant == "histogram":
            # bin labels -> pixel labels through the bin LUT
            lut = np.asarray(res.labels)
            bins = np.clip(np.round(np.asarray(img)), 0,
                           lut.shape[0] - 1).astype(np.int64)
            dsc = _dsc_gray(lut[bins], res.centers, gt)
        elif variant == "spatial":
            dsc = _dsc_gray(res.labels, res.centers, gt)
        else:
            dsc = _dsc_gray(np.asarray(res.labels).reshape(img.shape),
                            res.centers, gt)
        accuracy = {"dsc": dsc, "mean_dsc": round(_mean_dsc(dsc), 4)}
        n_iters = int(res.n_iters)
    else:
        imgs = [_gray(size, seed, i)[0] for i in range(batch)]
        if variant == "pixel":
            feats = np.stack([im.ravel().astype(np.float32)
                              for im in imgs])
            problem = SV.batch_problems(feats, cfg=cfg)
        elif variant == "histogram":
            hists = B.histograms_of(imgs)
            problem = SV.batch_problems(B.hist_rows(hists), hists, cfg=cfg)
        else:
            problem = SV.batch_problems(
                np.stack(imgs).astype(np.float32),
                stencil=SV.StencilSpec(alpha=scfg.alpha,
                                       neighbors=scfg.neighbors),
                cfg=scfg)

        def run():
            return SV.solve_batched(problem, backend=backend,
                                    interpret=interpret)

        with obs.scoped_registry() as reg:
            res = run()
            lat = reg.histogram("sweep.cell_seconds",
                                edges=obs.LATENCY_EDGES)
            for _ in range(reps):
                lat.record(time_fn(run, warmup=0, iters=1))
            fit_s = lat.vmin
            latency = lat.snapshot()
            convergence = _convergence_block(reg)
            obs_snapshot = reg.snapshot()
        n_iters = int(np.max(res.n_iters))

    wall_s = float(fit_s) + float(compress_s)
    metrics = {"wall_s": wall_s, "fit_s": float(fit_s),
               "compress_s": float(compress_s),
               "per_image_s": wall_s / batch, "n_iters": n_iters}
    return {**cell, "status": "ok", "metrics": metrics,
            "accuracy": accuracy, "latency": latency,
            "convergence": convergence, "obs": obs_snapshot}


def _run_serving_cell(cell: Dict[str, Any], tiny: bool) -> Dict[str, Any]:
    """One cold-cache (route, batch) cell end-to-end through the
    serving engine; the engine's own obs layer supplies the latency /
    convergence / stage blocks."""
    from repro.serving.fcm_engine import FCMServeEngine

    ax = cell["axes"]
    route, batch = ax["route"], ax["batch"]
    size = 32 if tiny else 64
    cfg, scfg, spcfg = _cfgs()
    if size <= 96:
        spcfg = dataclasses.replace(spcfg, n_segments=64)
    maker = _rgb if route == "superpixel" else _gray
    imgs = [maker(size, 0, i)[0].astype(np.float32) for i in range(batch)]

    def run():
        eng = FCMServeEngine(cfg, batch_sizes=(batch,), cache_size=0,
                             spatial_cfg=scfg, superpixel_cfg=spcfg)
        eng.segment(imgs, method=route)
        return eng

    eng = run()                                           # warm compile
    wall_s = time_fn(run, warmup=0, iters=1 if tiny else 3)
    eng = run()                                           # fresh stats
    s = eng.stats()
    metrics = {"wall_s": float(wall_s),
               "per_image_s": float(wall_s) / batch,
               "stage_seconds": s["stage_seconds"][route]}
    return {**cell, "status": "ok", "metrics": metrics,
            "latency": s["latency"][route],
            "convergence": s["convergence"][route]}


def _distributed_cells(tiny: bool) -> List[Dict[str, Any]]:
    """The multi-device family: shard_map solver cells measured in a
    subprocess under ``--xla_force_host_platform_device_count=8`` (the
    flag must precede jax init, so the parent process cannot host
    them). Each mode carries a parity block against its single-device
    twin; a dead subprocess becomes one error cell per required mode so
    the schema's coverage check fails loudly."""
    import subprocess
    import sys as _sys

    try:
        from . import bench_schema
    except ImportError:
        import bench_schema

    script = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                          "_dist_cells.py")
    cmd = [_sys.executable, script] + (["--tiny"] if tiny else [])
    try:
        out = subprocess.run(cmd, capture_output=True, text=True,
                             timeout=1800, check=True)
        payload = json.loads(out.stdout.strip().splitlines()[-1])
    except Exception as e:
        return [{"cell_id": cell_id("distributed",
                                    {"mode": mode, "devices": 8}),
                 "family": "distributed",
                 "axes": {"mode": mode, "devices": 8},
                 "status": "error", "error": repr(e)}
                for mode in bench_schema.REQUIRED_DIST_MODES]
    cells = []
    for row in payload["cells"]:
        axes = {"mode": row["mode"], "devices": payload["devices"]}
        cells.append({
            "cell_id": cell_id("distributed", axes),
            "family": "distributed", "axes": axes, "status": "ok",
            "metrics": {"wall_s": row["wall_s"],
                        "per_image_s": row["per_image_s"],
                        "batch": row["batch"]},
            "parity": row["parity"],
        })
    return cells


def _kernel_cells(tiny: bool) -> Tuple[List[Dict[str, Any]], dict]:
    """The registry-coverage family: every (kind, impl) dispatch cell as
    a roofline achieved-vs-bound probe (also writes
    benchmarks/out/roofline_report.json, so the standalone report and
    the sweep stay one measurement)."""
    try:
        from . import roofline_report
    except ImportError:
        import roofline_report
    report = roofline_report.write_kernel_report(smoke=tiny)
    cells = []
    for row in report["cells"]:
        axes = {"kind": row["kind"], "impl": row["impl"]}
        cell = {"cell_id": cell_id("kernel", axes), "family": "kernel",
                "axes": axes, "kernel": row}
        if "error" in row:
            cell.update(status="error", error=row["error"])
        else:
            cell["status"] = "ok"
        cells.append(cell)
    return cells, report


# ---------------------------------------------------------------------------
# Sweep driver
# ---------------------------------------------------------------------------

_EXECUTORS = {"solver": _run_solver_cell, "serving": _run_serving_cell}


def run_sweep(tiny: bool = False, write_cells: bool = True,
              sweep_dir: str = SWEEP_DIR) -> dict:
    """Expand the standing grid, execute every cell, validate each
    record against the schema, and return the consolidated sweep
    section (with the full roofline report riding along under
    ``"roofline"`` so ``benchmarks/run.py`` measures kernels once)."""
    import jax

    from repro import obs

    try:
        from . import bench_schema
    except ImportError:
        import bench_schema

    platform = jax.default_backend()
    cells: List[Dict[str, Any]] = []
    skipped: List[Dict[str, Any]] = []
    for spec in default_specs(tiny, platform):
        todo, skip = expand(spec)
        skipped.extend(skip)
        for cell in todo:
            try:
                rec = _EXECUTORS[spec.family](cell, tiny)
            except Exception as e:       # keep the cell, name the failure
                rec = {**cell, "status": "error", "error": repr(e)}
            cells.append(rec)
            _emit_cell(rec)

    kcells, roofline = _kernel_cells(tiny)
    cells.extend(kcells)
    dcells = _distributed_cells(tiny)
    cells.extend(dcells)
    for rec in dcells:
        _emit_cell(rec)

    section = {
        "name": "fcm-variant-zoo",
        "tiny": tiny,
        "backend": platform,
        "n_cells": len(cells),
        "n_skipped": len(skipped),
        "coverage": {
            "solver_variants": sorted({c["axes"]["variant"] for c in cells
                                       if c["family"] == "solver"}),
            "serving_routes": sorted({c["axes"]["route"] for c in cells
                                      if c["family"] == "serving"}),
            "kernel_cells": sorted(f"{c['axes']['kind']}/{c['axes']['impl']}"
                                   for c in cells
                                   if c["family"] == "kernel"),
            "distributed_modes": sorted({c["axes"]["mode"] for c in cells
                                         if c["family"] == "distributed"}),
        },
        "cells": obs.json_safe(cells),
        "skipped": skipped,
    }
    bench_schema.check_sweep_section(section)
    if write_cells:
        os.makedirs(sweep_dir, exist_ok=True)
        for rec in section["cells"]:
            fname = rec["cell_id"].replace("/", "__") + ".json"
            with open(os.path.join(sweep_dir, fname), "w") as f:
                json.dump(rec, f, indent=1)
        print(f"# sweep: wrote {len(section['cells'])} cell records "
              f"to {sweep_dir}")
    errors = [c["cell_id"] for c in cells if c["status"] == "error"]
    print(f"# sweep: {len(cells)} cells ({len(errors)} errored), "
          f"{len(skipped)} skipped with reasons")
    section["roofline"] = roofline
    return section


def _emit_cell(rec: Dict[str, Any]) -> None:
    if rec["status"] == "error":
        emit(f"sweep/{rec['cell_id']}", 0.0, f"ERROR {rec['error']}")
        return
    m = rec.get("metrics", {})
    derived = ""
    if rec.get("accuracy"):
        derived = f"mean_dsc={rec['accuracy']['mean_dsc']:.4f}"
    conv = rec.get("convergence") or {}
    if conv.get("mean_iters") is not None:
        derived += f" mean_iters={conv['mean_iters']:.1f}"
    emit(f"sweep/{rec['cell_id']}", m.get("wall_s", 0.0) * 1e6,
         derived.strip())


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--tiny", action="store_true",
                    help="CI smoke: reduced sizes/reps, full coverage")
    ap.add_argument("--out", default=None,
                    help="also write the consolidated sweep section "
                         "to this JSON path")
    args = ap.parse_args(argv)
    print("benchmark,us_per_call,derived")
    section = run_sweep(tiny=args.tiny)
    print("# sweep schema OK (every cell validated, coverage checked)")
    if args.out:
        payload = {k: v for k, v in section.items() if k != "roofline"}
        os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
        with open(args.out, "w") as f:
            json.dump(payload, f, indent=1)
        print(f"wrote {args.out}")
    return section


if __name__ == "__main__":
    main()
