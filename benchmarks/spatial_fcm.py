"""Noise-robustness + wall-clock benchmark: plain FCM vs FCM_S.

Sweeps the (gaussian sigma, impulse fraction) noise levels from
``repro.data.phantom.NOISE_LEVELS`` on a phantom slice and compares

* ``plain``        — histogram-blind fused FCM (fused pixel solve),
* ``spatial_ref``  — FCM_S with the pure-jnp stencil reference,
* ``spatial_pallas`` — FCM_S with the fused Pallas stencil kernel
  (interpret mode off-TPU, so its wall clock on CPU measures the
  Python interpreter, not the kernel),

on per-tissue DSC and median fit wall-clock. Writes
``benchmarks/out/spatial_fcm.json``.

  PYTHONPATH=src python -m benchmarks.spatial_fcm [--size 128] [--no-pallas]
"""
from __future__ import annotations

import argparse
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np

from benchmarks import bench_schema
from benchmarks.common import time_fn
from repro.configs.fcm_brainweb import make_config
from repro.core import solver as SV
from repro.data import phantom


def _dsc(labels, centers, gt):
    pred = phantom.match_labels_to_classes(np.asarray(labels),
                                           np.asarray(centers))
    d = phantom.dice_per_class(pred, gt)
    return {name: round(float(v), 4)
            for name, v in zip(phantom.CLASS_NAMES, d)}


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--size", type=int, default=128)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--no-pallas", action="store_true",
                    help="skip the (interpret-mode-slow on CPU) Pallas fits")
    args = ap.parse_args(argv)

    job = make_config()
    cfg, scfg = job.fcm, job.spatial
    report = {"backend": jax.default_backend(),
              "size": args.size, "seed": args.seed,
              "alpha": scfg.alpha, "neighbors": scfg.neighbors,
              "levels": []}
    for sigma, impulse in job.noise_levels:
        img, gt = phantom.noisy_phantom_slice(args.size, args.size,
                                              noise=sigma, impulse=impulse,
                                              seed=args.seed)
        x = img.ravel().astype(np.float32)
        imgf = img.astype(np.float32)
        level = {"sigma": sigma, "impulse": impulse, "fits": {}}

        plain = SV.pixel_problem(x, cfg)
        rp = SV.solve(plain, cfg)
        level["fits"]["plain"] = {
            "dsc": _dsc(np.asarray(rp.labels).reshape(img.shape), rp.centers,
                        gt),
            "n_iters": rp.n_iters,
            "seconds": time_fn(lambda: SV.solve(plain, cfg)),
        }
        spat = SV.spatial_problem(imgf, scfg)
        rs = SV.solve(spat, scfg)
        level["fits"]["spatial_ref"] = {
            "dsc": _dsc(rs.labels, rs.centers, gt),
            "n_iters": rs.n_iters,
            "seconds": time_fn(lambda: SV.solve(spat, scfg)),
        }
        if not args.no_pallas:
            rk = SV.solve(spat, scfg, backend="pallas")
            level["fits"]["spatial_pallas"] = {
                "dsc": _dsc(rk.labels, rk.centers, gt),
                "n_iters": rk.n_iters,
                "seconds": time_fn(
                    lambda: SV.solve(spat, scfg, backend="pallas")),
                "interpret": jax.default_backend() != "tpu",
            }
        report["levels"].append(level)
        print(f"sigma={sigma:5.1f} impulse={impulse:4.0%}  " + "  ".join(
            f"{k}: WM={v['dsc']['WM']:.3f} GM={v['dsc']['GM']:.3f} "
            f"CSF={v['dsc']['CSF']:.3f} ({v['seconds'] * 1e3:.0f} ms)"
            for k, v in level["fits"].items()))

    bench_schema.validate_spatial_report(report)
    out_dir = os.path.join(os.path.dirname(__file__), "out")
    os.makedirs(out_dir, exist_ok=True)
    out_path = os.path.join(out_dir, "spatial_fcm.json")
    with open(out_path, "w") as f:
        json.dump(report, f, indent=1)
    print(f"wrote {out_path} (schema OK)")

    worst = report["levels"][-1]["fits"]
    for cls in ("CSF", "GM", "WM"):
        gain = worst["spatial_ref"]["dsc"][cls] - worst["plain"]["dsc"][cls]
        print(f"highest-noise DSC gain {cls}: {gain:+.3f}")
    return report


if __name__ == "__main__":
    main()
