"""Pixels-vs-superpixels benchmark: vector FCM on a color phantom.

The headline claim: for a 512x512 RGB phantom, SLIC-compressing N =
262144 pixels to ~256 superpixel rows makes the FCM fit >= 10x faster
than the fused pixel solve at DSC parity (within 0.02 per class).
Records, per image size:

* ``pixel_fit_s``      — fused vector FCM over the (N, 3) pixel rows,
* ``compress_s``       — the SLIC compression (jnp reference path),
* ``superpixel_fit_s`` — weighted vector FCM over the (K, 3) rows,
* ``speedup_fit``      — pixel_fit_s / superpixel_fit_s,
* ``speedup_total``    — pixel_fit_s / (compress_s + superpixel_fit_s),
* per-class DSC for both and the max |DSC_pixel - DSC_superpixel|.

Writes ``benchmarks/out/superpixel_fcm.json``.

  PYTHONPATH=src python -m benchmarks.superpixel_fcm [--size 512] [--tiny]
"""
from __future__ import annotations

import argparse
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np

from benchmarks import bench_schema
from benchmarks.common import time_fn
from repro.configs.fcm_brainweb import make_config
from repro.core import solver as SV
from repro.data import phantom
from repro.superpixel import pipeline as SX


def _dsc(labels, centers, gt):
    pred = phantom.match_labels_to_means(np.asarray(labels), centers,
                                         phantom.CLASS_MEANS_RGB)
    d = phantom.dice_per_class(pred, gt)
    return {name: round(float(v), 4)
            for name, v in zip(phantom.CLASS_NAMES, d)}


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--size", type=int, default=512)
    ap.add_argument("--segments", type=int, default=0,
                    help="target superpixel count (0 = config default)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--noise", type=float, default=6.0)
    ap.add_argument("--tiny", action="store_true",
                    help="CI smoke: 96px, 64 superpixels, 1 timing rep")
    args = ap.parse_args(argv)
    if args.tiny:
        args.size = 96
        args.segments = args.segments or 64
    reps = 1 if args.tiny else 3

    job = make_config()
    cfg = job.fcm
    spcfg = job.superpixel
    if args.segments:
        import dataclasses
        spcfg = dataclasses.replace(spcfg, n_segments=args.segments)

    img, gt = phantom.phantom_slice_rgb(args.size, args.size,
                                        noise=args.noise, seed=args.seed)
    imgf = img.astype(np.float32)
    x = imgf.reshape(-1, 3)
    n = x.shape[0]

    # -- pixel-space reference fit ----------------------------------------
    pixel = SV.pixel_problem(x, cfg)
    rp = SV.solve(pixel, cfg)
    pixel_fit_s = time_fn(lambda: SV.solve(pixel, cfg), iters=reps)
    dsc_pixel = _dsc(np.asarray(rp.labels).reshape(gt.shape), rp.centers, gt)

    # -- superpixel path ---------------------------------------------------
    comp = SX.compress(imgf, spcfg)
    k = int(comp.features.shape[0])
    compress_s = time_fn(lambda: SX.compress(imgf, spcfg), iters=reps)
    vecp = SV.vector_problem(comp.features, comp.weights, spcfg)
    rs = SV.solve(vecp, spcfg)
    superpixel_fit_s = time_fn(lambda: SV.solve(vecp, spcfg), iters=reps)
    labels = SX.broadcast_labels(rs.labels, comp.label_map)
    dsc_sp = _dsc(labels, rs.centers, gt)

    parity = max(abs(dsc_pixel[c] - dsc_sp[c]) for c in phantom.CLASS_NAMES)
    report = {
        "backend": jax.default_backend(),
        "size": args.size, "noise": args.noise, "seed": args.seed,
        "n_pixels": n, "n_superpixels": k,
        "compression_ratio": round(n / k, 1),
        "slic_iters": comp.slic_iters,
        "pixel_fit_s": pixel_fit_s,
        "pixel_iters": rp.n_iters,
        "compress_s": compress_s,
        "superpixel_fit_s": superpixel_fit_s,
        "superpixel_iters": rs.n_iters,
        "speedup_fit": round(pixel_fit_s / superpixel_fit_s, 1),
        "speedup_total": round(
            pixel_fit_s / (compress_s + superpixel_fit_s), 2),
        "dsc_pixel": dsc_pixel,
        "dsc_superpixel": dsc_sp,
        "dsc_parity_max_delta": round(parity, 4),
    }

    bench_schema.validate_superpixel_report(report)
    out_dir = os.path.join(os.path.dirname(__file__), "out")
    os.makedirs(out_dir, exist_ok=True)
    out_path = os.path.join(out_dir, "superpixel_fcm.json")
    with open(out_path, "w") as f:
        json.dump(report, f, indent=1)

    print(f"{args.size}x{args.size} RGB: N={n} -> K={k} "
          f"({report['compression_ratio']}x)")
    print(f"pixel fit    {pixel_fit_s * 1e3:8.1f} ms ({rp.n_iters} iters)")
    print(f"compress     {compress_s * 1e3:8.1f} ms "
          f"({comp.slic_iters} SLIC iters)")
    print(f"superpx fit  {superpixel_fit_s * 1e3:8.1f} ms "
          f"({rs.n_iters} iters)")
    print(f"speedup: fit {report['speedup_fit']}x, "
          f"end-to-end {report['speedup_total']}x")
    print(f"DSC pixel {dsc_pixel}")
    print(f"DSC superpixel {dsc_sp} (max delta {parity:.4f})")
    print(f"wrote {out_path}")
    return report


if __name__ == "__main__":
    main()
