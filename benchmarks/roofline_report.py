"""Render the EXPERIMENTS.md roofline table from the dry-run JSONL
(single-pod mesh rows, per the assignment; multi-pod rows prove the pod
axis shards and are summarized separately)."""
from __future__ import annotations

import json
import os

DEFAULT = os.path.join(os.path.dirname(__file__), "..", "experiments",
                       "dryrun.jsonl")


def load(path=DEFAULT):
    rows = []
    if not os.path.exists(path):
        return rows
    with open(path) as f:
        for line in f:
            try:
                rows.append(json.loads(line))
            except json.JSONDecodeError:
                pass
    # keep the latest record per cell
    latest = {}
    for r in rows:
        latest[(r["arch"], r["shape"], r["mesh"])] = r
    return list(latest.values())


def fmt_row(r):
    mf = r["model_flops_total"]
    return (f"| {r['arch']} | {r['shape']} | {r['t_compute']:.4f} "
            f"| {r['t_memory']:.4f} | {r['t_collective']:.4f} "
            f"| {r['bottleneck']} | {mf:.2e} "
            f"| {r['useful_flops_frac']:.2f} | {r['fits_hbm']} |")


def markdown_table(rows, mesh="16x16"):
    out = ["| arch | shape | t_compute (s) | t_memory (s) | t_coll (s) "
           "| bottleneck | MODEL_FLOPS | useful/HLO | fits |",
           "|---|---|---|---|---|---|---|---|---|"]
    for r in sorted(rows, key=lambda r: (r["arch"], r["shape"])):
        if r["mesh"] == mesh:
            out.append(fmt_row(r))
    return "\n".join(out)


def run():
    rows = load()
    if not rows:
        print("# roofline: no dryrun.jsonl yet — run "
              "PYTHONPATH=src python -m repro.launch.dryrun first")
        return
    single = [r for r in rows if r["mesh"] == "16x16"]
    multi = [r for r in rows if r["mesh"] != "16x16"]
    print(f"# roofline: {len(single)} single-pod cells, "
          f"{len(multi)} multi-pod cells")
    for r in sorted(single, key=lambda r: (r["arch"], r["shape"])):
        dom = {"compute": r["t_compute"], "memory": r["t_memory"],
               "collective": r["t_collective"]}[r["bottleneck"]]
        print(f"roofline/{r['arch']}/{r['shape']},{dom * 1e6:.1f},"
              f"bottleneck={r['bottleneck']} "
              f"useful={r['useful_flops_frac']:.2f} fits={r['fits_hbm']}")


if __name__ == "__main__":
    print(markdown_table(load()))
