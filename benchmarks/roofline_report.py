"""Roofline-vs-achieved report for every registered kernel cell.

For each (step kind, impl) in the ``kernels/ops.py`` dispatch registry
this probes one representative invocation: analytic FLOPs/bytes from
:func:`repro.analysis.roofline.kernel_step_costs` (the intrinsic math,
comparable across impls of a kind), HLO-walker FLOPs/bytes where the
compiled module is parseable (Pallas custom-calls are opaque — those
record 0), a median wall time, and the roofline bound
``max(flops/peak, bytes/bw)`` from ``analysis/hw.py``. The JSON lands
in ``benchmarks/out/roofline_report.json`` and is folded into
``BENCH_pr6.json`` by ``benchmarks/run.py`` — the measurement the
registry's dispatch thresholds are supposed to be chosen from.

Off-TPU the Pallas impls run in interpret mode; their wall times are
the interpreter's, not the kernel's (``interpret: true`` marks them),
but every registry cell still gets an entry so the report's coverage
is platform-independent.

Run:  PYTHONPATH=src python -m benchmarks.roofline_report [--smoke]

The legacy EXPERIMENTS.md dry-run table (``load``/``markdown_table``)
is kept below; it renders from ``experiments/dryrun.jsonl`` when that
artifact exists.
"""
from __future__ import annotations

import argparse
import json
import os

import numpy as np

try:
    from .common import emit, time_fn
except ImportError:                      # run as a plain script
    from common import emit, time_fn

DEFAULT = os.path.join(os.path.dirname(__file__), "..", "experiments",
                       "dryrun.jsonl")
OUT_PATH = os.path.join(os.path.dirname(__file__), "out",
                        "roofline_report.json")


# ---------------------------------------------------------------------------
# Kernel probes: one representative invocation per registry cell
# ---------------------------------------------------------------------------

def _hlo_costs(fn, *args):
    """HLO-walker flops/bytes for a jitted call, or zeros when the
    module will not lower/parse (Pallas interpret closures, custom
    calls)."""
    import jax

    from repro.analysis import hlo_cost
    try:
        txt = jax.jit(fn).lower(*args).compile().as_text()
        c = hlo_cost.analyze_text(txt, 1)
        return float(c.flops), float(c.bytes)
    except Exception:
        return 0.0, 0.0


def _probe(impl, smoke: bool):
    """(callable, args, shape-dict, analytic costs) for one registry
    cell. Shapes are kept modest: interpret-mode Pallas on CPU pays the
    interpreter per block, and the cell's point is coverage + the
    achieved-vs-bound ratio, not a stress test."""
    import jax.numpy as jnp

    from repro.analysis import roofline as R
    from repro.core import spatial as SP
    from repro.kernels import ops as kops
    from repro.superpixel import slic as SL

    kind, name = impl.kind, impl.name
    c, m = 4, 2.0
    rng = np.random.default_rng(0)
    interpret = kops._interpret_default()

    if kind == "flat":
        n = 2048 if smoke else 16384
        x = jnp.asarray(rng.random(n, dtype=np.float32)) * 255.0
        w = jnp.ones((n,), jnp.float32)
        v = jnp.linspace(0.0, 255.0, c, dtype=jnp.float32)[:, None]
        shape = {"n_rows": n, "c": c, "n_feat": 1}
        if name == "reference":
            step = kops.build_step("flat", "reference", feats=x[:, None],
                                   weights=w, m=m)
            costs = R.kernel_step_costs("flat", n_rows=n, c=c, n_feat=1)
            return step, (v,), shape, costs
        if name == "pallas":
            br = 8
            x2d, w2d = kops.tile_rows(x, w, br)
            step = kops.build_step("flat", "pallas", x2d=x2d, w2d=w2d,
                                   m=m, block_rows=br, interpret=interpret)
            costs = R.kernel_step_costs("flat", n_rows=n, c=c, n_feat=1)
            return step, (v,), shape, costs
        # resident / resident_streamed: the whole convergence loop runs
        # inside the kernel — probe a fixed-trip solve (tol=0 never
        # early-stops) and scale the per-step model by the trip count.
        iters = 8
        if name == "resident_streamed":
            from repro.kernels import fcm_resident as KR
            # rows beyond the VMEM-resident bound, so the probe actually
            # exercises the HBM-streamed double-buffer path.
            n = 2048 if smoke else max(n, KR.MAX_ROWS * 128 * 2)
            x = jnp.asarray(rng.random(n, dtype=np.float32)) * 255.0
            w = jnp.ones((n,), jnp.float32)
            x4, w3 = kops.tile_rows_batched(
                x[None, :, None], w[None],
                rows_multiple=KR.STREAM_CHUNK_ROWS)
        else:
            x4, w3 = kops.tile_rows_batched(x[None, :, None], w[None])
        solve_fn = kops.build_step("flat", name, x4=x4, w3=w3, m=m,
                                   max_iters=iters, interpret=interpret)
        shape = {"n_rows": n, "c": c, "n_feat": 1, "n_iters": iters}
        costs = R.kernel_step_costs("flat", n_rows=n, c=c, n_feat=1,
                                    n_iters=iters)
        return (solve_fn, (v[None], jnp.zeros((1,), jnp.float32)),
                shape, costs)

    if kind == "stencil":
        hw_ = 48 if smoke else 128
        img = jnp.asarray(rng.random((hw_, hw_), dtype=np.float32)) * 255.0
        v = jnp.linspace(0.0, 255.0, c, dtype=jnp.float32)[:, None]
        alpha, neighbors = 1.0, SP.SpatialFCMConfig().neighbors
        shape = {"h": hw_, "w": hw_, "c": c, "neighbors": neighbors}
        costs = R.kernel_step_costs("stencil", h=hw_, w=hw_, c=c,
                                    neighbors=neighbors)
        if name == "reference":
            step = kops.build_step("stencil", "reference", img=img, m=m,
                                   alpha=alpha, neighbors=neighbors)
            return step, (v,), shape, costs
        if name == "resident":
            # whole-solve FCM_S: fixed-trip in-kernel convergence loop.
            iters = 8
            xpad, vpad = kops.tile_grid_batched(img[None])
            solve_fn = kops.build_step("stencil", "resident", xpad=xpad,
                                       vpad=vpad, m=m, alpha=alpha,
                                       neighbors=neighbors,
                                       max_iters=iters,
                                       interpret=interpret)
            shape = dict(shape, n_iters=iters)
            costs = R.kernel_step_costs("stencil", h=hw_, w=hw_, c=c,
                                        neighbors=neighbors,
                                        n_iters=iters)
            return (solve_fn, (v[:, 0][None],
                               jnp.zeros((1,), jnp.float32)),
                    shape, costs)
        br = 8
        xpad, wpad = kops.tile_grid(img, br)
        step = kops.build_step("stencil", "pallas", xpad=xpad, wpad=wpad,
                               m=m, alpha=alpha, neighbors=neighbors,
                               block_rows=br, interpret=interpret)
        return step, (v,), shape, costs

    if kind == "bin":
        b, n = (2, 4096) if smoke else (4, 65536)
        px = jnp.asarray(rng.integers(0, 256, (b, n)).astype(np.float32))
        shape = {"b": b, "n_rows": n, "n_bins": 256}
        costs = R.kernel_step_costs("bin", b=b, n_rows=n, n_bins=256)
        counts = kops.build_step("bin", name, n_bins=256,
                                 **({} if name == "reference"
                                    else {"interpret": interpret}))
        return counts, (px,), shape, costs

    if kind == "labels":
        n = 8192 if smoke else 262144
        x = jnp.asarray(rng.random(n, dtype=np.float32)) * 255.0
        v = jnp.linspace(0.0, 255.0, c, dtype=jnp.float32)
        shape = {"n_rows": n, "c": c, "n_feat": 1}
        costs = R.kernel_step_costs("labels", n_rows=n, c=c, n_feat=1)
        labels = kops.build_step("labels", name,
                                 **({} if name == "reference"
                                    else {"interpret": interpret}))
        return labels, (x, v), shape, costs

    if kind == "slic_assign":
        hw_, d = (32, 3) if smoke else (96, 3)
        img = jnp.asarray(rng.random((hw_, hw_, d), dtype=np.float32))
        gy, gx = SL.grid_shape(hw_, hw_, 64)
        sw = SL.spatial_weight(hw_, hw_, gy, gx, 10.0)
        centers = SL.seed_centers(img, gy, gx)
        shape = {"h": hw_, "w": hw_, "d": d, "n_centers": gy * gx}
        costs = R.kernel_step_costs("slic_assign", h=hw_, w=hw_, d=d,
                                    n_centers=gy * gx)
        if name == "reference":
            assign = kops.build_step("slic_assign", "reference",
                                     gy=gy, gx=gx, sw=sw)
            return assign, (img, centers), shape, costs
        br = 8
        xpad, _ = kops.tile_channels(img, br)
        assign = kops.build_step("slic_assign", "pallas", h=hw_, w=hw_,
                                 gy=gy, gx=gx, sw=sw, block_rows=br,
                                 interpret=interpret)
        return assign, (xpad, centers), shape, costs

    raise ValueError(f"no probe for step kind {kind!r}")


def _measure_cell(impl, smoke: bool) -> dict:
    import jax

    from repro.analysis import roofline as R
    from repro.kernels import ops as kops

    backend = jax.default_backend()
    fn, args, shape, costs = _probe(impl, smoke)
    jfn = jax.jit(fn)
    run = lambda: jax.block_until_ready(jfn(*args))  # noqa: E731
    wall_s = time_fn(run, warmup=1, iters=2 if smoke else 5)
    hlo_flops, hlo_bytes = _hlo_costs(fn, *args)
    cell = R.kernel_cell(
        impl.kind, impl.name, backend, shape,
        costs["flops"], costs["bytes"], wall_s,
        interpret=(backend not in impl.platforms
                   and kops._interpret_default()),
        hlo_flops=hlo_flops, hlo_bytes=hlo_bytes)
    return cell.row()


def kernel_report(smoke: bool = False) -> dict:
    """One roofline-vs-achieved entry per registered (kind, impl) —
    coverage is asserted by the BENCH schema validator, so a probe
    failure records an error cell instead of silently dropping one."""
    import jax

    from repro.analysis import hw
    from repro.kernels import ops as kops

    cells = []
    for impl in kops.step_impls():
        try:
            row = _measure_cell(impl, smoke)
        except Exception as e:           # keep the cell, name the failure
            row = {"kind": impl.kind, "impl": impl.name,
                   "backend": jax.default_backend(), "error": repr(e)}
        cells.append(row)
        if "error" in row:
            emit(f"roofline/{row['kind']}/{row['impl']}", 0.0,
                 f"ERROR {row['error']}")
        else:
            emit(f"roofline/{row['kind']}/{row['impl']}",
                 row["wall_s"] * 1e6,
                 f"achieved={row['achieved_flops_per_s']:.3e}F/s "
                 f"bound={row['bound']} "
                 f"roofline_frac={row['frac_of_roofline']:.2e}")
    return {"backend": jax.default_backend(), "smoke": smoke,
            "hw": {"peak_flops_bf16": hw.PEAK_FLOPS_BF16,
                   "hbm_bytes_per_s": hw.HBM_BW},
            "cells": cells}


def write_kernel_report(smoke: bool = False, out_path: str = OUT_PATH):
    report = kernel_report(smoke=smoke)
    os.makedirs(os.path.dirname(out_path), exist_ok=True)
    with open(out_path, "w") as f:
        json.dump(report, f, indent=1)
    print(f"wrote {out_path}")
    return report


# ---------------------------------------------------------------------------
# Legacy EXPERIMENTS.md dry-run table (dryrun.jsonl renderer)
# ---------------------------------------------------------------------------

def load(path=DEFAULT):
    rows = []
    if not os.path.exists(path):
        return rows
    with open(path) as f:
        for line in f:
            try:
                rows.append(json.loads(line))
            except json.JSONDecodeError:
                pass
    # keep the latest record per cell
    latest = {}
    for r in rows:
        latest[(r["arch"], r["shape"], r["mesh"])] = r
    return list(latest.values())


def fmt_row(r):
    mf = r["model_flops_total"]
    return (f"| {r['arch']} | {r['shape']} | {r['t_compute']:.4f} "
            f"| {r['t_memory']:.4f} | {r['t_collective']:.4f} "
            f"| {r['bottleneck']} | {mf:.2e} "
            f"| {r['useful_flops_frac']:.2f} | {r['fits_hbm']} |")


def markdown_table(rows, mesh="16x16"):
    out = ["| arch | shape | t_compute (s) | t_memory (s) | t_coll (s) "
           "| bottleneck | MODEL_FLOPS | useful/HLO | fits |",
           "|---|---|---|---|---|---|---|---|---|"]
    for r in sorted(rows, key=lambda r: (r["arch"], r["shape"])):
        if r["mesh"] == mesh:
            out.append(fmt_row(r))
    return "\n".join(out)


def run(smoke: bool = False, report: dict = None):
    """The benchmarks/run.py section: kernel cells always, plus the
    dry-run summary when its JSONL artifact exists. Pass a prebuilt
    ``report`` (the sweep harness measures the same cells) to reuse its
    measurements instead of probing every kernel twice."""
    if report is None:
        report = write_kernel_report(smoke=smoke)
    rows = load()
    if rows:
        single = [r for r in rows if r["mesh"] == "16x16"]
        multi = [r for r in rows if r["mesh"] != "16x16"]
        print(f"# roofline: {len(single)} single-pod cells, "
              f"{len(multi)} multi-pod cells")
        for r in sorted(single, key=lambda r: (r["arch"], r["shape"])):
            dom = {"compute": r["t_compute"], "memory": r["t_memory"],
                   "collective": r["t_collective"]}[r["bottleneck"]]
            print(f"roofline/{r['arch']}/{r['shape']},{dom * 1e6:.1f},"
                  f"bottleneck={r['bottleneck']} "
                  f"useful={r['useful_flops_frac']:.2f} "
                  f"fits={r['fits_hbm']}")
    return report


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI smoke: tiny probe shapes, 2 timing reps")
    ap.add_argument("--out", default=OUT_PATH)
    args = ap.parse_args(argv)
    print("benchmark,us_per_call,derived")
    return write_kernel_report(smoke=args.smoke, out_path=args.out)


if __name__ == "__main__":
    main()
