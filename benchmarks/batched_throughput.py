"""Batched segmentation throughput: images/sec vs batch size.

The one-at-a-time baseline is ``fit_fused`` per image (the paper's
optimized single-image path, one device launch sequence per image).
Against it:

* sequential ``fit_histogram`` per image — histogram compression alone;
* ``fit_batched`` — one vmapped ``(B, 256)`` fixed point per batch, the
  serving engine's hot path;
* ``FCMServeEngine.segment`` — the full request path (ingest + bucketing
  + cache + defuzzify LUT), cache cold.

Run:  PYTHONPATH=src python -m benchmarks.batched_throughput
"""
from __future__ import annotations

import numpy as np

from repro.core import batched as B
from repro.core import fcm as F
from repro.core import histogram as H
from repro.data import phantom
from repro.serving.fcm_engine import FCMServeEngine

try:
    from .common import emit, time_fn
except ImportError:                      # run as a plain script
    from common import emit, time_fn

BATCH_SIZES = (1, 8, 64)
H_IMG, W_IMG = 128, 128
CFG = F.FCMConfig(max_iters=300)


def _make_batch(b: int):
    """b distinct slices (distinct seeds/positions so nothing caches)."""
    return [phantom.phantom_slice(H_IMG, W_IMG,
                                  slice_pos=0.3 + 0.4 * i / max(b, 2),
                                  noise=3.0 + (i % 5), seed=i)[0]
            for i in range(b)]


def run():
    print("# batched_throughput: name,us_per_image,derived "
          f"(slice={H_IMG}x{W_IMG}, c={CFG.n_clusters})")
    speedups = {}
    for b in BATCH_SIZES:
        imgs = _make_batch(b)
        flats = [im.ravel().astype(np.float32) for im in imgs]
        hists = B.histograms_of(imgs)

        def seq_fused():
            for x in flats:
                F.fit_fused(x, CFG)

        def seq_hist():
            for x in flats:
                H.fit_histogram(x, CFG)

        def batched():
            B.fit_batched(hists, CFG)

        def engine():
            # fresh engine each call: cold cache, so the fit really runs
            FCMServeEngine(CFG, batch_sizes=BATCH_SIZES,
                           cache_size=0).segment(imgs)

        iters = 1 if b >= 64 else 2
        t_sf = time_fn(seq_fused, warmup=1, iters=iters)
        t_sh = time_fn(seq_hist, warmup=1, iters=iters)
        t_ba = time_fn(batched, warmup=1, iters=3)
        t_en = time_fn(engine, warmup=1, iters=iters)
        sp = t_sf / t_ba
        speedups[b] = sp
        emit(f"batched/B={b}/seq_fused", t_sf / b * 1e6,
             f"{b / t_sf:.1f} img/s")
        emit(f"batched/B={b}/seq_hist", t_sh / b * 1e6,
             f"{b / t_sh:.1f} img/s")
        emit(f"batched/B={b}/fit_batched", t_ba / b * 1e6,
             f"{b / t_ba:.1f} img/s speedup_vs_seq_fused={sp:.1f}x")
        emit(f"batched/B={b}/serve_engine", t_en / b * 1e6,
             f"{b / t_en:.1f} img/s")
    if speedups.get(64, 0.0) <= 2.0:
        raise SystemExit(
            f"FAIL: batched speedup at B=64 is {speedups[64]:.2f}x "
            "(expected > 2x over one-at-a-time fit_fused)")
    print(f"# OK: B=64 batched throughput {speedups[64]:.1f}x the "
          "one-at-a-time fit_fused baseline")
    return speedups


if __name__ == "__main__":
    run()
