"""Batched segmentation throughput: images/sec vs batch size.

Everything routes through the unified solver core. The one-at-a-time
baseline is ``solve(pixel_problem(x))`` per image (the paper's optimized
single-image path, one device launch sequence per image). Against it:

* sequential ``solve(histogram_problem(x))`` per image — histogram
  compression alone;
* ``solve_batched`` over the histogram stack — one vmapped ``(B, 256)``
  fixed point per batch, the serving engine's hot path;
* ``FCMServeEngine.segment`` — the full request path (ingest + bucketing
  + cache + defuzzify LUT), cache cold.

Then the **batched spatial** section (new with the route registry): B
same-shape FCM_S requests as one per-lane-masked stencil solve vs one
``solve(spatial_problem(img))`` per image, plus the full engine
``method="spatial"`` path. The run FAILS if the batched-spatial speedup
at B = 16 drops under 5x — that is the acceptance floor for spatial
traffic batching.

**Engine-overhead gate** (PR 5, the device-resident request pipeline):
at B = 64 the cold-cache engine end-to-end must cost at most
``ENGINE_MAX_OVERHEAD`` x the raw ``solve_batched`` it wraps — the
single-dispatch route programs collapsed that from the 26x recorded in
BENCH_pr4. The per-route ingest/solve/materialize stage seconds are
emitted so a future regression names its stage.

Run:  PYTHONPATH=src python -m benchmarks.batched_throughput
"""
from __future__ import annotations

import numpy as np

from repro.core import batched as B
from repro.core import fcm as F
from repro.core import solver as SV
from repro.core import spatial as SP
from repro.data import phantom
from repro.serving.fcm_engine import FCMServeEngine

try:
    from .common import emit, time_fn
except ImportError:                      # run as a plain script
    from common import emit, time_fn

BATCH_SIZES = (1, 8, 64)
H_IMG, W_IMG = 128, 128
CFG = F.FCMConfig(max_iters=300)

SPATIAL_B = 16
SPATIAL_HW = 48
SPATIAL_MIN_SPEEDUP = 5.0
ENGINE_MAX_OVERHEAD = 5.0
#: Hard ceiling on traced/untraced engine wall time at B=64. The
#: acceptance target is 1.05x; the gate allows slack for single-run
#: scheduler noise on a ~10 ms sample and fails only on a real
#: regression.
TRACING_MAX_OVERHEAD = 1.25


def _make_batch(b: int, h: int = H_IMG, w: int = W_IMG):
    """b distinct slices (distinct seeds/positions so nothing caches)."""
    return [phantom.phantom_slice(h, w,
                                  slice_pos=0.3 + 0.4 * i / max(b, 2),
                                  noise=3.0 + (i % 5), seed=i)[0]
            for i in range(b)]


def run_histogram(tiny: bool = False):
    """images/sec for the scalar fast path at each bucket size."""
    h = w = 64 if tiny else H_IMG
    speedups = {}
    stage_seconds = None
    for b in BATCH_SIZES:
        imgs = _make_batch(b, h, w)
        flats = [im.ravel().astype(np.float32) for im in imgs]
        hists = B.histograms_of(imgs)
        batch = SV.batch_problems(B.hist_rows(hists), hists, cfg=CFG)

        def seq_fused():
            for x in flats:
                SV.solve(SV.pixel_problem(x, CFG), CFG)

        def seq_hist():
            for x in flats:
                SV.solve(SV.histogram_problem(x, CFG), CFG)

        def batched():
            SV.solve_batched(batch, CFG)

        def engine():
            # fresh engine each call: cold cache, so the fit really runs
            FCMServeEngine(CFG, batch_sizes=BATCH_SIZES,
                           cache_size=0).segment(imgs)

        iters = 1 if (b >= 64 and not tiny) else 2
        t_sf = time_fn(seq_fused, warmup=1, iters=iters)
        t_sh = time_fn(seq_hist, warmup=1, iters=iters)
        # The overhead gate rides on these two medians: extra reps keep
        # a single noisy wall-clock sample from failing the run.
        t_ba = time_fn(batched, warmup=1, iters=7)
        t_en = time_fn(engine, warmup=1, iters=5)
        sp = t_sf / t_ba
        ov = t_en / t_ba
        speedups[b] = {"seq_fused_s": t_sf, "seq_hist_s": t_sh,
                       "batched_s": t_ba, "engine_s": t_en,
                       "speedup_batched_vs_seq": round(sp, 1),
                       "engine_overhead_vs_batched": round(ov, 2)}
        emit(f"batched/B={b}/seq_fused", t_sf / b * 1e6,
             f"{b / t_sf:.1f} img/s")
        emit(f"batched/B={b}/seq_hist", t_sh / b * 1e6,
             f"{b / t_sh:.1f} img/s")
        emit(f"batched/B={b}/solve_batched", t_ba / b * 1e6,
             f"{b / t_ba:.1f} img/s speedup_vs_seq_fused={sp:.1f}x")
        emit(f"batched/B={b}/serve_engine", t_en / b * 1e6,
             f"{b / t_en:.1f} img/s overhead_vs_batched={ov:.2f}x")
        if b == BATCH_SIZES[-1]:
            # One instrumented pass: stage breakdown + the new per-route
            # submit->result latency percentiles and convergence mix.
            eng = FCMServeEngine(CFG, batch_sizes=BATCH_SIZES, cache_size=0)
            eng.segment(imgs)
            s = eng.stats()
            stage_seconds = s["stage_seconds"]["histogram"]
            for stage, sec in stage_seconds.items():
                emit(f"batched/B={b}/engine_stage/{stage}", sec * 1e6, "")
            latency = s["latency"]["histogram"]
            convergence = s["convergence"]["histogram"]
            emit(f"batched/B={b}/latency_p50",
                 (latency["p50"] or 0.0) * 1e6,
                 f"p99={(latency['p99'] or 0.0) * 1e6:.1f}us "
                 f"n={latency['count']}")
            emit(f"batched/B={b}/mean_iters",
                 convergence["mean_iters"] or 0.0,
                 f"p99_iters={convergence['p99_iters']}")
            # Tracing-overhead check (the <=5% acceptance bound): the
            # same cold-cache end-to-end with the obs layer's ring +
            # span-histogram recording disabled.
            def engine_untraced():
                FCMServeEngine(CFG, batch_sizes=BATCH_SIZES, cache_size=0,
                               tracing=False).segment(imgs)

            t_un = time_fn(engine_untraced, warmup=1, iters=5)
            tracing_ratio = t_en / t_un if t_un > 0 else 1.0
            emit(f"batched/B={b}/tracing_overhead", (t_en - t_un) * 1e6,
                 f"traced/untraced={tracing_ratio:.3f}x")
    speedups["stage_seconds"] = stage_seconds
    speedups["latency"] = latency
    speedups["convergence"] = convergence
    speedups["tracing_overhead_ratio"] = round(tracing_ratio, 3)
    return speedups


def run_spatial(b: int = SPATIAL_B, size: int = SPATIAL_HW):
    """Batched-spatial throughput: the route-registry payoff. B
    same-shape noisy slices, FCM_S with the job config's stencil."""
    scfg = SP.SpatialFCMConfig(max_iters=CFG.max_iters)
    imgs = [phantom.noisy_phantom_slice(size, size, noise=6.0 + (i % 4),
                                        impulse=0.03, seed=i)[0]
            .astype(np.float32) for i in range(b)]
    batch = SV.batch_problems(
        np.stack(imgs),
        stencil=SV.StencilSpec(alpha=scfg.alpha, neighbors=scfg.neighbors),
        cfg=scfg)

    def one_at_a_time():
        for im in imgs:
            SV.solve(SV.spatial_problem(im, scfg), scfg)

    def batched():
        SV.solve_batched(batch, scfg)

    def engine():
        FCMServeEngine(CFG, batch_sizes=(1, 8, 16, 64),
                       spatial_cfg=scfg).segment(imgs, method="spatial")

    # The batched stencil solve is a ~25 ms wall-clock sample; transient
    # scheduler noise has failed the 5x floor before, so give the median
    # extra warmup + reps.
    t_seq = time_fn(one_at_a_time, warmup=1, iters=2)
    t_ba = time_fn(batched, warmup=2, iters=5)
    t_en = time_fn(engine, warmup=1, iters=2)
    sp = t_seq / t_ba
    emit(f"spatial/B={b}/one_at_a_time", t_seq / b * 1e6,
         f"{b / t_seq:.1f} img/s")
    emit(f"spatial/B={b}/solve_batched", t_ba / b * 1e6,
         f"{b / t_ba:.1f} img/s speedup_vs_one_at_a_time={sp:.1f}x")
    emit(f"spatial/B={b}/serve_engine", t_en / b * 1e6,
         f"{b / t_en:.1f} img/s overhead_vs_batched={t_en / t_ba:.2f}x")
    return {"b": b, "size": size, "one_at_a_time_s": t_seq,
            "batched_s": t_ba, "engine_s": t_en,
            "engine_overhead_vs_batched": round(t_en / t_ba, 2),
            "speedup_batched_vs_one_at_a_time": round(sp, 1)}


def run(tiny: bool = False):
    print("# batched_throughput: name,us_per_image,derived "
          f"(slice={64 if tiny else H_IMG}x{64 if tiny else W_IMG}, "
          f"c={CFG.n_clusters})")
    hist = run_histogram(tiny)
    spatial = run_spatial()
    hist_sp = hist[64]["speedup_batched_vs_seq"]
    if hist_sp <= 2.0:
        raise SystemExit(
            f"FAIL: batched speedup at B=64 is {hist_sp:.2f}x "
            "(expected > 2x over one-at-a-time fused solve)")
    ov = hist[64]["engine_overhead_vs_batched"]
    if ov > ENGINE_MAX_OVERHEAD:
        raise SystemExit(
            f"FAIL: histogram-route engine end-to-end at B=64 is {ov:.2f}x "
            f"the raw solve_batched (gate {ENGINE_MAX_OVERHEAD}x; the "
            "device-resident route program should keep serving overhead "
            "flat — see stage_seconds for the regressing stage)")
    sp = spatial["speedup_batched_vs_one_at_a_time"]
    if sp < SPATIAL_MIN_SPEEDUP:
        raise SystemExit(
            f"FAIL: batched-spatial speedup at B={SPATIAL_B} is "
            f"{sp:.2f}x (acceptance floor {SPATIAL_MIN_SPEEDUP}x over "
            "one-at-a-time spatial solves)")
    tr = hist["tracing_overhead_ratio"]
    if tr > TRACING_MAX_OVERHEAD:
        raise SystemExit(
            f"FAIL: tracing layer costs {tr:.2f}x the untraced engine "
            f"at B=64 (hard ceiling {TRACING_MAX_OVERHEAD}x; target "
            "<= 1.05x)")
    if tr > 1.05:
        print(f"# WARN: tracing overhead {tr:.3f}x exceeds the 1.05x "
              "target (within the hard ceiling; likely timer noise — "
              "rerun before acting on it)")
    print(f"# OK: B=64 batched histogram throughput {hist_sp:.1f}x, "
          f"engine overhead {ov:.2f}x (gate {ENGINE_MAX_OVERHEAD}x), "
          f"B={SPATIAL_B} batched spatial {sp:.1f}x the one-at-a-time "
          "baselines")
    return {"histogram": hist, "spatial": spatial}


if __name__ == "__main__":
    run()
