"""Schema validation for the consolidated BENCH JSON.

``validate(bench)`` raises ``ValueError`` listing every problem found:
missing top-level sections, a roofline section that does not cover
every (kind, impl) cell registered in ``kernels/ops.py``, or serving
latency/convergence blocks without the percentile fields the
observability layer promises. CI runs it against the ``--tiny`` output
so a PR cannot silently drop a section or a registry cell from the
perf record.

Run:  PYTHONPATH=src python -m benchmarks.bench_schema benchmarks/out/BENCH_pr7.json
"""
from __future__ import annotations

import json
import sys
from typing import List

TOP_KEYS = ("pr", "backend", "tiny", "batched_throughput", "spatial_fcm",
            "superpixel_fcm", "roofline")

CELL_KEYS = ("kind", "impl", "backend", "shape", "flops", "bytes",
             "wall_s", "achieved_flops_per_s", "achieved_bytes_per_s",
             "t_roofline", "bound", "frac_of_roofline")

HIST_KEYS = ("count", "mean", "p50", "p90", "p99")

#: Cells the perf record must carry even if someone deregisters the
#: impl: the whole-solve resident kernels are the dispatch thresholds'
#: evidence, so dropping their measurement is a schema violation.
REQUIRED_CELLS = (("flat", "resident"), ("flat", "resident_streamed"),
                  ("stencil", "resident"))


def _check_roofline(section, problems: List[str]) -> None:
    from repro.kernels import ops as kops
    cells = {(c.get("kind"), c.get("impl")): c
             for c in section.get("cells", [])}
    required = {(i.kind, i.name) for i in kops.step_impls()}
    required.update(REQUIRED_CELLS)
    for kind, name in sorted(required):
        cell = cells.get((kind, name))
        if cell is None:
            problems.append(f"roofline: no cell for registered kernel "
                            f"{kind}/{name}")
        elif "error" in cell:
            problems.append(f"roofline: cell {kind}/{name} "
                            f"errored: {cell['error']}")
        else:
            for k in CELL_KEYS:
                if k not in cell:
                    problems.append(f"roofline: cell {kind}/"
                                    f"{name} missing {k!r}")
    if "hw" not in section:
        problems.append("roofline: missing hw peaks")


def _check_latency(block, where: str, problems: List[str]) -> None:
    if not isinstance(block, dict):
        problems.append(f"{where}: latency block missing")
        return
    for k in HIST_KEYS:
        if k not in block:
            problems.append(f"{where}: latency missing {k!r}")


def validate(bench: dict) -> None:
    """Raise ValueError naming every schema violation (None when OK)."""
    problems: List[str] = []
    for k in TOP_KEYS:
        if k not in bench:
            problems.append(f"missing top-level key {k!r}")
    if "roofline" in bench:
        _check_roofline(bench["roofline"], problems)
    bt = bench.get("batched_throughput", {})
    hist = bt.get("histogram", {}) if isinstance(bt, dict) else {}
    _check_latency(hist.get("latency"), "batched_throughput.histogram",
                   problems)
    if "convergence" not in hist:
        problems.append("batched_throughput.histogram: convergence "
                        "block missing")
    if "tracing_overhead_ratio" not in hist:
        problems.append("batched_throughput.histogram: "
                        "tracing_overhead_ratio missing")
    if problems:
        raise ValueError("BENCH schema violations:\n  "
                         + "\n  ".join(problems))


def main(argv=None):
    path = (argv or sys.argv[1:])[0]
    with open(path) as f:
        bench = json.load(f)
    validate(bench)
    print(f"{path}: schema OK")


if __name__ == "__main__":
    main()
