"""Schema validation for every benchmark JSON artifact.

``validate(bench)`` raises ``ValueError`` listing every problem found
in a consolidated BENCH record: missing top-level sections, a roofline
section that does not cover every (kind, impl) cell registered in
``kernels/ops.py``, serving latency/convergence blocks without the
percentile fields the observability layer promises, or a sweep section
whose grid silently dropped a registry cell or serving route. The
standalone reports get the same treatment: ``validate_cell`` for one
sweep-cell record, ``validate_spatial_report`` /
``validate_superpixel_report`` for the two paper-table scripts (they
call these before writing their JSON). CI runs the CLI against the
``--tiny`` outputs so a PR cannot silently drop a section, a registry
cell, or a route from the perf record.

The CLI dispatches on filename: ``BENCH_pr*.json`` -> :func:`validate`,
``spatial_fcm.json`` / ``superpixel_fcm.json`` -> their report
validators, files under ``out/sweep/`` -> :func:`validate_cell`.

Run:  PYTHONPATH=src python -m benchmarks.bench_schema benchmarks/out/BENCH_pr8.json
"""
from __future__ import annotations

import json
import os
import sys
from typing import Any, Dict, List

TOP_KEYS = ("pr", "backend", "tiny", "batched_throughput", "spatial_fcm",
            "superpixel_fcm", "roofline", "sweep", "load_gen", "faults")

#: Keys of the ``faults`` section — the injected-vs-clean provenance
#: marker. A record claiming zero injections must also say chaos=False;
#: a chaos run (injected > 0) must be flagged so it can never be read
#: as (or regress-gated against) a clean perf record.
FAULTS_KEYS = ("seed", "injected", "by_site", "chaos")

CELL_KEYS = ("kind", "impl", "backend", "shape", "flops", "bytes",
             "wall_s", "achieved_flops_per_s", "achieved_bytes_per_s",
             "t_roofline", "bound", "frac_of_roofline")

HIST_KEYS = ("count", "mean", "p50", "p90", "p99")

#: Cells the perf record must carry even if someone deregisters the
#: impl: the whole-solve resident kernels are the dispatch thresholds'
#: evidence, so dropping their measurement is a schema violation.
REQUIRED_CELLS = (("flat", "resident"), ("flat", "resident_streamed"),
                  ("stencil", "resident"))


def _check_roofline(section, problems: List[str]) -> None:
    from repro.kernels import ops as kops
    cells = {(c.get("kind"), c.get("impl")): c
             for c in section.get("cells", [])}
    required = {(i.kind, i.name) for i in kops.step_impls()}
    required.update(REQUIRED_CELLS)
    for kind, name in sorted(required):
        cell = cells.get((kind, name))
        if cell is None:
            problems.append(f"roofline: no cell for registered kernel "
                            f"{kind}/{name}")
        elif "error" in cell:
            problems.append(f"roofline: cell {kind}/{name} "
                            f"errored: {cell['error']}")
        else:
            for k in CELL_KEYS:
                if k not in cell:
                    problems.append(f"roofline: cell {kind}/"
                                    f"{name} missing {k!r}")
    if "hw" not in section:
        problems.append("roofline: missing hw peaks")


def _check_latency(block, where: str, problems: List[str]) -> None:
    if not isinstance(block, dict):
        problems.append(f"{where}: latency block missing")
        return
    for k in HIST_KEYS:
        if k not in block:
            problems.append(f"{where}: latency missing {k!r}")


def _check_convergence(block, where: str, problems: List[str]) -> None:
    if not isinstance(block, dict):
        problems.append(f"{where}: convergence block missing")
        return
    for k in ("lanes", "mean_iters", "p50_iters", "p99_iters",
              "last_final_delta"):
        if k not in block:
            problems.append(f"{where}: convergence missing {k!r}")


# ---------------------------------------------------------------------------
# Sweep cells + section
# ---------------------------------------------------------------------------

#: Per-family required keys of an ok cell record, beyond the common
#: (cell_id, family, axes, status) envelope.
SWEEP_CELL_KEYS = {
    "solver": ("metrics", "latency", "convergence"),
    "serving": ("metrics", "latency", "convergence"),
    "kernel": ("kernel",),
    "distributed": ("metrics", "parity"),
}

#: Distributed (shard_map, 8 fake host devices) solver modes the sweep
#: must measure: batch-axis sharding on a ragged histogram batch, and
#: pixel-axis sharding of one image (flat + histogram-compressed).
REQUIRED_DIST_MODES = ("batch_hist", "pixel_flat", "pixel_hist")

SOLVER_METRIC_KEYS = ("wall_s", "fit_s", "compress_s", "per_image_s",
                      "n_iters")


def validate_cell(cell: dict) -> None:
    """Raise ValueError naming every problem in one sweep-cell record."""
    problems: List[str] = []
    check_cell(cell, problems)
    if problems:
        raise ValueError("sweep cell schema violations:\n  "
                         + "\n  ".join(problems))


def check_cell(cell: dict, problems: List[str]) -> None:
    cid = cell.get("cell_id", "<no cell_id>")
    for k in ("cell_id", "family", "axes", "status"):
        if k not in cell:
            problems.append(f"cell {cid}: missing {k!r}")
    family = cell.get("family")
    if family not in SWEEP_CELL_KEYS:
        problems.append(f"cell {cid}: unknown family {family!r}")
        return
    status = cell.get("status")
    if status == "skipped":
        if not cell.get("skip_reason"):
            problems.append(f"cell {cid}: skipped without a skip_reason")
        return
    if status == "error":
        if "error" not in cell:
            problems.append(f"cell {cid}: errored without an error field")
        return
    if status != "ok":
        problems.append(f"cell {cid}: unknown status {status!r}")
        return
    for k in SWEEP_CELL_KEYS[family]:
        if k not in cell or cell[k] is None:
            problems.append(f"cell {cid}: missing {k!r}")
    if family in ("solver", "serving"):
        _check_latency(cell.get("latency"), f"cell {cid}", problems)
        _check_convergence(cell.get("convergence"), f"cell {cid}",
                           problems)
        metrics = cell.get("metrics") or {}
        for k in ("wall_s", "per_image_s"):
            if k not in metrics:
                problems.append(f"cell {cid}: metrics missing {k!r}")
        if family == "solver":
            for k in SOLVER_METRIC_KEYS:
                if k not in metrics:
                    problems.append(f"cell {cid}: metrics missing {k!r}")
            if cell["axes"].get("batch") == 1:
                acc = cell.get("accuracy")
                if not isinstance(acc, dict) or "mean_dsc" not in acc:
                    problems.append(f"cell {cid}: batch=1 solver cell "
                                    "missing accuracy.mean_dsc")
    elif family == "kernel":
        kcell = cell.get("kernel") or {}
        if "error" not in kcell:
            for k in CELL_KEYS:
                if k not in kcell:
                    problems.append(f"cell {cid}: kernel row missing "
                                    f"{k!r}")
    elif family == "distributed":
        metrics = cell.get("metrics") or {}
        for k in ("wall_s", "per_image_s"):
            if k not in metrics:
                problems.append(f"cell {cid}: metrics missing {k!r}")
        parity = cell.get("parity")
        if not isinstance(parity, dict) or "ok" not in parity:
            problems.append(f"cell {cid}: parity block missing 'ok'")
        elif not parity["ok"]:
            problems.append(f"cell {cid}: distributed parity failed: "
                            f"{parity}")


def _check_sweep(section, problems: List[str]) -> None:
    """Coverage + per-cell checks for the consolidated sweep section:
    every registered (kind, impl) dispatch cell appears in the kernel
    family, every serving route in the serving family, and every
    skipped grid cell carries its reason."""
    from repro.kernels import ops as kops
    from repro.serving import fcm_engine as FE

    if not isinstance(section, dict):
        problems.append("sweep: section missing")
        return
    cells = section.get("cells", [])
    for k in ("name", "tiny", "backend", "coverage", "cells", "skipped"):
        if k not in section:
            problems.append(f"sweep: missing {k!r}")
    for cell in cells:
        check_cell(cell, problems)
    for sk in section.get("skipped", []):
        if not sk.get("skip_reason"):
            problems.append(f"sweep: skipped cell "
                            f"{sk.get('cell_id', '<no cell_id>')} "
                            "without a skip_reason")

    kernel_ok = {(c["axes"]["kind"], c["axes"]["impl"]) for c in cells
                 if c.get("family") == "kernel"
                 and c.get("status") == "ok"}
    required = {(i.kind, i.name) for i in kops.step_impls()}
    required.update(REQUIRED_CELLS)
    for kind, name in sorted(required - kernel_ok):
        problems.append(f"sweep: no ok kernel cell for registered "
                        f"{kind}/{name}")

    routes_ok = {c["axes"]["route"] for c in cells
                 if c.get("family") == "serving"
                 and c.get("status") == "ok"}
    for route in sorted(set(FE.METHODS) - routes_ok):
        problems.append(f"sweep: no ok serving cell for route {route!r}")

    variants_ok = {c["axes"]["variant"] for c in cells
                   if c.get("family") == "solver"
                   and c.get("status") == "ok"}
    for v in sorted({"pixel", "histogram", "spatial", "vector"}
                    - variants_ok):
        problems.append(f"sweep: no ok solver cell for variant {v!r}")

    dist_ok = {c["axes"]["mode"] for c in cells
               if c.get("family") == "distributed"
               and c.get("status") == "ok"}
    for mode in sorted(set(REQUIRED_DIST_MODES) - dist_ok):
        problems.append(f"sweep: no ok distributed cell for mode "
                        f"{mode!r}")


def check_sweep_section(section: dict) -> None:
    """Raise ValueError naming every sweep-section schema violation."""
    problems: List[str] = []
    _check_sweep(section, problems)
    if problems:
        raise ValueError("sweep schema violations:\n  "
                         + "\n  ".join(problems))


# ---------------------------------------------------------------------------
# Load-generator section (open-loop Poisson arrivals vs the async engine)
# ---------------------------------------------------------------------------

#: Per-rate record of one open-loop arrival sweep point.
RATE_KEYS = ("offered_qps", "achieved_qps", "completed", "p50_s",
             "p99_s", "queue_depth", "batch_occupancy")

SYNC_BASELINE_KEYS = ("qps", "p50_s", "p99_s", "n_requests")


def _check_load_gen(section, problems: List[str]) -> None:
    """The load_gen section must carry the sync baseline, every swept
    arrival rate with full latency/occupancy telemetry, the sustained
    point the gate judged, and the gate verdict itself."""
    if not isinstance(section, dict):
        problems.append("load_gen: section missing")
        return
    for k in ("tiny", "backend", "devices", "route", "sync_baseline",
              "rates", "sustained", "qps_ratio_vs_sync", "gate"):
        if k not in section:
            problems.append(f"load_gen: missing {k!r}")
    sb = section.get("sync_baseline")
    if not isinstance(sb, dict):
        problems.append("load_gen: sync_baseline block missing")
    else:
        for k in SYNC_BASELINE_KEYS:
            if k not in sb:
                problems.append(f"load_gen: sync_baseline missing {k!r}")
    rates = section.get("rates")
    if not isinstance(rates, list) or not rates:
        problems.append("load_gen: rates sweep empty")
        rates = []
    for i, rate in enumerate(rates):
        for k in RATE_KEYS:
            if k not in rate:
                problems.append(f"load_gen.rates[{i}]: missing {k!r}")
    sustained = section.get("sustained")
    if not isinstance(sustained, dict):
        problems.append("load_gen: sustained block missing")
    else:
        for k in RATE_KEYS:
            if k not in sustained:
                problems.append(f"load_gen.sustained: missing {k!r}")
    gate = section.get("gate")
    if not isinstance(gate, dict):
        problems.append("load_gen: gate block missing")
    else:
        for k in ("enforced", "min_ratio", "ok"):
            if k not in gate:
                problems.append(f"load_gen.gate: missing {k!r}")
        if gate.get("enforced") and not gate.get("ok"):
            problems.append(f"load_gen: gate failed: {gate}")


def check_load_gen_section(section: dict) -> None:
    """Raise ValueError naming every load_gen-section schema violation."""
    problems: List[str] = []
    _check_load_gen(section, problems)
    if problems:
        raise ValueError("load_gen schema violations:\n  "
                         + "\n  ".join(problems))


# ---------------------------------------------------------------------------
# Faults section (fault-injection provenance)
# ---------------------------------------------------------------------------

def _check_faults(section, problems: List[str]) -> None:
    """The faults section must carry the full injection snapshot, and
    its internal consistency is part of the schema: a record with
    injected faults that claims ``chaos: false`` is masquerading as a
    clean benchmark."""
    if not isinstance(section, dict):
        problems.append("faults: section missing")
        return
    for k in FAULTS_KEYS:
        if k not in section:
            problems.append(f"faults: missing {k!r}")
    injected = section.get("injected", 0)
    by_site = section.get("by_site")
    if not isinstance(by_site, dict):
        problems.append("faults: by_site must be a site->count mapping")
        by_site = {}
    if injected and not section.get("chaos"):
        problems.append(f"faults: {injected} faults injected but "
                        "chaos=false — an injected run may not pose as "
                        "a clean one")
    if sum(by_site.values()) != injected:
        problems.append(f"faults: by_site totals "
                        f"{sum(by_site.values())} but injected="
                        f"{injected}")


def check_faults_section(section: dict) -> None:
    """Raise ValueError naming every faults-section schema violation."""
    problems: List[str] = []
    _check_faults(section, problems)
    if problems:
        raise ValueError("faults schema violations:\n  "
                         + "\n  ".join(problems))


# ---------------------------------------------------------------------------
# Standalone report schemas (spatial_fcm.json / superpixel_fcm.json)
# ---------------------------------------------------------------------------

def validate_spatial_report(report: dict) -> None:
    """Schema of ``benchmarks/out/spatial_fcm.json``: per-noise-level
    plain/spatial fits, each with per-class DSC + wall seconds."""
    from repro.data import phantom
    problems: List[str] = []
    for k in ("backend", "size", "seed", "alpha", "neighbors", "levels"):
        if k not in report:
            problems.append(f"spatial_fcm: missing {k!r}")
    levels = report.get("levels") or []
    if not levels:
        problems.append("spatial_fcm: no noise levels")
    for i, level in enumerate(levels):
        for k in ("sigma", "impulse", "fits"):
            if k not in level:
                problems.append(f"spatial_fcm.levels[{i}]: missing {k!r}")
        fits = level.get("fits", {})
        for fit in ("plain", "spatial_ref"):
            if fit not in fits:
                problems.append(f"spatial_fcm.levels[{i}]: missing "
                                f"fit {fit!r}")
                continue
            rec = fits[fit]
            for k in ("dsc", "n_iters", "seconds"):
                if k not in rec:
                    problems.append(f"spatial_fcm.levels[{i}].{fit}: "
                                    f"missing {k!r}")
            for cls in phantom.CLASS_NAMES:
                if cls not in rec.get("dsc", {}):
                    problems.append(f"spatial_fcm.levels[{i}].{fit}: "
                                    f"dsc missing class {cls!r}")
    if problems:
        raise ValueError("spatial_fcm schema violations:\n  "
                         + "\n  ".join(problems))


def validate_superpixel_report(report: dict) -> None:
    """Schema of ``benchmarks/out/superpixel_fcm.json``: the
    pixels-vs-superpixels headline record."""
    from repro.data import phantom
    problems: List[str] = []
    for k in ("backend", "size", "n_pixels", "n_superpixels",
              "compression_ratio", "pixel_fit_s", "compress_s",
              "superpixel_fit_s", "speedup_fit", "speedup_total",
              "dsc_pixel", "dsc_superpixel", "dsc_parity_max_delta"):
        if k not in report:
            problems.append(f"superpixel_fcm: missing {k!r}")
    for side in ("dsc_pixel", "dsc_superpixel"):
        for cls in phantom.CLASS_NAMES:
            if cls not in report.get(side, {}):
                problems.append(f"superpixel_fcm.{side}: missing class "
                                f"{cls!r}")
    if problems:
        raise ValueError("superpixel_fcm schema violations:\n  "
                         + "\n  ".join(problems))


# ---------------------------------------------------------------------------
# Consolidated BENCH record + CLI
# ---------------------------------------------------------------------------

def validate(bench: dict) -> None:
    """Raise ValueError naming every schema violation (None when OK).

    ``sweep`` is required from pr >= 8, ``load_gen`` from pr >= 9 and
    ``faults`` from pr >= 10 (older committed ledger entries predate
    those harnesses and stay valid as-written)."""
    problems: List[str] = []
    pr = bench.get("pr", 0)
    optional = set()
    if pr < 8:
        optional.add("sweep")
    if pr < 9:
        optional.add("load_gen")
    if pr < 10:
        optional.add("faults")
    for k in TOP_KEYS:
        if k not in optional and k not in bench:
            problems.append(f"missing top-level key {k!r}")
    if "roofline" in bench:
        _check_roofline(bench["roofline"], problems)
    if "sweep" in bench:
        _check_sweep(bench["sweep"], problems)
    if "load_gen" in bench:
        _check_load_gen(bench["load_gen"], problems)
    if "faults" in bench:
        _check_faults(bench["faults"], problems)
    bt = bench.get("batched_throughput", {})
    hist = bt.get("histogram", {}) if isinstance(bt, dict) else {}
    _check_latency(hist.get("latency"), "batched_throughput.histogram",
                   problems)
    if "convergence" not in hist:
        problems.append("batched_throughput.histogram: convergence "
                        "block missing")
    if "tracing_overhead_ratio" not in hist:
        problems.append("batched_throughput.histogram: "
                        "tracing_overhead_ratio missing")
    if problems:
        raise ValueError("BENCH schema violations:\n  "
                         + "\n  ".join(problems))


def validate_path(path: str) -> str:
    """Validate one JSON artifact, dispatching on its filename.
    Returns a short description of which schema was applied."""
    with open(path) as f:
        payload = json.load(f)
    name = os.path.basename(path)
    if name == "spatial_fcm.json":
        validate_spatial_report(payload)
        return "spatial_fcm report"
    if name == "superpixel_fcm.json":
        validate_superpixel_report(payload)
        return "superpixel_fcm report"
    if name.startswith("load_gen") and name.endswith(".json"):
        check_load_gen_section(payload)
        return "load_gen section"
    if os.path.basename(os.path.dirname(path)) == "sweep":
        validate_cell(payload)
        return "sweep cell"
    if "cells" in payload and "coverage" in payload:
        check_sweep_section(payload)
        return "sweep section"
    validate(payload)
    return "BENCH record"


def main(argv=None):
    paths = list(argv or sys.argv[1:])
    if not paths:
        raise SystemExit("usage: bench_schema.py ARTIFACT.json [...]")
    for path in paths:
        kind = validate_path(path)
        print(f"{path}: schema OK ({kind})")


if __name__ == "__main__":
    main()
