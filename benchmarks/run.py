"""Benchmark harness: one module per paper table/figure. Prints
``name,us_per_call,derived`` CSV lines and writes the consolidated
``benchmarks/out/BENCH_pr9.json`` aggregating the batched / spatial /
superpixel serving numbers (engine-overhead + tracing-overhead gates,
per-route latency percentiles, convergence telemetry), the declarative
variant-zoo sweep (now including the 8-fake-device distributed solver
cells), the roofline-vs-achieved kernel report, and the async serving
load-generator section (open-loop Poisson QPS/p99 sweep + the
continuous-batching 3x gate), validates the result against
``bench_schema.py``, renders the accuracy-vs-speed frontier and
perf-trajectory figures, and regression-gates EVERY ledger metric
through ``repro.analysis.trajectory.diff`` against the newest committed
``BENCH_pr*.json`` — so the perf trajectory is machine-readable AND
regression-guarded per-metric across PRs (not just one hardcoded B=64
engine-seconds check).

  table1_variants    — paper Table 1 analogue (variant ladder)
  fig7_dsc           — paper Fig. 7 DSC parity (parallel == sequential)
  table3_speedup     — paper Table 3 exec times + Fig. 8 speedup curve
                       (sequential vs device, one solve() entry point)
  sweep              — declarative variant x backend x size x batch x
                       seed grid + serving routes + kernel roofline
                       cells (always runs: BENCH needs full coverage)
  batched_throughput — beyond-paper: images/sec vs batch size for the
                       histogram AND batched-spatial serving paths
  load_gen           — beyond-paper: open-loop Poisson load on the
                       async admission front door vs the sync baseline
  spatial_fcm        — FCM_S noise-robustness + wall clock
  superpixel_fcm     — pixels-vs-superpixels compression ladder

  PYTHONPATH=src python -m benchmarks.run [--tiny] [--skip-paper-tables]
"""
from __future__ import annotations

import argparse
import json
import os

#: This PR's ledger slot: the consolidated record lands in
#: ``BENCH_pr{CURRENT_PR}.json`` and the regression baseline
#: auto-resolves to the newest committed ``BENCH_pr*.json`` with an
#: older pr number (no more hand-bumping a hardcoded baseline path).
CURRENT_PR = 10

OUT_DIR = os.path.join(os.path.dirname(__file__), "out")
FIG_DIR = os.path.join(OUT_DIR, "figures")


def _faults_snapshot() -> dict:
    """The BENCH record's faults section: the live global injector's
    snapshot if one is somehow installed (a chaos run that must be
    flagged), otherwise the explicit all-clean marker."""
    from repro import faults as FI

    inj = FI.get()
    return inj.snapshot() if inj is not None else FI.clean_snapshot()


def perf_gate(bench: dict, baseline_path: str = None) -> None:
    """Per-metric regression gate through the trajectory ledger:
    ``trajectory.diff`` compares every ledger metric (engine seconds,
    overhead ratios, spatial/superpixel speedups, DSC parity, tracing
    overhead, iteration counts) against the newest committed baseline
    under its per-metric policy. Relative gates apply to comparable
    (full-vs-full) runs; absolute ceilings/floors — engine overhead
    <= 5x, tracing overhead <= 1.25x, spatial batched speedup >= 5x,
    DSC parity <= 0.05 — and missing-metric checks gate every run,
    including --tiny CI."""
    from repro.analysis import trajectory

    if baseline_path is None:
        baseline_path = trajectory.resolve_baseline(OUT_DIR,
                                                    before=CURRENT_PR)
    if baseline_path is None or not os.path.exists(baseline_path):
        print("# perf-gate: no committed baseline, skipping")
        return
    result = trajectory.diff(trajectory.load_bench(baseline_path), bench)
    print(f"# perf-gate baseline: {os.path.basename(baseline_path)}")
    for line in result.report().splitlines():
        print(f"# {line}")
    if not result.ok:
        raise SystemExit(
            "FAIL perf-gate: " + "; ".join(
                f"{v.metric}: {v.detail}" for v in result.failures))
    print("# perf-gate OK (trajectory.diff: "
          f"{len(result.verdicts)} metrics checked)")


def render_figures(bench: dict, fig_dir: str = FIG_DIR) -> list:
    """The two analysis figures: the perf-trajectory small multiples
    over every committed BENCH record (plus this run) and this run's
    accuracy-vs-speed frontier from the sweep's solver cells."""
    from repro.analysis import trajectory

    os.makedirs(fig_dir, exist_ok=True)
    paths = []
    try:
        ledger = [(pr, b) for pr, b in trajectory.load_ledger(OUT_DIR)
                  if pr != bench.get("pr")]
        ledger.append((bench.get("pr"), bench))
        paths.append(trajectory.render_trajectory(
            ledger, os.path.join(fig_dir, "perf_trajectory.png")))
        paths.append(trajectory.render_frontier(
            bench, os.path.join(fig_dir, "frontier.png")))
        for p in paths:
            print(f"wrote {p}")
    except Exception as e:       # figures are artifacts, not gates
        print(f"# figure rendering failed (non-fatal): {e!r}")
    return paths


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--tiny", action="store_true",
                    help="CI smoke: small images, single timing reps")
    ap.add_argument("--skip-paper-tables", action="store_true",
                    help="run only the serving/sweep sections that feed "
                         "the BENCH record")
    args = ap.parse_args(argv)

    import jax

    from . import (batched_throughput, bench_schema, fig7_dsc, load_gen,
                   roofline_report, spatial_fcm, superpixel_fcm, sweep,
                   table1_variants, table3_speedup)

    print("benchmark,us_per_call,derived")
    if not args.skip_paper_tables:
        table1_variants.run()
        fig7_dsc.run()
        table3_speedup.run()

    # The variant-zoo sweep always runs (even --skip-paper-tables): the
    # BENCH schema requires coverage of every registered kernel cell,
    # serving route, and solver variant. Its embedded roofline report
    # doubles as the bench["roofline"] section (one measurement).
    sweep_section = sweep.run_sweep(tiny=args.tiny)
    roofline = sweep_section.pop("roofline")
    roofline_report.run(smoke=args.tiny, report=roofline)

    throughput = batched_throughput.run(tiny=args.tiny)
    spatial_argv = [] if jax.default_backend() == "tpu" else ["--no-pallas"]
    if args.tiny:
        spatial_argv += ["--size", "48"]
    spatial = spatial_fcm.main(spatial_argv)
    superpixel = superpixel_fcm.main(["--tiny"] if args.tiny else [])
    load = load_gen.run_load_gen(tiny=args.tiny)

    bench = {
        "pr": CURRENT_PR,
        "backend": jax.default_backend(),
        "tiny": args.tiny,
        # serving-path throughput (batched histogram + batched spatial),
        # incl. the engine/tracing overhead gates, stage breakdown, and
        # per-route latency + convergence telemetry
        "batched_throughput": throughput,
        # FCM_S robustness/wall-clock sweep
        "spatial_fcm": spatial,
        # superpixel compression ladder
        "superpixel_fcm": superpixel,
        # roofline-vs-achieved, one cell per registered kernel impl
        "roofline": roofline,
        # declarative variant-zoo grid (solver/serving/kernel/
        # distributed families)
        "sweep": sweep_section,
        # async serving under open-loop Poisson load: sustained QPS,
        # p50/p99, queue depth, batch occupancy + the 3x gate
        "load_gen": load,
        # fault-injection provenance: the benchmark harness never
        # installs an injector, so a clean snapshot here is the record's
        # proof it was not a chaos run (bench_schema enforces the
        # consistency of injected/chaos).
        "faults": _faults_snapshot(),
    }
    bench_schema.validate(bench)
    print("# BENCH schema OK")
    perf_gate(bench)
    os.makedirs(OUT_DIR, exist_ok=True)
    out_path = os.path.join(OUT_DIR, f"BENCH_pr{CURRENT_PR}.json")
    with open(out_path, "w") as f:
        json.dump(bench, f, indent=1)
    print(f"wrote {out_path}")
    render_figures(bench)
    return bench


if __name__ == '__main__':
    main()
