"""Benchmark harness: one module per paper table/figure. Prints
``name,us_per_call,derived`` CSV lines and writes the consolidated
``benchmarks/out/BENCH_pr7.json`` aggregating the batched / spatial /
superpixel serving numbers (engine-overhead + tracing-overhead gates,
per-route latency percentiles, convergence telemetry) and the
roofline-vs-achieved kernel report, validates the result against
``bench_schema.py``, and perf-gates the B=64 engine overhead against
the committed ``BENCH_pr6.json`` baseline — so the perf trajectory is
machine-readable AND regression-guarded across PRs.

  table1_variants    — paper Table 1 analogue (variant ladder)
  fig7_dsc           — paper Fig. 7 DSC parity (parallel == sequential)
  table3_speedup     — paper Table 3 exec times + Fig. 8 speedup curve
                       (sequential vs device, one solve() entry point)
  roofline_report    — roofline-vs-achieved per registered kernel cell
                       (always runs: BENCH needs full cell coverage)
  batched_throughput — beyond-paper: images/sec vs batch size for the
                       histogram AND batched-spatial serving paths
  spatial_fcm        — FCM_S noise-robustness + wall clock
  superpixel_fcm     — pixels-vs-superpixels compression ladder

  PYTHONPATH=src python -m benchmarks.run [--tiny] [--skip-paper-tables]
"""
from __future__ import annotations

import argparse
import json
import os

#: Allowed growth of the B=64 histogram engine wall time over the
#: committed BENCH_pr6 baseline. The gate rides on the engine's OWN
#: seconds, not the overhead-vs-solve_batched ratio: the raw solve's
#: run-to-run variance would otherwise fail the serving path for
#: getting a faster denominator. The slack absorbs scheduler noise on
#: a ~10 ms sample.
PERF_GATE_RATIO = 1.5
BASELINE = os.path.join(os.path.dirname(__file__), "out", "BENCH_pr6.json")


def perf_gate(bench: dict, baseline_path: str = BASELINE) -> None:
    """Fail on regressions vs the committed baseline's B=64 engine
    seconds; print the stage-seconds comparison so a failure names its
    stage. Only comparable (full-vs-full) runs gate — a --tiny run
    against the full-size baseline reports but cannot fail."""
    if not os.path.exists(baseline_path):
        print("# perf-gate: no committed baseline, skipping")
        return
    with open(baseline_path) as f:
        base = json.load(f)
    try:
        bh = base["batched_throughput"]["histogram"]
        nh = bench["batched_throughput"]["histogram"]
        base_s = bh["64"]["engine_s"]
        now_s = nh[64]["engine_s"]
        base_st, now_st = bh["stage_seconds"], nh["stage_seconds"]
    except KeyError as e:
        print(f"# perf-gate: baseline incomparable ({e!r}), skipping")
        return
    for stage in ("ingest", "solve", "materialize"):
        b, n = base_st.get(stage, 0.0), now_st.get(stage, 0.0)
        print(f"# perf-gate stage {stage}: {n * 1e3:.2f} ms "
              f"(baseline {b * 1e3:.2f} ms)")
    ceiling = base_s * PERF_GATE_RATIO
    verdict = (f"B=64 engine {now_s * 1e3:.2f} ms (baseline "
               f"{base_s * 1e3:.2f} ms, ceiling {ceiling * 1e3:.2f} ms "
               f"= {PERF_GATE_RATIO}x)")
    if bench.get("tiny") and not base.get("tiny"):
        print(f"# perf-gate (informational, tiny vs full baseline): "
              f"{verdict}")
        return
    if now_s > ceiling:
        raise SystemExit(f"FAIL perf-gate: {verdict}; the stage lines "
                         "above name the regression")
    print(f"# perf-gate OK: {verdict}")


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--tiny", action="store_true",
                    help="CI smoke: small images, single timing reps")
    ap.add_argument("--skip-paper-tables", action="store_true",
                    help="run only the serving sections that feed "
                         "BENCH_pr7.json")
    args = ap.parse_args(argv)

    import jax

    from . import (batched_throughput, bench_schema, fig7_dsc,
                   roofline_report, spatial_fcm, superpixel_fcm,
                   table1_variants, table3_speedup)

    print("benchmark,us_per_call,derived")
    if not args.skip_paper_tables:
        table1_variants.run()
        fig7_dsc.run()
        table3_speedup.run()

    # The kernel roofline cells always run (even --skip-paper-tables):
    # the BENCH schema requires an entry per registered kernel cell.
    roofline = roofline_report.run(smoke=args.tiny)

    throughput = batched_throughput.run(tiny=args.tiny)
    spatial_argv = [] if jax.default_backend() == "tpu" else ["--no-pallas"]
    if args.tiny:
        spatial_argv += ["--size", "48"]
    spatial = spatial_fcm.main(spatial_argv)
    superpixel = superpixel_fcm.main(["--tiny"] if args.tiny else [])

    bench = {
        "pr": 7,
        "backend": jax.default_backend(),
        "tiny": args.tiny,
        # serving-path throughput (batched histogram + batched spatial),
        # incl. the engine/tracing overhead gates, stage breakdown, and
        # per-route latency + convergence telemetry
        "batched_throughput": throughput,
        # FCM_S robustness/wall-clock sweep
        "spatial_fcm": spatial,
        # superpixel compression ladder
        "superpixel_fcm": superpixel,
        # roofline-vs-achieved, one cell per registered kernel impl
        "roofline": roofline,
    }
    bench_schema.validate(bench)
    print("# BENCH schema OK")
    perf_gate(bench)
    out_dir = os.path.join(os.path.dirname(__file__), "out")
    os.makedirs(out_dir, exist_ok=True)
    out_path = os.path.join(out_dir, "BENCH_pr7.json")
    with open(out_path, "w") as f:
        json.dump(bench, f, indent=1)
    print(f"wrote {out_path}")
    return bench


if __name__ == '__main__':
    main()
