"""Benchmark harness: one module per paper table/figure. Prints
``name,us_per_call,derived`` CSV lines.

  table1_variants    — paper Table 1 analogue (variant ladder)
  fig7_dsc           — paper Fig. 7 DSC parity (parallel == sequential)
  table3_speedup     — paper Table 3 exec times + Fig. 8 speedup curve
  roofline_report    — §Roofline summary from the dry-run JSONL
  batched_throughput — beyond-paper: images/sec vs batch size (serving)
"""


def main() -> None:
    from . import (batched_throughput, fig7_dsc, roofline_report,
                   table1_variants, table3_speedup)
    print("benchmark,us_per_call,derived")
    table1_variants.run()
    fig7_dsc.run()
    table3_speedup.run()
    roofline_report.run()
    batched_throughput.run()


if __name__ == '__main__':
    main()
