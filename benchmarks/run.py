"""Benchmark harness: one module per paper table/figure. Prints
``name,us_per_call,derived`` CSV lines and writes the consolidated
``benchmarks/out/BENCH_pr5.json`` aggregating the batched / spatial /
superpixel serving numbers (including the engine-overhead gate the
device-resident route programs must hold), so the perf trajectory is
machine-readable across PRs.

  table1_variants    — paper Table 1 analogue (variant ladder)
  fig7_dsc           — paper Fig. 7 DSC parity (parallel == sequential)
  table3_speedup     — paper Table 3 exec times + Fig. 8 speedup curve
                       (sequential vs device, one solve() entry point)
  roofline_report    — §Roofline summary from the dry-run JSONL
  batched_throughput — beyond-paper: images/sec vs batch size for the
                       histogram AND batched-spatial serving paths
  spatial_fcm        — FCM_S noise-robustness + wall clock
  superpixel_fcm     — pixels-vs-superpixels compression ladder

  PYTHONPATH=src python -m benchmarks.run [--tiny] [--skip-paper-tables]
"""
from __future__ import annotations

import argparse
import json
import os


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--tiny", action="store_true",
                    help="CI smoke: small images, single timing reps")
    ap.add_argument("--skip-paper-tables", action="store_true",
                    help="run only the serving sections that feed "
                         "BENCH_pr5.json")
    args = ap.parse_args(argv)

    import jax

    from . import (batched_throughput, fig7_dsc, roofline_report,
                   spatial_fcm, superpixel_fcm, table1_variants,
                   table3_speedup)

    print("benchmark,us_per_call,derived")
    if not args.skip_paper_tables:
        table1_variants.run()
        fig7_dsc.run()
        table3_speedup.run()
        roofline_report.run()

    throughput = batched_throughput.run(tiny=args.tiny)
    spatial_argv = [] if jax.default_backend() == "tpu" else ["--no-pallas"]
    if args.tiny:
        spatial_argv += ["--size", "48"]
    spatial = spatial_fcm.main(spatial_argv)
    superpixel = superpixel_fcm.main(["--tiny"] if args.tiny else [])

    bench = {
        "pr": 5,
        "backend": jax.default_backend(),
        "tiny": args.tiny,
        # serving-path throughput (batched histogram + batched spatial),
        # incl. the B=64 engine-overhead gate and stage breakdown
        "batched_throughput": throughput,
        # FCM_S robustness/wall-clock sweep
        "spatial_fcm": spatial,
        # superpixel compression ladder
        "superpixel_fcm": superpixel,
    }
    out_dir = os.path.join(os.path.dirname(__file__), "out")
    os.makedirs(out_dir, exist_ok=True)
    out_path = os.path.join(out_dir, "BENCH_pr5.json")
    with open(out_path, "w") as f:
        json.dump(bench, f, indent=1)
    print(f"wrote {out_path}")
    return bench


if __name__ == '__main__':
    main()
