"""Subprocess entry for the sweep's distributed family: measures the
shard_map solver cells under 8 fake host devices (the flag must be set
before jax initialises, hence a fresh process) and prints one JSON
document to stdout.

Three modes (``bench_schema.REQUIRED_DIST_MODES``):

* ``batch_hist``  — ragged histogram batch, batch axis sharded via
  ``batched.fit_batched_sharded``; parity vs the unsharded
  ``solve_batched`` must be exact on per-lane iteration counts (the
  active-lane mask keeps padding lanes out of the convergence scalar).
* ``pixel_flat``  — one image, pixel axis sharded via
  ``distributed.fit_sharded``; parity vs the reference solve.
* ``pixel_hist``  — same, through the histogram-compressed path.

Run:  PYTHONPATH=src python benchmarks/_dist_cells.py [--tiny]
"""
import json
import os
import sys
import time

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

_SRC = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "src")
sys.path.insert(0, _SRC)

import numpy as np  # noqa: E402
import jax  # noqa: E402

from repro.core import fcm as F  # noqa: E402
from repro.core import batched as B  # noqa: E402
from repro.core import solver as SV  # noqa: E402
from repro.core import distributed as D  # noqa: E402
from repro.data import phantom  # noqa: E402


def _best_of(fn, reps):
    fn()                                        # warm compile
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def main(argv=None):
    tiny = "--tiny" in (argv or sys.argv[1:])
    n_dev = len(jax.devices())
    assert n_dev == 8, jax.devices()
    kwargs = {}
    if hasattr(jax.sharding, "AxisType"):
        kwargs["axis_types"] = (jax.sharding.AxisType.Auto,)
    mesh = jax.make_mesh((n_dev,), ("data",), **kwargs)
    cfg = F.FCMConfig(max_iters=300)
    reps = 1 if tiny else 3
    size = 64 if tiny else 128
    batch = 6 if tiny else 10
    cells = []

    # -- batch_hist: ragged batch, batch axis sharded -------------------
    imgs = [phantom.phantom_slice(size + 8 * (z % 3), size,
                                  slice_pos=0.3 + 0.04 * z, seed=z)[0]
            for z in range(batch)]
    hists = B.histograms_of(imgs)
    shard = B.fit_batched_sharded(hists, mesh, cfg)
    problem = SV.batch_problems(B.hist_rows(hists), hists, cfg=cfg)
    local = SV.solve_batched(problem, backend="reference")
    wall = _best_of(lambda: B.fit_batched_sharded(hists, mesh, cfg), reps)
    max_dc = float(np.max(np.abs(np.asarray(shard.centers)
                                 - np.asarray(local.centers))))
    iters_eq = bool(np.array_equal(np.asarray(shard.n_iters),
                                   np.asarray(local.n_iters)))
    cells.append({
        "mode": "batch_hist", "batch": batch,
        "wall_s": wall, "per_image_s": wall / batch,
        "parity": {"ok": max_dc < 1e-4 and iters_eq,
                   "max_center_delta": max_dc,
                   "n_iters_equal": iters_eq},
    })

    # -- pixel_flat / pixel_hist: one image, pixel axis sharded ---------
    img, _ = phantom.phantom_slice(size, size, seed=11)
    x = img.ravel().astype(np.float32)
    ref = SV.solve(SV.pixel_problem(x, cfg), backend="reference")
    for mode, histogram in (("pixel_flat", False), ("pixel_hist", True)):
        res = D.fit_sharded(x, mesh, cfg, histogram=histogram)
        wall = _best_of(
            lambda h=histogram: D.fit_sharded(x, mesh, cfg, histogram=h),
            reps)
        max_dc = float(np.max(np.abs(
            np.sort(np.asarray(res.centers))
            - np.sort(np.asarray(ref.centers)))))
        agree = float((np.asarray(res.labels)
                       == np.asarray(ref.labels)).mean())
        cells.append({
            "mode": mode, "batch": 1,
            "wall_s": wall, "per_image_s": wall,
            "parity": {"ok": max_dc < 0.75 and agree > 0.995,
                       "max_center_delta": max_dc,
                       "label_agreement": agree},
        })

    print(json.dumps({"devices": n_dev, "tiny": tiny, "cells": cells}))


if __name__ == "__main__":
    main()
