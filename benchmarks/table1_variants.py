"""Paper Table 1 analogue: comparison of implementation variants on the
same workload (the paper compares against four prior GPU-FCM systems; we
compare our ladder of variants, each mapped to the related-work row it
mirrors — Li et al.'s modified-algorithm -> our fused iteration;
br-FCM's data reduction -> our histogram FCM)."""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from repro.core import fcm as F
from repro.core import histogram as H
from repro.data import phantom
from repro.kernels import ops as kops
from .common import emit, time_fn

SIZE_KB = 300
ITERS = 10


def run():
    img, _ = phantom.phantom_of_bytes(SIZE_KB * 1024)
    x = img.astype(np.float32)
    xj = jnp.asarray(x)
    v0 = F.linspace_centers(xj, 4)

    def staged():       # paper-faithful 5-stage pipeline, one iteration
        u = F._stage_membership(xj, v0, 2.0)
        nt, dt = F._stage_terms(xj, u, 2.0)
        num = F._stage_reduce_num(nt)
        den = F._stage_reduce_den(dt)
        F._stage_combine(num, den).block_until_ready()

    def fused():
        F.fused_center_step(xj, v0, 2.0).block_until_ready()

    def fused_pallas():  # Pallas kernel (interpret mode on CPU)
        kops.fused_step(x, np.asarray(v0), 2.0).block_until_ready()

    hist = H.intensity_histogram(xj)
    vals = jnp.arange(256, dtype=jnp.float32)

    def histogram():
        H.weighted_center_step(vals, hist, v0, 2.0).block_until_ready()

    # HLO-derived HBM traffic per iteration (the TPU-relevant metric;
    # CPU wall time below is indicative only — interpret-mode Pallas in
    # particular runs the kernel body in Python).
    import jax
    from repro.analysis import hlo_cost

    def traffic(fn, *args):
        txt = jax.jit(fn).lower(*args).compile().as_text()
        return hlo_cost.analyze_text(txt, 1).bytes

    u_stage = F._stage_membership(xj, v0, 2.0)
    tr = {
        "staged-paper-faithful":
            traffic(lambda x, v: F._stage_membership(x, v, 2.0), xj, v0)
            + traffic(lambda x, u: F._stage_terms(x, u, 2.0), xj, u_stage)
            + traffic(lambda nt: F._stage_reduce_num(nt),
                      F._stage_terms(xj, u_stage, 2.0)[0])
            + traffic(lambda dt: F._stage_reduce_den(dt),
                      F._stage_terms(xj, u_stage, 2.0)[1]),
        "fused-iteration":
            traffic(lambda x, v: F.fused_center_step(x, v, 2.0), xj, v0),
        # Pallas kernel-boundary IO (analytic: interpret-mode HLO is a
        # Python loop, not representative): x + weights in, (c,128)x2 out.
        # All (c,N) intermediates live in VMEM — this is the fused win
        # the jnp path can't express (XLA materializes ~6 (c,N) tensors).
        "fused-pallas-interpret": 2 * x.size * 4 + 2 * 4 * 128 * 4,
        "histogram-256":
            traffic(lambda h, v: H.weighted_center_step(vals, h, v, 2.0),
                    hist, v0),
    }
    rows = [
        ("staged-paper-faithful", staged, "mirrors paper's 5 kernels"),
        ("fused-iteration", fused, "beyond-paper #1 (one pass)"),
        ("fused-pallas-interpret", fused_pallas,
         "TPU kernel, interpret mode"),
        ("histogram-256", histogram, "beyond-paper #2 (br-FCM[11])"),
    ]
    t0 = None
    for name, fn, note in rows:
        t = time_fn(fn, warmup=1, iters=3)
        t0 = t0 or t
        emit(f"table1/{name}", t * 1e6,
             f"{note}; vs_staged={t0 / t:.1f}x "
             f"hbm_bytes_per_iter={tr[name]:.3e}")


if __name__ == "__main__":
    run()
