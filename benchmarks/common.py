"""Shared benchmark utilities: timing, CSV output."""
from __future__ import annotations

import time
from typing import Callable, List


def time_fn(fn: Callable, warmup: int = 1, iters: int = 3) -> float:
    """Median wall-time of fn() in seconds."""
    for _ in range(warmup):
        fn()
    ts: List[float] = []
    for _ in range(iters):
        t0 = time.perf_counter()
        fn()
        ts.append(time.perf_counter() - t0)
    ts.sort()
    return ts[len(ts) // 2]


def emit(name: str, us_per_call: float, derived: str = ""):
    print(f"{name},{us_per_call:.1f},{derived}")
