"""Paper Fig. 7: Dice Similarity Coefficient of parallel vs sequential
FCM against ground truth, for WM/GM/CSF/background on four axial slices
(91st, 96th, 101st, 111th — realized as four slice positions of the
synthetic phantom). The paper's claim: parallel and sequential DSC are
statistically identical. We check DSC(parallel) == DSC(sequential)
within 0.5% and both >= 0.9 per tissue."""
from __future__ import annotations

import numpy as np

from repro.core import fcm as F
from repro.core import solver as SV
from repro.data import phantom
from .common import emit

SLICES = {"91st": 0.35, "96th": 0.5, "101st": 0.65, "111th": 0.85}


def run():
    print("# fig7: per-slice DSC (seq vs parallel) per tissue")
    ok = True
    for name, pos in SLICES.items():
        img, gt = phantom.phantom_slice(181, 217, slice_pos=pos,
                                        seed=hash(name) % 1000)
        x = img.ravel().astype(np.float32)
        # identical deterministic init for both (random membership init
        # can collapse clusters on some seeds — paper restarts manually;
        # we pin the comparison instead)
        v0 = np.asarray(F.linspace_centers(np.asarray(x), 4))
        d2 = (v0[:, None] - x[None, :]) ** 2
        p = np.clip(d2, 1e-12, None) ** -1.0
        u0 = p / p.sum(axis=0, keepdims=True)
        res_seq = SV.solve(SV.pixel_problem(x, c=4), backend="sequential",
                           eps=5e-3, max_iters=200, u0=u0)
        v_seq = np.asarray(res_seq.centers)
        lab_seq = np.asarray(res_seq.labels)
        res_par = SV.solve(SV.pixel_problem(x), eps=5e-3, max_iters=300)
        pred_seq = phantom.match_labels_to_classes(lab_seq, v_seq)
        pred_par = phantom.match_labels_to_classes(
            np.asarray(res_par.labels), np.asarray(res_par.centers))
        d_seq = phantom.dice_per_class(pred_seq, gt.ravel())
        d_par = phantom.dice_per_class(pred_par, gt.ravel())
        for k, cls in enumerate(phantom.CLASS_NAMES):
            emit(f"fig7/{name}/{cls}", 0.0,
                 f"dsc_seq={d_seq[k]:.4f} dsc_par={d_par[k]:.4f}")
            ok &= abs(d_seq[k] - d_par[k]) < 0.005 and d_par[k] > 0.9
    emit("fig7/parallel_equals_sequential", 0.0, f"pass={ok}")
    return ok


if __name__ == "__main__":
    run()
