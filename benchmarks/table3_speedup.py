"""Paper Table 3 + Fig. 8: execution time of sequential FCM vs the
parallel (JAX-jitted device) FCM across dataset sizes 20 KB -> 1 MB, and
the speedup curve with the processing-element line.

Every variant now runs from ONE entry point — ``repro.core.solver.solve``
— with the backend selecting the paper's two sides of the comparison:

* ``backend="sequential"``  — the single-core numpy comparator
  (``core/sequential.py``), the honest stand-in for the paper's C code;
* ``backend="auto"``        — the fused device fixed point (the paper's
  parallel side), on a pixel problem;
* the histogram problem     — the beyond-paper compressed variant.

``tol=-1`` pins every solve to exactly ``ITERS`` iterations for a
like-for-like per-iteration comparison (the sequential backend gets
``eps=-1``, its membership-space equivalent).

On this container the "device" is one CPU core, so absolute speedups are
NOT the paper's 674x (no 448-SP GPU here); what IS reproduced and checked
is the paper's scaling story: parallel time grows ~linearly and slowly
with N while sequential time grows linearly and steeply; iteration counts
and outputs agree.
"""
from __future__ import annotations

import numpy as np

from repro.core import solver as SV
from repro.data import phantom
from .common import emit, time_fn

SIZES_KB = [20, 40, 60, 80, 100, 200, 300, 500, 700, 1000]
ITERS = 10        # fixed iteration count for fair per-iteration timing


def _run_sequential(x, iters):
    SV.solve(SV.pixel_problem(x), backend="sequential", eps=-1.0,
             max_iters=iters)


def _run_fused(x, iters):
    SV.solve(SV.pixel_problem(x), tol=-1.0, max_iters=iters)


def _run_hist(x, iters):
    SV.solve(SV.histogram_problem(x), tol=-1.0, max_iters=iters)


def run():
    print("# table3: name,us_per_call,derived  "
          "(derived = seq_s;par_s;speedup per ITERS iterations)")
    # Warm the dispatch path once: the sequential backend is pure numpy,
    # but solve()'s problem construction touches jax, whose one-time
    # init must not land in the first (warmup=0) sequential timing.
    warm = np.zeros(64, np.float32)
    _run_sequential(warm, 1)
    _run_fused(warm, 1)
    _run_hist(warm, 1)
    rows = []
    for kb in SIZES_KB:
        img, _ = phantom.phantom_of_bytes(kb * 1024)
        x = img.astype(np.float32).ravel()
        t_seq = time_fn(lambda: _run_sequential(x, ITERS), warmup=0,
                        iters=1 if kb >= 300 else 2)
        t_par = time_fn(lambda: _run_fused(x, ITERS))
        t_hist = time_fn(lambda: _run_hist(x, ITERS))
        sp = t_seq / t_par
        sp_h = t_seq / t_hist
        rows.append((kb, t_seq, t_par, t_hist, sp, sp_h))
        emit(f"table3/{kb}KB", t_par * 1e6,
             f"seq={t_seq:.3f}s par={t_par:.4f}s hist={t_hist:.4f}s "
             f"speedup={sp:.1f}x hist_speedup={sp_h:.1f}x")
    # paper's qualitative claims, checked:
    kbs = [r[0] for r in rows]
    seqs = [r[1] for r in rows]
    pars = [r[2] for r in rows]
    # sequential time ~linear in N (paper Table 3: 57 s -> 2798 s).
    ratio_seq = seqs[-1] / seqs[0]
    ratio_n = kbs[-1] / kbs[0]
    emit("table3/seq_scaling", 0.0,
         f"seq t(1MB)/t(20KB)={ratio_seq:.1f} vs N ratio {ratio_n:.1f}")
    # parallel time grows much slower than N (paper: 0.102 s -> 4.2 s).
    ratio_par = pars[-1] / pars[0]
    emit("table3/par_scaling", 0.0,
         f"par t(1MB)/t(20KB)={ratio_par:.1f} (sublinear vs {ratio_n:.1f})")
    return rows


if __name__ == "__main__":
    run()
