"""Behavioural tests for the FCM core: invariants, equivalence of the
paper-faithful baseline with every optimized variant, and equivalence
with the literal sequential port."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import fcm as F
from repro.core import histogram as H
from repro.core import sequential as S
from repro.data import phantom


def _legacy(fn, *args, **kwargs):
    """Call a deprecated fit_* adapter, asserting (and swallowing) its
    DeprecationWarning so the -W error::DeprecationWarning lane stays
    green. These tests deliberately exercise the adapters."""
    with pytest.warns(DeprecationWarning):
        return fn(*args, **kwargs)


@pytest.fixture(scope="module")
def slice_image():
    img, labels = phantom.phantom_slice(96, 96, slice_pos=0.5, seed=3)
    return img.ravel().astype(np.float32), labels.ravel()


def _sorted_centers(v):
    return np.sort(np.asarray(v).ravel())


def test_membership_is_a_partition(slice_image):
    x, _ = slice_image
    v = jnp.asarray([10.0, 60.0, 110.0, 170.0])
    u = F.update_membership(jnp.asarray(x), v, 2.0)
    assert u.shape == (4, x.size)
    np.testing.assert_allclose(np.asarray(jnp.sum(u, axis=0)), 1.0, atol=1e-5)
    assert float(jnp.min(u)) >= 0.0 and float(jnp.max(u)) <= 1.0


def test_membership_zero_distance_onehot():
    x = jnp.asarray([50.0, 100.0, 75.0])
    v = jnp.asarray([50.0, 100.0])
    u = F.update_membership(x, v, 2.0)
    np.testing.assert_allclose(np.asarray(u[:, 0]), [1.0, 0.0], atol=1e-6)
    np.testing.assert_allclose(np.asarray(u[:, 1]), [0.0, 1.0], atol=1e-6)
    np.testing.assert_allclose(np.asarray(u[:, 2]), [0.5, 0.5], atol=1e-6)


def test_center_update_closed_form():
    x = jnp.asarray([0.0, 1.0, 10.0, 11.0])
    u = jnp.asarray([[1.0, 1.0, 0.0, 0.0], [0.0, 0.0, 1.0, 1.0]])
    v = F.update_centers(x, u, 2.0)
    np.testing.assert_allclose(np.asarray(v), [0.5, 10.5], atol=1e-6)


def test_objective_monotone_decreasing(slice_image):
    x, _ = slice_image
    x = jnp.asarray(x[:4096])
    key = jax.random.PRNGKey(0)
    u = F.random_membership(key, 4, x.shape[0])
    objs = []
    for _ in range(12):
        v = F.update_centers(x, u, 2.0)
        u = F.update_membership(x, v, 2.0)
        objs.append(float(F.objective(x, u, v, 2.0)))
    assert all(objs[i + 1] <= objs[i] * (1 + 1e-6) for i in range(len(objs) - 1))


def test_baseline_converges_and_segments(slice_image):
    x, gt = slice_image
    res = _legacy(F.fit_baseline, x, F.FCMConfig(max_iters=100))
    assert res.n_iters < 100
    assert res.final_delta < 5e-3
    # 4 clusters found, mapped by intensity rank -> decent DSC per class
    pred = phantom.match_labels_to_classes(np.asarray(res.labels), res.centers)
    dscs = phantom.dice_per_class(pred, gt)
    assert min(dscs) > 0.80, dscs


def test_baseline_max_iters_zero_returns_centers(slice_image):
    """Regression: centers used to come back None when the loop body
    never ran; now they derive from the initial membership."""
    x, _ = slice_image
    res = _legacy(F.fit_baseline, x[:2048], F.FCMConfig(max_iters=0))
    assert res.centers is not None
    assert res.centers.shape == (4,)
    assert np.isfinite(np.asarray(res.centers)).all()
    assert res.n_iters == 0 and res.final_delta == np.inf


def test_fused_matches_baseline(slice_image):
    x, _ = slice_image
    base = _legacy(F.fit_baseline, x, F.FCMConfig(max_iters=150))
    fused = _legacy(F.fit_fused, x, F.FCMConfig(max_iters=300))
    np.testing.assert_allclose(_sorted_centers(base.centers),
                               _sorted_centers(fused.centers), atol=1.0)
    pred_b = phantom.match_labels_to_classes(np.asarray(base.labels), base.centers)
    pred_f = phantom.match_labels_to_classes(np.asarray(fused.labels), fused.centers)
    agreement = (pred_b == pred_f).mean()
    assert agreement > 0.995, agreement


def test_histogram_matches_fused(slice_image):
    x, _ = slice_image
    fused = _legacy(F.fit_fused, x, F.FCMConfig(max_iters=300))
    hist = _legacy(H.fit_histogram, x, F.FCMConfig(max_iters=300))
    np.testing.assert_allclose(_sorted_centers(fused.centers),
                               _sorted_centers(hist.centers), atol=0.5)
    agreement = (np.asarray(fused.labels) == np.asarray(hist.labels)).mean()
    assert agreement > 0.999, agreement


def test_histogram_is_algebraically_exact():
    # On already-quantized data a single weighted step == a full step.
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.integers(0, 256, size=5000).astype(np.float32))
    v = jnp.asarray([30.0, 90.0, 150.0, 210.0])
    full = F.fused_center_step(x, v, 2.0)
    hist = H.intensity_histogram(x)
    vals = jnp.arange(256, dtype=jnp.float32)
    compressed = H.weighted_center_step(vals, hist, v, 2.0)
    np.testing.assert_allclose(np.asarray(full), np.asarray(compressed),
                               rtol=1e-5, atol=1e-3)


def test_sequential_python_vs_numpy():
    rng = np.random.default_rng(1)
    x = rng.integers(0, 256, size=400).astype(np.float64)
    v_py, lab_py, it_py = S.fcm_sequential_python(x, c=3, seed=7, max_iters=60)
    v_np, lab_np, it_np = S.fcm_sequential_numpy(x, c=3, seed=7, max_iters=60)
    np.testing.assert_allclose(np.sort(v_py), np.sort(v_np), atol=1e-6)
    assert (lab_py == lab_np).mean() > 0.999
    assert it_py == it_np


def test_sequential_vs_jax_baseline(slice_image):
    """Identical init => the float32 JAX pipeline must track the float64
    sequential reference step-for-step to convergence."""
    x, _ = slice_image
    x = x[:8192]
    rng = np.random.default_rng(5)
    u0 = rng.uniform(1e-3, 1.0, size=(4, x.size))
    u0 /= u0.sum(axis=0, keepdims=True)
    v_np, lab_np, it_np = S.fcm_sequential_numpy(x, c=4, max_iters=200, u0=u0)
    res = _legacy(F.fit_baseline, x, F.FCMConfig(max_iters=200), u0=u0)
    np.testing.assert_allclose(np.sort(v_np), _sorted_centers(res.centers),
                               atol=0.5)
    assert (lab_np == np.asarray(res.labels)).mean() > 0.999
    assert abs(it_np - res.n_iters) <= 2


def test_pallas_baseline_matches_jnp_baseline(slice_image):
    x, _ = slice_image
    x = x[:8192]
    a = _legacy(F.fit_baseline, x, F.FCMConfig(max_iters=40), use_pallas=False)
    b = _legacy(F.fit_baseline, x, F.FCMConfig(max_iters=40), use_pallas=True)
    assert a.n_iters == b.n_iters
    np.testing.assert_allclose(np.asarray(a.centers), np.asarray(b.centers),
                               rtol=1e-4, atol=1e-3)
    assert (np.asarray(a.labels) == np.asarray(b.labels)).mean() > 0.9999


def test_feature_dim_generalization():
    # (N, F) features (used by the MoE fuzzy router bridge).
    rng = np.random.default_rng(2)
    a = rng.normal((0, 0), 0.2, size=(100, 2))
    b = rng.normal((3, 3), 0.2, size=(100, 2))
    x = jnp.asarray(np.concatenate([a, b]), jnp.float32)
    v0 = jnp.asarray([[0.5, 0.5], [2.5, 2.5]], jnp.float32)
    res = _legacy(F.fit_fused, x, F.FCMConfig(n_clusters=2, max_iters=50), v0=v0)
    labels = np.asarray(res.labels)
    assert (labels[:100] == labels[0]).all()
    assert (labels[100:] == labels[100]).all()
    assert labels[0] != labels[100]


# ---------------------------------------------------------------------------
# Hard-assignment edge cases: ties and exact-center hits
# ---------------------------------------------------------------------------

def test_labels_from_centers_tie_is_deterministic_lowest_index():
    # 50 is equidistant from centers 40 and 60 (indices 1 and 2): the
    # argmin tie must resolve to the lowest cluster index, every time.
    x = jnp.asarray([50.0, 50.0, 50.0])
    v = jnp.asarray([0.0, 40.0, 60.0, 100.0])
    lab = np.asarray(F.labels_from_centers(x, v))
    np.testing.assert_array_equal(lab, [1, 1, 1])
    # permuting the centers moves the tie with the lower index
    v2 = jnp.asarray([0.0, 60.0, 40.0, 100.0])
    np.testing.assert_array_equal(np.asarray(F.labels_from_centers(x, v2)),
                                  [1, 1, 1])


def test_defuzzify_tie_is_deterministic_lowest_index():
    u = jnp.asarray([[0.4, 0.1], [0.4, 0.8], [0.2, 0.1]])
    np.testing.assert_array_equal(np.asarray(F.defuzzify(u)), [0, 1])


def test_defuzzify_matches_labels_from_centers_on_ties():
    # equidistant pixels: membership is symmetric, so argmax(u) and
    # argmin(d2) must pick the same (lowest) cluster.
    x = jnp.asarray([10.0, 30.0, 20.0])
    v = jnp.asarray([10.0, 30.0])
    u = F.update_membership(x, v, 2.0)
    np.testing.assert_array_equal(np.asarray(F.defuzzify(u)),
                                  np.asarray(F.labels_from_centers(x, v)))


def test_zero_distance_membership_no_nans_and_one_hot():
    # pixels exactly on a center — including duplicated centers, where
    # the mass splits evenly instead of producing NaNs.
    x = jnp.asarray([25.0, 75.0, 25.0])
    v = jnp.asarray([25.0, 75.0, 25.0])     # duplicate center at 25
    u = np.asarray(F.update_membership(x, v, 2.0))
    assert not np.isnan(u).any()
    np.testing.assert_allclose(u.sum(axis=0), 1.0, atol=1e-6)
    np.testing.assert_allclose(u[:, 0], [0.5, 0.0, 0.5], atol=1e-6)
    np.testing.assert_allclose(u[:, 1], [0.0, 1.0, 0.0], atol=1e-6)
    # hard labels stay deterministic through the tie
    np.testing.assert_array_equal(np.asarray(F.defuzzify(u)), [0, 1, 0])


def test_zero_distance_vector_features_one_hot():
    x = jnp.asarray([[1.0, 2.0], [5.0, 6.0]])
    v = jnp.asarray([[1.0, 2.0], [9.0, 9.0]])
    u = np.asarray(F.update_membership(x, v, 2.0))
    assert not np.isnan(u).any()
    np.testing.assert_allclose(u[:, 0], [1.0, 0.0], atol=1e-6)


# ---------------------------------------------------------------------------
# intensity_histogram input validation (clamping is now opt-in)
# ---------------------------------------------------------------------------

def test_histogram_rejects_normalized_float_images():
    x = jnp.asarray(np.random.default_rng(0).uniform(0, 1, 256),
                    jnp.float32)
    with pytest.raises(ValueError, match="normalized"):
        H.intensity_histogram(x)


def test_histogram_rejects_out_of_range_values():
    with pytest.raises(ValueError, match="outside the bin range"):
        H.intensity_histogram(jnp.asarray([-4.0, 10.0]))
    with pytest.raises(ValueError, match="outside the bin range"):
        H.intensity_histogram(jnp.asarray([0.0, 256.0]))


def test_histogram_clip_true_restores_clamping():
    h = np.asarray(H.intensity_histogram(jnp.asarray([-4.0, 10.0, 999.0]),
                                         clip=True))
    assert h[0] == 1 and h[10] == 1 and h[255] == 1


def test_histogram_accepts_uint8_range_and_binary_ints():
    img, _ = phantom.phantom_slice(32, 32, seed=0)
    h = np.asarray(H.intensity_histogram(
        jnp.asarray(img.ravel(), jnp.float32)))
    assert h.sum() == img.size
    # an integer-valued binary image is legitimate 8-bit data, not a
    # normalized float image
    hb = np.asarray(H.intensity_histogram(
        jnp.asarray([0, 1, 1, 0], jnp.int32)))
    assert hb[0] == 2 and hb[1] == 2
    # ... and so is the same mask cast to float (integral values): only
    # fractional values betray a [0, 1]-normalized image
    hf = np.asarray(H.intensity_histogram(
        jnp.asarray([0.0, 1.0, 1.0, 0.0], jnp.float32)))
    assert hf[0] == 2 and hf[1] == 2


def test_histogram_skips_validation_under_jit():
    # traced values are unknowable; the jitted caller keeps the old
    # clamping semantics (documented)
    fn = jax.jit(lambda x: H.intensity_histogram(x, clip=False))
    h = np.asarray(fn(jnp.asarray([0.25, 0.75])))
    assert h.sum() == 2
