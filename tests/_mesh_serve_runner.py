"""Subprocess entry for mesh-serving tests: an FCMServeEngine with its
RouteProgram launches sharded over 8 fake host devices must serve
results identical to the single-device engine — through both the sync
and async front doors — and set_mesh must never serve a stale program.
Prints MESH_SERVE_OK on success."""
import os
import sys

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

_SRC = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "src")
sys.path.insert(0, _SRC)

import numpy as np  # noqa: E402
import jax  # noqa: E402

from repro.core import fcm as F  # noqa: E402
from repro.data import phantom  # noqa: E402
from repro.serving.fcm_engine import FCMServeEngine  # noqa: E402


def _check_same(a, b):
    assert (a.labels == b.labels).all()
    np.testing.assert_array_equal(a.centers, b.centers)
    assert a.n_iters == b.n_iters


def main():
    assert len(jax.devices()) == 8, jax.devices()
    kwargs = {}
    if hasattr(jax.sharding, "AxisType"):
        kwargs["axis_types"] = (jax.sharding.AxisType.Auto,)
    mesh = jax.make_mesh((8,), ("data",), **kwargs)
    cfg = F.FCMConfig(max_iters=300)
    # bucket 8 divides the mesh; bucket 1 exercises the single-device
    # fallback inside a meshed engine (mesh does not divide the bucket).
    imgs = [phantom.phantom_slice(32, 32, noise=4.0 + (i % 3),
                                  seed=500 + i)[0] for i in range(11)]

    single = FCMServeEngine(cfg, batch_sizes=(1, 8), cache_size=0)
    meshed = FCMServeEngine(cfg, batch_sizes=(1, 8), cache_size=0,
                            mesh=mesh, max_wait_ms=10_000.0)

    # Sync parity: same buckets, mesh-sharded vs single-device launch.
    ref = single.segment(imgs)
    got = meshed.segment(imgs)
    for a, b in zip(got, ref):
        _check_same(a, b)

    # Async parity through the mesh: futures resolve with the same
    # results the single-device sync path produced.
    futs = [meshed.submit_async(im) for im in imgs]
    meshed.drain()
    for f, b in zip(futs, ref):
        _check_same(f.result(timeout=30), b)

    # set_mesh(None) detaches: programs recompile (new generation) and
    # keep serving identical results.
    meshed.set_mesh(None)
    for a, b in zip(meshed.segment(imgs), ref):
        _check_same(a, b)

    # A one-device mesh is the degenerate single-device path.
    one = jax.make_mesh((1,), ("data",),
                        **({"axis_types": (jax.sharding.AxisType.Auto,)}
                           if hasattr(jax.sharding, "AxisType") else {}))
    meshed.set_mesh(one)
    for a, b in zip(meshed.segment(imgs), ref):
        _check_same(a, b)

    single.shutdown()
    meshed.shutdown()
    print("MESH_SERVE_OK")


if __name__ == "__main__":
    main()
