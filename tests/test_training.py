"""Training substrate: optimizer math, loss decreases on a tiny model,
microbatch accumulation equivalence, checkpoint roundtrip + crash
consistency, async checkpointer, grad compression numerics, pipeline
determinism, straggler watchdog, elastic mesh planning."""
import os

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro import configs
from repro.data import pipeline
from repro.models import lm
from repro.training import checkpoint as ckpt
from repro.training import elastic, grad_compress, optimizer as opt
from repro.training import train_loop as tl


@pytest.fixture(scope="module")
def tiny():
    cfg = configs.get_config("llama3.2-1b").reduced()
    state = tl.init_state(jax.random.PRNGKey(0), cfg)
    return cfg, state


def test_schedule_warmup_and_cosine():
    c = opt.OptimizerConfig(lr=1.0, warmup_steps=10, total_steps=110,
                            min_lr_frac=0.1)
    assert float(opt.schedule(jnp.asarray(0), c)) == 0.0
    assert abs(float(opt.schedule(jnp.asarray(5), c)) - 0.5) < 1e-6
    assert abs(float(opt.schedule(jnp.asarray(10), c)) - 1.0) < 1e-6
    assert abs(float(opt.schedule(jnp.asarray(110), c)) - 0.1) < 1e-6


def test_adamw_first_step_is_lr_sized():
    p = {"w": jnp.ones((4, 4))}
    g = {"w": jnp.full((4, 4), 0.5)}
    st = opt.init_opt_state(p)
    c = opt.OptimizerConfig(lr=1e-2, warmup_steps=0, weight_decay=0.0,
                            grad_clip=1e9)
    newp, _, m = opt.adamw_step(p, g, st, jnp.asarray(0), c)
    # bias-corrected first update = lr * sign(g) (approx)
    np.testing.assert_allclose(np.asarray(newp["w"]),
                               1.0 - 1e-2, rtol=1e-3)
    assert float(m["grad_norm"]) > 0


def test_loss_decreases_tiny_train(tiny):
    cfg, state = tiny
    tcfg = tl.TrainConfig(optimizer=opt.OptimizerConfig(
        lr=3e-3, warmup_steps=5, total_steps=60))
    step = jax.jit(tl.make_train_step(cfg, tcfg))
    shape = configs.ShapeConfig("t", "train", 32, 8)
    losses = []
    for i in range(30):
        batch = {k: jnp.asarray(v)
                 for k, v in pipeline.make_batch(cfg, shape, i).items()}
        state, metrics = step(state, batch)
        losses.append(float(metrics["loss"]))
    assert losses[-1] < losses[0] - 0.5, losses[::6]
    assert int(state["step"]) == 30


def test_microbatch_equivalence(tiny):
    cfg, state = tiny
    import dataclasses
    cfg4 = dataclasses.replace(cfg, microbatches=4)
    shape = configs.ShapeConfig("t", "train", 16, 8)
    batch = {k: jnp.asarray(v)
             for k, v in pipeline.make_batch(cfg, shape, 0).items()}
    g1, m1 = tl._microbatch_grads(state["params"], batch, cfg,
                                  tl.TrainConfig())
    g4, m4 = tl._microbatch_grads(state["params"], batch, cfg4,
                                  tl.TrainConfig())
    flat1 = jax.tree_util.tree_leaves(g1)
    flat4 = jax.tree_util.tree_leaves(g4)
    for a, b in zip(flat1, flat4):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-4, atol=2e-5)


def test_checkpoint_roundtrip(tmp_path, tiny):
    cfg, state = tiny
    d = str(tmp_path / "ckpt")
    ckpt.save_checkpoint(d, state, 7, extra={"arch": cfg.name})
    assert ckpt.latest_step(d) == 7
    restored, manifest = ckpt.load_checkpoint(d, state)
    assert manifest["step"] == 7
    assert manifest["extra"]["arch"] == cfg.name
    for a, b in zip(jax.tree_util.tree_leaves(state),
                    jax.tree_util.tree_leaves(restored)):
        assert a.dtype == b.dtype
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_crash_consistency(tmp_path, tiny):
    """A half-written newer snapshot must not shadow the good one."""
    cfg, state = tiny
    d = str(tmp_path / "ckpt")
    ckpt.save_checkpoint(d, state, 1)
    # simulate a crash mid-save of step 2: stray .tmp dir
    os.makedirs(os.path.join(d, "step_00000002.tmp"))
    assert ckpt.latest_step(d) == 1
    restored, m = ckpt.load_checkpoint(d, state)
    assert m["step"] == 1


def test_checkpoint_gc(tmp_path, tiny):
    cfg, state = tiny
    small = {"x": jnp.zeros((2,))}
    d = str(tmp_path / "ckpt")
    for s in range(6):
        ckpt.save_checkpoint(d, small, s)
    ckpt.gc_old_checkpoints(d, keep=2)
    steps = sorted(n for n in os.listdir(d) if n.startswith("step_"))
    assert steps == ["step_00000004", "step_00000005"]


def test_async_checkpointer(tmp_path):
    d = str(tmp_path / "ckpt")
    ac = ckpt.AsyncCheckpointer(d, keep=2)
    tree = {"a": jnp.arange(5.0), "b": {"c": jnp.ones((3, 3))}}
    for s in (1, 2, 3):
        ac.save(tree, s)
    ac.wait()
    assert ac.last_error is None
    assert ckpt.latest_step(d) == 3


def test_int8_quantization_error_bound():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(0, 3, size=(128, 64)), jnp.float32)
    q, scale = grad_compress.quantize_int8(x)
    back = grad_compress.dequantize_int8(q, scale)
    assert q.dtype == jnp.int8
    assert float(jnp.max(jnp.abs(back - x))) <= float(scale) * 0.5 + 1e-6


def test_pipeline_determinism_and_sharding():
    cfg = configs.get_config("llama3.2-1b").reduced()
    shape = configs.ShapeConfig("t", "train", 16, 8)
    a = pipeline.make_batch(cfg, shape, step=3, host=0, n_hosts=2)
    b = pipeline.make_batch(cfg, shape, step=3, host=0, n_hosts=2)
    c = pipeline.make_batch(cfg, shape, step=3, host=1, n_hosts=2)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    assert (a["tokens"] != c["tokens"]).any()
    assert a["tokens"].shape == (4, 16)
    assert (a["tokens"] >= 0).all() and (a["tokens"] < cfg.vocab_size).all()
    np.testing.assert_array_equal(a["labels"][:, :-1], a["tokens"][:, 1:])


def test_step_timer_straggler_detection():
    import time
    flags = []
    t = elastic.StepTimer(window=16, threshold=3.0, consecutive_limit=3,
                          on_straggler=lambda dt, med: flags.append(dt))
    for _ in range(8):
        t.start(); time.sleep(0.005); t.stop()
    rebalance = False
    for _ in range(3):
        t.start(); time.sleep(0.05)
        rebalance = t.stop()
    assert len(flags) >= 3        # CPU jitter may flag a warmup step too
    assert rebalance


def test_plan_mesh_single_device():
    mesh = elastic.plan_mesh(1)
    assert mesh.devices.size == 1
    assert mesh.axis_names == ("data", "model")
