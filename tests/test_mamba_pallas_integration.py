"""Mamba layer with the Pallas selective-scan path (fwd + recompute VJP)
must match the lax.scan path, values and gradients."""
import dataclasses

import numpy as np
import jax
import jax.numpy as jnp

from repro import configs
from repro.models import ssm


def _cfg(pallas):
    base = configs.get_config("jamba-v0.1-52b").reduced()
    return dataclasses.replace(base, mamba_pallas=pallas)


def test_forward_and_grads_match_scan():
    cfg_s, cfg_p = _cfg(False), _cfg(True)
    p = ssm.init_mamba(jax.random.PRNGKey(0), cfg_s)
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(0, 1, (2, 64, cfg_s.d_model)), jnp.float32)

    y_s = ssm.mamba_forward(p, x, cfg_s)
    y_p = ssm.mamba_forward(p, x, cfg_p)
    np.testing.assert_allclose(np.asarray(y_s), np.asarray(y_p),
                               rtol=2e-4, atol=2e-4)

    g_s = jax.grad(lambda q: jnp.sum(ssm.mamba_forward(q, x, cfg_s) ** 2))(p)
    g_p = jax.grad(lambda q: jnp.sum(ssm.mamba_forward(q, x, cfg_p) ** 2))(p)
    for a, b in zip(jax.tree_util.tree_leaves(g_s),
                    jax.tree_util.tree_leaves(g_p)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=5e-3, atol=5e-3)
