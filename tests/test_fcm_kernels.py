"""Per-kernel validation: Pallas (interpret=True on CPU) vs ref.py jnp
oracles, swept over shapes, cluster counts, fuzziness and dtypes."""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.kernels import ops, ref

SHAPES = [96, 8192, 8192 + 17, 40000]          # incl. non-multiple-of-tile
CLUSTERS = [2, 4, 7]
FUZZ = [2.0, 1.6]
DTYPES = [jnp.float32, jnp.bfloat16]


def _data(n, c, dtype, seed=0):
    rng = np.random.default_rng(seed)
    x = rng.integers(0, 256, size=n).astype(np.float32)
    v = np.sort(rng.uniform(5, 250, size=c)).astype(np.float32)
    return jnp.asarray(x, dtype), jnp.asarray(v, jnp.float32)


@pytest.mark.parametrize("n", SHAPES)
@pytest.mark.parametrize("c", CLUSTERS)
def test_membership_kernel_shapes(n, c):
    x, v = _data(n, c, jnp.float32)
    got = ops.membership(x, v, 2.0, interpret=True)
    want = ref.membership_ref(x, v, 2.0)
    assert got.shape == (c, n)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("m", FUZZ)
@pytest.mark.parametrize("dtype", DTYPES)
def test_membership_kernel_dtypes_fuzz(m, dtype):
    x, v = _data(8192, 4, dtype, seed=1)
    got = ops.membership(x, v, m, interpret=True)
    want = ref.membership_ref(x, v, m)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


def test_membership_kernel_zero_distance():
    x = jnp.asarray(np.full(300, 77.0, np.float32))
    v = jnp.asarray([77.0, 150.0])
    got = ops.membership(x, v, 2.0, interpret=True)
    np.testing.assert_allclose(np.asarray(got[0]), 1.0, atol=1e-6)
    np.testing.assert_allclose(np.asarray(got[1]), 0.0, atol=1e-6)


@pytest.mark.parametrize("n", SHAPES)
@pytest.mark.parametrize("m", FUZZ)
def test_center_partials_kernel(n, m):
    x, v = _data(n, 4, jnp.float32, seed=2)
    u = ref.membership_ref(x, v, m)
    num, den = ops.center_partials(x, u, m, interpret=True)
    wnum, wden = ref.center_partials_ref(x, u, m)
    assert num.shape == (4, 1)
    np.testing.assert_allclose(np.asarray(num[:, 0]), np.asarray(wnum),
                               rtol=1e-4)
    np.testing.assert_allclose(np.asarray(den), np.asarray(wden), rtol=1e-4)


@pytest.mark.parametrize("n", SHAPES)
@pytest.mark.parametrize("c", CLUSTERS)
@pytest.mark.parametrize("m", FUZZ)
def test_fused_step_kernel(n, c, m):
    x, v = _data(n, c, jnp.float32, seed=3)
    got = ops.fused_step(x, v, m, interpret=True)
    want = ref.fused_step_ref(x, v, m)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-3)


@pytest.mark.parametrize("block_rows", [8, 32, 64])
def test_block_shape_sweep(block_rows):
    x, v = _data(50000, 4, jnp.float32, seed=4)
    got = ops.fused_step(x, v, 2.0, block_rows=block_rows, interpret=True)
    want = ref.fused_step_ref(x, v, 2.0)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-3)


def test_fused_iteration_fixed_point_matches_two_stage():
    """The fused kernel must equal membership-kernel -> partials-kernel."""
    x, v = _data(8192, 4, jnp.float32, seed=5)
    u = ops.membership(x, v, 2.0, interpret=True)
    num2, den2 = ops.center_partials(x, u, 2.0, interpret=True)
    v_two = np.asarray(num2[:, 0] / jnp.maximum(den2, 1e-12))
    v_fused = np.asarray(ops.fused_step(x, v, 2.0, interpret=True))
    np.testing.assert_allclose(v_fused, v_two, rtol=1e-4, atol=1e-3)


# ---------------------------------------------------------------------------
# One-pass binning kernel (serving ingest on-chip)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n", [96, 1024, 8192 + 17, 40000])
def test_histogram_bin_kernel_matches_bincount(n):
    """Parity with jnp.bincount / intensity_histogram on ragged sizes
    (incl. non-multiple-of-128 => zero-weight padding)."""
    from repro.core.histogram import intensity_histogram
    rng = np.random.default_rng(n)
    x = rng.integers(0, 256, n).astype(np.float32)
    got = np.asarray(ops.histogram_counts(jnp.asarray(x), interpret=True))
    want_np = np.bincount(x.astype(np.int64), minlength=256
                          ).astype(np.float32)
    want_ref = np.asarray(intensity_histogram(jnp.asarray(x)))
    np.testing.assert_array_equal(got, want_np)
    np.testing.assert_array_equal(got, want_ref)
    assert got.sum() == n


def test_histogram_bin_kernel_all_one_value():
    x = jnp.full((3000,), 137.0, jnp.float32)
    got = np.asarray(ops.histogram_counts(x, interpret=True))
    assert got[137] == 3000 and got.sum() == 3000


def test_histogram_bin_kernel_empty_after_padding_tiles():
    """A 1-pixel payload: every tile but one lane is padding — the
    validity weights must keep bin 0 (where padded pixels land) clean."""
    x = jnp.asarray([200.0])
    got = np.asarray(ops.histogram_counts(x, interpret=True))
    assert got[200] == 1 and got.sum() == 1
    assert got[0] == 0


def test_histogram_bin_kernel_batched_lanes_independent():
    rng = np.random.default_rng(0)
    px = rng.integers(0, 256, (3, 777)).astype(np.int32)
    got = np.asarray(ops.histogram_counts(jnp.asarray(px), interpret=True))
    for i in range(3):
        np.testing.assert_array_equal(
            got[i], np.bincount(px[i], minlength=256).astype(np.float32))


def test_histogram_bin_kernel_clamps_out_of_range():
    """Same clamp semantics as intensity_histogram(clip=True)."""
    x = jnp.asarray([-5.0, 0.0, 255.0, 300.0])
    got = np.asarray(ops.histogram_counts(x, interpret=True))
    assert got[0] == 2 and got[255] == 2


def test_histogram_bin_kernel_weighted_matches_manual():
    """The weighted kernel body (validity/count weights) against a
    manual weighted bincount; histogram_counts itself rides the
    unit-weight fast path, so the weighted face is pinned here."""
    from repro.kernels import histogram_bin as KB
    rng = np.random.default_rng(3)
    n = 2000
    x = rng.integers(0, 256, n).astype(np.float32)
    w = rng.uniform(0, 3, n).astype(np.float32)
    x3, w3 = ops.tile_pixels_batched(jnp.asarray(x)[None], 8)
    w3 = w3 * jnp.pad(jnp.asarray(w), (0, w3.size - n)).reshape(w3.shape)
    got = np.asarray(KB.histogram_bin_pallas(x3, w3, 256, 8,
                                             interpret=True))[0]
    want = np.zeros(256, np.float32)
    np.add.at(want, x.astype(np.int64), w)
    np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-4)


def test_bin_registry_dispatch():
    assert ops.select_step("bin", platform="cpu").name == "reference"
    assert ops.select_step("bin", platform="tpu").name == "pallas"
    ref_counts = ops.build_step("bin", "reference", n_bins=256)
    x = jnp.asarray(np.random.default_rng(1).integers(0, 256, 500),
                    jnp.float32)
    np.testing.assert_array_equal(
        np.asarray(ref_counts(x)),
        np.asarray(ops.histogram_counts(x, interpret=True)))


# ---------------------------------------------------------------------------
# Fused defuzzify (argmin-label) kernel
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n", [96, 8192 + 17])
@pytest.mark.parametrize("c", [2, 4, 7])
def test_defuzzify_kernel_matches_labels_from_centers(n, c):
    from repro.core import fcm as F
    x, v = _data(n, c, jnp.float32, seed=6)
    got = np.asarray(ops.defuzzify_labels_batched(
        x[None], v[None], impl="pallas", interpret=True))[0]
    want = np.asarray(F.labels_from_centers(x, v))
    np.testing.assert_array_equal(got, want)


def test_defuzzify_kernel_tie_breaks_to_lowest_index():
    from repro.core import fcm as F
    x = jnp.full((300,), 100.0, jnp.float32)
    v = jnp.asarray([50.0, 150.0, 100.0])     # ties between 0/1; 2 exact
    got = np.asarray(ops.defuzzify_labels_batched(
        x[None], v[None], impl="pallas", interpret=True))[0]
    np.testing.assert_array_equal(got,
                                  np.asarray(F.labels_from_centers(x, v)))
    assert (got == 2).all()


def test_labels_registry_dispatch():
    assert ops.select_step("labels", platform="cpu").name == "reference"
    assert ops.select_step("labels", platform="tpu").name == "pallas"
    # vector features are reference-only (the kernel is scalar)
    assert ops.select_step("labels", platform="tpu", n_feat=3
                           ).name == "reference"
