"""Per-kernel validation: Pallas (interpret=True on CPU) vs ref.py jnp
oracles, swept over shapes, cluster counts, fuzziness and dtypes."""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.kernels import ops, ref

SHAPES = [96, 8192, 8192 + 17, 40000]          # incl. non-multiple-of-tile
CLUSTERS = [2, 4, 7]
FUZZ = [2.0, 1.6]
DTYPES = [jnp.float32, jnp.bfloat16]


def _data(n, c, dtype, seed=0):
    rng = np.random.default_rng(seed)
    x = rng.integers(0, 256, size=n).astype(np.float32)
    v = np.sort(rng.uniform(5, 250, size=c)).astype(np.float32)
    return jnp.asarray(x, dtype), jnp.asarray(v, jnp.float32)


@pytest.mark.parametrize("n", SHAPES)
@pytest.mark.parametrize("c", CLUSTERS)
def test_membership_kernel_shapes(n, c):
    x, v = _data(n, c, jnp.float32)
    got = ops.membership(x, v, 2.0, interpret=True)
    want = ref.membership_ref(x, v, 2.0)
    assert got.shape == (c, n)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("m", FUZZ)
@pytest.mark.parametrize("dtype", DTYPES)
def test_membership_kernel_dtypes_fuzz(m, dtype):
    x, v = _data(8192, 4, dtype, seed=1)
    got = ops.membership(x, v, m, interpret=True)
    want = ref.membership_ref(x, v, m)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


def test_membership_kernel_zero_distance():
    x = jnp.asarray(np.full(300, 77.0, np.float32))
    v = jnp.asarray([77.0, 150.0])
    got = ops.membership(x, v, 2.0, interpret=True)
    np.testing.assert_allclose(np.asarray(got[0]), 1.0, atol=1e-6)
    np.testing.assert_allclose(np.asarray(got[1]), 0.0, atol=1e-6)


@pytest.mark.parametrize("n", SHAPES)
@pytest.mark.parametrize("m", FUZZ)
def test_center_partials_kernel(n, m):
    x, v = _data(n, 4, jnp.float32, seed=2)
    u = ref.membership_ref(x, v, m)
    num, den = ops.center_partials(x, u, m, interpret=True)
    wnum, wden = ref.center_partials_ref(x, u, m)
    assert num.shape == (4, 1)
    np.testing.assert_allclose(np.asarray(num[:, 0]), np.asarray(wnum),
                               rtol=1e-4)
    np.testing.assert_allclose(np.asarray(den), np.asarray(wden), rtol=1e-4)


@pytest.mark.parametrize("n", SHAPES)
@pytest.mark.parametrize("c", CLUSTERS)
@pytest.mark.parametrize("m", FUZZ)
def test_fused_step_kernel(n, c, m):
    x, v = _data(n, c, jnp.float32, seed=3)
    got = ops.fused_step(x, v, m, interpret=True)
    want = ref.fused_step_ref(x, v, m)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-3)


@pytest.mark.parametrize("block_rows", [8, 32, 64])
def test_block_shape_sweep(block_rows):
    x, v = _data(50000, 4, jnp.float32, seed=4)
    got = ops.fused_step(x, v, 2.0, block_rows=block_rows, interpret=True)
    want = ref.fused_step_ref(x, v, 2.0)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-3)


def test_fused_iteration_fixed_point_matches_two_stage():
    """The fused kernel must equal membership-kernel -> partials-kernel."""
    x, v = _data(8192, 4, jnp.float32, seed=5)
    u = ops.membership(x, v, 2.0, interpret=True)
    num2, den2 = ops.center_partials(x, u, 2.0, interpret=True)
    v_two = np.asarray(num2[:, 0] / jnp.maximum(den2, 1e-12))
    v_fused = np.asarray(ops.fused_step(x, v, 2.0, interpret=True))
    np.testing.assert_allclose(v_fused, v_two, rtol=1e-4, atol=1e-3)
