import numpy as np

from repro.data import phantom


def test_phantom_has_all_classes_and_right_stats():
    img, labels = phantom.phantom_slice(128, 128, seed=0)
    assert img.shape == (128, 128) and img.dtype == np.uint8
    assert set(np.unique(labels)) == {0, 1, 2, 3}
    for k in range(4):
        mean_k = img[labels == k].mean()
        assert abs(mean_k - phantom.CLASS_MEANS[k]) < 8.0, (k, mean_k)


def test_phantom_of_bytes_sizes():
    for nbytes in [20 * 1024, 100 * 1024]:
        img, lab = phantom.phantom_of_bytes(nbytes)
        assert img.size == nbytes // 256 * 256
        assert img.size == lab.size


def test_dice_metric():
    a = np.zeros((10, 10), bool)
    a[:5] = True
    assert phantom.dice(a, a) == 1.0
    assert phantom.dice(a, ~a) == 0.0
    b = np.zeros((10, 10), bool)
    b[:5, :5] = True
    assert abs(phantom.dice(a, b) - 2 * 25 / (50 + 25)) < 1e-9


def test_match_labels_to_classes():
    labels = np.array([0, 1, 2, 3])
    centers = np.array([160.0, 0.0, 100.0, 50.0])  # ranks: 3,0,2,1
    out = phantom.match_labels_to_classes(labels, centers)
    assert list(out) == [3, 0, 2, 1]
