"""Async admission: futures, deadlines, batch-formation policy,
shutdown semantics — and bitwise parity between the async front door
and the synchronous submit/flush path (same RouteProgram, same math)."""
import threading
import time

import numpy as np
import pytest

from repro.core import fcm as F
from repro.data import phantom
from repro.serving.admission import (DeadlineExceeded, EngineShutdown,
                                     SegmentationFuture)
from repro.serving.fcm_engine import FCMServeEngine

CFG = F.FCMConfig(max_iters=300)


def _imgs(n, size=24):
    return [phantom.phantom_slice(size, size, noise=4.0 + (i % 3),
                                  seed=100 + i)[0] for i in range(n)]


def _engine(**kw):
    kw.setdefault("cache_size", 0)
    kw.setdefault("batch_sizes", (1, 4))
    return FCMServeEngine(CFG, **kw)


# -- SegmentationFuture ------------------------------------------------------

def test_future_resolves_exactly_once():
    fut = SegmentationFuture(0, "histogram")
    assert not fut.done() and fut.latency_s is None
    fut.set_result("r")
    assert fut.done() and fut.result() == "r"
    assert fut.latency_s is not None and fut.latency_s >= 0
    with pytest.raises(RuntimeError, match="resolved twice"):
        fut.set_result("again")
    with pytest.raises(RuntimeError, match="resolved twice"):
        fut.set_exception(ValueError("nope"))


def test_future_timeout_and_exception():
    fut = SegmentationFuture(1, "histogram")
    with pytest.raises(TimeoutError):
        fut.result(timeout=0.01)
    fut.set_exception(ValueError("boom"))
    with pytest.raises(ValueError, match="boom"):
        fut.result()
    assert isinstance(fut.exception(), ValueError)


# -- drain / parity ----------------------------------------------------------

def test_zero_request_drain_is_noop():
    eng = _engine()
    assert eng.drain() == []
    assert eng.drain() == []          # repeatable
    eng.shutdown()


def test_async_bitwise_identical_to_sync():
    imgs = _imgs(6)
    sync_eng = _engine()
    for im in imgs:
        sync_eng.submit(im)
    sync_res = {r.request_id: r for r in sync_eng.flush()}
    sync_eng.shutdown()

    async_eng = _engine(max_wait_ms=10_000.0)   # only drain() flushes
    futs = [async_eng.submit_async(im) for im in imgs]
    async_eng.drain()
    for i, fut in enumerate(futs):
        a, s = fut.result(timeout=5), sync_res[i]
        assert (a.labels == s.labels).all()
        np.testing.assert_array_equal(a.centers, s.centers)
        assert a.n_iters == s.n_iters
    async_eng.shutdown()


def test_exactly_once_with_duplicates_and_cache_hits():
    # Duplicate payloads dedup within a flush and hit the LRU across
    # flushes; every future must still resolve exactly once, with the
    # representative's centers.
    eng = _engine(cache_size=64, max_wait_ms=10_000.0)
    img = _imgs(1)[0]
    futs = [eng.submit_async(img) for _ in range(3)]
    eng.drain()
    first = [f.result(timeout=5) for f in futs]
    assert all(f.done() for f in futs)
    # Across-flush cache hit: new request, same histogram.
    fut2 = eng.submit_async(img.copy())
    eng.drain()
    again = fut2.result(timeout=5)
    assert again.cache_hit
    np.testing.assert_array_equal(again.centers, first[0].centers)
    assert (again.labels == first[0].labels).all()
    eng.shutdown()


# -- deadlines ---------------------------------------------------------------

def test_expired_deadline_at_submit_consumes_nothing():
    eng = _engine()
    before = eng._next_id
    fut = eng.submit_async(_imgs(1)[0], deadline=0.0)
    assert fut.done()
    with pytest.raises(DeadlineExceeded):
        fut.result()
    assert eng._next_id == before             # no id, no queue slot
    assert eng.drain() == []
    assert eng._route_counter("deadline_expired", "histogram").value == 1
    eng.shutdown()


def test_deadline_expired_while_queued():
    eng = _engine(max_wait_ms=10_000.0)
    imgs = _imgs(2)
    doomed = eng.submit_async(imgs[0], deadline=0.005)
    ok = eng.submit_async(imgs[1])
    time.sleep(0.02)
    eng.drain()
    with pytest.raises(DeadlineExceeded):
        doomed.result(timeout=5)
    res = ok.result(timeout=5)                # batchmate unaffected
    assert res.labels.shape == imgs[1].shape
    eng.shutdown()


def test_deadline_ordering_most_urgent_first():
    # _admit_order sorts a drained queue by absolute deadline so tight
    # deadlines land in the earliest chunk of their bucket group.
    eng = _engine(max_wait_ms=10_000.0)
    imgs = _imgs(3)
    loose = eng.submit_async(imgs[0], deadline=60.0)
    none = eng.submit_async(imgs[1])
    tight = eng.submit_async(imgs[2], deadline=5.0)
    with eng._lock:
        pend = list(eng._queues["histogram"])
    from repro.serving.fcm_engine import ROUTES
    ordered = eng._admit_order(ROUTES["histogram"], pend)
    assert [p.request_id for p in ordered] == [
        tight.request_id, loose.request_id, none.request_id]
    # The reordered queue still resolves everyone (ids stay attached).
    eng.drain()
    for f in (loose, none, tight):
        assert f.result(timeout=5).labels.shape == imgs[0].shape
    eng.shutdown()


# -- background flusher ------------------------------------------------------

def test_flusher_is_lazy_and_sync_api_never_starts_it():
    eng = _engine()
    eng.submit(_imgs(1)[0])
    eng.flush()
    assert eng._flusher is None
    eng.submit_async(_imgs(1)[0])
    assert eng._flusher is not None and eng._flusher.is_alive()
    eng.shutdown()


def test_max_wait_flush_without_explicit_drain():
    eng = _engine(max_wait_ms=20.0)
    fut = eng.submit_async(_imgs(1)[0])
    res = fut.result(timeout=10)              # background flusher only
    assert res.labels.shape == (24, 24)
    assert fut.latency_s >= 0.015             # waited out the window
    eng.shutdown()


def test_target_shape_triggers_before_window():
    # A full target-shape group flushes immediately, long before the
    # (deliberately huge) admission window.
    eng = _engine(batch_sizes=(1, 2), max_wait_ms=60_000.0)
    imgs = _imgs(2)
    futs = [eng.submit_async(im) for im in imgs]
    for f in futs:
        assert f.result(timeout=10).labels.shape == imgs[0].shape
    assert max(f.latency_s for f in futs) < 30.0
    eng.shutdown()


def test_concurrent_submitters_all_resolve():
    eng = _engine(batch_sizes=(1, 8), max_wait_ms=15.0)
    imgs = _imgs(12)
    out = {}

    def worker(i):
        out[i] = eng.submit_async(imgs[i]).result(timeout=30)

    threads = [threading.Thread(target=worker, args=(i,))
               for i in range(len(imgs))]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert sorted(out) == list(range(12))
    for i, r in out.items():
        assert r.labels.shape == imgs[i].shape
    eng.shutdown()


# -- shutdown ----------------------------------------------------------------

def test_shutdown_drains_in_flight_futures():
    eng = _engine(max_wait_ms=10_000.0)
    futs = [eng.submit_async(im) for im in _imgs(3)]
    eng.shutdown()                            # drain=True default
    for f in futs:
        assert f.result(timeout=5).labels.shape == (24, 24)
    with pytest.raises(EngineShutdown):
        eng.submit_async(_imgs(1)[0])
    with pytest.raises(EngineShutdown):
        eng.submit(_imgs(1)[0])
    eng.shutdown()                            # idempotent


def test_concurrent_shutdown_and_erroring_route_exactly_once():
    # Regression: a route whose solve raises, racing shutdown(drain=True)
    # — both paths try to resolve the same futures. Every future must
    # resolve exactly once (typed error or EngineShutdown), with no
    # "resolved twice" RuntimeError escaping either resolver and no
    # future left pending.
    from repro import faults as FI
    from repro.core import solver as SV

    plan = FI.FaultPlan(seed=0, specs=(
        FI.FaultSpec(site="launch", kind="error", times=None),))
    eng = _engine(faults=plan, retries=0, breaker_threshold=10**9,
                  max_wait_ms=10_000.0)
    futs = [eng.submit_async(im) for im in _imgs(4)]

    def boom(*a, **k):
        raise ValueError("solver exploded")

    orig = SV.solve_batched
    SV.solve_batched = boom     # degraded fallback path raises too
    errs = []

    def flusher():
        try:
            eng.flush(raise_errors=False)
        except BaseException as e:  # noqa: BLE001
            errs.append(e)

    try:
        t = threading.Thread(target=flusher)
        t.start()
        eng.shutdown(drain=True)
        t.join()
    finally:
        SV.solve_batched = orig
    assert errs == []                       # no "resolved twice" escaped
    for f in futs:
        assert f.done()
        assert isinstance(f.exception(), (ValueError, EngineShutdown))
    assert eng.stats()["pending_futures"] == 0
    eng.shutdown()


def test_shutdown_drop_fails_queued_futures():
    eng = _engine(max_wait_ms=10_000.0)
    futs = [eng.submit_async(im) for im in _imgs(2)]
    eng.shutdown(drain=False)
    for f in futs:
        with pytest.raises(EngineShutdown):
            f.result(timeout=5)
    assert eng.closed
    assert eng.metrics.gauge("queue.depth").value == 0
