"""Trajectory ledger: known-trajectory reproduction over the committed
BENCH records, per-metric diff policies (improve / regress / missing /
tiny-vs-full / absolute bounds), and baseline auto-resolution."""
import copy
import json
import os

import pytest

from repro.analysis import trajectory

OUT_DIR = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "benchmarks", "out")

needs_ledger = pytest.mark.skipif(
    not trajectory.ledger_paths(OUT_DIR),
    reason="no committed BENCH_pr*.json ledger")


# ---------------------------------------------------------------------------
# The committed ledger reproduces the known trajectory
# ---------------------------------------------------------------------------

@needs_ledger
def test_series_reproduces_known_engine_overhead_trajectory():
    """The repo's headline perf story — engine overhead 26x at PR 4
    down to ~2.9x at PR 5 — must fall out of the normalized series,
    including the PR 4 record that predates the explicit overhead key
    (the extractor derives it from engine_s / batched_s)."""
    ss = trajectory.series(trajectory.load_ledger(OUT_DIR))
    pts = dict(ss["engine_overhead_b64"])
    assert pts[4] == pytest.approx(26.0, rel=0.05)
    assert pts[5] == pytest.approx(2.9, rel=0.05)
    assert all(v < 6.0 for pr, v in pts.items()
               if pr >= 5 and v is not None)


@needs_ledger
def test_series_reproduces_known_spatial_speedup_trajectory():
    ss = trajectory.series(trajectory.load_ledger(OUT_DIR))
    pts = dict(ss["spatial_batched_speedup"])
    assert pts[5] == pytest.approx(6.9, rel=0.05)
    assert pts[7] == pytest.approx(9.7, rel=0.05)
    assert all(v >= 5.0 for v in pts.values() if v is not None)


@needs_ledger
def test_series_keeps_gaps_for_pre_metric_records():
    """tracing_overhead_ratio only exists from PR 6 on; older records
    contribute None instead of being dropped from the series."""
    ss = trajectory.series(trajectory.load_ledger(OUT_DIR))
    pts = dict(ss["tracing_overhead_ratio"])
    if 4 in pts:
        assert pts[4] is None
    assert any(v is not None for pr, v in pts.items() if pr >= 6)


@needs_ledger
def test_diff_of_adjacent_committed_records_passes():
    ledger = trajectory.load_ledger(OUT_DIR)
    if len(ledger) < 2:
        pytest.skip("ledger has a single record")
    (_, base), (_, cur) = ledger[-2], ledger[-1]
    result = trajectory.diff(base, cur)
    assert result.ok, result.report()
    assert len(result.verdicts) == len(trajectory.METRICS)


# ---------------------------------------------------------------------------
# diff policies on synthetic records
# ---------------------------------------------------------------------------

def _bench(pr=7, tiny=False, engine_s=0.006, batched_s=0.0015,
           tracing=0.95, parity=0.0, spatial_speedup=9.0):
    return {
        "pr": pr, "tiny": tiny,
        "batched_throughput": {
            "histogram": {
                "64": {"engine_s": engine_s, "batched_s": batched_s,
                       "engine_overhead_vs_batched":
                           engine_s / batched_s,
                       "speedup_batched_vs_seq": 200.0},
                "tracing_overhead_ratio": tracing,
                "convergence": {"mean_iters": 3.7},
            },
            "spatial": {"engine_s": 0.0012, "batched_s": 0.0008,
                        "engine_overhead_vs_batched": 1.5,
                        "speedup_batched_vs_one_at_a_time":
                            spatial_speedup},
        },
        "spatial_fcm": {"levels": [
            {"fits": {"plain": {"dsc": {"WM": 0.1}},
                      "spatial_ref": {"dsc": {"WM": 0.93}}}}]},
        "superpixel_fcm": {"speedup_fit": 30.0,
                           "dsc_parity_max_delta": parity},
    }


def test_diff_identical_records_is_ok():
    result = trajectory.diff(_bench(), _bench(pr=8))
    assert result.ok
    assert not any(v.status in ("regressed", "missing_current")
                   for v in result.verdicts)


def test_diff_fails_synthetic_time_regression():
    result = trajectory.diff(_bench(), _bench(pr=8, engine_s=0.06))
    assert not result.ok
    failed = {v.metric for v in result.failures}
    assert "engine_s_b64" in failed
    assert "engine_overhead_b64" in failed


def test_diff_reports_improvements():
    result = trajectory.diff(_bench(), _bench(pr=8, engine_s=0.003))
    assert result.ok
    improved = {v.metric for v in result.verdicts
                if v.status == "improved"}
    assert "engine_s_b64" in improved


def test_diff_fails_on_dropped_metric():
    cur = _bench(pr=8)
    del cur["superpixel_fcm"]
    result = trajectory.diff(_bench(), cur)
    assert not result.ok
    by_metric = {v.metric: v for v in result.verdicts}
    assert by_metric["superpixel_speedup_fit"].status == "missing_current"
    assert by_metric["superpixel_speedup_fit"].fatal


def test_on_missing_warn_policy_demotes_dropped_metric():
    cur = _bench(pr=8)
    del cur["superpixel_fcm"]
    result = trajectory.diff(_bench(), cur,
                             trajectory.Policy(on_missing="warn"))
    assert result.ok
    assert any(v.status == "missing_current" and not v.fatal
               for v in result.verdicts)


def test_tiny_run_skips_relative_time_gates_but_keeps_bounds():
    """A --tiny CI record vs a full baseline: wall-clock regressions
    are not_comparable (cannot fail), but the absolute tracing-overhead
    ceiling still gates."""
    # 100x "slower" on both sides of the ratio, so the absolute
    # overhead ceiling is untouched and only wall-clock worsens
    cur = _bench(pr=8, tiny=True, engine_s=0.6, batched_s=0.15)
    result = trajectory.diff(_bench(), cur)
    by_metric = {v.metric: v for v in result.verdicts}
    assert by_metric["engine_s_b64"].status == "not_comparable"
    assert result.ok

    breached = _bench(pr=8, tiny=True, tracing=2.0)  # ceiling 1.25
    result = trajectory.diff(_bench(), breached)
    assert not result.ok
    assert any(v.metric == "tracing_overhead_ratio"
               and v.status == "bound_breach" for v in result.failures)


def test_quality_metrics_gate_even_on_tiny_runs():
    cur = _bench(pr=8, tiny=True, parity=0.06)       # ceiling is 0.05
    result = trajectory.diff(_bench(), cur)
    assert any(v.metric == "superpixel_dsc_parity" and v.fatal
               and v.status == "bound_breach" for v in result.verdicts)
    assert trajectory.diff(_bench(), _bench(pr=8, parity=0.04)).ok


def test_absolute_floor_breach_fails():
    result = trajectory.diff(_bench(), _bench(pr=8, spatial_speedup=3.0))
    assert any(v.metric == "spatial_batched_speedup"
               and v.status == "bound_breach" and v.fatal
               for v in result.verdicts)


def test_slack_scale_loosens_relative_gates():
    # 2x slower wall clock at the same overhead ratio
    base = _bench()
    cur = _bench(pr=8, engine_s=0.012, batched_s=0.003)
    assert not trajectory.diff(base, cur).ok
    loose = trajectory.Policy(slack_scale=10.0)
    assert trajectory.diff(base, cur, loose).ok


def test_new_metric_in_current_is_not_fatal():
    base = _bench()
    del base["superpixel_fcm"]
    result = trajectory.diff(base, _bench(pr=8))
    by_metric = {v.metric: v for v in result.verdicts}
    assert by_metric["superpixel_speedup_fit"].status == "new_metric"
    assert result.ok


# ---------------------------------------------------------------------------
# Baseline resolution
# ---------------------------------------------------------------------------

def _write(tmp_path, pr):
    p = tmp_path / f"BENCH_pr{pr}.json"
    p.write_text(json.dumps({"pr": pr}))
    return str(p)


def test_resolve_baseline_picks_newest_before_current(tmp_path):
    _write(tmp_path, 3)
    p5 = _write(tmp_path, 5)
    p9 = _write(tmp_path, 9)
    assert trajectory.resolve_baseline(str(tmp_path), before=9) == p5
    assert trajectory.resolve_baseline(str(tmp_path)) == p9


def test_resolve_baseline_empty_ledger_is_none(tmp_path):
    assert trajectory.resolve_baseline(str(tmp_path), before=8) is None


@needs_ledger
def test_resolve_baseline_on_committed_ledger():
    path = trajectory.resolve_baseline(OUT_DIR, before=10 ** 6)
    assert path is not None and os.path.exists(path)


def test_derived_overhead_matches_explicit_key():
    """Schema evolution: a record without the explicit overhead key
    yields the same value via engine_s / batched_s."""
    old = _bench()
    del old["batched_throughput"]["histogram"]["64"][
        "engine_overhead_vs_batched"]
    assert (trajectory._engine_overhead(old)
            == pytest.approx(trajectory._engine_overhead(_bench())))
