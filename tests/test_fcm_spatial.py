"""FCM_S (spatially-regularized FCM): Pallas stencil kernel parity
against the pure-jnp reference, alpha=0 degeneration to plain FCM, and
the noise-robustness regression the spatial term exists for."""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import fcm as F
from repro.core import solver as SV
from repro.core import spatial as S
from repro.data import phantom
from repro.kernels import ops

# Shapes chosen so padding and borders are exercised: non-multiple-of-128
# widths, a sub-tile image, a one-pixel image (no neighbors at all), and
# widths spanning >1 lane group.
SHAPES_2D = [(37, 53), (64, 128), (9, 300), (128, 181), (2, 2), (1, 1)]
SHAPES_3D = [(5, 19, 41), (1, 8, 128), (2, 2, 2), (3, 16, 130)]


def _data(shape, c=4, seed=0):
    rng = np.random.default_rng(seed)
    img = jnp.asarray(rng.integers(0, 256, shape).astype(np.float32))
    v = jnp.asarray(np.sort(rng.uniform(5, 250, c)).astype(np.float32))
    return img, v


# -- kernel parity (interpret mode on CPU) ----------------------------------

@pytest.mark.parametrize("shape", SHAPES_2D)
@pytest.mark.parametrize("neighbors", [4, 8])
def test_spatial_kernel_2d_matches_reference(shape, neighbors):
    img, v = _data(shape)
    want = S.spatial_center_step(img, v, 2.0, 0.7, neighbors)
    got = ops.spatial_step(img, v, 2.0, 0.7, neighbors, block_rows=8,
                           interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-4)


@pytest.mark.parametrize("shape", SHAPES_3D)
def test_spatial_kernel_3d_matches_reference(shape, neighbors=6):
    img, v = _data(shape, seed=1)
    want = S.spatial_center_step(img, v, 2.0, 1.3, neighbors)
    got = ops.spatial_step(img, v, 2.0, 1.3, neighbors, interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-4)


@pytest.mark.parametrize("block_rows", [8, 16, 64])
def test_spatial_kernel_block_shape_sweep(block_rows):
    """Halo handling must be invariant to where the tile cuts fall."""
    img, v = _data((100, 140), seed=2)
    want = ops.spatial_step(img, v, 2.0, 1.0, 8, block_rows=8,
                            interpret=True)
    got = ops.spatial_step(img, v, 2.0, 1.0, 8, block_rows=block_rows,
                           interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-6, atol=1e-5)


@pytest.mark.parametrize("m", [2.0, 1.6])
@pytest.mark.parametrize("alpha", [0.0, 0.3, 2.5])
def test_spatial_kernel_fuzz_alpha_sweep(m, alpha):
    img, v = _data((45, 77), c=3, seed=3)
    want = S.spatial_center_step(img, v, m, alpha, 4)
    got = ops.spatial_step(img, v, m, alpha, 4, interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-4)


def test_border_pixels_average_over_true_neighbors_only():
    """A corner pixel has 2 (4-conn) / 3 (8-conn) neighbors; validity
    weighting must not let zero padding leak into the stencil mean."""
    img = jnp.asarray([[200.0, 0.0], [0.0, 0.0]])
    v = jnp.asarray([0.0, 200.0])
    d2, nb, xbar = S.neighbor_fields(img, v, 4)
    # corner (0,0): neighbors are the two zeros -> mean d2 to center 200
    # is 200^2, mean intensity 0.
    assert float(nb[1, 0, 0]) == pytest.approx(200.0 ** 2)
    assert float(xbar[0, 0]) == 0.0
    # and the kernel agrees on the resulting center step
    want = S.spatial_center_step(img, v, 2.0, 1.0, 4)
    got = ops.spatial_step(img, v, 2.0, 1.0, 4, interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-4)


# -- alpha=0 degenerates to plain FCM ---------------------------------------

@pytest.mark.parametrize("shape", [(96, 96), (4, 48, 48)])
def test_alpha_zero_reproduces_fit_fused(shape):
    rng = np.random.default_rng(5)
    img = rng.integers(0, 256, shape).astype(np.float32)
    cfg = S.SpatialFCMConfig(alpha=0.0)
    res_sp = SV.solve(SV.spatial_problem(img, cfg), cfg)
    res_fu = SV.solve(SV.pixel_problem(img.ravel()), backend="reference")
    np.testing.assert_allclose(np.asarray(res_sp.centers),
                               np.asarray(res_fu.centers), atol=1e-5)
    assert res_sp.n_iters == res_fu.n_iters
    assert res_sp.labels.shape == shape
    np.testing.assert_array_equal(
        np.asarray(res_sp.labels).ravel(), np.asarray(res_fu.labels))


def test_alpha_zero_pallas_path_reproduces_fit_fused():
    img, _ = phantom.phantom_slice(64, 96, noise=5.0, seed=6)
    img = img.astype(np.float32)
    cfg = S.SpatialFCMConfig(alpha=0.0)
    res_sp = SV.solve(SV.spatial_problem(img, cfg), cfg,
                      backend="pallas", interpret=True)
    res_fu = SV.solve(SV.pixel_problem(img.ravel()), backend="reference")
    np.testing.assert_allclose(np.asarray(res_sp.centers),
                               np.asarray(res_fu.centers), atol=1e-3)


# -- full-fit parity: Pallas loop vs reference loop -------------------------

@pytest.mark.parametrize("shape,neighbors", [((60, 75), 8), ((3, 24, 40), 6)])
def test_fit_spatial_pallas_matches_reference(shape, neighbors):
    rng = np.random.default_rng(7)
    img = rng.integers(0, 256, shape).astype(np.float32)
    cfg = S.SpatialFCMConfig(alpha=1.0, neighbors=neighbors, max_iters=40)
    ref = SV.solve(SV.spatial_problem(img, cfg), cfg)
    pal = SV.solve(SV.spatial_problem(img, cfg), cfg, backend="pallas",
                   block_rows=8, interpret=True)
    np.testing.assert_allclose(np.asarray(pal.centers),
                               np.asarray(ref.centers), atol=5e-3)
    agree = np.mean(np.asarray(pal.labels) == np.asarray(ref.labels))
    assert agree > 0.999


# -- API validation ----------------------------------------------------------

def test_bad_neighborhoods_rejected():
    img = np.zeros((8, 8), np.float32)
    with pytest.raises(ValueError):
        SV.solve(SV.spatial_problem(img, S.SpatialFCMConfig(neighbors=5)))
    with pytest.raises(ValueError):
        S.neighbor_offsets(3, 4)
    with pytest.raises(ValueError):
        SV.solve(SV.spatial_problem(np.zeros(64, np.float32)))  # rank-1
    with pytest.raises(ValueError):              # kernel path agrees with
        ops.spatial_step(np.zeros((2, 4, 4), np.float32), np.zeros(2),
                         neighbors=8, interpret=True)  # ... the reference


def test_spatial_membership_shape_and_partition():
    img, v = _data((31, 47))
    u = S.spatial_membership(img, v, 2.0, 1.0, 8)
    assert u.shape == (4, 31, 47)
    np.testing.assert_allclose(np.asarray(jnp.sum(u, axis=0)), 1.0,
                               atol=1e-4)


# -- the point of it all: noise robustness (slow) ---------------------------

@pytest.mark.slow
def test_spatial_beats_plain_fcm_on_salt_and_pepper():
    """On the heaviest noise level, FCM_S must beat plain FCM's DSC by a
    wide margin on every tissue class (plain FCM's clusters get hijacked
    by the 0/255 impulse modes)."""
    sigma, impulse = phantom.NOISE_LEVELS[-1]
    img, gt = phantom.noisy_phantom_slice(128, 128, noise=sigma,
                                          impulse=impulse, seed=0)
    x = img.ravel().astype(np.float32)
    rp = SV.solve(SV.pixel_problem(x), backend="reference")
    plain = phantom.match_labels_to_classes(
        np.asarray(rp.labels).reshape(img.shape), rp.centers)
    scfg = S.SpatialFCMConfig(alpha=1.0, neighbors=8)
    rs = SV.solve(SV.spatial_problem(img.astype(np.float32), scfg), scfg)
    spatial = phantom.match_labels_to_classes(np.asarray(rs.labels),
                                              rs.centers)
    dsc_p = phantom.dice_per_class(plain, gt)
    dsc_s = phantom.dice_per_class(spatial, gt)
    for cls in (1, 2, 3):                      # CSF, GM, WM
        assert dsc_s[cls] >= dsc_p[cls] + 0.2, (
            phantom.CLASS_NAMES[cls], dsc_p[cls], dsc_s[cls])
        assert dsc_s[cls] > 0.75, (phantom.CLASS_NAMES[cls], dsc_s[cls])
