"""Batched multi-image FCM: every lane of ``fit_batched`` must reproduce
what the single-image histogram fit would have computed for that image
alone — including lanes that converge at different iteration counts."""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import batched as B
from repro.core import fcm as F
from repro.core import histogram as H
from repro.data import phantom


def _legacy(fn, *args, **kwargs):
    """Call a deprecated fit_* adapter, asserting (and swallowing) its
    DeprecationWarning: these tests exercise the adapters on purpose."""
    with pytest.warns(DeprecationWarning):
        return fn(*args, **kwargs)


@pytest.fixture(scope="module")
def mixed_batch():
    """Heterogeneous sizes + noise levels so convergence speeds differ."""
    specs = [(96, 96, 4.0, 0.3), (128, 96, 6.0, 0.5),
             (64, 64, 2.0, 0.7), (96, 128, 8.0, 0.4),
             (80, 80, 12.0, 0.6)]
    return [phantom.phantom_slice(h, w, slice_pos=sp, noise=nz, seed=i)[0]
            for i, (h, w, nz, sp) in enumerate(specs)]


CFG = F.FCMConfig(max_iters=300)


def test_batched_matches_per_image_fit_histogram(mixed_batch):
    res = _legacy(B.fit_batched, mixed_batch, CFG)
    assert res.centers.shape == (len(mixed_batch), CFG.n_clusters)
    for i, img in enumerate(mixed_batch):
        single = _legacy(H.fit_histogram, img.ravel().astype(np.float32), CFG)
        np.testing.assert_allclose(np.asarray(res.centers[i]),
                                   np.asarray(single.centers), atol=1e-4)
        assert res.n_iters[i] == single.n_iters
        assert (res.labels[i] ==
                np.asarray(single.labels).reshape(img.shape)).all()


def test_batched_lanes_converge_independently(mixed_batch):
    res = _legacy(B.fit_batched, mixed_batch, CFG)
    # The whole point of per-lane masking: a mixed batch must show mixed
    # iteration counts, and the loop runs exactly max(lane iters) times.
    assert len(set(res.n_iters.tolist())) > 1, res.n_iters
    assert res.total_iters == int(res.n_iters.max())
    assert (res.final_delta < np.inf).all()


def test_batched_accepts_prebuilt_histograms(mixed_batch):
    hists = B.histograms_of(mixed_batch)
    res_h = _legacy(B.fit_batched, hists, CFG)
    res_i = _legacy(B.fit_batched, mixed_batch, CFG)
    np.testing.assert_allclose(np.asarray(res_h.centers),
                               np.asarray(res_i.centers), atol=0)
    assert res_h.labels is None          # no pixels to defuzzify
    assert res_i.labels is not None


def test_batched_single_lane_degenerates_to_single_image(mixed_batch):
    img = mixed_batch[0]
    res = _legacy(B.fit_batched, [img], CFG)
    single = _legacy(H.fit_histogram, img.ravel().astype(np.float32), CFG)
    np.testing.assert_allclose(np.asarray(res.centers[0]),
                               np.asarray(single.centers), atol=1e-4)
    assert res.n_iters[0] == single.n_iters


def test_batched_pixels_same_shape_batch():
    xs, gts = [], []
    for z in range(4):
        img, gt = phantom.phantom_slice(96, 96, slice_pos=0.4 + 0.05 * z,
                                        seed=10 + z)
        xs.append(img)
        gts.append(gt)
    res = _legacy(B.fit_batched_pixels, np.stack(xs), CFG)
    assert res.centers.shape == (4, CFG.n_clusters)
    for i in range(4):
        pred = phantom.match_labels_to_classes(
            res.labels[i].reshape(96, 96), np.asarray(res.centers[i]))
        dscs = phantom.dice_per_class(pred, gts[i])
        assert min(dscs) > 0.80, (i, dscs)


def test_batched_max_iters_zero_is_safe(mixed_batch):
    res = _legacy(B.fit_batched, mixed_batch[:2], F.FCMConfig(max_iters=0))
    assert res.total_iters == 0
    assert (res.n_iters == 0).all()
    assert res.centers.shape == (2, 4)
    assert np.isfinite(np.asarray(res.centers)).all()   # linspace init


def test_masked_while_freezes_converged_lanes():
    # Lane 0's eps is huge, so it is "converged" after one step even though
    # its step keeps drifting (+10/iter); lane 1 contracts to 100. If the
    # mask failed to freeze lane 0 it would keep accumulating +10s.
    v0 = jnp.asarray([[10.0, 200.0], [10.0, 200.0]])
    eps_v = jnp.asarray([1e9, 1e-3])

    def step(v):
        return v * jnp.asarray([[1.0], [0.5]]) + jnp.asarray([[10.0], [50.0]])

    v, delta, iters, it = B._masked_while(step, v0, eps_v, 50)
    assert iters[0] == 1 and iters[1] > 1
    assert int(it) == int(iters[1])
    np.testing.assert_allclose(np.asarray(v[0]), [20.0, 210.0])   # frozen
    np.testing.assert_allclose(np.asarray(v[1]), [100.0, 100.0], atol=0.01)
