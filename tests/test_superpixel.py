"""Behavioural tests for the superpixel subsystem: SLIC invariants, the
weighted vector FCM core (incl. its D=1 equivalence to the histogram
path and the batched variant), and the compress -> fit -> broadcast
pipeline."""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import fcm as F
from repro.core import histogram as H
from repro.core import solver as SV
from repro.data import phantom
from repro.superpixel import pipeline as SX
from repro.superpixel import slic as SL

CFG = F.FCMConfig()


# ---------------------------------------------------------------------------
# SLIC reference
# ---------------------------------------------------------------------------

def test_grid_shape_tracks_aspect():
    gy, gx = SL.grid_shape(100, 400, 64)
    assert gy * gx == pytest.approx(64, rel=0.35)
    assert gx > gy                          # wide image, wide grid
    assert SL.grid_shape(8, 8, 1) == (1, 1)


def test_slic_labels_are_compact_and_complete():
    img, _ = phantom.phantom_slice(96, 80, seed=4)
    res = SL.fit_slic(img.astype(np.float32), SL.SLICParams(n_segments=48))
    lab = np.asarray(res.labels)
    k = res.gy * res.gx
    assert lab.shape == img.shape and lab.min() >= 0 and lab.max() < k
    np.testing.assert_allclose(
        np.bincount(lab.ravel(), minlength=k), np.asarray(res.counts))
    # compactness: every pixel's superpixel center stays within its 3x3
    # grid-cell neighborhood, so no superpixel spans > 3 cell intervals
    yy, xx = np.mgrid[0:96, 0:80]
    cy = np.asarray(res.centers[:, 1])[lab]
    cx = np.asarray(res.centers[:, 2])[lab]
    assert np.abs(yy - cy).max() <= 3 * (96 / res.gy)
    assert np.abs(xx - cx).max() <= 3 * (80 / res.gx)


def test_slic_grayscale_and_multichannel_agree_on_replicated_channels():
    """A 3-channel image with identical channels is the grayscale
    problem with 3x the feature distance — same compactness units give
    a valid (if differently weighted) partition; the degenerate check
    is that every superpixel's channel means coincide."""
    img, _ = phantom.phantom_slice(64, 64, seed=5)
    img3 = np.stack([img] * 3, axis=-1).astype(np.float32)
    res = SL.fit_slic(img3, SL.SLICParams(n_segments=32))
    feats = np.asarray(res.centers[:, :3])
    np.testing.assert_allclose(feats[:, 0], feats[:, 1], atol=1e-4)
    np.testing.assert_allclose(feats[:, 0], feats[:, 2], atol=1e-4)


def test_slic_converges_on_constant_image():
    res = SL.fit_slic(np.full((40, 48), 7.0, np.float32),
                      SL.SLICParams(n_segments=12, max_iters=10))
    # seeds never move on constant data: one iteration detects the
    # fixed point
    assert res.n_iters <= 2
    assert np.asarray(res.counts).sum() == 40 * 48


# ---------------------------------------------------------------------------
# Weighted vector FCM
# ---------------------------------------------------------------------------

def test_vector_fcm_d1_reproduces_histogram_fit():
    """(256, 1) bin values + counts as weights == the histogram solve,
    center for center, iteration for iteration."""
    img, _ = phantom.phantom_slice(96, 96, seed=3)
    x = img.ravel().astype(np.float32)
    hist = H.intensity_histogram(jnp.asarray(x))
    vals = jnp.arange(256, dtype=jnp.float32)[:, None]
    rv = SV.solve(SV.vector_problem(vals, hist, CFG))
    rh = SV.solve(SV.histogram_problem(x, CFG))
    np.testing.assert_allclose(np.asarray(rv.centers).ravel(),
                               np.asarray(rh.centers), atol=1e-5)
    assert rv.n_iters == rh.n_iters


def test_vector_fcm_membership_partition_and_labels():
    rng = np.random.default_rng(0)
    feats = rng.uniform(0, 255, (128, 3)).astype(np.float32)
    res = SV.solve(SV.vector_problem(feats, cfg=CFG),
                   keep_membership=True)
    u = np.asarray(res.membership)
    np.testing.assert_allclose(u.sum(axis=0), 1.0, atol=1e-5)
    np.testing.assert_array_equal(
        np.asarray(res.labels),
        np.asarray(F.labels_from_centers(jnp.asarray(feats), res.centers)))


def test_vector_fcm_zero_weight_rows_are_inert():
    """Appending zero-weight junk rows must not move the centers (they
    are excluded from both the init range and the weighted sums)."""
    rng = np.random.default_rng(1)
    feats = rng.uniform(20, 200, (64, 2)).astype(np.float32)
    w = rng.uniform(1, 10, (64,)).astype(np.float32)
    r0 = SV.solve(SV.vector_problem(feats, w, CFG))
    junk = np.array([[1e4, -1e4], [5e3, 5e3]], np.float32)
    feats2 = np.concatenate([feats, junk])
    w2 = np.concatenate([w, np.zeros((2,), np.float32)])
    r1 = SV.solve(SV.vector_problem(feats2, w2, CFG))
    # atol covers float non-associativity of the row sums, nothing more
    np.testing.assert_allclose(np.asarray(r0.centers),
                               np.asarray(r1.centers), atol=1e-3)
    assert r0.n_iters == r1.n_iters


def test_vector_batched_lanes_match_single_fits():
    rngs = [np.random.default_rng(s) for s in range(4)]
    feats = np.stack([r.uniform(0, 255, (48, 3)).astype(np.float32)
                      for r in rngs])
    ws = np.stack([r.uniform(1, 40, (48,)).astype(np.float32)
                   for r in rngs])
    ws[2, :8] = 0.0                          # a lane with empty rows
    rb = SV.solve_batched(SV.batch_problems(feats, ws, cfg=CFG), CFG)
    assert rb.centers.shape == (4, CFG.n_clusters, 3)
    for i in range(4):
        rs = SV.solve(SV.vector_problem(feats[i], ws[i], CFG))
        np.testing.assert_allclose(np.asarray(rb.centers[i]),
                                   np.asarray(rs.centers), atol=1e-3)
        assert int(rb.n_iters[i]) == rs.n_iters


# ---------------------------------------------------------------------------
# Pipeline
# ---------------------------------------------------------------------------

def test_compress_payload_shapes():
    img, _ = phantom.phantom_slice_rgb(80, 72, seed=6)
    cfg = SX.SuperpixelFCMConfig(n_segments=40)
    comp = SX.compress(img.astype(np.float32), cfg)
    k = comp.gy * comp.gx
    assert comp.features.shape == (k, 3)
    assert comp.weights.shape == (k,)
    assert comp.label_map.shape == (80, 72)
    assert float(jnp.sum(comp.weights)) == 80 * 72


@pytest.mark.parametrize("flavor", ["rgb", "t1t2pd", "gray"])
def test_pipeline_dsc_parity_with_pixel_space(flavor):
    """Superpixel-compressed FCM matches the pixel-space fit within 0.02
    DSC per class on every phantom flavor."""
    if flavor == "rgb":
        img, gt = phantom.phantom_slice_rgb(128, 128, noise=6.0, seed=7)
        means = phantom.CLASS_MEANS_RGB
    elif flavor == "t1t2pd":
        img, gt = phantom.phantom_slice_channels(128, 128, noise=6.0,
                                                 seed=7)
        means = phantom.CLASS_MEANS_MULTI
    else:
        img, gt = phantom.phantom_slice(128, 128, noise=6.0, seed=7)
        means = phantom.CLASS_MEANS[:, None]
    imgf = img.astype(np.float32)
    cfg = SX.SuperpixelFCMConfig(n_segments=128)
    seg, comp = SX.fit_superpixel(imgf, cfg)
    x = imgf.reshape(-1, imgf.shape[-1]) if imgf.ndim == 3 \
        else imgf.ravel()
    rp = SV.solve(SV.pixel_problem(x, CFG))
    d_sp = phantom.dice_per_class(
        phantom.match_labels_to_means(seg.labels, seg.centers, means), gt)
    d_px = phantom.dice_per_class(
        phantom.match_labels_to_means(
            np.asarray(rp.labels).reshape(gt.shape), rp.centers, means), gt)
    for a, b in zip(d_sp, d_px):
        assert abs(a - b) <= 0.02, (d_sp, d_px)


def test_broadcast_labels_is_a_pure_gather():
    sp_labels = jnp.asarray([3, 1, 2, 0], jnp.int32)
    label_map = jnp.asarray([[0, 1], [2, 3]], jnp.int32)
    out = np.asarray(SX.broadcast_labels(sp_labels, label_map))
    np.testing.assert_array_equal(out, [[3, 1], [2, 0]])


def test_match_labels_to_means_handles_contrast_inversion():
    # CSF is dark on T1, bright on T2: scalar rank matching would swap
    # CSF/WM, nearest-mean matching must not.
    centers = phantom.CLASS_MEANS_MULTI[[3, 0, 2, 1]] + 2.0
    labels = np.array([0, 1, 2, 3])
    out = phantom.match_labels_to_means(labels, centers,
                                        phantom.CLASS_MEANS_MULTI)
    np.testing.assert_array_equal(out, [3, 0, 2, 1])
