"""FCMServeEngine: bucketing, caching, correctness of served labels
against the single-image histogram fit, and the device-resident route
programs (single-dispatch serving, program-cache lifecycle)."""
import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import fcm as F
from repro.core import solver as SV
from repro.data import phantom
from repro.serving.fcm_engine import FCMServeEngine


CFG = F.FCMConfig(max_iters=300)


@pytest.fixture(scope="module")
def volume():
    """12 heterogeneous-size slices (volumetric traffic)."""
    return [phantom.phantom_slice(64 + 8 * (z % 4), 96,
                                  slice_pos=0.3 + 0.4 * z / 12,
                                  noise=4.0, seed=z)[0] for z in range(12)]


def test_served_labels_match_single_image_fit(volume):
    eng = FCMServeEngine(CFG, batch_sizes=(1, 8, 64), cache_size=0)
    results = eng.segment(volume)
    assert [r.request_id for r in results] == list(range(12))
    for img, r in zip(volume, results):
        assert r.labels.shape == img.shape
        x = img.ravel().astype(np.float32)
        single = SV.solve(SV.histogram_problem(x, CFG), backend="reference")
        np.testing.assert_allclose(r.centers, np.asarray(single.centers),
                                   atol=1e-4)
        lab = F.labels_from_centers(jnp.asarray(x), single.centers)
        assert (r.labels == np.asarray(lab).reshape(img.shape)).all()
        assert r.n_iters == single.n_iters


def test_bucketing_pads_to_fixed_shapes(volume):
    eng = FCMServeEngine(CFG, batch_sizes=(4, 16), cache_size=0)
    eng.segment(volume)                      # 12 requests -> one 16-bucket
    s = eng.stats()
    assert s["batches"] == 1
    assert s["padded_lanes"] == 4
    assert s["batched_images"] == 12
    assert s["queue_depth"] == 0


def test_oversize_flush_splits_into_max_buckets(volume):
    eng = FCMServeEngine(CFG, batch_sizes=(4,), cache_size=0)
    eng.segment(volume)                      # 12 requests -> three 4-buckets
    assert eng.stats()["batches"] == 3


def test_cache_hit_on_identical_resubmission(volume):
    eng = FCMServeEngine(CFG, batch_sizes=(1, 8, 64))
    first = eng.segment([volume[0]])[0]
    assert not first.cache_hit
    again = eng.segment([volume[0]])[0]
    assert again.cache_hit and again.n_iters == 0
    assert (again.labels == first.labels).all()
    np.testing.assert_allclose(again.centers, first.centers, atol=0)
    assert eng.stats()["cache_hits"] == 1


def test_intra_flush_dedup(volume):
    eng = FCMServeEngine(CFG, batch_sizes=(1, 8, 64))
    results = eng.segment([volume[0]] * 5)   # 5 identical in one flush
    s = eng.stats()
    assert s["batched_images"] == 1          # one representative fit
    assert s["cache_hits"] == 4
    assert all((r.labels == results[0].labels).all() for r in results)


def test_duplicates_with_cache_disabled_all_answered(volume):
    """Regression: with cache_size=0, duplicate submissions in one flush
    used to collapse in the dedup dict and lose requests."""
    eng = FCMServeEngine(CFG, batch_sizes=(1, 8), cache_size=0)
    results = eng.segment([volume[0]] * 3)
    assert len(results) == 3
    assert all((r.labels == results[0].labels).all() for r in results)


def test_duplicates_survive_intra_flush_lru_eviction(volume):
    """Regression: a duplicate's centers come from this flush's fits, not
    the LRU cache, which may already have evicted the representative."""
    eng = FCMServeEngine(CFG, batch_sizes=(8,), cache_size=1, cache_tol=0.0)
    imgs = [phantom.phantom_slice(64, 64, noise=2.0 + 3 * i, seed=i)[0]
            for i in range(3)]
    results = eng.segment([imgs[0], imgs[1], imgs[2], imgs[0]])
    assert len(results) == 4
    np.testing.assert_allclose(results[3].centers, results[0].centers,
                               atol=0)


def test_near_identical_histograms_hit_cache():
    # Same anatomy, fresh noise draw (L1 ~ 0.08 between normalized
    # histograms): the nearest-match scan must serve it from cache.
    a = phantom.phantom_slice(96, 96, slice_pos=0.5, noise=4.0, seed=1)[0]
    b, gt = phantom.phantom_slice(96, 96, slice_pos=0.5, noise=4.0, seed=2)
    eng = FCMServeEngine(CFG)
    ra = eng.segment([a])[0]
    rb = eng.segment([b])[0]
    assert not ra.cache_hit and rb.cache_hit
    # served-from-cache labels are still per-pixel correct for image b
    pred = phantom.match_labels_to_classes(rb.labels, rb.centers)
    assert min(phantom.dice_per_class(pred, gt)) > 0.80


def test_distinct_content_does_not_hit_cache():
    # Different anatomy/noise (L1 ~ 0.5) must NOT near-match.
    a = phantom.phantom_slice(96, 96, slice_pos=0.5, noise=4.0, seed=1)[0]
    b = phantom.phantom_slice(96, 96, slice_pos=0.9, noise=8.0, seed=2)[0]
    eng = FCMServeEngine(CFG)
    eng.segment([a])
    assert not eng.segment([b])[0].cache_hit


def test_lru_eviction():
    eng = FCMServeEngine(CFG, cache_size=2)
    imgs = [phantom.phantom_slice(64, 64, noise=2.0 + 3 * i, seed=i)[0]
            for i in range(3)]
    eng.segment(imgs)                        # fills + evicts oldest
    assert eng.stats()["cache_entries"] == 2
    assert eng.segment([imgs[0]])[0].cache_hit is False   # evicted
    assert eng.segment([imgs[2]])[0].cache_hit is True    # still resident


def test_spatial_route_bypasses_histogram_cache():
    """method="spatial" requests carry full pixel payloads around the
    LRU cache; histogram requests in the same flush still hit it."""
    eng = FCMServeEngine(CFG)
    img, _ = phantom.noisy_phantom_slice(48, 48, noise=10.0, impulse=0.05,
                                         seed=0)
    first = eng.segment([img])[0]            # histogram fit, fills cache
    assert first.method == "histogram"
    hits0 = eng.stats()["cache_hits"]
    entries0 = eng.stats()["cache_entries"]

    # Mixed batch: one identical histogram request + one spatial request.
    rid_h = eng.submit(img)
    rid_s = eng.submit(img, method="spatial")
    assert eng.queue_depth == 2
    res = {r.request_id: r for r in eng.flush()}
    assert eng.queue_depth == 0
    assert res[rid_h].cache_hit and res[rid_h].method == "histogram"
    sp = res[rid_s]
    assert sp.method == "spatial"
    assert not sp.cache_hit and sp.n_iters > 0
    assert sp.labels.shape == img.shape

    s = eng.stats()
    assert s["cache_hits"] == hits0 + 1      # only the histogram request
    assert s["cache_entries"] == entries0    # spatial never populated it
    assert s["spatial_requests"] == 1
    assert s["spatial_iters"] == sp.n_iters

    # An identical spatial resubmission must run the fit again — pixel
    # positions matter, histogram identity is not segmentation identity.
    sp2 = eng.segment([img], method="spatial")[0]
    assert not sp2.cache_hit and sp2.n_iters > 0
    assert eng.stats()["cache_hits"] == hits0 + 1
    np.testing.assert_allclose(sp2.centers, sp.centers, atol=1e-5)
    assert (sp2.labels == sp.labels).all()


def test_spatial_results_match_direct_fit_spatial():
    eng = FCMServeEngine(CFG)
    img, _ = phantom.noisy_phantom_slice(40, 56, noise=12.0, impulse=0.05,
                                         seed=3)
    served = eng.segment([img], method="spatial")[0]
    direct = SV.solve(SV.spatial_problem(img.astype(np.float32),
                                         eng.spatial_cfg), eng.spatial_cfg)
    np.testing.assert_allclose(served.centers, np.asarray(direct.centers),
                               atol=1e-5)
    assert (served.labels == np.asarray(direct.labels)).all()
    assert served.n_iters == direct.n_iters


def test_spatial_cache_hit_rate_counts_cacheable_traffic_only():
    eng = FCMServeEngine(CFG)
    img, _ = phantom.noisy_phantom_slice(32, 32, seed=1)
    eng.segment([img])                       # miss, fills cache
    eng.segment([img])                       # hit
    eng.segment([img], method="spatial")     # must not dilute the rate
    assert eng.stats()["cache_hit_rate"] == 0.5


def test_superpixel_route_serves_color_and_bypasses_cache():
    """method="superpixel" handles (H, W, D) payloads the histogram
    route cannot represent, and never touches the 1-D LRU."""
    eng = FCMServeEngine(CFG)
    img, gt = phantom.phantom_slice_rgb(96, 96, noise=4.0, seed=1)
    entries0 = eng.stats()["cache_entries"]
    res = eng.segment([img], method="superpixel")[0]
    assert res.method == "superpixel"
    assert not res.cache_hit and res.n_iters > 0
    assert res.labels.shape == (96, 96)
    assert res.centers.shape == (CFG.n_clusters, 3)
    pred = phantom.match_labels_to_means(res.labels, res.centers,
                                         phantom.CLASS_MEANS_RGB)
    assert min(phantom.dice_per_class(pred, gt)) > 0.9
    s = eng.stats()
    assert s["cache_entries"] == entries0       # never populated the LRU
    assert s["cache_hits"] == 0
    # resubmission runs the fit again (no vector cache yet, by design)
    again = eng.segment([img], method="superpixel")[0]
    assert not again.cache_hit and again.n_iters > 0
    assert (again.labels == res.labels).all()


def test_superpixel_bucket_matches_single_fits():
    """A flushed superpixel batch (with pad lanes) gives each request the
    centers a solo fit of its compressed payload would."""
    eng = FCMServeEngine(CFG, batch_sizes=(4,))
    imgs = [phantom.phantom_slice_rgb(64, 64, noise=3.0 + 2 * i, seed=i)[0]
            for i in range(3)]
    ids = [eng.submit(im, method="superpixel") for im in imgs]
    pend = {q.request_id: q for q in eng._superpixel_queue}
    by_id = {r.request_id: r for r in eng.flush()}
    s = eng.stats()
    assert s["superpixel_batches"] == 1 and s["superpixel_padded_lanes"] == 1
    for rid in ids:
        solo = SV.solve(SV.vector_problem(pend[rid].features,
                                          pend[rid].weights, CFG),
                        backend="reference")
        np.testing.assert_allclose(by_id[rid].centers,
                                   np.asarray(solo.centers), atol=1e-3)
        assert by_id[rid].n_iters == solo.n_iters


def test_superpixel_fit_honors_superpixel_cfg():
    """Regression: the bucket fit must run with the caller's
    superpixel_cfg hyper-parameters (here n_clusters=3), not self.cfg."""
    from repro.superpixel.pipeline import SuperpixelFCMConfig

    sp_cfg = SuperpixelFCMConfig(n_clusters=3, n_segments=48)
    eng = FCMServeEngine(CFG, superpixel_cfg=sp_cfg)
    img, _ = phantom.phantom_slice_rgb(64, 64, seed=4)
    res = eng.segment([img], method="superpixel")[0]
    assert res.centers.shape == (3, 3)
    assert set(np.unique(res.labels)) <= {0, 1, 2}


def test_pixel_route_matches_fit_fused():
    eng = FCMServeEngine(CFG)
    img, _ = phantom.phantom_slice(48, 56, seed=2)
    res = eng.segment([img], method="pixel")[0]
    direct = SV.solve(SV.pixel_problem(img.ravel().astype(np.float32),
                                       CFG), backend="reference")
    assert res.method == "pixel"
    np.testing.assert_allclose(res.centers, np.asarray(direct.centers),
                               atol=1e-5)
    assert (res.labels == np.asarray(direct.labels).reshape(48, 56)).all()


def test_per_method_counters_increment():
    """The stats() route mix: every submit bumps its method's request
    counter, and only histogram traffic ever bumps a cache-hit one."""
    eng = FCMServeEngine(CFG)
    s = eng.stats()
    assert s["method_requests"] == {
        "histogram": 0, "pixel": 0, "spatial": 0, "superpixel": 0}
    assert s["method_cache_hits"] == {
        "histogram": 0, "pixel": 0, "spatial": 0, "superpixel": 0}

    gray, _ = phantom.phantom_slice(48, 48, seed=0)
    rgb, _ = phantom.phantom_slice_rgb(48, 48, seed=0)
    eng.segment([gray])                          # histogram miss
    eng.segment([gray])                          # histogram hit
    eng.segment([gray, gray])                    # hit + intra-flush... both hit
    eng.segment([gray], method="pixel")
    eng.segment([gray], method="spatial")
    eng.segment([rgb], method="superpixel")
    eng.segment([rgb], method="superpixel")      # no cache for vectors

    s = eng.stats()
    assert s["method_requests"] == {
        "histogram": 4, "pixel": 1, "spatial": 1, "superpixel": 2}
    assert s["method_cache_hits"] == {
        "histogram": 3, "pixel": 0, "spatial": 0, "superpixel": 0}
    assert s["cache_hits"] == 3                  # legacy aggregate agrees
    assert s["requests"] == 8
    # hit rate is over histogram traffic only
    assert s["cache_hit_rate"] == pytest.approx(3 / 4)


def test_bad_pixel_request_rejected_at_ingest():
    """A (D, H, W) volume must not silently cluster on W-dim feature
    rows through the channels-last pixel route."""
    eng = FCMServeEngine(CFG)
    with pytest.raises(ValueError, match="channels-last"):
        eng.submit(np.zeros((16, 64, 64)), method="pixel")  # volume-shaped
    with pytest.raises(ValueError):
        eng.submit(np.zeros((2, 3, 4, 5)), method="pixel")
    assert eng.queue_depth == 0


def test_bad_superpixel_request_rejected_at_ingest():
    eng = FCMServeEngine(CFG)
    with pytest.raises(ValueError):
        eng.submit(np.zeros(64), method="superpixel")
    with pytest.raises(ValueError):
        eng.submit(np.zeros((2, 3, 4, 5)), method="superpixel")
    assert eng.queue_depth == 0


def test_unknown_method_rejected():
    eng = FCMServeEngine(CFG)
    with pytest.raises(ValueError):
        eng.submit(np.zeros((8, 8)), method="fuzzy")


def test_bad_spatial_request_rejected_at_ingest():
    """A rank-1 spatial payload must fail in submit(), not poison a
    whole flush() after the queues have been drained."""
    eng = FCMServeEngine(CFG)
    img, _ = phantom.phantom_slice(32, 32, seed=0)
    eng.submit(img)
    with pytest.raises(ValueError):
        eng.submit(np.zeros(64), method="spatial")
    results = eng.flush()                    # the good request survives
    assert len(results) == 1 and results[0].method == "histogram"


def test_stats_shape():
    eng = FCMServeEngine(CFG)
    s = eng.stats()
    for k in ("requests", "cache_hits", "batches", "batched_images",
              "padded_lanes", "queue_depth", "cache_entries",
              "cache_hit_rate", "images_per_sec"):
        assert k in s
    assert s["requests"] == 0 and s["cache_hit_rate"] == 0.0


def test_bad_batch_sizes_rejected():
    with pytest.raises(ValueError):
        FCMServeEngine(CFG, batch_sizes=())
    with pytest.raises(ValueError):
        FCMServeEngine(CFG, batch_sizes=(0, 8))


# ---------------------------------------------------------------------------
# Route registry: cross-request batching for spatial/pixel, extensibility
# ---------------------------------------------------------------------------

def test_spatial_requests_batch_across_requests():
    """Same-shape FCM_S requests in one flush share ONE batched solve,
    and every request still gets its solo-fit trajectory."""
    from repro.core import solver as SV

    eng = FCMServeEngine(CFG, batch_sizes=(1, 8, 64))
    imgs = [phantom.noisy_phantom_slice(40, 48, noise=6.0 + 3 * i,
                                        impulse=0.04, seed=i)[0]
            for i in range(6)]
    results = eng.segment(imgs, method="spatial")
    s = eng.stats()
    assert s["spatial_batches"] == 1                 # one device loop
    assert s["spatial_batched_images"] == 6
    assert s["spatial_padded_lanes"] == 2            # 6 -> bucket 8
    for img, r in zip(imgs, results):
        solo = SV.solve(SV.spatial_problem(img.astype(np.float32),
                                           eng.spatial_cfg),
                        eng.spatial_cfg)
        np.testing.assert_allclose(r.centers, np.asarray(solo.centers),
                                   atol=1e-5)
        assert (r.labels == np.asarray(solo.labels)).all()
        assert r.n_iters == solo.n_iters


def test_spatial_mixed_shapes_bucket_separately():
    eng = FCMServeEngine(CFG, batch_sizes=(4,))
    a = [phantom.noisy_phantom_slice(32, 32, seed=i)[0] for i in range(2)]
    b = [phantom.noisy_phantom_slice(32, 48, seed=i)[0] for i in range(3)]
    eng.segment(a + b, method="spatial")
    s = eng.stats()
    assert s["spatial_batches"] == 2                 # one per grid shape
    assert s["spatial_batched_images"] == 5
    assert s["spatial_padded_lanes"] == 3            # 2->4 and 3->4


def test_pixel_requests_batch_across_requests():
    from repro.core import solver as SV

    eng = FCMServeEngine(CFG, batch_sizes=(4,))
    imgs = [phantom.phantom_slice(40, 44, noise=2.0 + i, seed=i)[0]
            for i in range(3)]
    results = eng.segment(imgs, method="pixel")
    s = eng.stats()
    assert s["pixel_batches"] == 1
    assert s["pixel_batched_images"] == 3 and s["pixel_padded_lanes"] == 1
    for img, r in zip(imgs, results):
        solo = SV.solve(SV.pixel_problem(
            img.ravel().astype(np.float32), CFG), CFG)
        np.testing.assert_allclose(r.centers, np.asarray(solo.centers),
                                   atol=1e-5)
        assert (r.labels == np.asarray(solo.labels).reshape(40, 44)).all()


# ---------------------------------------------------------------------------
# Device-resident route programs (single-dispatch serving pipeline)
# ---------------------------------------------------------------------------

def test_fused_program_matches_staged_route_path():
    """The single-dispatch histogram program must serve exactly what the
    staged build_problem -> solve_batched -> materialize path serves."""
    from repro.serving import fcm_engine as E

    imgs = [phantom.phantom_slice(48, 56, noise=2.0 + i, seed=i)[0]
            for i in range(5)]
    fused = FCMServeEngine(CFG, batch_sizes=(8,), cache_size=0)
    res_fused = fused.segment(imgs)
    assert fused.stats()["compiled_programs"] == 1

    # Staged comparator: same route minus the program hooks.
    base = E.ROUTES["histogram"]
    E.register_route(dataclasses.replace(base, program_key=None,
                                         make_program=None))
    try:
        staged = FCMServeEngine(CFG, batch_sizes=(8,), cache_size=0)
        res_staged = staged.segment(imgs)
        assert staged.stats()["compiled_programs"] == 0
    finally:
        E.register_route(base)
    for f, s in zip(res_fused, res_staged):
        np.testing.assert_allclose(f.centers, s.centers, atol=1e-5)
        assert f.n_iters == s.n_iters
        assert (f.labels == s.labels).all()


def test_fused_program_mixed_sizes_one_dispatch():
    """Heterogeneous payload sizes still share ONE solve via the
    histograms-only program flavor."""
    imgs = [phantom.phantom_slice(64 + 8 * i, 96, seed=i)[0]
            for i in range(4)]
    eng = FCMServeEngine(CFG, batch_sizes=(4,), cache_size=0)
    results = eng.segment(imgs)
    assert eng.stats()["batches"] == 1
    for img, r in zip(imgs, results):
        x = img.ravel().astype(np.float32)
        single = SV.solve(SV.histogram_problem(x, CFG), backend="reference")
        np.testing.assert_allclose(r.centers, np.asarray(single.centers),
                                   atol=1e-4)
        lab = F.labels_from_centers(jnp.asarray(x), single.centers)
        assert (r.labels == np.asarray(lab).reshape(img.shape)).all()


def test_fused_spatial_program_matches_staged_route_path():
    """The spatial route now compiles a fused stencil program (whole
    batched convergence in one launch); it must serve exactly what the
    staged build_problem -> solve_batched -> materialize path serves."""
    from repro.serving import fcm_engine as E

    imgs = [phantom.phantom_slice(40, 48, noise=2.0 + i, seed=i)[0]
            for i in range(3)]
    fused = FCMServeEngine(CFG, batch_sizes=(4,), cache_size=0,
                           trace_ring=8)
    res_fused = fused.segment(imgs, method="spatial")
    assert fused.stats()["compiled_programs"] == 1
    buckets = [c for t in fused.tracer.traces() if t["name"] == "flush"
               for c in t["children"] if c["name"] == "bucket"
               and c["attrs"]["route"] == "spatial"]
    assert buckets and buckets[-1]["attrs"]["fused"] is True
    assert [c["name"] for c in buckets[-1]["children"]] == [
        "gather", "launch", "scatter"]

    base = E.ROUTES["spatial"]
    E.register_route(dataclasses.replace(base, program_key=None,
                                         make_program=None))
    try:
        staged = FCMServeEngine(CFG, batch_sizes=(4,), cache_size=0)
        res_staged = staged.segment(imgs, method="spatial")
        assert staged.stats()["compiled_programs"] == 0
    finally:
        E.register_route(base)
    for f, s in zip(res_fused, res_staged):
        np.testing.assert_allclose(f.centers, s.centers, atol=1e-5)
        assert f.n_iters == s.n_iters
        assert (f.labels == s.labels).all()


def test_program_cache_reused_across_flushes_and_engines():
    imgs = [phantom.phantom_slice(32, 32, noise=2.0 + i, seed=i)[0]
            for i in range(3)]
    eng = FCMServeEngine(CFG, batch_sizes=(4,), cache_size=0)
    eng.segment(imgs)
    eng.segment(imgs)
    assert eng.stats()["compiled_programs"] == 1      # same shape key
    eng.segment([phantom.phantom_slice(16, 16, seed=9)[0]])
    assert eng.stats()["compiled_programs"] == 2      # new payload size


def test_program_cache_evicts_on_route_reregistration():
    """Regression: register_route replacing a spec must not leave an
    engine serving the old spec's compiled program."""
    from repro.serving import fcm_engine as E

    img, _ = phantom.phantom_slice(32, 32, seed=3)
    eng = FCMServeEngine(CFG, batch_sizes=(1,), cache_size=0)
    first = eng.segment([img])[0]
    assert eng.stats()["compiled_programs"] == 1

    base = E.ROUTES["histogram"]
    calls = []

    def make_program(e, key, bucket):
        calls.append(key)
        return base.make_program(e, key, bucket)

    E.register_route(dataclasses.replace(base, make_program=make_program))
    try:
        again = eng.segment([img])[0]
        assert calls, "stale compiled program served after re-registration"
        np.testing.assert_allclose(again.centers, first.centers, atol=1e-6)
        assert (again.labels == first.labels).all()
        # the old generation's entry was purged, not orphaned
        assert eng.stats()["compiled_programs"] == 1
    finally:
        E.register_route(base)


def test_stage_seconds_breakdown_in_stats():
    eng = FCMServeEngine(CFG)
    s = eng.stats()["stage_seconds"]
    assert set(s) == set(eng.stats()["method_requests"])
    for route_stages in s.values():
        assert set(route_stages) == {"ingest", "solve", "materialize"}
    img, _ = phantom.phantom_slice(32, 32, seed=0)
    eng.segment([img])
    eng.segment([img], method="spatial")
    s = eng.stats()["stage_seconds"]
    assert s["histogram"]["ingest"] >= 0 and s["histogram"]["solve"] > 0
    assert s["spatial"]["solve"] > 0


def test_histogram_materialize_lut_matches_labels_from_centers():
    """Satellite: the np defuzzify LUT used for cache hits / duplicates
    is numerically identical to the old jnp labels_from_centers path."""
    import jax.numpy as jnp
    from repro.serving.fcm_engine import _label_lut

    rng = np.random.default_rng(0)
    for _ in range(5):
        centers = np.sort(rng.uniform(0, 255, 4)).astype(np.float32)
        vals = jnp.arange(256, dtype=jnp.float32)
        want = np.asarray(F.labels_from_centers(vals, jnp.asarray(centers)))
        np.testing.assert_array_equal(_label_lut(centers, 256), want)
    # exact ties resolve to the lowest cluster index in both
    centers = np.asarray([10.0, 30.0, 20.0], np.float32)
    vals = jnp.arange(256, dtype=jnp.float32)
    np.testing.assert_array_equal(
        _label_lut(centers, 256),
        np.asarray(F.labels_from_centers(vals, jnp.asarray(centers))))


def test_pixel_materialize_fused_labels_match_full_membership_path():
    """Satellite: pixel-route labels via the fused argmin kernel path
    equal the old materialize-the-membership-then-argmax path."""
    import jax.numpy as jnp
    from repro.kernels import ops as kops

    rng = np.random.default_rng(1)
    x = rng.uniform(0, 255, 4000).astype(np.float32)
    v = np.sort(rng.uniform(10, 240, 4)).astype(np.float32)
    old = np.asarray(F.defuzzify(F.update_membership(
        jnp.asarray(x), jnp.asarray(v), 2.0)))
    new = np.asarray(kops.defuzzify_labels(jnp.asarray(x), jnp.asarray(v)))
    np.testing.assert_array_equal(new, old)
    # and through the kernel itself (interpret mode)
    kern = np.asarray(kops.defuzzify_labels_batched(
        jnp.asarray(x)[None], jnp.asarray(v)[None],
        impl="pallas", interpret=True))[0]
    np.testing.assert_array_equal(kern, old)


def test_uint8_zero_copy_ingest_matches_clipped_path():
    """uint8 payloads skip the clip pass; results must match a clipped
    int submission of the same values."""
    img_u8 = phantom.phantom_slice(40, 40, seed=7)[0]
    assert img_u8.dtype == np.uint8
    eng = FCMServeEngine(CFG, cache_size=0)
    a = eng.segment([img_u8])[0]
    b = eng.segment([img_u8.astype(np.int32)])[0]
    np.testing.assert_allclose(a.centers, b.centers, atol=0)
    assert (a.labels == b.labels).all()


def test_route_registration_roundtrip():
    """A new serving method costs one RouteSpec registration: flush,
    bucketing and stats need no engine changes."""
    from repro.serving import fcm_engine as E

    base = E.ROUTES["histogram"]
    spec = E.RouteSpec(name="histogram-shadow", ingest=base.ingest,
                       bucket_key=base.bucket_key,
                       build_problem=base.build_problem,
                       materialize=base.materialize,
                       cacheable=False, stats_prefix="histogram_shadow")
    E.register_route(spec)
    try:
        assert "histogram-shadow" in E.METHODS
        eng = FCMServeEngine(CFG)
        img, _ = phantom.phantom_slice(32, 32, seed=0)
        res = eng.segment([img], method="histogram-shadow")[0]
        direct = eng.segment([img])[0]
        np.testing.assert_allclose(res.centers, direct.centers, atol=1e-5)
        s = eng.stats()
        assert s["histogram_shadow_batches"] == 1
        assert s["method_requests"]["histogram-shadow"] == 1
    finally:
        del E.ROUTES["histogram-shadow"]
        E.METHODS = tuple(E.ROUTES)


# ---------------------------------------------------------------------------
# Observability layer (PR 6)
# ---------------------------------------------------------------------------

def test_stats_latency_percentiles_per_route(volume):
    eng = FCMServeEngine(CFG, batch_sizes=(4, 16), cache_size=0)
    eng.segment(volume)
    lat = eng.stats()["latency"]["histogram"]
    assert lat["count"] == len(volume)       # one sample per request
    for k in ("p50", "p90", "p99", "mean", "min", "max"):
        assert lat[k] is not None and lat[k] > 0.0
    assert lat["min"] <= lat["p50"] <= lat["p99"] <= lat["max"]
    # untouched routes keep an empty (schema'd) histogram
    assert eng.stats()["latency"]["spatial"]["count"] == 0


def test_stats_convergence_per_route(volume):
    eng = FCMServeEngine(CFG, batch_sizes=(4, 16), cache_size=0)
    results = eng.segment(volume)
    conv = eng.stats()["convergence"]["histogram"]
    iters = [r.n_iters for r in results]
    assert conv["lanes"] == len(volume)
    assert conv["mean_iters"] == pytest.approx(np.mean(iters), abs=1e-6)
    assert conv["p50_iters"] == pytest.approx(np.percentile(iters, 50),
                                              abs=1.0)
    # the residual is the center-movement delta at the final accepted
    # iteration (convergence itself gates on membership change)
    assert conv["last_final_delta"] is not None
    assert np.isfinite(conv["last_final_delta"])
    assert conv["last_final_delta"] >= 0.0
    # a route that never solved reports no residual
    assert eng.stats()["convergence"]["pixel"]["last_final_delta"] is None


def test_cache_hits_do_not_pollute_convergence(volume):
    eng = FCMServeEngine(CFG, batch_sizes=(1, 8))
    eng.segment([volume[0]])
    eng.segment([volume[0]])                 # cache hit: no solve ran
    conv = eng.stats()["convergence"]["histogram"]
    assert conv["lanes"] == 1
    lat = eng.stats()["latency"]["histogram"]
    assert lat["count"] == 2                 # but both requests have latency


def test_reset_stats_zeroes_but_keeps_schema(volume):
    eng = FCMServeEngine(CFG, batch_sizes=(4, 16), cache_size=0)
    eng.segment(volume)
    before = eng.stats()
    assert before["requests"] == len(volume)
    eng.reset_stats()
    after = eng.stats()
    assert set(after) == set(before)         # same schema
    assert after["requests"] == 0 and after["batches"] == 0
    assert after["latency"]["histogram"]["count"] == 0
    assert after["convergence"]["histogram"]["lanes"] == 0
    assert eng.tracer.traces() == []
    # and the engine keeps serving after a reset
    res = eng.segment([volume[0]])[0]
    assert res.labels.shape == volume[0].shape
    assert eng.stats()["requests"] == 1


def test_snapshot_is_plain_json(volume):
    import json as _json
    eng = FCMServeEngine(CFG, batch_sizes=(4, 16))
    eng.segment(volume)
    eng.segment([volume[0]], method="spatial")
    snap = eng.snapshot()
    _json.dumps(snap)                        # no numpy scalars anywhere
    assert set(snap) == {"stats", "metrics", "traces"}
    assert snap["stats"]["requests"] == len(volume) + 1
    assert "route.latency_seconds{route=histogram}" in \
        snap["metrics"]["histograms"]


def test_trace_ring_records_flush_tree(volume):
    eng = FCMServeEngine(CFG, batch_sizes=(4, 16), cache_size=0,
                         trace_ring=8)
    eng.segment(volume[:4])
    flushes = [t for t in eng.tracer.traces() if t["name"] == "flush"]
    assert flushes
    bucket = flushes[-1]["children"][0]
    assert bucket["name"] == "bucket"
    assert bucket["attrs"]["route"] == "histogram"
    assert bucket["attrs"]["n"] == 4
    stages = [c["name"] for c in bucket["children"]]
    assert "scatter" in stages or "solve" in stages
    launch = [c for c in bucket["children"]
              if c["name"] in ("launch", "solve")][0]
    assert launch.get("device_s") is not None  # fenced device time


def test_tracing_disabled_keeps_stats_but_no_traces(volume):
    eng = FCMServeEngine(CFG, batch_sizes=(4, 16), cache_size=0,
                         tracing=False)
    eng.segment(volume)
    s = eng.stats()
    assert s["requests"] == len(volume)
    assert s["latency"]["histogram"]["count"] == len(volume)
    assert s["stage_seconds"]["histogram"]["solve"] > 0
    assert eng.tracer.traces() == []


def test_compress_seconds_accounted_per_route():
    """Satellite: compress used to land in one global stats key; it is
    now a per-route stage counter surfaced through route.stat()."""
    rgb = np.stack([phantom.phantom_slice(48, 48, seed=i)[0]
                    for i in range(3)], axis=-1)
    eng = FCMServeEngine(CFG)
    eng.segment([rgb], method="superpixel")
    s = eng.stats()
    assert s["superpixel_compress_seconds"] > 0.0
    assert s["compress_seconds"] == pytest.approx(
        s["superpixel_compress_seconds"])
    # histogram traffic adds no compress time
    img, _ = phantom.phantom_slice(32, 32, seed=0)
    eng.segment([img])
    assert eng.stats()["compress_seconds"] == pytest.approx(
        eng.stats()["superpixel_compress_seconds"])
