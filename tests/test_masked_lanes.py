"""Active-lane masking in the batched solver: padding lanes freeze at
iteration 0 and can never perturb real lanes' trajectories — the
semantics ``core/distributed`` and ``core/batched`` rely on to keep
mesh-padded ragged batches bitwise-faithful to their unpadded solves."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import batched as B
from repro.core import fcm as F
from repro.core import solver as SV
from repro.data import phantom

CFG = F.FCMConfig(max_iters=300)


def _ragged_hists(n=3, size=40):
    imgs = [phantom.phantom_slice(size + 8 * z, size, noise=4.0,
                                  slice_pos=0.3 + 0.1 * z, seed=z)[0]
            for z in range(n)]
    return B.histograms_of(imgs)


def test_masked_while_inactive_lanes_frozen():
    v0 = jnp.asarray([[0.0, 1.0], [5.0, 9.0]], jnp.float32)
    step = lambda v: v * 0.5 + 1.0            # contraction, nontrivial
    tol = jnp.asarray([1e-6, 1e-6], jnp.float32)
    active = jnp.asarray([True, False])
    v, delta, iters, total = SV.masked_while_centers(
        step, v0, tol, 50, active=active)
    # Inactive lane: v0 verbatim, 0 iterations, 0.0 residual.
    np.testing.assert_array_equal(np.asarray(v)[1], np.asarray(v0)[1])
    assert int(np.asarray(iters)[1]) == 0
    assert float(np.asarray(delta)[1]) == 0.0
    # Active lane: identical to the unmasked solo run.
    v_solo, d_solo, it_solo, _ = SV.masked_while_centers(
        step, v0[:1], tol[:1], 50)
    np.testing.assert_array_equal(np.asarray(v)[0], np.asarray(v_solo)[0])
    assert int(np.asarray(iters)[0]) == int(np.asarray(it_solo)[0])
    assert int(total) == int(np.asarray(iters)[0])


def test_masked_none_is_bitwise_preexisting_behavior():
    hists = _ragged_hists()
    feats = jnp.broadcast_to(
        jnp.arange(256, dtype=jnp.float32)[None, :, None],
        hists.shape + (1,))
    a = SV.flat_batched_solve(feats, hists, 4, 2.0, 1e-4, 300)
    b = SV.flat_batched_solve(feats, hists, 4, 2.0, 1e-4, 300,
                              active=jnp.ones((hists.shape[0],), bool))
    for x, y in zip(a, b):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_flat_batched_padding_lanes_cannot_perturb_real_lanes():
    hists = _ragged_hists()
    nb = hists.shape[1]
    # Pad with an adversarial payload (all mass in one bin): with the
    # mask it must not change real lanes, iterate, or stretch `total`.
    spike = np.zeros((1, nb), np.float32)
    spike[0, 0] = 1e6
    padded = jnp.concatenate([hists, jnp.asarray(spike)])
    active = jnp.asarray([True] * hists.shape[0] + [False])

    def solve(h, act=None):
        feats = jnp.broadcast_to(
            jnp.arange(nb, dtype=jnp.float32)[None, :, None],
            h.shape + (1,))
        return SV.flat_batched_solve(feats, h, 4, 2.0, 1e-4, 300,
                                     active=act)

    v_ref, d_ref, it_ref, tot_ref = solve(hists)
    v, d, it, tot = solve(padded, active)
    np.testing.assert_array_equal(np.asarray(v)[:-1], np.asarray(v_ref))
    np.testing.assert_array_equal(np.asarray(it)[:-1], np.asarray(it_ref))
    assert int(np.asarray(it)[-1]) == 0
    assert int(tot) == int(tot_ref)


def test_solve_batched_parity_on_mesh_padded_ragged_batch():
    # The exact contract fit_batched_sharded depends on: solving the
    # padded batch with the mask == solving the unpadded batch, per
    # lane, including iteration counts.
    hists = _ragged_hists(5)
    ref = SV.solve_batched(
        SV.batch_problems(B.hist_rows(hists), hists, cfg=CFG),
        backend="reference")
    pad = jnp.ones((3, hists.shape[1]), jnp.float32)   # 5 -> 8 lanes
    padded = jnp.concatenate([hists, pad])
    active = jnp.asarray([True] * 5 + [False] * 3)
    feats = jnp.broadcast_to(
        jnp.arange(256, dtype=jnp.float32)[None, :, None],
        padded.shape + (1,))
    v, delta, iters, _ = SV.flat_batched_solve(
        feats, padded, CFG.n_clusters, CFG.m, CFG.eps, CFG.max_iters,
        active=active)
    np.testing.assert_allclose(np.asarray(v)[:5, :, 0],
                               np.asarray(ref.centers), atol=1e-5)
    np.testing.assert_array_equal(np.asarray(iters)[:5],
                                  np.asarray(ref.n_iters))


def test_resident_impls_reject_active_mask():
    hists = _ragged_hists(2)
    feats = jnp.broadcast_to(
        jnp.arange(256, dtype=jnp.float32)[None, :, None],
        hists.shape + (1,))
    active = jnp.ones((2,), bool)
    for impl in ("resident", "resident_streamed"):
        with pytest.raises(ValueError, match="reference impl only"):
            SV.flat_batched_solve(feats, hists, 4, 2.0, 1e-4, 300,
                                  impl=impl, active=active)
