"""Per-architecture smoke tests: every assigned arch instantiates a
reduced same-family config and runs forward + one train-like grad step +
prefill/decode on CPU, asserting shapes and finiteness."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro import configs
from repro.models import lm

ARCHS = configs.list_archs()


def _batch_inputs(cfg, batch=2, seq=16, seed=0):
    rng = np.random.default_rng(seed)
    kwargs = {}
    tokens = jnp.asarray(rng.integers(0, cfg.vocab_size, (batch, seq)),
                         jnp.int32)
    if cfg.is_encdec:
        kwargs["frames"] = jnp.asarray(
            rng.normal(0, 1, (batch, seq, cfg.d_model)), jnp.float32)
    if cfg.n_img_tokens:
        kwargs["memory"] = jnp.asarray(
            rng.normal(0, 1, (batch, cfg.n_img_tokens, cfg.d_model)),
            cfg.dtype)
    return tokens, kwargs


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes_and_finite(arch):
    cfg = configs.get_config(arch).reduced()
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    tokens, kwargs = _batch_inputs(cfg)
    logits, aux = jax.jit(
        lambda p, t, kw: lm.forward(p, t, cfg, **kw))(params, tokens, kwargs)
    assert logits.shape == (2, 16, cfg.vocab_size)
    assert logits.dtype == jnp.float32
    assert bool(jnp.isfinite(logits).all()), arch
    assert bool(jnp.isfinite(aux)), arch


@pytest.mark.parametrize("arch", ARCHS)
def test_one_train_step_grads_finite(arch):
    cfg = configs.get_config(arch).reduced()
    params = lm.init_params(jax.random.PRNGKey(1), cfg)
    tokens, kwargs = _batch_inputs(cfg, seed=1)
    labels = jnp.roll(tokens, -1, axis=1)

    def loss_fn(p):
        logits, aux = lm.forward(p, tokens, cfg, **kwargs)
        logp = jax.nn.log_softmax(logits, axis=-1)
        nll = -jnp.take_along_axis(logp, labels[..., None], -1).mean()
        return nll + 0.01 * aux

    loss, grads = jax.jit(jax.value_and_grad(loss_fn))(params)
    assert bool(jnp.isfinite(loss))
    flat = jax.tree_util.tree_leaves(grads)
    assert all(bool(jnp.isfinite(g).all()) for g in flat), arch
    # embedding must receive signal
    gnorm = float(jnp.linalg.norm(grads["embed"]["table"]))
    assert gnorm > 0.0


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_decode_consistency(arch):
    """Greedy decode after prefill must match teacher-forced argmax of the
    train forward on the same token stream (cache correctness)."""
    cfg = configs.get_config(arch).reduced()
    params = lm.init_params(jax.random.PRNGKey(2), cfg)
    batch, prompt_len, total_len = 2, 8, 12
    tokens, kwargs = _batch_inputs(cfg, batch, total_len, seed=2)

    logits_full, _ = jax.jit(
        lambda p, t, kw: lm.forward(p, t, cfg, **kw))(params, tokens, kwargs)

    cache = lm.init_cache(cfg, batch, total_len)
    pre_logits, cache = jax.jit(
        lambda p, t, c, kw: lm.prefill(p, t, c, cfg, **kw))(
        params, tokens[:, :prompt_len], cache, kwargs)
    np.testing.assert_allclose(
        np.asarray(pre_logits[:, 0]),
        np.asarray(logits_full[:, prompt_len - 1]), rtol=2e-2, atol=2e-2)

    step = jax.jit(lambda p, t, c, pos: lm.decode_step(p, t, c, pos, cfg))
    for pos in range(prompt_len, total_len):
        logits_t, cache = step(params, tokens[:, pos:pos + 1], cache, pos)
        np.testing.assert_allclose(np.asarray(logits_t[:, 0]),
                                   np.asarray(logits_full[:, pos]),
                                   rtol=2e-2, atol=2e-2)


def test_param_spec_trees_match_param_trees():
    """Every arch: the logical-spec tree must be structurally identical to
    the param tree (guards spec drift)."""
    for arch in ARCHS:
        cfg = configs.get_config(arch).reduced()
        params = lm.abstract_params(cfg)
        specs = lm.param_specs(cfg)
        ps = jax.tree_util.tree_structure(params)
        ss = jax.tree_util.tree_structure(
            specs, is_leaf=lambda x: isinstance(x, tuple))
        assert ps == ss, arch


def test_cache_spec_trees_match_cache_trees():
    for arch in ARCHS:
        cfg = configs.get_config(arch).reduced()
        cache = jax.eval_shape(lambda: lm.init_cache(cfg, 2, 8))
        specs = lm.cache_specs(cfg)
        cs = jax.tree_util.tree_structure(cache)
        ss = jax.tree_util.tree_structure(
            specs, is_leaf=lambda x: isinstance(x, tuple))
        assert cs == ss, arch


def test_full_configs_match_assignment():
    """Pin the exact assigned hyper-parameters."""
    c = configs.get_config("mistral-nemo-12b")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff,
            c.vocab_size) == (40, 5120, 32, 8, 14336, 131072)
    c = configs.get_config("mistral-large-123b")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff,
            c.vocab_size) == (88, 12288, 96, 8, 28672, 32768)
    c = configs.get_config("deepseek-v2-236b")
    assert c.moe.n_experts == 160 and c.moe.top_k == 6
    assert c.mla.kv_lora_rank == 512 and c.moe.n_shared == 2
    c = configs.get_config("granite-moe-3b-a800m")
    assert c.moe.n_experts == 40 and c.moe.top_k == 8
    c = configs.get_config("jamba-v0.1-52b")
    assert c.moe.n_experts == 16 and c.moe.top_k == 2
    mixers = [d.mixer for d in c.group_layout]
    assert mixers.count("gqa") == 1 and mixers.count("mamba") == 7
    c = configs.get_config("llama-3.2-vision-90b")
    assert c.n_layers == 100
    assert sum(d.mixer == "cross" for d in c.group_layout) == 1
    c = configs.get_config("rwkv6-1.6b")
    assert c.sub_quadratic and c.group_layout[0].mixer == "rwkv6"
