"""Mesh-sharded serving: RouteProgram launches sharded over an 8-device
fake mesh must serve results identical to the single-device engine,
sync and async. Runs in a subprocess because device count is locked at
first jax init."""
import os
import subprocess
import sys

import pytest

HERE = os.path.dirname(os.path.abspath(__file__))


@pytest.mark.slow
def test_mesh_serving_matches_single_device():
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run(
        [sys.executable, os.path.join(HERE, "_mesh_serve_runner.py")],
        capture_output=True, text=True, env=env, timeout=600)
    assert proc.returncode == 0, proc.stdout + "\n" + proc.stderr
    assert "MESH_SERVE_OK" in proc.stdout
