"""VMEM-resident whole-solve kernel: parity suite.

The resident kernel runs the COMPLETE convergence loop inside one
``pallas_call`` (interpret mode here), so the bar is higher than
step-level parity: against ``solve()``/``solve_batched()`` it must match
**center-for-center** (<= 1e-5; relative, since a 3e-5 absolute drift on
a ~200-valued f32 center is sub-ulp reduction-order noise) and
**iteration-for-iteration** — the in-kernel ``max|v' - v| < tol`` test
must fire on exactly the same iteration as the reference loop. Plus the
registry dispatch contract: eligibility bounds enforced, ``"resident"``
falling back to ``"reference"`` off-TPU.
"""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import batched as B
from repro.core import solver as SV
from repro.data import phantom
from repro.kernels import fcm_resident as KR
from repro.kernels import ops as kops

ATOL = 1e-5
RTOL = 1e-5


def _assert_centers(got, want):
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=RTOL, atol=ATOL)


# ---------------------------------------------------------------------------
# Single-problem parity (solve(backend="resident", interpret=True))
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("shape", [(37, 53), (45, 59), (64, 64)])
def test_histogram_resident_matches_reference(shape):
    img, _ = phantom.phantom_slice(*shape, seed=shape[0])
    x = img.ravel().astype(np.float32)
    ref = SV.solve(SV.histogram_problem(x), max_iters=300)
    res = SV.solve(SV.histogram_problem(x), backend="resident",
                   interpret=True, max_iters=300)
    _assert_centers(res.centers, ref.centers)
    assert res.n_iters == ref.n_iters
    assert (np.asarray(res.labels) == np.asarray(ref.labels)).all()


@pytest.mark.parametrize("c", [2, 4, 8])
def test_resident_cluster_count_sweep(c):
    rng = np.random.default_rng(c)
    x = rng.integers(0, 256, 3000).astype(np.float32)
    ref = SV.solve(SV.histogram_problem(x, c=c))
    res = SV.solve(SV.histogram_problem(x, c=c), backend="resident",
                   interpret=True)
    _assert_centers(res.centers, ref.centers)
    assert res.n_iters == ref.n_iters


@pytest.mark.parametrize("k,d", [(73, 3), (200, 2), (256, 1)])
def test_vector_resident_matches_reference(k, d):
    """Weighted (K, D) rows — the superpixel-compression payload."""
    rng = np.random.default_rng(k + d)
    feats = rng.uniform(0, 255, (k, d)).astype(np.float32)
    w = rng.integers(1, 40, k).astype(np.float32)
    ref = SV.solve(SV.vector_problem(feats, w))
    res = SV.solve(SV.vector_problem(feats, w), backend="resident",
                   interpret=True)
    _assert_centers(res.centers, ref.centers)
    assert res.n_iters == ref.n_iters


def test_resident_ragged_row_counts():
    """Non-128-multiple row counts pad at zero weight — inert rows."""
    rng = np.random.default_rng(5)
    for k in (17, 100, 129, 255):
        vals = np.sort(rng.uniform(0, 255, k)).astype(np.float32)
        w = rng.integers(1, 20, k).astype(np.float32)
        ref = SV.solve(SV.vector_problem(vals[:, None], w))
        res = SV.solve(SV.vector_problem(vals[:, None], w),
                       backend="resident", interpret=True)
        _assert_centers(res.centers, ref.centers)
        assert res.n_iters == ref.n_iters, f"k={k}"


def test_resident_tol_override_forces_fixed_iterations():
    img, _ = phantom.phantom_slice(21, 27, seed=13)
    x = img.ravel().astype(np.float32)
    res = SV.solve(SV.histogram_problem(x), backend="resident",
                   interpret=True, tol=-1.0, max_iters=7)
    assert res.n_iters == 7


# ---------------------------------------------------------------------------
# Batched parity (per-lane trajectories == solo solves)
# ---------------------------------------------------------------------------

def test_batched_resident_lanes_match_reference_and_solo():
    imgs = [phantom.phantom_slice(37 + 6 * i, 53, noise=2.0 + 3 * i,
                                  seed=i)[0] for i in range(4)]
    hists = B.histograms_of(imgs)
    batch = SV.batch_problems(B.hist_rows(hists), hists)
    ref = SV.solve_batched(batch)
    res = SV.solve_batched(batch, backend="resident", interpret=True)
    _assert_centers(res.centers, ref.centers)
    np.testing.assert_array_equal(res.n_iters, ref.n_iters)
    assert res.total_iters == ref.total_iters
    for i, img in enumerate(imgs):
        solo = SV.solve(SV.histogram_problem(
            img.ravel().astype(np.float32)))
        np.testing.assert_allclose(np.asarray(res.centers[i]),
                                   np.asarray(solo.centers),
                                   rtol=1e-4, atol=1e-4)
        assert res.n_iters[i] == solo.n_iters


def test_batched_resident_divergent_lane_iterations():
    """Each grid step runs its lane to ITS OWN convergence — no frozen
    masking; verify lanes genuinely stop at different counts."""
    imgs = [phantom.phantom_slice(48, 48, noise=1.0 + 6 * i, seed=i)[0]
            for i in range(3)]
    hists = B.histograms_of(imgs)
    batch = SV.batch_problems(B.hist_rows(hists), hists)
    res = SV.solve_batched(batch, backend="resident", interpret=True)
    assert len(set(res.n_iters.tolist())) > 1
    assert res.total_iters == int(res.n_iters.max())


# ---------------------------------------------------------------------------
# Registry dispatch
# ---------------------------------------------------------------------------

def test_resident_registered_with_bounds():
    impl = kops.select_step("flat", prefer="resident", platform="tpu",
                            n_rows=256, c=8)
    assert impl.name == "resident"
    assert impl.max_rows == 256 and impl.max_c == 8


def test_resident_falls_back_to_reference_off_tpu():
    """The documented off-TPU behavior: prefer="resident" degrades to
    the reference step instead of erroring or interpreting."""
    impl = kops.select_step("flat", prefer="resident", platform="cpu",
                            n_rows=256, c=4)
    assert impl.name == "reference"
    # and solve(backend="resident") without interpret matches the
    # reference backend bit-for-bit (it IS the reference backend here)
    img, _ = phantom.phantom_slice(33, 35, seed=5)
    x = img.ravel().astype(np.float32)
    ref = SV.solve(SV.histogram_problem(x), backend="reference")
    res = SV.solve(SV.histogram_problem(x), backend="resident")
    np.testing.assert_array_equal(np.asarray(res.centers),
                                  np.asarray(ref.centers))
    assert res.n_iters == ref.n_iters


def test_resident_auto_dispatch_on_tpu_when_fits():
    # auto picks resident on TPU only when rows/c/D fit VMEM ...
    assert kops.select_step("flat", platform="tpu", n_feat=1,
                            n_rows=256, c=4).name == "resident"
    assert kops.select_step("flat", platform="tpu", n_feat=3,
                            n_rows=200, c=4, batched=True
                            ).name == "resident"
    # ... hands rows beyond the small-kernel bound to the HBM-streamed
    # resident variant ...
    assert kops.select_step("flat", platform="tpu", n_feat=1,
                            n_rows=100000, c=4).name == "resident_streamed"
    # ... and falls through (pallas / reference) when neither fits.
    assert kops.select_step("flat", platform="tpu", n_feat=1,
                            n_rows=KR.STREAM_MAX_ROWS + 1, c=4
                            ).name == "pallas"
    assert kops.select_step("flat", platform="tpu", n_feat=1,
                            n_rows=256, c=16).name == "pallas"
    # unknown row count (legacy callers) can never claim residency
    assert kops.select_step("flat", platform="tpu", n_feat=1
                            ).name == "pallas"
    # off-TPU auto stays on the reference step
    assert kops.select_step("flat", platform="cpu", n_feat=1,
                            n_rows=256, c=4).name == "reference"


def test_resident_rejects_oversized_problems():
    # beyond even the HBM-streamed row bound
    x = np.zeros(KR.STREAM_MAX_ROWS + 128, dtype=np.float32)
    x[:64] = np.arange(64)
    with pytest.raises(ValueError, match="VMEM-resident"):
        SV.solve(SV.pixel_problem(x), backend="resident")
    rng = np.random.default_rng(0)
    feats = rng.uniform(0, 1, (64, 16)).astype(np.float32)
    with pytest.raises(ValueError, match="VMEM-resident"):
        SV.solve(SV.vector_problem(feats), backend="resident")


def test_resident_stencil_dispatch_and_parity():
    """backend="resident" on a stencil problem selects the resident
    FCM_S kernel on TPU, and (interpret mode) matches the jnp stencil
    reference center-for-center."""
    impl = kops.select_step("stencil", prefer="resident", platform="tpu",
                            n_rows=32 * 32, c=4)
    assert impl.name == "resident"
    img, _ = phantom.phantom_slice(31, 33, seed=9)
    ref = SV.solve(SV.spatial_problem(img), backend="reference")
    res = SV.solve(SV.spatial_problem(img), backend="resident",
                   interpret=True)
    np.testing.assert_allclose(np.asarray(res.centers),
                               np.asarray(ref.centers),
                               rtol=1e-5, atol=1e-5)
    assert res.n_iters == ref.n_iters
