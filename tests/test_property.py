"""Property-based tests (hypothesis) on system invariants."""
import numpy as np
import jax.numpy as jnp
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="optional dep: pip install hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import fcm as F
from repro.core import histogram as H
from repro.core import solver as SV
from repro.core import spatial as S
from repro.training import grad_compress as gc

_settings = dict(max_examples=25, deadline=None)


@given(st.integers(2, 6), st.integers(16, 400),
       st.floats(1.3, 4.0), st.integers(0, 10 ** 6))
@settings(**_settings)
def test_membership_always_a_partition(c, n, m, seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.uniform(0, 255, n), jnp.float32)
    v = jnp.asarray(np.sort(rng.uniform(0, 255, c)), jnp.float32)
    u = F.update_membership(x, v, m)
    assert u.shape == (c, n)
    np.testing.assert_allclose(np.asarray(jnp.sum(u, axis=0)), 1.0,
                               atol=1e-4)
    assert float(jnp.min(u)) >= 0.0


@given(st.integers(2, 5), st.integers(32, 300), st.integers(0, 10 ** 6))
@settings(**_settings)
def test_centers_stay_in_data_hull(c, n, seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.uniform(10, 200, n), jnp.float32)
    res = SV.solve(SV.pixel_problem(x, c=c), backend="reference",
                   max_iters=50)
    v = np.asarray(res.centers)
    assert (v >= float(jnp.min(x)) - 1e-3).all()
    assert (v <= float(jnp.max(x)) + 1e-3).all()


@given(st.integers(0, 10 ** 6))
@settings(**_settings)
def test_histogram_step_equals_full_step_on_quantized(seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.integers(0, 256, 512).astype(np.float32))
    v = jnp.asarray(np.sort(rng.uniform(1, 254, 4)), jnp.float32)
    full = F.fused_center_step(x, v, 2.0)
    comp = H.weighted_center_step(jnp.arange(256, dtype=jnp.float32),
                                  H.intensity_histogram(x), v, 2.0)
    np.testing.assert_allclose(np.asarray(full), np.asarray(comp),
                               rtol=1e-4, atol=1e-2)


@given(st.integers(1, 64), st.integers(1, 64), st.integers(0, 10 ** 6),
       st.floats(1e-3, 1e3))
@settings(**_settings)
def test_int8_roundtrip_error_bound(rows, cols, seed, scale):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(0, scale, (rows, cols)), jnp.float32)
    q, s = gc.quantize_int8(x)
    back = gc.dequantize_int8(q, s)
    assert float(jnp.max(jnp.abs(back - x))) <= float(s) * 0.5 + 1e-9


@given(st.integers(2, 5), st.integers(2, 24), st.integers(2, 24),
       st.floats(0.0, 8.0), st.sampled_from([4, 8]),
       st.integers(0, 10 ** 6))
@settings(**_settings)
def test_spatial_membership_always_a_partition(c, h, w, alpha, neighbors,
                                               seed):
    """FCM_S memberships stay column-stochastic and in [0, 1] for any
    alpha / neighborhood arity / image."""
    rng = np.random.default_rng(seed)
    img = jnp.asarray(rng.uniform(0, 255, (h, w)), jnp.float32)
    v = jnp.asarray(np.sort(rng.uniform(0, 255, c)), jnp.float32)
    u = S.spatial_membership(img, v, 2.0, alpha, neighbors)
    assert u.shape == (c, h, w)
    np.testing.assert_allclose(np.asarray(jnp.sum(u, axis=0)), 1.0,
                               atol=1e-4)
    assert float(jnp.min(u)) >= 0.0
    assert float(jnp.max(u)) <= 1.0 + 1e-6


@given(st.integers(4, 20), st.integers(4, 20), st.sampled_from([4, 8]),
       st.sampled_from([0, 1]), st.integers(0, 10 ** 6))
@settings(**_settings)
def test_fit_spatial_flip_equivariant(h, w, neighbors, axis, seed):
    """The stencils are mirror-symmetric, so flipping the image must
    flip the solution: same centers, mirrored memberships. Fixed
    iteration count (tiny eps) keeps both trajectories in lockstep."""
    rng = np.random.default_rng(seed)
    img = rng.integers(0, 256, (h, w)).astype(np.float32)
    cfg = S.SpatialFCMConfig(alpha=1.5, neighbors=neighbors,
                             eps=1e-12, max_iters=5)
    a = SV.solve(SV.spatial_problem(img, cfg), cfg, keep_membership=True)
    b = SV.solve(SV.spatial_problem(np.flip(img, axis=axis).copy(), cfg),
                 cfg, keep_membership=True)
    np.testing.assert_allclose(np.asarray(a.centers), np.asarray(b.centers),
                               rtol=1e-4, atol=1e-3)
    np.testing.assert_allclose(np.asarray(a.membership),
                               np.flip(np.asarray(b.membership),
                                       axis=axis + 1),
                               rtol=1e-3, atol=1e-3)


@given(st.integers(2, 4), st.integers(64, 256), st.integers(0, 10 ** 6))
@settings(**_settings)
def test_objective_never_increases_across_one_iteration(c, n, seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.uniform(0, 255, n), jnp.float32)
    key = __import__("jax").random.PRNGKey(seed % (2 ** 31))
    u = F.random_membership(key, c, n)
    v1 = F.update_centers(x, u, 2.0)
    u1 = F.update_membership(x, v1, 2.0)
    j0 = float(F.objective(x, u, v1, 2.0))
    j1 = float(F.objective(x, u1, v1, 2.0))
    assert j1 <= j0 * (1 + 1e-5)
