"""Property-based tests (hypothesis) on system invariants."""
import numpy as np
import jax.numpy as jnp
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="optional dep: pip install hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import fcm as F
from repro.core import histogram as H
from repro.training import grad_compress as gc

_settings = dict(max_examples=25, deadline=None)


@given(st.integers(2, 6), st.integers(16, 400),
       st.floats(1.3, 4.0), st.integers(0, 10 ** 6))
@settings(**_settings)
def test_membership_always_a_partition(c, n, m, seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.uniform(0, 255, n), jnp.float32)
    v = jnp.asarray(np.sort(rng.uniform(0, 255, c)), jnp.float32)
    u = F.update_membership(x, v, m)
    assert u.shape == (c, n)
    np.testing.assert_allclose(np.asarray(jnp.sum(u, axis=0)), 1.0,
                               atol=1e-4)
    assert float(jnp.min(u)) >= 0.0


@given(st.integers(2, 5), st.integers(32, 300), st.integers(0, 10 ** 6))
@settings(**_settings)
def test_centers_stay_in_data_hull(c, n, seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.uniform(10, 200, n), jnp.float32)
    res = F.fit_fused(x, F.FCMConfig(n_clusters=c, max_iters=50))
    v = np.asarray(res.centers)
    assert (v >= float(jnp.min(x)) - 1e-3).all()
    assert (v <= float(jnp.max(x)) + 1e-3).all()


@given(st.integers(0, 10 ** 6))
@settings(**_settings)
def test_histogram_step_equals_full_step_on_quantized(seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.integers(0, 256, 512).astype(np.float32))
    v = jnp.asarray(np.sort(rng.uniform(1, 254, 4)), jnp.float32)
    full = F.fused_center_step(x, v, 2.0)
    comp = H.weighted_center_step(jnp.arange(256, dtype=jnp.float32),
                                  H.intensity_histogram(x), v, 2.0)
    np.testing.assert_allclose(np.asarray(full), np.asarray(comp),
                               rtol=1e-4, atol=1e-2)


@given(st.integers(1, 64), st.integers(1, 64), st.integers(0, 10 ** 6),
       st.floats(1e-3, 1e3))
@settings(**_settings)
def test_int8_roundtrip_error_bound(rows, cols, seed, scale):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(0, scale, (rows, cols)), jnp.float32)
    q, s = gc.quantize_int8(x)
    back = gc.dequantize_int8(q, s)
    assert float(jnp.max(jnp.abs(back - x))) <= float(s) * 0.5 + 1e-9


@given(st.integers(2, 4), st.integers(64, 256), st.integers(0, 10 ** 6))
@settings(**_settings)
def test_objective_never_increases_across_one_iteration(c, n, seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.uniform(0, 255, n), jnp.float32)
    key = __import__("jax").random.PRNGKey(seed % (2 ** 31))
    u = F.random_membership(key, c, n)
    v1 = F.update_centers(x, u, 2.0)
    u1 = F.update_membership(x, v1, 2.0)
    j0 = float(F.objective(x, u, v1, 2.0))
    j1 = float(F.objective(x, u1, v1, 2.0))
    assert j1 <= j0 * (1 + 1e-5)
