"""HLO cost walker: trip-count multipliers, dot flops, collective wire
math — validated against hand-counted jitted programs."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.analysis import hlo_cost, roofline


def _text(fn, *args):
    return jax.jit(fn).lower(*args).compile().as_text()


def test_dot_flops_exact():
    a = jnp.zeros((128, 256), jnp.float32)
    b = jnp.zeros((256, 512), jnp.float32)
    c = hlo_cost.analyze_text(_text(lambda x, y: x @ y, a, b), 1)
    assert c.flops == 2 * 128 * 256 * 512


def test_scan_trip_count_multiplies():
    a = jnp.zeros((64, 64), jnp.float32)

    def body(x, _):
        return jnp.tanh(x @ x), None

    def once(x):
        return jnp.tanh(x @ x)

    def scanned(x):
        y, _ = jax.lax.scan(body, x, None, length=17)
        return y

    f1 = hlo_cost.analyze_text(_text(once, a), 1).flops
    f17 = hlo_cost.analyze_text(_text(scanned, a), 1).flops
    assert f1 == 2 * 64 ** 3
    assert f17 == pytest.approx(17 * f1, rel=1e-6)


def test_while_override():
    a = jnp.zeros((64, 64), jnp.float32)

    def scanned(x):
        y, _ = jax.lax.scan(lambda c, _: (jnp.tanh(c @ c), None), x,
                            None, length=9)
        return y

    txt = _text(scanned, a)
    f_override = hlo_cost.analyze_text(txt, 1, while_override=1).flops
    assert f_override == pytest.approx(2 * 64 ** 3, rel=1e-6)


def test_collective_wire_ring_math():
    model = hlo_cost.HloCostModel("", 8)
    # all-reduce of 100 bytes over 8: 2*(7/8)*100
    assert model._collective_wire("all-reduce", "f32[25]", "") == \
        pytest.approx(2 * 7 / 8 * 100)
    assert model._collective_wire("all-gather", "f32[25]", "") == \
        pytest.approx(7 / 8 * 100)
    assert model._collective_wire("reduce-scatter", "f32[25]", "") == \
        pytest.approx(7 * 100)
    assert model._collective_wire("collective-permute", "f32[25]", "") \
        == 100


def test_group_size_parsing():
    model = hlo_cost.HloCostModel("", 16)
    line = "x = f32[4] all-reduce(y), replica_groups=[4,4]"
    assert model._group_size(line) == 4
    line2 = "x = f32[4] all-reduce(y), replica_groups={{0,1,2,3,4,5,6,7}}"
    assert model._group_size(line2) == 8
    assert model._group_size("x = f32[4] all-reduce(y)") == 16


def test_bytes_counted_at_fusion_boundaries():
    a = jnp.zeros((1024,), jnp.float32)
    c = hlo_cost.analyze_text(_text(lambda x: jnp.tanh(x) * 2 + 1, a), 1)
    # one fused elementwise chain: ~input + output = 8 KB (allow copies)
    assert 4096 <= c.bytes <= 32768, c.bytes


def test_model_flops_moe_active_params():
    from repro import configs
    cfg = configs.get_config("granite-moe-3b-a800m")
    n = roofline.count_params(cfg)
    assert n["active"] < 0.55 * n["total"]      # 8/40 experts active
    shape = configs.SHAPES["train_4k"]
    mf = roofline.model_flops(cfg, shape)
    assert mf == pytest.approx(6 * n["active"] * 4096 * 256)


def test_dryrun_jsonl_exists_and_complete():
    """The committed dry-run results must cover every (arch x applicable
    shape x mesh) cell, all compiled OK."""
    import os
    from benchmarks.roofline_report import load
    from repro import configs as C
    rows = load()
    if not rows:
        pytest.skip("dryrun.jsonl not generated in this checkout")
    have = {(r["arch"], r["shape"], r["mesh"]) for r in rows}
    for arch in C.list_archs():
        for s in C.applicable_shapes(C.get_config(arch)):
            for mesh in ("16x16", "2x16x16"):
                assert (arch, s.name, mesh) in have, (arch, s.name, mesh)
    assert ("fcm-brainweb", "fcm_1g", "16x16") in have
