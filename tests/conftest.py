import os
import sys

# Make `import repro` work regardless of PYTHONPATH (tests are normally
# run with PYTHONPATH=src). Deliberately does NOT touch XLA_FLAGS: unit
# tests run on the single real CPU device; multi-device tests spawn
# subprocesses with their own flags (see tests/_dist_runner.py).
_SRC = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src")
if _SRC not in sys.path:
    sys.path.insert(0, _SRC)
