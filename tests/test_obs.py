"""Observability layer: histogram quantiles vs numpy percentiles, span
nesting/exception safety, JSON-safety, registry reset semantics, and
solver convergence telemetry (incl. per-lane parity on ragged
batches)."""
import json
import math

import numpy as np
import pytest

from repro import obs
from repro.core import fcm as F
from repro.core import solver as SV
from repro.data import phantom


# ---------------------------------------------------------------------------
# Histogram quantiles
# ---------------------------------------------------------------------------

def test_latency_quantiles_match_numpy_within_bucket_width():
    """Fixed log buckets (10^(1/8) steps): the interpolated quantile
    must land within one bucket ratio of the exact numpy percentile."""
    rng = np.random.default_rng(0)
    samples = np.exp(rng.normal(math.log(5e-3), 1.0, size=5000))
    h = obs.Histogram(obs.LATENCY_EDGES)
    for v in samples:
        h.record(v)
    ratio = 10.0 ** (1.0 / 8.0)
    for q in (0.50, 0.90, 0.99):
        exact = float(np.percentile(samples, 100 * q))
        got = h.quantile(q)
        assert exact / ratio <= got <= exact * ratio, (q, got, exact)


def test_iter_quantiles_exact_to_one_iteration():
    """Unit-spaced edges through 64: quantiles good to +-1 iter."""
    rng = np.random.default_rng(1)
    samples = rng.integers(1, 60, size=2000)
    h = obs.Histogram(obs.ITER_EDGES)
    for v in samples:
        h.record(int(v))
    for q in (0.50, 0.90, 0.99):
        exact = float(np.percentile(samples, 100 * q))
        assert abs(h.quantile(q) - exact) <= 1.0


def test_histogram_quantiles_clamped_to_observed_range():
    h = obs.Histogram(edges=(1.0, 2.0, 4.0))
    for v in (0.25, 0.25, 8.0):              # under- and overflow buckets
        h.record(v)
    assert h.quantile(0.0) >= 0.25
    assert h.quantile(1.0) <= 8.0
    s = h.snapshot()
    assert s["min"] == 0.25 and s["max"] == 8.0 and s["count"] == 3


def test_overflow_p99_tracks_numpy_not_last_edge():
    """Regression: p99 used to clamp at edges[-1] once samples spilled
    into the overflow bucket (easy with ITER_EDGES when max_iters
    exceeds the unit-spaced range). The overflow bucket's upper bound
    is the tracked vmax, so the interpolated quantile must stay within
    the overflow bucket's width of the exact numpy percentile — far
    beyond the last edge, not pinned to it."""
    last = obs.ITER_EDGES[-1]                # 512
    rng = np.random.default_rng(2)
    samples = rng.integers(last + 100, last + 500, size=4000)
    h = obs.Histogram(obs.ITER_EDGES)
    for v in samples:
        h.record(int(v))
    got, over = h.quantile_info(0.99)
    assert over is True
    assert got > last                        # not clamped at the edge
    exact = float(np.percentile(samples, 99))
    # one-bucket error bound: everything landed in [edges[-1], vmax]
    assert abs(got - exact) <= samples.max() - last
    s = h.snapshot()
    assert s["p99"] == got and s["p99_overflow"] is True


def test_quantiles_inside_edges_are_not_overflow_flagged():
    h = obs.Histogram(obs.ITER_EDGES)
    for v in (3, 5, 7, 9, 520):              # one overflow sample
        h.record(v)
    s = h.snapshot()
    assert s["p50_overflow"] is False
    got, over = h.quantile_info(1.0)         # the max IS the overflow
    assert over is True and 512 < got <= 520


def test_empty_histogram_snapshot_is_none_safe():
    s = obs.Histogram(obs.LATENCY_EDGES).snapshot()
    assert s["count"] == 0
    assert s["mean"] is None and s["p50"] is None and s["p99"] is None
    json.dumps(s)                            # and it serializes


def test_histogram_rejects_bad_edges_and_bad_q():
    with pytest.raises(ValueError):
        obs.Histogram(edges=(1.0, 1.0, 2.0))
    h = obs.Histogram(edges=(1.0, 2.0))
    h.record(1.5)
    with pytest.raises(ValueError):
        h.quantile(1.5)


def test_histogram_exact_mean_and_sum():
    h = obs.Histogram(obs.LATENCY_EDGES)
    for v in (0.001, 0.002, 0.003):
        h.record(v)
    assert h.snapshot()["sum"] == pytest.approx(0.006)
    assert h.snapshot()["mean"] == pytest.approx(0.002)


# ---------------------------------------------------------------------------
# Counters / gauges / registry
# ---------------------------------------------------------------------------

def test_counter_stays_python_int_for_int_feeds():
    c = obs.Counter()
    c.inc()
    c.inc(3)
    assert c.snapshot() == 4 and type(c.snapshot()) is int
    c.inc(0.5)                               # stage seconds -> float
    assert isinstance(c.snapshot(), float)


def test_registry_labels_key_distinct_metrics():
    reg = obs.MetricsRegistry()
    reg.counter("req", route="a").inc()
    reg.counter("req", route="b").inc(2)
    assert reg.counter("req", route="a").value == 1
    assert reg.counter("req", route="b").value == 2
    snap = reg.snapshot()
    assert snap["counters"]["req{route=a}"] == 1
    assert snap["counters"]["req{route=b}"] == 2


def test_registry_type_conflict_raises():
    reg = obs.MetricsRegistry()
    reg.counter("x")
    with pytest.raises(TypeError):
        reg.gauge("x")


def test_registry_reset_zeroes_in_place_keeping_schema():
    reg = obs.MetricsRegistry()
    c = reg.counter("n")
    g = reg.gauge("g")
    h = reg.histogram("h", edges=obs.ITER_EDGES, kind="flat")
    c.inc(7)
    g.set(3.5)
    h.record(12)
    reg.reset()
    assert c.value == 0 and g.value == 0.0 and h.count == 0
    snap = reg.snapshot()                    # keys survive the reset
    assert set(snap["counters"]) == {"n"}
    assert set(snap["histograms"]) == {"h{kind=flat}"}
    assert snap["histograms"]["h{kind=flat}"]["count"] == 0
    # the reset histogram still records into the same object
    reg.histogram("h", edges=obs.ITER_EDGES, kind="flat").record(3)
    assert h.count == 1


def test_registry_peek_never_creates():
    reg = obs.MetricsRegistry()
    assert reg.peek("nope", route="x") is None
    assert reg.snapshot() == {"counters": {}, "gauges": {},
                              "histograms": {}}
    reg.counter("yes").inc()
    assert reg.peek("yes").value == 1


def test_registry_to_json_round_trips():
    reg = obs.MetricsRegistry()
    reg.histogram("lat").record(0.01)
    reg.gauge("depth").set(2)
    assert json.loads(reg.to_json())["gauges"]["depth"] == 2.0


# ---------------------------------------------------------------------------
# json_safe
# ---------------------------------------------------------------------------

def test_json_safe_coerces_numpy_scalars_and_arrays():
    out = obs.json_safe({"a": np.float32(1.5), "b": np.int64(3),
                         "c": np.arange(3), "d": (1, 2),
                         "e": np.bool_(True)})
    json.dumps(out)
    assert out == {"a": 1.5, "b": 3, "c": [0, 1, 2], "d": [1, 2],
                   "e": True}
    assert type(out["b"]) is int


def test_json_safe_raises_on_unserializable():
    with pytest.raises(TypeError):
        obs.json_safe({"f": object()})


# ---------------------------------------------------------------------------
# Spans / tracer
# ---------------------------------------------------------------------------

def test_span_nesting_builds_tree_and_ring_keeps_roots_only():
    tr = obs.Tracer(max_traces=8)
    with tr.span("flush", queued=2):
        with tr.span("bucket", route="histogram", bucket=2):
            with tr.span("solve"):
                pass
            with tr.span("materialize"):
                pass
    traces = tr.traces()
    assert len(traces) == 1                  # only the root lands
    root = traces[0]
    assert root["name"] == "flush" and root["attrs"] == {"queued": 2}
    (bucket,) = root["children"]
    assert [c["name"] for c in bucket["children"]] == ["solve",
                                                       "materialize"]
    assert all(c["wall_s"] >= 0.0 for c in bucket["children"])
    json.dumps(traces)                       # trace records are plain JSON


def test_span_exception_marks_error_and_propagates():
    tr = obs.Tracer()
    with pytest.raises(ValueError, match="boom"):
        with tr.span("outer"):
            with tr.span("inner"):
                raise ValueError("boom")
    assert tr.current_span is None           # stack fully unwound
    root = tr.traces()[-1]
    assert root["status"] == "error" and "boom" in root["error"]
    inner = root["children"][0]
    assert inner["status"] == "error" and inner["wall_s"] is not None
    with tr.span("after"):                   # tracer still usable
        pass
    assert tr.traces()[-1]["name"] == "after"


def test_disabled_tracer_times_but_records_nothing():
    reg = obs.MetricsRegistry()
    tr = obs.Tracer(enabled=False, metrics=reg)
    with tr.span("solve") as sp:
        pass
    assert sp.wall_s is not None             # timing still works
    assert tr.traces() == []
    assert reg.snapshot()["histograms"] == {}


def test_ring_false_skips_ring_but_feeds_metrics():
    reg = obs.MetricsRegistry()
    tr = obs.Tracer(metrics=reg)
    with tr.span("ingest", ring=False):
        pass
    assert tr.traces() == []
    assert reg.peek("span_seconds", span="ingest").count == 1


def test_ring_buffer_caps_at_max_traces():
    tr = obs.Tracer(max_traces=3)
    for i in range(5):
        with tr.span(f"s{i}"):
            pass
    assert [t["name"] for t in tr.traces()] == ["s2", "s3", "s4"]
    tr.clear()
    assert tr.traces() == []


def test_span_fence_records_device_time():
    import jax.numpy as jnp
    tr = obs.Tracer()
    with tr.span("launch") as sp:
        out = sp.fence(jnp.arange(8) * 2)
    assert int(out[3]) == 6
    assert sp.device_s is not None and sp.device_s <= sp.wall_s


# ---------------------------------------------------------------------------
# Solver convergence telemetry
# ---------------------------------------------------------------------------

CFG = F.FCMConfig(max_iters=300)


def test_solve_records_iters_and_residual():
    reg = obs.default_registry()
    reg.reset()
    img = phantom.phantom_slice(48, 48, noise=3.0, seed=0)[0]
    res = SV.solve(SV.histogram_problem(img.ravel().astype(np.float32),
                                        CFG), CFG)
    h = reg.peek("solver.iters", kind="flat")
    assert h is not None and h.count == 1
    assert h.quantile(0.5) == pytest.approx(res.n_iters, abs=1.0)
    g = reg.peek("solver.last_final_delta", kind="flat")
    assert g is not None and g.value == pytest.approx(res.final_delta)


def test_batched_telemetry_matches_per_lane_iters_on_ragged_batch():
    """Per-lane masked iteration counts land in the histogram: on a
    ragged batch the recorded lane iters must equal the result's
    n_iters lane for lane — not B copies of the shared trip count."""
    reg = obs.default_registry()
    reg.reset()
    imgs = [phantom.phantom_slice(40 + 8 * i, 64, noise=2.0 + 3 * i,
                                  seed=i)[0] for i in range(3)]
    from repro.core import batched as B
    hists = B.histograms_of(imgs)
    batch = SV.batch_problems(B.hist_rows(hists), hists, cfg=CFG)
    res = SV.solve_batched(batch, CFG)
    lane_iters = np.asarray(res.n_iters)
    assert len(set(lane_iters.tolist())) > 1  # genuinely ragged
    h = reg.peek("solver.iters", kind="flat")
    assert h.count == 3
    assert h.total == pytest.approx(float(lane_iters.sum()))
    assert h.vmin == float(lane_iters.min())
    assert h.vmax == float(lane_iters.max())
    assert reg.peek("solver.lanes", kind="flat",
                    impl="reference").value == 3
    assert reg.peek("solver.solves", kind="flat",
                    impl="reference").value == 1
    g = reg.peek("solver.last_final_delta", kind="flat")
    assert g.value == pytest.approx(float(np.max(res.final_delta)))


# ---------------------------------------------------------------------------
# Scoped registries (the sweep harness's per-cell capture)
# ---------------------------------------------------------------------------

def test_scoped_registry_captures_without_touching_default():
    base = obs.default_registry()
    base.reset()
    with obs.scoped_registry() as reg:
        assert obs.default_registry() is reg
        assert reg is not base
        obs.default_registry().counter("inner").inc(3)
    assert obs.default_registry() is base
    assert reg.peek("inner").value == 3
    assert base.peek("inner") is None


def test_scoped_registry_nests_innermost_wins():
    with obs.scoped_registry() as outer:
        outer_active = obs.default_registry()
        with obs.scoped_registry() as inner:
            obs.default_registry().counter("n").inc()
        assert obs.default_registry() is outer_active is outer
        assert inner.peek("n").value == 1
        assert outer.peek("n") is None


def test_scoped_registry_accepts_caller_registry():
    mine = obs.MetricsRegistry()
    with obs.scoped_registry(mine) as reg:
        assert reg is mine
        obs.default_registry().gauge("g").set(2.5)
    assert mine.peek("g").value == 2.5


def test_scoped_registry_pops_on_exception():
    base = obs.default_registry()
    with pytest.raises(RuntimeError):
        with obs.scoped_registry():
            raise RuntimeError("boom")
    assert obs.default_registry() is base


def test_scoped_registry_captures_solver_telemetry():
    base = obs.default_registry()
    base.reset()
    img = phantom.phantom_slice(32, 32, noise=3.0, seed=0)[0]
    prob = SV.histogram_problem(img.ravel().astype(np.float32), CFG)
    with obs.scoped_registry() as reg:
        res = SV.solve(prob, CFG)
    h = reg.peek("solver.iters", kind="flat")
    assert h is not None and h.count == 1
    assert h.vmax == float(res.n_iters)
    # nothing leaked into the process-wide registry (reset() keeps the
    # key registered from earlier tests, so check the count, not None)
    leaked = base.peek("solver.iters", kind="flat")
    assert leaked is None or leaked.count == 0
