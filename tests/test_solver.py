"""Solver-core parity suite.

Every deprecated ``fit_*`` adapter must (a) emit a DeprecationWarning
and (b) match what building the FCMProblem and calling ``solve()`` /
``solve_batched()`` directly produces, center-for-center (<= 1e-5) and
iteration-for-iteration — on pixel, histogram, spatial, vector and
batched problems, including ragged / non-128-multiple shapes. The new
public API itself must be DeprecationWarning-clean (CI runs this file
under ``-W error::DeprecationWarning``).
"""
import warnings

import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import batched as B
from repro.core import fcm as F
from repro.core import histogram as H
from repro.core import sequential as SQ
from repro.core import solver as SV
from repro.core import spatial as S
from repro.core import vector_fcm as VF
from repro.data import phantom
from repro.kernels import ops as kops

CFG = F.FCMConfig(max_iters=300)
ATOL = 1e-5


def _legacy(fn, *args, **kwargs):
    """Call a deprecated alias, asserting it warns (works under -W
    error::DeprecationWarning too — pytest.warns captures first)."""
    with pytest.warns(DeprecationWarning):
        return fn(*args, **kwargs)


def _assert_result_parity(old, new, atol=ATOL):
    np.testing.assert_allclose(np.asarray(old.centers),
                               np.asarray(new.centers), atol=atol)
    assert old.n_iters == new.n_iters
    assert (np.asarray(old.labels) == np.asarray(new.labels)).all()


# ---------------------------------------------------------------------------
# Single-problem parity (ragged, non-128-multiple shapes throughout)
# ---------------------------------------------------------------------------

def test_pixel_parity_scalar():
    img, _ = phantom.phantom_slice(37, 53, seed=1)        # 1961 pixels
    x = img.ravel().astype(np.float32)
    old = _legacy(F.fit_fused, x, CFG)
    new = SV.solve(SV.pixel_problem(x, CFG), CFG)
    _assert_result_parity(old, new)


def test_pixel_parity_vector_features():
    rng = np.random.default_rng(2)
    x = np.concatenate([rng.normal((0, 0), 0.2, size=(70, 2)),
                        rng.normal((3, 3), 0.2, size=(59, 2))]
                       ).astype(np.float32)
    cfg = F.FCMConfig(n_clusters=2, max_iters=80)
    old = _legacy(F.fit_fused, x, cfg)
    new = SV.solve(SV.pixel_problem(x, cfg), cfg)
    _assert_result_parity(old, new)
    assert new.centers.shape == (2, 2)


def test_pixel_parity_explicit_v0_and_membership():
    img, _ = phantom.phantom_slice(41, 47, seed=3)
    x = img.ravel().astype(np.float32)
    v0 = jnp.asarray([10.0, 60.0, 120.0, 200.0])
    old = _legacy(F.fit_fused, x, CFG, v0=v0, keep_membership=True)
    new = SV.solve(SV.pixel_problem(x, CFG, v0=v0), CFG,
                   keep_membership=True)
    _assert_result_parity(old, new)
    np.testing.assert_allclose(np.asarray(old.membership),
                               np.asarray(new.membership), atol=ATOL)


def test_histogram_parity():
    img, _ = phantom.phantom_slice(45, 59, seed=4)
    x = img.ravel().astype(np.float32)
    old = _legacy(H.fit_histogram, x, CFG)
    new = SV.solve(SV.histogram_problem(x, CFG), CFG)
    np.testing.assert_allclose(np.asarray(old.centers),
                               np.asarray(new.centers), atol=ATOL)
    assert old.n_iters == new.n_iters
    # adapter labels are per-pixel; solve's are per-bin — related by LUT
    lut = np.asarray(new.labels)
    flat = np.clip(x.astype(np.int64), 0, 255)
    assert (np.asarray(old.labels) == lut[flat]).all()


def test_histogram_parity_prebuilt_hist():
    img, _ = phantom.phantom_slice(33, 35, seed=5)
    x = img.ravel().astype(np.float32)
    hist = H.intensity_histogram(jnp.asarray(x))
    old = _legacy(H.fit_histogram, x, CFG, hist=hist)
    new = SV.solve(SV.histogram_problem(cfg=CFG, hist=hist), CFG)
    np.testing.assert_allclose(np.asarray(old.centers),
                               np.asarray(new.centers), atol=ATOL)
    assert old.n_iters == new.n_iters


@pytest.mark.parametrize("shape", [(37, 53), (5, 19, 23)])
def test_spatial_parity(shape):
    img, _ = (phantom.noisy_phantom_slice(*shape, noise=8.0, impulse=0.03,
                                          seed=6) if len(shape) == 2
              else phantom.noisy_phantom_volume(*shape, noise=8.0,
                                                impulse=0.03, seed=6))
    scfg = S.SpatialFCMConfig(alpha=1.2, neighbors=8)
    old = _legacy(S.fit_spatial, img.astype(np.float32), scfg)
    new = SV.solve(SV.spatial_problem(img.astype(np.float32), scfg), scfg)
    _assert_result_parity(old, new)
    assert new.labels.shape == img.shape


def test_spatial_parity_pallas():
    img, _ = phantom.noisy_phantom_slice(19, 23, noise=8.0, seed=7)
    scfg = S.SpatialFCMConfig(alpha=1.0, neighbors=4, max_iters=40)
    old = _legacy(S.fit_spatial, img.astype(np.float32), scfg,
                  use_pallas=True, block_rows=8, interpret=True)
    new = SV.solve(SV.spatial_problem(img.astype(np.float32), scfg), scfg,
                   backend="pallas", block_rows=8, interpret=True)
    _assert_result_parity(old, new)


def test_vector_parity():
    rng = np.random.default_rng(8)
    feats = rng.uniform(0, 255, (73, 3)).astype(np.float32)
    w = rng.integers(1, 40, 73).astype(np.float32)
    old = _legacy(VF.fit_vector_fcm, feats, w, CFG)
    new = SV.solve(SV.vector_problem(feats, w, CFG), CFG)
    _assert_result_parity(old, new)
    assert new.centers.shape == (CFG.n_clusters, 3)


def test_staged_parity():
    img, _ = phantom.phantom_slice(31, 33, seed=9)
    x = img.ravel().astype(np.float32)
    cfg = F.FCMConfig(max_iters=60, seed=5)
    old = _legacy(F.fit_baseline, x, cfg)
    # no explicit seed: solve() must thread cfg.seed into the staged
    # backend's random membership init
    new = SV.solve(SV.pixel_problem(x, cfg), cfg, backend="staged")
    _assert_result_parity(old, new)
    assert old.final_delta == new.final_delta


def test_sequential_backend_matches_numpy_reference():
    rng = np.random.default_rng(10)
    x = rng.integers(0, 256, size=700).astype(np.float32)
    v_np, lab_np, it_np = SQ.fcm_sequential_numpy(x, c=3, seed=2,
                                                  max_iters=80)
    res = SV.solve(SV.pixel_problem(x, c=3), backend="sequential",
                   eps=5e-3, max_iters=80, seed=2)
    np.testing.assert_allclose(np.sort(np.asarray(res.centers)),
                               np.sort(v_np), atol=1e-5)
    assert res.n_iters == it_np
    assert (np.asarray(res.labels) == lab_np).all()


# ---------------------------------------------------------------------------
# Batched parity (per-lane masking == solo trajectories)
# ---------------------------------------------------------------------------

def test_batched_histogram_parity():
    imgs = [phantom.phantom_slice(37 + 6 * i, 53, noise=2.0 + 3 * i,
                                  seed=i)[0] for i in range(4)]
    old = _legacy(B.fit_batched, imgs, CFG)
    hists = B.histograms_of(imgs)
    new = SV.solve_batched(SV.batch_problems(B.hist_rows(hists), hists,
                                             cfg=CFG), CFG)
    np.testing.assert_allclose(np.asarray(old.centers),
                               np.asarray(new.centers), atol=ATOL)
    np.testing.assert_array_equal(old.n_iters, new.n_iters)
    # and each lane is a solo solve's trajectory
    for i, img in enumerate(imgs):
        solo = SV.solve(SV.histogram_problem(
            img.ravel().astype(np.float32), CFG), CFG)
        np.testing.assert_allclose(np.asarray(new.centers[i]),
                                   np.asarray(solo.centers), atol=1e-4)
        assert new.n_iters[i] == solo.n_iters


def test_batched_pixels_parity():
    xs = np.stack([phantom.phantom_slice(41, 43, seed=20 + i)[0]
                   for i in range(3)]).astype(np.float32)
    old = _legacy(B.fit_batched_pixels, xs, CFG)
    new = SV.solve_batched(
        SV.batch_problems(xs.reshape(3, -1), cfg=CFG), CFG)
    np.testing.assert_allclose(np.asarray(old.centers),
                               np.asarray(new.centers), atol=ATOL)
    np.testing.assert_array_equal(old.n_iters, new.n_iters)


def test_batched_vector_parity():
    rng = np.random.default_rng(11)
    feats = rng.uniform(0, 255, (3, 61, 3)).astype(np.float32)
    ws = rng.integers(1, 30, (3, 61)).astype(np.float32)
    old = _legacy(VF.fit_vector_batched, feats, ws, CFG)
    new = SV.solve_batched(SV.batch_problems(feats, ws, cfg=CFG), CFG)
    np.testing.assert_allclose(np.asarray(old.centers),
                               np.asarray(new.centers), atol=ATOL)
    np.testing.assert_array_equal(old.n_iters, new.n_iters)
    for i in range(3):
        solo = SV.solve(SV.vector_problem(feats[i], ws[i], CFG), CFG)
        np.testing.assert_allclose(np.asarray(new.centers[i]),
                                   np.asarray(solo.centers), atol=1e-4)
        assert new.n_iters[i] == solo.n_iters


def test_batched_spatial_lanes_match_solo_solves():
    """The new capability the engine's spatial batching rides on: a
    stacked stencil batch converges lane-for-lane like solo FCM_S."""
    imgs = np.stack([phantom.noisy_phantom_slice(37, 45, noise=6.0 + 4 * i,
                                                 impulse=0.04, seed=i)[0]
                     for i in range(3)]).astype(np.float32)
    scfg = S.SpatialFCMConfig(alpha=1.0, neighbors=4)
    batch = SV.batch_problems(
        imgs, stencil=SV.StencilSpec(alpha=scfg.alpha,
                                     neighbors=scfg.neighbors), cfg=scfg)
    res = SV.solve_batched(batch, scfg)
    assert len(set(res.n_iters.tolist())) >= 1
    assert res.total_iters == int(res.n_iters.max())
    for i in range(3):
        solo = SV.solve(SV.spatial_problem(imgs[i], scfg), scfg)
        np.testing.assert_allclose(np.asarray(res.centers[i]),
                                   np.asarray(solo.centers), atol=ATOL)
        assert res.n_iters[i] == solo.n_iters


# ---------------------------------------------------------------------------
# New API hygiene + controls
# ---------------------------------------------------------------------------

def test_new_api_is_deprecationwarning_clean():
    img, _ = phantom.phantom_slice(21, 27, seed=12)
    x = img.ravel().astype(np.float32)
    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        SV.solve(SV.pixel_problem(x, CFG), CFG)
        SV.solve(SV.histogram_problem(x, CFG), CFG)
        SV.solve(SV.spatial_problem(img.astype(np.float32), alpha=0.5), CFG)
        hists = B.histograms_of([img])
        SV.solve_batched(SV.batch_problems(B.hist_rows(hists), hists,
                                           cfg=CFG), CFG)


def test_tol_override_forces_fixed_iterations():
    img, _ = phantom.phantom_slice(21, 27, seed=13)
    x = img.ravel().astype(np.float32)
    res = SV.solve(SV.pixel_problem(x, CFG), tol=-1.0, max_iters=7)
    assert res.n_iters == 7


def test_solve_rejects_mismatched_batchness():
    x = np.zeros((32,), np.float32)
    with pytest.raises(ValueError, match="solve_batched"):
        SV.solve(SV.batch_problems(np.zeros((2, 16), np.float32)))
    with pytest.raises(ValueError, match="batch=True"):
        SV.solve_batched(SV.pixel_problem(x))
    with pytest.raises(ValueError, match="backend"):
        SV.solve(SV.pixel_problem(x), backend="warp-drive")


def test_problem_validation():
    with pytest.raises(ValueError, match="no row weights"):
        SV.FCMProblem(features=np.zeros((4, 4), np.float32),
                      weights=np.ones(16, np.float32),
                      stencil=SV.StencilSpec())
    with pytest.raises(ValueError, match="connected"):
        SV.spatial_problem(np.zeros((8, 8), np.float32), neighbors=5)
    with pytest.raises(ValueError, match="pixel grid"):
        SV.spatial_problem(np.zeros((64,), np.float32))
    with pytest.raises(ValueError, match="feature rows"):
        SV.FCMProblem(features=np.zeros((2, 3, 4), np.float32))


# ---------------------------------------------------------------------------
# Step dispatch registry
# ---------------------------------------------------------------------------

def test_registry_lists_all_kinds_and_impls():
    pairs = {(i.kind, i.name) for i in kops.step_impls()}
    for kind in ("flat", "stencil", "slic_assign"):
        assert (kind, "reference") in pairs
        assert (kind, "pallas") in pairs
        assert [i.name for i in kops.step_impls(kind)]


def test_registry_platform_dispatch():
    # Off-TPU the reference step always wins by default.
    assert kops.select_step("flat", platform="cpu").name == "reference"
    assert kops.select_step("stencil", platform="cpu").name == "reference"
    # On TPU the Pallas kernels win where eligible ...
    assert kops.select_step("flat", platform="tpu", n_feat=1).name == "pallas"
    assert kops.select_step("stencil", platform="tpu").name == "pallas"
    # ... but shape/vmap restrictions fall back to the reference.
    assert kops.select_step("flat", platform="tpu", n_feat=3
                            ).name == "reference"
    assert kops.select_step("flat", platform="tpu", n_feat=1,
                            batched=True).name == "reference"


def test_registry_prefer_and_errors():
    assert kops.select_step("flat", prefer="pallas", n_feat=1
                            ).name == "pallas"
    with pytest.raises(ValueError, match="registered"):
        kops.select_step("flat", prefer="cuda")
    with pytest.raises(ValueError, match="scalar"):
        kops.select_step("flat", prefer="pallas", n_feat=3)
    with pytest.raises(ValueError, match="batched"):
        kops.select_step("flat", prefer="pallas", n_feat=1, batched=True)
    with pytest.raises(ValueError, match="unknown step kind"):
        kops.select_step("warp")


def test_registry_registration_roundtrip():
    """A new variant costs one registration (and can be torn down)."""
    @kops.register_step("flat", "test-noop")
    def _noop(feats, weights, m, **_):
        return lambda v: v
    try:
        assert kops.select_step("flat", prefer="test-noop").name == \
            "test-noop"
        step = kops.build_step("flat", "test-noop", feats=None,
                               weights=None, m=2.0)
        v = jnp.ones((4, 1))
        assert (step(v) == v).all()
    finally:
        del kops._STEP_REGISTRY[("flat", "test-noop")]
