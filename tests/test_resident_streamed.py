"""HBM-streamed resident kernel + resident FCM_S stencil: parity suite.

Both kernels run the COMPLETE convergence loop inside one
``pallas_call`` (interpret mode here), so the bar is the resident-kernel
one: center-for-center (rtol 1e-5) and iteration-for-iteration against
the reference loops, on row counts far beyond the VMEM-held bound
(streamed flat) and on non-multiple-of-128 grids with border pixels
(resident stencil). Plus the single-dispatch acceptance check (exactly
one ``pallas_call`` in the traced solve, no host-level ``while``) and
the fallback-chain regression the new entries exposed in
``select_step``.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import solver as SV
from repro.data import phantom
from repro.kernels import fcm_resident as KR
from repro.kernels import ops as kops

RTOL = 1e-5
ATOL = 1e-5


def _assert_centers(got, want):
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=RTOL, atol=ATOL)


def _rows(k, d=1, seed=0):
    rng = np.random.default_rng(seed)
    feats = rng.uniform(0, 255, (k, d)).astype(np.float32)
    w = rng.uniform(0.5, 4.0, (k,)).astype(np.float32)
    return feats, w


# ---------------------------------------------------------------------------
# Streamed flat solve: solo parity on ragged row counts
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("k,d", [(300, 1), (4099, 3), (50000, 2)])
def test_streamed_matches_reference_ragged_rows(k, d):
    """Ragged K (never a multiple of the 8x128 stream chunk): the
    zero-weight tile padding must be inert, and each solve must stop on
    the same iteration as the reference loop."""
    feats, w = _rows(k, d, seed=k)
    problem = SV.vector_problem(feats, w)
    ref = SV.solve(problem, backend="reference")
    res = SV.solve(problem, backend="resident", interpret=True)
    _assert_centers(res.centers, ref.centers)
    assert res.n_iters == ref.n_iters


def test_streamed_scalar_pixels_match_reference():
    img, _ = phantom.phantom_slice(70, 73, seed=2)     # 5110 rows, ragged
    x = img.ravel().astype(np.float32)
    ref = SV.solve(SV.pixel_problem(x), backend="reference")
    res = SV.solve(SV.pixel_problem(x), backend="resident", interpret=True)
    _assert_centers(res.centers, ref.centers)
    assert res.n_iters == ref.n_iters
    agree = (np.asarray(res.labels) == np.asarray(ref.labels)).mean()
    assert agree > 0.999, agree


def test_resident_backend_routes_by_size():
    """backend="resident" picks the VMEM-held kernel when rows fit its
    bound and the HBM-streamed variant beyond it — same answer."""
    feats, w = _rows(256, 2, seed=5)
    small = SV.solve(SV.vector_problem(feats, w), backend="resident",
                     interpret=True)
    feats_big = np.concatenate([feats] * 3)
    w_pad = np.concatenate([w, np.zeros((2 * 256,), np.float32)])
    big = SV.solve(SV.vector_problem(feats_big, w_pad), backend="resident",
                   interpret=True)
    # zero-weight duplicate rows are inert: both solves see the same
    # effective problem, through two different kernels
    _assert_centers(big.centers, small.centers)
    assert big.n_iters == small.n_iters


# ---------------------------------------------------------------------------
# Streamed flat solve: batched parity + divergent-lane early stop
# ---------------------------------------------------------------------------

def test_streamed_batched_lanes_match_solo_and_diverge():
    rngs = [np.random.default_rng(s) for s in range(4)]
    k = 2100                                  # > MAX_ROWS, ragged
    feats = np.stack([r.uniform(0, 200 + 20 * i, (k, 2)).astype(np.float32)
                      for i, r in enumerate(rngs)])
    ws = np.stack([r.uniform(0.5, 2.0, (k,)).astype(np.float32)
                   for r in rngs])
    batch = SV.batch_problems(feats, ws)
    res = SV.solve_batched(batch, backend="resident", interpret=True)
    for i in range(4):
        solo = SV.solve(SV.vector_problem(feats[i], ws[i]),
                        backend="reference")
        _assert_centers(res.centers[i], solo.centers)
        assert int(res.n_iters[i]) == solo.n_iters
    # heterogeneous data => heterogeneous convergence; the early-stopped
    # lanes must have frozen at their own iteration counts
    assert len(set(res.n_iters.tolist())) > 1, res.n_iters
    assert res.total_iters == int(res.n_iters.max())


# ---------------------------------------------------------------------------
# Single-dispatch acceptance: K >= 4096 rows, ONE pallas_call, no host loop
# ---------------------------------------------------------------------------

def _count_primitives(jaxpr, names):
    """Count primitives in the HOST program: recursion stops at the
    pallas_call boundary, so the convergence while_loop INSIDE the
    kernel body does not count as a host-level while."""
    found = {n: 0 for n in names}

    def walk(jx):
        for eqn in jx.eqns:
            if eqn.primitive.name in found:
                found[eqn.primitive.name] += 1
            if eqn.primitive.name == "pallas_call":
                continue
            for param in eqn.params.values():
                for sub in (param if isinstance(param, (list, tuple))
                            else [param]):
                    inner = getattr(sub, "jaxpr", sub)
                    if hasattr(inner, "eqns"):
                        walk(inner)

    walk(jaxpr)
    return found


def test_streamed_solve_is_one_pallas_call_no_host_loop():
    """The acceptance criterion: a superpixel/vector problem with
    K >= 4096 rows traces to exactly ONE pallas_call and no XLA-level
    while — the whole convergence loop lives inside the kernel."""
    feats, w = _rows(4608, 3, seed=7)
    x4, w3 = kops.tile_rows_batched(feats[None], w[None],
                                    rows_multiple=KR.STREAM_CHUNK_ROWS)
    solve_fn = kops.build_step("flat", "resident_streamed", x4=x4, w3=w3,
                               m=2.0, max_iters=300, interpret=True)
    v0 = jnp.broadcast_to(jnp.linspace(10.0, 240.0, 4)[None, :, None],
                          (1, 4, 3))
    tol = jnp.full((1,), 0.05, jnp.float32)
    jaxpr = jax.make_jaxpr(solve_fn)(v0, tol)
    counts = _count_primitives(jaxpr.jaxpr, ("pallas_call", "while"))
    assert counts["pallas_call"] == 1, jaxpr
    assert counts["while"] == 0, jaxpr


def test_resident_stencil_solve_is_one_pallas_call_no_host_loop():
    img = jnp.zeros((64, 80), jnp.float32)
    xpad, vpad = kops.tile_grid_batched(img[None])
    solve_fn = kops.build_step("stencil", "resident", xpad=xpad, vpad=vpad,
                               m=2.0, alpha=1.0, neighbors=8,
                               max_iters=300, interpret=True)
    v0 = jnp.linspace(10.0, 240.0, 4)[None, :]
    tol = jnp.full((1,), 0.05, jnp.float32)
    jaxpr = jax.make_jaxpr(solve_fn)(v0, tol)
    counts = _count_primitives(jaxpr.jaxpr, ("pallas_call", "while"))
    assert counts["pallas_call"] == 1, jaxpr
    assert counts["while"] == 0, jaxpr


# ---------------------------------------------------------------------------
# Resident FCM_S stencil: full-fit parity vs the jnp reference
# ---------------------------------------------------------------------------

STENCIL_SHAPES_2D = [(37, 53), (9, 300), (64, 128), (2, 2)]
STENCIL_SHAPES_3D = [(5, 19, 41), (2, 2, 2)]


@pytest.mark.parametrize("shape", STENCIL_SHAPES_2D)
@pytest.mark.parametrize("neighbors", [4, 8])
def test_stencil_resident_2d_matches_reference(shape, neighbors):
    """Non-multiple-of-128 widths and sub-tile grids: the validity-sheet
    padding must reproduce the reference's zero-filled border handling
    (border pixels average over their true neighbors only)."""
    rng = np.random.default_rng(shape[0] * 1000 + shape[1])
    img = rng.integers(0, 256, shape).astype(np.float32)
    problem = SV.spatial_problem(img, alpha=0.9, neighbors=neighbors)
    ref = SV.solve(problem, backend="reference", max_iters=40)
    res = SV.solve(problem, backend="resident", interpret=True,
                   max_iters=40)
    _assert_centers(res.centers, ref.centers)
    assert res.n_iters == ref.n_iters
    agree = (np.asarray(res.labels) == np.asarray(ref.labels)).mean()
    assert agree > 0.999, agree


@pytest.mark.parametrize("shape", STENCIL_SHAPES_3D)
def test_stencil_resident_3d_matches_reference(shape):
    rng = np.random.default_rng(11)
    img = rng.integers(0, 256, shape).astype(np.float32)
    problem = SV.spatial_problem(img, alpha=1.3)     # 6-stencil
    ref = SV.solve(problem, backend="reference", max_iters=40)
    res = SV.solve(problem, backend="resident", interpret=True,
                   max_iters=40)
    _assert_centers(res.centers, ref.centers)
    assert res.n_iters == ref.n_iters


def test_stencil_resident_batched_divergent_lanes():
    rng = np.random.default_rng(13)
    imgs = np.stack([rng.integers(0, 60 + 70 * i, (24, 33))
                     for i in range(3)]).astype(np.float32)
    stencil = SV.StencilSpec(alpha=1.0, neighbors=4)
    batch = SV.batch_problems(imgs, stencil=stencil)
    res = SV.solve_batched(batch, backend="resident", interpret=True)
    for i in range(3):
        solo = SV.solve(SV.spatial_problem(imgs[i], alpha=1.0, neighbors=4),
                        backend="reference")
        _assert_centers(res.centers[i], solo.centers)
        assert int(res.n_iters[i]) == solo.n_iters
    assert len(set(res.n_iters.tolist())) > 1, res.n_iters


def test_stencil_alpha_zero_degenerates_to_flat():
    img, _ = phantom.phantom_slice(33, 37, seed=3)
    res = SV.solve(SV.spatial_problem(img, alpha=0.0), backend="resident",
                   interpret=True)
    flat = SV.solve(SV.pixel_problem(img.ravel().astype(np.float32)),
                    backend="reference")
    _assert_centers(res.centers, flat.centers)
    assert res.n_iters == flat.n_iters


# ---------------------------------------------------------------------------
# Registry: fallback chain + tiling helpers
# ---------------------------------------------------------------------------

def test_fallback_chain_walks_two_hops_off_tpu():
    """Regression: resident_streamed declares fallback="resident", whose
    own fallback is "reference". Off-TPU with rows beyond the VMEM-held
    bound, the middle link is ineligible — the old single-recursion
    resolution raised; the chain walk must land on the reference step."""
    impl = kops.select_step("flat", prefer="resident_streamed",
                            platform="cpu", n_rows=50000, c=4)
    assert impl.name == "reference"
    # ... and when the middle link IS eligible, it still gets skipped
    # off-platform rather than claimed
    impl = kops.select_step("flat", prefer="resident_streamed",
                            platform="cpu", n_rows=128, c=4)
    assert impl.name == "reference"


def test_fallback_chain_cycle_and_exhaustion_raise():
    """A chain that never reaches an eligible link must raise (with the
    walked chain named), not loop: registered here as throwaway entries
    that form a 2-cycle of off-platform impls."""
    reg = kops._STEP_REGISTRY
    kops.register_step("flat", "_test_a", platforms=("tpu",),
                       fallback="_test_b")(lambda **kw: None)
    kops.register_step("flat", "_test_b", platforms=("tpu",),
                       fallback="_test_a")(lambda **kw: None)
    try:
        with pytest.raises(ValueError, match="fallback chain"):
            kops.select_step("flat", prefer="_test_a", platform="cpu",
                             n_rows=64, c=4)
    finally:
        del reg[("flat", "_test_a")], reg[("flat", "_test_b")]


def test_streamed_registered_with_bounds():
    impl = kops.select_step("flat", prefer="resident_streamed",
                            platform="tpu", n_rows=KR.STREAM_MAX_ROWS, c=8)
    assert impl.name == "resident_streamed"
    assert impl.max_rows == KR.STREAM_MAX_ROWS
    assert impl.fallback == "resident"
    st = kops.select_step("stencil", prefer="resident", platform="tpu",
                          n_rows=KR.STENCIL_MAX_PIXELS,
                          c=KR.STENCIL_MAX_C)
    assert st.name == "resident" and st.fallback == "reference"


def test_tile_grid_batched_pads_and_validates():
    imgs = np.arange(2 * 9 * 300, dtype=np.float32).reshape(2, 9, 300)
    xpad, vpad = kops.tile_grid_batched(imgs)
    assert xpad.shape == (2, 16, 384) and vpad.shape == (2, 16, 384)
    assert float(vpad.sum()) == 2 * 9 * 300          # 1 on real pixels
    assert float(xpad[0, 9:].sum()) == 0.0           # zero-filled padding
    vol = np.ones((1, 5, 19, 41), np.float32)
    xpad3, vpad3 = kops.tile_grid_batched(vol)
    assert xpad3.shape == (1, 5, 24, 128)
    assert float(vpad3.sum()) == 5 * 19 * 41
    with pytest.raises(ValueError, match="rank 3 or 4"):
        kops.tile_grid_batched(np.ones((4, 4), np.float32))


def test_tile_rows_batched_rows_multiple():
    feats = np.ones((1, 300, 2), np.float32)
    w = np.ones((1, 300), np.float32)
    x4, w3 = kops.tile_rows_batched(feats, w,
                                    rows_multiple=KR.STREAM_CHUNK_ROWS)
    assert x4.shape[2] % KR.STREAM_CHUNK_ROWS == 0
    assert float(w3.sum()) == 300                    # padding weight 0
