"""Sweep harness: declarative grid expansion (skip predicates, seed
fan-out, deterministic cell ids), executed-cell schema round-trips, and
the sweep section's coverage enforcement."""
import itertools
import json

import pytest

from benchmarks import bench_schema, sweep


# ---------------------------------------------------------------------------
# Grid expansion
# ---------------------------------------------------------------------------

def _spec(axes, skip=()):
    return sweep.SweepSpec(name="t", family="solver", axes=axes, skip=skip)


def test_expand_is_full_cartesian_product_without_skips():
    spec = _spec({"a": (1, 2), "b": ("x", "y", "z"), "c": (0,)})
    cells, skipped = sweep.expand(spec)
    assert len(cells) == 6 and not skipped
    assert len({c["cell_id"] for c in cells}) == 6


def test_cell_id_is_deterministic_and_axis_order_independent():
    assert (sweep.cell_id("solver", {"b": 2, "a": "x"})
            == sweep.cell_id("solver", {"a": "x", "b": 2})
            == "solver/a=x,b=2")


def test_expand_order_is_deterministic():
    axes = {"b": (1, 2), "a": ("p", "q")}
    ids1 = [c["cell_id"] for c in sweep.expand(_spec(axes))[0]]
    ids2 = [c["cell_id"] for c in sweep.expand(_spec(dict(
        reversed(list(axes.items())))))[0]]
    assert ids1 == ids2


def test_skip_predicates_record_reasons_not_silence():
    spec = _spec({"a": (1, 2, 3)},
                 skip=(lambda ax: "odd is out" if ax["a"] % 2 else None,))
    cells, skipped = sweep.expand(spec)
    assert [c["axes"]["a"] for c in cells] == [2]
    assert [s["axes"]["a"] for s in skipped] == [1, 3]
    assert all(s["skip_reason"] == "odd is out" for s in skipped)
    assert all(s["status"] == "skipped" for s in skipped)


def test_first_matching_skip_predicate_wins():
    spec = _spec({"a": (1,)}, skip=(lambda ax: "first",
                                    lambda ax: "second"))
    _, skipped = sweep.expand(spec)
    assert skipped[0]["skip_reason"] == "first"


def test_seed_axis_fans_out_into_distinct_cells():
    spec = _spec({"seed": (0, 1, 2), "size": (32,)})
    cells, _ = sweep.expand(spec)
    assert sorted(c["axes"]["seed"] for c in cells) == [0, 1, 2]
    assert len({c["cell_id"] for c in cells}) == 3


# ---------------------------------------------------------------------------
# The solver grid's eligibility rules
# ---------------------------------------------------------------------------

def _skip_reason(ax, platform="cpu"):
    return next((r for r in (p(ax) for p in sweep.solver_skips(platform))
                 if r), None)


def test_sequential_is_pixel_only():
    base = {"backend": "sequential", "size": 32, "batch": 1, "seed": 0}
    assert _skip_reason({**base, "variant": "pixel"}) is None
    for v in ("histogram", "spatial", "vector"):
        assert "sequential" in _skip_reason({**base, "variant": v})


def test_pallas_rejects_vector_rows():
    ax = {"variant": "vector", "backend": "pallas", "size": 32,
          "batch": 1, "seed": 0}
    assert "scalar-only" in _skip_reason(ax)


def test_batched_cells_run_reference_or_resident_only():
    base = {"variant": "pixel", "size": 32, "batch": 4, "seed": 0}
    assert "solve_batched" in _skip_reason({**base, "backend": "pallas"})
    assert _skip_reason({**base, "backend": "reference"}) is None


def test_vector_batching_is_a_serving_concern():
    ax = {"variant": "vector", "backend": "reference", "size": 32,
          "batch": 4, "seed": 0}
    assert "serving route" in _skip_reason(ax)


def test_interpret_mode_size_cap_applies_off_tpu_only():
    ax = {"variant": "histogram", "backend": "resident", "size": 128,
          "batch": 1, "seed": 0}
    assert "interpret" in _skip_reason(ax, platform="cpu")
    assert _skip_reason(ax, platform="tpu") is None
    small = {**ax, "size": 32}
    assert _skip_reason(small, platform="cpu") is None


def test_default_specs_cover_all_variants_and_routes():
    from repro.serving import fcm_engine as FE
    specs = sweep.default_specs(tiny=True, platform="cpu")
    by_family = {s.family: s for s in specs}
    assert set(by_family["solver"].axes["variant"]) == {
        "pixel", "histogram", "spatial", "vector"}
    assert set(by_family["serving"].axes["route"]) == set(FE.METHODS)


# ---------------------------------------------------------------------------
# Executed cells round-trip the schema
# ---------------------------------------------------------------------------

def _roundtrip(rec):
    """Executed record -> JSON text -> parsed -> schema-valid."""
    from repro import obs
    parsed = json.loads(json.dumps(obs.json_safe(rec)))
    bench_schema.validate_cell(parsed)
    return parsed


def test_solver_cell_record_roundtrips_schema():
    axes = {"variant": "histogram", "backend": "reference", "size": 32,
            "batch": 1, "seed": 0}
    cell = {"cell_id": sweep.cell_id("solver", axes), "family": "solver",
            "axes": axes}
    rec = _roundtrip(sweep._run_solver_cell(cell, tiny=True))
    assert rec["status"] == "ok"
    assert rec["metrics"]["wall_s"] > 0
    assert rec["accuracy"]["mean_dsc"] > 0.9
    assert rec["latency"]["count"] >= 1
    assert rec["convergence"]["lanes"] >= 1


def test_batched_solver_cell_record_roundtrips_schema():
    axes = {"variant": "spatial", "backend": "reference", "size": 32,
            "batch": 2, "seed": 0}
    cell = {"cell_id": sweep.cell_id("solver", axes), "family": "solver",
            "axes": axes}
    rec = _roundtrip(sweep._run_solver_cell(cell, tiny=True))
    assert rec["status"] == "ok"
    # 2 lanes per solve x (1 warm + 1 timed rep in tiny mode): the
    # convergence block accumulates over every solve in the cell scope
    assert rec["convergence"]["lanes"] == 4
    assert rec["accuracy"] is None               # batch cells skip DSC


def test_serving_cell_record_roundtrips_schema():
    axes = {"route": "histogram", "batch": 2}
    cell = {"cell_id": sweep.cell_id("serving", axes),
            "family": "serving", "axes": axes}
    rec = _roundtrip(sweep._run_serving_cell(cell, tiny=True))
    assert rec["status"] == "ok"
    assert set(rec["metrics"]["stage_seconds"]) == {
        "ingest", "solve", "materialize"}


def test_solver_cell_telemetry_does_not_leak_to_default_registry():
    from repro import obs
    before = obs.default_registry().snapshot()
    axes = {"variant": "pixel", "backend": "reference", "size": 32,
            "batch": 1, "seed": 0}
    cell = {"cell_id": sweep.cell_id("solver", axes), "family": "solver",
            "axes": axes}
    sweep._run_solver_cell(cell, tiny=True)
    assert obs.default_registry().snapshot() == before


# ---------------------------------------------------------------------------
# Schema: per-cell and section-level checks
# ---------------------------------------------------------------------------

def test_validate_cell_rejects_skipped_without_reason():
    cell = {"cell_id": "solver/x=1", "family": "solver",
            "axes": {"x": 1}, "status": "skipped"}
    with pytest.raises(ValueError, match="skip_reason"):
        bench_schema.validate_cell(cell)


def test_validate_cell_rejects_ok_solver_cell_missing_blocks():
    cell = {"cell_id": "solver/x=1", "family": "solver",
            "axes": {"x": 1, "batch": 1}, "status": "ok",
            "metrics": {"wall_s": 1.0}}
    with pytest.raises(ValueError) as exc:
        bench_schema.validate_cell(cell)
    msg = str(exc.value)
    assert "latency" in msg and "convergence" in msg
    assert "accuracy.mean_dsc" in msg


def test_check_sweep_section_requires_kernel_registry_coverage():
    section = {"name": "t", "tiny": True, "backend": "cpu",
               "coverage": {}, "cells": [], "skipped": []}
    with pytest.raises(ValueError) as exc:
        bench_schema.check_sweep_section(section)
    msg = str(exc.value)
    assert "no ok kernel cell" in msg
    assert "flat/resident_streamed" in msg
    assert "no ok serving cell" in msg


def test_check_sweep_section_counts_error_cells_as_missing_coverage():
    from repro.kernels import ops as kops
    required = {(i.kind, i.name) for i in kops.step_impls()}
    required.update(bench_schema.REQUIRED_CELLS)
    cells = [{"cell_id": f"kernel/impl={impl},kind={kind}",
              "family": "kernel", "axes": {"kind": kind, "impl": impl},
              "status": "ok",
              "kernel": {k: 1 for k in bench_schema.CELL_KEYS}}
             for kind, impl in sorted(required)]
    # break one cell: an errored probe must still fail coverage
    cells[0]["status"] = "error"
    cells[0]["error"] = "boom"
    section = {"name": "t", "tiny": True, "backend": "cpu",
               "coverage": {}, "cells": cells, "skipped": []}
    with pytest.raises(ValueError, match="no ok kernel cell"):
        bench_schema.check_sweep_section(section)


# ---------------------------------------------------------------------------
# Distributed family + load_gen section (PR 9)
# ---------------------------------------------------------------------------

def _dist_cell(mode="batch_hist", ok=True):
    return {"cell_id": f"distributed/devices=8,mode={mode}",
            "family": "distributed",
            "axes": {"mode": mode, "devices": 8}, "status": "ok",
            "metrics": {"wall_s": 0.1, "per_image_s": 0.01, "batch": 6},
            "parity": {"ok": ok, "max_center_delta": 0.0}}


def test_distributed_cell_roundtrips_schema():
    bench_schema.validate_cell(json.loads(json.dumps(_dist_cell())))


def test_distributed_cell_failed_parity_is_a_schema_violation():
    with pytest.raises(ValueError, match="parity failed"):
        bench_schema.validate_cell(_dist_cell(ok=False))


def test_check_sweep_section_requires_distributed_modes():
    section = {"name": "t", "tiny": True, "backend": "cpu",
               "coverage": {}, "cells": [], "skipped": []}
    with pytest.raises(ValueError) as exc:
        bench_schema.check_sweep_section(section)
    msg = str(exc.value)
    for mode in bench_schema.REQUIRED_DIST_MODES:
        assert f"no ok distributed cell for mode '{mode}'" in msg


def _load_gen_section(**over):
    rate = {k: 1.0 for k in bench_schema.RATE_KEYS}
    section = {
        "tiny": True, "backend": "cpu", "devices": 1,
        "route": "histogram",
        "sync_baseline": {k: 1.0 for k in bench_schema.SYNC_BASELINE_KEYS},
        "rates": [dict(rate)], "sustained": dict(rate),
        "qps_ratio_vs_sync": 3.5,
        "gate": {"enforced": True, "min_ratio": 3.0, "ok": True},
    }
    section.update(over)
    return section


def test_check_load_gen_section_roundtrips():
    bench_schema.check_load_gen_section(
        json.loads(json.dumps(_load_gen_section())))


def test_check_load_gen_section_flags_enforced_failed_gate():
    bad = _load_gen_section(
        gate={"enforced": True, "min_ratio": 3.0, "ok": False})
    with pytest.raises(ValueError, match="gate failed"):
        bench_schema.check_load_gen_section(bad)
    # Unenforced failure is recorded, not fatal.
    bench_schema.check_load_gen_section(_load_gen_section(
        gate={"enforced": False, "min_ratio": 3.0, "ok": False}))


def test_check_load_gen_section_names_missing_rate_keys():
    sec = _load_gen_section()
    del sec["rates"][0]["p99_s"]
    del sec["sustained"]["queue_depth"]
    with pytest.raises(ValueError) as exc:
        bench_schema.check_load_gen_section(sec)
    msg = str(exc.value)
    assert "rates[0]: missing 'p99_s'" in msg
    assert "sustained: missing 'queue_depth'" in msg


def test_check_load_gen_section_requires_empty_rates_to_fail():
    with pytest.raises(ValueError, match="rates sweep empty"):
        bench_schema.check_load_gen_section(_load_gen_section(rates=[]))


def test_validate_requires_load_gen_from_pr9():
    base = {k: {} for k in bench_schema.TOP_KEYS
            if k not in ("pr", "load_gen")}
    with pytest.raises(ValueError,
                       match="missing top-level key 'load_gen'"):
        bench_schema.validate({**base, "pr": 9, "tiny": True})
    # pr 8 records predate the harness and stay valid without it.
    try:
        bench_schema.validate({**base, "pr": 8, "tiny": True})
    except ValueError as e:
        assert "load_gen" not in str(e)
