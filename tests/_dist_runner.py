"""Subprocess entry for multi-device tests: runs under 8 fake host
devices (set here, NOT globally — see dry-run rule in the launcher)."""
import os
import sys

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

_SRC = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src")
sys.path.insert(0, _SRC)

import numpy as np  # noqa: E402
import jax  # noqa: E402

from repro.core import fcm as F  # noqa: E402
from repro.core import batched as B  # noqa: E402
from repro.core import solver as SV  # noqa: E402
from repro.core import distributed as D  # noqa: E402
from repro.data import phantom  # noqa: E402


def main():
    assert len(jax.devices()) == 8, jax.devices()
    # axis_types only exists on newer jax; explicit-Auto is its default.
    kwargs = {}
    if hasattr(jax.sharding, "AxisType"):
        kwargs["axis_types"] = (jax.sharding.AxisType.Auto,) * 2
    mesh = jax.make_mesh((4, 2), ("data", "model"), **kwargs)
    img, _ = phantom.phantom_slice(256, 256, seed=11)
    x = img.ravel().astype(np.float32)

    single = SV.solve(SV.pixel_problem(x), backend="reference",
                      max_iters=300)
    sharded = D.fit_sharded(x, mesh, F.FCMConfig(max_iters=300))
    np.testing.assert_allclose(np.sort(np.asarray(single.centers)),
                               np.sort(np.asarray(sharded.centers)),
                               atol=0.75)
    agree = (np.asarray(single.labels) == np.asarray(sharded.labels)).mean()
    assert agree > 0.995, agree

    hist = D.fit_sharded(x, mesh, F.FCMConfig(max_iters=300), histogram=True)
    np.testing.assert_allclose(np.sort(np.asarray(sharded.centers)),
                               np.sort(np.asarray(hist.centers)), atol=0.75)

    # Odd N exercising the padding path.
    x_odd = x[:50021]
    s2 = D.fit_sharded(x_odd, mesh, F.FCMConfig(max_iters=300))
    f2 = SV.solve(SV.pixel_problem(x_odd), backend="reference",
                  max_iters=300)
    np.testing.assert_allclose(np.sort(np.asarray(s2.centers)),
                               np.sort(np.asarray(f2.centers)), atol=0.75)
    assert s2.labels.shape[0] == 50021

    # Batched multi-image fit with the batch axis split over the mesh:
    # every lane must match the unsharded batched fit, including the
    # pad-to-mesh-size path (10 lanes on 8 devices -> 6 padding lanes).
    imgs = [phantom.phantom_slice(64 + 8 * (z % 3), 96,
                                  slice_pos=0.3 + 0.04 * z, seed=z)[0]
            for z in range(10)]
    hists = B.histograms_of(imgs)
    import warnings  # the adapter pair under test warns by design
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        local = B.fit_batched(hists, F.FCMConfig(max_iters=300))
    shard = B.fit_batched_sharded(hists, mesh, F.FCMConfig(max_iters=300))
    np.testing.assert_allclose(np.asarray(shard.centers),
                               np.asarray(local.centers), atol=1e-4)
    np.testing.assert_array_equal(shard.n_iters, local.n_iters)

    print("DIST_OK")


if __name__ == "__main__":
    main()
