"""Attention paths: chunked online-softmax (flash) vs plain parity,
GQA repeat correctness, causal masking, and SSM state streaming
(segment-wise == monolithic)."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro import configs
from repro.models import attention as A
from repro.models import ssm


def _qkv(b, hq, hkv, sq, skv, hd, seed=0, dtype=jnp.float32):
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.normal(0, 1, (b, hq, sq, hd)), dtype)
    k = jnp.asarray(rng.normal(0, 1, (b, hkv, skv, hd)), dtype)
    v = jnp.asarray(rng.normal(0, 1, (b, hkv, skv, hd)), dtype)
    return q, k, v


@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("hq,hkv", [(4, 4), (8, 2)])
def test_flash_matches_plain(causal, hq, hkv):
    q, k, v = _qkv(2, hq, hkv, 256, 256, 32)
    plain = A.grouped_attention(q, k, v, causal, flash_threshold=1 << 20)
    flash = A.grouped_attention(q, k, v, causal, flash_threshold=1,
                                q_chunk=64, kv_chunk=64)
    np.testing.assert_allclose(np.asarray(plain), np.asarray(flash),
                               rtol=2e-4, atol=2e-4)


def test_causal_mask_blocks_future():
    q, k, v = _qkv(1, 2, 2, 16, 16, 8, seed=1)
    out = A.grouped_attention(q, k, v, causal=True)
    # position 0 attends only to kv 0 -> output == v[:, :, 0]
    np.testing.assert_allclose(np.asarray(out[:, :, 0]),
                               np.asarray(v[:, :, 0]), rtol=1e-4,
                               atol=1e-5)


def test_kv_len_masking_matches_truncated():
    q, k, v = _qkv(2, 2, 2, 1, 32, 8, seed=2)
    full = A.grouped_attention(q, k[:, :, :20], v[:, :, :20], causal=False)
    masked = A.grouped_attention(q, k, v, causal=False,
                                 kv_len=jnp.asarray([20, 20]))
    np.testing.assert_allclose(np.asarray(full), np.asarray(masked),
                               rtol=1e-4, atol=1e-5)


def test_rwkv6_segment_streaming_matches_monolithic():
    cfg = configs.get_config("rwkv6-1.6b").reduced()
    p = ssm.init_rwkv6(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(0, 1, (2, 32, cfg.d_model)), jnp.float32)
    full = ssm.rwkv6_forward(p, x, cfg)
    y1, st = ssm.rwkv6_forward(p, x[:, :16], cfg, return_state=True)
    y2 = ssm.rwkv6_forward(p, x[:, 16:], cfg, state=st)
    np.testing.assert_allclose(np.asarray(full),
                               np.asarray(jnp.concatenate([y1, y2], 1)),
                               rtol=1e-4, atol=1e-4)


def test_mamba_segment_streaming_matches_monolithic():
    cfg = configs.get_config("jamba-v0.1-52b").reduced()
    p = ssm.init_mamba(jax.random.PRNGKey(1), cfg)
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.normal(0, 1, (2, 24, cfg.d_model)), jnp.float32)
    full = ssm.mamba_forward(p, x, cfg)
    y1, st = ssm.mamba_forward(p, x[:, :12], cfg, return_state=True)
    y2 = ssm.mamba_forward(p, x[:, 12:], cfg, state=st)
    np.testing.assert_allclose(np.asarray(full),
                               np.asarray(jnp.concatenate([y1, y2], 1)),
                               rtol=1e-4, atol=1e-4)
