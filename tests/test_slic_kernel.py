"""Interpret-mode parity of the SLIC Pallas assignment kernel against
the pure-jnp reference, on tile-aligned, non-128-multiple, and
border-heavy shapes (CI runs this file in the kernel-parity lane)."""
import numpy as np
import pytest

from repro.data import phantom
from repro.kernels import ops as kops
from repro.superpixel import slic as SL

# (H, W, n_segments): aligned, ragged both axes, width-dominant strip
# (every pixel row borders padding), and a tall sliver.
SHAPES = [(64, 128, 48), (37, 61, 12), (16, 300, 30), (129, 131, 100),
          (200, 40, 20)]


def _img(h, w, channels, seed=0):
    if channels == 1:
        return phantom.phantom_slice(h, w, seed=seed)[0].astype(np.float32)
    img, _ = phantom.phantom_slice_rgb(h, w, seed=seed)
    return img.astype(np.float32)[:, :, :channels]


def _assign_both(img, centers, gy, gx, sw):
    """One assignment step through the reference and the kernel."""
    h, w = img.shape[:2]
    ref = np.asarray(SL.assign_ref(img, centers, gy, gx, sw))
    xpad, _ = kops.tile_channels(img)
    ker = np.asarray(kops.slic_assign(xpad, centers, h, w, gy, gx, sw,
                                      interpret=True))[:h, :w]
    return ref, ker


@pytest.mark.parametrize("h,w,segs", SHAPES)
@pytest.mark.parametrize("channels", [1, 3])
def test_assignment_step_parity(h, w, segs, channels):
    """A single assignment step agrees exactly: same candidate sets,
    same accumulation order, same lowest-index tie resolution."""
    img = _img(h, w, channels, seed=h + w + channels)
    gy, gx = SL.grid_shape(h, w, segs)
    sw = SL.spatial_weight(h, w, gy, gx, 10.0)
    centers = SL.seed_centers(img, gy, gx)
    ref, ker = _assign_both(img, centers, gy, gx, sw)
    assert ref.shape == ker.shape == (h, w)
    np.testing.assert_array_equal(ref, ker)


@pytest.mark.parametrize("h,w,segs", SHAPES)
def test_assignment_parity_after_center_drift(h, w, segs):
    """Parity must also hold off the seed grid: run a few reference
    iterations so centers sit at irregular positions, then compare."""
    img = _img(h, w, 3, seed=1)
    gy, gx = SL.grid_shape(h, w, segs)
    sw = SL.spatial_weight(h, w, gy, gx, 10.0)
    centers = SL.seed_centers(img, gy, gx)
    for _ in range(3):
        labels = SL.assign_ref(img, centers, gy, gx, sw)
        centers, _ = SL.update_centers(img, labels, centers)
    ref, ker = _assign_both(img, centers, gy, gx, sw)
    agree = float((ref == ker).mean())
    assert agree >= 0.999, agree


@pytest.mark.parametrize("h,w,segs", SHAPES[:3])
def test_full_fit_parity_and_broadcast(h, w, segs):
    """End-to-end fit_slic: label maps agree on >= 99.9% of pixels and
    a label broadcast through the two maps is byte-identical."""
    img = _img(h, w, 3, seed=2)
    params = SL.SLICParams(n_segments=segs)
    r_ref = SL.fit_slic(img, params)
    r_ker = SL.fit_slic(img, params, use_pallas=True, interpret=True)
    lab_ref = np.asarray(r_ref.labels)
    lab_ker = np.asarray(r_ker.labels)
    assert lab_ref.shape == lab_ker.shape == (h, w)
    assert lab_ker.dtype == np.int32
    agree = float((lab_ref == lab_ker).mean())
    assert agree >= 0.999, agree
    # Byte-identical broadcast: any per-superpixel coloring gathered
    # through the two maps must match wherever the maps agree (and the
    # maps themselves are byte-identical when agreement is exact).
    k = r_ref.centers.shape[0]
    coloring = np.arange(k, dtype=np.int32) % 7
    b_ref, b_ker = coloring[lab_ref], coloring[lab_ker]
    if agree == 1.0:
        assert b_ref.tobytes() == b_ker.tobytes()
    else:
        assert (b_ref == b_ker).mean() >= 0.999


def test_labels_cover_every_nonempty_superpixel():
    img = _img(96, 96, 3)
    res = SL.fit_slic(img, SL.SLICParams(n_segments=64), use_pallas=True,
                      interpret=True)
    lab = np.asarray(res.labels)
    counts = np.asarray(res.counts)
    assert lab.min() >= 0 and lab.max() < res.gy * res.gx
    # counts from the validity-weighted update match the label map
    np.testing.assert_allclose(
        np.bincount(lab.ravel(), minlength=res.gy * res.gx), counts)
    assert counts.sum() == img.shape[0] * img.shape[1]


def test_auto_block_rows_respects_vmem_budget():
    from repro.kernels.slic_assign import LANES, auto_block_rows

    for k, w in [(64, 96), (256, 512), (256, 2048), (1024, 4096)]:
        rows = auto_block_rows(k, w)
        kp = k + (-k) % LANES
        wp = w + (-w) % LANES
        assert 1 <= rows <= 64
        # either within the 4 MB budget, or already at the floor of 1
        assert kp * rows * wp * 4 <= 4 * 1024 * 1024 or rows == 1
        if rows >= 8:
            assert rows % 8 == 0
    # small problems get deep blocks, wide ones get shallow blocks
    assert auto_block_rows(64, 96) == 64
    assert auto_block_rows(256, 2048) < 8


def test_parity_with_auto_block_rows():
    """fit_slic's auto-sized row blocks (here 64, not the old 8) must
    not change the labels: the grid split is invisible to the argmin."""
    img = _img(70, 90, 3, seed=9)
    params = SL.SLICParams(n_segments=24)
    r_auto = SL.fit_slic(img, params, use_pallas=True, interpret=True)
    r_8 = SL.fit_slic(img, params, use_pallas=True, block_rows=8,
                      interpret=True)
    np.testing.assert_array_equal(np.asarray(r_auto.labels),
                                  np.asarray(r_8.labels))


def test_padded_pixels_do_not_leak_into_centers():
    """A width that pads by 67 lanes: center feature means must stay
    inside the true data range (padding rows carry weight 0)."""
    img = np.full((24, 61), 200.0, np.float32)
    res = SL.fit_slic(img, SL.SLICParams(n_segments=6), use_pallas=True,
                      interpret=True)
    feats = np.asarray(res.centers[:, 0])
    counts = np.asarray(res.counts)
    np.testing.assert_allclose(feats[counts > 0], 200.0, atol=1e-4)
