"""Pallas selective-scan kernel vs the exact lax.scan oracle,
swept over shapes, tiles and dtypes (interpret=True on CPU)."""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.kernels import ref
from repro.kernels.selective_scan import selective_scan_pallas


def _data(b, s, di, ds, dtype=jnp.float32, seed=0):
    rng = np.random.default_rng(seed)
    u = jnp.asarray(rng.normal(0, 1, (b, s, di)), dtype)
    dt = jnp.asarray(rng.uniform(1e-3, 0.1, (b, s, di)), dtype)
    bmat = jnp.asarray(rng.normal(0, 1, (b, s, ds)), dtype)
    cmat = jnp.asarray(rng.normal(0, 1, (b, s, ds)), dtype)
    a = jnp.asarray(-rng.uniform(0.5, 4.0, (di, ds)), jnp.float32)
    return u, dt, bmat, cmat, a


@pytest.mark.parametrize("b,s,di,ds", [
    (1, 128, 64, 4), (2, 256, 128, 16), (1, 512, 64, 8)])
def test_matches_scan_oracle(b, s, di, ds):
    u, dt, bmat, cmat, a = _data(b, s, di, ds)
    got = selective_scan_pallas(u, dt, bmat, cmat, a, di_tile=64,
                                seq_blk=128, interpret=True)
    want = ref.selective_scan_ref(u, dt, bmat, cmat, a)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("di_tile,seq_blk", [(32, 64), (64, 128),
                                             (128, 256)])
def test_tile_sweep(di_tile, seq_blk):
    u, dt, bmat, cmat, a = _data(1, 256, 128, 8, seed=1)
    got = selective_scan_pallas(u, dt, bmat, cmat, a, di_tile=di_tile,
                                seq_blk=seq_blk, interpret=True)
    want = ref.selective_scan_ref(u, dt, bmat, cmat, a)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


def test_bf16_inputs():
    u, dt, bmat, cmat, a = _data(1, 128, 64, 4, dtype=jnp.bfloat16, seed=2)
    got = selective_scan_pallas(u, dt, bmat, cmat, a, di_tile=64,
                                seq_blk=64, interpret=True)
    want = ref.selective_scan_ref(u, dt, bmat, cmat, a)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=3e-2, atol=3e-2)


def test_state_continuity_across_seq_blocks():
    """A long decay chain must carry state across seq blocks exactly."""
    b, s, di, ds = 1, 512, 32, 4
    rng = np.random.default_rng(3)
    u = jnp.asarray(np.ones((b, s, di)), jnp.float32)
    dt = jnp.asarray(np.full((b, s, di), 0.05), jnp.float32)
    bmat = jnp.asarray(np.ones((b, s, ds)), jnp.float32)
    cmat = jnp.asarray(np.ones((b, s, ds)), jnp.float32)
    a = jnp.asarray(-np.full((di, ds), 0.1), jnp.float32)
    got = selective_scan_pallas(u, dt, bmat, cmat, a, di_tile=32,
                                seq_blk=64, interpret=True)
    want = ref.selective_scan_ref(u, dt, bmat, cmat, a)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4)
    # state visibly accumulates beyond one block
    assert float(got[0, -1, 0]) > float(got[0, 63, 0]) * 1.5
