"""Chaos suite: deterministic fault injection against the serving stack.

Every scenario here pins the contract of ISSUE PR 10: under injected
failures (launch errors, NaN/Inf payloads, flusher death, overload)
every submitted request resolves exactly once — with a correct result
or a *typed* error — and degraded paths stay center-for-center close
(<= 1e-5) to the fault-free run. All injection is driven by a seeded
:class:`repro.faults.FaultPlan`, so a failure here replays bit-for-bit.

CI runs this file as its own chaos lane (fixed seeds throughout).
"""
import threading
import time

import numpy as np
import pytest

from repro import faults as FI
from repro.core import batched as B
from repro.core import fcm as F
from repro.core import solver as SV
from repro.data import phantom
from repro.serving import (FCMServeEngine, InvalidInput, Overloaded,
                           SolveFailed)

CFG = F.FCMConfig(max_iters=100)
ATOL = 1e-5


def _imgs(n, size=20):
    return [phantom.phantom_slice(size, size, noise=4.0 + (i % 3),
                                  seed=300 + i)[0] for i in range(n)]


def _engine(**kw):
    kw.setdefault("cache_size", 0)
    kw.setdefault("batch_sizes", (1, 4))
    return FCMServeEngine(CFG, **kw)


def _clean_run(imgs):
    eng = _engine()
    for im in imgs:
        eng.submit(im)
    res = {r.request_id: r for r in eng.flush()}
    eng.shutdown()
    return res


@pytest.fixture(autouse=True)
def _no_global_injector():
    # Tests that install the process-global injector must never leak it
    # into the next test (or the rest of the suite).
    yield
    FI.clear()


# -- plan / injector unit behavior -------------------------------------------

def test_fault_spec_rejects_unknown_kind():
    with pytest.raises(ValueError, match="unknown fault kind"):
        FI.FaultSpec(site="launch", kind="segfault")


def test_window_firing_is_deterministic():
    spec = FI.FaultSpec(site="launch", kind="error", after=2, times=3)
    inj = FI.FaultInjector(FI.FaultPlan(seed=0, specs=(spec,)))
    outcomes = []
    for _ in range(8):
        try:
            inj.maybe_fail("launch")
            outcomes.append(False)
        except FI.InjectedFault:
            outcomes.append(True)
    # hits 0,1 pass; hits 2,3,4 fire; hits 5+ pass again.
    assert outcomes == [False, False, True, True, True, False, False, False]
    snap = inj.snapshot()
    assert snap == {"seed": 0, "injected": 3, "by_site": {"launch": 3},
                    "chaos": True}


def test_probabilistic_firing_replays_with_same_seed():
    spec = FI.FaultSpec(site="launch", kind="error", p=0.5, times=None)

    def pattern(seed):
        inj = FI.FaultInjector(FI.FaultPlan(seed=seed, specs=(spec,)))
        out = []
        for _ in range(64):
            try:
                inj.maybe_fail("launch")
                out.append(0)
            except FI.InjectedFault:
                out.append(1)
        return out

    a, b = pattern(7), pattern(7)
    assert a == b                       # same seed => same chaos
    assert 0 < sum(a) < 64              # actually probabilistic
    assert pattern(8) != a              # seed matters


def test_route_filter_and_corrupt_lanes():
    plan = FI.FaultPlan(seed=0, specs=(
        FI.FaultSpec(site="solve", kind="nan", route="histogram",
                     lanes=(1, 3)),))
    inj = FI.FaultInjector(plan)
    arr = np.zeros((4, 4), np.float32)
    # Wrong route: untouched (and identity — no silent copies).
    assert inj.corrupt("solve", arr, route="pixel") is arr
    out = inj.corrupt("solve", arr, route="histogram")
    assert np.isnan(out[1]).all() and np.isnan(out[3]).all()
    assert np.isfinite(out[0]).all() and np.isfinite(out[2]).all()
    assert np.isfinite(arr).all()       # input never mutated


def test_latency_injection_sleeps_then_succeeds():
    plan = FI.FaultPlan(seed=0, specs=(
        FI.FaultSpec(site="ingest", kind="latency", latency_s=0.05),))
    inj = FI.FaultInjector(plan)
    t0 = time.perf_counter()
    inj.maybe_fail("ingest")            # fires: sleeps, no raise
    assert time.perf_counter() - t0 >= 0.04
    assert inj.snapshot()["by_site"] == {"ingest": 1}


# -- transient launch failure: retry absorbs it ------------------------------

def test_transient_launch_failure_retried_to_parity():
    imgs = _imgs(2)
    clean = _clean_run(imgs)
    plan = FI.FaultPlan(seed=3, specs=(
        FI.FaultSpec(site="launch", kind="error", route="histogram",
                     times=1),))
    eng = _engine(faults=plan, retries=2, retry_backoff_s=0.0)
    for im in imgs:
        eng.submit(im)
    res = {r.request_id: r for r in eng.flush()}
    st = eng.stats()
    assert st["fault_tolerance"]["retries"]["histogram"] == 1
    assert st["fault_tolerance"]["degraded"]["histogram"] == 0
    assert st["fault_tolerance"]["breaker_state"].get(
        "histogram", "closed") == "closed"
    assert st["faults"]["injected"] == 1 and st["faults"]["chaos"]
    for i in clean:
        np.testing.assert_allclose(res[i].centers, clean[i].centers,
                                   atol=ATOL)
    eng.shutdown()


# -- persistent launch failure: breaker trips, reference fallback ------------

def test_breaker_trips_and_reference_fallback_matches():
    imgs = _imgs(1)
    clean = _clean_run(imgs)
    plan = FI.FaultPlan(seed=5, specs=(
        FI.FaultSpec(site="launch", kind="error", route="histogram",
                     times=None),))      # every launch attempt fails
    eng = _engine(faults=plan, retries=1, retry_backoff_s=0.0,
                  breaker_threshold=2, breaker_cooldown_s=1000.0)
    last = None
    for _ in range(4):
        eng.submit(imgs[0])
        last = eng.flush()[0]
    st = eng.stats()
    ft = st["fault_tolerance"]
    assert ft["breaker_state"]["histogram"] == "open"
    assert ft["breaker_trips"]["histogram"] == 1
    # Flushes 1-2 burn a retry each then degrade; once open, flushes
    # 3-4 go straight to the reference path without touching the
    # program (no further retries).
    assert ft["retries"]["histogram"] == 2
    assert ft["degraded"]["histogram"] == 2
    np.testing.assert_allclose(last.centers, clean[0].centers, atol=ATOL)
    assert not eng.readiness()["ready"]     # open breaker = not ready
    assert eng.healthy()                    # ...but degraded, not dead
    eng.shutdown()


def test_breaker_half_open_probe_recovers():
    imgs = _imgs(1)
    plan = FI.FaultPlan(seed=5, specs=(
        FI.FaultSpec(site="launch", kind="error", route="histogram",
                     times=1),))          # exactly one failing launch
    eng = _engine(faults=plan, retries=0, breaker_threshold=1,
                  breaker_cooldown_s=0.0)
    eng.submit(imgs[0])
    eng.flush()                           # fails -> trips open
    assert eng.stats()["fault_tolerance"]["breaker_state"][
        "histogram"] == "open"
    eng.submit(imgs[0])
    eng.flush()                           # cooldown=0: half-open probe, OK
    st = eng.stats()["fault_tolerance"]
    assert st["breaker_state"]["histogram"] == "closed"
    assert st["breaker_trips"]["histogram"] == 1
    assert eng.readiness()["ready"]
    eng.shutdown()


def test_half_open_probe_failure_reopens():
    imgs = _imgs(1)
    plan = FI.FaultPlan(seed=5, specs=(
        FI.FaultSpec(site="launch", kind="error", route="histogram",
                     times=None),))
    eng = _engine(faults=plan, retries=0, breaker_threshold=1,
                  breaker_cooldown_s=0.0)
    eng.submit(imgs[0])
    eng.flush()                           # trip
    eng.submit(imgs[0])
    eng.flush()                           # probe fails -> re-open
    st = eng.stats()["fault_tolerance"]
    assert st["breaker_state"]["histogram"] == "open"
    assert st["breaker_trips"]["histogram"] == 2
    eng.shutdown()


# -- NaN/Inf poisoning: per-lane salvage -------------------------------------

@pytest.mark.parametrize("kind", ["nan", "inf"])
def test_poisoned_lane_salvaged_healthy_lanes_bitwise(kind):
    imgs = _imgs(4)
    clean = _clean_run(imgs)
    plan = FI.FaultPlan(seed=11, specs=(
        FI.FaultSpec(site="solve", kind=kind, route="histogram",
                     lanes=(1,), times=1),))
    eng = _engine(faults=plan, batch_sizes=(4,))
    for im in imgs:
        eng.submit(im)
    res = {r.request_id: r for r in eng.flush()}
    assert len(res) == 4
    for i, r in res.items():
        assert np.isfinite(r.centers).all()
    # Healthy batchmates must be BITWISE untouched by the salvage.
    for i in (0, 2, 3):
        np.testing.assert_array_equal(res[i].centers, clean[i].centers)
        assert (res[i].labels == clean[i].labels).all()
    # The salvaged lane re-solved on reference: close, labeled, counted.
    np.testing.assert_allclose(res[1].centers, clean[1].centers, atol=ATOL)
    st = eng.stats()
    assert st["fault_tolerance"]["salvaged"]["histogram"] == 1
    eng.shutdown()


def test_salvaged_centers_never_enter_cache():
    img = _imgs(1)[0]
    plan = FI.FaultPlan(seed=11, specs=(
        FI.FaultSpec(site="solve", kind="nan", route="histogram",
                     lanes=(0,), times=1),))
    eng = _engine(cache_size=16, faults=plan)
    eng.submit(img)
    r1 = eng.flush()[0]
    assert np.isfinite(r1.centers).all() and not r1.cache_hit
    # Same payload again: if the poisoned program centers had been
    # cached, this hit would serve garbage. The salvage path caches the
    # clean reference centers instead, so the hit matches the salvage.
    eng.submit(img.copy())
    r2 = eng.flush()[0]
    assert r2.cache_hit
    np.testing.assert_array_equal(r2.centers, r1.centers)
    eng.shutdown()


def test_solver_level_corruption_salvaged_via_global_injector():
    rng = np.random.default_rng(0)
    hists = rng.integers(0, 50, (3, 256)).astype(np.float32)
    batch = SV.batch_problems(B.hist_rows(hists), hists, cfg=CFG)
    clean = SV.solve_batched(batch, CFG)
    FI.install(FI.FaultPlan(seed=13, specs=(
        FI.FaultSpec(site="solve_batched", kind="nan", lanes=(2,),
                     times=1),)))
    try:
        res = SV.solve_batched(batch, CFG)
    finally:
        FI.clear()
    assert np.isfinite(np.asarray(res.centers)).all()
    assert res.salvaged is not None and res.salvaged.tolist() == [
        False, False, True]
    assert res.healthy.all()
    np.testing.assert_allclose(np.asarray(res.centers),
                               np.asarray(clean.centers), atol=ATOL)
    # Untouched lanes bitwise identical to the clean run.
    np.testing.assert_array_equal(np.asarray(res.centers)[:2],
                                  np.asarray(clean.centers)[:2])


def test_solve_batched_salvage_opt_out():
    rng = np.random.default_rng(0)
    hists = rng.integers(0, 50, (2, 256)).astype(np.float32)
    batch = SV.batch_problems(B.hist_rows(hists), hists, cfg=CFG)
    FI.install(FI.FaultPlan(seed=13, specs=(
        FI.FaultSpec(site="solve_batched", kind="nan", lanes=(0,),
                     times=1),)))
    try:
        res = SV.solve_batched(batch, CFG, salvage=False)
    finally:
        FI.clear()
    # salvage=False surfaces the poison honestly instead of hiding it.
    assert not res.healthy[0] and res.healthy[1]
    assert not np.isfinite(np.asarray(res.centers)[0]).all()


def test_kernel_site_injection_raises_typed():
    from repro.kernels import ops as kops
    FI.install(FI.FaultPlan(seed=0, specs=(
        FI.FaultSpec(site="kernel", kind="error", times=1),)))
    try:
        with pytest.raises(FI.InjectedFault):
            kops.select_step("flat")
    finally:
        FI.clear()
    kops.select_step("flat")            # clean after clear()


# -- flusher death ------------------------------------------------------------

def test_flusher_kill_restarts_and_resolves_all():
    plan = FI.FaultPlan(seed=2, specs=(
        FI.FaultSpec(site="flusher", kind="kill", times=1),))
    eng = _engine(faults=plan, max_wait_ms=5.0)
    futs = [eng.submit_async(im) for im in _imgs(3)]
    for f in futs:
        r = f.result(timeout=60)
        assert np.isfinite(r.centers).all()
    assert eng._flusher_kills == 1
    st = eng.stats()["fault_tolerance"]
    assert st["flusher_kills"] == 1 and st["flusher_restarts"] >= 1
    rd = eng.readiness()
    assert rd["healthy"] and rd["flusher_restarts"] >= 1
    eng.shutdown()


def test_flusher_survives_repeated_kills():
    plan = FI.FaultPlan(seed=2, specs=(
        FI.FaultSpec(site="flusher", kind="kill", times=3),))
    eng = _engine(faults=plan, max_wait_ms=5.0)
    for im in _imgs(3):
        fut = eng.submit_async(im)
        assert np.isfinite(fut.result(timeout=60).centers).all()
    assert eng._flusher_kills >= 1
    eng.shutdown()


# -- overload shedding --------------------------------------------------------

def test_overload_sheds_lowest_urgency_with_typed_error():
    imgs = _imgs(3)
    eng = _engine(max_queue_depth=2, max_wait_ms=100_000.0)
    loose = eng.submit_async(imgs[0], deadline=100.0)
    mid = eng.submit_async(imgs[1], deadline=50.0)
    tight = eng.submit_async(imgs[2], deadline=1.0)   # displaces `loose`
    assert loose.done() and isinstance(loose.exception(), Overloaded)
    assert not mid.done() and not tight.done()
    assert eng.stats()["fault_tolerance"]["shed"]["histogram"] == 1
    eng.drain()
    assert mid.result(timeout=10).labels.shape == imgs[1].shape
    assert tight.result(timeout=10).labels.shape == imgs[2].shape
    eng.shutdown()


def test_overload_rejects_incoming_when_least_urgent():
    imgs = _imgs(3)
    eng = _engine(max_queue_depth=2, max_wait_ms=100_000.0)
    a = eng.submit_async(imgs[0], deadline=5.0)
    b = eng.submit_async(imgs[1], deadline=5.0)
    lazy = eng.submit_async(imgs[2])                 # no deadline: least urgent
    assert lazy.done() and isinstance(lazy.exception(), Overloaded)
    assert not a.done() and not b.done()
    eng.drain()
    for f in (a, b):
        assert f.result(timeout=10) is not None
    eng.shutdown()


def test_sync_submit_never_shed():
    # Queue-depth shedding only fails futures; the sync path has no
    # future to fail, so sync submits always enqueue.
    imgs = _imgs(3)
    eng = _engine(max_queue_depth=1, max_wait_ms=100_000.0)
    for im in imgs:
        eng.submit(im)
    assert len(eng.flush()) == 3
    eng.shutdown()


# -- input validation at ingest ----------------------------------------------

def test_nan_payload_rejected_sync_and_async():
    eng = _engine()
    bad = np.full((8, 8), np.nan, np.float32)
    with pytest.raises(InvalidInput):
        eng.submit(bad)
    before = eng._next_id
    fut = eng.submit_async(bad)
    assert fut.done() and isinstance(fut.exception(), InvalidInput)
    assert eng._next_id == before       # no id, no queue slot consumed
    assert eng.queue_depth == 0
    assert eng.stats()["fault_tolerance"][
        "invalid_input"]["histogram"] == 2
    eng.shutdown()


def test_empty_and_inf_payloads_rejected():
    eng = _engine()
    with pytest.raises(InvalidInput):
        eng.submit(np.zeros((0, 0), np.uint8))
    with pytest.raises(InvalidInput):
        eng.submit(np.array([[np.inf, 1.0]], np.float32), method="pixel")
    # Integer payloads skip the finite scan entirely and still work.
    eng.submit(_imgs(1)[0])
    assert len(eng.flush()) == 1
    eng.shutdown()


def test_ingest_fault_rejected_before_id_allocation():
    plan = FI.FaultPlan(seed=0, specs=(
        FI.FaultSpec(site="ingest", kind="error", times=1),))
    eng = _engine(faults=plan)
    img = _imgs(1)[0]
    before = eng._next_id
    fut = eng.submit_async(img)
    assert fut.done()
    assert eng._next_id == before
    # Next submit is clean (times=1) and resolves normally.
    ok = eng.submit_async(img)
    eng.drain()
    assert np.isfinite(ok.result(timeout=10).centers).all()
    eng.shutdown()


# -- degenerate solves --------------------------------------------------------

def test_constant_image_zero_variance():
    # All-one-value image: zero-range histogram, every distance tie.
    img = np.full((16, 16), 97, np.uint8)
    eng = _engine()
    eng.submit(img)
    r = eng.flush()[0]
    assert np.isfinite(r.centers).all()
    assert (r.labels >= 0).all() and (r.labels < CFG.n_clusters).all()
    eng.shutdown()


def test_more_clusters_than_distinct_values():
    img = np.where(np.indices((12, 12)).sum(0) % 2 == 0, 10, 200
                   ).astype(np.uint8)                # 2 distinct values
    cfg = F.FCMConfig(n_clusters=6, max_iters=100)
    eng = FCMServeEngine(cfg, cache_size=0, batch_sizes=(1, 4))
    eng.submit(img)
    r = eng.flush()[0]
    assert np.isfinite(r.centers).all() and r.centers.shape == (6,)
    # The two value populations must land on different clusters.
    assert len(np.unique(r.labels)) == 2
    eng.shutdown()


def test_constant_lane_inside_mixed_batch():
    imgs = _imgs(3) + [np.full((20, 20), 42, np.uint8)]
    clean = _clean_run(imgs[:3])
    eng = _engine(batch_sizes=(4,))
    for im in imgs:
        eng.submit(im)
    res = {r.request_id: r for r in eng.flush()}
    assert all(np.isfinite(r.centers).all() for r in res.values())
    # The degenerate lane must not perturb its healthy batchmates.
    for i in range(3):
        np.testing.assert_array_equal(res[i].centers, clean[i].centers)
    eng.shutdown()


# -- convergence / health signals on results ---------------------------------

def test_result_reports_nonconvergence_honestly():
    cfg = F.FCMConfig(max_iters=2)      # nothing converges in 2 iters
    eng = FCMServeEngine(cfg, cache_size=0, batch_sizes=(1, 4))
    eng.submit(_imgs(1, size=32)[0])
    r = eng.flush()[0]
    assert r.converged is False
    assert np.isfinite(r.centers).all()
    eng.shutdown()


def test_solve_result_converged_flag():
    img, _ = phantom.phantom_slice(24, 24, seed=9)
    ok = SV.solve(SV.histogram_problem(img, CFG), CFG)
    assert ok.converged and ok.healthy
    capped = SV.solve(SV.histogram_problem(img, CFG), max_iters=1)
    assert not capped.converged and capped.healthy


# -- bench provenance: injected runs can't pose as clean ----------------------

def test_faults_bench_section_schema():
    from benchmarks import bench_schema as BS
    BS.check_faults_section(FI.clean_snapshot())
    inj = FI.FaultInjector(FI.FaultPlan(seed=1, specs=(
        FI.FaultSpec(site="launch", kind="error"),)))
    with pytest.raises(FI.InjectedFault):
        inj.maybe_fail("launch")
    BS.check_faults_section(inj.snapshot())     # chaos honestly flagged
    with pytest.raises(ValueError, match="masquerade|pose as a clean"):
        BS.check_faults_section({"seed": 1, "injected": 2,
                                 "by_site": {"launch": 2},
                                 "chaos": False})
    with pytest.raises(ValueError, match="by_site totals"):
        BS.check_faults_section({"seed": 1, "injected": 2,
                                 "by_site": {"launch": 1}, "chaos": True})


def test_engine_stats_carry_faults_provenance():
    eng = _engine()
    assert eng.stats()["faults"] == FI.clean_snapshot()
    eng.shutdown()
    plan = FI.FaultPlan(seed=9, specs=(
        FI.FaultSpec(site="launch", kind="error", times=1),))
    eng2 = _engine(faults=plan, retries=1, retry_backoff_s=0.0)
    eng2.submit(_imgs(1)[0])
    eng2.flush()
    snap = eng2.stats()["faults"]
    assert snap["chaos"] and snap["seed"] == 9 and snap["injected"] == 1
    eng2.shutdown()


# -- every-future-resolves under concurrent chaos -----------------------------

def test_chaotic_async_storm_every_future_resolves_once():
    # Launch faults + a flusher kill + concurrent submitters: every
    # future must resolve exactly once with a result or a typed error.
    plan = FI.FaultPlan(seed=42, specs=(
        FI.FaultSpec(site="launch", kind="error", p=0.4, times=None),
        FI.FaultSpec(site="flusher", kind="kill", after=1, times=1),))
    eng = _engine(faults=plan, retries=1, retry_backoff_s=0.0,
                  breaker_threshold=2, breaker_cooldown_s=0.01,
                  batch_sizes=(1, 4), max_wait_ms=5.0)
    imgs = _imgs(10)
    futs = []

    def submitter(i):
        futs.append(eng.submit_async(imgs[i]))

    threads = [threading.Thread(target=submitter, args=(i,))
               for i in range(len(imgs))]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    resolved = 0
    for f in futs:
        r = f.result(timeout=120)
        assert np.isfinite(r.centers).all()
        resolved += 1
    assert resolved == len(imgs)
    eng.shutdown()
    # Post-shutdown: no leaked pending futures.
    assert eng.stats()["pending_futures"] == 0
